"""Table 2 (structural): MACE with Gaunt many-body products vs CG fold —
train-step wall time and compiled peak memory (memory_analysis), the two
quantities the paper reports (43.7x speed / 5.8% memory vs e3nn at scale)."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.gaunt_ff import gaunt_mace_ff
from repro.data import lj_dataset
from repro.models.equivariant import MaceGaunt

from .common import time_fn


def _step_cost(impl: str, L=2, nu=3):
    cfg = dataclasses.replace(gaunt_mace_ff, tp_impl=impl, L=L, nu=nu, channels=16,
                              n_layers=1)
    m = MaceGaunt(cfg)
    params = m.init(jax.random.PRNGKey(0))
    data = lj_dataset(4, n_atoms=6, n_species=cfg.n_species, seed=0)
    batch = {k: jnp.asarray(v) for k, v in data.items()}
    if impl == "cg":
        # CG comparison at the many-body site: replace the Gaunt self-product
        # with the iterated CG fold inside the same model (loss wiring equal)
        from repro.core.cg import cg_full_tensor_product
        from repro.core import manybody as mb

        orig = mb.manybody_selfmix

        def cg_selfmix(x, L, nu, Lout=None, weights=None, **kw):
            acc = x
            La = L
            for i in range(nu - 1):
                out_deg = (Lout if i == nu - 2 and Lout is not None else La + L)
                acc = cg_full_tensor_product(acc, x, La, L, out_deg)
                La = out_deg
            return acc

        import repro.models.equivariant as eq

        eq.manybody_selfmix = cg_selfmix
        try:
            grad_fn = jax.jit(jax.grad(m.loss))
            t = time_fn(grad_fn, params, batch, iters=5)
            mem = jax.jit(jax.grad(m.loss)).lower(params, batch).compile().memory_analysis()
        finally:
            eq.manybody_selfmix = orig
    else:
        grad_fn = jax.jit(jax.grad(m.loss))
        t = time_fn(grad_fn, params, batch, iters=5)
        mem = jax.jit(jax.grad(m.loss)).lower(params, batch).compile().memory_analysis()
    peak = mem.temp_size_in_bytes + mem.argument_size_in_bytes
    return t, peak


def run(csv=True):
    t_cg, m_cg = _step_cost("cg")
    t_g, m_g = _step_cost("gaunt")
    if csv:
        print(f"table2_mace_cg,{t_cg:.1f},peak_bytes={m_cg}")
        print(f"table2_mace_gaunt,{t_g:.1f},peak_bytes={m_g}")
        print(f"table2_mace_speedup,{t_cg/t_g:.3f},memory_ratio={m_g/max(m_cg,1):.3f}")
    return t_cg, t_g, m_cg, m_g


if __name__ == "__main__":
    run()
