"""Fig 1(e) sanity check: SEGNN on the N-body task — Gaunt parameterization vs
Clebsch-Gordan parameterization must reach the same accuracy class."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.gaunt_ff import gaunt_segnn_nbody
from repro.data import nbody_dataset
from repro.models.equivariant import SegnnNBody

from .common import time_fn

STEPS = 40


def _train(impl: str, data, steps=STEPS, lr=5e-3):
    cfg = dataclasses.replace(gaunt_segnn_nbody, tp_impl=impl, channels=16, n_layers=2)
    m = SegnnNBody(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = {k: jnp.asarray(v) for k, v in data.items()}
    grad_fn = jax.jit(jax.value_and_grad(m.loss))
    losses = []
    for _ in range(steps):
        loss, g = grad_fn(params, batch)
        params = jax.tree.map(lambda p, gg: p - lr * gg, params, g)
        losses.append(float(loss))
    t_step = time_fn(lambda p: grad_fn(p, batch)[0], params, iters=5)
    return losses, t_step


def run(csv=True):
    data = nbody_dataset(16, horizon=300, seed=0)
    lc, tc = _train("cg", data)
    lg, tg = _train("gaunt", data)
    if csv:
        print(f"fig1e_sanity_nbody_cg,{tc:.1f},final_mse={lc[-1]:.5f}")
        print(f"fig1e_sanity_nbody_gaunt,{tg:.1f},final_mse={lg[-1]:.5f}")
        print(f"fig1e_sanity_nbody_ratio,{tg/tc:.3f},mse_ratio={lg[-1]/max(lc[-1],1e-9):.3f}")
    return lc, lg, tc, tg


if __name__ == "__main__":
    run()
