"""Table 1 (structural): the EquiformerV2 Gaunt-Selfmix layer — per-call cost
of the added Equivariant Feature Interaction at L=4 and L=6, Gaunt vs CG.
(OC20 training is out of scope for this container; the paper's claim we
reproduce computationally is that the *added layer* is affordable only with
the Gaunt parameterization.)"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.irreps import num_coeffs
from repro.models.equivariant import SelfmixLayer

from .common import record, time_fn

NODES = 128
CHANNELS = 16


def run(L_list=(2, 4, 6), csv=True):
    records = []
    for L in L_list:
        x = jnp.asarray(
            np.random.default_rng(0).normal(size=(NODES, CHANNELS, num_coeffs(L))),
            jnp.float32)
        out = []
        for impl in ("cg", "gaunt", "gaunt_fused", "gaunt_auto"):
            layer = SelfmixLayer(L=L, channels=CHANNELS, tp_impl=impl)
            params = layer.init(jax.random.PRNGKey(0))
            t = time_fn(jax.jit(lambda p, a, layer=layer: layer(p, a)), params, x)
            out.append((impl, t))
        base = out[0][1]
        for impl, t in out:
            record(records, f"table1_selfmix_L{L}_{impl}", t, echo=csv,
                   speedup=round(base / t, 2))
    return records


if __name__ == "__main__":
    run()
