"""Benchmark entrypoint: one function per paper table/figure, plus the
engine autotune sweep.  Prints ``name,us_per_call,derived`` CSV rows and
writes machine-readable records (per-benchmark µs + the engine's chosen
backend) to BENCH_gaunt.json so the perf trajectory is tracked across PRs.

    PYTHONPATH=src python -m benchmarks.run [--only fig1a,table2] [--fast]
        [--backend auto|<registered backend>] [--json BENCH_gaunt.json]
"""
from __future__ import annotations

import argparse
import json
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="all")
    ap.add_argument("--fast", action="store_true", help="smaller L sweeps")
    ap.add_argument("--backend", default="auto",
                    help="engine backend for engine-routed rows ('auto' = "
                         "measured autotune)")
    ap.add_argument("--json", default="BENCH_gaunt.json",
                    help="output path for machine-readable records "
                         "('' disables)")
    args = ap.parse_args()
    only = None if args.only == "all" else set(args.only.split(","))

    from . import (
        bench_engine,
        bench_equiformer_selfmix,
        bench_equivariant_conv,
        bench_feature_interaction,
        bench_manybody,
        bench_mace_gaunt,
        bench_sanity_nbody,
        bench_serve,
    )

    jobs = {
        "engine": lambda: bench_engine.run(
            L_list=(1, 2, 3, 6) if args.fast else (1, 2, 3, 4, 6, 8),
            B_list=(64, 1024) if args.fast else (64, 1024, 8192),
            backend=args.backend),
        "engine_batched": lambda: bench_engine.run_batched(backend=args.backend),
        "engine_chain": bench_engine.run_chain,
        "engine_chain_kernel": bench_engine.run_chain_kernel,
        "engine_grid_gate": bench_engine.run_grid_gate,
        "engine_mixed": bench_engine.run_mixed_precision,
        "engine_autotune_cache": bench_engine.run_autotune_cache,
        "serve": lambda: bench_serve.run_serve(fast=args.fast),
        "serve_chaos": lambda: bench_serve.run_serve_chaos(fast=args.fast),
        "fig1a": lambda: bench_feature_interaction.run(
            L_list=(1, 2, 3, 4) if args.fast else (1, 2, 3, 4, 5, 6, 8),
            backend=args.backend),
        "fig1b": lambda: bench_equivariant_conv.run(
            L_list=(1, 2, 3) if args.fast else (1, 2, 3, 4, 5, 6),
            backend=args.backend),
        "fig1cd": lambda: bench_manybody.run(backend=args.backend),
        "fig1e": bench_sanity_nbody.run,
        "table1": lambda: bench_equiformer_selfmix.run(
            L_list=(2, 4) if args.fast else (2, 4, 6)),
        "table2": bench_mace_gaunt.run,
    }
    print("name,us_per_call,derived")
    failed = []
    records = []
    for name, job in jobs.items():
        if only and name not in only:
            continue
        try:
            out = job()
            if out:
                records.extend(r for r in out if isinstance(r, dict))
        except Exception:  # noqa: BLE001
            failed.append(name)
            traceback.print_exc()
    if args.json and records:
        import jax

        payload = {
            "meta": {"fast": args.fast, "backend_arg": args.backend,
                     "jax": jax.__version__, "device": jax.default_backend()},
            "records": records,
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=1)
        print(f"wrote {len(records)} records to {args.json}", file=sys.stderr)
    if failed:
        print(f"FAILED: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
