"""Benchmark entrypoint: one function per paper table/figure.
Prints ``name,us_per_call,derived`` CSV rows.

    PYTHONPATH=src python -m benchmarks.run [--only fig1a,table2] [--fast]
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="all")
    ap.add_argument("--fast", action="store_true", help="smaller L sweeps")
    args = ap.parse_args()
    only = None if args.only == "all" else set(args.only.split(","))

    from . import (
        bench_equiformer_selfmix,
        bench_equivariant_conv,
        bench_feature_interaction,
        bench_manybody,
        bench_mace_gaunt,
        bench_sanity_nbody,
    )

    jobs = {
        "fig1a": lambda: bench_feature_interaction.run(
            L_list=(1, 2, 3, 4) if args.fast else (1, 2, 3, 4, 5, 6, 8)),
        "fig1b": lambda: bench_equivariant_conv.run(
            L_list=(1, 2, 3) if args.fast else (1, 2, 3, 4, 5, 6)),
        "fig1cd": bench_manybody.run,
        "fig1e": bench_sanity_nbody.run,
        "table1": lambda: bench_equiformer_selfmix.run(
            L_list=(2, 4) if args.fast else (2, 4, 6)),
        "table2": bench_mace_gaunt.run,
    }
    print("name,us_per_call,derived")
    failed = []
    for name, job in jobs.items():
        if only and name not in only:
            continue
        try:
            job()
        except Exception:  # noqa: BLE001
            failed.append(name)
            traceback.print_exc()
    if failed:
        print(f"FAILED: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
