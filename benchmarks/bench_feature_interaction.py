"""Fig 1(a): Equivariant Feature Interaction — Gaunt Tensor Product vs the
e3nn-style CG full tensor product, across max degree L.

Paper setting: pairs of features up to degree L, 128 channels.  On this CPU
container we use 128 channels x 4 batch rows and report per-call wall time
for: CG baseline, Gaunt (paper FFT path), Gaunt (direct conv), Gaunt
(fused sample-multiply-project = the TPU-kernel math via XLA).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import engine
from repro.core.cg import cg_full_tensor_product
from repro.core.gaunt import GauntTensorProduct
from repro.core.irreps import num_coeffs
from repro.kernels.ops import gaunt_tp_fused_xla

from .common import record, time_fn

ROWS = 4
CHANNELS = 128


def run(L_list=(1, 2, 3, 4, 5, 6, 8), backend: str = "auto", csv=True):
    records = []
    for L in L_list:
        x1 = jnp.asarray(np.random.default_rng(0).normal(size=(ROWS, CHANNELS, num_coeffs(L))),
                         jnp.float32)
        x2 = jnp.asarray(np.random.default_rng(1).normal(size=(ROWS, CHANNELS, num_coeffs(L))),
                         jnp.float32)

        cg = jax.jit(functools.partial(cg_full_tensor_product, L1=L, L2=L, Lout=L))
        t_cg = time_fn(cg, x1, x2)

        tp_fft = GauntTensorProduct(L, L, L, conversion="dense", conv="fft")
        t_fft = time_fn(jax.jit(tp_fft.__call__), x1, x2)

        tp_dir = GauntTensorProduct(L, L, L, conversion="dense", conv="direct")
        t_dir = time_fn(jax.jit(tp_dir.__call__), x1, x2)

        t_fused = time_fn(lambda a, b: gaunt_tp_fused_xla(a, b, L, L, L), x1, x2)

        # the engine's pick for this size (measured autotune unless pinned)
        p = engine.plan(L, L, L, batch_hint=ROWS * CHANNELS, requires_grad=False,
                        **({"tune": "measure"} if backend == "auto"
                           else {"backend": backend}))
        t_auto = time_fn(jax.jit(lambda a, b: p.apply(a, b)), x1, x2)

        record(records, f"fig1a_feature_interaction_L{L}_cg", t_cg, echo=csv, speedup=1.00)
        record(records, f"fig1a_feature_interaction_L{L}_gaunt_fft", t_fft, echo=csv,
               speedup=round(t_cg / t_fft, 2), backend="fft")
        record(records, f"fig1a_feature_interaction_L{L}_gaunt_direct", t_dir, echo=csv,
               speedup=round(t_cg / t_dir, 2), backend="direct")
        record(records, f"fig1a_feature_interaction_L{L}_gaunt_fused", t_fused, echo=csv,
               speedup=round(t_cg / t_fused, 2), backend="fused_xla")
        record(records, f"fig1a_feature_interaction_L{L}_gaunt_engine", t_auto, echo=csv,
               speedup=round(t_cg / t_auto, 2), backend=p.backend)
    return records


if __name__ == "__main__":
    run()
