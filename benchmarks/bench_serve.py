"""Serve load generator (DESIGN.md §10.5): open-loop QPS sweep and the
bucketed-vs-single-``max_atoms`` throughput comparison.

Two scenarios over a tiny MaceGaunt model and a MIXED-size molecular
workload (60% small / 30% medium / 10% large — the distribution bucketing
exists for):

- ``serve_bucketed_vs_single`` — closed loop: the same request stream
  drained through size-bucketed slot pools vs one fixed-``max_atoms`` slot
  array with the SAME total slot count.  Records wall time, throughput,
  padding efficiency for both, and the throughput speedup (the CI guard's
  acceptance signal: bucketing must beat worst-case padding on CPU).
- ``serve_qps{q}`` — open loop at each swept arrival rate: requests are
  submitted on a wall-clock schedule (arrival i at ``i/qps`` seconds) and
  the scheduler pumps the pipelined engine, admitting mid-flight.  Records
  p50/p99 total latency, achieved throughput, padding efficiency, and
  rejection counts straight from the serve metrics layer.

Both engines are warmed (per-bucket compiles excluded from timing) — serve
latency here is serving cost, not compile cost.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from .common import record

SIZE_CLASSES = ((2, 6, 0.6), (7, 12, 0.3), (13, 24, 0.1))
BUCKETS = ((6, 2), (12, 2), (24, 2))          # small/medium/large ladder
SINGLE_SLOTS = sum(n for _, n in BUCKETS)     # same concurrency, one bucket


def _tiny_model():
    import jax

    from repro.configs.gaunt_ff import gaunt_mace_ff
    from repro.models.equivariant import MaceGaunt

    cfg = dataclasses.replace(gaunt_mace_ff, channels=8, n_layers=1, L=1,
                              L_edge=1, n_species=4)
    model = MaceGaunt(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return model, params


def _workload(n_req: int, seed: int = 0):
    """Mixed-size request stream; deterministic."""
    from repro.serve.engine import EquivariantRequest

    rng = np.random.default_rng(seed)
    lo = np.array([c[0] for c in SIZE_CLASSES])
    hi = np.array([c[1] for c in SIZE_CLASSES])
    probs = np.array([c[2] for c in SIZE_CLASSES])
    cls = rng.choice(len(SIZE_CLASSES), size=n_req, p=probs)
    sizes = rng.integers(lo[cls], hi[cls] + 1)
    return [EquivariantRequest(
        species=rng.integers(0, 4, n),
        pos=(rng.normal(size=(n, 3)) * 1.5).astype(np.float32), rid=i)
        for i, n in enumerate(sizes)]


def _drain_timed(eng, reqs):
    t0 = time.monotonic()
    eng.run(reqs)
    return time.monotonic() - t0


def _open_loop(eng, reqs, qps: float) -> float:
    """Submit request i at wall-clock ``i/qps`` seconds; pump the pipelined
    engine (admissions overlap in-flight steps).  Returns elapsed seconds."""
    from repro.serve.scheduler import Scheduler

    sched = Scheduler(eng)
    arrivals = [i / qps for i in range(len(reqs))]
    t0 = time.monotonic()
    i = 0

    def feed():
        nonlocal i
        now = time.monotonic() - t0
        while i < len(reqs) and arrivals[i] <= now:
            sched.submit(reqs[i])
            i += 1

    while True:
        feed()
        if not sched.pump(poll=feed) and i >= len(reqs):
            break
        if not eng.has_active() and not len(sched.queue) and i < len(reqs):
            time.sleep(min(0.002, max(0.0, arrivals[i] -
                                      (time.monotonic() - t0))))
    return time.monotonic() - t0


def run_serve(fast: bool = True, csv: bool = True, qps_list=None,
              n_req: int | None = None):
    from repro.serve.engine import EquivariantServeEngine

    records = []
    model, params = _tiny_model()
    n_req = n_req or (24 if fast else 96)
    qps_list = qps_list or ((20.0, 60.0) if fast else (10.0, 30.0, 100.0))

    # ---------------- closed loop: bucketed vs single-max_atoms ------------
    bucketed = EquivariantServeEngine(model, params, buckets=BUCKETS)
    bucketed.warmup()
    single = EquivariantServeEngine(model, params, n_slots=SINGLE_SLOTS,
                                    max_atoms=max(b[0] for b in BUCKETS))
    single.warmup()
    t_single = _drain_timed(single, _workload(n_req))
    t_bucketed = _drain_timed(bucketed, _workload(n_req))
    ms = single.metrics.summary()
    mb = bucketed.metrics.summary()
    record(records, "serve_bucketed_vs_single", t_bucketed * 1e6, echo=csv,
           single_us=round(t_single * 1e6, 1),
           speedup_vs_single=round(t_single / t_bucketed, 2),
           throughput_rps=round(n_req / t_bucketed, 1),
           single_throughput_rps=round(n_req / t_single, 1),
           padding_efficiency=round(mb["padding_efficiency"], 3),
           single_padding_efficiency=round(ms["padding_efficiency"], 3),
           n_requests=n_req)

    # ---------------- open loop: QPS sweep over the bucketed engine --------
    from repro.core.engine import get_engine

    for qps in qps_list:
        bucketed.metrics.reset()
        runs0 = get_engine().timing_runs
        elapsed = _open_loop(bucketed, _workload(n_req, seed=int(qps)), qps)
        m = bucketed.metrics.summary()
        # timing runs DURING serving (the global counter also counts other
        # bench jobs in this process): must be zero — a warm engine never
        # time-measures mid-traffic
        mid_serve_runs = get_engine().timing_runs - runs0
        record(records, f"serve_qps{qps:g}", m["latency_p50_ms"] * 1e3,
               echo=csv,
               p99_us=round(m["latency_p99_ms"] * 1e3, 1),
               queue_wait_p50_us=round(m["queue_wait_p50_ms"] * 1e3, 1),
               step_p50_us=round(m["step_p50_ms"] * 1e3, 1),
               target_qps=qps,
               throughput_rps=round(m["completed"] / elapsed, 1),
               padding_efficiency=round(m["padding_efficiency"], 3),
               occupancy=round(m["occupancy_mean"], 3),
               completed=m["completed"], rejected=m["rejected"],
               steps=m["steps"], staged_early=m["staged_early"],
               timing_runs=mid_serve_runs)
    return records


def _chaos_workload(n_req: int, seed: int = 0):
    """The mixed-size stream as short relaxations with retry budget — the
    shape fault recovery must preserve (idempotent restart from snapshot)."""
    reqs = _workload(n_req, seed)
    for r in reqs:
        r.steps = 2
        r.step_size = 0.01
        r.max_retries = 8
    return reqs


def run_serve_chaos(fast: bool = True, csv: bool = True, rates=None,
                    n_req: int | None = None):
    """Chaos proof (DESIGN.md §11.4): the SAME closed-loop request stream
    drained fault-free and under seeded injected faults at sweep rates
    (step raises + non-finite outputs + timeouts, equal thirds).  Records
    per rate: lost requests (must be 0 — every request completed or
    structurally rejected), whether every non-rejected result matches the
    fault-free run bit-for-bit (retry idempotency), recovery p99, and
    throughput degradation vs the fault-free baseline.  A final record
    drives a 2-replica `ReplicaSet` with one replica's steps failing
    deterministically until it is cordoned — its requests must complete on
    the survivor."""
    from repro.serve.engine import EquivariantServeEngine
    from repro.serve.faults import FaultPlan, injected
    from repro.serve.replicas import ReplicaSet

    records = []
    model, params = _tiny_model()
    n_req = n_req or (24 if fast else 96)
    rates = rates or ((0.05, 0.15) if fast else (0.02, 0.05, 0.15))

    eng = EquivariantServeEngine(model, params, buckets=BUCKETS)
    eng.warmup()
    t_base = _drain_timed(eng, base := _chaos_workload(n_req))
    baseline = {r.rid: r.energy for r in base if not r.rejected}

    for rate in rates:
        eng.metrics.reset()
        plan = FaultPlan(seed=int(rate * 1000),
                         rates={"step_raise": rate / 3,
                                "step_nonfinite": rate / 3,
                                "step_timeout": rate / 3},
                         # every sweep point proves recovery from all three
                         # kinds at least once, even at tiny rates
                         at={"step_raise": (0,), "step_nonfinite": (1,),
                             "step_timeout": (2,)})
        reqs = _chaos_workload(n_req)
        t0 = time.monotonic()
        with injected(plan):
            eng.run(reqs)
        elapsed = time.monotonic() - t0
        m = eng.metrics.summary()
        lost = sum(1 for r in reqs if not r.done)
        diffs = [abs(r.energy - baseline[r.rid]) for r in reqs
                 if not r.rejected and r.rid in baseline]
        record(records, f"serve_chaos_rate{rate:g}", elapsed * 1e6, echo=csv,
               fault_rate=rate, faults_fired=len(plan.fired),
               lost=lost, completed=m["completed"], rejected=m["rejected"],
               results_match=bool(diffs and max(diffs) == 0.0
                                  or not diffs),
               max_energy_diff=float(max(diffs)) if diffs else 0.0,
               step_failures=m["step_failures"], retries=m["retries"],
               quarantined=m["quarantined"],
               recovery_p99_ms=round(m["recovery_p99_ms"], 3),
               throughput_rps=round(n_req / elapsed, 1),
               degradation_vs_baseline=round(elapsed / t_base, 2),
               n_requests=n_req)

    # ---------------- replica failover under a deterministic outage --------
    def factory(i, metrics):
        e = EquivariantServeEngine(model, params, buckets=BUCKETS,
                                   metrics=metrics, tag=f"replica{i}")
        e.warmup()
        return e

    rset = ReplicaSet(factory, n_replicas=2, max_fail_streak=2,
                      restart_backoff_s=5.0)   # no restart within the run:
    #                                            survivors must carry it all
    plan = FaultPlan(seed=0, rates={"step_raise": 1.0},
                     scope=lambda ctx: ctx.get("tag") == "replica0")
    reqs = _chaos_workload(n_req)
    t0 = time.monotonic()
    with injected(plan):
        rset.run(reqs)
    elapsed = time.monotonic() - t0
    m = rset.metrics.summary()
    lost = sum(1 for r in reqs if not r.done)
    diffs = [abs(r.energy - baseline[r.rid]) for r in reqs
             if not r.rejected and r.rid in baseline]
    record(records, "serve_chaos_failover", elapsed * 1e6, echo=csv,
           lost=lost, completed=m["completed"], rejected=m["rejected"],
           results_match=bool(diffs and max(diffs) == 0.0 or not diffs),
           failovers=m["failovers"],
           requeued_on_failover=m["requeued_on_failover"],
           replica_restarts=m["replica_restarts"],
           recovery_p99_ms=round(m["recovery_p99_ms"], 3),
           throughput_rps=round(n_req / elapsed, 1),
           n_requests=n_req)
    return records


if __name__ == "__main__":
    run_serve(fast=True)
    run_serve_chaos(fast=True)
