"""Fig 1(b): Equivariant Convolution — Gaunt+eSCN-sparsity conv vs the general
Gaunt conv vs the CG conv (feature (x) SH filter), across L."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cg import cg_full_tensor_product
from repro.core.conv import EquivariantConv
from repro.core.irreps import num_coeffs
from repro.core.so3 import real_sph_harm_jax

from .common import time_fn

EDGES = 256


def run(L_list=(1, 2, 3, 4, 5, 6), csv=True):
    rows = []
    for L in L_list:
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.normal(size=(EDGES, num_coeffs(L))), jnp.float32)
        r = rng.normal(size=(EDGES, 3))
        r = jnp.asarray(r / np.linalg.norm(r, axis=-1, keepdims=True), jnp.float32)

        def cg_conv(x, r):
            filt = real_sph_harm_jax(L, r).astype(x.dtype)
            return cg_full_tensor_product(x, filt, L, L, L)

        t_cg = time_fn(jax.jit(cg_conv), x, r)

        gen = EquivariantConv(L, L, L, method="general")
        t_gen = time_fn(jax.jit(gen.__call__), x, r)

        escn = EquivariantConv(L, L, L, method="escn")
        t_escn = time_fn(jax.jit(escn.__call__), x, r)

        rows.append((L, t_cg, t_gen, t_escn))
        if csv:
            print(f"fig1b_equiv_conv_L{L}_cg,{t_cg:.1f},speedup=1.00")
            print(f"fig1b_equiv_conv_L{L}_gaunt_general,{t_gen:.1f},speedup={t_cg/t_gen:.2f}")
            print(f"fig1b_equiv_conv_L{L}_gaunt_escn,{t_escn:.1f},speedup={t_cg/t_escn:.2f}")
    return rows


if __name__ == "__main__":
    run()
