"""Fig 1(b): Equivariant Convolution — Gaunt+eSCN-sparsity conv vs the general
Gaunt conv vs the CG conv (feature (x) SH filter), across L."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cg import cg_full_tensor_product
from repro.core.conv import EquivariantConv
from repro.core.irreps import num_coeffs
from repro.core.so3 import real_sph_harm_jax

from .common import record, time_fn

EDGES = 256


def run(L_list=(1, 2, 3, 4, 5, 6), backend: str = "auto", csv=True):
    records = []
    for L in L_list:
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.normal(size=(EDGES, num_coeffs(L))), jnp.float32)
        r = rng.normal(size=(EDGES, 3))
        r = jnp.asarray(r / np.linalg.norm(r, axis=-1, keepdims=True), jnp.float32)

        def cg_conv(x, r):
            filt = real_sph_harm_jax(L, r).astype(x.dtype)
            return cg_full_tensor_product(x, filt, L, L, L)

        t_cg = time_fn(jax.jit(cg_conv), x, r)

        gen = EquivariantConv(L, L, L, method="general")
        t_gen = time_fn(jax.jit(gen.__call__), x, r)

        escn = EquivariantConv(L, L, L, method="escn")
        t_escn = time_fn(jax.jit(escn.__call__), x, r)

        # the engine's conv_filter pick for this size
        auto_kw = dict(method="auto", batch_hint=EDGES) if backend == "auto" \
            else dict(backend=backend)
        auto = EquivariantConv(L, L, L, tune="measure" if backend == "auto" else "heuristic",
                               **auto_kw)
        t_auto = time_fn(jax.jit(auto.__call__), x, r)

        record(records, f"fig1b_equiv_conv_L{L}_cg", t_cg, echo=csv, speedup=1.00)
        record(records, f"fig1b_equiv_conv_L{L}_gaunt_general", t_gen, echo=csv,
               speedup=round(t_cg / t_gen, 2), backend=gen.backend)
        record(records, f"fig1b_equiv_conv_L{L}_gaunt_escn", t_escn, echo=csv,
               speedup=round(t_cg / t_escn, 2), backend="escn_aligned")
        record(records, f"fig1b_equiv_conv_L{L}_gaunt_engine", t_auto, echo=csv,
               speedup=round(t_cg / t_auto, 2), backend=auto.backend)
    return records


if __name__ == "__main__":
    run()
