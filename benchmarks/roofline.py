import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")
"""Roofline analysis (EXPERIMENTS.md §Roofline).

Three terms per (arch x shape), single-pod 16x16 mesh, per chip:

    compute    = HLO_FLOPs / 197e12          (bf16 peak, TPU v5e-class)
    memory     = HLO_bytes / 819e9           (HBM bw)
    collective = collective_bytes / 50e9     (per-link ICI)

Sources & method:
  * XLA's cost_analysis counts a while-loop body ONCE (verified), so the
    full-step numbers from the dry run undercount scanned layers.  We therefore
    lower ONE layer block per cell, scan-free (full attention — identical
    FLOPs to the flash path, which computes all tiles), on the same mesh with
    the same shardings, and account  total = n_layers x block + head.
    Recurrent-chunk scans (mamba2/wkv6) are linear in T: a 3-point fit over T
    recovers (per-token, per-chunk-body, const) exactly.
  * memory bytes from the same lowering; for chunked-attention cells the
    score-materialization bytes are an upper bound (flash keeps tiles in
    VMEM) — we report both raw and score-adjusted bytes.
  * collective bytes from the *full-step* compiled HLO (dry-run record),
    while-body ops scaled by layer count.
  * MODEL_FLOPS = 6 N_active D (train) / 2 N_active D (prefill/decode).
"""
import argparse
import dataclasses
import functools
import json

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import SHAPES, get_config
from repro.configs import ALL_LM_ARCHS, SUBQUADRATIC
from repro.distributed.sharding import batch_shardings, param_shardings
from repro.launch.mesh import make_production_mesh
from repro.models import count_params
from repro.models import transformer as T
from repro.models.api import softmax_cross_entropy

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9


# ---------------------------------------------------------------- analytic


def active_params(cfg) -> int:
    n = count_params(cfg)
    if cfg.family == "moe":
        ff = cfg.d_ff_expert or cfg.d_ff
        expert = 3 * cfg.n_experts * cfg.d_model * ff * cfg.n_layers
        active = 3 * cfg.top_k * cfg.d_model * ff * cfg.n_layers
        n = n - expert + active
    return n


def model_flops(cfg, shape) -> float:
    n = active_params(cfg)
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    return (6.0 if shape.kind == "train" else 2.0) * n * tokens


# ---------------------------------------------------------------- lowering


def _cost(lowered):
    c = lowered.compile()
    ca = c.cost_analysis() or {}
    return float(ca.get("flops") or 0.0), float(ca.get("bytes accessed") or 0.0)


def _mesh_sds(cfg, mesh, stacked_params):
    """one-layer param SDS + shardings (drop the stack axis)."""
    one = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape[1:], a.dtype), stacked_params)
    sh = param_shardings(one, mesh)  # rules match unstacked names equally
    return one, sh


@functools.lru_cache(maxsize=None)
def block_costs(arch: str, shape_name: str):
    """(flops, bytes) per chip for one layer block (+head), corrected."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=False)
    B = shape.global_batch
    S = shape.seq_len if shape.kind != "decode" else 1
    dt = jnp.dtype(cfg.dtype)
    # scan-free: full attention (same flops as flash), single recurrent chunk
    cfgx = dataclasses.replace(cfg, attn_chunk=1 << 30, remat=False)

    params_sds = jax.eval_shape(lambda: T.init_params(jax.random.PRNGKey(0), cfgx))
    dp = ("data",)
    from jax.sharding import NamedSharding, PartitionSpec as P

    def act_sh(*trail):
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        ok = B % int(np.prod([sizes[a] for a in dp])) == 0
        return NamedSharding(mesh, P(dp if ok else None, *trail))

    pos_full = jax.ShapeDtypeStruct((B, S), jnp.int32)
    x_sds = jax.ShapeDtypeStruct((B, S, cfg.d_model), dt)

    out = {}

    def lower_block(fn, *sds, in_sh):
        return jax.jit(fn, in_shardings=in_sh).lower(*sds)

    fam = cfg.family
    if fam in ("dense", "moe", "vlm", "encdec"):
        key = "layers"
        one_sds, one_sh = _mesh_sds(cfgx, mesh, params_sds[key])
        if shape.kind == "train":
            def blk(p, x, positions):
                y, aux = T._block_apply(p, x, positions, cfgx)
                return jnp.sum(y.astype(jnp.float32)) + aux

            f = jax.value_and_grad(blk, argnums=(0, 1))
            lw = lower_block(f, one_sds, x_sds, pos_full,
                             in_sh=(one_sh, act_sh(None, None), act_sh(None)))
        elif shape.kind == "prefill":
            def blk(p, x, positions):
                return T._block_apply(p, x, positions, cfgx)[0]

            lw = lower_block(blk, one_sds, x_sds, pos_full,
                             in_sh=(one_sh, act_sh(None, None), act_sh(None)))
        else:  # decode
            cache_sds = {
                "k": jax.ShapeDtypeStruct((B, shape.seq_len, cfg.kv_heads, cfg.hd), dt),
                "v": jax.ShapeDtypeStruct((B, shape.seq_len, cfg.kv_heads, cfg.hd), dt),
            }
            x1 = jax.ShapeDtypeStruct((B, 1, cfg.d_model), dt)
            pos1 = jax.ShapeDtypeStruct((B,), jnp.int32)

            def blk(p, c, x, pos):
                return T._block_decode(p, c, x, pos, cfgx)

            from repro.distributed.sharding import cache_shardings

            c_sh = cache_shardings(cache_sds, mesh)
            lw = lower_block(blk, one_sds, cache_sds, x1, pos1,
                             in_sh=(one_sh, c_sh, act_sh(None, None), act_sh()))
        fl, by = _cost(lw)
        n_blocks = cfg.n_layers
        out["block"] = (fl, by, n_blocks)
        if fam == "encdec" and shape.kind != "decode":
            # encoder blocks on the source length
            xe = jax.ShapeDtypeStruct((B, cfg.max_source_len, cfg.d_model), dt)
            pe = jax.ShapeDtypeStruct((B, cfg.max_source_len), jnp.int32)
            enc_sds, enc_sh = _mesh_sds(cfgx, mesh, params_sds["enc_layers"])

            def eblk(p, x, positions):
                y, _ = T._block_apply(p, x, positions, cfgx, causal=False)
                return jnp.sum(y.astype(jnp.float32)) if shape.kind == "train" else y

            f = jax.grad(eblk, argnums=(0, 1)) if shape.kind == "train" else eblk
            lwe = lower_block(f, enc_sds, xe, pe, in_sh=(enc_sh, act_sh(None, None), act_sh(None)))
            fe, be = _cost(lwe)
            out["enc_block"] = (fe, be, cfg.n_enc_layers)
    elif fam in ("ssm", "hybrid"):
        key = "layers" if fam == "ssm" else "mamba"
        one_sds, one_sh = _mesh_sds(cfgx, mesh, params_sds[key])
        chunk = 64

        def block_fn(p, x):
            if fam == "ssm":
                from repro.models.ssm import rwkv6_apply

                return rwkv6_apply(p, x, cfgx)
            from repro.models.ssm import mamba2_apply
            from repro.models.layers import norm_apply

            return x + mamba2_apply(p["m"], norm_apply(p["ln"], x, cfgx.norm), cfgx)

        if shape.kind == "decode":
            from repro.models import ssm as ssm_mod

            x1 = jax.ShapeDtypeStruct((B, 1, cfg.d_model), dt)
            if fam == "ssm":
                st = jax.eval_shape(lambda: ssm_mod.rwkv6_state_init(cfgx, B, dt))

                def dblk(p, x, s):
                    return ssm_mod.rwkv6_decode_step(p, x, s, cfgx)
            else:
                st = jax.eval_shape(lambda: ssm_mod.mamba2_state_init(cfgx, B, dt))

                def dblk(p, x, s):
                    from repro.models.layers import norm_apply

                    d, s2 = ssm_mod.mamba2_decode_step(
                        p["m"], norm_apply(p["ln"], x, cfgx.norm), s, cfgx)
                    return x + d, s2

            lw = jax.jit(dblk).lower(one_sds, x1, st)
            fl, by = _cost(lw)
            out["block"] = (fl, by, cfg.n_layers)
        else:
            # 3-point fit over T: lowered(T) = lin*T + body + const;
            # true(T) = lin*T + (T/chunk)*body + const
            sizes = [2 * chunk, 4 * chunk, 8 * chunk]
            costs = []
            for Tn in sizes:
                xT = jax.ShapeDtypeStruct((B, Tn, cfg.d_model), dt)
                if shape.kind == "train":
                    f = jax.grad(lambda p, x: jnp.sum(block_fn(p, x).astype(jnp.float32)),
                                 argnums=(0, 1))
                else:
                    f = block_fn
                lw = jax.jit(f, in_shardings=(one_sh, act_sh(None, None))).lower(one_sds, xT)
                costs.append(_cost(lw))
            M = np.array([[s, 1.0, 1.0] for s in sizes])  # [T, body(=1x), const]
            sol_f = np.linalg.lstsq(M, np.array([c[0] for c in costs]), rcond=None)[0]
            sol_b = np.linalg.lstsq(M, np.array([c[1] for c in costs]), rcond=None)[0]
            Tt = S

            def true_cost(sol):
                lin, body, const = sol
                return lin * Tt + (Tt / chunk) * max(body, 0.0) + max(const, 0.0)

            out["block"] = (true_cost(sol_f), true_cost(sol_b), cfg.n_layers)
        if fam == "hybrid":
            # shared attention block every attn_every layers
            n_stages = cfg.n_layers // cfg.attn_every
            one_sh2 = param_shardings(params_sds["shared"], mesh)
            if shape.kind == "decode":
                cache_sds = {
                    "k": jax.ShapeDtypeStruct((B, shape.seq_len, cfg.kv_heads, cfg.hd), dt),
                    "v": jax.ShapeDtypeStruct((B, shape.seq_len, cfg.kv_heads, cfg.hd), dt),
                }
                x1 = jax.ShapeDtypeStruct((B, 1, cfg.d_model), dt)
                pos1 = jax.ShapeDtypeStruct((B,), jnp.int32)

                def sblk(p, c, x, pos):
                    return T._block_decode(p, c, x, pos, cfgx)

                lw = jax.jit(sblk).lower(params_sds["shared"], cache_sds, x1, pos1)
            else:
                def sblk(p, x, positions):
                    y, _ = T._block_apply(p, x, positions, cfgx)
                    return jnp.sum(y.astype(jnp.float32)) if shape.kind == "train" else y

                f = (jax.grad(sblk, argnums=(0, 1)) if shape.kind == "train" else sblk)
                lw = jax.jit(f, in_shardings=(one_sh2, act_sh(None, None), act_sh(None))
                             ).lower(params_sds["shared"], x_sds, pos_full)
            fs, bs = _cost(lw)
            out["shared"] = (fs, bs, n_stages)
    # ---- head (final norm + logits (+ CE grad for train))
    head_params = {k: params_sds[k] for k in ("embed", "ln_f") if k in params_sds}
    if "unembed" in params_sds:
        head_params["unembed"] = params_sds["unembed"]
    hp_sh = param_shardings(head_params, mesh)
    hx = jax.ShapeDtypeStruct((B, S, cfg.d_model), dt)
    lab = jax.ShapeDtypeStruct((B, S), jnp.int32)

    def head_train(hp, h, labels):
        logits = T._logits(hp, cfgx, h)
        return softmax_cross_entropy(logits[:, :-1], labels[:, 1:])

    def head_fwd(hp, h):
        return T._logits(hp, cfgx, h)

    if shape.kind == "train":
        lwh = jax.jit(jax.grad(head_train, argnums=(0, 1)),
                      in_shardings=(hp_sh, act_sh(None, None), act_sh(None))
                      ).lower(head_params, hx, lab)
    else:
        lwh = jax.jit(head_fwd, in_shardings=(hp_sh, act_sh(None, None))
                      ).lower(head_params, hx)
    fh, bh = _cost(lwh)
    out["head"] = (fh, bh, 1)
    return out


def analytic_hbm_bytes(cfg, shape, n_dev: int, model_ways: int = 16,
                       data_ways: int = 16) -> float:
    """Transparent HBM-traffic model per chip per step (documented in
    EXPERIMENTS.md §Method).  The raw HLO 'bytes accessed' models zero
    fusion and overcounts HBM traffic by 1-2 orders of magnitude; this model
    is used for dominant-term identification, both are reported.

    train:  weights bf16 read 3x (fwd, dgrad, wgrad) of the device's
            TP-shard (FSDP gathers land in HBM once: +1 write), fp32
            grad + master + m + v read/write, bf16 weight write;
            activations: (16 d + 4 ff_active) bytes per token-layer
            (remat write+read + matmul intermediates with partial fusion).
    prefill: weights 1x, activations 1 pass, + KV-cache write.
    decode:  weights 1x + full KV/state cache read + tiny activations.
    """
    n_par = count_params(cfg)
    par_local = n_par / n_dev
    w_shard = n_par / model_ways * 2  # bf16 bytes of the TP shard
    ff_act = cfg.top_k * (cfg.d_ff_expert or cfg.d_ff) if cfg.family == "moe" else cfg.d_ff
    tokens_local = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    tokens_local /= min(data_ways, shape.global_batch) if shape.kind != "decode" else n_dev
    if shape.kind == "decode":
        tokens_local = max(shape.global_batch / n_dev, 1 / n_dev * shape.global_batch)
    L = cfg.n_layers
    act = (16 * cfg.d_model + 4 * ff_act) * tokens_local * L
    if shape.kind == "train":
        w = w_shard * (3 + 1) + par_local * (4 * 2 * 4 + 2)  # grads+master+m+v rw
        return w + 3 * act  # fwd + remat-recompute + bwd passes
    if shape.kind == "prefill":
        kv_dim = cfg.kv_heads * cfg.hd if cfg.n_heads else cfg.d_model  # attn-free: state
        cache_w = 2 * tokens_local * kv_dim * 2 * L
        return w_shard + act + cache_w
    # decode: weights + cache read dominate
    if cfg.family == "ssm":
        cache = L * (cfg.d_model // cfg.rwkv_head_k) * cfg.rwkv_head_k**2 * 4
        cache *= shape.global_batch / n_dev if shape.global_batch >= n_dev else 1
    elif cfg.family == "hybrid":
        d_in = cfg.ssm_expand * cfg.d_model
        ssm = L * (d_in // cfg.ssm_headdim) * cfg.ssm_headdim * cfg.ssm_state * 4
        kv = (L // cfg.attn_every) * shape.seq_len * cfg.kv_heads * cfg.hd * 2 * 2
        cache = (ssm + kv) * max(shape.global_batch, 1)
        cache /= n_dev
    else:
        cache = L * shape.seq_len * cfg.kv_heads * cfg.hd * 2 * 2 * shape.global_batch
        cache /= n_dev
    return w_shard + cache + act


def roofline_cell(arch: str, shape_name: str, dryrun_rec: dict) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    costs = block_costs(arch, shape_name)
    flops = sum(f * n for f, _, n in costs.values())
    bytes_hlo = sum(b * n for _, b, n in costs.values())
    n_dev = dryrun_rec["devices"]
    bytes_model = analytic_hbm_bytes(cfg, shape, n_dev)
    coll = dryrun_rec["collectives"]["total_bytes"]  # already layer-scaled
    t_comp = flops / PEAK_FLOPS
    t_mem_hlo = bytes_hlo / HBM_BW
    t_mem = bytes_model / HBM_BW
    t_coll = coll / ICI_BW
    dominant = max((t_comp, "compute"), (t_mem, "memory"), (t_coll, "collective"))[1]
    mf = model_flops(cfg, shape) / n_dev
    return {
        "arch": arch, "shape": shape_name,
        "flops_per_chip": flops, "bytes_per_chip_hlo": bytes_hlo,
        "bytes_per_chip_model": bytes_model,
        "collective_bytes_per_chip": coll,
        "t_compute_s": t_comp, "t_memory_s": t_mem, "t_memory_hlo_s": t_mem_hlo,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "model_flops_per_chip": mf,
        "useful_flop_ratio": mf / flops if flops else None,
        "roofline_fraction": (
            mf / PEAK_FLOPS / max(t_comp, t_mem, t_coll) if flops else None),
        "peak_hbm_gb": dryrun_rec["memory"]["peak_per_device_gb"],
        "block_detail": {k: {"flops": f, "bytes": b, "count": n}
                         for k, (f, b, n) in costs.items()},
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun", default="results/dryrun.json")
    ap.add_argument("--out", default="results/roofline.json")
    ap.add_argument("--arch", default="all")
    args = ap.parse_args()
    dr = json.load(open(args.dryrun))
    results = {}
    if os.path.exists(args.out):
        results = json.load(open(args.out))
    archs = ALL_LM_ARCHS if args.arch == "all" else args.arch.split(",")
    for arch in archs:
        for shape_name in SHAPES:
            key = f"{arch}|{shape_name}"
            dkey = f"{arch}|{shape_name}|16x16"
            rec = dr.get(dkey)
            if rec is None or rec.get("status") == "error":
                continue
            if rec.get("status") == "skipped":
                results[key] = {"arch": arch, "shape": shape_name, "status": "skipped",
                                "reason": rec["reason"]}
                continue
            if key in results and "t_memory_hlo_s" in results[key]:
                continue
            print("===", key, flush=True)
            try:
                results[key] = roofline_cell(arch, shape_name, rec)
            except Exception as e:  # noqa: BLE001
                results[key] = {"arch": arch, "shape": shape_name, "status": "error",
                                "error": f"{type(e).__name__}: {e}"}
            with open(args.out, "w") as f:
                json.dump(results, f, indent=1)
    # print table
    print(f"{'arch':<18}{'shape':<13}{'t_comp(ms)':>11}{'t_mem(ms)':>11}"
          f"{'t_coll(ms)':>11}{'dominant':>11}{'useful':>8}{'roofline%':>10}")
    for k, r in results.items():
        if "t_compute_s" not in r:
            print(f"{r['arch']:<18}{r['shape']:<13}{'skip' if r.get('status')=='skipped' else 'ERR':>11}")
            continue
        print(f"{r['arch']:<18}{r['shape']:<13}{r['t_compute_s']*1e3:>11.2f}"
              f"{r['t_memory_s']*1e3:>11.2f}{r['t_collective_s']*1e3:>11.2f}"
              f"{r['dominant']:>11}{(r['useful_flop_ratio'] or 0):>8.2f}"
              f"{100*(r['roofline_fraction'] or 0):>10.1f}")


if __name__ == "__main__":
    main()
