"""Fig 1(c,d): Equivariant Many-body Interaction — divide-and-conquer Gaunt
nu-fold products vs the iterated-CG (MACE-style) implementation.
(c) fix nu=3, vary L;  (d) fix L=2, vary nu."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cg import cg_full_tensor_product
from repro.core.irreps import num_coeffs
from repro.core.manybody import manybody_selfmix

from .common import time_fn

ROWS = 64


def _cg_fold(x, L, nu, Lout):
    acc = x
    La = L
    for _ in range(nu - 1):
        acc = cg_full_tensor_product(acc, x, La, L, min(La + L, Lout if _ == nu - 2 else La + L))
        La = min(La + L, La + L)
    return acc


def run(csv=True):
    rows = []
    # (c) vary L at nu=3
    for L in (1, 2, 3, 4):
        x = jnp.asarray(np.random.default_rng(0).normal(size=(ROWS, num_coeffs(L))), jnp.float32)
        t_cg = time_fn(jax.jit(lambda a: _cg_fold(a, L, 3, 3 * L)), x)
        t_g = time_fn(jax.jit(lambda a: manybody_selfmix(a, L, 3)), x)
        rows.append(("c", L, 3, t_cg, t_g))
        if csv:
            print(f"fig1c_manybody_L{L}_nu3_cg,{t_cg:.1f},speedup=1.00")
            print(f"fig1c_manybody_L{L}_nu3_gaunt,{t_g:.1f},speedup={t_cg/t_g:.2f}")
    # (d) vary nu at L=2
    L = 2
    x = jnp.asarray(np.random.default_rng(1).normal(size=(ROWS, num_coeffs(L))), jnp.float32)
    for nu in (2, 3, 4, 5):
        t_cg = time_fn(jax.jit(lambda a, nu=nu: _cg_fold(a, L, nu, nu * L)), x)
        t_g = time_fn(jax.jit(lambda a, nu=nu: manybody_selfmix(a, L, nu)), x)
        rows.append(("d", L, nu, t_cg, t_g))
        if csv:
            print(f"fig1d_manybody_L2_nu{nu}_cg,{t_cg:.1f},speedup=1.00")
            print(f"fig1d_manybody_L2_nu{nu}_gaunt,{t_g:.1f},speedup={t_cg/t_g:.2f}")
    return rows


if __name__ == "__main__":
    run()
