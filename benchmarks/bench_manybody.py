"""Fig 1(c,d): Equivariant Many-body Interaction — divide-and-conquer Gaunt
nu-fold products vs the iterated-CG (MACE-style) implementation.
(c) fix nu=3, vary L;  (d) fix L=2, vary nu."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cg import cg_full_tensor_product
from repro.core.irreps import num_coeffs
from repro.core.manybody import manybody_selfmix

from .common import record, time_fn

ROWS = 64


def _cg_fold(x, L, nu, Lout):
    acc = x
    La = L
    for _ in range(nu - 1):
        acc = cg_full_tensor_product(acc, x, La, L, min(La + L, Lout if _ == nu - 2 else La + L))
        La = min(La + L, La + L)
    return acc


def _gaunt_fn(L: int, nu: int, backend: str):
    """jitted nu-fold self-product + the backend name actually used.

    'auto' plans outside the jit so the measured autotune really runs
    (inside a trace it would silently fall back to the cost model)."""
    if backend == "auto":
        from repro.core import engine

        p = engine.plan(kind="manybody", Ls=(L,) * nu, batch_hint=ROWS,
                        tune="measure")
        return jax.jit(lambda a: p.apply([a] * nu)), p.backend
    return jax.jit(lambda a: manybody_selfmix(a, L, nu, backend=backend)), backend


def run(backend: str = "auto", csv=True):
    records = []
    # (c) vary L at nu=3
    for L in (1, 2, 3, 4):
        x = jnp.asarray(np.random.default_rng(0).normal(size=(ROWS, num_coeffs(L))), jnp.float32)
        t_cg = time_fn(jax.jit(lambda a: _cg_fold(a, L, 3, 3 * L)), x)
        fn, be = _gaunt_fn(L, 3, backend)
        t_g = time_fn(fn, x)
        record(records, f"fig1c_manybody_L{L}_nu3_cg", t_cg, echo=csv, speedup=1.00)
        record(records, f"fig1c_manybody_L{L}_nu3_gaunt", t_g, echo=csv,
               speedup=round(t_cg / t_g, 2), backend=be)
    # (d) vary nu at L=2
    L = 2
    x = jnp.asarray(np.random.default_rng(1).normal(size=(ROWS, num_coeffs(L))), jnp.float32)
    for nu in (2, 3, 4, 5):
        t_cg = time_fn(jax.jit(lambda a, nu=nu: _cg_fold(a, L, nu, nu * L)), x)
        fn, be = _gaunt_fn(L, nu, backend)
        t_g = time_fn(fn, x)
        record(records, f"fig1d_manybody_L2_nu{nu}_cg", t_cg, echo=csv, speedup=1.00)
        record(records, f"fig1d_manybody_L2_nu{nu}_gaunt", t_g, echo=csv,
               speedup=round(t_cg / t_g, 2), backend=be)
    return records


if __name__ == "__main__":
    run()
