"""Benchmark utilities: stable wall-time of jitted callables on CPU."""
from __future__ import annotations

import time

import jax

__all__ = ["time_fn"]


def time_fn(fn, *args, iters: int = 10, warmup: int = 3) -> float:
    """Median microseconds per call of a jitted fn (blocks on results)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2] * 1e6
