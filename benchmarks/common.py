"""Benchmark utilities: stable wall-time of jitted callables on CPU."""
from __future__ import annotations

import time

import jax

__all__ = ["time_fn", "record"]


def record(records: list, name: str, us: float, echo: bool = True, **extra) -> dict:
    """Append a machine-readable benchmark record and print the CSV row.

    The third CSV column is `k=v;...` of the extras (backend choice,
    speedups, ...); the same fields land in BENCH_gaunt.json via run.py.
    ``echo=False`` suppresses the print (the benches' csv flag).
    """
    rec = {"name": name, "us": round(float(us), 1), **extra}
    records.append(rec)
    if echo:
        derived = ";".join(f"{k}={v}" for k, v in extra.items()) or "-"
        print(f"{name},{us:.1f},{derived}")
    return rec


def time_fn(fn, *args, iters: int = 10, warmup: int = 3) -> float:
    """Median microseconds per call of a jitted fn (blocks on results)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2] * 1e6
