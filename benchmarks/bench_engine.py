"""Engine autotune sweep: what does the unified Gaunt engine pick, and how
fast is the pick, per (kind, L, batch)?

With ``backend='auto'`` the engine's measured autotuner chooses among all
eligible backends (the heuristic cost-model pick is reported alongside, so
divergence between model and measurement is visible in the record stream);
any other value pins that backend for the whole sweep.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import engine
from repro.core.irreps import num_coeffs

from .common import record, time_fn


def _rand(shape, seed):
    return jnp.asarray(np.random.default_rng(seed).normal(size=shape), jnp.float32)


def _bytes_moved(Ls, Lout, B, dtype: str = "float32") -> int:
    """Estimated bytes moved by one collocation-style product at ``dtype``
    storage (DESIGN.md §3.6): operand/output SH rows + sampling/projection
    constants at storage width, the per-operand sample grids and the product
    grid at accumulation width (always >= f32).  An analytic traffic model —
    not a hardware counter — so mixed-precision records report bandwidth
    *utilization* (bytes/us) on a common scale, not just relative speedup."""
    sb = {"bfloat16": 2, "float64": 8}.get(dtype, 4)
    ab = 8 if dtype == "float64" else 4
    nin = sum(num_coeffs(L) for L in Ls)
    G = (2 * sum(Ls) + 2) ** 2  # alias-free collocation grid (pre lane-pad)
    io = B * (nin + num_coeffs(Lout)) * sb          # operand + output rows
    consts = (nin + num_coeffs(Lout)) * G * sb      # T_i and P matrices
    grids = B * G * (len(Ls) + 1) * ab              # sampled + product grids
    return io + consts + grids


def _time_many(fns_args, iters: int = 10, warmup: int = 3) -> float:
    """Median microseconds for one sweep over [(fn, args), ...] — the looped
    dispatch pattern plan_batch replaces."""
    import time

    def sweep():
        outs = [fn(*args) for fn, args in fns_args]
        jax.block_until_ready(outs)

    for _ in range(warmup):
        sweep()
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        sweep()
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2] * 1e6


def run_batched(backend: str = "auto", csv=True):
    """Batched-vs-looped: one plan_batch invocation vs per-plan dispatch
    loops, for (a) many same-degree items and (b) a ragged mixed-degree set."""
    from .common import record

    records = []
    eng = engine.get_engine()
    be = None if backend == "auto" else backend
    # (name, items, pinned backend or None=CLI choice): tiny items are
    # dispatch-bound (batching amortizes call overhead); the spectral
    # 'direct' pipeline is many-small-ops per call (batching fuses them);
    # the ragged set exercises multi-bucket slicing
    workloads = [
        ("tiny_x32_B4", [(2, 2, 4, 4)] * 32, be),
        ("direct_x16_B64", [(2, 2, 4, 64)] * 16, be or "direct"),
        ("mixedL_ragged", [(1, 1, 2, 64), (2, 2, 4, 64), (3, 3, 6, 64),
                           (2, 2, 4, 32)] * 4, be),
    ]
    for name, items, be in workloads:
        ins = [(_rand((n, num_coeffs(L1)), 2 * i),
                _rand((n, num_coeffs(L2)), 2 * i + 1))
               for i, (L1, L2, Lout, n) in enumerate(items)]
        # looped: one jitted dispatch per item (the pre-batching consumer)
        fns_args = []
        for (L1, L2, Lout, n), args in zip(items, ins):
            p = eng.plan(L1, L2, Lout, batch_hint=n, backend=be,
                         requires_grad=False)
            fns_args.append((jax.jit(lambda a, b, p=p: p.apply(a, b)), args))
        t_loop = _time_many(fns_args)
        # batched: one fused invocation per degree bucket
        bp = eng.plan_batch(items, backend=be, requires_grad=False)
        t_batch = _time_many([(lambda: jax.block_until_ready(bp.apply(ins)), ())])
        record(records, f"engine_batched_{name}", t_batch, echo=csv,
               looped_us=round(t_loop, 1),
               speedup_vs_looped=round(t_loop / t_batch, 2),
               buckets=len(bp.buckets),
               backends=",".join(sorted({b.plan.backend for b in bp.buckets})))
    return records


def run(L_list=(1, 2, 3, 4, 6), B_list=(64, 1024), backend: str = "auto", csv=True):
    records = []
    eng = engine.get_engine()
    # install the host-measured fused cost factor BEFORE recording heuristic
    # picks: the regret guard bounds the *calibrated* cost model (the one
    # heuristic-mode plans actually use after calibrate_fused), not the
    # shipped default factor
    eng.calibrate_fused()
    for L in L_list:
        for B in B_list:
            x1 = _rand((B, num_coeffs(L)), 0)
            x2 = _rand((B, num_coeffs(L)), 1)
            kw = dict(batch_hint=B, requires_grad=False)
            if backend == "auto":
                p = eng.plan(L, L, L, tune="measure", **kw)
            else:
                p = eng.plan(L, L, L, backend=backend, **kw)
            heuristic = eng.select(p.key)
            t = time_fn(jax.jit(lambda a, b: p.apply(a, b)), x1, x2)
            extra = {}
            if heuristic != p.backend:
                # cost-model/measured disagreement: time the heuristic pick so
                # the record (and the CI guard) can bound the regret
                ph = eng.plan(L, L, L, backend=heuristic, **kw)
                th = time_fn(jax.jit(lambda a, b: ph.apply(a, b)), x1, x2)
                extra = {"heuristic_us": round(th, 1),
                         "heuristic_ratio": round(th / t, 2)}
            nb = _bytes_moved((L, L), L, B)
            record(records, f"engine_pairwise_L{L}_B{B}", t, echo=csv,
                   backend=p.backend, heuristic=heuristic,
                   bytes_moved=nb, gbps=round(nb / t / 1e3, 2), **extra)
        # conv_filter: the message-passing hot path
        B = B_list[-1]
        x = _rand((B, num_coeffs(L)), 2)
        v = np.random.default_rng(3).normal(size=(B, 3))
        r = jnp.asarray(v / np.linalg.norm(v, axis=-1, keepdims=True), jnp.float32)
        kw = dict(kind="conv_filter", batch_hint=B, requires_grad=False)
        if backend == "auto":
            p = eng.plan(L, L, L, tune="measure", **kw)
        else:
            be = backend if backend in engine.available_backends("conv_filter", requires_grad=False) else "escn_aligned"
            p = eng.plan(L, L, L, backend=be, **kw)
        heuristic = eng.select(p.key)
        t = time_fn(jax.jit(lambda a, b: p.apply(a, b)), x, r)
        record(records, f"engine_conv_L{L}_B{B}", t, echo=csv,
               backend=p.backend, heuristic=heuristic)
    return records


def run_chain(csv=True):
    """Fourier-resident chain plans vs the looped per-product Fourier path.

    Each workload is a *chained* product (many-body trees, shared-operand
    selfmix, a conv layer stack with fixed edge geometry).  The looped
    baseline pays the full SH->Fourier->SH round trip per product (the 'fft'
    backend); the resident path plans the whole chain, converting each
    operand once and projecting once.  Records per-workload eliminated
    conversion counts (measured by the `repro.core.rep` counters, not
    inferred) and end-to-end speedup.
    """
    import numpy as _np

    from repro.core import rep
    from repro.core.engine import expand_degree_weights
    from repro.core.irreps import num_coeffs as _nc
    from repro.core.rep import Rep
    from repro.core.so3 import real_sph_harm_jax

    records = []
    eng = engine.get_engine()

    def _counts(fn):
        rep.reset_conversion_stats()
        jax.block_until_ready(fn())
        c = rep.conversion_stats()
        return c["sh_to_fourier"], c["fourier_to_sh"]

    # ---- chained products: many-body trees + shared-operand selfmix ------
    workloads = [
        # MACE's actual many-body shape: B_nu = A (x) A (x) A, per-operand
        # weights — the shared operand converts ONCE (degree-resolved).
        # Measured at L=3: the regime where the Fourier path is competitive
        # at all (at L<=2 CG wins regardless of conversion strategy)
        ("mace_mb_L3_nu3_B128", (3, 3, 3), 3, 128, True),
        ("manybody_L3_nu3_B128", (3, 3, 3), 3, 128, False),
        ("manybody_L2_nu4_B256", (2, 2, 2, 2), 2, 256, False),
        ("manybody_L4_nu3_B64", (4, 4, 4), 4, 64, False),
        ("selfmix_L4_B256", (4, 4), 4, 256, True),
        ("selfmix_L6_B64", (6, 6), 6, 64, True),
    ]
    for name, Ls, Lout, B, shared in workloads:
        if shared:
            x = _rand((B, _nc(Ls[0])), 1)
            xs = [x] * len(Ls)
            ws = [_rand((B, L + 1), 10 + i) for i, L in enumerate(Ls)]
        else:
            xs = [_rand((B, _nc(L)), i) for i, L in enumerate(Ls)]
            ws = None
        plans = []
        La = Ls[0]
        for i, L in enumerate(Ls[1:], start=1):
            Lt = Lout if i == len(Ls) - 1 else La + L
            # the historical per-product default: direct for small L, else fft
            be = engine.spectral_default(La, L)
            plans.append(eng.plan(La, L, Lt, backend=be, requires_grad=False))
            La += L

        def looped(*xf, _plans=plans, _ws=ws, _Ls=Ls):
            acc = xf[0]
            if _ws is not None:
                acc = acc * expand_degree_weights(_ws[0], _Ls[0]).astype(acc.dtype)
            for i, p in enumerate(_plans, start=1):
                acc = p.apply(acc, xf[i], None, _ws[i] if _ws else None)
            return acc

        cp = eng.plan_chain(Ls, Lout)  # auto: half grids, direct/rfft by shape

        s2f_l, f2s_l = _counts(lambda: looped(*xs))
        s2f_c, f2s_c = _counts(lambda: cp.apply(xs, weights=ws))
        t_loop = time_fn(jax.jit(looped), *xs)
        # time apply_jit, NOT jax.jit(cp.apply): a bare jit boundary hands a
        # shared operand to n distinct tracers, silently un-deduplicating the
        # very conversion this benchmark measures — apply_jit dedups first
        t_chain = time_fn(lambda: cp.apply_jit(xs, weights=ws))
        record(records, f"engine_chain_{name}", t_chain, echo=csv,
               looped_us=round(t_loop, 1),
               speedup_vs_looped=round(t_loop / t_chain, 2),
               conversions=f"{s2f_c}+{f2s_c}",
               looped_conversions=f"{s2f_l}+{f2s_l}",
               pairs_eliminated=min(s2f_l - s2f_c, f2s_l - f2s_c),
               conversions_eliminated=(s2f_l + f2s_l) - (s2f_c + f2s_c))

    # ---- conv layer stack: filter resident across layers -----------------
    # Execution matches the real consumer pattern: one dispatch per layer
    # (each layer's plan is its own jitted call, as in the model stacks), so
    # the looped path genuinely re-materializes and re-converts the filter
    # every layer — a single mega-jit would let XLA CSE hide that cost, which
    # is exactly what eager/streaming serving does NOT get.
    for name, L, n_layers, B in [("convstack_L2_x8_B512", 2, 8, 512),
                                 ("convstack_L3_x8_B256", 3, 8, 256)]:
        x0 = _rand((B, _nc(L)), 3)
        v = _np.random.default_rng(4).normal(size=(B, 3))
        r = jnp.asarray(v / _np.linalg.norm(v, axis=-1, keepdims=True),
                        jnp.float32)
        be = engine.spectral_default(L, L)
        p_loop = eng.plan(L, L, L, kind="conv_filter", backend=be,
                          requires_grad=False)
        # resident stack: half-grid (real-input) boundary plan + a filter
        # converted once for the whole stack; conv follows the chain policy
        p_res = eng.plan(L, L, L, backend="rfft", requires_grad=False,
                         options={"boundary": ("sh", "fourier", "sh"),
                                  "conv": "direct" if L <= 4 else "rfft"})
        f_loop = jax.jit(lambda x, r: p_loop.apply(x, r))
        f_res = jax.jit(lambda x, filt: p_res.apply(x, filt))
        f_filt = jax.jit(
            lambda r: Rep.from_sh(real_sph_harm_jax(L, r), L).to_fourier("half"))

        def looped(x, r):
            for _ in range(n_layers):
                x = f_loop(x, r)
            return x

        def resident(x, r):
            filt = f_filt(r)
            for _ in range(n_layers):
                x = f_res(x, filt)
            return x

        # count the REAL executions (eager per-layer applies — each dispatch
        # runs its conversions), not a one-layer count extrapolated by hand
        def looped_eager():
            for _ in range(n_layers):
                p_loop.apply(x0, r)

        def resident_eager():
            filt = Rep.from_sh(real_sph_harm_jax(L, r), L).to_fourier("half")
            for _ in range(n_layers):
                p_res.apply(x0, filt)

        s2f_l, f2s_l = _counts(looped_eager)
        s2f_c, f2s_c = _counts(resident_eager)
        t_loop = time_fn(lambda: looped(x0, r))
        t_chain = time_fn(lambda: resident(x0, r))
        # each layer still checkpoints to SH (the projection is the layer's
        # degree truncation), so the elision here is the filter's sh->F
        record(records, f"engine_chain_{name}", t_chain, echo=csv,
               looped_us=round(t_loop, 1),
               speedup_vs_looped=round(t_loop / t_chain, 2),
               conversions=f"{s2f_c}+{f2s_c}",
               looped_conversions=f"{s2f_l}+{f2s_l}",
               conversions_eliminated=(s2f_l + f2s_l) - (s2f_c + f2s_c))

    # ---- eSCN geometry residency: Wigner blocks hoisted per geometry -----
    # The rotation-aligned conv used to rebuild align_rotation + the CG
    # Wigner recursion from the SAME layer-constant rhat inside every
    # layer's dispatch; `EquivariantConv.geometry_rep` hoists them once per
    # geometry (ROADMAP "eSCN geometry residency") and the aligned banded
    # conv consumes the precomputed WignerBlocks through its bucket.
    from repro.core.conv import EquivariantConv

    for name, L, n_layers, B in [("escn_wigner_L2_x8_B512", 2, 8, 512),
                                 ("escn_wigner_L3_x8_B256", 3, 8, 256)]:
        x0 = _rand((B, _nc(L)), 5)
        v = _np.random.default_rng(6).normal(size=(B, 3))
        r = jnp.asarray(v / _np.linalg.norm(v, axis=-1, keepdims=True),
                        jnp.float32)
        conv = EquivariantConv(L, L, L, method="escn")

        def looped(x, r, _conv=conv, _n=n_layers):
            for _ in range(_n):
                x = _conv(x, r)
            return x

        def resident(x, r, _conv=conv, _n=n_layers):
            geom = _conv.geometry_rep(r)
            for _ in range(_n):
                x = _conv(x, geom)
            return x

        t_loop = time_fn(lambda: looped(x0, r))
        t_res = time_fn(lambda: resident(x0, r))
        record(records, f"engine_chain_{name}", t_res, echo=csv,
               looped_us=round(t_loop, 1),
               speedup_vs_looped=round(t_loop / t_res, 2),
               wigner_builds=f"1-vs-{n_layers}")
    return records


def run_chain_kernel(csv=True):
    """Measured chain autotune (DESIGN.md §6.4): per chained workload, which
    ChainPlan backend does the measured autotuner pick, and how does the pick
    compare to the resident tree-conv baseline?

    Also measures and records the fused cost model's skinny-matmul
    calibration constant (`engine_calibration_fused_skinny`) — heuristic-mode
    plans on this host then use the measured factor instead of the CPU-era
    default.  The CI guard fails if the autotuner picks the collocation
    kernel on a workload where it then *loses* to tree-conv, or if the
    kernel wins nowhere at all (the autotune fold would be dead weight).
    """
    from repro.core.irreps import num_coeffs as _nc
    from repro.kernels import gaunt_fused as _gk

    records = []
    eng = engine.get_engine()
    cal = eng.calibrate_fused()
    record(records, "engine_calibration_fused_skinny", cal["fused_xla_us"],
           echo=csv, factor=cal["factor"],
           dense_einsum_us=cal["dense_einsum_us"],
           default_factor=4.0)
    # chained workloads spanning the regimes: short fat chains (collocation's
    # home turf — one dispatch vs many small spectral ops), long thin chains
    # (tree-conv's home turf: grids grow as sum(L) and the collocation grid
    # pays G ~ (2*sum(L)+2)^2 per operand), and a full-degree exit
    workloads = [
        ("L1x3_B512", (1, 1, 1), 1, 512),
        ("L2x2_B64", (2, 2), 2, 64),
        ("L2x3_B128", (2, 2, 2), 2, 128),
        ("L3x3_B64", (3, 3, 3), 3, 64),
        ("L2x4_B256_full", (2, 2, 2, 2), 8, 256),
    ]
    for name, Ls, Lout, B in workloads:
        xs = [_rand((B, _nc(L)), 7 + i) for i, L in enumerate(Ls)]
        cp = eng.plan_chain(Ls, Lout, tune="measure", batch_hint=B)
        tree = eng.plan_chain(Ls, Lout, backend="tree")
        t_pick = time_fn(lambda: cp.apply_jit(xs))
        t_tree = time_fn(lambda: tree.apply_jit(xs))
        # dispatch proof data: the collocation backends tick the kernel-call
        # counter once per trace — the pallas flavor is ONE pallas_call
        extra = {}
        if cp.backend == "fused_pallas":
            _gk.reset_kernel_stats()
            jax.block_until_ready(cp.apply(xs))
            extra["pallas_calls"] = _gk.kernel_stats()["chain_pallas_calls"]
        record(records, f"engine_chain_kernel_{name}", t_pick, echo=csv,
               backend=cp.backend, tree_us=round(t_tree, 1),
               speedup_vs_tree=round(t_tree / t_pick, 2),
               n_operands=len(Ls), **extra)
    return records


def run_grid_gate(csv=True):
    """Grid-resident equivariant gates (DESIGN.md §6.5): the fused pointwise
    gate stage vs the SH-gate baseline, per chained workload.

    Two workload families, both computing the IDENTICAL function on both
    paths (the gate is affine on the sphere once its scalars are known, so
    the grid evaluation is exact — the recorded ``err`` is storage roundoff,
    not aliasing, and the CI guard holds it to ``BENCH_GUARD_GATE_TOL``):

    * ``region_*`` — a TP -> gate -> selfmix layer region.  Resident path:
      the gate fuses into chain 1 (pointwise stage on the product grid) and
      the gated product enters chain 2 still Fourier-resident — one exit
      conversion for the whole region.  SH path: chain 1 exits to SH, the
      gate runs on coefficients, chain 2 re-enters — the exit/re-entry pair
      the fusion elides.
    * ``selfmix_*`` — MACE's gated many-body chain (grid_gate='on' layer
      shape): gate fused into the selfmix kernel vs the ungated chain plus
      the SH affine epilogue.

    Each record carries the measured ``auto`` gate policy for the workload
    (engine.select_gate) so the guard can fail a policy that picks the grid
    gate where the bench shows it losing.
    """
    from repro.core.engine import _gate_sh

    records = []
    eng = engine.get_engine()

    def _gp(B, seed):
        rng = np.random.default_rng(seed)
        return {"w1": jnp.asarray(rng.normal(size=(B, 16)), jnp.float32) * .3,
                "w2": jnp.asarray(rng.normal(size=(16, B)), jnp.float32) * .3}

    def _err(got, ref):
        got = np.asarray(got, np.float64)
        ref = np.asarray(ref, np.float64)
        return float(np.abs(got - ref).max() / max(1.0, np.abs(ref).max()))

    # ---- TP -> gate -> selfmix regions -----------------------------------
    for name, L1, L2, Lout, B in [("region_L2xL2_B256", 2, 2, 2, 256),
                                  ("region_L1xL1_B1024", 1, 1, 1, 1024)]:
        Lt = L1 + L2
        xs = [_rand((B, num_coeffs(L)), 30 + i) for i, L in enumerate((L1, L2))]
        gp = _gp(B, 40)
        kw = dict(tune="measure", batch_hint=B)
        cp1g = eng.plan_chain((L1, L2), Lt, gate=True, out_hint="fourier",
                              **kw)
        cp1 = eng.plan_chain((L1, L2), Lt, **kw)
        cp2f = eng.plan_chain((Lt, Lt), Lout, share_hint=(0, 0),
                              entry_hint=("fourier", "fourier"), **kw)
        cp2s = eng.plan_chain((Lt, Lt), Lout, share_hint=(0, 0), **kw)

        def grid_path(_cp1g=cp1g, _cp2=cp2f, _xs=xs, _gp=gp):
            mid = _cp1g.apply_jit(_xs, out_basis="fourier", gate_params=_gp)
            return _cp2.apply_jit([mid, mid])

        def sh_path(_cp1=cp1, _cp2=cp2s, _xs=xs, _gp=gp):
            mid = _gate_sh(_gp, _cp1.apply_jit(_xs))
            return _cp2.apply_jit([mid, mid])

        err = _err(grid_path(), sh_path())
        t_grid = time_fn(lambda: jax.block_until_ready(grid_path()))
        t_sh = time_fn(lambda: jax.block_until_ready(sh_path()))
        pol = eng.select_gate((L1, L2), Lt, batch_hint=B, out_hint="fourier")
        record(records, f"engine_grid_gate_{name}", t_grid, echo=csv,
               sh_gate_us=round(t_sh, 1),
               speedup_vs_sh_gate=round(t_sh / t_grid, 2),
               err=round(err, 6), auto_policy=pol,
               backends=f"{cp1g.backend}+{cp2f.backend}")

    # ---- MACE-shaped gated selfmix chains --------------------------------
    for name, L, nu, B in [("selfmix_L2_nu3_B256", 2, 3, 256),
                           ("selfmix_L3_nu3_B64", 3, 3, 64)]:
        x = _rand((B, num_coeffs(L)), 50)
        xs = [x] * nu
        gp = _gp(B, 51)
        kw = dict(tune="measure", batch_hint=B, share_hint=(0,) * nu)
        cpg = eng.plan_chain((L,) * nu, L, gate=True, **kw)
        cps = eng.plan_chain((L,) * nu, L, **kw)
        err = _err(cpg.apply_jit(xs, gate_params=gp),
                   _gate_sh(gp, cps.apply_jit(xs)))
        t_grid = time_fn(
            lambda: jax.block_until_ready(cpg.apply_jit(xs, gate_params=gp)))
        t_sh = time_fn(
            lambda: jax.block_until_ready(_gate_sh(gp, cps.apply_jit(xs))))
        pol = eng.select_gate((L,) * nu, L, batch_hint=B,
                              share_hint=(0,) * nu)
        record(records, f"engine_grid_gate_{name}", t_grid, echo=csv,
               sh_gate_us=round(t_sh, 1),
               speedup_vs_sh_gate=round(t_sh / t_grid, 2),
               err=round(err, 6), auto_policy=pol, backend=cpg.backend)
    return records


def run_mixed_precision(csv=True):
    """bf16 storage vs its f32 sibling, per workload (DESIGN.md §3.6).

    For pairwise and chained workloads this times the SAME op planned at
    float32 and bfloat16 storage, measures the numerical gap on identical
    (bf16-quantized) inputs, and reports what ``dtype='auto'`` under the
    measured autotuner picked for that key family.  The CI guard holds every
    record to the documented bf16 error budget AND forbids the autotuner
    from keeping a bf16 plan that *loses* to its f32 sibling — it does NOT
    require bf16 to win (on hosts emulating bf16, declining is correct).
    Bytes-moved estimates accompany wall time so the record shows bandwidth
    utilization, not just speedup.
    """
    records = []
    eng = engine.get_engine()

    def _err(got, ref):
        got = np.asarray(got, np.float64)
        ref = np.asarray(ref, np.float64)
        return float(np.abs(got - ref).max() / max(1.0, np.abs(ref).max()))

    def _time_pair(ff, fb, rounds=3):
        # the guard consumes the f32/bf16 RATIO, so the two sides must be
        # timed interleaved: back-to-back rounds with a per-side min discard
        # slow host drift (throttling late in a CI run) that would skew a
        # one-shot sequential measurement by 30%+
        tfs, tbs = [], []
        for _ in range(rounds):
            tfs.append(time_fn(ff))
            tbs.append(time_fn(fb))
        return min(tfs), min(tbs)

    # ---- pairwise ---------------------------------------------------------
    for L, B in [(2, 1024), (4, 256), (6, 64)]:
        x1 = _rand((B, num_coeffs(L)), 0).astype(jnp.bfloat16)
        x2 = _rand((B, num_coeffs(L)), 1).astype(jnp.bfloat16)
        x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
        kw = dict(batch_hint=B, requires_grad=False, tune="measure")
        pf = eng.plan(L, L, L, dtype="float32", **kw)
        pb = eng.plan(L, L, L, dtype="bfloat16", **kw)
        pa = eng.plan(L, L, L, dtype="auto", **kw)
        jf = jax.jit(lambda a, b: pf.apply(a, b))
        jb = jax.jit(lambda a, b: pb.apply(a, b))
        tf, tb = _time_pair(lambda: jf(x1f, x2f), lambda: jb(x1, x2))
        err = _err(pb.apply(x1, x2), pf.apply(x1f, x2f))
        nb = _bytes_moved((L, L), L, B, "bfloat16")
        record(records, f"engine_mixed_precision_pairwise_L{L}_B{B}", tb,
               echo=csv, f32_us=round(tf, 1),
               speedup_vs_f32=round(tf / tb, 2), err=round(err, 4),
               auto_dtype=pa.key.dtype, backend=pb.backend,
               f32_backend=pf.backend,
               bytes_moved=nb, bytes_moved_f32=_bytes_moved((L, L), L, B),
               gbps=round(nb / tb / 1e3, 2))

    # ---- chains (fused_xla + fused_pallas interpret are exercised by the
    # measured pool; the record keeps whatever each precision's winner was) -
    for Ls, Lout, B in [((2, 2, 2), 2, 256), ((3, 3), 3, 128)]:
        xs = [_rand((B, num_coeffs(L)), 10 + i).astype(jnp.bfloat16)
              for i, L in enumerate(Ls)]
        xsf = [x.astype(jnp.float32) for x in xs]
        kw = dict(tune="measure", batch_hint=B)
        cf = eng.plan_chain(Ls, Lout, dtype="float32", **kw)
        cb = eng.plan_chain(Ls, Lout, dtype="bfloat16", **kw)
        ca = eng.plan_chain(Ls, Lout, dtype="auto", **kw)
        tf, tb = _time_pair(lambda: cf.apply_jit(xsf), lambda: cb.apply_jit(xs))
        err = _err(cb.apply_jit(xs), cf.apply_jit(xsf))
        nb = _bytes_moved(Ls, Lout, B, "bfloat16")
        name = f"engine_mixed_precision_chain_L{Ls[0]}x{len(Ls)}_B{B}"
        record(records, name, tb, echo=csv, f32_us=round(tf, 1),
               speedup_vs_f32=round(tf / tb, 2), err=round(err, 4),
               auto_dtype=ca.dtype, backend=cb.backend,
               f32_backend=cf.backend,
               bytes_moved=nb, bytes_moved_f32=_bytes_moved(Ls, Lout, B),
               gbps=round(nb / tb / 1e3, 2))
    return records


_COLD_WARM_CHILD = r"""
import json, os, sys, time
from repro.core import engine

t0 = time.perf_counter()
eng = engine.get_engine()
eng.set_autotune_cache(os.environ["REPRO_BENCH_CACHE"])
eng.load_autotune_cache()
picks = {}
p = eng.plan(2, 2, 2, batch_hint=256, tune="measure", requires_grad=False)
picks["pairwise"] = p.backend
c = eng.plan_chain((2, 2, 2), 2, tune="measure", batch_hint=512)
picks["chain"] = c.backend
a = eng.plan(2, 2, 2, batch_hint=256, dtype="auto", tune="measure",
             requires_grad=False)
picks["auto_dtype"] = a.key.dtype
eng.flush_autotune_cache()
us = (time.perf_counter() - t0) * 1e6
print("BENCH_JSON " + json.dumps(
    {"us": us, "timing_runs": eng.timing_runs, "picks": picks}))
"""


def run_autotune_cache(csv=True):
    """Cold-vs-warm autotune startup (DESIGN.md §4.5).

    Two SUBPROCESSES (honest cold start — a fresh in-process engine would
    still share jit/XLA compilation caches) run the same measure-mode
    workload against one shared cache file: the first boots cold, measures,
    and flushes; the second must answer every selection from the file.  The
    record carries both latencies, both timing-run counters, and whether the
    warm process picked identically — the CI guard holds warm timing runs to
    ZERO and picks to equality, the persisted-cache correctness contract.
    """
    import json as _json
    import os
    import subprocess
    import sys
    import tempfile

    records = []
    with tempfile.TemporaryDirectory() as td:
        env = dict(os.environ)
        env["REPRO_BENCH_CACHE"] = os.path.join(td, "autotune.json")
        env.setdefault("PYTHONPATH", "")
        env["PYTHONPATH"] = os.path.join(
            os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
            "src") + os.pathsep + env["PYTHONPATH"]
        out = []
        for _ in range(2):
            r = subprocess.run([sys.executable, "-c", _COLD_WARM_CHILD],
                               capture_output=True, text=True, env=env,
                               timeout=900)
            line = [ln for ln in r.stdout.splitlines()
                    if ln.startswith("BENCH_JSON ")]
            if r.returncode != 0 or not line:
                raise RuntimeError(f"cold/warm child failed: "
                                   f"{r.stdout[-1000:]} {r.stderr[-1000:]}")
            out.append(_json.loads(line[0][len("BENCH_JSON "):]))
    cold, warm = out
    record(records, "engine_autotune_cache_warm_start", warm["us"], echo=csv,
           cold_us=round(cold["us"], 1),
           speedup_vs_cold=round(cold["us"] / warm["us"], 2),
           cold_timing_runs=cold["timing_runs"],
           warm_timing_runs=warm["timing_runs"],
           picks_match=cold["picks"] == warm["picks"],
           backend=warm["picks"]["chain"])
    return records


if __name__ == "__main__":
    run()
    run_chain()
    run_chain_kernel()
    run_grid_gate()
    run_mixed_precision()
    run_autotune_cache()
