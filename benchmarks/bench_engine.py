"""Engine autotune sweep: what does the unified Gaunt engine pick, and how
fast is the pick, per (kind, L, batch)?

With ``backend='auto'`` the engine's measured autotuner chooses among all
eligible backends (the heuristic cost-model pick is reported alongside, so
divergence between model and measurement is visible in the record stream);
any other value pins that backend for the whole sweep.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import engine
from repro.core.irreps import num_coeffs

from .common import record, time_fn


def _rand(shape, seed):
    return jnp.asarray(np.random.default_rng(seed).normal(size=shape), jnp.float32)


def _time_many(fns_args, iters: int = 10, warmup: int = 3) -> float:
    """Median microseconds for one sweep over [(fn, args), ...] — the looped
    dispatch pattern plan_batch replaces."""
    import time

    def sweep():
        outs = [fn(*args) for fn, args in fns_args]
        jax.block_until_ready(outs)

    for _ in range(warmup):
        sweep()
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        sweep()
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2] * 1e6


def run_batched(backend: str = "auto", csv=True):
    """Batched-vs-looped: one plan_batch invocation vs per-plan dispatch
    loops, for (a) many same-degree items and (b) a ragged mixed-degree set."""
    from .common import record

    records = []
    eng = engine.get_engine()
    be = None if backend == "auto" else backend
    # (name, items, pinned backend or None=CLI choice): tiny items are
    # dispatch-bound (batching amortizes call overhead); the spectral
    # 'direct' pipeline is many-small-ops per call (batching fuses them);
    # the ragged set exercises multi-bucket slicing
    workloads = [
        ("tiny_x32_B4", [(2, 2, 4, 4)] * 32, be),
        ("direct_x16_B64", [(2, 2, 4, 64)] * 16, be or "direct"),
        ("mixedL_ragged", [(1, 1, 2, 64), (2, 2, 4, 64), (3, 3, 6, 64),
                           (2, 2, 4, 32)] * 4, be),
    ]
    for name, items, be in workloads:
        ins = [(_rand((n, num_coeffs(L1)), 2 * i),
                _rand((n, num_coeffs(L2)), 2 * i + 1))
               for i, (L1, L2, Lout, n) in enumerate(items)]
        # looped: one jitted dispatch per item (the pre-batching consumer)
        fns_args = []
        for (L1, L2, Lout, n), args in zip(items, ins):
            p = eng.plan(L1, L2, Lout, batch_hint=n, backend=be,
                         requires_grad=False)
            fns_args.append((jax.jit(lambda a, b, p=p: p.apply(a, b)), args))
        t_loop = _time_many(fns_args)
        # batched: one fused invocation per degree bucket
        bp = eng.plan_batch(items, backend=be, requires_grad=False)
        t_batch = _time_many([(lambda: jax.block_until_ready(bp.apply(ins)), ())])
        record(records, f"engine_batched_{name}", t_batch, echo=csv,
               looped_us=round(t_loop, 1),
               speedup_vs_looped=round(t_loop / t_batch, 2),
               buckets=len(bp.buckets),
               backends=",".join(sorted({b.plan.backend for b in bp.buckets})))
    return records


def run(L_list=(1, 2, 3, 4, 6), B_list=(64, 1024), backend: str = "auto", csv=True):
    records = []
    eng = engine.get_engine()
    for L in L_list:
        for B in B_list:
            x1 = _rand((B, num_coeffs(L)), 0)
            x2 = _rand((B, num_coeffs(L)), 1)
            kw = dict(batch_hint=B, requires_grad=False)
            if backend == "auto":
                p = eng.plan(L, L, L, tune="measure", **kw)
            else:
                p = eng.plan(L, L, L, backend=backend, **kw)
            heuristic = eng.select(p.key)
            t = time_fn(jax.jit(lambda a, b: p.apply(a, b)), x1, x2)
            record(records, f"engine_pairwise_L{L}_B{B}", t, echo=csv,
                   backend=p.backend, heuristic=heuristic)
        # conv_filter: the message-passing hot path
        B = B_list[-1]
        x = _rand((B, num_coeffs(L)), 2)
        v = np.random.default_rng(3).normal(size=(B, 3))
        r = jnp.asarray(v / np.linalg.norm(v, axis=-1, keepdims=True), jnp.float32)
        kw = dict(kind="conv_filter", batch_hint=B, requires_grad=False)
        if backend == "auto":
            p = eng.plan(L, L, L, tune="measure", **kw)
        else:
            be = backend if backend in engine.available_backends("conv_filter", requires_grad=False) else "escn_aligned"
            p = eng.plan(L, L, L, backend=be, **kw)
        heuristic = eng.select(p.key)
        t = time_fn(jax.jit(lambda a, b: p.apply(a, b)), x, r)
        record(records, f"engine_conv_L{L}_B{B}", t, echo=csv,
               backend=p.backend, heuristic=heuristic)
    return records


if __name__ == "__main__":
    run()
