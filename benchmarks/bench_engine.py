"""Engine autotune sweep: what does the unified Gaunt engine pick, and how
fast is the pick, per (kind, L, batch)?

With ``backend='auto'`` the engine's measured autotuner chooses among all
eligible backends (the heuristic cost-model pick is reported alongside, so
divergence between model and measurement is visible in the record stream);
any other value pins that backend for the whole sweep.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import engine
from repro.core.irreps import num_coeffs

from .common import record, time_fn


def _rand(shape, seed):
    return jnp.asarray(np.random.default_rng(seed).normal(size=shape), jnp.float32)


def run(L_list=(1, 2, 3, 4, 6), B_list=(64, 1024), backend: str = "auto", csv=True):
    records = []
    eng = engine.get_engine()
    for L in L_list:
        for B in B_list:
            x1 = _rand((B, num_coeffs(L)), 0)
            x2 = _rand((B, num_coeffs(L)), 1)
            kw = dict(batch_hint=B, requires_grad=False)
            if backend == "auto":
                p = eng.plan(L, L, L, tune="measure", **kw)
            else:
                p = eng.plan(L, L, L, backend=backend, **kw)
            heuristic = eng.select(p.key)
            t = time_fn(jax.jit(lambda a, b: p.apply(a, b)), x1, x2)
            record(records, f"engine_pairwise_L{L}_B{B}", t, echo=csv,
                   backend=p.backend, heuristic=heuristic)
        # conv_filter: the message-passing hot path
        B = B_list[-1]
        x = _rand((B, num_coeffs(L)), 2)
        v = np.random.default_rng(3).normal(size=(B, 3))
        r = jnp.asarray(v / np.linalg.norm(v, axis=-1, keepdims=True), jnp.float32)
        kw = dict(kind="conv_filter", batch_hint=B, requires_grad=False)
        if backend == "auto":
            p = eng.plan(L, L, L, tune="measure", **kw)
        else:
            be = backend if backend in engine.available_backends("conv_filter", requires_grad=False) else "escn_aligned"
            p = eng.plan(L, L, L, backend=be, **kw)
        heuristic = eng.select(p.key)
        t = time_fn(jax.jit(lambda a, b: p.apply(a, b)), x, r)
        record(records, f"engine_conv_L{L}_B{B}", t, echo=csv,
               backend=p.backend, heuristic=heuristic)
    return records


if __name__ == "__main__":
    run()
