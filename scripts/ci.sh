#!/usr/bin/env bash
# CI gate: tier-1 tests + the fast benchmark sweep (BENCH_gaunt.json).
#
#   scripts/ci.sh [extra pytest args...]
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"

echo "=== tier-1 tests ==="
python -m pytest -x -q "$@"

echo "=== fast benchmarks (--backend auto -> BENCH_gaunt.json) ==="
python -m benchmarks.run --fast --backend auto --json BENCH_gaunt.json

echo "=== BENCH_gaunt.json summary ==="
python - <<'EOF'
import json
d = json.load(open("BENCH_gaunt.json"))
recs = d["records"]
print(f"{len(recs)} records; engine picks:")
for r in recs:
    if r["name"].startswith("engine_"):
        print(f"  {r['name']:32s} {r['us']:>10.1f} us  -> {r.get('backend')}")
EOF
echo "CI OK"
