#!/usr/bin/env bash
# CI gate: tier-1 tests + the fast benchmark sweep (BENCH_gaunt.json).
#
#   scripts/ci.sh [extra pytest args...]
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"

echo "=== tier-1 tests (conformance files deferred to their own tier) ==="
python -m pytest -x -q \
  --ignore=tests/test_equivariance.py --ignore=tests/test_engine_transforms.py "$@"

echo "=== conformance tier: equivariance + transform/batched-plan parity ==="
python -m pytest -q tests/test_equivariance.py tests/test_engine_transforms.py

echo "=== batched-bench smoke (batched vs looped dispatch) ==="
python -m benchmarks.run --fast --only engine_batched --json ''

echo "=== fast benchmarks (--backend auto -> BENCH_gaunt.json) ==="
python -m benchmarks.run --fast --backend auto --json BENCH_gaunt.json

echo "=== BENCH_gaunt.json summary ==="
python - <<'EOF'
import json
d = json.load(open("BENCH_gaunt.json"))
recs = d["records"]
print(f"{len(recs)} records; engine picks:")
for r in recs:
    if r["name"].startswith("engine_batched"):
        print(f"  {r['name']:32s} {r['us']:>10.1f} us  "
              f"(looped {r.get('looped_us')} us, x{r.get('speedup_vs_looped')})")
    elif r["name"].startswith("engine_"):
        print(f"  {r['name']:32s} {r['us']:>10.1f} us  -> {r.get('backend')}")
EOF
echo "CI OK"
