#!/usr/bin/env bash
# CI gate: tier-1 tests + the fast benchmark sweep (BENCH_gaunt.json).
#
#   scripts/ci.sh [extra pytest args...]
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"

echo "=== tier-1 tests (conformance + resident-sharded + chain-kernel files deferred to their own tiers) ==="
python -m pytest -x -q \
  --ignore=tests/test_equivariance.py --ignore=tests/test_engine_transforms.py \
  --ignore=tests/test_resident_batched.py --ignore=tests/test_chain_kernel.py "$@"

echo "=== conformance tier: equivariance + transform/batched-plan parity ==="
python -m pytest -q tests/test_equivariance.py tests/test_engine_transforms.py

echo "=== resident x sharded tier: MaceGaunt shard_data+fourier_resident on 2 devices ==="
# the unification gate: counter-proven no-fallback residency under
# donate/shard_spec, and the sharded resident MaceGaunt matching the
# unsharded legacy path numerically (subprocess tests set the XLA 2-device
# flag) — a silent fallback or divergence fails CI here
python -m pytest -q tests/test_resident_batched.py

echo "=== Pallas interpret tier: fused pairwise + n-way chain kernels (interpret=True) ==="
# every Pallas Gaunt kernel exercised off-TPU through the interpreter in one
# named gate: the pairwise collocation kernel (selected from test_kernels —
# a few seconds of dedicated re-run keeps this tier self-contained) and the
# n-way chain kernel with its grid-blocked accumulation, grad, vmap,
# residency, f64 and sharded paths — one pallas_call per chain, counter-proven
python -m pytest -q tests/test_chain_kernel.py
python -m pytest -q tests/test_kernels.py -k "gaunt_fused"

echo "=== batched-bench smoke (batched vs looped dispatch) ==="
python -m benchmarks.run --fast --only engine_batched --json ''

echo "=== fast benchmarks (--backend auto -> BENCH_gaunt.json) ==="
python -m benchmarks.run --fast --backend auto --json BENCH_gaunt.json

echo "=== BENCH_gaunt.json summary ==="
python - <<'EOF'
import json
d = json.load(open("BENCH_gaunt.json"))
recs = d["records"]
print(f"{len(recs)} records; engine picks:")
for r in recs:
    if r["name"].startswith("engine_chain_kernel"):
        print(f"  {r['name']:36s} {r['us']:>10.1f} us  -> {r.get('backend')} "
              f"(tree {r.get('tree_us')} us, x{r.get('speedup_vs_tree')})")
    elif r["name"].startswith("engine_calibration"):
        print(f"  {r['name']:36s} factor={r.get('factor')} "
              f"(default {r.get('default_factor')})")
    elif r["name"].startswith(("engine_batched", "engine_chain")):
        print(f"  {r['name']:36s} {r['us']:>10.1f} us  "
              f"(looped {r.get('looped_us')} us, x{r.get('speedup_vs_looped')})")
    elif r["name"].startswith("engine_"):
        print(f"  {r['name']:36s} {r['us']:>10.1f} us  -> {r.get('backend')}")
EOF

echo "=== bench guards: heuristic regret + chain-speedup regression ==="
git show HEAD:BENCH_gaunt.json > /tmp/bench_baseline.json 2>/dev/null || true
python - <<'EOF'
import json, os, sys

# guard 1 — autotune cost model: where the heuristic pick disagrees with the
# measured winner, its measured regret must stay within tolerance
TOL = 1.5
fail = []
recs = json.load(open("BENCH_gaunt.json"))["records"]
for r in recs:
    ratio = r.get("heuristic_ratio")
    if ratio is not None and ratio > TOL:
        fail.append(f"{r['name']}: heuristic {r['heuristic']} is {ratio}x the "
                    f"measured winner {r['backend']} (> {TOL}x tolerance)")

# guard 2 — chain benchmarks: resident speedups must not regress > 20%
# against the committed baseline, nor fall below the absolute floor.
# Committed runs show > 1 everywhere; the floor sits below 1 because the
# baseline was measured on a different host and CPU microbenchmark noise
# across machines exceeds a few percent.  Both knobs are env-tunable for
# noisier runners (BENCH_GUARD_FLOOR / BENCH_GUARD_FRAC).
FLOOR = float(os.environ.get("BENCH_GUARD_FLOOR", "0.9"))
FRAC = float(os.environ.get("BENCH_GUARD_FRAC", "0.8"))
if os.path.exists("/tmp/bench_baseline.json") and os.path.getsize("/tmp/bench_baseline.json"):
    base = {r["name"]: r for r in json.load(open("/tmp/bench_baseline.json"))["records"]}
else:
    base = {}
for r in recs:
    if not r["name"].startswith("engine_chain") or \
            r["name"].startswith("engine_chain_kernel"):
        continue
    s = r.get("speedup_vs_looped", 0.0)
    if s < FLOOR:
        fail.append(f"{r['name']}: resident path LOST to looped (x{s} < {FLOOR})")
    b = base.get(r["name"], {}).get("speedup_vs_looped")
    if b and s < FRAC * b:
        fail.append(f"{r['name']}: chain speedup regressed x{b} -> x{s} (>20%)")

# guard 3 — chain autotune: where the measured autotuner picked the
# collocation kernel, the pick must actually beat (>= KFLOOR x) the resident
# tree-conv on that workload — a kernel that wins the measurement but loses
# the bench means the autotune methodology regressed.  And the kernel must
# win SOMEWHERE: if no benchmarked chain workload selects a fused backend,
# the chain-autotune fold is dead weight.  Both knobs are env-tunable:
# BENCH_GUARD_KERNEL_FLOOR for the loss check, and
# BENCH_GUARD_REQUIRE_KERNEL_WIN=0 for hosts whose matmul/FFT balance makes
# tree the honest winner everywhere (that is a valid autotune outcome, not
# a regression).
KFLOOR = float(os.environ.get("BENCH_GUARD_KERNEL_FLOOR", "0.9"))
REQUIRE_WIN = os.environ.get("BENCH_GUARD_REQUIRE_KERNEL_WIN", "1") != "0"
kernel_recs = [r for r in recs if r["name"].startswith("engine_chain_kernel_")]
if kernel_recs:
    picked = [r for r in kernel_recs
              if r.get("backend", "").startswith("fused")]
    if not picked and REQUIRE_WIN:
        fail.append("engine_chain_kernel: the measured autotuner picked the "
                    "collocation kernel on NO benchmarked chain workload "
                    "(set BENCH_GUARD_REQUIRE_KERNEL_WIN=0 if tree honestly "
                    "wins everywhere on this host)")
    for r in picked:
        s = r.get("speedup_vs_tree", 0.0)
        if s < KFLOOR:
            fail.append(f"{r['name']}: autotuner picked {r['backend']} but it "
                        f"LOST to tree-conv (x{s} < {KFLOOR})")
if fail:
    print("BENCH GUARD FAILURES:")
    for f in fail:
        print(" -", f)
    sys.exit(1)
print("bench guards OK")
EOF
echo "CI OK"
