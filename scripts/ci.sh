#!/usr/bin/env bash
# CI gate: tier-1 tests + the fast benchmark sweep (BENCH_gaunt.json).
#
#   scripts/ci.sh [extra pytest args...]
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"

echo "=== tier-1 tests (conformance + resident-sharded + chain-kernel files deferred to their own tiers) ==="
python -m pytest -x -q \
  --ignore=tests/test_equivariance.py --ignore=tests/test_engine_transforms.py \
  --ignore=tests/test_resident_batched.py --ignore=tests/test_chain_kernel.py "$@"

echo "=== conformance tier: equivariance + transform/batched-plan parity (f32; bf16 has its own tier) ==="
python -m pytest -q tests/test_equivariance.py tests/test_engine_transforms.py \
  -k "not bfloat16"

echo "=== resident x sharded tier: MaceGaunt shard_data+fourier_resident on 2 devices ==="
# the unification gate: counter-proven no-fallback residency under
# donate/shard_spec, and the sharded resident MaceGaunt matching the
# unsharded legacy path numerically (subprocess tests set the XLA 2-device
# flag) — a silent fallback or divergence fails CI here
python -m pytest -q tests/test_resident_batched.py

echo "=== Pallas interpret tier: fused pairwise + n-way chain kernels (interpret=True) ==="
# every Pallas Gaunt kernel exercised off-TPU through the interpreter in one
# named gate: the pairwise collocation kernel (selected from test_kernels —
# a few seconds of dedicated re-run keeps this tier self-contained) and the
# n-way chain kernel with its grid-blocked accumulation, grad, vmap,
# residency, f64 and sharded paths — one pallas_call per chain, counter-proven
python -m pytest -q tests/test_chain_kernel.py -k "not bfloat16"
python -m pytest -q tests/test_kernels.py -k "gaunt_fused and not bfloat16"

echo "=== bf16 interpret tier: bfloat16 storage / f32 accumulation (conformance + chain kernels) ==="
# every bfloat16-parameterized case in one named gate: rotation-equivariance
# conformance at the documented bf16 tolerances (DESIGN.md §3.6), the n-way
# chain kernel vs the f32 tree oracle, and the pairwise kernel's dtype sweep
# — all through the Pallas interpreter off-TPU, storage bf16 / accumulation f32
python -m pytest -q tests/test_equivariance.py tests/test_chain_kernel.py \
  tests/test_kernels.py -k "bfloat16"

echo "=== batched-bench smoke (batched vs looped dispatch) ==="
python -m benchmarks.run --fast --only engine_batched --json ''

echo "=== serve tier: load-generator smoke (low QPS, tiny model, bucketed pools) ==="
# the serving scale-out gate (DESIGN.md §10): the open-loop load generator
# drives the bucketed scheduler/pool/pipelining stack end-to-end at low QPS
# — a deadlock, lost request, or scheduler regression hangs or fails here
# before the full bench (which re-runs serve into BENCH_gaunt.json) starts
python - <<'EOF'
from benchmarks.bench_serve import run_serve
recs = run_serve(fast=True, n_req=12, qps_list=(15.0,))
by = {r["name"]: r for r in recs}
assert by["serve_qps15"]["completed"] == 12, by
assert by["serve_qps15"]["rejected"] == 0, by
print("serve smoke OK")
EOF

echo "=== chaos smoke: fault-injected closed loop (raises + NaNs + timeouts) ==="
# the fault-tolerance gate (DESIGN.md §11): a seeded FaultPlan fails steps
# mid-drain and the run must still lose ZERO requests — every one completed
# or structurally rejected — with results identical to fault-free for the
# completions; a recovery regression (lost request, poisoned bucket-mate,
# retry that isn't idempotent) fails here before the full chaos bench runs
python - <<'EOF'
from benchmarks.bench_serve import run_serve_chaos
recs = run_serve_chaos(fast=True, n_req=12, rates=(0.2,))
for r in recs:
    assert r["lost"] == 0, r
    assert r["results_match"], r
chaos = [r for r in recs if r["name"].startswith("serve_chaos_rate")]
assert chaos and all(r["step_failures"] > 0 for r in chaos), recs
fo = [r for r in recs if r["name"] == "serve_chaos_failover"]
assert fo and fo[0]["failovers"] >= 1, recs
print("chaos smoke OK")
EOF

echo "=== fast benchmarks (--backend auto -> BENCH_gaunt.json) ==="
python -m benchmarks.run --fast --backend auto --json BENCH_gaunt.json

echo "=== BENCH_gaunt.json summary ==="
python - <<'EOF'
import json
d = json.load(open("BENCH_gaunt.json"))
recs = d["records"]
print(f"{len(recs)} records; engine picks:")
for r in recs:
    if r["name"].startswith("engine_chain_kernel"):
        print(f"  {r['name']:36s} {r['us']:>10.1f} us  -> {r.get('backend')} "
              f"(tree {r.get('tree_us')} us, x{r.get('speedup_vs_tree')})")
    elif r["name"].startswith("engine_calibration"):
        print(f"  {r['name']:36s} factor={r.get('factor')} "
              f"(default {r.get('default_factor')})")
    elif r["name"].startswith("engine_grid_gate"):
        print(f"  {r['name']:36s} {r['us']:>10.1f} us  grid gate "
              f"x{r.get('speedup_vs_sh_gate')} vs SH gate, err={r.get('err')}, "
              f"auto->{r.get('auto_policy')}")
    elif r["name"].startswith("engine_mixed_precision"):
        print(f"  {r['name']:36s} {r['us']:>10.1f} us  bf16 "
              f"x{r.get('speedup_vs_f32')} vs f32, err={r.get('err')}, "
              f"auto->{r.get('auto_dtype')}")
    elif r["name"].startswith("engine_autotune_cache"):
        print(f"  {r['name']:36s} {r['us']:>10.1f} us  warm "
              f"(cold {r.get('cold_us')} us, x{r.get('speedup_vs_cold')}, "
              f"warm timing runs {r.get('warm_timing_runs')})")
    elif r["name"] == "serve_bucketed_vs_single":
        print(f"  {r['name']:36s} {r['us']:>10.1f} us  bucketed "
              f"x{r.get('speedup_vs_single')} vs single max_atoms "
              f"({r.get('throughput_rps')} rps, padding eff "
              f"{r.get('padding_efficiency')} vs "
              f"{r.get('single_padding_efficiency')})")
    elif r["name"].startswith("serve_qps"):
        print(f"  {r['name']:36s} {r['us']:>10.1f} us p50  "
              f"(p99 {r.get('p99_us')} us, {r.get('throughput_rps')} rps, "
              f"padding eff {r.get('padding_efficiency')})")
    elif r["name"].startswith("serve_chaos_rate"):
        print(f"  {r['name']:36s} {r['us']:>10.1f} us  lost={r.get('lost')} "
              f"match={r.get('results_match')} "
              f"failures={r.get('step_failures')} retries={r.get('retries')} "
              f"recovery p99 {r.get('recovery_p99_ms')} ms, "
              f"x{r.get('degradation_vs_baseline')} of fault-free")
    elif r["name"] == "serve_chaos_failover":
        print(f"  {r['name']:36s} {r['us']:>10.1f} us  lost={r.get('lost')} "
              f"match={r.get('results_match')} "
              f"failovers={r.get('failovers')} "
              f"requeued={r.get('requeued_on_failover')}")
    elif r["name"].startswith(("engine_batched", "engine_chain")):
        print(f"  {r['name']:36s} {r['us']:>10.1f} us  "
              f"(looped {r.get('looped_us')} us, x{r.get('speedup_vs_looped')})")
    elif r["name"].startswith("engine_"):
        print(f"  {r['name']:36s} {r['us']:>10.1f} us  -> {r.get('backend')}")
EOF

echo "=== warm-cache guard: calibrate CLI populates, second sweep runs 0 timings ==="
# two passes over a throwaway cache file: the first measures the (fast)
# workload grid and persists it, the second must answer every selection from
# the file — --verify-warm exits non-zero if even one timing run happened,
# which is exactly the cold-start cliff the persistent cache exists to close
AUTOTUNE_CACHE="$(mktemp -t autotune_cache.XXXXXX.json)"
trap 'rm -f "$AUTOTUNE_CACHE"' EXIT
rm -f "$AUTOTUNE_CACHE"  # the CLI wants to create it atomically itself
python -m repro.core.autotune_cache --fast --cache "$AUTOTUNE_CACHE"
python -m repro.core.autotune_cache --fast --cache "$AUTOTUNE_CACHE" --verify-warm

echo "=== bench guards: heuristic regret + chain-speedup + mixed-precision + warm-start ==="
# per-run baseline path (mktemp, not a fixed /tmp name): concurrent CI runs
# on a shared runner must not clobber each other's baselines
BENCH_BASELINE="$(mktemp -t bench_baseline.XXXXXX.json)"
trap 'rm -f "$AUTOTUNE_CACHE" "$BENCH_BASELINE"' EXIT
git show HEAD:BENCH_gaunt.json > "$BENCH_BASELINE" 2>/dev/null || true
BENCH_BASELINE="$BENCH_BASELINE" python - <<'EOF'
import json, os, sys

# guard 1 — autotune cost model: where the heuristic pick disagrees with the
# measured winner, its measured regret must stay within tolerance
TOL = 1.5
fail = []
recs = json.load(open("BENCH_gaunt.json"))["records"]
for r in recs:
    ratio = r.get("heuristic_ratio")
    if ratio is not None and ratio > TOL:
        fail.append(f"{r['name']}: heuristic {r['heuristic']} is {ratio}x the "
                    f"measured winner {r['backend']} (> {TOL}x tolerance)")

# guard 2 — chain benchmarks: resident speedups must not regress > 20%
# against the committed baseline, nor fall below the absolute floor.
# Committed runs show > 1 everywhere; the floor sits below 1 because the
# baseline was measured on a different host and CPU microbenchmark noise
# across machines exceeds a few percent.  Both knobs are env-tunable for
# noisier runners (BENCH_GUARD_FLOOR / BENCH_GUARD_FRAC).
FLOOR = float(os.environ.get("BENCH_GUARD_FLOOR", "0.9"))
FRAC = float(os.environ.get("BENCH_GUARD_FRAC", "0.8"))
baseline = os.environ.get("BENCH_BASELINE", "")
if baseline and os.path.exists(baseline) and os.path.getsize(baseline):
    base = {r["name"]: r for r in json.load(open(baseline))["records"]}
else:
    base = {}
for r in recs:
    if not r["name"].startswith("engine_chain") or \
            r["name"].startswith("engine_chain_kernel"):
        continue
    s = r.get("speedup_vs_looped", 0.0)
    if s < FLOOR:
        fail.append(f"{r['name']}: resident path LOST to looped (x{s} < {FLOOR})")
    b = base.get(r["name"], {}).get("speedup_vs_looped")
    if b and s < FRAC * b:
        fail.append(f"{r['name']}: chain speedup regressed x{b} -> x{s} (>20%)")

# guard 3 — chain autotune: where the measured autotuner picked the
# collocation kernel, the pick must actually beat (>= KFLOOR x) the resident
# tree-conv on that workload — a kernel that wins the measurement but loses
# the bench means the autotune methodology regressed.  And the kernel must
# win SOMEWHERE: if no benchmarked chain workload selects a fused backend,
# the chain-autotune fold is dead weight.  Both knobs are env-tunable:
# BENCH_GUARD_KERNEL_FLOOR for the loss check, and
# BENCH_GUARD_REQUIRE_KERNEL_WIN=0 for hosts whose matmul/FFT balance makes
# tree the honest winner everywhere (that is a valid autotune outcome, not
# a regression).
KFLOOR = float(os.environ.get("BENCH_GUARD_KERNEL_FLOOR", "0.9"))
REQUIRE_WIN = os.environ.get("BENCH_GUARD_REQUIRE_KERNEL_WIN", "1") != "0"
kernel_recs = [r for r in recs if r["name"].startswith("engine_chain_kernel_")]
if kernel_recs:
    picked = [r for r in kernel_recs
              if r.get("backend", "").startswith("fused")]
    if not picked and REQUIRE_WIN:
        fail.append("engine_chain_kernel: the measured autotuner picked the "
                    "collocation kernel on NO benchmarked chain workload "
                    "(set BENCH_GUARD_REQUIRE_KERNEL_WIN=0 if tree honestly "
                    "wins everywhere on this host)")
    for r in picked:
        s = r.get("speedup_vs_tree", 0.0)
        if s < KFLOOR:
            fail.append(f"{r['name']}: autotuner picked {r['backend']} but it "
                        f"LOST to tree-conv (x{s} < {KFLOOR})")
# guard 4 — mixed precision: every engine_mixed_precision_* record must keep
# its bf16-vs-f32 relative error inside the documented budget (DESIGN.md
# §3.6; bf16 eps is 2^-8 ~ 3.9e-3, committed runs show err <= 4e-3, the
# default tolerance leaves ~10x headroom for input-dependent cancellation),
# AND wherever the measured autotuner kept a bfloat16 plan it must not LOSE
# to its f32 sibling on the bench re-measure.  bf16 is NOT required to win
# anywhere — on hosts that emulate bf16 (CPU) float32 everywhere is the
# honest autotune outcome; only a *losing* bf16 pick means the precision
# autotune methodology regressed.  The floor sits at 0.75, looser than
# guard 3's 0.9: kernel-vs-tree wins are x2-6 so 0.9 is far from the
# signal, but precision wins on an emulating host are marginal by nature
# (observed x0.8-1.4 run-to-run on the same workload) — the floor exists
# to catch a pick that is *clearly* wrong, not measurement jitter between
# the autotune timing and the bench re-timing.  Both knobs are env-tunable
# (BENCH_GUARD_BF16_TOL / BENCH_GUARD_BF16_FLOOR, modeled on guard 3).
BF16_TOL = float(os.environ.get("BENCH_GUARD_BF16_TOL", "0.05"))
BF16_FLOOR = float(os.environ.get("BENCH_GUARD_BF16_FLOOR", "0.75"))
for r in recs:
    if not r["name"].startswith("engine_mixed_precision_"):
        continue
    e = r.get("err")
    if e is not None and e > BF16_TOL:
        fail.append(f"{r['name']}: bf16 error {e} exceeds tolerance "
                    f"{BF16_TOL} (storage rounding should stay ~eps=3.9e-3; "
                    f"an err this large means accumulation dropped to bf16)")
    if r.get("auto_dtype") == "bfloat16":
        s = r.get("speedup_vs_f32", 0.0)
        if s < BF16_FLOOR:
            fail.append(f"{r['name']}: autotuner kept bfloat16 but it LOST "
                        f"to its f32 sibling (x{s} < {BF16_FLOOR})")

# guard 5 — persistent autotune: the warm subprocess in the cold-vs-warm
# record must have performed ZERO timing runs and selected identically to
# the cold one — a single warm timing run means the persisted table failed
# to cover the workload (broken serialization, fingerprint drift, or a
# selection path that stopped consulting the cache)
for r in recs:
    if not r["name"].startswith("engine_autotune_cache"):
        continue
    if r.get("warm_timing_runs", 0) != 0:
        fail.append(f"{r['name']}: warm process ran "
                    f"{r['warm_timing_runs']} timing runs (must be 0 — the "
                    f"persisted cache did not cover the workload)")
    if not r.get("picks_match", False):
        fail.append(f"{r['name']}: warm process selected differently from "
                    f"the cold one (persisted table is not faithful)")

# guard 6 — grid-resident gates (DESIGN.md §6.5): exactness first — the
# gate is affine on the sphere once its scalars are known, so grid-vs-SH
# disagreement is storage roundoff, NOT aliasing; err above tolerance means
# the fused pointwise stage or the quadrature projection broke.  Then
# policy honesty: where the measured gate policy (engine.select_gate)
# picked the grid gate, the bench re-measure must not show it losing to
# the SH epilogue; and the fused gate must win somewhere, else the gate
# fusion (and its autotune fold) is dead weight.  All knobs env-tunable,
# modeled on guards 3/4; BENCH_GUARD_REQUIRE_GATE_WIN=0 for hosts where
# the SH epilogue honestly wins everywhere.
GATE_TOL = float(os.environ.get("BENCH_GUARD_GATE_TOL", "1e-3"))
GATE_FLOOR = float(os.environ.get("BENCH_GUARD_GATE_FLOOR", "0.9"))
REQUIRE_GATE_WIN = os.environ.get("BENCH_GUARD_REQUIRE_GATE_WIN", "1") != "0"
gate_recs = [r for r in recs if r["name"].startswith("engine_grid_gate_")]
for r in gate_recs:
    e = r.get("err")
    if e is not None and e > GATE_TOL:
        fail.append(f"{r['name']}: grid-gate error {e} exceeds "
                    f"{GATE_TOL} (the affine gate is exact on the grid — "
                    f"an err this large means the fused stage broke)")
    if r.get("auto_policy") == "grid" and \
            r.get("speedup_vs_sh_gate", 0.0) < GATE_FLOOR:
        fail.append(f"{r['name']}: gate policy picked 'grid' but it LOST "
                    f"to the SH gate (x{r.get('speedup_vs_sh_gate')} < "
                    f"{GATE_FLOOR})")
if gate_recs and REQUIRE_GATE_WIN and not any(
        r.get("speedup_vs_sh_gate", 0.0) >= 1.0 for r in gate_recs):
    fail.append("engine_grid_gate: the fused grid gate beat the SH gate on "
                "NO benchmarked workload (set BENCH_GUARD_REQUIRE_GATE_WIN=0 "
                "if the SH epilogue honestly wins everywhere on this host)")

# guard 7 — serve scale-out (DESIGN.md §10): the bench record must EXIST
# (a silently-skipped serve job would let the serving layer rot unmeasured),
# open-loop p99 latency must stay under an env-tunable ceiling, nothing may
# be rejected at the smoke's low QPS, and the bucketed pools must beat the
# single-max_atoms baseline on throughput for the mixed-size workload —
# the whole point of size bucketing (committed runs show ~x2.7 on CPU; the
# floor sits at 1.0 because the win comes from padded-FLOP arithmetic, not
# microbenchmark noise).  BENCH_GUARD_SERVE_P99_MS / BENCH_GUARD_SERVE_FLOOR
# env-tunable; BENCH_GUARD_REQUIRE_SERVE_WIN=0 opts out of the win check on
# hosts whose scheduling jitter genuinely swamps the padding arithmetic.
SERVE_P99_MS = float(os.environ.get("BENCH_GUARD_SERVE_P99_MS", "500"))
SERVE_FLOOR = float(os.environ.get("BENCH_GUARD_SERVE_FLOOR", "1.0"))
REQUIRE_SERVE_WIN = os.environ.get("BENCH_GUARD_REQUIRE_SERVE_WIN", "1") != "0"
serve_recs = [r for r in recs if r["name"].startswith("serve_")]
if not serve_recs:
    fail.append("serve: BENCH_gaunt.json carries NO serve_* records — the "
                "load-generator bench did not run or did not record")
else:
    vs = [r for r in serve_recs if r["name"] == "serve_bucketed_vs_single"]
    if not vs:
        fail.append("serve: the serve_bucketed_vs_single record is missing")
    elif REQUIRE_SERVE_WIN and vs[0].get("speedup_vs_single", 0.0) < SERVE_FLOOR:
        fail.append(f"serve_bucketed_vs_single: bucketed pools LOST to the "
                    f"single-max_atoms baseline on throughput "
                    f"(x{vs[0].get('speedup_vs_single')} < {SERVE_FLOOR})")
    qps_recs = [r for r in serve_recs if r["name"].startswith("serve_qps")]
    if not qps_recs:
        fail.append("serve: no serve_qps* records — the QPS sweep is missing")
    for r in qps_recs:
        p99_ms = r.get("p99_us", 0.0) / 1e3
        if p99_ms > SERVE_P99_MS:
            fail.append(f"{r['name']}: p99 latency {p99_ms:.1f}ms exceeds "
                        f"the {SERVE_P99_MS}ms ceiling "
                        f"(BENCH_GUARD_SERVE_P99_MS)")
        if r.get("timing_runs") not in (None, 0):
            fail.append(f"{r['name']}: {r['timing_runs']} mid-serve autotune "
                        f"timing runs (serving must never time-measure)")

# guard 8 — chaos / fault tolerance (DESIGN.md §11): the serve_chaos_*
# records must EXIST (unmeasured recovery is asserted recovery), the lost-
# request count must be 0 at every injected fault rate (every request
# completed or structurally rejected — a lost request is a serving bug, not
# a tuning matter, so there is NO escape hatch for it), non-rejected results
# must match the fault-free run (retry idempotency), and recovery p99 must
# stay under an env-tunable ceiling (BENCH_GUARD_RECOVERY_P99_MS — the one
# knob here that is host-speed-dependent: recovery includes a re-staged
# evaluation, so slow runners may honestly exceed the default).
RECOVERY_P99_MS = float(os.environ.get("BENCH_GUARD_RECOVERY_P99_MS", "500"))
chaos_recs = [r for r in recs if r["name"].startswith("serve_chaos_")]
if not chaos_recs:
    fail.append("serve_chaos: BENCH_gaunt.json carries NO serve_chaos_* "
                "records — the chaos bench did not run or did not record")
for r in chaos_recs:
    if r.get("lost", 1) != 0:
        fail.append(f"{r['name']}: {r.get('lost')} requests LOST (every "
                    f"request must complete or reject structurally)")
    if r.get("results_match") is False:
        fail.append(f"{r['name']}: non-rejected results differ from the "
                    f"fault-free run (retry is not idempotent; max energy "
                    f"diff {r.get('max_energy_diff')})")
    p99 = r.get("recovery_p99_ms")
    if p99 is not None and p99 > RECOVERY_P99_MS:
        fail.append(f"{r['name']}: recovery p99 {p99}ms exceeds the "
                    f"{RECOVERY_P99_MS}ms ceiling "
                    f"(BENCH_GUARD_RECOVERY_P99_MS)")
if chaos_recs and not any(r["name"] == "serve_chaos_failover"
                          for r in chaos_recs):
    fail.append("serve_chaos: the serve_chaos_failover record is missing — "
                "replica failover is not being exercised")

if fail:
    print("BENCH GUARD FAILURES:")
    for f in fail:
        print(" -", f)
    sys.exit(1)
print("bench guards OK")
EOF
echo "CI OK"
