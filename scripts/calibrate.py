#!/usr/bin/env python
"""Offline autotune calibration — thin wrapper over the real CLI.

    scripts/calibrate.py --cache /var/cache/repro/gaunt_autotune.json
    scripts/calibrate.py --cache ... --verify-warm   # prove zero timing runs

Sweeps the known workload grid (plan keys, chain keys at both storage
precisions, fused-cost calibration per dtype) and persists the measured
selection table so production serve processes boot warm.  See
`python -m repro.core.autotune_cache --help` and DESIGN.md §4.5.
"""
import os
import sys

sys.path.insert(0, os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"))

from repro.core.autotune_cache import main  # noqa: E402

if __name__ == "__main__":
    raise SystemExit(main())
