"""Config system: one dataclass drives model build, sharding, launch and the
dry-run.  Arch configs live in `repro.configs.<id>` and register themselves.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

__all__ = ["ModelConfig", "ShapeConfig", "TrainConfig", "register", "get_config", "list_configs", "SHAPES"]


@dataclasses.dataclass
class ModelConfig:
    name: str
    family: str = "dense"  # dense | moe | hybrid | encdec | vlm | ssm
    n_layers: int = 2
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: Optional[int] = None  # None -> MHA
    head_dim: Optional[int] = None  # None -> d_model // n_heads
    d_ff: int = 1024
    vocab: int = 1000
    act: str = "swiglu"  # swiglu | geglu | gelu_mlp
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    qkv_bias: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 10000.0
    partial_rotary: float = 1.0  # fraction of head_dim that rotates
    mrope_sections: Optional[tuple[int, ...]] = None  # qwen2-vl M-RoPE
    embed_scale: bool = False  # gemma sqrt(d) embedding scale
    rms_one_offset: bool = False  # gemma (1 + w) rmsnorm
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    d_ff_expert: Optional[int] = None
    router_aux_loss: float = 0.001
    capacity_factor: float = 1.25
    # --- SSM / RWKV ---
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_groups: int = 1
    rwkv_head_k: int = 64
    attn_every: int = 0  # zamba2: shared attention block interval
    # --- enc-dec ---
    n_enc_layers: int = 0
    max_source_len: int = 1500  # whisper frame count after conv stub
    # --- numerics / runtime ---
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    remat: bool = True
    scan_layers: bool = True
    attn_chunk: int = 1024  # blockwise attention query-chunk
    kv_cache_dtype: str = "model"  # model | int8 (per-position-head scales)
    use_pallas: bool = False
    logit_softcap: float = 0.0
    max_seq: int = 8192

    @property
    def kv_heads(self) -> int:
        return self.n_kv_heads if self.n_kv_heads is not None else self.n_heads

    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    def reduced(self, **over) -> "ModelConfig":
        """Tiny same-family variant for CPU smoke tests."""
        kw = dataclasses.asdict(self)
        kw.update(
            n_layers=min(self.n_layers, 2 if self.attn_every == 0 else self.attn_every),
            d_model=128,
            n_heads=4,
            n_kv_heads=min(self.kv_heads, 4) if self.kv_heads < self.n_heads else 4,
            head_dim=32,
            d_ff=256,
            vocab=512,
            n_experts=min(self.n_experts, 4),
            top_k=min(self.top_k, 2),
            n_shared_experts=min(self.n_shared_experts, 1),
            d_ff_expert=128 if self.d_ff_expert else None,
            ssm_state=min(self.ssm_state, 16),
            ssm_headdim=16,
            rwkv_head_k=16,
            n_enc_layers=min(self.n_enc_layers, 2),
            max_source_len=64,
            attn_every=min(self.attn_every, 2) if self.attn_every else 0,
            dtype="float32",
            param_dtype="float32",
            attn_chunk=64,
            remat=False,
            max_seq=256,
        )
        if self.mrope_sections:
            kw["mrope_sections"] = (4, 6, 6)
        kw.update(over)
        kw["name"] = self.name + "-smoke"
        return ModelConfig(**kw)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


@dataclasses.dataclass
class TrainConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    schedule: str = "cosine"
    microbatch: int = 0  # 0 = no accumulation
    seed: int = 0
    checkpoint_every: int = 200
    keep_checkpoints: int = 3
    grad_compression: str = "none"  # none | int8_ef (pod axis)
    log_every: int = 10


_REGISTRY: dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    if not _REGISTRY:
        from repro import configs  # noqa: F401  (registers all)
    import repro.configs  # noqa

    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_configs() -> list[str]:
    import repro.configs  # noqa

    return sorted(_REGISTRY)
