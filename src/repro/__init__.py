"""repro — the Gaunt Tensor Product paper as a production JAX framework.

Layers: core (the paper), kernels (Pallas), models (10 LM archs +
equivariant nets), optim/data/checkpoint/train/serve (substrate),
distributed (sharding/fault tolerance), launch (mesh/dryrun/train/serve).
"""

__version__ = "1.0.0"
