"""Fused Gaunt tensor product Pallas TPU kernels — sample * multiply * project.

TPU adaptation of the paper's FFT pipeline (see DESIGN.md §3): instead of
(complex s2f -> FFT conv -> complex f2s) we use the mathematically identical
*collocation* form on the torus grid,

    out = ((x1 @ T1) .* (x2 @ T2) .* ... .* (xn @ Tn)) @ P

with  T_i[j, g]   = S_j(theta_g, psi_g)        (real SH sampled on the grid)
      P[g, k]     = Re((1/G) sum_{u,v} e^{-i(u t_g + v p_g)} z^{k}_{u,v})

for any chain length n >= 2 (`gaunt_chain_fused_pallas`; the historical
pairwise `gaunt_fused_pallas` is the n = 2 wrapper).

Exactness: the product of n bandlimited spherical functions is bandlimited
at sum(L_i) on the torus double cover; an N x N grid with N >= 2*sum(L_i)+1
samples it alias-free, so the discrete projection equals the paper's
convolution-theorem result to machine precision (tested).

Why this shape for TPU: n+1 dense real matmuls hit the MXU back-to-back with
VMEM-resident elementwise multiplies between them — a whole ChainPlan is ONE
`pallas_call` instead of n+2 XLA ops; the FFT path (VPU butterflies on tiny
grids) and gather-based sparse conversions are far from MXU peak at
practical L.  Large product grids (high sum(L_i)) are handled by blocking
the grid axis and accumulating partial projections in the output block, so
per-step VMEM stays bounded; batch rows block as before.  All operands are
zero-padded to lane/tile boundaries (8 x 128) outside the kernel.

Fourier-resident operands enter *as grids*: their real-stacked half grid
multiplies the grid-evaluation matrix (`constants.chain_sample_grid`)
instead of the SH sampling matrix — same kernel, no sh_to_fourier, and a
'grid' exit returns the resident half product grid (`chain_project_grid`).

The chain kernel carries a custom VJP (the collocation matmuls are their own
adjoints: dV_i = (dout @ P^T) * prod_{j!=i} V_j, dx_i = dV_i @ T_i^T, run as
plain jnp), so chain plans on the kernel backend support grad — unlike the
historical pairwise `fused_pallas` backend.

Mixed precision (DESIGN.md §3.6): every runner takes a *storage* dtype
('float32' | 'bfloat16' | 'float64') governing operand and sampling-matrix
(T_i) storage; the MXU accumulates at >= f32 via ``preferred_element_type``
and the projection matrix P plus the output stay at the accumulation dtype.
bf16 halves operand/constant bytes, so the default VMEM blocks
(`block_b`/`block_g`) double and the row-block floor rises to the bf16
sublane tile (16 x 128).

``kernel_stats()`` counts kernel dispatches (ticked once per trace/eager
call), letting tests *prove* the one-`pallas_call` claim instead of assuming
it.
"""
from __future__ import annotations

from functools import lru_cache

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

__all__ = [
    "gaunt_fused_matrices",
    "gaunt_fused_pallas",
    "gaunt_chain_fused_pallas",
    "gaunt_chain_fused_xla",
    "kernel_stats",
    "reset_kernel_stats",
]


# ticked once per wrapper call (eager) or trace (jit) — the proof counters
# behind "a >= 3-operand chain runs as ONE pallas_call"
_STATS = {"pairwise_pallas_calls": 0, "chain_pallas_calls": 0}


def kernel_stats() -> dict:
    """{'pairwise_pallas_calls': n, 'chain_pallas_calls': m} since reset."""
    return dict(_STATS)


def reset_kernel_stats() -> None:
    for k in _STATS:
        _STATS[k] = 0


def gaunt_fused_matrices(L1: int, L2: int, Lout: int, pad_lanes: bool = True,
                         dtype: str = "float32"):
    """Numpy (T1 [d1,G], T2 [d2,G], P [G,dout]) — exact.

    Back-compat alias: the builder (and its cache) lives in the engine's
    constant-cache module, `repro.core.constants.fused_matrices`.
    """
    from repro.core.constants import fused_matrices

    return fused_matrices(L1, L2, Lout, pad_lanes, dtype=dtype)


# storage-dtype resolution for every kernel entry point: an explicit request
# wins; otherwise the operands' jnp promotion decides (bfloat16 only when
# EVERY operand is bf16 — a mixed bf16/f32 chain promotes to f32 storage),
# complex residents map to their real width, and float64 storage only exists
# under x64 (it is interpret-only: no accelerator lowers it).
def _storage_dtype(xs, dtype) -> str:
    if dtype is None:
        rt = jnp.result_type(*xs)
        name = {"complex64": "float32", "complex128": "float64"}.get(
            rt.name, rt.name)
    else:
        name = dtype if isinstance(dtype, str) else jnp.dtype(dtype).name
    if name not in ("float32", "bfloat16", "float64"):
        name = "float32"
    if name == "float64" and not jax.config.jax_enable_x64:
        name = "float32"
    return name


def _kernel(x1_ref, x2_ref, t1_ref, t2_ref, p_ref, o_ref):
    v1 = jnp.dot(x1_ref[...], t1_ref[...], preferred_element_type=jnp.float32)
    v2 = jnp.dot(x2_ref[...], t2_ref[...], preferred_element_type=jnp.float32)
    o_ref[...] = jnp.dot(v1 * v2, p_ref[...], preferred_element_type=jnp.float32)


def _make_chain_kernel(n: int, acc_dt, gated: bool = False):
    """The n-operand collocation kernel body.

    Grid is (row blocks, grid blocks): for one row block the kernel walks the
    (lane-padded) sample axis in `block_g` slices — sample every operand onto
    the slice, multiply n-way in VMEM, project the slice, and accumulate into
    the output block (revisited across the minor grid axis, the standard
    k-accumulation pattern).  Padded sample columns are zero in every T AND
    carry zero projection rows, so they contribute nothing.

    ``gated`` adds the fused pointwise-gate stage (DESIGN.md §6.5): two extra
    per-row scalar inputs (gs, gb — the affine form of `gate_apply` given its
    l=0 scalars, computed outside the kernel) scale-and-shift the VMEM-
    resident product values *before* projection: ``v <- v*gs + gb``.  Padded
    sample columns pick up the constant ``gb`` but their projection rows are
    zero; padded batch rows carry gs = gb = 0, so both stay inert.
    """

    def kernel(*refs):
        xs, ts = refs[:n], refs[n: 2 * n]
        p_ref, o_ref = refs[2 * n], refs[-1]
        v = jnp.dot(xs[0][...], ts[0][...], preferred_element_type=acc_dt)
        for x_ref, t_ref in zip(xs[1:], ts[1:]):
            v = v * jnp.dot(x_ref[...], t_ref[...], preferred_element_type=acc_dt)
        if gated:
            gs_ref, gb_ref = refs[2 * n + 1], refs[2 * n + 2]
            v = v * gs_ref[...] + gb_ref[...]
        part = jnp.dot(v, p_ref[...], preferred_element_type=acc_dt)
        g = pl.program_id(1)

        @pl.when(g == 0)
        def _init():
            o_ref[...] = part

        @pl.when(g != 0)
        def _accumulate():
            o_ref[...] = o_ref[...] + part

    return kernel


def _pad_axis(a: np.ndarray, axis: int, to: int) -> np.ndarray:
    pad = [(0, 0)] * a.ndim
    pad[axis] = (0, to - a.shape[axis])
    return np.pad(a, pad)


@lru_cache(maxsize=None)
def _chain_runner(Ls: tuple, Lout: int, entries: tuple, out_entry: str,
                  block_b: int, block_g: int, interpret: bool, sdt: str,
                  gated: bool = False):
    """A cached, custom-VJP'd row-level chain runner for one static config.

    Takes the tuple of row-flattened operands ([Bp, d_i], already padded to a
    multiple of ``block_b``) and returns [Bp, dout] — ONE `pallas_call`.
    The VJP reuses the same collocation matrices in plain jnp (dV_i =
    (dout @ P^T) * prod_{j != i} V_j; dx_i = dV_i @ T_i^T), so the kernel
    backend is grad-capable while the forward stays a single kernel.

    ``sdt`` is the storage dtype: operands and sampling matrices T_i live at
    ``sdt``, every dot accumulates at the >= f32 accumulation dtype, and the
    projection matrix P plus the output stay at the accumulation dtype.

    ``gated`` runners take two extra row-scalar arrays ([Bp, 1], at the
    accumulation dtype): ``run(arrs, gs, gb)`` applies ``v <- v*gs + gb`` to
    the product values between the n-way multiply and the projection —
    still ONE `pallas_call`.  The VJP extends accordingly: with V the
    pre-gate product grid, dgs = rowsum(U*V), dgb = rowsum(U), and each
    operand gradient picks up the gs scale (U = dout @ P^T).
    """
    from repro.core.constants import chain_matrices

    acc_dt = jnp.float64 if sdt == "float64" else jnp.float32
    acc_np = "float64" if sdt == "float64" else "float32"
    Ts, _ = chain_matrices(Ls, Lout, entries, out_entry, dtype=sdt)
    _, P = chain_matrices(Ls, Lout, entries, out_entry, dtype=acc_np)
    G = Ts[0].shape[1]
    Gp = -(-G // block_g) * block_g  # zero-pad: inert sample columns/rows
    Ts = tuple(_pad_axis(T, 1, Gp) for T in Ts)
    P = _pad_axis(P, 0, Gp)
    dout = P.shape[1]
    n = len(Ls)
    kernel = _make_chain_kernel(n, acc_dt, gated)

    def _call(arrs, gate_arrs=()):
        Bp = arrs[0].shape[0]
        d_in = [T.shape[0] for T in Ts]
        in_specs = (
            [pl.BlockSpec((block_b, d), lambda i, g: (i, 0)) for d in d_in]
            + [pl.BlockSpec((d, block_g), lambda i, g: (0, g)) for d in d_in]
            + [pl.BlockSpec((block_g, dout), lambda i, g: (g, 0))]
            + [pl.BlockSpec((block_b, 1), lambda i, g: (i, 0))
               for _ in gate_arrs]
        )
        return pl.pallas_call(
            kernel,
            grid=(Bp // block_b, Gp // block_g),
            in_specs=in_specs,
            out_specs=pl.BlockSpec((block_b, dout), lambda i, g: (i, 0)),
            out_shape=jax.ShapeDtypeStruct((Bp, dout), acc_dt),
            interpret=interpret,
        )(*arrs, *(jnp.asarray(T) for T in Ts), jnp.asarray(P), *gate_arrs)

    def _bwd_core(arrs, gs, dout_bar):
        # same storage discipline as the forward: operands and T stay at
        # ``sdt`` into the MXU, accumulation at acc_dt via preferred dtype
        Tj = [jnp.asarray(T) for T in Ts]
        Vs = [jnp.dot(a, T, preferred_element_type=acc_dt)
              for a, T in zip(arrs, Tj)]
        U = dout_bar.astype(acc_dt) @ jnp.asarray(P).T
        Ug = U if gs is None else U * gs.astype(acc_dt)
        grads = []
        for i in range(n):
            dV = Ug
            for j in range(n):
                if j != i:
                    dV = dV * Vs[j]
            grads.append((dV @ Tj[i].T.astype(acc_dt)).astype(arrs[i].dtype))
        return tuple(grads), Vs, U

    if gated:

        @jax.custom_vjp
        def run(arrs, gs, gb):
            return _call(arrs, (gs, gb))

        def fwd(arrs, gs, gb):
            return _call(arrs, (gs, gb)), (arrs, gs, gb)

        def bwd(res, dout_bar):
            arrs, gs, gb = res
            grads, Vs, U = _bwd_core(arrs, gs, dout_bar)
            V = Vs[0]
            for Vj in Vs[1:]:
                V = V * Vj
            dgs = jnp.sum(U * V, axis=-1, keepdims=True).astype(gs.dtype)
            dgb = jnp.sum(U, axis=-1, keepdims=True).astype(gb.dtype)
            return grads, dgs, dgb

    else:

        @jax.custom_vjp
        def run(arrs):
            return _call(arrs)

        def fwd(arrs):
            return _call(arrs), arrs

        def bwd(arrs, dout_bar):
            grads, _, _ = _bwd_core(arrs, None, dout_bar)
            return (grads,)

    run.defvjp(fwd, bwd)
    return run, dout


def _chain_prepare(xs, Ls, entries):
    """Broadcast/flatten chain operands to row layout [B, d_i].

    'grid' entries arrive as complex half grids [..., 2L+1, L+1] and stack
    into real vectors [..., 2*(2L+1)*(L+1)] = [Re F; Im F].
    """
    flat = []
    for x, L, e in zip(xs, Ls, entries):
        if e == "grid":
            lead = x.shape[:-2]
            F = x.reshape(*lead, -1)
            x = jnp.concatenate([F.real, F.imag], axis=-1)
        flat.append(x)
    lead = jnp.broadcast_shapes(*[a.shape[:-1] for a in flat])
    B = int(np.prod(lead)) if lead else 1
    flat = [jnp.broadcast_to(a, lead + a.shape[-1:]).reshape(B, a.shape[-1])
            for a in flat]
    return flat, lead, B


def _chain_finish(out, lead, Lout: int, out_entry: str):
    if out_entry == "grid":
        half = out.shape[-1] // 2
        F = jax.lax.complex(out[..., :half], out[..., half:])
        return F.reshape(*lead, 2 * Lout + 1, Lout + 1)
    return out.reshape(*lead, out.shape[-1])


def gaunt_chain_fused_pallas(
    xs,
    Ls,
    Lout: int | None = None,
    *,
    entries: tuple | None = None,
    out_entry: str = "sh",
    block_b: int | None = None,
    block_g: int | None = None,
    interpret: bool | None = None,
    dtype: str | None = None,
    gate=None,
):
    """n-way fused chain Gaunt product — ONE `pallas_call`.

    xs      : per-operand arrays; entry 'sh' is packed SH [..., (L_i+1)^2],
              entry 'grid' is the Fourier-resident half grid
              [..., 2L_i+1, L_i+1] (complex — it enters the kernel as its
              real-stacked form and skips the SH sampling matmul).
    Lout    : exit degree (default sum(Ls)); out_entry 'sh' returns packed SH
              [..., (Lout+1)^2], 'grid' the resident half product grid.
    block_b : row-block size; block_g: sample-axis block (multiple of 128)
              — large product grids accumulate across grid blocks in VMEM.
              Defaults double under bf16 storage (half the bytes per block).
    dtype   : storage dtype ('float32'|'bfloat16'|'float64'); None infers
              from the operands (bf16 only when ALL operands are bf16).
              Operands are cast to it once at entry; accumulation is always
              >= f32 and the output comes back at the accumulation dtype.
    gate    : optional (gs, gb) pair of per-row scalars (each broadcastable
              to the operands' leading shape): the fused pointwise stage
              applies ``v <- v*gs + gb`` to the VMEM-resident product values
              before projection — `gate_apply` in its affine form, for free
              inside the same single `pallas_call` (DESIGN.md §6.5).

    float64 storage exists only under x64 and is interpret-only (TPUs have
    no f64).  Differentiable via the collocation VJP (extended with
    dgs/dgb when gated).
    """
    Ls = tuple(int(L) for L in Ls)
    Lout = sum(Ls) if Lout is None else int(Lout)
    entries = ("sh",) * len(Ls) if entries is None else tuple(entries)
    if len(xs) != len(Ls) or len(entries) != len(Ls):
        raise ValueError(f"chain kernel got {len(xs)} operands / "
                         f"{len(entries)} entries for degrees {Ls}")
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    sdt = _storage_dtype(xs, dtype)
    if sdt == "float64":
        interpret = True  # f64 is interpret-only: no accelerator lowers it
    bf16 = sdt == "bfloat16"
    if block_b is None:
        block_b = 512 if bf16 else 256
    if block_g is None:
        block_g = 1024 if bf16 else 512
    flat, lead, B = _chain_prepare(xs, Ls, entries)
    # clamp the row block to the batch, quantized to powers of two: tiny
    # batches avoid 50x zero-row padding, while the quantization bounds the
    # per-config `_chain_runner` cache at ~6 entries (8..block_b) even for
    # callers with ragged eager batch sizes.  bf16 sublane tiles are 16 rows
    # (f32: 8), so the bf16 floor is one full tile.
    eff_b = 16 if bf16 else 8
    while eff_b < min(block_b, B):
        eff_b *= 2
    block_b = min(block_b, eff_b)
    block_g = max(128, (block_g // 128) * 128)
    run, dout = _chain_runner(Ls, Lout, entries, out_entry, block_b, block_g,
                              bool(interpret), sdt, gate is not None)
    _STATS["chain_pallas_calls"] += 1
    Bp = -(-B // block_b) * block_b
    st_dt = jnp.dtype(sdt)
    flat = [jnp.zeros((Bp, a.shape[-1]), st_dt).at[:B].set(a.astype(st_dt))
            for a in flat]
    if gate is not None:
        acc_dt = jnp.float64 if sdt == "float64" else jnp.float32
        pads = [jnp.zeros((Bp, 1), acc_dt).at[:B].set(
                    jnp.broadcast_to(g, lead).reshape(B, 1).astype(acc_dt))
                for g in gate]
        out = run(tuple(flat), *pads)[:B]
    else:
        out = run(tuple(flat))[:B]
    return _chain_finish(out, lead, sum(Ls), out_entry)


def gaunt_chain_fused_xla(
    xs,
    Ls,
    Lout: int | None = None,
    *,
    entries: tuple | None = None,
    out_entry: str = "sh",
    dtype: str | None = None,
    gate=None,
):
    """The chain collocation math as plain jnp (XLA) — the same matrices,
    no Pallas.  Grad/vmap/dtype support come for free; off-TPU this is the
    fast realization of the chain kernel (interpret mode never is).

    Same storage rule as the Pallas runner: operands and T_i at the storage
    dtype, >= f32 accumulation via ``preferred_element_type``, P and the
    output at the accumulation dtype.  ``gate=(gs, gb)`` applies the same
    fused pointwise stage as the Pallas runner (``v <- v*gs + gb`` on the
    product values before projection).
    """
    from repro.core.constants import chain_matrices

    Ls = tuple(int(L) for L in Ls)
    Lout = sum(Ls) if Lout is None else int(Lout)
    entries = ("sh",) * len(Ls) if entries is None else tuple(entries)
    sdt = _storage_dtype(xs, dtype)
    st_dt = jnp.dtype(sdt)
    acc_dt = jnp.float64 if sdt == "float64" else jnp.float32
    acc_np = "float64" if sdt == "float64" else "float32"
    Ts, _ = chain_matrices(Ls, Lout, entries, out_entry, dtype=sdt)
    _, P = chain_matrices(Ls, Lout, entries, out_entry, dtype=acc_np)
    flat, lead, B = _chain_prepare(xs, Ls, entries)
    v = jnp.dot(flat[0].astype(st_dt), jnp.asarray(Ts[0]),
                preferred_element_type=acc_dt)
    for a, T in zip(flat[1:], Ts[1:]):
        v = v * jnp.dot(a.astype(st_dt), jnp.asarray(T),
                        preferred_element_type=acc_dt)
    if gate is not None:
        gs, gb = (jnp.broadcast_to(g, lead).reshape(B, 1).astype(acc_dt)
                  for g in gate)
        v = v * gs + gb
    out = v @ jnp.asarray(P)
    return _chain_finish(out, lead, sum(Ls), out_entry)


def gaunt_fused_pallas(
    x1,
    x2,
    L1: int,
    L2: int,
    Lout: int | None = None,
    block_b: int | None = None,
    interpret: bool | None = None,
    dtype: str | None = None,
):
    """Fused Gaunt TP.  x1 [..., d1], x2 [..., d2] -> [..., dout].

    Leading dims are flattened into a row-block grid; T1/T2/P stay fully
    VMEM-resident per block (they are tiny: L=8 -> T 81x1156 f32 = 375 KiB).

    ``dtype`` is the storage dtype (operands + T1/T2; None infers from the
    inputs); the MXU accumulates at f32 and P/the output stay f32.  The
    default row block doubles under bf16 storage.
    """
    from repro.core.constants import chain_matrices
    from repro.core.irreps import num_coeffs

    Lout = L1 + L2 if Lout is None else Lout
    sdt = _storage_dtype((x1, x2), dtype)
    if sdt == "float64":
        sdt = "float32"  # the pairwise kernel is f32/bf16-storage only
    st_dt = jnp.dtype(sdt)
    if block_b is None:
        block_b = 512 if sdt == "bfloat16" else 256
    (T1, T2), _ = chain_matrices((L1, L2), Lout, ("sh", "sh"), "sh", dtype=sdt)
    _, P = chain_matrices((L1, L2), Lout, ("sh", "sh"), "sh", dtype="float32")
    T1, T2, P = (jnp.asarray(a) for a in (T1, T2, P))
    batch = x1.shape[:-1]
    B = int(np.prod(batch)) if batch else 1
    d1, d2, dout = num_coeffs(L1), num_coeffs(L2), num_coeffs(Lout)
    Bp = ((B + block_b - 1) // block_b) * block_b
    a1 = jnp.zeros((Bp, d1), st_dt).at[:B].set(x1.reshape(B, d1).astype(st_dt))
    a2 = jnp.zeros((Bp, d2), st_dt).at[:B].set(x2.reshape(B, d2).astype(st_dt))
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    G = T1.shape[1]
    _STATS["pairwise_pallas_calls"] += 1
    out = pl.pallas_call(
        _kernel,
        grid=(Bp // block_b,),
        in_specs=[
            pl.BlockSpec((block_b, d1), lambda i: (i, 0)),
            pl.BlockSpec((block_b, d2), lambda i: (i, 0)),
            pl.BlockSpec((d1, G), lambda i: (0, 0)),
            pl.BlockSpec((d2, G), lambda i: (0, 0)),
            pl.BlockSpec((G, dout), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, dout), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Bp, dout), jnp.float32),
        interpret=interpret,
    )(a1, a2, T1, T2, P)
    return out[:B].reshape(*batch, dout)
