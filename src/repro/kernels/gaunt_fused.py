"""Fused Gaunt tensor product Pallas TPU kernel — sample * multiply * project.

TPU adaptation of the paper's FFT pipeline (see DESIGN.md §3): instead of
(complex s2f -> FFT conv -> complex f2s) we use the mathematically identical
*collocation* form on the torus grid:

    out = ((x1 @ T1) .* (x2 @ T2)) @ P

with  T_i[j, g]   = S_j(theta_g, psi_g)        (real SH sampled on the grid)
      P[g, k]     = Re((1/G) sum_{u,v} e^{-i(u t_g + v p_g)} z^{k}_{u,v})

Exactness: the product of two bandlimited spherical functions is bandlimited
at L1+L2 on the torus double cover; an N x N grid with N >= 2(L1+L2)+1
samples it alias-free, so the discrete projection equals the paper's
convolution-theorem result to machine precision (tested).

Why this shape for TPU: three dense real matmuls hit the MXU back-to-back
with one VMEM-resident elementwise multiply between them; the FFT path
(VPU butterflies on tiny grids) and gather-based sparse conversions are far
from MXU peak at practical L.  All operands are zero-padded to lane/tile
boundaries (8 x 128) outside the kernel.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

__all__ = ["gaunt_fused_matrices", "gaunt_fused_pallas"]


def gaunt_fused_matrices(L1: int, L2: int, Lout: int, pad_lanes: bool = True):
    """Numpy (T1 [d1,G], T2 [d2,G], P [G,dout]) — exact.

    Back-compat alias: the builder (and its cache) lives in the engine's
    constant-cache module, `repro.core.constants.fused_matrices`.
    """
    from repro.core.constants import fused_matrices

    return fused_matrices(L1, L2, Lout, pad_lanes)


def _kernel(x1_ref, x2_ref, t1_ref, t2_ref, p_ref, o_ref):
    v1 = jnp.dot(x1_ref[...], t1_ref[...], preferred_element_type=jnp.float32)
    v2 = jnp.dot(x2_ref[...], t2_ref[...], preferred_element_type=jnp.float32)
    o_ref[...] = jnp.dot(v1 * v2, p_ref[...], preferred_element_type=jnp.float32)


def gaunt_fused_pallas(
    x1,
    x2,
    L1: int,
    L2: int,
    Lout: int | None = None,
    block_b: int = 256,
    interpret: bool | None = None,
):
    """Fused Gaunt TP.  x1 [..., d1], x2 [..., d2] -> [..., dout].

    Leading dims are flattened into a row-block grid; T1/T2/P stay fully
    VMEM-resident per block (they are tiny: L=8 -> T 81x1156 f32 = 375 KiB).
    """
    from repro.core.constants import fused_matrices
    from repro.core.irreps import num_coeffs

    Lout = L1 + L2 if Lout is None else Lout
    T1, T2, P = (jnp.asarray(a) for a in fused_matrices(L1, L2, Lout))
    batch = x1.shape[:-1]
    B = int(np.prod(batch)) if batch else 1
    d1, d2, dout = num_coeffs(L1), num_coeffs(L2), num_coeffs(Lout)
    Bp = ((B + block_b - 1) // block_b) * block_b
    a1 = jnp.zeros((Bp, d1), x1.dtype).at[:B].set(x1.reshape(B, d1))
    a2 = jnp.zeros((Bp, d2), x2.dtype).at[:B].set(x2.reshape(B, d2))
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    G = T1.shape[1]
    out = pl.pallas_call(
        _kernel,
        grid=(Bp // block_b,),
        in_specs=[
            pl.BlockSpec((block_b, d1), lambda i: (i, 0)),
            pl.BlockSpec((block_b, d2), lambda i: (i, 0)),
            pl.BlockSpec((d1, G), lambda i: (0, 0)),
            pl.BlockSpec((d2, G), lambda i: (0, 0)),
            pl.BlockSpec((G, dout), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, dout), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Bp, dout), jnp.float32),
        interpret=interpret,
    )(a1, a2, T1, T2, P)
    return out[:B].reshape(*batch, dout)
