"""Pure-jnp oracles for every Pallas kernel in this package."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.cg import gaunt_einsum_reference


def gaunt_fused_ref(x1, x2, T1, T2, P):
    """Sample-multiply-project Gaunt TP, unfused.

    x1 [B, d1], x2 [B, d2]; T1 [d1, G], T2 [d2, G] torus sample matrices;
    P [G, dout] projection.  out[B, dout] = ((x1 T1) * (x2 T2)) P.
    """
    v1 = x1 @ T1
    v2 = x2 @ T2
    return (v1 * v2) @ P


def gaunt_oracle(x1, x2, L1, L2, Lout):
    """Ground truth: dense einsum with the exact real Gaunt tensor."""
    return gaunt_einsum_reference(x1, x2, L1, L2, Lout)


def wkv6_ref(r, k, v, w, u):
    """Naive RWKV6 recurrence (fp32), the oracle for the chunked kernel.

    Shapes: r,k,w [B, T, H, K]; v [B, T, H, V]; u [H, K].
    S_t = diag(w_t) S_{t-1} + k_t v_t^T ;  o_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T)
    """
    B, T, H, K = r.shape
    V = v.shape[-1]
    S = jnp.zeros((B, H, K, V), dtype=jnp.float32)
    outs = []
    for t in range(T):
        kt, vt, rt, wt = k[:, t], v[:, t], r[:, t], w[:, t]
        kv = kt[..., :, None] * vt[..., None, :]  # [B,H,K,V]
        o = jnp.einsum("bhk,bhkv->bhv", rt, S + u[None, :, :, None] * kv)
        outs.append(o)
        S = wt[..., :, None] * S + kv
    return jnp.stack(outs, axis=1)  # [B, T, H, V]


def mamba2_ssd_ref(x, dt, A, B, C, D):
    """Naive Mamba-2 SSD recurrence oracle.

    x [Bt, T, H, P] (heads x headdim), dt [Bt, T, H] (post-softplus),
    A [H] (negative), B,C [Bt, T, G, N] (groups), D [H].
    h_t = exp(A dt_t) h_{t-1} + dt_t * B_t x_t^T ; y_t = C_t h_t + D x_t
    (single group broadcast over heads).
    """
    Bt, T, H, Pd = x.shape
    G, N = B.shape[2], B.shape[3]
    heads_per_group = H // G
    h = jnp.zeros((Bt, H, Pd, N), dtype=jnp.float32)
    ys = []
    for t in range(T):
        dts = dt[:, t][..., None, None]  # [Bt,H,1,1]
        decay = jnp.exp(A[None, :, None, None] * dts)
        Bg = jnp.repeat(B[:, t], heads_per_group, axis=1)  # [Bt,H,N]
        Cg = jnp.repeat(C[:, t], heads_per_group, axis=1)
        xt = x[:, t]  # [Bt,H,P]
        h = decay * h + dts * xt[..., :, None] * Bg[..., None, :]
        y = jnp.einsum("bhpn,bhn->bhp", h, Cg) + D[None, :, None] * xt
        ys.append(y)
    return jnp.stack(ys, axis=1)
