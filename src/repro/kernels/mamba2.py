"""Mamba-2 SSD (state-space duality) — chunked scan, TPU-friendly.

Per head (headdim P, state N, scalar A < 0):
    h_t = exp(A dt_t) h_{t-1} + dt_t x_t B_t^T        h in R^{P x N}
    y_t = h_t C_t + D x_t

Chunked (la = cumsum(A dt) within chunk, all exponents <= 0):
    intra:  M[i,j] = exp(la_i - la_j) dt_j (C_i . B_j)   (j <= i);  Y = M X
    inter:  y_i += exp(la_i) (h_0 C_i)
    state:  h' = exp(la_C) h_0 + sum_j exp(la_C - la_j) dt_j x_j B_j^T

`mamba2_ssd_chunked` is the pure-jnp scan; `mamba2_ssd_pallas` the Pallas TPU
kernel (grid (B*H, T/C), VMEM-resident h across the sequential chunk axis).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax < 0.5 exposes the TPU compiler params as TPUCompilerParams
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

__all__ = ["mamba2_ssd_chunked", "mamba2_ssd_pallas"]


def mamba2_ssd_chunked(x, dt, A, B, C, D, chunk: int = 64, return_state: bool = False):
    """x [Bt,T,H,P]; dt [Bt,T,H]; A [H]; B,C [Bt,T,G,N]; D [H] -> y like x.

    With return_state, also returns final h [Bt,H,P,N]."""
    Bt, T, H, P = x.shape
    G, N = B.shape[2], B.shape[3]
    hpg = H // G
    Ck = min(chunk, T)
    assert T % Ck == 0
    n = T // Ck

    # broadcast groups to heads, fold (Bt, H) -> R rows
    Bh = jnp.repeat(B, hpg, axis=2)
    Ch = jnp.repeat(C, hpg, axis=2)

    def to_r(a, d):
        return (
            a.astype(jnp.float32)
            .transpose(0, 2, 1, 3)
            .reshape(Bt * H, n, Ck, d)
            .transpose(1, 0, 2, 3)
        )

    xs = to_r(x, P)
    Bs = to_r(Bh, N)
    Cs = to_r(Ch, N)
    dts = (
        dt.astype(jnp.float32).transpose(0, 2, 1).reshape(Bt * H, n, Ck).transpose(1, 0, 2)
    )
    A_r = jnp.tile(A.astype(jnp.float32), (Bt,))  # [Bt*H]

    def step(h, xs_):
        xc, Bc, Cc, dtc = xs_  # [R,C,P], [R,C,N], [R,C,N], [R,C]
        la = jnp.cumsum(A_r[:, None] * dtc, axis=1)  # [R,C] (<= 0, decreasing)
        ii = jnp.arange(Ck)[:, None]
        jj = jnp.arange(Ck)[None, :]
        diff = la[:, :, None] - la[:, None, :]  # [R,i,j]
        Mexp = jnp.exp(jnp.where((ii >= jj)[None], diff, -jnp.inf))
        M = Mexp * jnp.einsum("rin,rjn->rij", Cc, Bc) * dtc[:, None, :]
        y = jnp.einsum("rij,rjp->rip", M, xc)
        y = y + jnp.exp(la)[..., None] * jnp.einsum("rpn,rin->rip", h, Cc)
        w = jnp.exp(la[:, -1:] - la)[..., None] * dtc[..., None]  # [R,C,1->N]
        h = jnp.exp(la[:, -1])[:, None, None] * h + jnp.einsum(
            "rjp,rjn->rpn", xc * w[..., :1], Bc
        )
        return h, y

    h0 = jnp.zeros((Bt * H, P, N), dtype=jnp.float32)
    # checkpoint the chunk body (see wkv6.py — §Perf H9)
    h_fin, ys = jax.lax.scan(jax.checkpoint(step, prevent_cse=False),
                             h0, (xs, Bs, Cs, dts))
    y = ys.transpose(1, 0, 2, 3).reshape(Bt, H, T, P).transpose(0, 2, 1, 3)
    y = y + D.astype(jnp.float32)[None, None, :, None] * x.astype(jnp.float32)
    if return_state:
        return y, h_fin.reshape(Bt, H, P, N)
    return y


def _ssd_kernel(x_ref, b_ref, c_ref, dt_ref, a_ref, y_ref, h_ref):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[0]  # [C, P]
    Bc = b_ref[0]  # [C, N]
    Cc = c_ref[0]
    dt = dt_ref[0]  # [1, C] row
    A = a_ref[0]  # [1, 1]
    h = h_ref[...]  # [P, N]
    Ck = x.shape[0]
    la = jnp.cumsum(A[0, 0] * dt[0], axis=0)  # [C]
    ii = jax.lax.broadcasted_iota(jnp.int32, (Ck, Ck), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (Ck, Ck), 1)
    diff = la[:, None] - la[None, :]
    Mexp = jnp.exp(jnp.where(ii >= jj, diff, -jnp.inf))
    M = Mexp * jnp.dot(Cc, Bc.T, preferred_element_type=jnp.float32) * dt[0][None, :]
    y = jnp.dot(M, x, preferred_element_type=jnp.float32)
    y = y + jnp.exp(la)[:, None] * jnp.dot(Cc, h.T, preferred_element_type=jnp.float32)
    y_ref[0] = y
    w = (jnp.exp(la[-1] - la) * dt[0])[:, None]
    h_ref[...] = jnp.exp(la[-1]) * h + jnp.dot(
        (x * w).T, Bc, preferred_element_type=jnp.float32
    )


def mamba2_ssd_pallas(x, dt, A, B, C, D, chunk: int = 64, interpret: bool | None = None):
    Bt, T, H, P = x.shape
    G, N = B.shape[2], B.shape[3]
    hpg = H // G
    Ck = min(chunk, T)
    assert T % Ck == 0
    n = T // Ck
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    R = Bt * H
    xs = x.astype(jnp.float32).transpose(0, 2, 1, 3).reshape(R, T, P)
    Bs = jnp.repeat(B, hpg, axis=2).astype(jnp.float32).transpose(0, 2, 1, 3).reshape(R, T, N)
    Cs = jnp.repeat(C, hpg, axis=2).astype(jnp.float32).transpose(0, 2, 1, 3).reshape(R, T, N)
    dts = dt.astype(jnp.float32).transpose(0, 2, 1).reshape(R, 1, T)
    A_r = jnp.tile(A.astype(jnp.float32), (Bt,)).reshape(R, 1, 1)
    y = pl.pallas_call(
        _ssd_kernel,
        grid=(R, n),
        in_specs=[
            pl.BlockSpec((1, Ck, P), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, Ck, N), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, Ck, N), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, 1, Ck), lambda b, c: (b, 0, c)),
            pl.BlockSpec((1, 1, 1), lambda b, c: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, Ck, P), lambda b, c: (b, c, 0)),
        out_shape=jax.ShapeDtypeStruct((R, T, P), jnp.float32),
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary")
        ),
        interpret=interpret,
    )(xs, Bs, Cs, dts, A_r)
    y = y.reshape(Bt, H, T, P).transpose(0, 2, 1, 3)
    return y + D.astype(jnp.float32)[None, None, :, None] * x.astype(jnp.float32)
