"""Pallas TPU kernels for the paper's compute hot-spots (+ arch SSM scans).

Each kernel ships three layers: <name>.py (pl.pallas_call + BlockSpec VMEM
tiling), ops.py (jit'd dispatch wrappers), ref.py (pure-jnp oracles).  On CPU
the kernels run in interpret mode (tests); model code defaults to the jnp
chunked forms which are math-identical.
"""
from .ops import (  # noqa: F401
    gaunt_tp_channel_mix,
    gaunt_tp_fused,
    gaunt_tp_fused_xla,
    mamba2_ssd,
    wkv6,
)
