"""RWKV6 (Finch) WKV recurrence — chunked, numerically stable, TPU-friendly.

Recurrence (per batch, head; K/V head dims):
    S_t = diag(w_t) S_{t-1} + k_t v_t^T
    o_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T)

Chunked form (chunk C, lw = cumsum log w within chunk, lw_0 = 0):
    intra:  A[i,j] = sum_k r[i,k] k[j,k] exp(lw[i-1,k] - lw[j,k])   (j < i)
            + diag(sum_k r[i,k] u[k] k[i,k])
    inter:  o += (r ⊙ exp(lw_prev)) @ S_chunk_start
    state:  S' = diag(exp(lw_C)) S + (k ⊙ exp(lw_C - lw))^T V

Every exponent is masked to <= 0 before exp — no overflow for any data-
dependent decay (tested against the naive recurrence oracle in fp32).

`wkv6_chunked` is the pure-jnp scan (used inside scanned model layers);
`wkv6_pallas` is the Pallas TPU kernel: grid (B*H, T/C) with the sequential
chunk axis carrying S in a VMEM scratch accumulator.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax < 0.5 exposes the TPU compiler params as TPUCompilerParams
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

__all__ = ["wkv6_chunked", "wkv6_pallas"]


def wkv6_chunked(r, k, v, w, u, chunk: int = 64, return_state: bool = False):
    """r,k,w [B,T,H,K]; v [B,T,H,V]; u [H,K] -> o [B,T,H,V] (fp32 inside).

    With return_state, also returns the final S [B,H,K,V] (prefill -> decode
    handoff)."""
    B, T, H, K = r.shape
    V = v.shape[-1]
    C = min(chunk, T)
    assert T % C == 0, f"T={T} not divisible by chunk={C}"
    n = T // C

    def to_bh(x, d):
        # [B,T,H,d] -> [n, B*H, C, d]
        x = x.astype(jnp.float32).transpose(0, 2, 1, 3).reshape(B * H, T, d)
        return x.reshape(B * H, n, C, d).transpose(1, 0, 2, 3)

    rs, ks, ws = to_bh(r, K), to_bh(k, K), to_bh(w, K)
    vs = to_bh(v, V)
    u_full = jnp.tile(u.astype(jnp.float32), (B, 1)).reshape(B * H, K)

    def step(S, xs):
        rc, kc, vc, wc = xs
        # u is per-head; fold into einsum via per-row u
        C_ = rc.shape[1]
        logw = jnp.log(jnp.clip(wc, 1e-12, 1.0))
        lw = jnp.cumsum(logw, axis=1)
        lw_prev = jnp.pad(lw[:, :-1], ((0, 0), (1, 0), (0, 0)))
        diff = lw_prev[:, :, None, :] - lw[:, None, :, :]
        mask = (jnp.arange(C_)[:, None] > jnp.arange(C_)[None, :])[None, :, :, None]
        E = jnp.exp(jnp.where(mask, diff, -jnp.inf))
        A = jnp.einsum("bik,bjk,bijk->bij", rc, kc, E)
        Adiag = jnp.einsum("bik,bk,bik->bi", rc, u_full, kc)
        o = jnp.einsum("bij,bjv->biv", A, vc) + Adiag[..., None] * vc
        o = o + jnp.einsum("bik,bkv->biv", rc * jnp.exp(lw_prev), S)
        k_t = kc * jnp.exp(lw[:, -1:, :] - lw)
        S = jnp.exp(lw[:, -1, :])[..., None] * S + jnp.einsum("bik,biv->bkv", k_t, vc)
        return S, o

    S0 = jnp.zeros((B * H, K, V), dtype=jnp.float32)
    # checkpoint the chunk body: backward recomputes the O(C^2 K) intra-chunk
    # tensors instead of saving them per iteration (§Perf H9)
    S_fin, os = jax.lax.scan(jax.checkpoint(step, prevent_cse=False),
                             S0, (rs, ks, vs, ws))
    # os [n, BH, C, V] -> [B, T, H, V]
    o = os.transpose(1, 0, 2, 3).reshape(B, H, T, V).transpose(0, 2, 1, 3)
    if return_state:
        return o, S_fin.reshape(B, H, K, V)
    return o


# ----------------------------------------------------------------------
# Pallas kernel
# ----------------------------------------------------------------------


def _wkv6_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, o_ref, S_ref):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        S_ref[...] = jnp.zeros_like(S_ref)

    r = r_ref[0]  # [C, K]
    k = k_ref[0]
    v = v_ref[0]
    w = w_ref[0]
    u = u_ref[0]  # [1, K] (head-broadcast row)
    C = r.shape[0]
    S = S_ref[...]
    logw = jnp.log(jnp.clip(w, 1e-12, 1.0))
    lw = jnp.cumsum(logw, axis=0)
    lw_prev = jnp.concatenate([jnp.zeros_like(lw[:1]), lw[:-1]], axis=0)
    diff = lw_prev[:, None, :] - lw[None, :, :]  # [i, j, K]
    ii = jax.lax.broadcasted_iota(jnp.int32, (C, C), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (C, C), 1)
    E = jnp.exp(jnp.where((ii > jj)[..., None], diff, -jnp.inf))
    A = jnp.einsum("ik,jk,ijk->ij", r, k, E)
    Adiag = jnp.sum(r * u * k, axis=-1)  # [C]
    o = jnp.dot(A, v, preferred_element_type=jnp.float32) + Adiag[:, None] * v
    o = o + jnp.dot(r * jnp.exp(lw_prev), S, preferred_element_type=jnp.float32)
    o_ref[0] = o
    k_t = k * jnp.exp(lw[-1:, :] - lw)
    S_ref[...] = jnp.exp(lw[-1])[:, None] * S + jnp.dot(
        k_t.T, v, preferred_element_type=jnp.float32
    )


def wkv6_pallas(r, k, v, w, u, chunk: int = 64, interpret: bool | None = None):
    """Pallas WKV6: grid (B*H, T/C); S carried in VMEM scratch across chunks."""
    B, T, H, K = r.shape
    V = v.shape[-1]
    C = min(chunk, T)
    assert T % C == 0
    n = T // C
    if interpret is None:
        interpret = jax.default_backend() != "tpu"

    def to_bh(x, d):
        return x.astype(jnp.float32).transpose(0, 2, 1, 3).reshape(B * H, T, d)

    rs, ks, ws, vs = to_bh(r, K), to_bh(k, K), to_bh(w, K), to_bh(v, V)
    u_rows = jnp.tile(u.astype(jnp.float32), (B, 1)).reshape(B * H, 1, K)

    out = pl.pallas_call(
        _wkv6_kernel,
        grid=(B * H, n),
        in_specs=[
            pl.BlockSpec((1, C, K), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, C, K), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, C, V), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, C, K), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, 1, K), lambda b, c: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, C, V), lambda b, c: (b, c, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, T, V), jnp.float32),
        scratch_shapes=[pltpu.VMEM((K, V), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary")
        ),
        interpret=interpret,
    )(rs, ks, vs, ws, u_rows)
    return out.reshape(B, H, T, V).transpose(0, 2, 1, 3)
