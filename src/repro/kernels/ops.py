"""jit'd public wrappers for the Pallas kernels (TPU) with automatic
interpret-mode execution on CPU (correctness-identical, used by tests).

The Gaunt wrappers are thin: they resolve a plan on the unified engine
(`repro.core.engine`) pinned to the fused backends."""
from __future__ import annotations

import functools

import jax

from repro.core import engine as _engine

__all__ = ["gaunt_tp_fused", "gaunt_tp_fused_xla", "gaunt_tp_channel_mix",
           "wkv6", "mamba2_ssd"]


@functools.partial(jax.jit, static_argnums=(2, 3, 4, 5))
def gaunt_tp_fused(x1, x2, L1: int, L2: int, Lout: int | None = None, block_b: int = 256):
    """Fused sample-multiply-project Gaunt tensor product (Pallas kernel)."""
    p = _engine.plan(L1, L2, Lout, kind="pairwise", backend="fused_pallas",
                     options={"block_b": block_b}, requires_grad=False)
    return p.apply(x1, x2)


@functools.partial(jax.jit, static_argnums=(2, 3, 4))
def gaunt_tp_fused_xla(x1, x2, L1: int, L2: int, Lout: int | None = None):
    """Same math lowered through plain XLA (baseline for the kernel & the
    path used inside scanned model code where pallas_call is not needed)."""
    p = _engine.plan(L1, L2, Lout, kind="pairwise", backend="fused_xla")
    return p.apply(x1, x2)


def wkv6(r, k, v, w, u, chunk: int = 64):
    """RWKV6 linear-attention with data-dependent decay (chunked kernel)."""
    from .wkv6 import wkv6_chunked

    return wkv6_chunked(r, k, v, w, u, chunk=chunk)


def mamba2_ssd(x, dt, A, B, C, D, chunk: int = 64):
    """Mamba-2 SSD (chunked scan)."""
    from .mamba2 import mamba2_ssd_chunked

    return mamba2_ssd_chunked(x, dt, A, B, C, D, chunk=chunk)


@functools.partial(jax.jit, static_argnums=(3, 4, 5))
def gaunt_tp_channel_mix(x1, x2, w_mix, L1: int, L2: int, Lout: int | None = None):
    """Channel-MIXING Gaunt TP (paper §3.3 discussion, the O(C^2) variant):

        y_e = sum_{c1,c2} w[c1,c2,e] (x1_{c1} (x)_Gaunt x2_{c2})

    Beyond-paper realization: in the fused sample domain the product of
    spherical functions is pointwise, so the channel mixing *commutes with
    the basis change* and becomes one einsum over sample values — O(C^2 G)
    instead of C^2 separate tensor products:

        y = einsum(V1[c1,g], V2[c2,g], w[c1,c2,e]) @ P,  V_i = x_i @ T_i.

    x1 [..., C1, d1], x2 [..., C2, d2], w_mix [C1, C2, E] -> [..., E, dout].
    """
    p = _engine.plan(L1, L2, Lout, kind="channel_mix", backend="fused_xla")
    return p.apply(x1, x2, w_mix)
