"""Arch config: qwen2-0.5b (see package __init__ for the registry)."""
from repro.config import ModelConfig, register

qwen2_0p5b = register(ModelConfig(
    name="qwen2-0.5b", family="dense",
    n_layers=24, d_model=896, n_heads=14, n_kv_heads=2, d_ff=4864,
    vocab=151936, qkv_bias=True, tie_embeddings=True, act="swiglu",
    norm="rmsnorm", rope_theta=1000000.0,
))  # [arXiv:2407.10671]
