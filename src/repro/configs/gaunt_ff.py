"""The paper's own model configs: Gaunt-accelerated equivariant networks."""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class EquivariantConfig:
    name: str
    kind: str  # mace | segnn | equiformer_selfmix
    L: int = 2           # max feature degree
    L_edge: int = 2      # SH filter degree
    channels: int = 64
    n_layers: int = 2
    n_species: int = 8
    nu: int = 3          # many-body order (MACE)
    cutoff: float = 5.0
    n_radial: int = 8
    tp_impl: str = "gaunt"  # gaunt | cg | gaunt_fused
    conv_impl: str = "escn"  # escn | general
    hidden: int = 128
    # batched-execution knob (engine.plan_batch, DESIGN.md §5); donation is
    # NOT a config knob — model loops reuse operand buffers across layers,
    # so donating them is only safe for callers that own buffer lifetimes
    shard_data: bool = False       # shard rows over the activation mesh's data axes
    # basis-residency knob (DESIGN.md §6): keep layer-constant operands (the
    # edge SH filter / eSCN Wigner blocks) Fourier-resident across the layer
    # stack and run chained products through engine.plan_chain.  Composes
    # with shard_data (resident grids shard like SH rows).  Off only for A/B
    # debugging — the resident path is numerically identical up to dtype
    # roundoff.
    fourier_resident: bool = True
    # chain-backend policy (DESIGN.md §6.4): 'heuristic' keeps the resident
    # spectral tree; 'measure' folds the model's chained products into the
    # engine's measured autotuner, which may collapse a whole chain into the
    # n-way collocation kernel (one dispatch, zero conversions).  Measurement
    # only runs outside jit: a forward traced before any eager call stays on
    # 'tree' for its chain keys — run one eager forward (or serve warmup(),
    # which seeds the keys) before jitting to engage the measured picks.
    chain_tune: str = "heuristic"
    # storage precision for the Gaunt products (DESIGN.md §3.6): the SH
    # operands/constants of every engine plan the model builds are stored at
    # this dtype; accumulation and the resident complex grids stay >= f32.
    # 'float32' (default) | 'bfloat16' | 'auto' ('auto' + chain_tune=
    # 'measure' lets the engine time both precisions per workload and keep
    # bf16 only where it wins).  Activations between plans (mixes, gates)
    # follow the plan output dtype via jnp promotion.
    compute_dtype: str = "float32"
    # persistent autotune cache file (DESIGN.md §4.5): serve warmup() points
    # the engine at this path so measured selections (backends, chain
    # flavors, dtype winners, fused calibration) load from disk instead of
    # re-timing — a warm host boots with zero timing runs.  None (default)
    # falls back to $REPRO_AUTOTUNE_CACHE, else persistence stays off.
    # Pre-populate with `python -m repro.core.autotune_cache --cache <path>`.
    autotune_cache: str | None = None
    # grid-resident equivariant gates (DESIGN.md §6.5): where the layer gate
    # runs.  'off' (default) applies gate_apply on SH coefficients between
    # chain exits; 'on' keeps the gate on the resident grid — MACE fuses the
    # affine gate g*f + beta*Y00 into the selfmix chain (pointwise stage in
    # the collocation kernel; the layer reorders to gate-before-mb_mix, an
    # equally expressive reparameterization), SEGNN evaluates the gate on the
    # S^2 quadrature grid.  'auto' asks the engine's measured gate policy
    # (engine.select_gate, keyed like chain plans) per workload; requires
    # chain_tune='measure', else it resolves to 'off'.
    grid_gate: str = "off"
    # serve-time slot buckets (DESIGN.md §10.2): ((max_atoms, n_slots), ...)
    # size-bucketed pools for EquivariantServeEngine — each bucket compiles
    # its own step at its own padded shape and seeds its own warmup/autotune
    # keys, so small molecules stop padding to the deployment maximum.  None
    # (default) keeps the engine's single fixed-max_atoms bucket; the
    # engine's explicit ``buckets=`` argument overrides this knob.  See
    # serve/pools.py `default_buckets` for the small/medium/large ladder.
    serve_buckets: tuple[tuple[int, int], ...] | None = None


gaunt_mace_ff = EquivariantConfig(
    name="gaunt-mace-ff", kind="mace", L=2, L_edge=3, channels=64, n_layers=2, nu=3
)
gaunt_segnn_nbody = EquivariantConfig(
    name="gaunt-segnn-nbody", kind="segnn", L=1, L_edge=1, channels=32, n_layers=4
)
gaunt_equiformer_selfmix = EquivariantConfig(
    name="gaunt-equiformer-selfmix", kind="equiformer_selfmix", L=4, L_edge=4,
    channels=32, n_layers=2
)
