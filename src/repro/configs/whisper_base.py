"""Arch config: whisper-base (see package __init__ for the registry)."""
from repro.config import ModelConfig, register

whisper_base = register(ModelConfig(
    name="whisper-base", family="encdec",
    n_layers=6, n_enc_layers=6, d_model=512, n_heads=8, n_kv_heads=8,
    d_ff=2048, vocab=51865, act="gelu_mlp", norm="layernorm",
    partial_rotary=0.0, max_source_len=1500, max_seq=32768,
))  # [arXiv:2212.04356] — conv frontend stubbed (frame embeddings provided)
