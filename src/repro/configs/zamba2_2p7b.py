"""Arch config: zamba2-2.7b (see package __init__ for the registry)."""
from repro.config import ModelConfig, register

zamba2_2p7b = register(ModelConfig(
    name="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32, d_ff=10240,
    vocab=32000, ssm_state=64, ssm_headdim=64, ssm_expand=2, ssm_conv=4,
    attn_every=6, act="geglu", norm="rmsnorm",
))  # [arXiv:2411.15242] — Mamba2 backbone + shared attention blocks
