"""Arch config: qwen2-vl-72b (see package __init__ for the registry)."""
from repro.config import ModelConfig, register

qwen2_vl_72b = register(ModelConfig(
    name="qwen2-vl-72b", family="vlm",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=29568,
    vocab=152064, qkv_bias=True, act="swiglu", norm="rmsnorm",
    rope_theta=1000000.0, mrope_sections=(16, 24, 24),
))  # [arXiv:2409.12191] — M-RoPE; vision tower stubbed (patch embeddings)
