"""Arch config: dbrx-132b (see package __init__ for the registry)."""
from repro.config import ModelConfig, register

dbrx_132b = register(ModelConfig(
    name="dbrx-132b", family="moe",
    n_layers=40, d_model=6144, n_heads=48, n_kv_heads=8, d_ff=10752,
    vocab=100352, n_experts=16, top_k=4, d_ff_expert=10752,
    act="swiglu", norm="layernorm", rope_theta=500000.0,
))  # [hf:databricks/dbrx-base]
