"""Assigned architecture configs (exact public-literature sizes) + the paper's
own equivariant model configs.  One file per arch; importing this package
registers everything."""
from repro.configs.dbrx_132b import dbrx_132b
from repro.configs.qwen2_moe_a2p7b import qwen2_moe_a2p7b
from repro.configs.qwen15_32b import qwen15_32b
from repro.configs.qwen2_0p5b import qwen2_0p5b
from repro.configs.stablelm_3b import stablelm_3b
from repro.configs.gemma_2b import gemma_2b
from repro.configs.zamba2_2p7b import zamba2_2p7b
from repro.configs.rwkv6_3b import rwkv6_3b
from repro.configs.whisper_base import whisper_base
from repro.configs.qwen2_vl_72b import qwen2_vl_72b
from repro.configs.gaunt_ff import gaunt_mace_ff, gaunt_segnn_nbody, gaunt_equiformer_selfmix

ALL_LM_ARCHS = [
    "dbrx-132b", "qwen2-moe-a2.7b", "qwen1.5-32b", "qwen2-0.5b",
    "stablelm-3b", "gemma-2b", "zamba2-2.7b", "whisper-base",
    "qwen2-vl-72b", "rwkv6-3b",
]

# archs with sub-quadratic decode (run long_500k); the rest skip it (DESIGN.md)
SUBQUADRATIC = {"zamba2-2.7b", "rwkv6-3b"}
