"""Arch config: qwen1.5-32b (see package __init__ for the registry)."""
from repro.config import ModelConfig, register

qwen15_32b = register(ModelConfig(
    name="qwen1.5-32b", family="dense",
    n_layers=64, d_model=5120, n_heads=40, n_kv_heads=40, d_ff=27392,
    vocab=152064, qkv_bias=True, act="swiglu", norm="rmsnorm",
    rope_theta=1000000.0,
))
