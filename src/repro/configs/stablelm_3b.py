"""Arch config: stablelm-3b (see package __init__ for the registry)."""
from repro.config import ModelConfig, register

stablelm_3b = register(ModelConfig(
    name="stablelm-3b", family="dense",
    n_layers=32, d_model=2560, n_heads=32, n_kv_heads=32, d_ff=6912,
    vocab=50304, act="swiglu", norm="layernorm", partial_rotary=0.25,
))  # [hf:stabilityai/stablelm-*]
