"""Arch config: gemma-2b (see package __init__ for the registry)."""
from repro.config import ModelConfig, register

gemma_2b = register(ModelConfig(
    name="gemma-2b", family="dense",
    n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1, head_dim=256,
    d_ff=16384, vocab=256000, act="geglu", norm="rmsnorm",
    embed_scale=True, rms_one_offset=True, tie_embeddings=True,
))  # [arXiv:2403.08295] — MQA, GeGLU, head_dim=256
