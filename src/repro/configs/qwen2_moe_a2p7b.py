"""Arch config: qwen2-moe-a2.7b (see package __init__ for the registry)."""
from repro.config import ModelConfig, register

qwen2_moe_a2p7b = register(ModelConfig(
    name="qwen2-moe-a2.7b", family="moe",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=16, d_ff=5632,
    vocab=151936, n_experts=60, top_k=4, n_shared_experts=4, d_ff_expert=1408,
    qkv_bias=True, act="swiglu", norm="rmsnorm", rope_theta=1000000.0,
))  # [hf:Qwen/Qwen1.5-MoE-A2.7B]
