"""Arch config: rwkv6-3b (see package __init__ for the registry)."""
from repro.config import ModelConfig, register

rwkv6_3b = register(ModelConfig(
    name="rwkv6-3b", family="ssm",
    n_layers=32, d_model=2560, n_heads=0, d_ff=8960, vocab=65536,
    rwkv_head_k=64, norm="layernorm",
))  # [arXiv:2404.05892] — Finch, attention-free
