"""Optimizers (no optax in this environment — a small, tested, optax-shaped
implementation).  All state is a pytree; master/optimizer state is fp32
regardless of param dtype (mixed-precision convention)."""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

__all__ = ["adamw", "lion", "sgd", "clip_by_global_norm", "apply_updates", "global_norm"]


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable
    update: Callable  # (grads, state, params) -> (updates, state)


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )


def clip_by_global_norm(grads, max_norm: float):
    n = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(n, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), n


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p.astype(jnp.float32) + u).astype(p.dtype),
                        params, updates)


def adamw(lr_fn, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1, decay_mask: Callable | None = None) -> Optimizer:
    """AdamW with decoupled weight decay.  lr_fn: step -> lr."""

    def init(params):
        z = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return {"mu": z, "nu": jax.tree.map(jnp.copy, z), "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        step = state["step"] + 1
        lr = lr_fn(step)
        b1c = 1 - b1**step.astype(jnp.float32)
        b2c = 1 - b2**step.astype(jnp.float32)

        def upd(g, m, v, p):
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            mh = m / b1c
            vh = v / b2c
            u = -lr * (mh / (jnp.sqrt(vh) + eps) + weight_decay * _maybe_decay(p))
            return u, m, v

        def _maybe_decay(p):
            return p.astype(jnp.float32) if p.ndim >= 2 else jnp.zeros_like(p, jnp.float32)

        flat_u, flat_m, flat_v = [], [], []
        gl, ml, vl, pl = (jax.tree.leaves(t) for t in (grads, state["mu"], state["nu"], params))
        for g, m, v, p in zip(gl, ml, vl, pl):
            u, m2, v2 = upd(g, m, v, p)
            flat_u.append(u)
            flat_m.append(m2)
            flat_v.append(v2)
        treedef = jax.tree.structure(grads)
        return (
            jax.tree.unflatten(treedef, flat_u),
            {"mu": jax.tree.unflatten(treedef, flat_m),
             "nu": jax.tree.unflatten(treedef, flat_v),
             "step": step},
        )

    return Optimizer(init, update)


def lion(lr_fn, b1: float = 0.9, b2: float = 0.99, weight_decay: float = 0.1) -> Optimizer:
    def init(params):
        return {"mu": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
                "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        step = state["step"] + 1
        lr = lr_fn(step)

        def upd(g, m, p):
            g = g.astype(jnp.float32)
            u = -lr * (jnp.sign(b1 * m + (1 - b1) * g)
                       + (weight_decay * p.astype(jnp.float32) if p.ndim >= 2 else 0.0))
            m2 = b2 * m + (1 - b2) * g
            return u, m2

        us, ms = zip(*[upd(g, m, p) for g, m, p in zip(
            jax.tree.leaves(grads), jax.tree.leaves(state["mu"]), jax.tree.leaves(params))])
        td = jax.tree.structure(grads)
        return jax.tree.unflatten(td, list(us)), {"mu": jax.tree.unflatten(td, list(ms)),
                                                  "step": step}

    return Optimizer(init, update)


def sgd(lr_fn, momentum: float = 0.9) -> Optimizer:
    def init(params):
        return {"mu": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
                "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        step = state["step"] + 1
        lr = lr_fn(step)
        mu = jax.tree.map(lambda m, g: momentum * m + g.astype(jnp.float32),
                          state["mu"], grads)
        updates = jax.tree.map(lambda m: -lr * m, mu)
        return updates, {"mu": mu, "step": step}

    return Optimizer(init, update)
