"""LR schedules as step -> lr functions (jnp-friendly)."""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["cosine_schedule", "linear_schedule", "constant_schedule"]


def cosine_schedule(peak_lr: float, warmup: int, total: int, floor: float = 0.1):
    def lr(step):
        s = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
        warm = peak_lr * s / max(warmup, 1)
        t = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = peak_lr * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * t)))
        return jnp.where(s < warmup, warm, cos)

    return lr


def linear_schedule(peak_lr: float, warmup: int, total: int):
    def lr(step):
        s = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
        warm = peak_lr * s / max(warmup, 1)
        t = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
        return jnp.where(s < warmup, warm, peak_lr * (1 - t))

    return lr


def constant_schedule(lr_value: float):
    return lambda step: jnp.asarray(lr_value, jnp.float32)
