from .optimizers import adamw, lion, sgd, clip_by_global_norm, apply_updates  # noqa: F401
from .schedules import cosine_schedule, linear_schedule, constant_schedule  # noqa: F401
