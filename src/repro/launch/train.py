"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b \
        --steps 1000 --ckpt /data/run1 [--supervise] [--mesh-data 16 ...]

Wires together: arch config, mesh + shardings, sharded jit train step,
resumable data pipeline, async checkpoints, heartbeat, SIGTERM checkpoint,
straggler monitor, and (with --supervise) restart-from-latest with backoff —
the single-binary entry a cluster scheduler would run on every host.

Recommended XLA flags for real TPU runs (collective/compute overlap — the
latency-hiding scheduler needs these; harmless elsewhere):
    --xla_tpu_enable_data_parallel_all_reduce_opt=true
    --xla_tpu_data_parallel_opt_different_sized_ops=true
    --xla_enable_async_all_gather=true
    --xla_enable_async_collective_permute=true
"""
from __future__ import annotations

import argparse
import subprocess
import sys
import time

import jax
import numpy as np


def run_once(args) -> int:
    from repro.config import TrainConfig, get_config
    from repro.data import LMTokenPipeline
    from repro.distributed.sharding import batch_shardings, param_shardings
    from repro.launch.mesh import make_host_mesh
    from repro.models import build_model
    from repro.train import train_loop

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    mesh = make_host_mesh(data=args.mesh_data, model=args.mesh_model)
    params = model.init(jax.random.PRNGKey(args.seed))
    n = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    print(f"[train] arch={cfg.name} params={n/1e6:.1f}M mesh={mesh.devices.shape}")

    pipe = LMTokenPipeline(vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch,
                           seed=args.seed)
    tcfg = TrainConfig(lr=args.lr, warmup_steps=args.warmup, total_steps=args.steps,
                       checkpoint_every=args.ckpt_every, microbatch=args.microbatch,
                       log_every=args.log_every)

    shardings = None
    if np.prod(mesh.devices.shape) > 1:
        p_sh = param_shardings(jax.eval_shape(lambda: params), mesh)
        from repro.optim import adamw, cosine_schedule

        opt = adamw(cosine_schedule(tcfg.lr, tcfg.warmup_steps, tcfg.total_steps))
        o_sds = jax.eval_shape(opt.init, jax.eval_shape(lambda: params))
        o_sh = {"mu": p_sh, "nu": p_sh,
                "step": jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())}
        b_sh = batch_shardings(
            {"tokens": jax.ShapeDtypeStruct((args.batch, args.seq), np.int32),
             "labels": jax.ShapeDtypeStruct((args.batch, args.seq), np.int32)}, mesh)
        shardings = {"params": p_sh, "opt": o_sh, "batch": b_sh}

    state, hist = train_loop(
        model.loss, params, pipe, tcfg, ckpt_dir=args.ckpt, mesh=mesh,
        shardings=shardings,
        hooks={"log": lambda m: print(f"[train] step {m['step']} loss {m['loss']:.4f}"),
               "heartbeat_path": f"{args.ckpt}/heartbeat.json" if args.ckpt else None}
        if args.ckpt else {"log": lambda m: print(m)},
    )
    print(f"[train] done at step {state.step}; loss {hist[-1]['loss']:.4f}")
    return 0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--warmup", type=int, default=10)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--mesh-data", type=int, default=1)
    ap.add_argument("--mesh-model", type=int, default=1)
    ap.add_argument("--supervise", action="store_true",
                    help="restart from latest checkpoint on failure (backoff)")
    ap.add_argument("--max-restarts", type=int, default=5)
    args = ap.parse_args()

    if not args.supervise:
        sys.exit(run_once(args))

    # supervisor: restart the worker process on crash, resuming from ckpt
    child_args = [a for a in sys.argv[1:] if a not in ("--supervise",)]
    backoff = 2.0
    for attempt in range(args.max_restarts + 1):
        code = subprocess.call([sys.executable, "-m", "repro.launch.train", *child_args])
        if code == 0:
            sys.exit(0)
        print(f"[supervise] worker exited {code}; restart {attempt + 1} "
              f"in {backoff:.0f}s", file=sys.stderr)
        time.sleep(backoff)
        backoff = min(backoff * 2, 60)
    sys.exit(1)


if __name__ == "__main__":
    main()
