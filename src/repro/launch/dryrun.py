import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import: jax locks the device count on first init.
"""Multi-pod dry run: lower + compile every (arch x shape x mesh) cell with
ShapeDtypeStruct inputs on the production meshes, record memory analysis,
cost analysis, and the collective schedule (EXPERIMENTS.md §Dry-run).

    PYTHONPATH=src python -m repro.launch.dryrun --arch dbrx-132b \
        --shape train_4k --mesh single --out results/dryrun.json

Skips (recorded, per DESIGN.md §Arch-applicability):
  * long_500k on pure full-attention archs (needs sub-quadratic decode)
"""
import argparse
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import SHAPES, TrainConfig, get_config
from repro.configs import ALL_LM_ARCHS, SUBQUADRATIC
from repro.distributed.sharding import batch_shardings, cache_shardings, param_shardings
from repro.launch.mesh import make_production_mesh
from repro.models import build_model, input_specs
from repro.train import make_train_step

COLLECTIVE_RE = re.compile(
    r"=\s*\S*\s*(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\("
)
TYPE_RE = re.compile(r"(f8e4m3fn|f8e5m2|bf16|f16|f32|f64|u8|u16|u32|u64|s8|s16|s32|s64|pred)\[([0-9,]*)\]")
BYTES = {"bf16": 2, "f16": 2, "f32": 4, "f64": 8, "u8": 1, "s8": 1, "u16": 2, "s16": 2,
         "u32": 4, "s32": 4, "u64": 8, "s64": 8, "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}


def parse_collectives(hlo_text: str, body_multipliers: dict[str, int]) -> dict:
    """Sum per-device collective payload bytes from compiled (post-SPMD) HLO.

    Ops inside a while-loop body computation execute once per trip; we scale
    them with `body_multipliers` {computation-name-substring: trips} (layer
    scans are the only loops in these models — see EXPERIMENTS.md §Method).
    all-reduce counts 2x (ring reduce+broadcast); others 1x payload.
    """
    per_op: dict[str, float] = {}
    total = 0.0
    comp_mult = 1
    for line in hlo_text.splitlines():
        # top-level computation definitions are unindented "name (...) -> ... {"
        if line and not line[0].isspace() and line.rstrip().endswith("{"):
            name = line.split("(")[0].strip().lstrip("%")
            comp_mult = 1
            for key, mult in body_multipliers.items():
                if key in name:
                    comp_mult = mult
                    break
        cm = COLLECTIVE_RE.search(line)
        if not cm:
            continue
        kind = cm.group(1)
        types = TYPE_RE.findall(line)
        if not types:
            continue
        # payload: largest tensor named in the op line (operand or result)
        size = max(
            BYTES[t] * (np.prod([int(x) for x in dims.split(",") if x]) if dims else 1)
            for t, dims in types
        )
        factor = 2.0 if kind == "all-reduce" else 1.0
        contrib = factor * float(size) * comp_mult
        per_op[kind] = per_op.get(kind, 0.0) + contrib
        total += contrib
    return {"total_bytes": total, "by_kind": per_op}


def body_multipliers_for(cfg) -> dict[str, int]:
    """while-body trip counts for the layer scans (name -> trips)."""
    if cfg.family == "hybrid":
        stages = cfg.n_layers // cfg.attn_every
        return {"while": stages, "body": stages}  # outer scan; inner handled as x attn_every below
    return {"while": cfg.n_layers, "body": cfg.n_layers}


def dryrun_cell(arch: str, shape_name: str, multi_pod: bool, tiny: bool = False,
                layout: str = "default") -> dict:
    cfg = get_config(arch)
    if os.environ.get("DRYRUN_KV_INT8"):
        import dataclasses as _dc

        cfg = _dc.replace(cfg, kv_cache_dtype="int8")
    if tiny:
        cfg = cfg.reduced()
    shape = SHAPES[shape_name]
    if shape.name == "long_500k" and arch not in SUBQUADRATIC and not tiny:
        return {"status": "skipped", "reason": "full-attention arch; long_500k needs "
                "sub-quadratic decode (DESIGN.md §Arch-applicability)"}
    mesh = make_production_mesh(multi_pod=multi_pod)
    from repro.distributed.sharding import set_activation_mesh

    set_activation_mesh(mesh)
    model = build_model(cfg)
    t0 = time.time()

    params_sds = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    p_sh = param_shardings(params_sds, mesh, layout=layout)
    specs = input_specs(cfg, shape)

    if shape.kind == "train":
        tcfg = TrainConfig(microbatch=int(os.environ.get("DRYRUN_MICROBATCH", "0")))
        step_fn, opt = make_train_step(model.loss, tcfg)
        opt_sds = jax.eval_shape(opt.init, params_sds)
        o_sh = {"mu": p_sh, "nu": p_sh,
                "step": jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())}
        b_sh = batch_shardings(specs, mesh)
        lowered = jax.jit(
            step_fn,
            in_shardings=(p_sh, o_sh, b_sh),
            out_shardings=(p_sh, o_sh, None),
        ).lower(params_sds, opt_sds, specs)
    elif shape.kind == "prefill":
        b_sh = batch_shardings(specs, mesh)
        cache_sds = jax.eval_shape(lambda: model.init_cache(shape.global_batch, shape.seq_len))
        c_sh = cache_shardings(cache_sds, mesh)

        def prefill_step(params, batch):
            return model.prefill(params, batch, shape.seq_len)

        lowered = jax.jit(
            prefill_step, in_shardings=(p_sh, b_sh), out_shardings=(None, c_sh)
        ).lower(params_sds, specs)
    else:  # decode
        c_sh = cache_shardings(specs["cache"], mesh)
        b_sh = batch_shardings({"tokens": specs["tokens"], "pos": specs["pos"]}, mesh)

        def serve_step(params, cache, tokens, pos):
            return model.decode_step(params, cache, tokens, pos)

        lowered = jax.jit(
            serve_step,
            in_shardings=(p_sh, c_sh, b_sh["tokens"], b_sh["pos"]),
            out_shardings=(None, c_sh),
            donate_argnums=(1,),
        ).lower(params_sds, specs["cache"], specs["tokens"], specs["pos"])

    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):  # jax < 0.5 returns a per-device list
        ca = ca[0] if ca else {}
    hlo = compiled.as_text()
    coll = parse_collectives(hlo, body_multipliers_for(cfg))
    n_dev = int(np.prod(mesh.devices.shape))
    rec = {
        "status": "ok",
        "layout": layout,
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "devices": n_dev,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_per_device_gb": round(
                (mem.argument_size_in_bytes + mem.output_size_in_bytes
                 + mem.temp_size_in_bytes - mem.alias_size_in_bytes) / 2**30, 3),
        },
        "cost": {"flops_per_device": ca.get("flops"),
                 "bytes_per_device": ca.get("bytes accessed")},
        "collectives": coll,
        "hlo_lines": hlo.count("\n"),
    }
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="results/dryrun.json")
    ap.add_argument("--tiny", action="store_true", help="reduced configs (CI)")
    ap.add_argument("--layout", default="default",
                    help="sharding layout variant (default|dp_heavy|moe_expert_tp)")
    ap.add_argument("--resume", action="store_true", help="skip cells already in --out")
    args = ap.parse_args()

    archs = ALL_LM_ARCHS if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    results = {}
    if args.resume and os.path.exists(args.out):
        results = json.load(open(args.out))

    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                key = f"{arch}|{shape}|{'2x16x16' if mp else '16x16'}"
                if args.layout != "default":
                    key += f"|{args.layout}"
                if key in results and results[key].get("status") in ("ok", "skipped"):
                    continue
                print(f"=== {key}", flush=True)
                try:
                    rec = dryrun_cell(arch, shape, mp, tiny=args.tiny, layout=args.layout)
                except Exception as e:  # noqa: BLE001 — record and continue
                    rec = {"status": "error", "error": f"{type(e).__name__}: {e}",
                           "trace": traceback.format_exc()[-2000:]}
                results[key] = rec
                print(json.dumps({k: v for k, v in rec.items() if k != "trace"})[:600],
                      flush=True)
                with open(args.out, "w") as f:
                    json.dump(results, f, indent=1)
    n_ok = sum(1 for r in results.values() if r.get("status") == "ok")
    n_skip = sum(1 for r in results.values() if r.get("status") == "skipped")
    n_err = sum(1 for r in results.values() if r.get("status") == "error")
    print(f"DONE ok={n_ok} skipped={n_skip} errors={n_err}")


if __name__ == "__main__":
    main()
