"""Production meshes.  A FUNCTION (not a module constant) so importing this
module never touches jax device state."""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_host_mesh"]


def _axis_type_kwargs(n_axes: int) -> dict:
    """jax >= 0.5 wants explicit AxisType; older jax has neither the enum nor
    the kwarg — omit it there (Auto is the default behavior anyway)."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def make_production_mesh(*, multi_pod: bool = False):
    """(16,16) ('data','model') single pod; (2,16,16) ('pod','data','model')
    for the 512-chip two-pod dry run."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_type_kwargs(len(axes)))


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests / local runs)."""
    n = len(jax.devices())
    data = min(data, n)
    model = max(1, min(model, n // data))
    return jax.make_mesh((data, model), ("data", "model"), **_axis_type_kwargs(2))
