"""Serving launcher: batched decode over the continuous-batching engine.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --reduced \
        --requests 8 --slots 4
"""
from __future__ import annotations

import argparse
import time

import jax


def main():
    from repro.config import get_config
    from repro.models import build_model
    from repro.serve import Request, ServeEngine

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(model, params, n_slots=args.slots, max_len=args.max_len)
    reqs = [Request(prompt=[(11 * i + j) % cfg.vocab for j in range(5)],
                    max_new_tokens=args.max_new, temperature=args.temperature, rid=i)
            for i in range(args.requests)]
    t0 = time.time()
    engine.run(reqs)
    dt = time.time() - t0
    tokens = sum(len(r.output) for r in reqs)
    print(f"[serve] {len(reqs)} requests, {tokens} tokens, {dt:.2f}s "
          f"({tokens/dt:.1f} tok/s)")


if __name__ == "__main__":
    main()
