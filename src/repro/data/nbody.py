"""Charged N-body simulation (the SEGNN sanity-check task, Satorras et al.).

5 particles with +-1 charges, random initial state; leapfrog integration of
Coulomb dynamics; the model predicts positions after `horizon` steps.
"""
from __future__ import annotations

import numpy as np

__all__ = ["nbody_dataset"]


def _simulate(charge, pos, vel, steps: int, dt: float = 0.001):
    for _ in range(steps):
        diff = pos[None, :, :] - pos[:, None, :]
        d = np.linalg.norm(diff, axis=-1) + np.eye(len(charge))
        f = (charge[:, None] * charge[None, :])[:, :, None] * diff / (d**3)[:, :, None]
        acc = -np.sum(f * (1 - np.eye(len(charge)))[:, :, None], axis=1)
        vel = vel + dt * acc
        pos = pos + dt * vel
    return pos, vel


def nbody_dataset(n_samples: int, n_particles: int = 5, horizon: int = 500, seed: int = 0):
    rng = np.random.default_rng(seed)
    charge = rng.choice([-1.0, 1.0], (n_samples, n_particles))
    pos = rng.normal(scale=1.0, size=(n_samples, n_particles, 3))
    vel = rng.normal(scale=0.5, size=(n_samples, n_particles, 3))
    target = np.empty_like(pos)
    for s in range(n_samples):
        target[s], _ = _simulate(charge[s], pos[s], vel[s], horizon)
    return {
        "charge": charge.astype(np.float32),
        "pos": pos.astype(np.float32),
        "vel": vel.astype(np.float32),
        "target": target.astype(np.float32),
    }
