"""Deterministic, resumable, per-host-sharded synthetic LM data pipeline.

Production contract: the pipeline state is a tiny pytree (step counter +
seed + host shard) checkpointed with the model, so restart/elastic-reshard
resumes the *exact* token stream (tested).  Token streams are a stationary
Markov chain (so the LM has learnable structure; loss decreases).
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["LMTokenPipeline"]


@dataclasses.dataclass
class LMTokenPipeline:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    host_id: int = 0
    n_hosts: int = 1
    step: int = 0

    def __post_init__(self):
        assert self.global_batch % self.n_hosts == 0
        self.local_batch = self.global_batch // self.n_hosts
        rng = np.random.default_rng(self.seed)
        # low-entropy Markov transition: each token prefers a few successors
        k = min(8, self.vocab)
        self._succ = rng.integers(0, self.vocab, size=(self.vocab, k))
        self._probs = rng.dirichlet(np.ones(k) * 0.3, size=self.vocab)

    # -- checkpointable state ------------------------------------------------
    def state(self) -> dict:
        return {"step": self.step, "seed": self.seed}

    def restore(self, state: dict, host_id: int | None = None, n_hosts: int | None = None):
        self.step = int(state["step"])
        self.seed = int(state["seed"])
        if host_id is not None:
            self.host_id, self.n_hosts = host_id, n_hosts
            self.local_batch = self.global_batch // self.n_hosts
        return self

    # -- iteration -------------------------------------------------------------
    def _gen_row(self, rng):
        toks = np.empty(self.seq_len + 1, dtype=np.int32)
        toks[0] = rng.integers(0, self.vocab)
        for t in range(self.seq_len):
            succ = self._succ[toks[t]]
            toks[t + 1] = succ[rng.choice(len(succ), p=self._probs[toks[t]])]
        return toks

    def next_batch(self) -> dict:
        """Host-local batch; deterministic in (seed, step, host shard)."""
        out = np.empty((self.local_batch, self.seq_len + 1), dtype=np.int32)
        for i in range(self.local_batch):
            row_id = self.step * self.global_batch + self.host_id * self.local_batch + i
            rng = np.random.default_rng((self.seed, row_id))
            out[i] = self._gen_row(rng)
        self.step += 1
        # Model.loss shifts internally (predict token t+1 from logits at t),
        # so labels == tokens.
        toks = out[:, :-1]
        return {"tokens": toks, "labels": toks}
