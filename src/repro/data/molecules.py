"""Synthetic molecular force-field data (Lennard-Jones clusters).

Stands in for 3BPA/OC20 (no dataset downloads in this container): random
clusters with per-species LJ parameters; energies and analytic forces are
exact, so the force-field learning task is well-posed and E(3)-symmetric.
"""
from __future__ import annotations

import numpy as np

__all__ = ["lj_dataset", "lj_energy_forces"]


def lj_energy_forces(species, pos, eps_table, sig_table):
    """Pairwise LJ.  species [n], pos [n,3] -> (E, F [n,3])."""
    n = pos.shape[0]
    diff = pos[None, :, :] - pos[:, None, :]
    d2 = np.sum(diff**2, axis=-1) + np.eye(n)
    d = np.sqrt(d2)
    eps = eps_table[species][:, None] * eps_table[species][None, :]
    sig = 0.5 * (sig_table[species][:, None] + sig_table[species][None, :])
    x6 = (sig / d) ** 6
    emat = 4 * eps * (x6**2 - x6) * (1 - np.eye(n))
    E = 0.5 * np.sum(emat)
    # dE/dr_i
    dEdd = 4 * eps * (-12 * x6**2 + 6 * x6) / d * (1 - np.eye(n))
    F = np.zeros_like(pos)
    for i in range(n):
        grad = np.sum(dEdd[i][:, None] * (-diff[i]) / d[i][:, None], axis=0)
        F[i] = -grad
    return E, F


def lj_dataset(n_samples: int, n_atoms: int = 8, n_species: int = 4, seed: int = 0):
    """Returns dict of arrays: species [S,n], pos [S,n,3], energy [S],
    forces [S,n,3]."""
    rng = np.random.default_rng(seed)
    eps_table = rng.uniform(0.5, 1.5, n_species)
    sig_table = rng.uniform(0.7, 0.9, n_species)
    species = rng.integers(0, n_species, (n_samples, n_atoms))
    pos = np.empty((n_samples, n_atoms, 3))
    E = np.empty(n_samples)
    F = np.empty((n_samples, n_atoms, 3))
    grid = np.stack(np.meshgrid(*[np.arange(2)] * 3, indexing="ij"), -1).reshape(-1, 3)
    for s in range(n_samples):
        # jittered lattice keeps pairs off the singular core; resample any
        # configuration with pathological forces
        for _ in range(50):
            base = rng.normal(scale=0.08, size=(n_atoms, 3))
            pos[s] = grid[:n_atoms] * 1.3 + base
            E[s], F[s] = lj_energy_forces(species[s], pos[s], eps_table, sig_table)
            if np.abs(F[s]).max() < 25.0 and abs(E[s]) < 25.0:
                break
    return {
        "species": species.astype(np.int32),
        "pos": pos.astype(np.float32),
        "energy": E.astype(np.float32),
        "forces": F.astype(np.float32),
    }
