from .pipeline import LMTokenPipeline  # noqa: F401
from .molecules import lj_dataset  # noqa: F401
from .nbody import nbody_dataset  # noqa: F401
