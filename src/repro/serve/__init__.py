from .engine import ServeEngine, Request  # noqa: F401
