from .engine import (ServeEngine, Request,  # noqa: F401
                     EquivariantServeEngine, EquivariantRequest)
from .metrics import ServeMetrics, percentile  # noqa: F401
from .pools import BucketSpec, BucketedPools, SlotPool, default_buckets  # noqa: F401
from .scheduler import AdmissionQueue, Scheduler  # noqa: F401
