from .engine import (ServeEngine, Request,  # noqa: F401
                     EquivariantServeEngine, EquivariantRequest)
from .faults import FaultPlan, InjectedFault, injected  # noqa: F401
from .metrics import ServeMetrics, percentile  # noqa: F401
from .pools import BucketSpec, BucketedPools, SlotPool, default_buckets  # noqa: F401
from .replicas import ReplicaSet  # noqa: F401
from .scheduler import AdmissionQueue, Scheduler  # noqa: F401
