"""Deterministic fault injection for the serve subsystem (DESIGN.md §11.1).

Recovery behavior must be *measured under injected faults*, not asserted —
the same discipline the repo applies to performance claims.  This module is
the injection half: a seeded `FaultPlan` names WHERE a fault fires
(injection points threaded through `SlotPool.begin_step`/`finish_step` and
`EquivariantServeEngine.warmup`) and WHEN (an explicit per-point invocation
schedule, a per-invocation probability, or both), so a chaos run is exactly
reproducible from its seed and two runs with the same plan see the same
fault sequence (`FaultPlan.fired` records it; tests compare the records).

Injection points (`POINTS`):

- ``step_raise``     — the pool's dispatched step raises (checked in
  `begin_step` before dispatch; real dispatch exceptions take the same
  recovery path);
- ``step_nonfinite`` — the step returns non-finite energy/forces for one
  slot (payload ``slots=[rel_idx,...]``), a deterministic seeded pick, or
  the whole batch (``slots='all'`` — exercises the bisect path);
- ``step_timeout``   — the step is treated as having exceeded the pool's
  watchdog deadline;
- ``compile_fail``   — a bucket's warmup compile raises (transient; the
  engine's warmup retries);
- ``autotune_cache_load`` — the persistent autotune cache is unreadable at
  warmup (the engine falls back to cold measurement, serving still works).

Zero overhead when no plan is installed: call sites guard on the
module-level ``_ACTIVE is None`` check (one attribute load per step), and
nothing here ever touches device state — faults corrupt *host-side* results
or raise *host-side* exceptions, so recovery exercises the real rebuild
path (host slot arrays are the source of truth).

Scoping: a plan may carry a ``scope`` predicate over the call-site context
(pools pass ``tag``/``pool``), so chaos tests can fail exactly one replica
of a `ReplicaSet` (`serve/replicas.py` tags each replica's engine).  Only
in-scope invocations advance a point's counter — the schedule is
deterministic relative to the scoped stream.
"""
from __future__ import annotations

import contextlib
import dataclasses
import zlib
from collections import Counter

import numpy as np

__all__ = ["POINTS", "InjectedFault", "FaultSpec", "FaultPlan",
           "install", "uninstall", "active", "fire", "injected"]

POINTS = ("step_raise", "step_nonfinite", "step_timeout", "compile_fail",
          "autotune_cache_load")


class InjectedFault(RuntimeError):
    """Raised by injection points whose fault kind is 'raise'."""


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One fired fault: the point, its invocation index, and a payload the
    call site interprets (e.g. which relative slots go non-finite)."""
    point: str
    n: int
    payload: dict = dataclasses.field(default_factory=dict)

    def key(self) -> tuple:
        """Hashable schedule identity (payload excluded — it is derived
        deterministically from (seed, point, n))."""
        return (self.point, self.n)


def _point_rng(seed: int, point: str, n: int, salt: str = ""):
    """Deterministic per-(point, invocation) generator: the decision for
    invocation ``n`` never depends on how many other points fired."""
    return np.random.default_rng(
        (int(seed), zlib.crc32((point + salt).encode()) & 0xFFFFFFFF, int(n)))


class FaultPlan:
    """A seeded, deterministic fault schedule.

    Parameters
    ----------
    seed:     base seed for every probabilistic draw.
    rates:    ``{point: probability}`` — each in-scope invocation of the
              point fires independently with this probability (seeded, so
              the schedule is a pure function of (seed, invocation index)).
    at:       ``{point: iterable[int]}`` — fire on exactly these 0-based
              in-scope invocation indices (composable with ``rates``).
    payload:  ``{point: dict}`` — static payload attached to every fire of
              the point (e.g. ``{'step_nonfinite': {'slots': [0]}}``; the
              default non-finite payload is a seeded one-slot pick).
    scope:    optional predicate over the call-site context dict; out-of-
              scope invocations neither fire nor advance the counter.
    max_fires: optional per-point cap on total fires.
    """

    def __init__(self, seed: int = 0, rates=None, at=None, payload=None,
                 scope=None, max_fires: int | None = None):
        for src in (rates, at, payload):
            for point in (src or {}):
                if point not in POINTS:
                    raise ValueError(f"unknown injection point {point!r}; "
                                     f"known: {POINTS}")
        self.seed = int(seed)
        self.rates = dict(rates or {})
        self.at = {k: frozenset(int(i) for i in v)
                   for k, v in (at or {}).items()}
        self.payload = {k: dict(v) for k, v in (payload or {}).items()}
        self.scope = scope
        self.max_fires = max_fires
        self._count: Counter = Counter()    # in-scope invocations per point
        self._fires: Counter = Counter()
        self.fired: list[FaultSpec] = []    # the realized schedule

    # ------------------------------------------------------------- schedule
    def would_fire(self, point: str, n: int) -> bool:
        """Pure query: does invocation ``n`` of ``point`` fire under this
        plan?  (Determinism proofs compare these across plan instances.)"""
        if n in self.at.get(point, ()):
            return True
        rate = self.rates.get(point, 0.0)
        return rate > 0.0 and bool(_point_rng(self.seed, point, n).random()
                                   < rate)

    def check(self, point: str, **ctx):
        """One invocation of ``point``: returns a `FaultSpec` if the plan
        fires here, else None.  Called via the module-level `fire`."""
        if point not in POINTS:
            raise ValueError(f"unknown injection point {point!r}")
        if self.scope is not None and not self.scope(ctx):
            return None
        n = self._count[point]
        self._count[point] += 1
        if not self.would_fire(point, n):
            return None
        if self.max_fires is not None and self._fires[point] >= self.max_fires:
            return None
        self._fires[point] += 1
        payload = dict(self.payload.get(point, {}))
        if point == "step_nonfinite" and "slots" not in payload:
            # deterministic one-slot pick among the active slots
            n_active = max(1, int(ctx.get("n_active", 1)))
            payload["slots"] = [int(_point_rng(self.seed, point, n,
                                               salt=":pick")
                                    .integers(n_active))]
        spec = FaultSpec(point, n, payload)
        if len(self.fired) < 100_000:       # bounded record, plenty for tests
            self.fired.append(spec)
        return spec

    def schedule_keys(self) -> list[tuple]:
        """The realized schedule as comparable (point, n) keys."""
        return [s.key() for s in self.fired]


# ---------------------------------------------------------------------------
# module-level installation (call sites guard on `_ACTIVE is not None`)
# ---------------------------------------------------------------------------

_ACTIVE: FaultPlan | None = None


def install(plan: FaultPlan | None) -> FaultPlan | None:
    global _ACTIVE
    _ACTIVE = plan
    return plan


def uninstall() -> None:
    install(None)


def active() -> FaultPlan | None:
    return _ACTIVE


def fire(point: str, **ctx):
    """Check the installed plan at an injection point (None = no fault)."""
    plan = _ACTIVE
    if plan is None:
        return None
    return plan.check(point, **ctx)


@contextlib.contextmanager
def injected(plan: FaultPlan):
    """Install ``plan`` for the duration of a with-block (restores the
    previously installed plan, so chaos tests nest safely)."""
    global _ACTIVE
    prev = _ACTIVE
    _ACTIVE = plan
    try:
        yield plan
    finally:
        _ACTIVE = prev
