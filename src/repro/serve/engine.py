"""Batched serving engines (DESIGN.md §10).

`ServeEngine` — slot-based continuous batching for LM decoding over a shared
KV (or recurrent-state) cache:

- Fixed B decode slots; requests are admitted into free slots, prefilled
  one-at-a-time (slot-batched prefill), then all active slots step together.
- Greedy or temperature sampling; sampling keys derive from
  ``(engine seed, request rid, token index)`` so a request's sampled tokens
  are reproducible regardless of admission order or batch composition.
- Per-slot stop conditions (EOS / max_len); the ``max_new_tokens`` budget is
  checked at admission too — the prefill-sampled token counts against it.
- Cache layouts come from Model.init_cache and work for every family
  (attention KV, RWKV state, Zamba hybrid).

`EquivariantServeEngine` — the same continuous-batching discipline for
force-field inference (energy/forces/relaxation requests on a Gaunt-MACE
model), scaled out across the serve subsystem:

- **admission** rides `serve/scheduler.py`: a priority queue with
  per-request deadlines and structured rejection (invalid or oversized
  geometry never touches a shared batched step);
- **slots** ride `serve/pools.py`: size-bucketed slot pools, each bucket
  compiling its own step function for its own padded shape, so a small
  molecule no longer pads to the deployment-maximum atom count;
- **stepping** is pipelined: each pool's jitted step is dispatched
  asynchronously and the NEXT step's admissions + host slot writes +
  device staging overlap the in-flight device computation;
- **observability** rides `serve/metrics.py`: queue-wait/step/total
  latency, occupancy and padding-waste gauges, rejection counters, and the
  Gaunt engine's own timing-run/conversion counters.

Inside every step each layer's tensor products route through the engine's
batched Gaunt plans (DESIGN.md §5) and Fourier-resident chain plans
(DESIGN.md §6): per relaxation step each layer's many-body product converts
once and projects once, the edge geometry is built once, and each bucket's
compiled step (plus the plan/constant caches behind it) is carried across
ALL relaxation steps of every request it serves.  Residency holds for
sharded configs too (``shard_data``): resident grids row-shard through the
batched buckets, so the serving step is never forced off the resident
route.  ``warmup()`` seeds every bucket's measured autotune keys and
compiles every bucket's step on ghost-only slots, so the first real request
pays serving cost only.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import faults
from .metrics import ServeMetrics
from .pools import BucketedPools, BucketSpec
from .scheduler import REASON_INVALID, REASON_TOO_LARGE, Scheduler

__all__ = ["ServeEngine", "Request",
           "EquivariantServeEngine", "EquivariantRequest"]


@dataclasses.dataclass
class Request:
    prompt: list[int]
    max_new_tokens: int = 16
    temperature: float = 0.0
    rid: int = 0
    # scheduling (serve/scheduler.py): lower priority value = served first;
    # deadline = seconds of allowed queue wait from submission, None = none
    priority: int = 0
    deadline: float | None = None
    # filled by the engine:
    output: list[int] = dataclasses.field(default_factory=list)
    done: bool = False
    rejected: bool = False
    reject_reason: str | None = None


class ServeEngine:
    def __init__(self, model, params, n_slots: int = 4, max_len: int = 512, seed: int = 0):
        self.model = model
        self.params = params
        self.n_slots = n_slots
        self.max_len = max_len
        self.cache = model.init_cache(n_slots, max_len)
        self.pos = np.full(n_slots, -1, dtype=np.int32)  # last written index
        self.slot_req: list[Optional[Request]] = [None] * n_slots
        self._base_key = jax.random.PRNGKey(seed)
        self.metrics = ServeMetrics()
        self._decode = jax.jit(model.decode_step)

        def prefill_one(params, cache, tokens, slot):
            """Prefill a single sequence via repeated decode steps (works for
            every cache family without slot-gather logic)."""
            def body(carry, tok_pos):
                cache, _ = carry
                tok, p = tok_pos
                toks = jnp.zeros((self.n_slots, 1), jnp.int32).at[slot, 0].set(tok)
                # inactive slots write to a scratch position (max_len-1) so
                # they can never clobber live sequences
                pos = jnp.full((self.n_slots,), max_len - 1, jnp.int32).at[slot].set(p)
                logits, cache = model.decode_step(params, cache, toks, pos)
                return (cache, logits[slot, 0]), None

            (cache, last_logits), _ = jax.lax.scan(
                body, (cache, jnp.zeros((model.cfg.vocab,), jnp.float32)),
                (tokens, jnp.arange(tokens.shape[0], dtype=jnp.int32)),
            )
            return cache, last_logits

        self._prefill_one = jax.jit(prefill_one)

    # ------------------------------------------------------------- admission
    def _free_slots(self) -> list[int]:
        return [i for i, r in enumerate(self.slot_req) if r is None]

    def has_active(self) -> bool:
        return any(r is not None for r in self.slot_req)

    def validate(self, req: Request):
        """Admission-time validation -> None | (reason, detail)."""
        if not req.prompt:
            return (REASON_INVALID, "empty prompt")
        if req.max_new_tokens < 1:
            return (REASON_INVALID,
                    f"max_new_tokens={req.max_new_tokens} < 1")
        if len(req.prompt) + 1 >= self.max_len:
            return (REASON_TOO_LARGE,
                    f"prompt of {len(req.prompt)} tokens leaves no decode "
                    f"room under max_len={self.max_len}")
        return None

    def _reset_slot(self, slot: int):
        """Zero one slot's rows in every cache leaf (batch dim = 1)."""
        self.cache = jax.tree.map(
            lambda a: a.at[:, slot].set(jnp.zeros_like(a[:, slot])), self.cache)

    def add_request(self, req: Request) -> bool:
        free = self._free_slots()
        if not free:
            return False
        slot = free[0]
        pre = self.cache  # pre-admission cache (fast-retire restores it)
        self._reset_slot(slot)  # recurrent families accumulate state otherwise
        toks = jnp.asarray(req.prompt, jnp.int32)
        snapshot = self.cache
        new_cache, last_logits = self._prefill_one(
            self.params, self.cache, toks, slot)
        # keep ONLY this slot's rows from the prefill — recurrent families
        # update every row per step, which would pollute live slots
        self.cache = jax.tree.map(
            lambda old, new: old.at[:, slot].set(new[:, slot]), snapshot, new_cache)
        # first generated token comes from the last prompt logits
        tok = self._sample(last_logits, req)
        req.output.append(int(tok))
        if len(req.output) >= req.max_new_tokens:
            # budget met by the prefill-sampled token: retire at admission,
            # never occupy the slot (a max_new_tokens=1 request used to get
            # a second token before the post-step done check fired) — and
            # put the cache back exactly as found: the slot was never
            # occupied, so its rows must not carry this prefill's state
            self.cache = pre
            req.done = True
            self.metrics.observe_complete(req)
            return True
        self.pos[slot] = len(req.prompt) - 1
        self.slot_req[slot] = req
        return True

    # scheduler protocol: admission (validation runs in the scheduler)
    try_admit = add_request

    def _sample(self, logits, req: Request):
        if req.temperature <= 0:
            return int(jnp.argmax(logits))
        # reproducible per request: (engine seed, rid, token index) — NOT a
        # shared mutating engine key, whose stream depended on admission order
        key = jax.random.fold_in(
            jax.random.fold_in(self._base_key, req.rid), len(req.output))
        return int(jax.random.categorical(key, logits / req.temperature))

    # ------------------------------------------------------------- stepping
    def step(self, overlap=None):
        """One decode step for all active slots.  ``overlap`` (the
        scheduler's admission pass) runs after the decode dispatch and
        before sampling reads the logits, so prefill/bookkeeping for the
        next step's admissions overlaps the in-flight decode."""
        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        if not active:
            return
        toks = np.zeros((self.n_slots, 1), np.int32)
        pos_np = np.full(self.n_slots, self.max_len - 1, np.int32)  # scratch
        for i in active:
            toks[i, 0] = self.slot_req[i].output[-1]
            pos_np[i] = self.pos[i] + 1
        pos = jnp.asarray(pos_np)
        logits, self.cache = self._decode(
            self.params, self.cache, jnp.asarray(toks), pos)
        if overlap is not None:
            overlap()
        for i in active:
            self.pos[i] += 1
            req = self.slot_req[i]
            tok = self._sample(logits[i, 0], req)
            req.output.append(tok)
            if len(req.output) >= req.max_new_tokens or self.pos[i] + 2 >= self.max_len:
                req.done = True
                self.metrics.observe_complete(req)
                self.slot_req[i] = None
                self.pos[i] = -1

    def run(self, requests: list[Request]) -> list[Request]:
        return Scheduler(self).run(requests)


# --------------------------------------------------------------------------
# equivariant (force-field) serving
# --------------------------------------------------------------------------


@dataclasses.dataclass
class EquivariantRequest:
    """One molecular inference job: `steps` gradient-descent relaxation steps
    (steps=1 => a single energy/forces evaluation)."""

    species: np.ndarray           # [n] int
    pos: np.ndarray               # [n, 3]; updated in place by relaxation —
    #                               on completion it is the geometry that
    #                               produced `energy`/`forces`
    steps: int = 1
    step_size: float = 0.0        # relaxation: pos += step_size * forces
    rid: int = 0
    # fault tolerance (DESIGN.md §11): failed/timed-out/non-finite steps
    # retry this request from its admission snapshot up to max_retries
    # total attempts beyond the first; past it -> reject_reason='step_failed'
    max_retries: int = 2
    # scheduling (serve/scheduler.py): lower priority value = served first;
    # deadline = seconds of allowed queue wait from submission, None = none
    priority: int = 0
    deadline: float | None = None
    # filled by the engine:
    energy: float | None = None
    forces: np.ndarray | None = None
    done: bool = False
    rejected: bool = False
    reject_reason: str | None = None


class EquivariantServeEngine:
    """Continuous batching for a MaceGaunt-style model over size-bucketed
    atom-padded slot pools: every step dispatches one fused batched
    evaluation per active bucket, pipelining the next step's admissions
    against the in-flight device compute."""

    def __init__(self, model, params, n_slots: int = 4, max_atoms: int = 16,
                 warmup: bool = False, buckets=None, clock=time.monotonic,
                 step_timeout_s: float | None = None,
                 retry_backoff_s: float = 5e-4, metrics=None, tag: str = ""):
        self.model = model
        self.params = params
        self.clock = clock
        self.tag = tag                 # replica label (fault scoping)
        self.metrics = metrics if metrics is not None \
            else ServeMetrics(clock=clock)
        specs = self._resolve_buckets(buckets, n_slots, max_atoms)
        self.pools = BucketedPools(model, params, specs,
                                   metrics=self.metrics, clock=clock,
                                   step_timeout_s=step_timeout_s,
                                   retry_backoff_s=retry_backoff_s, tag=tag)
        if warmup:
            self.warmup()

    def _resolve_buckets(self, buckets, n_slots, max_atoms):
        """Bucket resolution: explicit ``buckets`` arg > the config's
        ``serve_buckets`` knob > a single (max_atoms, n_slots) bucket (the
        historical fixed-padding behavior)."""
        if buckets is None:
            cfg = getattr(self.model, "cfg", None)
            buckets = getattr(cfg, "serve_buckets", None) \
                if cfg is not None else None
        if buckets is None:
            return (BucketSpec(max_atoms, n_slots),)
        return tuple(b if isinstance(b, BucketSpec) else BucketSpec(*b)
                     for b in buckets)

    # ------------------------------------------------------- compat surface
    @property
    def max_atoms(self) -> int:
        return self.pools.max_atoms

    @property
    def n_slots(self) -> int:
        return sum(p.spec.n_slots for p in self.pools)

    @property
    def slot_req(self) -> list:
        """Flat view over every pool's slots (smallest bucket first)."""
        return [r for p in self.pools for r in p.slot_req]

    # ------------------------------------------------------------- warmup
    def warmup(self) -> None:
        """Per-bucket compile + autotune seeding, so admission latency for
        the first real request is serving cost only.  Each bucket's step is
        compiled on ghost-only slots, and each bucket's measured chain keys
        are seeded at that bucket's OWN row count — the batch_hint its
        traced step actually presents.

        With ``cfg.chain_tune='measure'`` the model's chained products
        dispatch through the engine's measured chain autotuner (DESIGN.md
        §6.4) — measurement cannot run inside a step's jit trace, so it is
        seeded here, outside jit: per bucket, the many-body selfmix chain
        key (the only chain a served MaceGaunt plans — its layer-constant
        edge geometry rides boundary buckets, not chains) is measured once
        and the traced step then hits the cached selection.  Both storage
        precisions are pre-measured (DESIGN.md §3.6), and a ``grid_gate``
        'auto' policy is resolved per bucket before its step compiles
        (DESIGN.md §6.5).  Skipped for ``shard_data`` configs: sharded
        chains pin the 'tree' backend and never consult the measured cache.

        If a persistent autotune cache is configured (``cfg.autotune_cache``
        or $REPRO_AUTOTUNE_CACHE, DESIGN.md §4.5), it is loaded FIRST: on a
        warm host every per-bucket key hits the persisted table and warmup
        performs zero timing runs — subprocess-proven in
        tests/test_serve_scale.py."""
        cfg = getattr(self.model, "cfg", None)
        from repro.core import engine as _engine

        eng = _engine.get_engine()
        cache = getattr(cfg, "autotune_cache", None) if cfg is not None else None
        if cache is not None:
            eng.set_autotune_cache(cache)
        if faults._ACTIVE is not None and faults.fire(
                "autotune_cache_load", tag=self.tag) is not None:
            # unreadable persistent cache: degrade to cold measurement —
            # serving still comes up, it just pays warmup timing runs
            self.metrics.counters["autotune_cache_load_failed"] += 1
        else:
            eng._maybe_load_cache()
        if (cfg is not None
                and getattr(cfg, "chain_tune", "heuristic") == "measure"
                and not getattr(cfg, "shard_data", False)):
            for pool in self.pools:
                # mirror each bucket's traced call exactly: per-slot row
                # count (the step vmaps over slots, so the chain sees
                # [bucket max_atoms, channels] leading dims per element)
                # and the selfmix [A]*nu share pattern
                rows = pool.spec.max_atoms * cfg.channels
                dts = getattr(cfg, "compute_dtype", "float32")
                gg = getattr(cfg, "grid_gate", "off")
                if gg == "auto":
                    gg = "on" if eng.select_gate(
                        (cfg.L,) * cfg.nu, cfg.L, dtype=dts, batch_hint=rows,
                        entry_hint=("sh",) * cfg.nu,
                        share_hint=(0,) * cfg.nu) == "grid" else "off"
                gate_opts = (False, True) if gg in ("on", "grid", True) \
                    else (False,)
                for d in dict.fromkeys(["float32", dts] if dts != "auto"
                                       else ["auto"]):
                    for g in gate_opts:
                        _engine.plan_chain((cfg.L,) * cfg.nu, cfg.L,
                                           tune="measure", batch_hint=rows,
                                           share_hint=(0,) * cfg.nu, dtype=d,
                                           gate=g)
        for pool in self.pools:
            # transient compile failures (injected or real) retry: a serving
            # host that loses one compile attempt should come up, not die
            for attempt in range(3):
                try:
                    pool.warmup_compile()
                    break
                except Exception:
                    self.metrics.counters["warmup_retries"] += 1
                    if attempt == 2:
                        raise

    # ------------------------------------------------------------- admission
    def has_active(self) -> bool:
        return self.pools.has_active()

    def evict_active(self) -> list:
        """Pull every in-flight request out of every pool, restored to its
        admission snapshot (replica failover: `serve/replicas.py` requeues
        them onto surviving replicas)."""
        return [r for p in self.pools for r in p.evict()]

    def validate(self, req: EquivariantRequest):
        """Admission-time validation -> None | (reason, detail).  Bad
        geometry is rejected HERE, structurally — one NaN position evaluated
        in a shared batched step would poison every slot's gradient."""
        species = np.asarray(req.species)
        if species.size == 0:
            return (REASON_INVALID, "empty species")
        if not np.issubdtype(species.dtype, np.integer):
            return (REASON_INVALID,
                    f"species dtype {species.dtype} is not integral")
        if species.min() < 0:
            return (REASON_INVALID,
                    f"negative species value {int(species.min())}")
        n_species = getattr(getattr(self.model, "cfg", None),
                            "n_species", None)
        if n_species is not None and species.max() >= n_species:
            # the jitted step's embedding gather clamps out-of-range
            # indices, which would silently produce a wrong energy
            return (REASON_INVALID,
                    f"species value {int(species.max())} >= "
                    f"n_species={n_species}")
        if getattr(req, "steps", 1) < 1:
            return (REASON_INVALID, f"steps={req.steps} < 1")
        pos = np.asarray(req.pos, np.float32)
        if pos.shape != (species.size, 3):
            return (REASON_INVALID,
                    f"pos shape {pos.shape} != ({species.size}, 3)")
        if not np.all(np.isfinite(pos)):
            return (REASON_INVALID, "non-finite positions")
        if species.size > self.pools.max_atoms:
            return (REASON_TOO_LARGE,
                    f"{species.size} atoms > largest bucket "
                    f"{self.pools.max_atoms}")
        return None

    def try_admit(self, req: EquivariantRequest) -> bool:
        """Admit into the smallest bucket that fits (strictly — a small
        request never spills into a larger bucket, so it can never trigger
        a larger bucket's compile or pay its padding)."""
        pool = self.pools.select(len(req.species))
        if pool is None:  # unreachable through the scheduler (validate)
            return False
        return pool.admit(req)

    def add_request(self, req: EquivariantRequest) -> bool:
        """Direct (scheduler-less) admission, kept for callers that manage
        their own loop: validation failures reject structurally (the request
        is consumed: ``rejected=True, done=True``) and return True; False
        means no free slot right now."""
        err = self.validate(req)
        if err is not None:
            req.rejected, req.done = True, True
            req.reject_reason = f"{err[0]}:{err[1]}" if err[1] else err[0]
            self.metrics.observe_reject(req, err[0])
            return True
        return self.try_admit(req)

    # ------------------------------------------------------------- stepping
    def step(self, overlap=None):
        """One pipelined evaluation round: dispatch every active bucket's
        jitted step (asynchronous), run the overlap callback (the
        scheduler's admission pass — queue pops, validation, host slot
        writes) and pre-stage idle pools' tensors while the device computes,
        then block, retire finished requests, and advance relaxations."""
        inflight = []
        for pool in self.pools:
            h = pool.begin_step()
            if h is not None:
                inflight.append((pool, h))
        if overlap is not None:
            overlap()
        busy = {id(p) for p, _ in inflight}
        for pool in self.pools:
            # stage pools admitted-into during the overlap window (their
            # step dispatches next round); in-flight pools re-stage after
            # finish_step's relaxation writes
            if id(pool) not in busy and pool.n_active():
                pool.stage(early=True)
        for pool, h in inflight:
            pool.finish_step(h)

    def run(self, requests: list[EquivariantRequest]) -> list[EquivariantRequest]:
        return Scheduler(self, clock=self.clock).run(requests)
