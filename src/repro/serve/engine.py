"""Batched serving engine: slot-based continuous batching over a shared KV
(or recurrent-state) cache.

- Fixed B decode slots; requests are admitted into free slots, prefilled
  one-at-a-time (slot-batched prefill), then all active slots step together.
- Greedy or temperature sampling; per-slot stop conditions (EOS / max_len).
- Cache layouts come from Model.init_cache and work for every family
  (attention KV, RWKV state, Zamba hybrid).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["ServeEngine", "Request"]


@dataclasses.dataclass
class Request:
    prompt: list[int]
    max_new_tokens: int = 16
    temperature: float = 0.0
    rid: int = 0
    # filled by the engine:
    output: list[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, model, params, n_slots: int = 4, max_len: int = 512, seed: int = 0):
        self.model = model
        self.params = params
        self.n_slots = n_slots
        self.max_len = max_len
        self.cache = model.init_cache(n_slots, max_len)
        self.pos = np.full(n_slots, -1, dtype=np.int32)  # last written index
        self.slot_req: list[Optional[Request]] = [None] * n_slots
        self.key = jax.random.PRNGKey(seed)
        self._decode = jax.jit(model.decode_step)

        def prefill_one(params, cache, tokens, slot):
            """Prefill a single sequence via repeated decode steps (works for
            every cache family without slot-gather logic)."""
            def body(carry, tok_pos):
                cache, _ = carry
                tok, p = tok_pos
                toks = jnp.zeros((self.n_slots, 1), jnp.int32).at[slot, 0].set(tok)
                # inactive slots write to a scratch position (max_len-1) so
                # they can never clobber live sequences
                pos = jnp.full((self.n_slots,), max_len - 1, jnp.int32).at[slot].set(p)
                logits, cache = model.decode_step(params, cache, toks, pos)
                return (cache, logits[slot, 0]), None

            (cache, last_logits), _ = jax.lax.scan(
                body, (cache, jnp.zeros((model.cfg.vocab,), jnp.float32)),
                (tokens, jnp.arange(tokens.shape[0], dtype=jnp.int32)),
            )
            return cache, last_logits

        self._prefill_one = jax.jit(prefill_one)

    # ------------------------------------------------------------- admission
    def _free_slots(self) -> list[int]:
        return [i for i, r in enumerate(self.slot_req) if r is None]

    def _reset_slot(self, slot: int):
        """Zero one slot's rows in every cache leaf (batch dim = 1)."""
        self.cache = jax.tree.map(
            lambda a: a.at[:, slot].set(jnp.zeros_like(a[:, slot])), self.cache)

    def add_request(self, req: Request) -> bool:
        free = self._free_slots()
        if not free:
            return False
        slot = free[0]
        self._reset_slot(slot)  # recurrent families accumulate state otherwise
        toks = jnp.asarray(req.prompt, jnp.int32)
        snapshot = self.cache
        new_cache, last_logits = self._prefill_one(
            self.params, self.cache, toks, slot)
        # keep ONLY this slot's rows from the prefill — recurrent families
        # update every row per step, which would pollute live slots
        self.cache = jax.tree.map(
            lambda old, new: old.at[:, slot].set(new[:, slot]), snapshot, new_cache)
        self.pos[slot] = len(req.prompt) - 1
        self.slot_req[slot] = req
        # first generated token comes from the last prompt logits
        tok = self._sample(last_logits, req.temperature)
        req.output.append(int(tok))
        return True

    def _sample(self, logits, temperature: float):
        if temperature <= 0:
            return int(jnp.argmax(logits))
        self.key, sub = jax.random.split(self.key)
        return int(jax.random.categorical(sub, logits / temperature))

    # ------------------------------------------------------------- stepping
    def step(self):
        """One decode step for all active slots."""
        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        if not active:
            return
        toks = np.zeros((self.n_slots, 1), np.int32)
        pos_np = np.full(self.n_slots, self.max_len - 1, np.int32)  # scratch
        for i in active:
            toks[i, 0] = self.slot_req[i].output[-1]
            pos_np[i] = self.pos[i] + 1
        pos = jnp.asarray(pos_np)
        logits, self.cache = self._decode(
            self.params, self.cache, jnp.asarray(toks), pos)
        for i in active:
            self.pos[i] += 1
            req = self.slot_req[i]
            tok = self._sample(logits[i, 0], req.temperature)
            req.output.append(tok)
            if len(req.output) >= req.max_new_tokens or self.pos[i] + 2 >= self.max_len:
                req.done = True
                self.slot_req[i] = None
                self.pos[i] = -1

    def run(self, requests: list[Request]) -> list[Request]:
        """Continuous batching: admit as slots free up, step until drained."""
        pending = list(requests)
        while pending or any(r is not None for r in self.slot_req):
            while pending and self._free_slots():
                self.add_request(pending.pop(0))
            self.step()
        return requests
