"""Batched serving engines.

`ServeEngine` — slot-based continuous batching for LM decoding over a shared
KV (or recurrent-state) cache:

- Fixed B decode slots; requests are admitted into free slots, prefilled
  one-at-a-time (slot-batched prefill), then all active slots step together.
- Greedy or temperature sampling; per-slot stop conditions (EOS / max_len).
- Cache layouts come from Model.init_cache and work for every family
  (attention KV, RWKV state, Zamba hybrid).

`EquivariantServeEngine` — the same continuous-batching discipline for
force-field inference (energy/forces/relaxation requests on a Gaunt-MACE
model): ragged molecules are padded into fixed atom slots, ghost atoms are
parked beyond the cutoff and masked out of the energy, and every step
evaluates ALL active slots in one jitted vmapped call — whose tensor
products route through the engine's batched Gaunt plans (DESIGN.md §5) and
through Fourier-resident chain plans (DESIGN.md §6): inside every relaxation
step each layer's many-body product converts once and projects once, the
edge geometry (resident filter grid or hoisted Wigner blocks) is built once
per step, and the compiled step function (plus the plan/constant caches
backing it) is carried across ALL relaxation steps of every request — so
the per-step cost is pure resident math, no replanning and no interior SH
round trips.  Residency holds for sharded configs too (``shard_data``):
resident grids row-shard through the batched buckets, so the serving step
is never forced off the resident route.  ``warmup()`` builds and compiles
that step on ghost-only slots so the first real request pays serving cost
only.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["ServeEngine", "Request",
           "EquivariantServeEngine", "EquivariantRequest"]


def _drain(engine, requests: list) -> list:
    """Continuous batching: admit as slots free up, step until drained.
    Shared by both engines (they expose _free_slots/add_request/step)."""
    pending = list(requests)
    while pending or any(r is not None for r in engine.slot_req):
        while pending and engine._free_slots():
            engine.add_request(pending.pop(0))
        engine.step()
    return requests


@dataclasses.dataclass
class Request:
    prompt: list[int]
    max_new_tokens: int = 16
    temperature: float = 0.0
    rid: int = 0
    # filled by the engine:
    output: list[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, model, params, n_slots: int = 4, max_len: int = 512, seed: int = 0):
        self.model = model
        self.params = params
        self.n_slots = n_slots
        self.max_len = max_len
        self.cache = model.init_cache(n_slots, max_len)
        self.pos = np.full(n_slots, -1, dtype=np.int32)  # last written index
        self.slot_req: list[Optional[Request]] = [None] * n_slots
        self.key = jax.random.PRNGKey(seed)
        self._decode = jax.jit(model.decode_step)

        def prefill_one(params, cache, tokens, slot):
            """Prefill a single sequence via repeated decode steps (works for
            every cache family without slot-gather logic)."""
            def body(carry, tok_pos):
                cache, _ = carry
                tok, p = tok_pos
                toks = jnp.zeros((self.n_slots, 1), jnp.int32).at[slot, 0].set(tok)
                # inactive slots write to a scratch position (max_len-1) so
                # they can never clobber live sequences
                pos = jnp.full((self.n_slots,), max_len - 1, jnp.int32).at[slot].set(p)
                logits, cache = model.decode_step(params, cache, toks, pos)
                return (cache, logits[slot, 0]), None

            (cache, last_logits), _ = jax.lax.scan(
                body, (cache, jnp.zeros((model.cfg.vocab,), jnp.float32)),
                (tokens, jnp.arange(tokens.shape[0], dtype=jnp.int32)),
            )
            return cache, last_logits

        self._prefill_one = jax.jit(prefill_one)

    # ------------------------------------------------------------- admission
    def _free_slots(self) -> list[int]:
        return [i for i, r in enumerate(self.slot_req) if r is None]

    def _reset_slot(self, slot: int):
        """Zero one slot's rows in every cache leaf (batch dim = 1)."""
        self.cache = jax.tree.map(
            lambda a: a.at[:, slot].set(jnp.zeros_like(a[:, slot])), self.cache)

    def add_request(self, req: Request) -> bool:
        free = self._free_slots()
        if not free:
            return False
        slot = free[0]
        self._reset_slot(slot)  # recurrent families accumulate state otherwise
        toks = jnp.asarray(req.prompt, jnp.int32)
        snapshot = self.cache
        new_cache, last_logits = self._prefill_one(
            self.params, self.cache, toks, slot)
        # keep ONLY this slot's rows from the prefill — recurrent families
        # update every row per step, which would pollute live slots
        self.cache = jax.tree.map(
            lambda old, new: old.at[:, slot].set(new[:, slot]), snapshot, new_cache)
        self.pos[slot] = len(req.prompt) - 1
        self.slot_req[slot] = req
        # first generated token comes from the last prompt logits
        tok = self._sample(last_logits, req.temperature)
        req.output.append(int(tok))
        return True

    def _sample(self, logits, temperature: float):
        if temperature <= 0:
            return int(jnp.argmax(logits))
        self.key, sub = jax.random.split(self.key)
        return int(jax.random.categorical(sub, logits / temperature))

    # ------------------------------------------------------------- stepping
    def step(self):
        """One decode step for all active slots."""
        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        if not active:
            return
        toks = np.zeros((self.n_slots, 1), np.int32)
        pos_np = np.full(self.n_slots, self.max_len - 1, np.int32)  # scratch
        for i in active:
            toks[i, 0] = self.slot_req[i].output[-1]
            pos_np[i] = self.pos[i] + 1
        pos = jnp.asarray(pos_np)
        logits, self.cache = self._decode(
            self.params, self.cache, jnp.asarray(toks), pos)
        for i in active:
            self.pos[i] += 1
            req = self.slot_req[i]
            tok = self._sample(logits[i, 0], req.temperature)
            req.output.append(tok)
            if len(req.output) >= req.max_new_tokens or self.pos[i] + 2 >= self.max_len:
                req.done = True
                self.slot_req[i] = None
                self.pos[i] = -1

    def run(self, requests: list[Request]) -> list[Request]:
        return _drain(self, requests)


# --------------------------------------------------------------------------
# equivariant (force-field) serving
# --------------------------------------------------------------------------


@dataclasses.dataclass
class EquivariantRequest:
    """One molecular inference job: `steps` gradient-descent relaxation steps
    (steps=1 => a single energy/forces evaluation)."""

    species: np.ndarray           # [n] int
    pos: np.ndarray               # [n, 3]; updated in place by relaxation —
    #                               on completion it is the geometry that
    #                               produced `energy`/`forces`
    steps: int = 1
    step_size: float = 0.0        # relaxation: pos += step_size * forces
    rid: int = 0
    # filled by the engine:
    energy: float | None = None
    forces: np.ndarray | None = None
    done: bool = False


class EquivariantServeEngine:
    """Continuous batching for a MaceGaunt-style model: fixed atom-padded
    slots, one fused batched evaluation per step for every active request."""

    def __init__(self, model, params, n_slots: int = 4, max_atoms: int = 16,
                 warmup: bool = False):
        self.model = model
        self.params = params
        self.n_slots = n_slots
        self.max_atoms = max_atoms
        self.slot_req: list[Optional[EquivariantRequest]] = [None] * n_slots
        self.species = np.zeros((n_slots, max_atoms), np.int32)
        self.pos = np.asarray(self._parked(), np.float32)[None].repeat(n_slots, 0)
        self.mask = np.zeros((n_slots, max_atoms), np.float32)

        def batched(params, species, pos, mask):
            """All slots in one call: vmapped masked energy + forces."""
            def one(sp, p, m):
                e, g = jax.value_and_grad(
                    lambda pp: model.energy_masked(params, sp, pp, m))(p)
                return e, -g
            return jax.vmap(one)(species, pos, mask)

        # step inputs are fresh device buffers every step (jnp.asarray of the
        # host-side slot state), so donating them is safe on accelerators
        donate = (1, 2, 3) if jax.default_backend() != "cpu" else ()
        self._step_fn = jax.jit(batched, donate_argnums=donate)
        if warmup:
            self.warmup()

    def warmup(self) -> None:
        """Compile the fused step (and build every Gaunt chain/boundary plan
        + conversion constant behind it) on ghost-only slots, so admission
        latency for the first real request is serving cost only.  The
        compiled step — with its Fourier-resident plans — is what every
        subsequent relaxation step of every request reuses.

        With ``cfg.chain_tune='measure'`` the model's chained products
        dispatch through the engine's measured chain autotuner (DESIGN.md
        §6.4) — measurement cannot run inside the step's jit trace, so it is
        seeded here, outside jit: the many-body selfmix chain key (the only
        chain a served MaceGaunt plans — its layer-constant edge geometry
        rides boundary buckets, not chains) is measured once and the traced
        step then hits the cached selection (possibly the single-dispatch
        collocation kernel).  Both storage precisions are pre-measured
        (DESIGN.md §3.6): the config's ``compute_dtype`` AND its float32
        sibling — for ``compute_dtype='auto'`` the auto key itself times
        both and caches the winner — so the traced step hits a warm
        precision selection, never a mid-serve timing pass.  Skipped for
        ``shard_data`` configs: sharded chains pin the 'tree' backend and
        never consult the measured cache, so seeding would be pure wasted
        warmup latency.

        If a persistent autotune cache is configured (``cfg.autotune_cache``
        or $REPRO_AUTOTUNE_CACHE, see DESIGN.md §4.5), it is loaded FIRST:
        on a warm host every seeded key hits the persisted table and warmup
        performs zero timing runs — the chain measurements below become
        lookups and the whole cold-start cliff collapses to one jit compile."""
        cfg = getattr(self.model, "cfg", None)
        from repro.core import engine as _engine

        eng = _engine.get_engine()
        cache = getattr(cfg, "autotune_cache", None) if cfg is not None else None
        if cache is not None:
            eng.set_autotune_cache(cache)
        eng._maybe_load_cache()
        if (cfg is not None
                and getattr(cfg, "chain_tune", "heuristic") == "measure"
                and not getattr(cfg, "shard_data", False)):
            # mirror the traced call's key exactly: per-slot row count (the
            # step vmaps over slots, so the chain sees [max_atoms, channels]
            # leading dims per element) and the selfmix [A]*nu share pattern
            rows = self.max_atoms * cfg.channels
            dts = getattr(cfg, "compute_dtype", "float32")
            # grid-resident gate (DESIGN.md §6.5): resolve the measured
            # 'auto' policy here, outside jit — inside the step's trace an
            # unseeded select_gate key falls back to 'sh', so the policy
            # must be decided (and cached) before the step compiles.  A
            # resolved-on config additionally seeds the gate-fused chain
            # key so the traced step hits the cached gated selection.
            gg = getattr(cfg, "grid_gate", "off")
            if gg == "auto":
                gg = "on" if eng.select_gate(
                    (cfg.L,) * cfg.nu, cfg.L, dtype=dts, batch_hint=rows,
                    entry_hint=("sh",) * cfg.nu,
                    share_hint=(0,) * cfg.nu) == "grid" else "off"
            gate_opts = (False, True) if gg in ("on", "grid", True) \
                else (False,)
            for d in dict.fromkeys(["float32", dts] if dts != "auto"
                                   else ["auto"]):
                for g in gate_opts:
                    _engine.plan_chain((cfg.L,) * cfg.nu, cfg.L,
                                       tune="measure", batch_hint=rows,
                                       share_hint=(0,) * cfg.nu, dtype=d,
                                       gate=g)
        jax.block_until_ready(self._step_fn(
            self.params, jnp.asarray(self.species), jnp.asarray(self.pos),
            jnp.asarray(self.mask)))

    def _parked(self) -> np.ndarray:
        """Ghost-atom positions: distinct sites far outside any cutoff, so
        padded atoms interact with nothing (incl. each other)."""
        far = 1e4 * (1.0 + np.arange(self.max_atoms, dtype=np.float32))
        return np.stack([far, np.zeros_like(far), np.zeros_like(far)], -1)

    # ------------------------------------------------------------- admission
    def _free_slots(self) -> list[int]:
        return [i for i, r in enumerate(self.slot_req) if r is None]

    def add_request(self, req: EquivariantRequest) -> bool:
        n = len(req.species)
        if n > self.max_atoms:
            raise ValueError(f"request has {n} atoms > max_atoms={self.max_atoms}")
        free = self._free_slots()
        if not free:
            return False
        slot = free[0]
        self.species[slot] = 0
        self.species[slot, :n] = np.asarray(req.species, np.int32)
        self.pos[slot] = self._parked()
        self.pos[slot, :n] = np.asarray(req.pos, np.float32)
        self.mask[slot] = 0.0
        self.mask[slot, :n] = 1.0
        self.slot_req[slot] = req
        return True

    # ------------------------------------------------------------- stepping
    def step(self):
        """One fused evaluation for all active slots; advances relaxations
        and retires finished requests."""
        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        if not active:
            return
        e, f = self._step_fn(self.params, jnp.asarray(self.species),
                             jnp.asarray(self.pos), jnp.asarray(self.mask))
        e = np.asarray(e)
        f = np.asarray(f)
        for i in active:
            req = self.slot_req[i]
            n = len(req.species)
            req.energy = float(e[i])
            req.forces = f[i, :n].copy()
            req.pos = self.pos[i, :n].copy()  # the evaluated geometry
            req.steps -= 1
            if req.steps <= 0:
                req.done = True
                self.slot_req[i] = None
                self.mask[i] = 0.0
            else:  # relaxation: steepest descent on the masked energy
                self.pos[i, :n] += req.step_size * f[i, :n]

    def run(self, requests: list[EquivariantRequest]) -> list[EquivariantRequest]:
        return _drain(self, requests)
