"""Serve observability (DESIGN.md §10.4).

One `ServeMetrics` instance rides along an engine and its scheduler/pools:

- **per-request latency** — queue wait (submit→admit), service
  (admit→complete), and total (submit→complete), kept as raw second lists so
  any percentile can be asked for after the fact (`percentile`, `p50`/`p99`);
- **per-step gauges** — slot occupancy (active/total slots at each dispatched
  step) and padding waste (real atoms vs padded atom-slots the step actually
  computed on), both per pool and aggregated;
- **counters** — submissions, admissions, completions, structured rejections
  (`rejected:<reason>`), steps, early host-side stagings (the async-pipelining
  overlap hits);
- **fault tolerance** (DESIGN.md §11) — step failures by kind
  (`step_failures:<kind>`), per-request retries, non-finite slot
  quarantines and bisect passes, replica failovers/restarts and requeued
  in-flight requests, straggler flags (a capped `StragglerMonitor` rides
  along), and time-to-recovery samples (failure detected → first successful
  step afterwards) with p50/p99 in `summary()`;
- **engine surfacing** — `summary()` snapshots the Gaunt engine's
  `timing_runs` counter and the `repro.core.rep` basis-conversion counters,
  so a serve deployment can see mid-traffic autotune timing passes (there
  must be none after warmup) and interior conversion regressions without
  instrumenting the model.

Everything is plain host-side Python (no device work, no locks — the serving
loop is single-threaded by design); a fake clock can be injected for tests.
"""
from __future__ import annotations

import collections
import time
from typing import Optional

from repro.distributed.fault_tolerance import StragglerMonitor

__all__ = ["ServeMetrics", "percentile"]


def percentile(xs, p: float) -> float:
    """Linear-interpolated percentile of a sequence (p in [0, 100])."""
    if not xs:
        return 0.0
    s = sorted(xs)
    if len(s) == 1:
        return float(s[0])
    rank = (p / 100.0) * (len(s) - 1)
    lo = int(rank)
    hi = min(lo + 1, len(s) - 1)
    frac = rank - lo
    return float(s[lo] * (1.0 - frac) + s[hi] * frac)


class ServeMetrics:
    """Mutable metrics sink shared by a serve engine, its scheduler, and its
    slot pools.  All observation methods are cheap appends/increments."""

    def __init__(self, clock=time.monotonic):
        self.clock = clock
        self.counters: collections.Counter = collections.Counter()
        # latency samples (seconds)
        self.queue_wait_s: list[float] = []
        self.service_s: list[float] = []
        self.total_s: list[float] = []
        self.step_s: list[float] = []
        # per-step gauge samples
        self.occupancy: list[tuple[int, int]] = []   # (active, n_slots)
        self.atoms_real = 0        # sum over steps of real atoms evaluated
        self.atoms_padded = 0      # sum over steps of padded atom-slots
        self.per_pool: dict[str, collections.Counter] = \
            collections.defaultdict(collections.Counter)
        # fault tolerance (DESIGN.md §11): time-to-recovery samples, the
        # completion sequence (failover ordering proofs read it), and a
        # capped straggler monitor fed by every observed step duration
        self.recovery_s: list[float] = []
        self.completed_order: collections.deque = collections.deque(
            maxlen=10_000)
        self.straggler = StragglerMonitor()

    def reset(self) -> None:
        """Zero every counter/sample (the load generator reuses one warmed
        engine across sweep points; compiled steps survive, numbers don't)."""
        self.counters.clear()
        self.queue_wait_s.clear()
        self.service_s.clear()
        self.total_s.clear()
        self.step_s.clear()
        self.occupancy.clear()
        self.atoms_real = self.atoms_padded = 0
        self.per_pool.clear()
        self.recovery_s.clear()
        self.completed_order.clear()
        self.straggler = StragglerMonitor()

    # ------------------------------------------------------------ lifecycle
    def observe_submit(self, req, now: Optional[float] = None) -> None:
        req._submit_t = self.clock() if now is None else now
        self.counters["submitted"] += 1

    def observe_admit(self, req, now: Optional[float] = None) -> None:
        now = self.clock() if now is None else now
        req._admit_t = now
        sub = getattr(req, "_submit_t", None)
        if sub is not None:
            self.queue_wait_s.append(now - sub)
        self.counters["admitted"] += 1

    def observe_reject(self, req, reason: str) -> None:
        self.counters["rejected"] += 1
        self.counters[f"rejected:{reason}"] += 1

    def observe_complete(self, req, now: Optional[float] = None) -> None:
        now = self.clock() if now is None else now
        sub = getattr(req, "_submit_t", None)
        adm = getattr(req, "_admit_t", None)
        if sub is not None:
            self.total_s.append(now - sub)
        if adm is not None:
            self.service_s.append(now - adm)
        self.counters["completed"] += 1
        self.completed_order.append(getattr(req, "rid", None))

    # ------------------------------------------------------------ stepping
    def observe_step(self, pool: str, active: int, n_slots: int,
                     real_atoms: int, padded_atoms: int,
                     dur_s: float) -> None:
        self.counters["steps"] += 1
        self.step_s.append(dur_s)
        self.occupancy.append((active, n_slots))
        self.atoms_real += real_atoms
        self.atoms_padded += padded_atoms
        pc = self.per_pool[pool]
        pc["steps"] += 1
        pc["active_slots"] += active
        pc["atoms_real"] += real_atoms
        pc["atoms_padded"] += padded_atoms
        if self.straggler.record(self.counters["steps"], dur_s):
            self.counters["straggler_steps"] += 1
            pc["straggler_steps"] += 1

    # ------------------------------------------------------ fault tolerance
    def observe_step_failure(self, pool: str, kind: str) -> None:
        """A pool step raised, timed out, or returned unusable results and
        entered recovery (host-state rebuild + per-request retry)."""
        self.counters["step_failures"] += 1
        self.counters[f"step_failures:{kind}"] += 1
        self.per_pool[pool]["step_failures"] += 1

    def observe_retry(self, pool: str, kind: str) -> None:
        """One request re-queued in its slot for another attempt (restarted
        from its admission geometry snapshot — retry is idempotent)."""
        self.counters["retries"] += 1
        self.counters[f"retries:{kind}"] += 1
        self.per_pool[pool]["retries"] += 1

    def observe_quarantine(self, pool: str) -> None:
        """One slot's results were non-finite and ONLY that slot was pulled
        from the step's retirements (bucket-mates keep their numbers)."""
        self.counters["quarantined"] += 1
        self.per_pool[pool]["quarantined"] += 1

    def observe_bisect(self, pool: str, evals: int) -> None:
        """A collectively non-finite batch was bisected into per-slot
        verdicts (``evals`` extra sub-batch evaluations)."""
        self.counters["nonfinite_bisects"] += 1
        self.counters["nonfinite_bisect_evals"] += evals
        self.per_pool[pool]["nonfinite_bisects"] += 1

    def observe_recovery(self, dur_s: float) -> None:
        """Time-to-recovery: first failure detection in a pool → its next
        successful step (includes retry backoff, honest end-to-end)."""
        self.recovery_s.append(dur_s)

    def observe_failover(self, replica, reason: str, n_requeued: int) -> None:
        self.counters["failovers"] += 1
        self.counters[f"failovers:{reason}"] += 1
        self.counters["requeued_on_failover"] += n_requeued

    def observe_restart(self, replica) -> None:
        self.counters["replica_restarts"] += 1

    def observe_staged_early(self, pool: str) -> None:
        """A pool's next-step tensors were staged on the host while another
        step was in flight on the device (the pipelining overlap win)."""
        self.counters["staged_early"] += 1
        self.per_pool[pool]["staged_early"] += 1

    # ------------------------------------------------------------ derived
    def padding_efficiency(self) -> float:
        """Real atoms / padded atom-slots over every dispatched step — 1.0
        means no ghost-atom compute at all; a 12-atom molecule padded into a
        256-atom slot scores 0.047."""
        if self.atoms_padded == 0:
            return 1.0
        return self.atoms_real / self.atoms_padded

    def occupancy_mean(self) -> float:
        if not self.occupancy:
            return 0.0
        return sum(a for a, _ in self.occupancy) / \
            max(1, sum(n for _, n in self.occupancy))

    def summary(self) -> dict:
        """One flat dict for logging / bench records — latency percentiles,
        gauges, counters, and the engine-side counters (autotune timing runs
        and basis-conversion totals) snapshotted at call time."""
        out = {
            "submitted": self.counters["submitted"],
            "admitted": self.counters["admitted"],
            "completed": self.counters["completed"],
            "rejected": self.counters["rejected"],
            "steps": self.counters["steps"],
            "staged_early": self.counters["staged_early"],
            "queue_wait_p50_ms": percentile(self.queue_wait_s, 50) * 1e3,
            "queue_wait_p99_ms": percentile(self.queue_wait_s, 99) * 1e3,
            "latency_p50_ms": percentile(self.total_s, 50) * 1e3,
            "latency_p99_ms": percentile(self.total_s, 99) * 1e3,
            "step_p50_ms": percentile(self.step_s, 50) * 1e3,
            "step_p99_ms": percentile(self.step_s, 99) * 1e3,
            "occupancy_mean": self.occupancy_mean(),
            "padding_efficiency": self.padding_efficiency(),
            # fault tolerance (DESIGN.md §11)
            "step_failures": self.counters["step_failures"],
            "retries": self.counters["retries"],
            "quarantined": self.counters["quarantined"],
            "nonfinite_bisects": self.counters["nonfinite_bisects"],
            "failovers": self.counters["failovers"],
            "replica_restarts": self.counters["replica_restarts"],
            "requeued_on_failover": self.counters["requeued_on_failover"],
            "straggler_steps": self.straggler.total_flagged,
            "recovery_p50_ms": percentile(self.recovery_s, 50) * 1e3,
            "recovery_p99_ms": percentile(self.recovery_s, 99) * 1e3,
        }
        for name, pc in self.per_pool.items():
            out[f"pool:{name}:steps"] = pc["steps"]
            if pc["atoms_padded"]:
                out[f"pool:{name}:padding_efficiency"] = \
                    pc["atoms_real"] / pc["atoms_padded"]
        for k, v in self.counters.items():
            if k.startswith(("rejected:", "step_failures:", "retries:",
                             "failovers:")):
                out[k] = v
        # engine-side counters: mid-serve timing passes (should be zero on a
        # warm host) and interior basis conversions
        try:
            from repro.core import engine as _engine
            from repro.core import rep as _rep

            out["engine_timing_runs"] = _engine.get_engine().timing_runs
            out["conversions"] = dict(_rep.conversion_stats())
        except Exception:  # pragma: no cover - engine import must not break
            pass
        return out
