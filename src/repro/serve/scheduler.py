"""Admission and deadline scheduling for the serving engines (DESIGN.md §10.1).

The continuous-batching discipline that used to live as a private `_drain`
loop inside ``serve/engine.py`` — admit while capacity is free, step until
everything drains — extracted and grown into a real scheduler shared by the
LM ``ServeEngine`` and the force-field ``EquivariantServeEngine``:

- **priority queue** — requests carry ``priority`` (lower value = more
  urgent) and are admitted in strict priority order, FIFO within a priority
  class.  A request whose capacity target is full (e.g. its size bucket has
  no free slot) is skipped WITHOUT blocking later requests that fit
  elsewhere — only same-destination requests behind it keep their FIFO
  position relative to it.
- **deadlines** — ``deadline`` is seconds of allowed queue wait from
  submission; a request still queued past it is **rejected with a
  structured reason** (``reject_reason='deadline_expired'``) instead of
  being silently padded into a batch whose result nobody is waiting for.
- **structured rejection** — admission-time validation failures (engine
  ``validate``: NaN geometry, zero step budgets, oversized molecules) mark
  the request ``rejected=True, reject_reason=...`` and complete it
  immediately; they never occupy a slot or poison a shared batched step.
- **overlap admission** — ``Scheduler.pump`` passes its own admission pass
  as the engine step's ``overlap`` callback, so queue pops, validation, and
  host-side slot writes for the NEXT step run while the CURRENT step's
  device computation is in flight (DESIGN.md §10.3).

Engines plug in through a four-method protocol: ``validate(req)``,
``try_admit(req)``, ``has_active()``, ``step(overlap=None)``.  The clock is
injectable (tests drive deadlines with a fake clock).
"""
from __future__ import annotations

import heapq
import time
from typing import Callable, Optional

__all__ = ["AdmissionQueue", "Scheduler",
           "REASON_DEADLINE", "REASON_INVALID", "REASON_TOO_LARGE"]

REASON_DEADLINE = "deadline_expired"
REASON_INVALID = "invalid"
REASON_TOO_LARGE = "too_large"


def _deadline_expired(req, now: float) -> bool:
    dl = getattr(req, "deadline", None)
    sub = getattr(req, "_submit_t", None)
    return dl is not None and sub is not None and (now - sub) > dl


class AdmissionQueue:
    """Priority admission queue: strict ``priority`` (lower first), FIFO
    within a priority class (stable sequence numbers), deadline expiry."""

    def __init__(self, clock=time.monotonic):
        self._clock = clock
        self._heap: list = []      # (priority, seq, req)
        self._seq = 0

    def __len__(self) -> int:
        return len(self._heap)

    def submit(self, req, now: Optional[float] = None) -> None:
        now = self._clock() if now is None else now
        if getattr(req, "_submit_t", None) is None:
            req._submit_t = now
        req._seq = self._seq
        self._seq += 1
        heapq.heappush(self._heap,
                       (getattr(req, "priority", 0), req._seq, req))

    def requeue(self, req) -> None:
        """Put a popped-but-unadmittable request back at its ORIGINAL
        position (same priority, same sequence number): a full bucket must
        not cost a request its FIFO standing."""
        heapq.heappush(self._heap,
                       (getattr(req, "priority", 0), req._seq, req))

    def expire(self, now: Optional[float] = None) -> list:
        """Remove and return every queued request whose deadline has passed
        (the caller marks them rejected).  O(n) heap rebuild — admission
        queues are small next to a device step."""
        now = self._clock() if now is None else now
        expired = [r for _, _, r in self._heap if _deadline_expired(r, now)]
        if expired:
            self._heap = [e for e in self._heap
                          if not _deadline_expired(e[2], now)]
            heapq.heapify(self._heap)
        return expired

    def pop(self) -> Optional[object]:
        """Next request in (priority, FIFO) order, or None."""
        if not self._heap:
            return None
        return heapq.heappop(self._heap)[2]


class Scheduler:
    """Continuous-batching drain over an engine's admission protocol.

    ``run(requests)`` is the closed-loop entry (submit everything, drain);
    open-loop load generators submit as arrivals happen and call ``pump()``
    per iteration (benchmarks/bench_serve.py).
    """

    def __init__(self, engine, clock=time.monotonic, metrics=None):
        self.engine = engine
        self.clock = clock
        self.queue = AdmissionQueue(clock)
        self.metrics = metrics if metrics is not None \
            else getattr(engine, "metrics", None)
        # engines that re-submit work (a ReplicaSet failing over a cordoned
        # replica's in-flight requests) need the queue to requeue into
        attach = getattr(engine, "attach_queue", None)
        if attach is not None:
            attach(self.queue)

    # ------------------------------------------------------------ admission
    def submit(self, req) -> None:
        now = self.clock()
        if self.metrics is not None:
            self.metrics.observe_submit(req, now)
        self.queue.submit(req, now)

    def _reject(self, req, reason: str, detail: str = "") -> None:
        req.rejected = True
        req.reject_reason = f"{reason}:{detail}" if detail else reason
        req.done = True
        if self.metrics is not None:
            self.metrics.observe_reject(req, reason)

    def admit_ready(self) -> int:
        """One admission pass: expire stale requests, then admit everything
        that fits right now, in (priority, FIFO) order.  Requests whose
        destination is full are requeued at their original position.

        Touches only host state (queue bookkeeping + slot-array writes), so
        the engine step may safely run it as the ``overlap`` callback while
        a device step is in flight.  Returns the number admitted."""
        now = self.clock()
        for req in self.queue.expire(now):
            self._reject(req, REASON_DEADLINE,
                         f"queued {now - req._submit_t:.3f}s > "
                         f"deadline {req.deadline}s")
        admitted = 0
        blocked: list = []
        while True:
            req = self.queue.pop()
            if req is None:
                break
            if _deadline_expired(req, now):
                self._reject(req, REASON_DEADLINE)
                continue
            err = self.engine.validate(req)
            if err is not None:
                reason, detail = err if isinstance(err, tuple) else (err, "")
                self._reject(req, reason, detail)
                continue
            if self.engine.try_admit(req):
                admitted += 1
                if self.metrics is not None:
                    self.metrics.observe_admit(req, self.clock())
            else:
                blocked.append(req)
        for req in blocked:
            self.queue.requeue(req)
        return admitted

    # ------------------------------------------------------------ stepping
    def pump(self, poll: Optional[Callable[[], None]] = None) -> bool:
        """One scheduling iteration: admit what fits, then step the engine —
        handing `admit_ready` (plus the optional ``poll`` arrival hook) to
        the step as its overlap callback, so the next batch is built while
        the device computes the current one.  True while work remains."""
        def overlap():
            if poll is not None:
                poll()
            self.admit_ready()

        overlap()
        if self.engine.has_active():
            self.engine.step(overlap=overlap)
        return bool(len(self.queue)) or self.engine.has_active()

    def drain(self) -> None:
        while len(self.queue) or self.engine.has_active():
            made_progress = self.admit_ready() > 0
            if self.engine.has_active():
                self.engine.step(overlap=self.admit_ready)
            elif not made_progress and len(self.queue):
                # nothing running, nothing admitted, queue non-empty: every
                # queued request is unschedulable against an idle engine —
                # a validator hole, not a transient.  Reject rather than spin.
                req = self.queue.pop()
                self._reject(req, REASON_INVALID, "unschedulable on an idle engine")

    def run(self, requests: list) -> list:
        """Closed loop: submit everything, drain, hand the list back (each
        request is completed or structurally rejected in place)."""
        for r in requests:
            self.submit(r)
        self.drain()
        return requests
