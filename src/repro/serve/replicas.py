"""Replica failover for serving (DESIGN.md §11.3).

A `ReplicaSet` runs N serve engines behind ONE Scheduler-compatible facade
(`validate`/`try_admit`/`has_active`/`step`), so the admission queue,
deadline handling, and metrics above it are exactly the single-engine
stack.  Health-checking reuses `distributed/fault_tolerance.py`: every
replica writes a `Heartbeat` file after each clean step (the cluster
health-checker idiom — staleness is judged by re-READING the file, so an
external prober sees the same signal), and a per-replica `StragglerMonitor`
tracks its step durations.

Failure handling:

- a replica whose pools keep failing (``max_fail_streak`` consecutive
  stepped rounds with new failures and no clean progress) or whose
  heartbeat file has gone stale (``stale_after_s``) is **cordoned**: its
  in-flight requests are pulled out restored to their admission snapshots
  (`SlotPool.evict`) and re-submitted to the survivors through
  `AdmissionQueue.requeue` — the ORIGINAL ``_seq`` is preserved, so
  failover costs a request none of its (priority, FIFO) standing;
- a cordoned replica is **restarted** after an exponential backoff (the
  `PreemptionGuard` supervisor idiom: same engine object — its host slot
  arrays and compiled steps survive — fresh health state, forced heartbeat);
- while ANY replica is cordoned the set reports ``has_active() == True``,
  so the scheduler's drain keeps pumping (and keeps reaching the restart
  check) instead of mis-rejecting queued work against a temporarily
  shrunken fleet.

The factory receives ``(idx, metrics)`` and must tag its engine
``tag=f"replica{idx}"`` if fault plans are to target one replica by scope
(`serve/faults.py`); the shared `ServeMetrics` sink keeps the aggregate
picture while per-replica failure attribution reads each engine's own pool
counters (`SlotPool.failures`), which a shared sink cannot split.
"""
from __future__ import annotations

import json
import tempfile
import time
from typing import Optional

from repro.distributed.fault_tolerance import Heartbeat, StragglerMonitor

from .metrics import ServeMetrics
from .scheduler import Scheduler

__all__ = ["ReplicaSet"]


class _Replica:
    """One engine plus its health state (internal to `ReplicaSet`)."""

    def __init__(self, idx: int, engine, heartbeat_path: str):
        self.idx = idx
        self.name = f"replica{idx}"
        self.engine = engine
        self.heartbeat = Heartbeat(heartbeat_path, interval_s=0.0)
        self.straggler = StragglerMonitor()
        self.live = True
        self.fail_streak = 0       # stepped rounds with failures, no progress
        self.restarts = 0
        self.restart_at = 0.0      # injectable-clock time of next restart try
        self.steps = 0             # rounds this replica was stepped
        self._last_failures = 0    # pool-failure counter at last health check
        self._last_steps_run = 0   # pool steps_run counter at last check


class ReplicaSet:
    """N serve engines behind one Scheduler-compatible facade, with
    cordon/requeue/restart failover.

    Parameters
    ----------
    factory:          ``factory(idx, metrics) -> engine`` building one
                      replica's engine against the SHARED metrics sink
                      (engines must support ``evict_active`` — the
                      force-field `EquivariantServeEngine` does).
    n_replicas:       fleet size.
    metrics:          shared `ServeMetrics` (created if None).
    clock:            injectable clock for scheduling/backoff (heartbeat
                      staleness uses wall time — the file format is
                      ``time.time`` based, shared with cluster probers).
    max_fail_streak:  consecutive failing rounds before cordoning.
    stale_after_s:    heartbeat-file age (seconds of wall time) past which
                      a replica is cordoned; None disables the check.
    restart_backoff_s: base of the exponential restart backoff.
    heartbeat_dir:    where heartbeat files live (a TemporaryDirectory is
                      created — and kept alive — if None).
    """

    def __init__(self, factory, n_replicas: int = 2, metrics=None,
                 clock=time.monotonic, max_fail_streak: int = 3,
                 stale_after_s: float | None = None,
                 restart_backoff_s: float = 1e-3,
                 heartbeat_dir: str | None = None):
        self.clock = clock
        self.metrics = metrics if metrics is not None \
            else ServeMetrics(clock=clock)
        self.max_fail_streak = max(1, int(max_fail_streak))
        self.stale_after_s = stale_after_s
        self.restart_backoff_s = restart_backoff_s
        if heartbeat_dir is None:
            self._tmpdir = tempfile.TemporaryDirectory(prefix="repro_hb_")
            heartbeat_dir = self._tmpdir.name
        self.replicas: list[_Replica] = []
        for i in range(n_replicas):
            r = _Replica(i, factory(i, self.metrics),
                         f"{heartbeat_dir}/replica{i}.json")
            r.heartbeat.beat(0, force=True)   # the file must exist to age
            self.replicas.append(r)
        self._queue = None          # AdmissionQueue, via attach_queue
        self._orphans: list = []    # evicted requests with no queue to rejoin

    # ---------------------------------------------------- scheduler protocol
    def attach_queue(self, queue) -> None:
        """Called by `Scheduler.__init__`: failover requeues go here."""
        self._queue = queue

    def validate(self, req):
        # validation is host-side and replica-independent: any engine's rules
        return self.replicas[0].engine.validate(req)

    def try_admit(self, req) -> bool:
        """Admit into the least-loaded LIVE replica that has room."""
        live = [r for r in self.replicas if r.live]
        for r in sorted(live, key=lambda r: (self._load(r), r.idx)):
            if r.engine.try_admit(req):
                req._replica = r.idx
                return True
        return False

    def has_active(self) -> bool:
        """Work in flight on a live replica, evicted requests awaiting
        re-admission, or queued work held up by a cordoned replica (the
        fleet will grow back — that work is schedulable, not invalid, so
        the scheduler's drain must keep pumping instead of mis-rejecting
        it; with no queued work a cordoned replica does NOT hold the set
        active — it restarts on the next round that needs it)."""
        if any(r.live and r.engine.has_active() for r in self.replicas) \
                or self._orphans:
            return True
        return (any(not r.live for r in self.replicas)
                and self._queue is not None and len(self._queue) > 0)

    def step(self, overlap=None) -> None:
        """One fleet round: restart checks, health checks, then one engine
        step per live replica (the scheduler's overlap callback runs with
        the first stepped replica, as in the single-engine stack)."""
        for r in self.replicas:
            if not r.live:
                self._maybe_restart(r)
        self._readmit_orphans()
        for r in self.replicas:
            if r.live and self._heartbeat_stale(r):
                self._cordon(r, "heartbeat_stale")
        stepped_overlap = False
        for r in self.replicas:
            if not r.live or not r.engine.has_active():
                continue
            t0 = self.clock()
            r.engine.step(overlap=None if stepped_overlap else overlap)
            stepped_overlap = True
            r.steps += 1
            r.straggler.record(r.steps, self.clock() - t0)
            self._health_check(r)
        if overlap is not None and not stepped_overlap:
            overlap()   # admissions must still run while the fleet is idle

    def run(self, requests: list) -> list:
        return Scheduler(self, clock=self.clock).run(requests)

    # ------------------------------------------------------------- internals
    @staticmethod
    def _load(r: _Replica) -> int:
        pools = getattr(r.engine, "pools", None)
        if pools is None:
            return 0
        return sum(p.n_active() for p in pools)

    @staticmethod
    def _fail_count(r: _Replica) -> int:
        return sum(p.failures for p in getattr(r.engine, "pools", ()))

    @staticmethod
    def _steps_run(r: _Replica) -> int:
        return sum(p.steps_run for p in getattr(r.engine, "pools", ()))

    def _health_check(self, r: _Replica) -> None:
        """Post-step verdict from the replica's own pool counters (the
        shared metrics sink cannot attribute failures per replica)."""
        failures = self._fail_count(r)
        steps_run = self._steps_run(r)
        new_failures = failures - r._last_failures
        progressed = steps_run > r._last_steps_run
        r._last_failures = failures
        r._last_steps_run = steps_run
        if new_failures > 0:
            r.fail_streak += 1
            if r.fail_streak >= self.max_fail_streak:
                self._cordon(r, "step_failures")
        elif progressed:
            # a clean, advancing round: healthy — beat the heartbeat file
            # (a cooldown no-op round proves nothing either way)
            r.fail_streak = 0
            r.heartbeat.beat(steps_run, force=True)

    def _heartbeat_stale(self, r: _Replica) -> bool:
        if self.stale_after_s is None:
            return False
        try:
            with open(r.heartbeat.path) as f:
                t = json.load(f)["t"]
        except (OSError, ValueError, KeyError):
            return True           # unreadable health file = unhealthy
        return time.time() - t > self.stale_after_s

    def _cordon(self, r: _Replica, reason: str) -> None:
        """Pull the replica out of rotation: evict its in-flight requests
        (restored to admission snapshots) back onto the queue at their
        original (priority, _seq) standing, schedule a backed-off restart."""
        r.live = False
        r.fail_streak = 0
        r.restart_at = self.clock() + self.restart_backoff_s * \
            (2.0 ** min(r.restarts, 6))
        evicted = r.engine.evict_active() \
            if hasattr(r.engine, "evict_active") else []
        for req in evicted:
            if self._queue is not None and hasattr(req, "_seq"):
                self._queue.requeue(req)
            else:
                self._orphans.append(req)
        self.metrics.observe_failover(r.name, reason, len(evicted))

    def _maybe_restart(self, r: _Replica) -> None:
        if self.clock() < r.restart_at:
            return
        # supervisor restart: same engine (host slot arrays and compiled
        # steps survive the cordon), fresh health state, forced heartbeat
        r.live = True
        r.restarts += 1
        r.fail_streak = 0
        r._last_failures = self._fail_count(r)
        r._last_steps_run = self._steps_run(r)
        r.heartbeat.beat(r._last_steps_run, force=True)
        self.metrics.observe_restart(r.name)

    def _readmit_orphans(self) -> None:
        if not self._orphans:
            return
        still: list = []
        for req in self._orphans:
            if not self.try_admit(req):
                still.append(req)
        self._orphans = still
