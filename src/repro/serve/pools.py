"""Size-bucketed slot pools for force-field serving (DESIGN.md §10.2).

The single fixed-``max_atoms`` slot array that `EquivariantServeEngine`
carried since PR 2 padded EVERY molecule to the worst case: a 12-atom
molecule in a 256-atom deployment paid 256-atom pair geometry, convolution,
and many-body products.  A `SlotPool` is that slot array scoped to one
atom-count bucket — its own host arrays, its own ghost-atom parking, and its
OWN jitted step function compiled for its own ``[n_slots, max_atoms]``
shapes — and `BucketedPools` is the small/medium/large ladder: a request is
routed to the smallest bucket it fits (`select`), so padding waste is
bounded by the bucket ladder instead of the deployment maximum.

Per-bucket compilation is lazy (a bucket that never sees traffic never
compiles — counter-proven in tests/test_serve_scheduler.py) and per-bucket
warmup is explicit: `EquivariantServeEngine.warmup()` seeds each bucket's
measured chain/gate autotune keys at that bucket's own row count
(``max_atoms * channels`` — the batch_hint the traced step actually sees)
and compiles each step on ghost-only slots.

Async host↔device pipelining (DESIGN.md §10.3) lives in the
`begin_step`/`finish_step` split: `begin_step` uploads the staged slot
tensors and dispatches the jitted step — JAX dispatch is asynchronous, so
the call returns an in-flight handle while the device computes — and
`finish_step` blocks, retires finished requests, and advances relaxations.
Between the two, the engine runs the scheduler's admission pass and
pre-stages other pools' tensors (`stage`), overlapping `jnp.asarray` +
bookkeeping with device compute.  A pool whose host state did not change
since the last upload reuses its staged device tensors (skipped when the
step donates its inputs — donation consumes them).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["BucketSpec", "SlotPool", "BucketedPools", "default_buckets"]


@dataclasses.dataclass(frozen=True)
class BucketSpec:
    """One size bucket: molecules with ``n <= max_atoms`` atoms may land in
    any of its ``n_slots`` slots."""
    max_atoms: int
    n_slots: int = 4
    name: str = ""

    def label(self) -> str:
        return self.name or f"b{self.max_atoms}"


def default_buckets(max_atoms: int, n_slots: int = 4,
                    ladder=(4, 2, 1)) -> tuple[BucketSpec, ...]:
    """A small/medium/large ladder under a deployment cap: bucket sizes
    ``max_atoms // f`` for each ladder divisor (deduplicated, floor 2).
    ``default_buckets(256)`` -> 64/128/256; tiny caps collapse to fewer
    buckets (``default_buckets(4)`` is a single bucket)."""
    names = {0: "small", 1: "medium", 2: "large"}
    sizes = sorted({max(2, max_atoms // f) for f in ladder})
    n = len(sizes)
    return tuple(
        BucketSpec(sz, n_slots, names.get(i + (3 - n), f"b{sz}"))
        for i, sz in enumerate(sizes))


class _Inflight:
    """Handle for a dispatched-but-unfinished pool step."""
    __slots__ = ("active", "energy", "forces", "t0")

    def __init__(self, active, energy, forces, t0):
        self.active = active
        self.energy = energy
        self.forces = forces
        self.t0 = t0


class SlotPool:
    """Fixed atom-padded slots for ONE size bucket, with the bucket's own
    compiled step function (vmapped masked energy + forces over slots)."""

    def __init__(self, model, params, spec: BucketSpec, metrics=None,
                 clock=time.monotonic):
        self.model = model
        self.params = params
        self.spec = spec
        self.metrics = metrics
        self.clock = clock
        n_slots, max_atoms = spec.n_slots, spec.max_atoms
        self.slot_req: list[Optional[object]] = [None] * n_slots
        self.species = np.zeros((n_slots, max_atoms), np.int32)
        self.pos = np.asarray(self._parked(), np.float32)[None] \
            .repeat(n_slots, 0)
        self.mask = np.zeros((n_slots, max_atoms), np.float32)
        self.steps_run = 0

        def batched(params, species, pos, mask):
            """All slots in one call: vmapped masked energy + forces."""
            def one(sp, p, m):
                e, g = jax.value_and_grad(
                    lambda pp: model.energy_masked(params, sp, pp, m))(p)
                return e, -g
            return jax.vmap(one)(species, pos, mask)

        # step inputs are fresh device buffers every step on accelerators
        # (donation consumes them, so the staged-tensor reuse below is a
        # CPU-only economy); on CPU nothing is donated and clean staged
        # tensors survive across steps
        self._donate = jax.default_backend() != "cpu"
        donate = (1, 2, 3) if self._donate else ()
        self._step_fn = jax.jit(batched, donate_argnums=donate)
        self._staged = None          # (species_dev, pos_dev, mask_dev)
        self._dirty = True

    # ------------------------------------------------------------ queries
    def compiled(self) -> bool:
        """Whether this bucket's step function has ever compiled — the
        no-cross-bucket-compile counter-proof hooks in here."""
        return self._step_fn._cache_size() > 0

    def fits(self, n_atoms: int) -> bool:
        return n_atoms <= self.spec.max_atoms

    def free_slots(self) -> list[int]:
        return [i for i, r in enumerate(self.slot_req) if r is None]

    def n_active(self) -> int:
        return sum(1 for r in self.slot_req if r is not None)

    # ------------------------------------------------------------ slots
    def _parked(self) -> np.ndarray:
        """Ghost-atom positions: distinct sites far outside any cutoff, so
        padded atoms interact with nothing (incl. each other)."""
        far = 1e4 * (1.0 + np.arange(self.spec.max_atoms, dtype=np.float32))
        return np.stack([far, np.zeros_like(far), np.zeros_like(far)], -1)

    def admit(self, req) -> bool:
        """Place a (validated, fitting) request into a free slot; host-side
        writes only — safe while a step for the CURRENT slot contents is in
        flight (the step read its own device copies at dispatch)."""
        free = self.free_slots()
        if not free:
            return False
        n = len(req.species)
        slot = free[0]
        self.species[slot] = 0
        self.species[slot, :n] = np.asarray(req.species, np.int32)
        self.pos[slot] = self._parked()
        self.pos[slot, :n] = np.asarray(req.pos, np.float32)
        self.mask[slot] = 0.0
        self.mask[slot, :n] = 1.0
        self.slot_req[slot] = req
        self._dirty = True
        return True

    # ------------------------------------------------------------ stepping
    def stage(self, early: bool = False) -> None:
        """Upload the slot arrays to the device if they changed since the
        last upload.  Called with ``early=True`` from the pipelining overlap
        window (another pool's step in flight) — counted so the overlap is
        observable, not just asserted."""
        if self._staged is not None and not self._dirty:
            return
        self._staged = (jnp.asarray(self.species), jnp.asarray(self.pos),
                        jnp.asarray(self.mask))
        self._dirty = False
        if early and self.metrics is not None:
            self.metrics.observe_staged_early(self.spec.label())

    def warmup_compile(self) -> None:
        """Compile this bucket's step on its current (ghost-only at boot)
        slot contents, blocking until done — the per-bucket half of
        `EquivariantServeEngine.warmup()`."""
        self.stage()
        sp, p, m = self._staged
        if self._donate:
            self._staged = None
        jax.block_until_ready(self._step_fn(self.params, sp, p, m))

    def begin_step(self) -> Optional[_Inflight]:
        """Dispatch one fused evaluation of every active slot; returns an
        in-flight handle (device compute proceeds asynchronously)."""
        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        if not active:
            return None
        self.stage()
        sp, p, m = self._staged
        if self._donate:
            self._staged = None          # donated — never touch again
        t0 = self.clock()
        e, f = self._step_fn(self.params, sp, p, m)
        return _Inflight(active, e, f, t0)

    def finish_step(self, h: _Inflight) -> list:
        """Block on the in-flight step, retire finished requests, advance
        relaxations.  Returns the requests completed by this step."""
        e = np.asarray(h.energy)       # blocks until the device finishes
        f = np.asarray(h.forces)
        dur = self.clock() - h.t0
        self.steps_run += 1
        completed = []
        real_atoms = sum(len(self.slot_req[i].species) for i in h.active)
        if self.metrics is not None:
            self.metrics.observe_step(
                self.spec.label(), active=len(h.active),
                n_slots=self.spec.n_slots, real_atoms=real_atoms,
                padded_atoms=len(h.active) * self.spec.max_atoms,
                dur_s=dur)
        for i in h.active:
            req = self.slot_req[i]
            n = len(req.species)
            req.energy = float(e[i])
            req.forces = f[i, :n].copy()
            req.pos = self.pos[i, :n].copy()  # the evaluated geometry
            req.steps -= 1
            if req.steps <= 0:
                req.done = True
                self.slot_req[i] = None
                self.mask[i] = 0.0
                self._dirty = True
                completed.append(req)
                if self.metrics is not None:
                    self.metrics.observe_complete(req, self.clock())
            elif req.step_size != 0.0:
                # relaxation: steepest descent on the masked energy
                self.pos[i, :n] += req.step_size * f[i, :n]
                self._dirty = True
        return completed


class BucketedPools:
    """The bucket ladder: pools sorted by ``max_atoms`` ascending; a request
    routes to the smallest bucket that fits it."""

    def __init__(self, model, params, specs, metrics=None,
                 clock=time.monotonic):
        specs = sorted(specs, key=lambda s: s.max_atoms)
        if len({s.max_atoms for s in specs}) != len(specs):
            raise ValueError(f"duplicate bucket sizes: {specs}")
        self.pools = [SlotPool(model, params, s, metrics=metrics,
                               clock=clock) for s in specs]

    def __iter__(self):
        return iter(self.pools)

    def __len__(self) -> int:
        return len(self.pools)

    @property
    def max_atoms(self) -> int:
        return self.pools[-1].spec.max_atoms

    def select(self, n_atoms: int) -> Optional[SlotPool]:
        """Smallest bucket with ``max_atoms >= n_atoms``; None if the
        request exceeds even the largest bucket."""
        for p in self.pools:
            if p.fits(n_atoms):
                return p
        return None

    def has_active(self) -> bool:
        return any(p.n_active() for p in self.pools)
