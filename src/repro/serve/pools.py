"""Size-bucketed slot pools for force-field serving (DESIGN.md §10.2).

The single fixed-``max_atoms`` slot array that `EquivariantServeEngine`
carried since PR 2 padded EVERY molecule to the worst case: a 12-atom
molecule in a 256-atom deployment paid 256-atom pair geometry, convolution,
and many-body products.  A `SlotPool` is that slot array scoped to one
atom-count bucket — its own host arrays, its own ghost-atom parking, and its
OWN jitted step function compiled for its own ``[n_slots, max_atoms]``
shapes — and `BucketedPools` is the small/medium/large ladder: a request is
routed to the smallest bucket it fits (`select`), so padding waste is
bounded by the bucket ladder instead of the deployment maximum.

Per-bucket compilation is lazy (a bucket that never sees traffic never
compiles — counter-proven in tests/test_serve_scheduler.py) and per-bucket
warmup is explicit: `EquivariantServeEngine.warmup()` seeds each bucket's
measured chain/gate autotune keys at that bucket's own row count
(``max_atoms * channels`` — the batch_hint the traced step actually sees)
and compiles each step on ghost-only slots.

Async host↔device pipelining (DESIGN.md §10.3) lives in the
`begin_step`/`finish_step` split: `begin_step` uploads the staged slot
tensors and dispatches the jitted step — JAX dispatch is asynchronous, so
the call returns an in-flight handle while the device computes — and
`finish_step` blocks, retires finished requests, and advances relaxations.
Between the two, the engine runs the scheduler's admission pass and
pre-stages other pools' tensors (`stage`), overlapping `jnp.asarray` +
bookkeeping with device compute.  A pool whose host state did not change
since the last upload reuses its staged device tensors (skipped when the
step donates its inputs — donation consumes them).

Step-level fault tolerance (DESIGN.md §11.2): the host slot arrays are the
source of truth, so recovery from a failed step is cheap — drop the staged
device tensors and re-stage.  A step that raises (dispatch or at the
blocking read), exceeds the per-pool watchdog deadline (``step_timeout_s``
against the injectable clock), or returns non-finite results enters
`_on_step_failure`: every affected request is restarted from its admission
geometry snapshot (retry is idempotent — relaxations restart from step 0)
up to its ``max_retries``, past which it is structurally rejected with
``reject_reason='step_failed:<kind>'``; the pool backs off exponentially
(``retry_backoff_s``, consecutive-failure doubling) before re-dispatching.
Non-finite outputs quarantine ONLY the offending slots — bucket-mates with
finite numbers retire normally in the same step — and a batch that fails
collectively is bisected into per-slot verdicts by re-evaluating masked
sub-batches, so one degenerate geometry cannot poison its mates' results.
Fault-injection points (`serve/faults.py`) thread through both halves of
the step; they are no-ops unless a `FaultPlan` is installed.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import faults

__all__ = ["BucketSpec", "SlotPool", "BucketedPools", "default_buckets"]


@dataclasses.dataclass(frozen=True)
class BucketSpec:
    """One size bucket: molecules with ``n <= max_atoms`` atoms may land in
    any of its ``n_slots`` slots."""
    max_atoms: int
    n_slots: int = 4
    name: str = ""

    def label(self) -> str:
        return self.name or f"b{self.max_atoms}"


def default_buckets(max_atoms: int, n_slots: int = 4,
                    ladder=(4, 2, 1)) -> tuple[BucketSpec, ...]:
    """A small/medium/large ladder under a deployment cap: bucket sizes
    ``max_atoms // f`` for each ladder divisor (deduplicated, floor 2).
    ``default_buckets(256)`` -> 64/128/256; tiny caps collapse to fewer
    buckets (``default_buckets(4)`` is a single bucket)."""
    names = {0: "small", 1: "medium", 2: "large"}
    sizes = sorted({max(2, max_atoms // f) for f in ladder})
    n = len(sizes)
    return tuple(
        BucketSpec(sz, n_slots, names.get(i + (3 - n), f"b{sz}"))
        for i, sz in enumerate(sizes))


class _Inflight:
    """Handle for a dispatched-but-unfinished pool step."""
    __slots__ = ("active", "energy", "forces", "t0")

    def __init__(self, active, energy, forces, t0):
        self.active = active
        self.energy = energy
        self.forces = forces
        self.t0 = t0


class SlotPool:
    """Fixed atom-padded slots for ONE size bucket, with the bucket's own
    compiled step function (vmapped masked energy + forces over slots)."""

    def __init__(self, model, params, spec: BucketSpec, metrics=None,
                 clock=time.monotonic, step_timeout_s: float | None = None,
                 retry_backoff_s: float = 5e-4, tag: str = ""):
        self.model = model
        self.params = params
        self.spec = spec
        self.metrics = metrics
        self.clock = clock
        self.step_timeout_s = step_timeout_s
        self.retry_backoff_s = retry_backoff_s
        self.tag = tag                 # fault-scope / replica label
        n_slots, max_atoms = spec.n_slots, spec.max_atoms
        self.slot_req: list[Optional[object]] = [None] * n_slots
        self.species = np.zeros((n_slots, max_atoms), np.int32)
        self.pos = np.asarray(self._parked(), np.float32)[None] \
            .repeat(n_slots, 0)
        self.mask = np.zeros((n_slots, max_atoms), np.float32)
        self.steps_run = 0
        # recovery state (DESIGN.md §11.2)
        self.failures = 0              # total failed steps (replica health)
        self._fail_streak = 0          # consecutive failures -> backoff
        self._cooldown_until = 0.0     # begin_step sits out until then
        self._failed_at = None         # first failure of the current outage

        def batched(params, species, pos, mask):
            """All slots in one call: vmapped masked energy + forces."""
            def one(sp, p, m):
                e, g = jax.value_and_grad(
                    lambda pp: model.energy_masked(params, sp, pp, m))(p)
                return e, -g
            return jax.vmap(one)(species, pos, mask)

        # step inputs are fresh device buffers every step on accelerators
        # (donation consumes them, so the staged-tensor reuse below is a
        # CPU-only economy); on CPU nothing is donated and clean staged
        # tensors survive across steps
        self._donate = jax.default_backend() != "cpu"
        donate = (1, 2, 3) if self._donate else ()
        self._step_fn = jax.jit(batched, donate_argnums=donate)
        self._staged = None          # (species_dev, pos_dev, mask_dev)
        self._dirty = True

    # ------------------------------------------------------------ queries
    def compiled(self) -> bool:
        """Whether this bucket's step function has ever compiled — the
        no-cross-bucket-compile counter-proof hooks in here."""
        return self._step_fn._cache_size() > 0

    def fits(self, n_atoms: int) -> bool:
        return n_atoms <= self.spec.max_atoms

    def free_slots(self) -> list[int]:
        return [i for i, r in enumerate(self.slot_req) if r is None]

    def n_active(self) -> int:
        return sum(1 for r in self.slot_req if r is not None)

    # ------------------------------------------------------------ slots
    def _parked(self) -> np.ndarray:
        """Ghost-atom positions: distinct sites far outside any cutoff, so
        padded atoms interact with nothing (incl. each other)."""
        far = 1e4 * (1.0 + np.arange(self.spec.max_atoms, dtype=np.float32))
        return np.stack([far, np.zeros_like(far), np.zeros_like(far)], -1)

    def admit(self, req) -> bool:
        """Place a (validated, fitting) request into a free slot; host-side
        writes only — safe while a step for the CURRENT slot contents is in
        flight (the step read its own device copies at dispatch).  The
        admission geometry is snapshotted on the request: a retried or
        failed-over request restarts from this snapshot, so retry is
        idempotent (relaxations restart from step 0)."""
        free = self.free_slots()
        if not free:
            return False
        n = len(req.species)
        slot = free[0]
        self.species[slot] = 0
        self.species[slot, :n] = np.asarray(req.species, np.int32)
        self.pos[slot] = self._parked()
        self.pos[slot, :n] = np.asarray(req.pos, np.float32)
        self.mask[slot] = 0.0
        self.mask[slot, :n] = 1.0
        self.slot_req[slot] = req
        req._snap_pos = self.pos[slot, :n].copy()
        req._snap_steps = int(getattr(req, "steps", 1))
        self._dirty = True
        return True

    # ------------------------------------------------------------ stepping
    def stage(self, early: bool = False) -> None:
        """Upload the slot arrays to the device if they changed since the
        last upload.  Called with ``early=True`` from the pipelining overlap
        window (another pool's step in flight) — counted so the overlap is
        observable, not just asserted."""
        if self._staged is not None and not self._dirty:
            return
        self._staged = (jnp.asarray(self.species), jnp.asarray(self.pos),
                        jnp.asarray(self.mask))
        self._dirty = False
        if early and self.metrics is not None:
            self.metrics.observe_staged_early(self.spec.label())

    def warmup_compile(self) -> None:
        """Compile this bucket's step on its current (ghost-only at boot)
        slot contents, blocking until done — the per-bucket half of
        `EquivariantServeEngine.warmup()` (which retries transient compile
        failures — the injected kind raises here, before any device work)."""
        if faults._ACTIVE is not None and faults.fire(
                "compile_fail", tag=self.tag,
                pool=self.spec.label()) is not None:
            raise faults.InjectedFault(
                f"injected compile failure in bucket {self.spec.label()}")
        self.stage()
        sp, p, m = self._staged
        if self._donate:
            self._staged = None
        jax.block_until_ready(self._step_fn(self.params, sp, p, m))

    def begin_step(self) -> Optional[_Inflight]:
        """Dispatch one fused evaluation of every active slot; returns an
        in-flight handle (device compute proceeds asynchronously).  Returns
        None while the pool is in retry backoff, and routes dispatch-time
        exceptions (real or injected) into step-failure recovery."""
        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        if not active:
            return None
        if self._cooldown_until and self.clock() < self._cooldown_until:
            return None                  # retry backoff: sit this round out
        if faults._ACTIVE is not None and faults.fire(
                "step_raise", tag=self.tag, pool=self.spec.label(),
                n_active=len(active)) is not None:
            self._on_step_failure(active, "step_raised")
            return None
        self.stage()
        sp, p, m = self._staged
        if self._donate:
            self._staged = None          # donated — never touch again
        t0 = self.clock()
        try:
            e, f = self._step_fn(self.params, sp, p, m)
        except Exception:
            self._on_step_failure(active, "step_raised")
            return None
        return _Inflight(active, e, f, t0)

    def finish_step(self, h: _Inflight) -> list:
        """Block on the in-flight step, retire finished requests, advance
        relaxations.  Returns the requests completed by this step.

        The recovery half of the watchdog lives here: an exception at the
        blocking read, a duration past ``step_timeout_s``, or non-finite
        outputs route into `_on_step_failure` — non-finite outputs
        quarantine ONLY the offending slots (bucket-mates retire normally;
        a collectively failing batch is bisected first)."""
        try:
            e = np.asarray(h.energy)   # blocks until the device finishes
            f = np.asarray(h.forces)
        except Exception:
            self._on_step_failure(h.active, "step_raised")
            return []
        dur = self.clock() - h.t0
        timed_out = (self.step_timeout_s is not None
                     and dur > self.step_timeout_s)
        if faults._ACTIVE is not None:
            if faults.fire("step_timeout", tag=self.tag,
                           pool=self.spec.label(),
                           n_active=len(h.active)) is not None:
                timed_out = True
            nf = faults.fire("step_nonfinite", tag=self.tag,
                             pool=self.spec.label(), n_active=len(h.active))
            if nf is not None:
                e = e.copy()
                f = f.copy()
                slots = nf.payload.get("slots", [0])
                rel = range(len(h.active)) if slots == "all" \
                    else [int(j) % len(h.active) for j in slots]
                for j in rel:
                    e[h.active[j]] = np.nan
                    f[h.active[j]] = np.nan
        if timed_out:
            self._on_step_failure(h.active, "step_timeout")
            return []
        self.steps_run += 1
        real_atoms = sum(len(self.slot_req[i].species) for i in h.active)
        if self.metrics is not None:
            self.metrics.observe_step(
                self.spec.label(), active=len(h.active),
                n_slots=self.spec.n_slots, real_atoms=real_atoms,
                padded_atoms=len(h.active) * self.spec.max_atoms,
                dur_s=dur)
        finite = {i: self._finite(e, f, i) for i in h.active}
        bad = [i for i in h.active if not finite[i]]
        if bad and len(bad) == len(h.active) and len(h.active) > 1:
            # the whole batch is non-finite: bisect into per-slot verdicts
            # (one poisoned slot must not take its mates down with it)
            truly_bad = self._bisect_nonfinite(list(h.active))
            if truly_bad:
                self._on_step_failure(sorted(truly_bad), "nonfinite",
                                      quarantine=True)
            transient = [i for i in h.active if i not in truly_bad
                         and self.slot_req[i] is not None]
            if transient:
                # individually finite — the corruption was batch-level;
                # plain retry, no quarantine accounting
                self._on_step_failure(transient, "nonfinite_collective")
            return []
        if bad:
            # per-slot quarantine: pull ONLY the offending slots from this
            # step's retirements; finite bucket-mates retire normally below
            self._on_step_failure(bad, "nonfinite", quarantine=True)
        completed = []
        good = [i for i in h.active if finite[i]]
        for i in good:
            req = self.slot_req[i]
            n = len(req.species)
            req.energy = float(e[i])
            req.forces = f[i, :n].copy()
            req.pos = self.pos[i, :n].copy()  # the evaluated geometry
            req.steps -= 1
            if req.steps <= 0:
                req.done = True
                self.slot_req[i] = None
                self.mask[i] = 0.0
                self._dirty = True
                completed.append(req)
                if self.metrics is not None:
                    self.metrics.observe_complete(req, self.clock())
            elif req.step_size != 0.0:
                # relaxation: steepest descent on the masked energy
                self.pos[i, :n] += req.step_size * f[i, :n]
                self._dirty = True
        if good:
            # the pool produced usable results: the outage (if any) is over
            self._fail_streak = 0
            self._cooldown_until = 0.0
            if self._failed_at is not None:
                if self.metrics is not None:
                    self.metrics.observe_recovery(self.clock()
                                                  - self._failed_at)
                self._failed_at = None
        return completed

    # --------------------------------------------------------- recovery
    def _finite(self, e, f, i) -> bool:
        n = len(self.slot_req[i].species)
        return bool(np.isfinite(e[i]) and np.all(np.isfinite(f[i, :n])))

    def _bisect_nonfinite(self, slots: list) -> set:
        """Per-slot finite verdicts for a collectively non-finite batch, by
        re-evaluating masked sub-batches from the host slot arrays: a group
        whose re-evaluation separates finite from non-finite slots is
        trusted; a group that fails collectively again is split in half.
        Returns the set of slots that are INDIVIDUALLY non-finite."""
        evals = 0

        def verdicts(group):
            nonlocal evals
            evals += 1
            mask = np.zeros_like(self.mask)
            for i in group:
                mask[i, :len(self.slot_req[i].species)] = 1.0
            e, f = self._step_fn(self.params, jnp.asarray(self.species),
                                 jnp.asarray(self.pos), jnp.asarray(mask))
            e, f = np.asarray(e), np.asarray(f)
            return {i: self._finite(e, f, i) for i in group}

        def bisect(group):
            v = verdicts(group)
            bad = [i for i in group if not v[i]]
            if len(group) == 1 or len(bad) < len(group):
                return set(bad)
            mid = len(group) // 2
            return bisect(group[:mid]) | bisect(group[mid:])

        bad = bisect(slots)
        if self.metrics is not None:
            self.metrics.observe_bisect(self.spec.label(), evals)
        return bad

    def _on_step_failure(self, slots: list, kind: str,
                         quarantine: bool = False) -> None:
        """Step-failure recovery for ``slots``: restart each affected
        request from its admission snapshot (or structurally reject it past
        ``max_retries``), rebuild device state from the host slot arrays,
        and back off exponentially before the next dispatch."""
        now = self.clock()
        if self._failed_at is None:
            self._failed_at = now
        self.failures += 1
        self._fail_streak += 1
        self._cooldown_until = now + self.retry_backoff_s * \
            (2.0 ** min(self._fail_streak - 1, 6))
        if self.metrics is not None:
            self.metrics.observe_step_failure(self.spec.label(), kind)
        for i in slots:
            req = self.slot_req[i]
            if req is None:
                continue
            if quarantine and self.metrics is not None:
                self.metrics.observe_quarantine(self.spec.label())
            req._retries = getattr(req, "_retries", 0) + 1
            if req._retries > max(0, int(getattr(req, "max_retries", 2))):
                req.rejected = True
                req.done = True
                req.reject_reason = f"step_failed:{kind}"
                req.energy = None
                req.forces = None
                if self.metrics is not None:
                    self.metrics.observe_reject(req, "step_failed")
                self.slot_req[i] = None
                self.mask[i] = 0.0
            else:
                if self.metrics is not None:
                    self.metrics.observe_retry(self.spec.label(), kind)
                self._restore_slot(i)
        # the staged device tensors may reflect the failed dispatch (or have
        # been donated into it): drop them — the host arrays are the source
        # of truth and the next stage() rebuilds device state from them
        self._staged = None
        self._dirty = True

    def _restore_slot(self, i: int) -> None:
        """Reset slot ``i`` to its request's admission snapshot (idempotent
        retry: relaxation restarts from step 0 on the original geometry)."""
        req = self.slot_req[i]
        n = len(req.species)
        self.pos[i] = self._parked()
        self.pos[i, :n] = req._snap_pos
        req.steps = req._snap_steps
        req.energy = None
        req.forces = None

    def evict(self) -> list:
        """Pull every active request out of the pool (replica failover):
        each is restored to its admission snapshot and its slot freed, so
        the caller can requeue it elsewhere.  Retry counts survive — a
        failover does not launder a degenerate geometry's history."""
        evicted = []
        for i, req in enumerate(self.slot_req):
            if req is None:
                continue
            n = len(req.species)
            req.pos = req._snap_pos.copy()
            req.steps = req._snap_steps
            req.energy = None
            req.forces = None
            self.slot_req[i] = None
            self.mask[i] = 0.0
            evicted.append(req)
        self._staged = None
        self._dirty = True
        return evicted


class BucketedPools:
    """The bucket ladder: pools sorted by ``max_atoms`` ascending; a request
    routes to the smallest bucket that fits it."""

    def __init__(self, model, params, specs, metrics=None,
                 clock=time.monotonic, step_timeout_s: float | None = None,
                 retry_backoff_s: float = 5e-4, tag: str = ""):
        specs = sorted(specs, key=lambda s: s.max_atoms)
        if len({s.max_atoms for s in specs}) != len(specs):
            raise ValueError(f"duplicate bucket sizes: {specs}")
        self.pools = [SlotPool(model, params, s, metrics=metrics,
                               clock=clock, step_timeout_s=step_timeout_s,
                               retry_backoff_s=retry_backoff_s, tag=tag)
                      for s in specs]

    def __iter__(self):
        return iter(self.pools)

    def __len__(self) -> int:
        return len(self.pools)

    @property
    def max_atoms(self) -> int:
        return self.pools[-1].spec.max_atoms

    def select(self, n_atoms: int) -> Optional[SlotPool]:
        """Smallest bucket with ``max_atoms >= n_atoms``; None if the
        request exceeds even the largest bucket."""
        for p in self.pools:
            if p.fits(n_atoms):
                return p
        return None

    def has_active(self) -> bool:
        return any(p.n_active() for p in self.pools)
