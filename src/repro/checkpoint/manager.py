"""Sharded, async, integrity-checked checkpointing with elastic restore.

Layout per step:
    <dir>/step_<N>/shard_<k>.npz      flat {path: array} groups, ~1 GiB each
    <dir>/step_<N>/manifest.json      pytree paths, shapes, dtypes, crc32s,
                                      pipeline state, mesh snapshot
    <dir>/step_<N>/COMMITTED          written last — restore ignores
                                      uncommitted (crashed) checkpoints

Elastic restore: arrays are loaded on host and `jax.device_put` with the
*current* sharding pytree, so a run checkpointed on one mesh restores onto a
different mesh/device-count (tested: 1 device -> 4 fake devices round trip).
Async: the save runs on a daemon thread off a host-side snapshot; `wait()`
joins before the next save (single outstanding save, bounded memory).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import zlib
from typing import Any

import jax
import ml_dtypes  # noqa: F401  (registers bfloat16 etc. with numpy)
import numpy as np

__all__ = ["CheckpointManager"]

SHARD_BYTES = 1 << 30

# numpy's npz cannot round-trip ml_dtypes (bfloat16, fp8); store them as
# unsigned byte views and reinterpret on load using the manifest dtype.
_VIEW = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8, "float8_e5m2": np.uint8}


def _to_storable(v: np.ndarray) -> np.ndarray:
    name = v.dtype.name
    if name in _VIEW:
        return v.view(_VIEW[name])
    return v


def _from_storable(v: np.ndarray, logical_dtype: str) -> np.ndarray:
    if logical_dtype in _VIEW:
        return v.view(np.dtype(getattr(ml_dtypes, logical_dtype)))
    return v


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None

    # ------------------------------------------------------------- save
    def save(self, step: int, tree: Any, extra: dict | None = None, blocking: bool = False):
        """Snapshot to host then write asynchronously."""
        self.wait()
        flat = _flatten(tree)  # host copies
        extra = dict(extra or {})

        def _write():
            tmp = os.path.join(self.dir, f".tmp_step_{step}")
            final = os.path.join(self.dir, f"step_{step}")
            shutil.rmtree(tmp, ignore_errors=True)
            os.makedirs(tmp, exist_ok=True)
            shards: list[list[str]] = [[]]
            size = 0
            for k, v in flat.items():
                if size > SHARD_BYTES:
                    shards.append([])
                    size = 0
                shards[-1].append(k)
                size += v.nbytes
            manifest = {"step": step, "extra": extra, "entries": {}, "n_shards": len(shards)}
            for si, keys in enumerate(shards):
                payload = {k: _to_storable(flat[k]) for k in keys}
                np.savez(os.path.join(tmp, f"shard_{si}.npz"), **payload)
                for k in keys:
                    v = flat[k]
                    manifest["entries"][k] = {
                        "shard": si,
                        "shape": list(v.shape),
                        "dtype": str(v.dtype),
                        "crc32": zlib.crc32(np.ascontiguousarray(v).tobytes()) & 0xFFFFFFFF,
                    }
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            with open(os.path.join(tmp, "COMMITTED"), "w") as f:
                f.write("ok")
            shutil.rmtree(final, ignore_errors=True)
            os.rename(tmp, final)
            self._gc()

        if blocking:
            _write()
        else:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"), ignore_errors=True)

    # ------------------------------------------------------------- restore
    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and os.path.exists(
                os.path.join(self.dir, name, "COMMITTED")
            ):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, target_tree: Any, shardings: Any | None = None,
                verify: bool = True):
        """Restore into the structure of target_tree.  shardings (same
        structure, jax.sharding.Sharding leaves) places leaves on the current
        mesh — the elastic-reshard path."""
        d = os.path.join(self.dir, f"step_{step}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        data: dict[str, np.ndarray] = {}
        for si in range(manifest["n_shards"]):
            with np.load(os.path.join(d, f"shard_{si}.npz")) as z:
                for k in z.files:
                    data[k] = _from_storable(z[k], manifest["entries"][k]["dtype"])
        if verify:
            for k, meta in manifest["entries"].items():
                crc = zlib.crc32(np.ascontiguousarray(data[k]).tobytes()) & 0xFFFFFFFF
                if crc != meta["crc32"]:
                    raise IOError(f"checkpoint corruption in leaf {k!r}")
        paths, treedef = jax.tree_util.tree_flatten_with_path(target_tree)
        shard_leaves = (
            jax.tree_util.tree_flatten(shardings)[0] if shardings is not None else None
        )
        leaves = []
        for i, (path, proto) in enumerate(paths):
            key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
            if key not in data:
                raise KeyError(f"checkpoint missing leaf {key!r}")
            arr = data[key].astype(proto.dtype) if hasattr(proto, "dtype") else data[key]
            if shard_leaves is not None:
                arr = jax.device_put(arr, shard_leaves[i])
            leaves.append(arr)
        return jax.tree_util.tree_unflatten(treedef, leaves), manifest["extra"]
