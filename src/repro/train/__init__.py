from .loop import TrainState, make_train_step, train_loop  # noqa: F401
