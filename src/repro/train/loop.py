"""Training loop: jit'd step (grad accumulation via scan, global-norm clip,
AdamW), sharded state, checkpoint/restore/heartbeat/preemption/straggler
hooks.  Works identically on 1 CPU device and on the production mesh (the
step function is built once with in/out shardings when a mesh is given).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.config import TrainConfig
from repro.distributed.fault_tolerance import Heartbeat, PreemptionGuard, StragglerMonitor
from repro.optim import adamw, apply_updates, clip_by_global_norm, cosine_schedule

__all__ = ["TrainState", "make_train_step", "train_loop"]


@dataclasses.dataclass
class TrainState:
    params: Any
    opt_state: Any
    step: int = 0


def make_train_step(loss_fn: Callable, tcfg: TrainConfig, optimizer=None):
    """loss_fn(params, batch) -> (loss, metrics dict).  Returns
    step(params, opt_state, batch) -> (params, opt_state, metrics)."""
    opt = optimizer or adamw(
        cosine_schedule(tcfg.lr, tcfg.warmup_steps, tcfg.total_steps),
        tcfg.b1, tcfg.b2, tcfg.eps, tcfg.weight_decay,
    )

    def grads_of(params, batch):
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        return loss, metrics, grads

    def step(params, opt_state, batch):
        if tcfg.microbatch and tcfg.microbatch > 1:
            mb = tcfg.microbatch

            def split(x):
                return x.reshape(mb, x.shape[0] // mb, *x.shape[1:])

            batches = jax.tree.map(split, batch)

            def acc_fn(carry, b):
                loss_a, grads_a = carry
                loss, metrics, grads = grads_of(params, b)
                return (loss_a + loss / mb,
                        jax.tree.map(lambda a, g: a + g.astype(jnp.float32) / mb,
                                     grads_a, grads)), metrics

            zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), metrics = jax.lax.scan(acc_fn, (0.0, zero), batches)
            metrics = jax.tree.map(lambda m: m[-1], metrics)
        else:
            loss, metrics, grads = grads_of(params, batch)
        grads, gnorm = clip_by_global_norm(grads, tcfg.grad_clip)
        updates, opt_state = opt.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        metrics = dict(metrics, loss=loss, grad_norm=gnorm)
        return params, opt_state, metrics

    return step, opt


def train_loop(
    loss_fn: Callable,
    init_params: Any,
    data_iter,
    tcfg: TrainConfig,
    ckpt_dir: str | None = None,
    mesh=None,
    shardings=None,
    hooks: dict | None = None,
):
    """Run tcfg.total_steps steps with full fault-tolerance plumbing.

    Resumes from the latest committed checkpoint in ckpt_dir if present
    (params + optimizer + data-pipeline state).
    """
    hooks = hooks or {}
    step_fn, opt = make_train_step(loss_fn, tcfg)
    params = init_params
    opt_state = opt.init(params)
    start_step = 0

    mgr = CheckpointManager(ckpt_dir, keep=tcfg.keep_checkpoints) if ckpt_dir else None
    if mgr is not None and mgr.latest_step() is not None:
        s = mgr.latest_step()
        tree = {"params": params, "opt": opt_state}
        (restored, extra) = mgr.restore(s, jax.eval_shape(lambda: tree), shardings=None)
        params, opt_state = restored["params"], restored["opt"]
        params = jax.tree.map(jnp.asarray, params)
        opt_state = jax.tree.map(jnp.asarray, opt_state)
        start_step = s
        if hasattr(data_iter, "restore") and "pipeline" in extra:
            data_iter.restore(extra["pipeline"])

    jit_kwargs = {}
    if mesh is not None and shardings is not None:
        jit_kwargs = dict(
            in_shardings=(shardings["params"], shardings["opt"], shardings["batch"]),
            out_shardings=(shardings["params"], shardings["opt"], None),
        )
    jstep = jax.jit(step_fn, donate_argnums=(0, 1), **jit_kwargs)

    guard = PreemptionGuard().install() if hooks.get("preemption", True) else None
    hb = Heartbeat(hooks["heartbeat_path"]) if "heartbeat_path" in hooks else None
    straggler = StragglerMonitor()
    history = []
    step = start_step - 1  # if already past total_steps (resume), no-op

    for step in range(start_step, tcfg.total_steps):
        batch = data_iter.next_batch() if hasattr(data_iter, "next_batch") else next(data_iter)
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        t0 = time.time()
        params, opt_state, metrics = jstep(params, opt_state, batch)
        if step % tcfg.log_every == 0 or step == tcfg.total_steps - 1:
            metrics = jax.tree.map(float, jax.device_get(metrics))
            history.append({"step": step + 1, **metrics})
            if hooks.get("log"):
                hooks["log"](history[-1])
        dt = time.time() - t0
        straggler.record(step, dt)
        if hb:
            hb.beat(step)
        should_ckpt = mgr is not None and (
            (step + 1) % tcfg.checkpoint_every == 0
            or step == tcfg.total_steps - 1
            or (guard and guard.should_exit)
        )
        if should_ckpt:
            extra = {}
            if hasattr(data_iter, "state"):
                extra["pipeline"] = data_iter.state()
            mgr.save(step + 1, {"params": params, "opt": opt_state}, extra=extra,
                     blocking=(guard and guard.should_exit) or step == tcfg.total_steps - 1)
        if guard and guard.should_exit:
            break
    if mgr:
        mgr.wait()
    return TrainState(params, opt_state, step + 1), history
