"""Distributed-optimization tricks.

int8 error-feedback gradient compression for the cross-pod reduction: inside
a shard_map over the 'pod' axis, gradients are quantized to int8 (per-tensor
absmax scale), psum'ed over 'pod', dequantized, and the quantization residual
is carried as error-feedback state so the compression is unbiased over time.
The 'data'-axis reduce-scatter stays full precision (intra-pod ICI is cheap;
the pod axis is the long DCN-ish hop — that is where compression pays).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

__all__ = ["int8_ef_cross_pod_mean", "ef_state_init"]


def ef_state_init(grads):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def _quant(x):
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-8) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def int8_ef_cross_pod_mean(grads, ef, mesh):
    """Mean-reduce grads over the 'pod' mesh axis with int8 + error feedback.

    grads/ef: pytrees of arrays already reduced over 'data'.  Returns
    (reduced_grads, new_ef).  No-op (identity, ef unchanged) if the mesh has
    no pod axis.
    """
    if "pod" not in mesh.axis_names:
        return grads, ef

    npod = dict(zip(mesh.axis_names, mesh.devices.shape))["pod"]

    def one(g, e):
        spec = P(*([None] * g.ndim))  # replicated view within the shard_map

        @functools.partial(
            jax.shard_map, mesh=mesh, in_specs=(spec, spec), out_specs=(spec, spec),
            check_vma=False,
        )
        def body(gl, el):
            x = gl.astype(jnp.float32) + el
            q, scale = _quant(x)
            deq = q.astype(jnp.float32) * scale
            new_e = x - deq
            total = jax.lax.psum(deq, axis_name="pod") / npod
            return total, new_e

        return body(g, e)

    flat_g, td = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(ef)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (jax.tree.unflatten(td, [o[0] for o in outs]),
            jax.tree.unflatten(td, [o[1] for o in outs]))
