"""Fault-tolerance plumbing: heartbeat, preemption trap, straggler monitor.

On a real cluster every host runs these; in this container they are unit-
tested directly.  The launcher (`repro.launch.train`) wires them together
with the CheckpointManager: SIGTERM -> synchronous checkpoint -> exit 143,
and the supervisor loop (`--supervise`) restarts from the latest committed
step with exponential backoff.
"""
from __future__ import annotations

import json
import os
import signal
import time
from collections import deque

__all__ = ["Heartbeat", "PreemptionGuard", "StragglerMonitor"]


class Heartbeat:
    """Writes {step, t} to a file the cluster health-checker watches."""

    def __init__(self, path: str, interval_s: float = 10.0):
        self.path = path
        self.interval = interval_s
        self._last = 0.0

    def beat(self, step: int, force: bool = False):
        now = time.time()
        if force or now - self._last >= self.interval:
            tmp = self.path + ".tmp"
            with open(tmp, "w") as f:
                json.dump({"step": step, "t": now, "pid": os.getpid()}, f)
            os.replace(tmp, self.path)
            self._last = now


class PreemptionGuard:
    """SIGTERM/SIGINT -> set flag; the train loop checkpoints and exits."""

    def __init__(self, signals=(signal.SIGTERM,)):
        self.should_exit = False
        self._signals = signals

    def install(self):
        for s in self._signals:
            signal.signal(s, self._handler)
        return self

    def _handler(self, signum, frame):
        self.should_exit = True


class StragglerMonitor:
    """Flags steps slower than `factor` x rolling median (straggler
    mitigation hook: the launcher logs and can trigger re-balancing or host
    cordoning; serving cordons replicas on it — serve/replicas.py).

    ``flagged`` keeps only the most recent ``max_flagged`` events (a
    long-lived serving host flags forever; an unbounded list is a slow
    leak); ``total_flagged`` counts every flag ever raised and is what
    `ServeMetrics.summary()` folds in."""

    def __init__(self, window: int = 50, factor: float = 2.0,
                 max_flagged: int = 256):
        self.times = deque(maxlen=window)
        self.factor = factor
        self.flagged: deque[tuple[int, float]] = deque(maxlen=max_flagged)
        self.total_flagged = 0

    def record(self, step: int, dt: float) -> bool:
        slow = False
        if len(self.times) >= 10:
            med = sorted(self.times)[len(self.times) // 2]
            slow = dt > self.factor * med
            if slow:
                self.flagged.append((step, dt))
                self.total_flagged += 1
        self.times.append(dt)
        return slow
