from .sharding import (  # noqa: F401
    param_shardings,
    batch_shardings,
    cache_shardings,
    choose_pspec,
    DP_AXES,
)
