"""Partitioning rules: parameter/optimizer/activation/cache PartitionSpecs.

Scheme (DESIGN.md §5): batch over ('pod','data'); FSDP shards params over
'data'; TP (Megatron col/row) over 'model'; EP maps the expert dim onto
'model' when divisible.  Rules are *candidate lists per tensor dim* resolved
against actual shapes — non-divisible dims degrade gracefully to the next
candidate or replication (e.g. qwen2-moe's 60 experts on a 16-way model axis
fall back to sharding d_ff).
"""
from __future__ import annotations

import re
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["choose_pspec", "param_shardings", "batch_shardings", "cache_shardings",
           "DP_AXES", "set_activation_mesh", "get_activation_mesh",
           "constrain_batch", "dp_axes", "dp_size", "row_pspec", "row_sharding"]

DP_AXES = ("pod", "data")  # batch axes (pod missing on single-pod meshes)

# --- activation sharding constraints ----------------------------------------
# SPMD propagation loses batch sharding through scatter/gather-heavy code
# (observed: MoE dispatch materializing full-batch [256, ...] tensors per
# device).  Model code calls constrain_batch(x) at those points; launchers
# opt in with set_activation_mesh(mesh) (no-op otherwise, e.g. smoke tests).
_ACT_MESH: Mesh | None = None


def set_activation_mesh(mesh: Mesh | None):
    global _ACT_MESH
    _ACT_MESH = mesh


def get_activation_mesh() -> Mesh | None:
    """The mesh registered by the launcher (None outside launched runs)."""
    return _ACT_MESH


# --- row-parallel helpers (the Gaunt engine's batched/sharded dispatch) ------
# A "row" layout is any array whose dim0 is a flat batch of independent work
# items (edges, nodes, stacked tensor-product operands).  The batched Gaunt
# plans (core/engine.py plan_batch, DESIGN.md §5) and the resident chain
# plans (plan_chain, DESIGN.md §6) shard that axis over the data-parallel
# mesh axes and replicate everything else.  Specs are built RANK-AWARE per
# leaf (`row_pspec(a.ndim, dp)` / `row_sharding(mesh, a.ndim)`): the row
# layout mixes leaf ranks — SH rows [rows, k], half/dense Fourier grids
# [rows, n, nv], Wigner blocks [rows, d, d] — and a fixed-rank spec would
# silently shard a grid's frequency axis.


def dp_axes(mesh: Mesh, prefer: tuple = DP_AXES) -> tuple:
    """The data-parallel axes of `mesh` (subset of `prefer` that exists)."""
    return _axes_in(mesh, prefer)


def dp_size(mesh: Mesh, axes: tuple | None = None) -> int:
    """Total device count across the data-parallel axes (1 if none)."""
    axes = dp_axes(mesh) if axes is None else axes
    if not axes:
        return 1
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return int(np.prod([sizes[a] for a in axes]))


def row_pspec(ndim: int, axes: tuple) -> P:
    """PartitionSpec sharding dim0 over `axes`, replicating the rest."""
    if not axes:
        return P(*([None] * ndim))
    return P(axes, *([None] * (ndim - 1)))


def row_sharding(mesh: Mesh, ndim: int, axes: tuple | None = None) -> NamedSharding:
    """NamedSharding for a row layout on `mesh` (dim0 over the dp axes)."""
    axes = dp_axes(mesh) if axes is None else axes
    return NamedSharding(mesh, row_pspec(ndim, axes))


def constrain_ep_weights(w):
    """Pin expert weights [E, a, b] to their *compute* form: EP over 'model'
    (when divisible), inner dims gathered.  Storage stays FSDP-sharded via the
    param shardings; this constraint makes XLA materialize the (weight-sized)
    all-gather instead of resharding the (much larger) dispatch activations —
    the §Perf H6 fix for the H3/H4 interaction."""
    if _ACT_MESH is None:
        return w
    sizes = dict(zip(_ACT_MESH.axis_names, _ACT_MESH.devices.shape))
    e_axis = "model" if ("model" in sizes and w.shape[-3] % sizes["model"] == 0) else None
    spec = [None] * (w.ndim - 3) + [e_axis, None, None]
    return jax.lax.with_sharding_constraint(w, NamedSharding(_ACT_MESH, P(*spec)))


def constrain_batch(x, *trailing):
    """Pin dim0 of x to the data-parallel axes (trailing dims per *trailing)."""
    if _ACT_MESH is None:
        return x
    dp = _axes_in(_ACT_MESH, DP_AXES)
    if not dp:
        return x
    sizes = dict(zip(_ACT_MESH.axis_names, _ACT_MESH.devices.shape))
    n = int(np.prod([sizes[a] for a in dp]))
    if x.shape[0] % n != 0:
        return x
    spec = [dp] + list(trailing) + [None] * (x.ndim - 1 - len(trailing))
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(_ACT_MESH, P(*spec)))


def _axes_in(mesh: Mesh, names) -> tuple:
    return tuple(n for n in names if n in mesh.axis_names)


def choose_pspec(shape, mesh: Mesh, prefs: list[list[str]]) -> P:
    """prefs[i]: ordered candidate mesh-axis names for dim i ([] = replicate).
    First candidate that exists in the mesh, divides the dim size, and is not
    already used wins."""
    used: set[str] = set()
    spec = []
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    for dim, cands in zip(shape, list(prefs) + [[]] * (len(shape) - len(prefs))):
        pick = None
        for c in cands:
            if c in sizes and c not in used and dim % sizes[c] == 0 and sizes[c] > 1:
                pick = c
                used.add(c)
                break
        spec.append(pick)
    return P(*spec)


# per-leaf-name rules: list of per-dim candidate lists (for the *unstacked*
# shape; a leading scan-stack axis is detected and prepended as replicated)
_RULES: list[tuple[str, list[list[str]]]] = [
    # embeddings / unembedding
    (r"embed/embedding$", [["model"], ["data"]]),
    (r"unembed/w$", [["data"], ["model"]]),
    (r"dec_pos$", [[], ["data"]]),
    # attention (col-parallel qkv, row-parallel o)
    (r"(attn|xattn)/wq/w$", [["data"], ["model"]]),
    (r"(attn|xattn)/wk/w$", [["data"], ["model"]]),
    (r"(attn|xattn)/wv/w$", [["data"], ["model"]]),
    (r"(attn|xattn)/w[qkv]/b$", [["model"]]),
    (r"(attn|xattn)/wo/w$", [["model"], ["data"]]),
    # dense mlp
    (r"mlp/w_(up|gate)/w$", [["data"], ["model"]]),
    (r"mlp/w_down/w$", [["model"], ["data"]]),
    # moe: EP on model if divisible, else shard ff on model + d on data
    (r"moe/router/w$", [["data"], []]),
    (r"moe/we_(gate|up)$", [["model"], ["data"], ["model"]]),
    (r"moe/we_down$", [["model", "data"], ["model"], ["data"]]),
    (r"moe/shared/w_(up|gate)/w$", [["data"], ["model"]]),
    (r"moe/shared/w_down/w$", [["model"], ["data"]]),
    # mamba2
    (r"in_proj/w$", [["data"], ["model"]]),
    (r"out_proj/w$", [["model"], ["data"]]),
    (r"conv_w$", [[], ["model"]]),
    (r"conv_b$", [["model"]]),
    # rwkv6
    (r"tm/w[rkvg]/w$", [["data"], ["model"]]),
    (r"tm/wo/w$", [["model"], ["data"]]),
    (r"tm/maa_w1$", [["data"], []]),
    (r"tm/maa_w2$", [[], [], ["data"]]),
    (r"tm/decay_w1$", [["data"], []]),
    (r"tm/decay_w2$", [[], ["data"]]),
    (r"cm/cm_k/w$", [["data"], ["model"]]),
    (r"cm/cm_v/w$", [["model"], ["data"]]),
    (r"cm/cm_r/w$", [["data"], ["model"]]),
    # zamba2 glue
    (r"cat_proj/w$", [["data"], ["model"]]),
]

_STACK_PREFIXES = ("layers/", "mamba/", "enc_layers/")

# --- layout variants (the §Perf hillclimb levers) ---------------------------
# default     : FSDP('data') x TP('model'), EP on 'model' where divisible
# dp_heavy    : for small models — params replicated over 'model' (only
#               FSDP over 'data'); kills per-layer TP all-reduces at the cost
#               of replicated compute ... batch stays on ('pod','data').
# moe_expert_tp: MoE expert weights NOT FSDP-gathered; d_ff sharded over
#               'data' (TP *within* each expert) — swaps the per-layer weight
#               all-gather volume for activation-sized all-reduces.
_MOE_EXPERT_TP = [
    (r"moe/we_(gate|up)$", [["model"], [], ["data"]]),
    (r"moe/we_down$", [["model"], ["data"], []]),
]


def _leaf_key(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)


def param_pspec(key: str, shape, mesh: Mesh, layout: str = "default") -> P:
    stacked = key.startswith(_STACK_PREFIXES)
    base_shape = shape[1:] if stacked else shape
    rules = _RULES
    if layout == "moe_expert_tp":
        rules = _MOE_EXPERT_TP + _RULES
    for pat, prefs in rules:
        if re.search(pat, key):
            if layout == "dp_heavy":
                prefs = [[c for c in cand if c != "model"] for cand in prefs]
            spec = choose_pspec(base_shape, mesh, prefs)
            return P(None, *spec) if stacked else spec
    # default: replicate small things; FSDP-shard big 2D+ tensors on 'data'
    if len(base_shape) >= 2 and np.prod(base_shape) >= 1 << 20:
        spec = choose_pspec(base_shape, mesh, [["data"], ["model"]])
        return P(None, *spec) if stacked else spec
    return P(*([None] * len(shape)))


def param_shardings(param_tree, mesh: Mesh, layout: str = "default"):
    """pytree of NamedSharding matching param_tree (works on ShapeDtypeStructs)."""

    def one(path, leaf):
        return NamedSharding(mesh, param_pspec(_leaf_key(path), leaf.shape, mesh, layout))

    return jax.tree_util.tree_map_with_path(one, param_tree)


def batch_shardings(batch_tree, mesh: Mesh):
    """Shard leading (batch) dim over ('pod','data')."""
    dp = _axes_in(mesh, DP_AXES)

    def one(leaf):
        if leaf.ndim == 0:
            return NamedSharding(mesh, P())
        bsz = leaf.shape[0]
        n = int(np.prod([dict(zip(mesh.axis_names, mesh.devices.shape))[a] for a in dp])) if dp else 1
        if dp and bsz % n == 0:
            return NamedSharding(mesh, P(dp, *([None] * (leaf.ndim - 1))))
        return NamedSharding(mesh, P(*([None] * leaf.ndim)))

    return jax.tree.map(one, batch_tree)


def cache_shardings(cache_tree, mesh: Mesh):
    """KV/recurrent caches: [L, B, S, KV, hd]-style. Prefer batch over
    ('pod','data'), then heads over 'model', then sequence over 'model'."""
    dp = _axes_in(mesh, DP_AXES)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    n_dp = int(np.prod([sizes[a] for a in dp])) if dp else 1

    def one(leaf):
        nd = leaf.ndim
        if nd < 3:
            return NamedSharding(mesh, P(*([None] * nd)))
        spec = [None] * nd
        used = set()
        # dim1 = batch
        if dp and leaf.shape[1] % n_dp == 0:
            spec[1] = dp
            used.update(dp)
        elif "data" in sizes and leaf.shape[1] % sizes["data"] == 0:
            spec[1] = "data"
            used.add("data")
        # prefer model on a heads-like dim (>=4D: dim3), else the seq dim 2
        if "model" in sizes and sizes["model"] > 1:
            if nd >= 4 and leaf.shape[3] % sizes["model"] == 0:
                spec[3] = "model"
            elif leaf.shape[2] % sizes["model"] == 0:
                spec[2] = "model"
        # long-context single-batch: also spread seq over data if unused
        if spec[1] is None and "data" not in used and "data" in sizes and nd >= 3:
            if leaf.shape[2] % (sizes["data"] * sizes.get("model", 1)) == 0 and spec[2] == "model":
                spec[2] = ("data", "model")
            elif spec[2] is None and leaf.shape[2] % sizes["data"] == 0:
                spec[2] = "data"
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(one, cache_tree)
