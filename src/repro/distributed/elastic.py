"""Elastic scaling: move a training state between meshes of different shape
or device count (scale-up after repair, scale-down after failures).

Mechanics: checkpoints are mesh-agnostic (host npz shards); restoring with
the *new* mesh's shardings places every leaf correctly (CheckpointManager).
For live in-memory resharding (no disk round trip) use `reshard_tree`.
"""
from __future__ import annotations

import jax

from .sharding import param_shardings

__all__ = ["reshard_tree", "restore_on_mesh"]


def reshard_tree(tree, new_mesh, layout: str = "default"):
    """Re-place a live pytree onto `new_mesh` per the standard param rules."""
    sh = param_shardings(jax.eval_shape(lambda: tree), new_mesh, layout=layout)
    return jax.tree.map(lambda x, s: jax.device_put(x, s), tree, sh)


def restore_on_mesh(manager, step: int, target_tree, new_mesh, layout: str = "default"):
    """Restore a checkpoint directly onto a (possibly different) mesh."""
    sh = param_shardings(target_tree, new_mesh, layout=layout)
    return manager.restore(step, target_tree, shardings=sh)
