"""Unified Gaunt execution engine — one plan/dispatch layer for every Gaunt op.

This repo grew several concrete realizations of the paper's O(L^3) Gaunt
tensor product (dense/packed spectral conversions x fft/direct convolution,
the fused collocation kernel, the eSCN rotation-aligned convolution).  The
engine makes them *backends* behind a single planning API (DESIGN.md §4):

    plan = engine.plan(L1, L2, Lout, kind="pairwise", batch_hint=4096)
    out  = plan.apply(x1, x2, w1=w1)          # paper's w_{l1} w_{l2} w_l hooks

A plan is keyed by ``(L1, L2, Lout, kind, batch_hint, dtype)`` (+ kind
specific extras) and resolved to a registered backend:

    kind         backends
    pairwise     dense_einsum | fft | direct | packed | fused_xla | fused_pallas
    conv_filter  escn_aligned + every pairwise backend (filter materialized)
    manybody     dense_einsum | fft | direct | packed
    channel_mix  dense_einsum | fused_xla

Backends carry capability flags (grad support, dtype support, whether Pallas
must run in interpret mode off-TPU); selection is either a closed-form cost
model (``tune="heuristic"``) or measured wall-time on synthetic inputs with
an in-process autotune cache (``tune="measure"``).  Plans and their constants
are cached: planning twice is free, and all numpy precompute lives in the
central :mod:`repro.core.constants` cache.

Thin public wrappers (`GauntTensorProduct`, `EquivariantConv`,
`manybody_gaunt_product`, `gaunt_tp_channel_mix`, the model `_tp` hook) keep
their historical signatures and route here.

Batched execution (DESIGN.md §5): ``engine.plan_batch(items, ...)`` buckets a
ragged multi-degree workload (items sharing an (L1, L2, Lout) signature) into
one padded fused invocation per bucket, with operand buffer donation on the
hot path and sharding-aware dispatch over the mesh's data axes:

    bp  = engine.plan_batch([(2, 2, 4, nE), (1, 1, 2, nN)], donate=True,
                            shard_spec=ShardSpec(mode="shard_map"))
    o1, o2 = bp.apply([(x1, x2), (a, b)])
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from . import constants
from .irreps import l_array, num_coeffs

__all__ = [
    "PlanKey",
    "Backend",
    "GauntPlan",
    "BatchItem",
    "ShardSpec",
    "BatchedGauntPlan",
    "GauntEngine",
    "register_backend",
    "available_backends",
    "expand_degree_weights",
    "get_engine",
    "plan",
    "plan_batch",
]

KINDS = ("pairwise", "conv_filter", "manybody", "channel_mix")

_RDTYPE = {"float32": jnp.float32, "bfloat16": jnp.bfloat16, "float64": jnp.float64}
_CDTYPE = {"float32": "complex64", "bfloat16": "complex64", "float64": "complex128"}


def _dtype_str(dtype) -> str:
    """Normalize any dtype spec (incl. the wrappers' cdtype) to a plan key.

    float64/complex128 requests are demoted to float32 when jax runs with
    x64 disabled (the default): arrays would silently degrade to f32 anyway,
    and keying plans on the *requested* precision would hash
    otherwise-identical plans to different cache entries and build complex128
    constants that every apply immediately downcasts.
    """
    s = jnp.dtype(dtype).name
    if s.startswith("complex"):
        s = "float64" if s == "complex128" else "float32"
    if s == "float64" and not jax.config.jax_enable_x64:
        return "float32"
    if s not in _RDTYPE:
        raise ValueError(f"unsupported dtype {s!r} (expected one of {sorted(_RDTYPE)})")
    return s


def expand_degree_weights(w, L: int):
    """w [..., L+1] per-degree -> [..., (L+1)^2] packed broadcast.

    The canonical implementation (gaunt.py re-exports it for back-compat).
    """
    return w[..., jnp.asarray(l_array(L).astype(np.int32))]


def _wmul(x, w, L: int):
    return x if w is None else x * expand_degree_weights(w, L).astype(x.dtype)


# --------------------------------------------------------------------------
# plan keys and backend registry
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PlanKey:
    """Identity of a planned Gaunt op (hashable; the plan-cache key)."""

    L1: int
    L2: int
    Lout: int
    kind: str = "pairwise"
    batch_hint: int | None = None
    dtype: str = "float32"
    # kind/backend-specific knobs, as a sorted tuple of (name, value) pairs:
    # manybody carries ("Ls", (...)); packed carries ("conv", "fft"|"direct").
    extra: tuple = ()

    def opt(self, name: str, default=None):
        return dict(self.extra).get(name, default)


@dataclasses.dataclass(frozen=True)
class Backend:
    """A registered Gaunt realization with capability flags."""

    name: str
    kinds: frozenset
    build: Callable[[PlanKey], Callable] = dataclasses.field(repr=False, compare=False, default=None)
    cost: Callable[[PlanKey], float] = dataclasses.field(repr=False, compare=False, default=None)
    supports_grad: bool = True
    dtypes: frozenset = frozenset({"float32", "bfloat16", "float64"})
    needs_interpret: bool = False  # Pallas: off-TPU only via (slow) interpret mode

    def eligible(self, key: PlanKey, requires_grad: bool) -> bool:
        if key.dtype not in self.dtypes:
            return False
        if requires_grad and not self.supports_grad:
            return False
        if key.kind in self.kinds:
            return True
        # any pairwise backend can serve conv_filter by materializing Y(rhat)
        return key.kind == "conv_filter" and "pairwise" in self.kinds


_REGISTRY: dict[str, Backend] = {}


def register_backend(backend: Backend) -> Backend:
    _REGISTRY[backend.name] = backend
    return backend


def available_backends(kind: str = "pairwise", dtype: str = "float32",
                       requires_grad: bool = True) -> list[str]:
    # same normalization as plan(): a float64 query on an x64-disabled runtime
    # must see the float32 capability set, not a phantom-precision one
    key = PlanKey(1, 1, 2, kind=kind, dtype=_dtype_str(dtype))
    return [b.name for b in _REGISTRY.values() if b.eligible(key, requires_grad)]


@dataclasses.dataclass(frozen=True)
class GauntPlan:
    """A resolved (key, backend) pair; ``apply`` runs the op."""

    key: PlanKey
    backend: str
    apply: Callable = dataclasses.field(repr=False, compare=False)

    def describe(self) -> str:
        k = self.key
        return (f"{k.kind}(L1={k.L1}, L2={k.L2}, Lout={k.Lout}, "
                f"dtype={k.dtype}, batch_hint={k.batch_hint}) -> {self.backend}")


# --------------------------------------------------------------------------
# batched execution (DESIGN.md §5): ragged multi-degree workloads in one
# padded invocation per degree bucket, with donation + sharded dispatch
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class BatchItem:
    """One entry of a batched workload: a degree signature + expected rows.

    ``size`` is a planning hint (feeds the bucket's batch_hint); the actual
    row count comes from the arrays at apply time.  manybody items carry
    ``Ls`` instead of (L1, L2).
    """

    L1: int | None = None
    L2: int | None = None
    Lout: int | None = None
    Ls: tuple | None = None
    size: int | None = None
    options: tuple = ()

    def signature(self) -> tuple:
        return (self.L1, self.L2, self.Lout, self.Ls, self.options)


def _as_batch_item(it) -> BatchItem:
    if isinstance(it, BatchItem):
        return it
    if isinstance(it, dict):
        d = dict(it)
        if "options" in d:
            d["options"] = tuple(sorted(dict(d["options"]).items()))
        if "Ls" in d and d["Ls"] is not None:
            d["Ls"] = tuple(int(L) for L in d["Ls"])
        return BatchItem(**d)
    it = tuple(it)
    if len(it) == 3:
        return BatchItem(L1=it[0], L2=it[1], Lout=it[2])
    if len(it) == 4:
        return BatchItem(L1=it[0], L2=it[1], Lout=it[2], size=it[3])
    raise ValueError(f"batch item {it!r}: expected (L1, L2, Lout[, size]), "
                     "a dict, or a BatchItem")


@dataclasses.dataclass(frozen=True)
class ShardSpec:
    """How a batched apply is laid out over a device mesh.

    mesh : a jax Mesh, or None to use the launcher-registered activation
           mesh (``distributed.sharding.set_activation_mesh``); with neither,
           the spec is inert and execution stays single-device.
    axes : mesh axis names eligible to shard the row axis (dim0 of every
           flattened operand); the subset present in the mesh is used.
    mode : 'constraint' — pjit-style ``with_sharding_constraint`` on operands
           and outputs (SPMD partitioner does the rest); 'shard_map' — the
           bucket body runs per-shard under ``shard_map`` (row-parallel by
           construction, so no collectives are needed).
    """

    mesh: object = None
    axes: tuple = ("pod", "data")
    mode: str = "constraint"

    def resolve(self):
        """-> (mesh, dp_axes) or (None, ()) when no mesh is available."""
        from repro.distributed import sharding as _sh  # lazy: keep core light

        mesh = self.mesh if self.mesh is not None else _sh.get_activation_mesh()
        if mesh is None:
            return None, ()
        axes = _sh.dp_axes(mesh, tuple(self.axes))
        return mesh, axes


def _split_leads(leads: list) -> tuple:
    """Split operand leading shapes into (row prefix, inner broadcast dims).

    The *prefix* is the longest run of leading dims on which every operand
    agrees exactly (after numpy-style right-aligned rank padding) — those
    flatten into the row axis.  The remaining *inner* dims are where the
    operands exploit broadcasting (e.g. one edge direction against C channel
    features); they pass through to the backend, which broadcasts natively —
    flattening them instead would materialize the broadcast and repeat
    shared per-row work (the eSCN Wigner blocks) per inner element.
    """
    full = jnp.broadcast_shapes(*leads)
    n = len(full)
    padded = [(1,) * (n - len(ld)) + tuple(ld) for ld in leads]
    k = 0
    while k < n and all(p[k] == full[k] for p in padded):
        k += 1
    return full[:k], full[k:]


def _n_operands(kind: str, item: BatchItem) -> int:
    return len(item.Ls) if kind == "manybody" else 2


def _weight_degrees(kind: str, item: BatchItem) -> tuple:
    """Per-weight-slot packed width (L+1) for an item's apply signature."""
    if kind == "manybody":
        return tuple(L + 1 for L in item.Ls)
    return (item.L1 + 1, item.L2 + 1, item.Lout + 1)


def _bucket_runner(plan: GauntPlan, kind: str) -> Callable:
    """The (ops, ws) -> out body executed once per bucket invocation."""
    if kind == "manybody":
        def run(ops, ws):
            ws_list = list(ws)
            if all(w is None for w in ws_list):
                ws_list = None
            return plan.apply(list(ops), ws_list)
        return run

    def run(ops, ws):
        return plan.apply(ops[0], ops[1], *ws)

    return run


def _bucket_batch_body(run: Callable, kind: str, item: BatchItem,
                       granularity: int, rd, item_ops, item_ws):
    """Trace-time batching: flatten/broadcast/concat/pad the per-item
    operands, execute the core once, slice per-item results back out.

    Layout: each item's leading dims split into (row prefix, inner broadcast
    dims) via `_split_leads`; rows concatenate across items and tail-pad to
    `granularity`.  All of this is shape logic + cheap jnp ops that XLA fuses
    into the single bucket dispatch.
    """
    n_ops = _n_operands(kind, item)
    wdeg = _weight_degrees(kind, item)
    # pass 1: per-item lead splits; concatenation needs identical post-row
    # shapes, so if items disagree on inner dims fall back to a full flatten
    splits = []
    for ops_i, ws_i in zip(item_ops, item_ws):
        prefix, inner = _split_leads([jnp.shape(x)[:-1] for x in ops_i])
        # weights usually broadcast INTO prefix+inner (they are materialized
        # per row below).  A weight whose lead extends BEYOND the operands'
        # broadcast shape broadens the output instead (plan.apply contract:
        # 'w [..., L+1]'), which the row layout cannot express — degrade the
        # item to all-inner (rows=1) and let the backend broadcast natively.
        w_leads = [jnp.shape(w)[:-1] for w in ws_i if w is not None]
        pi = prefix + inner
        if any(jnp.broadcast_shapes(wl, pi) != pi for wl in w_leads):
            prefix, inner = (), jnp.broadcast_shapes(pi, *w_leads)
        splits.append((prefix, inner))
    if len({inner for _, inner in splits}) > 1:
        splits = [(prefix + inner, ()) for prefix, inner in splits]
    prefixes, inner_leads, rows = [], [], []
    ops_flat = [[] for _ in range(n_ops)]   # per operand: per item [rows, *inner, k]
    ws_used = [any(ws[j] is not None for ws in item_ws)
               for j in range(len(wdeg))]
    for t, ops_i in enumerate(item_ops):
        prefix, inner = splits[t]
        r = int(np.prod(prefix)) if prefix else 1
        prefixes.append(prefix)
        inner_leads.append(inner)
        rows.append(r)
        np_ = len(prefix)
        rank = np_ + len(inner)
        for j, x in enumerate(ops_i):
            shp = jnp.shape(x)
            pl = (1,) * (rank - (len(shp) - 1)) + tuple(shp[:-1])
            x = jnp.reshape(x, pl + shp[-1:])
            x = jnp.broadcast_to(x, prefix + pl[np_:] + shp[-1:])
            ops_flat[j].append(jnp.reshape(x, (r,) + pl[np_:] + shp[-1:]))
    if len(item_ops) > 1:
        # same broadcast inner dims, but an operand may still carry an
        # un-materialized size-1 inner dim on one item only
        for col in ops_flat:
            if len({jnp.shape(x)[1:-1] for x in col}) > 1:
                for t, x in enumerate(col):
                    col[t] = jnp.broadcast_to(
                        x, (rows[t],) + inner_leads[t] + (jnp.shape(x)[-1],))
    # weights: flatten each used slot per item (ones where absent) so the
    # concatenation stays row-aligned with the operands
    ws_cat = []
    for j, used in enumerate(ws_used):
        if not used:
            ws_cat.append(None)
            continue
        cols = []
        for t, ws in enumerate(item_ws):
            w = ws[j]
            if w is None:
                cols.append(jnp.ones((rows[t],) + inner_leads[t] + (wdeg[j],),
                                     dtype=rd))
            else:
                w = jnp.broadcast_to(w, prefixes[t] + inner_leads[t] + (wdeg[j],))
                cols.append(jnp.reshape(
                    w, (rows[t],) + inner_leads[t] + (wdeg[j],)).astype(rd))
        ws_cat.append(jnp.concatenate(cols, axis=0))
    ops_cat = [jnp.concatenate(col, axis=0) for col in ops_flat]
    total = sum(rows)
    pad = -(-total // granularity) * granularity - total
    if pad:
        def pad_rows(x, operand):
            # conv_filter directions pad with e_z, not zeros —
            # align_rotation of a zero vector is NaN
            if kind == "conv_filter" and operand == 1:
                ez = jnp.broadcast_to(jnp.asarray([0.0, 0.0, 1.0], x.dtype),
                                      (pad,) + x.shape[1:])
                return jnp.concatenate([x, ez], axis=0)
            return jnp.pad(x, [(0, pad)] + [(0, 0)] * (x.ndim - 1))

        ops_cat = [pad_rows(x, j) for j, x in enumerate(ops_cat)]
        ws_cat = [None if w is None else
                  jnp.pad(w, [(0, pad)] + [(0, 0)] * (w.ndim - 1),
                          constant_values=1.0)
                  for w in ws_cat]
    out = run(tuple(ops_cat), tuple(ws_cat))
    res, off = [], 0
    for t in range(len(item_ops)):
        res.append(jnp.reshape(out[off:off + rows[t]],
                               prefixes[t] + out.shape[1:]))
        off += rows[t]
    return tuple(res)


def _make_bucket_fn(plan: GauntPlan, kind: str, item: BatchItem, donate: bool,
                    mesh, dp: tuple, mode: str, granularity: int) -> Callable:
    """Jit the whole bucket step: flatten/concat/pad -> core -> slice out.

    The pre/post layout work traces into the SAME jitted call as the backend
    math, so one bucket invocation is one dispatch — otherwise the eager
    reshapes/concats would cost more dispatches than the loop being replaced.
    The concatenated row layout entering the core is uniform [rows, *inner,
    k], so the partition spec is the row spec P(dp) with trailing dims
    replicated.
    """
    run = _bucket_runner(plan, kind)
    if mesh is not None and dp:
        from jax.sharding import NamedSharding

        from repro.distributed.sharding import row_pspec

        spec = row_pspec(2, dp)
        if mode == "shard_map":
            from jax.experimental.shard_map import shard_map

            run = shard_map(run, mesh=mesh, in_specs=(spec, spec),
                            out_specs=spec)
        elif mode == "constraint":
            ns = NamedSharding(mesh, spec)
            inner = run

            def run(ops, ws):  # noqa: F811 — deliberate wrap
                con = lambda a: jax.lax.with_sharding_constraint(a, ns)  # noqa: E731
                ops = jax.tree.map(con, ops)
                ws = jax.tree.map(con, ws)
                return jax.lax.with_sharding_constraint(inner(ops, ws), ns)
        else:
            raise ValueError(f"unknown shard mode {mode!r} "
                             "(expected 'constraint' or 'shard_map')")

    rd = _RDTYPE[plan.key.dtype]

    def full(item_ops, item_ws):
        return _bucket_batch_body(run, kind, item, granularity, rd,
                                  item_ops, item_ws)

    # donation hands the per-item operand buffers to XLA (callers must not
    # reuse them after a donated apply); only meaningful on accelerators
    donate_args = (0,) if donate and jax.default_backend() != "cpu" else ()
    return jax.jit(full, donate_argnums=donate_args)


@dataclasses.dataclass(frozen=True)
class _Bucket:
    """Items sharing one degree signature, resolved to one inner plan."""

    item_ids: tuple
    plan: GauntPlan
    fn: Callable = dataclasses.field(repr=False, compare=False)


@dataclasses.dataclass(frozen=True)
class BatchedGauntPlan:
    """A bucketed multi-degree workload; ``apply`` runs one fused invocation
    per bucket (see GauntEngine.plan_batch)."""

    kind: str
    dtype: str
    items: tuple
    buckets: tuple
    granularity: int = 1
    donate: bool = False
    shard: ShardSpec | None = None

    def plans(self) -> list[GauntPlan]:
        return [b.plan for b in self.buckets]

    def describe(self) -> str:
        lines = [f"plan_batch(kind={self.kind}, dtype={self.dtype}, "
                 f"items={len(self.items)}, buckets={len(self.buckets)}, "
                 f"granularity={self.granularity}, donate={self.donate})"]
        for b in self.buckets:
            lines.append(f"  items {list(b.item_ids)} -> {b.plan.describe()}")
        return "\n".join(lines)

    # -- execution ---------------------------------------------------------

    def apply(self, inputs, weights=None):
        """Run every item; returns outputs aligned with ``items``.

        inputs  : sequence (len == len(items)); element i is the operand
                  tuple of item i — (x1, x2) for pairwise, (x, rhat) for
                  conv_filter, the xs sequence for manybody.  Operands of one
                  item share their leading (batch) dims.
        weights : optional sequence aligned with items; element i is the
                  weight tuple of item i ((w1, w2, w3), or per-operand list
                  for manybody; None entries allowed) or None.
        """
        inputs = list(inputs)
        if len(inputs) != len(self.items):
            raise ValueError(f"apply got {len(inputs)} inputs for "
                             f"{len(self.items)} items")
        if weights is None:
            weights = [None] * len(self.items)
        weights = list(weights)
        if len(weights) != len(self.items):
            raise ValueError(f"apply got {len(weights)} weight entries for "
                             f"{len(self.items)} items")
        if self.donate and jax.default_backend() != "cpu":
            inputs, weights = self._copy_donation_aliases(inputs, weights)
        outs = [None] * len(self.items)
        for bucket in self.buckets:
            self._run_bucket(bucket, inputs, weights, outs)
        return outs

    def _copy_donation_aliases(self, inputs, weights):
        """Donating one buffer twice is invalid, and a buffer donated by an
        earlier bucket is DEAD for later ones — so before any bucket runs,
        copy every repeat reference (operand or weight) to an operand that
        will have been donated by then (e.g. selfmix's [x, x, x], or one
        rhat shared across degree items)."""
        donated: set[int] = set()
        for bucket in self.buckets:
            for i in bucket.item_ids:
                ops_i = list(inputs[i])
                for j, x in enumerate(ops_i):
                    if id(x) in donated:
                        ops_i[j] = jnp.copy(x)
                    else:
                        donated.add(id(x))
                inputs[i] = tuple(ops_i)
                w_i = weights[i]
                if w_i is not None:
                    w_i = list(w_i)
                    for j, w in enumerate(w_i):
                        if w is not None and id(w) in donated:
                            w_i[j] = jnp.copy(w)
                    weights[i] = tuple(w_i)
        return inputs, weights

    def _run_bucket(self, bucket: _Bucket, inputs, weights, outs) -> None:
        item0 = self.items[bucket.item_ids[0]]
        n_ops = _n_operands(self.kind, item0)
        wdeg = _weight_degrees(self.kind, item0)
        item_ops, item_ws = [], []
        for i in bucket.item_ids:
            ops_i = tuple(inputs[i])
            if len(ops_i) != n_ops:
                raise ValueError(f"item {i}: expected {n_ops} operands, "
                                 f"got {len(ops_i)}")
            item_ops.append(ops_i)
            w_i = weights[i]
            w_i = tuple(w_i) if w_i is not None else (None,) * len(wdeg)
            if len(w_i) != len(wdeg):
                raise ValueError(f"item {i}: expected {len(wdeg)} weight "
                                 f"slots, got {len(w_i)}")
            item_ws.append(w_i)
        res = bucket.fn(tuple(item_ops), tuple(item_ws))
        for t, i in enumerate(bucket.item_ids):
            outs[i] = res[t]


# --------------------------------------------------------------------------
# cost model (relative real-MAC counts; calibrated coarsely, see DESIGN.md §4)
# --------------------------------------------------------------------------

_C_CPLX = 4.0        # complex MAC = 4 real MACs
_C_FFT = 10.0        # per point per log2 level: tiny-grid FFTs vectorize poorly
_OVERHEAD = 3e4      # per dispatched op: favors fewer, denser ops at small sizes
_INTERPRET_PENALTY = 1e4   # Pallas interpret mode off-TPU is not a real option


def _dims(key: PlanKey):
    B = key.batch_hint or 1
    n1, n2 = 2 * key.L1 + 1, 2 * key.L2 + 1
    N = n1 + n2 - 1
    return B, num_coeffs(key.L1), num_coeffs(key.L2), num_coeffs(key.Lout), n1, n2, N


def _cost_dense_einsum(key: PlanKey) -> float:
    B, d1, d2, do, *_ = _dims(key)
    if key.kind == "channel_mix":
        return 16.0 * B * d1 * d2 * do + _OVERHEAD  # x C1*C2 (unknown): scaled proxy
    if key.kind == "manybody":
        Ls = key.opt("Ls", (key.L1, key.L2))
        total, La = 0.0, Ls[0]
        for L in Ls[1:]:
            total += B * num_coeffs(La) * num_coeffs(L) * num_coeffs(La + L)
            La += L
        return total + _OVERHEAD * len(Ls)
    return B * d1 * d2 * do + _OVERHEAD


def _spectral_common(key: PlanKey, conv: str, packed: bool) -> float:
    B, d1, d2, do, n1, n2, N = _dims(key)
    if packed:  # O(L^3) stacked matmuls
        conv_in = 4.0 * B * (key.L1 + 1) ** 3 + 4.0 * B * (key.L2 + 1) ** 3
        proj = 8.0 * B * (key.Lout + 1) ** 2 * N
    else:  # O(L^4) dense einsum conversions
        conv_in = 2.0 * B * (d1 * n1 * n1 + d2 * n2 * n2)
        proj = _C_CPLX * B * N * N * do
    if conv == "fft":
        c = 3.0 * _C_FFT * B * N * N * max(1.0, math.log2(N * N)) + _C_CPLX * B * N * N
    else:
        c = _C_CPLX * B * N * N * n2 * n2
    n_ops = 8 if not packed else 14
    return conv_in + c + proj + _OVERHEAD * n_ops


def _cost_fft(key):
    if key.kind == "manybody":
        return _cost_manybody_spectral(key, "fft", packed=False)
    return _spectral_common(key, "fft", packed=False)


def _cost_direct(key):
    if key.kind == "manybody":
        return _cost_manybody_spectral(key, "direct", packed=False)
    return _spectral_common(key, "direct", packed=False)


def _cost_packed(key):
    conv = key.opt("conv", "fft")
    if key.kind == "manybody":
        return _cost_manybody_spectral(key, conv, packed=True)
    return _spectral_common(key, conv, packed=True)


def _cost_manybody_spectral(key: PlanKey, conv: str, packed: bool) -> float:
    Ls = key.opt("Ls", (key.L1, key.L2))
    B = key.batch_hint or 1
    Lt = sum(Ls)
    N = 2 * Lt + 1
    convs = _C_FFT * len(Ls) * B * N * N * max(1.0, math.log2(N * N)) if conv == "fft" \
        else _C_CPLX * len(Ls) * B * N * N * (2 * max(Ls) + 1) ** 2
    conv_in = sum(2.0 * B * num_coeffs(L) * (2 * L + 1) ** 2 for L in Ls)
    proj = _C_CPLX * B * N * N * num_coeffs(key.Lout)
    return conv_in + convs + proj + _OVERHEAD * (6 + 2 * len(Ls))


def _cost_fused(key: PlanKey, pallas: bool) -> float:
    B, d1, d2, do, n1, n2, N = _dims(key)
    Nf = 2 * (key.L1 + key.L2) + 2
    G = ((Nf * Nf + 127) // 128) * 128
    c = B * G * (d1 + d2 + do) + _OVERHEAD * 4
    if key.kind == "channel_mix":
        c = 16.0 * B * G * (d1 + d2 + do) + _OVERHEAD * 4
    if pallas:
        c *= 0.5 if jax.default_backend() == "tpu" else _INTERPRET_PENALTY
    return c


def _cost_escn(key: PlanKey) -> float:
    B, d1, d2, do, n1, n2, N = _dims(key)
    Lw = max(key.L1, key.Lout)
    wigner = B * sum((2 * l + 1) ** 4 for l in range(2, Lw + 1)) + \
        2.0 * B * sum((2 * l + 1) ** 2 for l in range(Lw + 1))
    s2f = 2.0 * B * d1 * n1 * n1
    banded = _C_CPLX * B * N * n1 * n1
    proj = _C_CPLX * B * N * N * do
    return wigner + s2f + banded + proj + _OVERHEAD * 10


# --------------------------------------------------------------------------
# backend builders
# --------------------------------------------------------------------------


def _build_dense_einsum(key: PlanKey) -> Callable:
    gd = "float64" if key.dtype == "float64" else "float32"
    rd = _RDTYPE[key.dtype]
    if key.kind == "channel_mix":
        G = constants.gaunt_dense(key.L1, key.L2, key.Lout, gd)

        def apply_mix(x1, x2, w_mix):
            Gj = jnp.asarray(G)
            out = jnp.einsum("...ci,...dj,ijk,cde->...ek",
                             x1.astype(Gj.dtype), x2.astype(Gj.dtype), Gj,
                             w_mix.astype(Gj.dtype))
            return out.astype(rd)

        return apply_mix
    if key.kind == "manybody":
        Ls = key.opt("Ls")

        def apply_mb(xs, weights=None):
            xs = list(xs)
            if weights is not None:
                xs = [_wmul(x, w, L) for x, w, L in zip(xs, weights, Ls)]
            acc, La = xs[0], Ls[0]
            for i, (x, L) in enumerate(zip(xs[1:], Ls[1:])):
                last = i == len(Ls) - 2
                Lt = key.Lout if last else La + L
                G = jnp.asarray(constants.gaunt_dense(La, L, Lt, gd))
                acc = jnp.einsum("...i,...j,ijk->...k",
                                 acc.astype(G.dtype), x.astype(G.dtype), G)
                La += L
            return acc.astype(rd)

        return apply_mb
    G = constants.gaunt_dense(key.L1, key.L2, key.Lout, gd)

    def apply_pair(x1, x2, w1=None, w2=None, w3=None):
        Gj = jnp.asarray(G)
        x1 = _wmul(x1, w1, key.L1).astype(Gj.dtype)
        x2 = _wmul(x2, w2, key.L2).astype(Gj.dtype)
        out = jnp.einsum("...i,...j,ijk->...k", x1, x2, Gj)
        return _wmul(out.astype(rd), w3, key.Lout)

    return apply_pair


def _build_spectral(key: PlanKey, conversion: str, conv: str) -> Callable:
    from .gaunt import conv2d_full, fourier_to_sh, sh_to_fourier  # lazy: gaunt imports engine

    cd = _CDTYPE[key.dtype]
    rd = _RDTYPE[key.dtype]
    # warm constants at plan time so jit tracing never re-runs numpy precompute
    if key.kind != "manybody":
        if conversion == "dense":
            constants.y_dense(key.L1, cd), constants.y_dense(key.L2, cd)
            constants.z_dense(key.L1 + key.L2, key.Lout, cd)
        else:
            constants.y_packed(key.L1, cd), constants.y_packed(key.L2, cd)
            constants.z_packed(key.L1 + key.L2, key.Lout, cd)

    if key.kind == "manybody":
        from .manybody import _tree_convolve

        Ls = key.opt("Ls")
        Ltot = sum(Ls)
        if conversion == "dense":
            for L in Ls:
                constants.y_dense(L, cd)
            constants.z_dense(Ltot, key.Lout, cd)
        else:
            for L in Ls:
                constants.y_packed(L, cd)
            constants.z_packed(Ltot, key.Lout, cd)

        def apply_mb(xs, weights=None):
            grids = []
            for i, (x, L) in enumerate(zip(xs, Ls)):
                if weights is not None and weights[i] is not None:
                    x = _wmul(x, weights[i], L)
                grids.append(sh_to_fourier(x, L, conversion, jnp.dtype(cd)))
            F = _tree_convolve(grids, conv)
            return fourier_to_sh(F, Ltot, key.Lout, conversion, rd)

        return apply_mb

    def apply_pair(x1, x2, w1=None, w2=None, w3=None):
        x1 = _wmul(x1, w1, key.L1)
        x2 = _wmul(x2, w2, key.L2)
        F1 = sh_to_fourier(x1, key.L1, conversion, jnp.dtype(cd))
        F2 = sh_to_fourier(x2, key.L2, conversion, jnp.dtype(cd))
        F3 = conv2d_full(F1, F2, conv)
        out = fourier_to_sh(F3, key.L1 + key.L2, key.Lout, conversion, rd)
        return _wmul(out, w3, key.Lout)

    return apply_pair


def _build_fused(key: PlanKey, pallas: bool) -> Callable:
    rd = _RDTYPE[key.dtype]
    T1, T2, P = constants.fused_matrices(key.L1, key.L2, key.Lout)

    if key.kind == "channel_mix":

        def apply_mix(x1, x2, w_mix):
            T1j, T2j, Pj = jnp.asarray(T1), jnp.asarray(T2), jnp.asarray(P)
            V1 = x1.astype(jnp.float32) @ T1j  # [..., C1, G]
            V2 = x2.astype(jnp.float32) @ T2j  # [..., C2, G]
            V = jnp.einsum("...cg,...dg,cde->...eg", V1, V2, w_mix.astype(V1.dtype))
            return (V @ Pj).astype(rd)

        return apply_mix

    if pallas:
        block_b = key.opt("block_b", 256)

        def apply_pair(x1, x2, w1=None, w2=None, w3=None):
            from repro.kernels.gaunt_fused import gaunt_fused_pallas  # lazy: kernels import core

            x1 = _wmul(x1, w1, key.L1)
            x2 = _wmul(x2, w2, key.L2)
            out = gaunt_fused_pallas(x1, x2, key.L1, key.L2, key.Lout, block_b=block_b)
            return _wmul(out.astype(rd), w3, key.Lout)

        return apply_pair

    def apply_pair(x1, x2, w1=None, w2=None, w3=None):
        T1j, T2j, Pj = jnp.asarray(T1), jnp.asarray(T2), jnp.asarray(P)
        x1 = _wmul(x1, w1, key.L1)
        x2 = _wmul(x2, w2, key.L2)
        v1 = x1.astype(jnp.float32) @ T1j
        v2 = x2.astype(jnp.float32) @ T2j
        out = ((v1 * v2) @ Pj).astype(rd)
        return _wmul(out, w3, key.Lout)

    return apply_pair


def _build_escn(key: PlanKey) -> Callable:
    cd = _CDTYPE[key.dtype]
    rd = _RDTYPE[key.dtype]
    L1, L2, Lout = key.L1, key.L2, key.Lout
    constants.y_dense(L1, cd)
    constants.z_dense(L1 + L2, Lout, cd)
    constants.filter_fourier_col(L2, cd)
    constants.conv_u_index(L1, L2)
    constants.cg_11_blocks(max(L1, Lout))
    fl0 = np.array([math.sqrt((2 * l + 1) / (4 * math.pi)) for l in range(L2 + 1)],
                   dtype=np.float32)

    def apply_conv(x, rhat, w1=None, w2=None, w3=None):
        # lazy: conv.py routes through the engine, so import its helpers at call
        from .conv import align_rotation, apply_wigner_blocks, wigner_blocks_from_rotmat
        from .gaunt import fourier_to_sh, sh_to_fourier

        x = _wmul(x, w1, L1)
        R = align_rotation(rhat.astype(jnp.float32))
        Ds = wigner_blocks_from_rotmat(max(L1, Lout), R)
        x_rot = apply_wigner_blocks(Ds[: L1 + 1], x)
        F1 = sh_to_fourier(x_rot, L1, "dense", jnp.dtype(cd))  # [..., n1, n1]
        # filter coefficients: only m=0 -> single v=0 column, O(L^2)
        fl = jnp.asarray(fl0, dtype=rd)
        if w2 is not None:
            fl = fl * w2.astype(rd)
        cols = jnp.asarray(constants.filter_fourier_col(L2, cd))
        k = jnp.einsum("...l,lu->...u", fl.astype(cols.dtype), cols)  # [..., 2L2+1]
        # banded 1D conv along u for every v column (v support unchanged)
        gidx, mask = constants.conv_u_index(L1, L2)
        kmat = k[..., jnp.asarray(gidx)] * jnp.asarray(mask, dtype=rd)  # [..., N, n1]
        F3 = jnp.einsum("...ti,...iv->...tv", kmat, F1)  # [..., N, n1(v)]
        # pad v axis to the full output grid (v support still |v| <= L1)
        pv = (2 * (L1 + L2) + 1 - (2 * L1 + 1)) // 2
        F3 = jnp.pad(F3, [(0, 0)] * (F3.ndim - 1) + [(pv, pv)])
        out_rot = fourier_to_sh(F3, L1 + L2, Lout, "dense", rd)
        out = apply_wigner_blocks(Ds[: Lout + 1], out_rot, transpose=True)
        return _wmul(out, w3, Lout)

    return apply_conv


def _wrap_conv_filter(key: PlanKey, pair_apply: Callable) -> Callable:
    """Serve kind='conv_filter' on a pairwise backend: materialize Y(rhat)."""

    def apply_conv(x, rhat, w1=None, w2=None, w3=None):
        from .so3 import real_sph_harm_jax

        filt = real_sph_harm_jax(key.L2, rhat).astype(x.dtype)
        return pair_apply(x, filt, w1, w2, w3)

    return apply_conv


register_backend(Backend(
    name="dense_einsum",
    kinds=frozenset({"pairwise", "conv_filter", "manybody", "channel_mix"}),
    build=_build_dense_einsum,
    cost=_cost_dense_einsum,
))
register_backend(Backend(
    name="fft",
    kinds=frozenset({"pairwise", "conv_filter", "manybody"}),
    build=lambda key: _build_spectral(key, "dense", "fft"),
    cost=_cost_fft,
))
register_backend(Backend(
    name="direct",
    kinds=frozenset({"pairwise", "conv_filter", "manybody"}),
    build=lambda key: _build_spectral(key, "dense", "direct"),
    cost=_cost_direct,
))
register_backend(Backend(
    name="packed",
    kinds=frozenset({"pairwise", "conv_filter", "manybody"}),
    build=lambda key: _build_spectral(key, "packed", key.opt("conv", "fft")),
    cost=_cost_packed,
))
register_backend(Backend(
    name="fused_xla",
    kinds=frozenset({"pairwise", "conv_filter", "channel_mix"}),
    build=lambda key: _build_fused(key, pallas=False),
    cost=lambda key: _cost_fused(key, pallas=False),
    dtypes=frozenset({"float32", "bfloat16"}),
))
register_backend(Backend(
    name="fused_pallas",
    kinds=frozenset({"pairwise", "conv_filter"}),
    build=lambda key: _build_fused(key, pallas=True),
    cost=lambda key: _cost_fused(key, pallas=True),
    supports_grad=False,  # pallas_call has no registered VJP
    dtypes=frozenset({"float32", "bfloat16"}),
    needs_interpret=True,
))
register_backend(Backend(
    name="escn_aligned",
    kinds=frozenset({"conv_filter"}),
    build=_build_escn,
    cost=_cost_escn,
))


# --------------------------------------------------------------------------
# the engine
# --------------------------------------------------------------------------


class GauntEngine:
    """Plans, caches, and autotunes Gaunt ops over the backend registry."""

    def __init__(self):
        self._plans: dict[tuple, GauntPlan] = {}
        self._batched: dict[tuple, BatchedGauntPlan] = {}
        self._measured: dict[PlanKey, str] = {}

    # -- public API --------------------------------------------------------

    def plan(self, L1: int | None = None, L2: int | None = None,
             Lout: int | None = None, *, kind: str = "pairwise",
             Ls: tuple | None = None, batch_hint: int | None = None,
             dtype="float32", backend: str | None = None,
             options: dict | None = None, tune: str = "heuristic",
             requires_grad: bool = True) -> GauntPlan:
        """Resolve (and cache) a plan.  ``backend=None`` -> engine selection.

        kind='manybody' takes ``Ls`` (per-operand degrees) instead of L1/L2.
        ``tune`` is 'heuristic' (cost model) or 'measure' (timed autotune).
        """
        if kind not in KINDS:
            raise ValueError(f"unknown kind {kind!r} (expected one of {KINDS})")
        extra = tuple(sorted((options or {}).items()))
        if kind == "manybody":
            if Ls is None or len(Ls) < 2:
                raise ValueError("manybody plans need Ls with >= 2 degrees")
            Ls = tuple(int(L) for L in Ls)
            L1, L2 = max(Ls), min(Ls)
            Lout = sum(Ls) if Lout is None else Lout
            extra = extra + (("Ls", Ls),)
        else:
            if L1 is None or L2 is None:
                raise ValueError(f"kind={kind!r} plans need L1 and L2")
            Lout = L1 + L2 if Lout is None else Lout
        if Lout > (sum(Ls) if kind == "manybody" else L1 + L2):
            raise ValueError("Lout cannot exceed the total degree (Gaunt selection rule)")
        key = PlanKey(L1, L2, Lout, kind, batch_hint, _dtype_str(dtype), extra)
        cache_key = (key, backend, tune, requires_grad)
        hit = self._plans.get(cache_key)
        if hit is not None:
            return hit
        name = backend or self.select(key, tune=tune, requires_grad=requires_grad)
        spec = _REGISTRY.get(name)
        if spec is None:
            raise ValueError(f"unknown backend {name!r}; registered: {sorted(_REGISTRY)}")
        if not spec.eligible(key, requires_grad):
            raise ValueError(f"backend {name!r} cannot serve {key} "
                             f"(requires_grad={requires_grad})")
        apply = spec.build(key)
        if key.kind == "conv_filter" and spec.name != "escn_aligned":
            # generic backends build the pairwise form; materialize Y(rhat)
            apply = _wrap_conv_filter(key, apply)
        p = GauntPlan(key=key, backend=name, apply=apply)
        self._plans[cache_key] = p
        return p

    def plan_batch(self, items, *, kind: str = "pairwise", dtype="float32",
                   backend: str | None = None, tune: str = "heuristic",
                   requires_grad: bool = True, donate: bool = False,
                   shard_spec: ShardSpec | None = None,
                   pad_to: int | None = None) -> BatchedGauntPlan:
        """Plan a ragged multi-degree workload as bucketed fused invocations.

        items: sequence of (L1, L2, Lout[, size]) tuples / dicts / BatchItems
        (manybody items carry ``Ls``).  Items sharing a degree signature form
        one *bucket*: their operands are flattened to rows, concatenated,
        tail-padded to the plan granularity, and executed by a single jitted
        call on the bucket's inner plan — per-item results are sliced back
        out, numerically identical to per-plan loops (all backends are
        row-parallel).  ``donate=True`` donates the concatenated operand
        buffers on accelerators; ``shard_spec`` shards the row axis over the
        mesh's data axes (see :class:`ShardSpec`).  ``pad_to`` forces a row
        granularity (e.g. 128 for lane alignment); the data-parallel device
        count is always folded in so shards stay equal.
        """
        if kind not in KINDS:
            raise ValueError(f"unknown kind {kind!r} (expected one of {KINDS})")
        if kind == "channel_mix":
            raise ValueError("plan_batch does not support kind='channel_mix': "
                             "w_mix is not a row-batched operand (use plan())")
        norm = []
        for it in items:
            it = _as_batch_item(it)
            if kind == "manybody":
                if it.Ls is None or len(it.Ls) < 2:
                    raise ValueError("manybody batch items need Ls with >= 2 degrees")
                if it.Lout is None:
                    it = dataclasses.replace(it, Lout=sum(it.Ls))
            else:
                if it.L1 is None or it.L2 is None:
                    raise ValueError(f"kind={kind!r} batch items need L1 and L2")
                if it.Lout is None:
                    it = dataclasses.replace(it, Lout=it.L1 + it.L2)
            norm.append(it)
        norm = tuple(norm)
        if not norm:
            raise ValueError("plan_batch needs at least one item")
        dts = _dtype_str(dtype)
        mesh, dp = (None, ()) if shard_spec is None else shard_spec.resolve()
        g = max(1, int(pad_to or 1))
        if mesh is not None and dp:
            from repro.distributed import sharding as _sh

            g = math.lcm(g, _sh.dp_size(mesh, dp))
        mode = shard_spec.mode if shard_spec is not None else "constraint"
        # cache the batched plan: the jitted bucket callables must be stable
        # across calls or every eager invocation would recompile
        cache_key = (norm, kind, dts, backend, tune, requires_grad, donate,
                     g, mesh, dp, mode)
        hit = self._batched.get(cache_key)
        if hit is not None:
            return hit
        groups: dict[tuple, list[int]] = {}
        for i, it in enumerate(norm):
            groups.setdefault(it.signature(), []).append(i)
        buckets = []
        for idxs in groups.values():
            it0 = norm[idxs[0]]
            known = [norm[i].size for i in idxs if norm[i].size]
            hint = sum(known) if known else None
            p = self.plan(
                it0.L1, it0.L2, it0.Lout, kind=kind, Ls=it0.Ls,
                batch_hint=hint, dtype=dts, backend=backend,
                options=dict(it0.options) or None, tune=tune,
                requires_grad=requires_grad,
            )
            fn = _make_bucket_fn(p, kind, it0, donate, mesh, dp, mode, g)
            buckets.append(_Bucket(item_ids=tuple(idxs), plan=p, fn=fn))
        bp = BatchedGauntPlan(kind=kind, dtype=dts, items=norm,
                              buckets=tuple(buckets), granularity=g,
                              donate=donate, shard=shard_spec)
        self._batched[cache_key] = bp
        return bp

    def select(self, key: PlanKey, tune: str = "heuristic",
               requires_grad: bool = True) -> str:
        """Pick the backend for ``key`` by cost model or measurement."""
        eligible = [b for b in _REGISTRY.values() if b.eligible(key, requires_grad)]
        if not eligible:
            raise ValueError(f"no eligible backend for {key}")
        if tune == "measure" and _trace_clean():
            hit = self._measured.get(key)
            if hit is not None:
                return hit
            name = self._measure(key, eligible)
            self._measured[key] = name
            return name
        return min(eligible, key=lambda b: b.cost(key)).name

    def plans(self) -> list[GauntPlan]:
        return list(self._plans.values())

    def clear(self) -> None:
        self._plans.clear()
        self._batched.clear()
        self._measured.clear()

    # -- measured autotune -------------------------------------------------

    def _measure(self, key: PlanKey, eligible: list[Backend]) -> str:
        args = _synthetic_inputs(key)
        best_name, best_t = None, float("inf")
        for spec in eligible:
            if spec.needs_interpret and jax.default_backend() != "tpu":
                continue  # interpret-mode timing is meaningless
            try:
                apply = spec.build(key)
                if key.kind == "conv_filter" and spec.name != "escn_aligned":
                    apply = _wrap_conv_filter(key, apply)
                fn = jax.jit(lambda *a: apply(*a))
                jax.block_until_ready(fn(*args))  # compile + warm
                ts = []
                for _ in range(3):
                    t0 = time.perf_counter()
                    jax.block_until_ready(fn(*args))
                    ts.append(time.perf_counter() - t0)
                t = sorted(ts)[1]
            except Exception:  # noqa: BLE001 — a broken backend just loses
                continue
            if t < best_t:
                best_name, best_t = spec.name, t
        if best_name is None:  # everything failed: fall back to the cost model
            return min(eligible, key=lambda b: b.cost(key)).name
        return best_name


def _trace_clean() -> bool:
    try:
        return jax.core.trace_state_clean()
    except Exception:  # noqa: BLE001 — jax internals moved; assume clean
        return True


def _synthetic_inputs(key: PlanKey):
    B = key.batch_hint or 256
    rd = _RDTYPE[key.dtype]
    rng = np.random.default_rng(0)

    def r(*shape):
        return jnp.asarray(rng.normal(size=shape), dtype=rd)

    if key.kind == "pairwise":
        return r(B, num_coeffs(key.L1)), r(B, num_coeffs(key.L2))
    if key.kind == "conv_filter":
        v = rng.normal(size=(B, 3))
        v /= np.linalg.norm(v, axis=-1, keepdims=True)
        return r(B, num_coeffs(key.L1)), jnp.asarray(v, dtype=jnp.float32)
    if key.kind == "manybody":
        Ls = key.opt("Ls")
        return ([r(B, num_coeffs(L)) for L in Ls],)
    # channel_mix: small representative channel counts
    C1 = C2 = E = 4
    return (r(B, C1, num_coeffs(key.L1)), r(B, C2, num_coeffs(key.L2)),
            r(C1, C2, E))


_ENGINE = GauntEngine()


def get_engine() -> GauntEngine:
    """The process-wide engine (plan + autotune caches are shared)."""
    return _ENGINE


def plan(*args, **kw) -> GauntPlan:
    """Module-level shorthand for ``get_engine().plan(...)``."""
    return _ENGINE.plan(*args, **kw)


def plan_batch(*args, **kw) -> BatchedGauntPlan:
    """Module-level shorthand for ``get_engine().plan_batch(...)``."""
    return _ENGINE.plan_batch(*args, **kw)
