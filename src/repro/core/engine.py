"""Unified Gaunt execution engine — one plan/dispatch layer for every Gaunt op.

This repo grew several concrete realizations of the paper's O(L^3) Gaunt
tensor product (dense/packed spectral conversions x fft/direct convolution,
the fused collocation kernel, the eSCN rotation-aligned convolution).  The
engine makes them *backends* behind a single planning API (DESIGN.md §4):

    plan = engine.plan(L1, L2, Lout, kind="pairwise", batch_hint=4096)
    out  = plan.apply(x1, x2, w1=w1)          # paper's w_{l1} w_{l2} w_l hooks

A plan is keyed by ``(L1, L2, Lout, kind, batch_hint, dtype)`` (+ kind
specific extras) and resolved to a registered backend:

    kind         backends
    pairwise     dense_einsum | fft | direct | packed | rfft | fused_xla | fused_pallas
    conv_filter  escn_aligned + every pairwise backend (filter materialized)
    manybody     dense_einsum | fft | direct | packed | rfft
    channel_mix  dense_einsum | fused_xla

Backends carry capability flags (grad support, dtype support, whether Pallas
must run in interpret mode off-TPU); selection is either a closed-form cost
model (``tune="heuristic"``) or measured wall-time on synthetic inputs with
an in-process autotune cache (``tune="measure"``).  Plans and their constants
are cached: planning twice is free, and all numpy precompute lives in the
central :mod:`repro.core.constants` cache.

Thin public wrappers (`GauntTensorProduct`, `EquivariantConv`,
`manybody_gaunt_product`, `gaunt_tp_channel_mix`, the model `_tp` hook) keep
their historical signatures and route here.

Basis residency (DESIGN.md §6): spectral plans accept ``options={"boundary":
(in1, in2, out)}`` with entries in {'sh', 'fourier'} — 'fourier' operands
arrive as Fourier-resident :class:`repro.core.rep.Rep` grids (their SH->F
conversion is skipped), and a 'fourier' output returns a Rep without the
final projection.  ``engine.plan_chain(Ls, Lout)`` plans a whole chained
product (the many-body tree, selfmix stacks): every operand is converted at
most once — identical operands share one (degree-resolved) conversion even
under different per-degree weights — grids combine by 2D convolution, and a
single projection happens at the chain exit, eliminating the interior
``fourier_to_sh . sh_to_fourier`` pairs the looped per-product path pays.
Chains additionally carry their own backend dispatch (DESIGN.md §6.4,
:data:`CHAIN_BACKENDS`): the resident 'tree', the per-product 'looped'
fold, or the n-way collocation kernel ('fused_xla' / 'fused_pallas' — ONE
MXU-resident pallas_call for the whole chain), selected by the measured
autotuner under ``tune='measure'`` and keyed like plans.

Batched execution (DESIGN.md §5): ``engine.plan_batch(items, ...)`` buckets a
ragged multi-degree workload (items sharing an (L1, L2, Lout) signature) into
one padded fused invocation per bucket, with operand buffer donation on the
hot path and sharding-aware dispatch over the mesh's data axes:

    bp  = engine.plan_batch([(2, 2, 4, nE), (1, 1, 2, nN)], donate=True,
                            shard_spec=ShardSpec(mode="shard_map"))
    o1, o2 = bp.apply([(x1, x2), (a, b)])

Residency and batching COMPOSE (no "resident OR scaled" fork): buckets key
on (degree signature, basis/geometry options), so batched items may carry
Fourier-resident ``Rep`` operands (their half/dense grids flatten, concat,
pad, shard, and donate like SH rows), a 'fourier' output boundary returns
resident Reps per item, and ``plan_chain(..., donate=..., shard_spec=...)``
runs whole chains donated/sharded with <= 1 conversion per operand.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from . import constants
from .irreps import l_array, num_coeffs

__all__ = [
    "PlanKey",
    "Backend",
    "GauntPlan",
    "BatchItem",
    "ShardSpec",
    "BatchedGauntPlan",
    "ChainPlan",
    "CHAIN_BACKENDS",
    "GauntEngine",
    "register_backend",
    "available_backends",
    "get_calibration",
    "set_calibration",
    "reset_calibration",
    "spectral_default",
    "expand_degree_weights",
    "get_engine",
    "plan",
    "plan_batch",
    "plan_chain",
]

KINDS = ("pairwise", "conv_filter", "manybody", "channel_mix")

_RDTYPE = {"float32": jnp.float32, "bfloat16": jnp.bfloat16, "float64": jnp.float64}
_CDTYPE = {"float32": "complex64", "bfloat16": "complex64", "float64": "complex128"}


def _dtype_str(dtype) -> str:
    """Normalize any dtype spec (incl. the wrappers' cdtype) to a plan key.

    float64/complex128 requests are demoted to float32 when jax runs with
    x64 disabled (the default): arrays would silently degrade to f32 anyway,
    and keying plans on the *requested* precision would hash
    otherwise-identical plans to different cache entries and build complex128
    constants that every apply immediately downcasts.
    """
    s = jnp.dtype(dtype).name
    if s.startswith("complex"):
        s = "float64" if s == "complex128" else "float32"
    if s == "float64" and not jax.config.jax_enable_x64:
        return "float32"
    if s not in _RDTYPE:
        raise ValueError(f"unsupported dtype {s!r} (expected one of {sorted(_RDTYPE)})")
    return s


def _acc_dtype_str(storage: str) -> str:
    """Accumulation dtype for a storage dtype: always >= f32, never below
    the storage precision — f32 for f32/bf16 storage, f64 for f64 storage.
    The one place the storage/accumulation split is defined (DESIGN.md §3.6).
    """
    return "float64" if storage == "float64" else "float32"


def spectral_default(*Ls: int) -> str:
    """The dense-spectral conv crossover (DESIGN.md §3.2): shift-and-add
    'direct' wins on small grids, 'fft' above.  The ONE home of the
    historical ``conv='auto'`` rule — wrappers, models, and benches all
    call this instead of re-stating the threshold."""
    return "direct" if max(Ls) <= 4 else "fft"


def expand_degree_weights(w, L: int):
    """w [..., L+1] per-degree -> [..., (L+1)^2] packed broadcast.

    The canonical implementation (gaunt.py re-exports it for back-compat).
    """
    return w[..., jnp.asarray(l_array(L).astype(np.int32))]


def _wmul(x, w, L: int):
    return x if w is None else x * expand_degree_weights(w, L).astype(x.dtype)


def _chain_entry_cast(x, rd):
    """THE chain-entry dtype rule — one rule for every chain backend, not
    backend-dependent drift: a non-resident SH operand arriving in a storage
    dtype other than the plan's is cast ONCE here, at entry.  Fourier-
    resident operands are untouched (residency is complex and complex has no
    bf16; the plan's storage dtype re-applies at the SH exit)."""
    return x if jnp.result_type(x) == jnp.dtype(rd) else x.astype(rd)


# --------------------------------------------------------------------------
# the affine gate (DESIGN.md §6.5) — models.gate_apply, given its l=0 scalars
# --------------------------------------------------------------------------

# Y_00 = 1/(2 sqrt(pi)): one unit of SH coefficient 0 is this constant on S^2
_GATE_C0 = 0.5 / math.sqrt(math.pi)


def _gate_mlp(p, s):
    """The gate's scalar MLP: l=0 scalars s [..., C] -> gate g [..., C]."""
    return jax.nn.sigmoid(jax.nn.silu(s @ p["w1"]) @ p["w2"])


def _gate_coeffs(p, s):
    """(g, beta): models.gate_apply in its affine form.

    Given the l=0 scalars s, the gate is  gate(x) = g*x + beta*e0  on packed
    SH coefficients — equivalently  gate(f) = g*f + beta*Y00  pointwise on
    sphere samples — with beta = silu(s) - g*s, so coefficient 0 lands
    exactly on silu(s) while every l > 0 coefficient scales by g.  Being
    affine in the signal (g and beta depend only on s), the gate commutes
    with every linear stage (projection, degree truncation), which is what
    lets it fuse into the collocation kernel as a per-row scale+bias on the
    VMEM-resident product grid — exactly, with zero aliasing.
    """
    g = _gate_mlp(p, s)
    return g, jax.nn.silu(s) - g * s


def _gate_sh(p, x):
    """Apply the gate on packed SH coefficients (== models.gate_apply)."""
    s = x[..., 0]
    g = _gate_mlp(p, s)
    return (x * g[..., None]).at[..., 0].set(jax.nn.silu(s))


def _gate_rep(p, rep):
    """Apply the gate on a Fourier-resident Rep WITHOUT leaving the basis.

    The l=0 scalars come from the z-transform's l0 row — the torus (0,0)
    coefficient is NOT the spherical mean (higher-degree S_l0 modes have
    nonzero torus means), so a bare grid read would be wrong.  The whole
    grid then scales by g, and beta*Y00 lands on the (u,v) = (0,0) mode
    (a constant on the grid IS a pure (0,0) torus coefficient).
    """
    F = rep.data
    L = rep.L
    z0 = jnp.asarray((constants.z_half if rep.form == "half"
                      else constants.z_dense)(L, 0, F.dtype.name)[:, :, 0])
    s = jnp.einsum("...uv,uv->...", F, z0).real
    g, beta = _gate_coeffs(p, s)
    F = F * g[..., None, None].astype(F.dtype)
    vc = 0 if rep.form == "half" else L
    F = F.at[..., L, vc].add((beta * _GATE_C0).astype(F.dtype))
    return dataclasses.replace(rep, data=F)


# --------------------------------------------------------------------------
# plan keys and backend registry
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PlanKey:
    """Identity of a planned Gaunt op (hashable; the plan-cache key).

    ``dtype`` is the *storage* dtype — what operands, SH-side constants and
    outputs are held in ('float32' | 'bfloat16' | 'float64').  The
    accumulation dtype is derived, never stored: always >= f32
    (``acc_dtype``), so a bf16 key means bf16 bytes moved with f32 math.
    """

    L1: int
    L2: int
    Lout: int
    kind: str = "pairwise"
    batch_hint: int | None = None
    dtype: str = "float32"
    # kind/backend-specific knobs, as a sorted tuple of (name, value) pairs:
    # manybody carries ("Ls", (...)); packed carries ("conv", "fft"|"direct").
    extra: tuple = ()

    @property
    def acc_dtype(self) -> str:
        return _acc_dtype_str(self.dtype)

    def opt(self, name: str, default=None):
        return dict(self.extra).get(name, default)

    def with_dtype(self, dtype: str) -> "PlanKey":
        """The same op at a different storage dtype — the 'key family' the
        precision-aware autotuner walks (f32 <-> bf16 siblings)."""
        return dataclasses.replace(self, dtype=dtype)


@dataclasses.dataclass(frozen=True)
class Backend:
    """A registered Gaunt realization with capability flags."""

    name: str
    kinds: frozenset
    build: Callable[[PlanKey], Callable] = dataclasses.field(repr=False, compare=False, default=None)
    cost: Callable[[PlanKey], float] = dataclasses.field(repr=False, compare=False, default=None)
    supports_grad: bool = True
    dtypes: frozenset = frozenset({"float32", "bfloat16", "float64"})
    needs_interpret: bool = False  # Pallas: off-TPU only via (slow) interpret mode
    # spectral backends can take/return Fourier-resident operands (Rep grids)
    fourier_boundary: bool = False
    # conv_filter backends that accept precomputed WignerBlocks geometry
    wigner_geometry: bool = False

    def eligible(self, key: PlanKey, requires_grad: bool) -> bool:
        if key.dtype not in self.dtypes:
            return False
        if requires_grad and not self.supports_grad:
            return False
        bound = key.opt("boundary")
        if bound and "fourier" in bound and not self.fourier_boundary:
            return False
        if key.opt("geometry") and not self.wigner_geometry:
            return False
        if key.kind in self.kinds:
            return True
        # any pairwise backend can serve conv_filter by materializing Y(rhat)
        return key.kind == "conv_filter" and "pairwise" in self.kinds


_REGISTRY: dict[str, Backend] = {}


def register_backend(backend: Backend) -> Backend:
    _REGISTRY[backend.name] = backend
    return backend


def available_backends(kind: str = "pairwise", dtype: str = "float32",
                       requires_grad: bool = True) -> list[str]:
    # same normalization as plan(): a float64 query on an x64-disabled runtime
    # must see the float32 capability set, not a phantom-precision one
    key = PlanKey(1, 1, 2, kind=kind, dtype=_dtype_str(dtype))
    return [b.name for b in _REGISTRY.values() if b.eligible(key, requires_grad)]


@dataclasses.dataclass(frozen=True)
class GauntPlan:
    """A resolved (key, backend) pair; ``apply`` runs the op."""

    key: PlanKey
    backend: str
    apply: Callable = dataclasses.field(repr=False, compare=False)

    def describe(self) -> str:
        k = self.key
        return (f"{k.kind}(L1={k.L1}, L2={k.L2}, Lout={k.Lout}, "
                f"dtype={k.dtype}, batch_hint={k.batch_hint}) -> {self.backend}")


# --------------------------------------------------------------------------
# batched execution (DESIGN.md §5): ragged multi-degree workloads in one
# padded invocation per degree bucket, with donation + sharded dispatch
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class BatchItem:
    """One entry of a batched workload: a degree signature + expected rows.

    ``size`` is a planning hint (feeds the bucket's batch_hint); the actual
    row count comes from the arrays at apply time.  manybody items carry
    ``Ls`` instead of (L1, L2).
    """

    L1: int | None = None
    L2: int | None = None
    Lout: int | None = None
    Ls: tuple | None = None
    size: int | None = None
    options: tuple = ()

    def signature(self) -> tuple:
        return (self.L1, self.L2, self.Lout, self.Ls, self.options)


def _as_batch_item(it) -> BatchItem:
    if isinstance(it, BatchItem):
        return it
    if isinstance(it, dict):
        d = dict(it)
        if "options" in d:
            d["options"] = tuple(sorted(dict(d["options"]).items()))
        if "Ls" in d and d["Ls"] is not None:
            d["Ls"] = tuple(int(L) for L in d["Ls"])
        return BatchItem(**d)
    it = tuple(it)
    if len(it) == 3:
        return BatchItem(L1=it[0], L2=it[1], Lout=it[2])
    if len(it) == 4:
        return BatchItem(L1=it[0], L2=it[1], Lout=it[2], size=it[3])
    raise ValueError(f"batch item {it!r}: expected (L1, L2, Lout[, size]), "
                     "a dict, or a BatchItem")


@dataclasses.dataclass(frozen=True)
class ShardSpec:
    """How a batched apply is laid out over a device mesh.

    mesh : a jax Mesh, or None to use the launcher-registered activation
           mesh (``distributed.sharding.set_activation_mesh``); with neither,
           the spec is inert and execution stays single-device.
    axes : mesh axis names eligible to shard the row axis (dim0 of every
           flattened operand); the subset present in the mesh is used.
    mode : 'constraint' — pjit-style ``with_sharding_constraint`` on operands
           and outputs (SPMD partitioner does the rest); 'shard_map' — the
           bucket body runs per-shard under ``shard_map`` (row-parallel by
           construction, so no collectives are needed).
    """

    mesh: object = None
    axes: tuple = ("pod", "data")
    mode: str = "constraint"

    def resolve(self):
        """-> (mesh, dp_axes) or (None, ()) when no mesh is available."""
        from repro.distributed import sharding as _sh  # lazy: keep core light

        mesh = self.mesh if self.mesh is not None else _sh.get_activation_mesh()
        if mesh is None:
            return None, ()
        axes = _sh.dp_axes(mesh, tuple(self.axes))
        return mesh, axes


def _split_leads(leads: list) -> tuple:
    """Split operand leading shapes into (row prefix, inner broadcast dims).

    The *prefix* is the longest run of leading dims on which every operand
    agrees exactly (after numpy-style right-aligned rank padding) — those
    flatten into the row axis.  The remaining *inner* dims are where the
    operands exploit broadcasting (e.g. one edge direction against C channel
    features); they pass through to the backend, which broadcasts natively —
    flattening them instead would materialize the broadcast and repeat
    shared per-row work (the eSCN Wigner blocks) per inner element.
    """
    full = jnp.broadcast_shapes(*leads)
    n = len(full)
    padded = [(1,) * (n - len(ld)) + tuple(ld) for ld in leads]
    k = 0
    while k < n and all(p[k] == full[k] for p in padded):
        k += 1
    return full[:k], full[k:]


def _n_operands(kind: str, item: BatchItem) -> int:
    return len(item.Ls) if kind == "manybody" else 2


def _weight_degrees(kind: str, item: BatchItem) -> tuple:
    """Per-weight-slot packed width (L+1) for an item's apply signature."""
    if kind == "manybody":
        return tuple(L + 1 for L in item.Ls)
    return (item.L1 + 1, item.L2 + 1, item.Lout + 1)


def _bucket_runner(plan: GauntPlan, kind: str) -> Callable:
    """The (ops, ws) -> out body executed once per bucket invocation."""
    if kind == "manybody":
        def run(ops, ws):
            ws_list = list(ws)
            if all(w is None for w in ws_list):
                ws_list = None
            return plan.apply(list(ops), ws_list)
        return run

    def run(ops, ws):
        return plan.apply(ops[0], ops[1], *ws)

    return run


def _op_parts(op) -> tuple:
    """Decompose a (possibly structured) operand into row-layout leaves.

    Returns ``(leaves, event_ranks, rebuild)``: each leaf batches over its
    leading dims, with ``event_rank`` trailing dims belonging to the math —
    1 for packed SH rows and raw conv directions, 2 for Fourier coefficient
    grids (Rep) and Wigner rotation blocks.  ``rebuild(leaves)`` reassembles
    the operand around new (flattened/concatenated/padded) leaves, so half-
    Hermitian grids concat/pad/slice through the bucket layout exactly like
    SH rows (DESIGN.md §5.1/§6).
    """
    from .conv import WignerBlocks  # lazy: conv routes through the engine
    from .rep import Rep

    if isinstance(op, Rep):
        meta = (op.L, op.basis, op.form)
        return [op.data], (2,), lambda ls: Rep(ls[0], *meta)
    if isinstance(op, WignerBlocks):
        return list(op.blocks), (2,) * len(op.blocks), \
            lambda ls: WignerBlocks(tuple(ls))
    return [op], (1,), lambda ls: ls[0]


def _norm_operand(op, j: int, kind: str, item: BatchItem, form: str):
    """Validate/canonicalize one operand before leaf decomposition: SH Reps
    unwrap to their data, Fourier Reps check their bandlimit against the
    item's degree and coerce to the bucket plan's storage form."""
    from .rep import Rep

    if isinstance(op, Rep):
        if op.basis == "sh":
            return op.data
        degs = item.Ls if kind == "manybody" else (item.L1, item.L2)
        if j < len(degs) and op.L != degs[j]:
            raise ValueError(f"operand {j}: resident bandlimit {op.L} != "
                             f"planned degree {degs[j]}")
        return op.with_form(form)
    return op


def _bucket_batch_body(run: Callable, kind: str, item: BatchItem,
                       granularity: int, rd, form: str, item_ops, item_ws):
    """Trace-time batching: flatten/broadcast/concat/pad the per-item
    operands, execute the core once, slice per-item results back out.

    Operands may be plain SH arrays, Fourier-resident ``Rep`` grids, or
    precomputed ``WignerBlocks`` geometry — each decomposes into row-layout
    leaves (`_op_parts`).  Every item's leading dims split into (row prefix,
    inner broadcast dims) via `_split_leads`; rows concatenate across items
    and tail-pad to `granularity`.  All of this is shape logic + cheap jnp
    ops that XLA fuses into the single bucket dispatch.  A bucket whose plan
    has a 'fourier' output boundary returns resident Reps per item.
    """
    from .rep import Rep

    n_ops = _n_operands(kind, item)
    wdeg = _weight_degrees(kind, item)
    item_parts = []   # per item: per operand (leaves, event_ranks, rebuild)
    for ops_i in item_ops:
        item_parts.append([_op_parts(_norm_operand(op, j, kind, item, form))
                           for j, op in enumerate(ops_i)])
    # structure check per EVENT-RANK signature, not leaf count: a Fourier
    # Rep and a plain SH array both decompose to one leaf, but their grids
    # cannot concatenate — catch the mix here with a real message instead
    # of an opaque downstream concat shape error
    n_leaves = [len(item_parts[0][j][0]) for j in range(n_ops)]
    struct0 = [p[1] for p in item_parts[0]]
    for t, parts in enumerate(item_parts):
        if [p[1] for p in parts] != struct0:
            raise ValueError(f"item {t}: operand structure (Rep/WignerBlocks/"
                             "array mix) differs from the bucket's first item "
                             f"({[p[1] for p in parts]} vs {struct0})")
    # pass 1: per-item lead splits; concatenation needs identical post-row
    # shapes, so if items disagree on inner dims fall back to a full flatten
    splits = []
    for parts_i, ws_i in zip(item_parts, item_ws):
        leads = [jnp.shape(leaf)[: len(jnp.shape(leaf)) - er]
                 for leaves, ers, _ in parts_i
                 for leaf, er in zip(leaves, ers)]
        prefix, inner = _split_leads(leads)
        # weights usually broadcast INTO prefix+inner (they are materialized
        # per row below).  A weight whose lead extends BEYOND the operands'
        # broadcast shape broadens the output instead (plan.apply contract:
        # 'w [..., L+1]'), which the row layout cannot express — degrade the
        # item to all-inner (rows=1) and let the backend broadcast natively.
        w_leads = [jnp.shape(w)[:-1] for w in ws_i if w is not None]
        pi = prefix + inner
        if any(jnp.broadcast_shapes(wl, pi) != pi for wl in w_leads):
            prefix, inner = (), jnp.broadcast_shapes(pi, *w_leads)
        splits.append((prefix, inner))
    if len({inner for _, inner in splits}) > 1:
        splits = [(prefix + inner, ()) for prefix, inner in splits]
    prefixes, inner_leads, rows = [], [], []
    # per operand, per leaf: per item [rows, *inner, *event]
    leaf_cols = [[[] for _ in range(n_leaves[j])] for j in range(n_ops)]
    ws_used = [any(ws[j] is not None for ws in item_ws)
               for j in range(len(wdeg))]
    for t, parts_i in enumerate(item_parts):
        prefix, inner = splits[t]
        r = int(np.prod(prefix)) if prefix else 1
        prefixes.append(prefix)
        inner_leads.append(inner)
        rows.append(r)
        np_ = len(prefix)
        rank = np_ + len(inner)
        for j, (leaves, ers, _) in enumerate(parts_i):
            for q, (x, er) in enumerate(zip(leaves, ers)):
                shp = jnp.shape(x)
                ev = tuple(shp[len(shp) - er:])
                pl = (1,) * (rank - (len(shp) - er)) + tuple(shp[: len(shp) - er])
                x = jnp.reshape(x, pl + ev)
                x = jnp.broadcast_to(x, prefix + pl[np_:] + ev)
                leaf_cols[j][q].append(jnp.reshape(x, (r,) + pl[np_:] + ev))
    if len(item_ops) > 1:
        # same broadcast inner dims, but a leaf may still carry an
        # un-materialized size-1 inner dim on one item only
        for j in range(n_ops):
            for q, col in enumerate(leaf_cols[j]):
                er = item_parts[0][j][1][q]
                if len({jnp.shape(x)[1: x.ndim - er] for x in col}) > 1:
                    for t, x in enumerate(col):
                        ev = tuple(jnp.shape(x)[x.ndim - er:])
                        col[t] = jnp.broadcast_to(
                            x, (rows[t],) + inner_leads[t] + ev)
    # weights: flatten each used slot per item (ones where absent) so the
    # concatenation stays row-aligned with the operands
    ws_cat = []
    for j, used in enumerate(ws_used):
        if not used:
            ws_cat.append(None)
            continue
        cols = []
        for t, ws in enumerate(item_ws):
            w = ws[j]
            if w is None:
                cols.append(jnp.ones((rows[t],) + inner_leads[t] + (wdeg[j],),
                                     dtype=rd))
            else:
                w = jnp.broadcast_to(w, prefixes[t] + inner_leads[t] + (wdeg[j],))
                cols.append(jnp.reshape(
                    w, (rows[t],) + inner_leads[t] + (wdeg[j],)).astype(rd))
        ws_cat.append(jnp.concatenate(cols, axis=0))
    total = sum(rows)
    pad = -(-total // granularity) * granularity - total
    ops_cat = []
    for j in range(n_ops):
        _, ers, rebuild = item_parts[0][j]
        cat = []
        for q, col in enumerate(leaf_cols[j]):
            x = jnp.concatenate(col, axis=0)
            if pad:
                if kind == "conv_filter" and j == 1 and ers[q] == 1:
                    # raw conv directions pad with e_z, not zeros —
                    # align_rotation of a zero vector is NaN (precomputed
                    # Wigner blocks and grids pad with inert zero rows)
                    ez = jnp.broadcast_to(jnp.asarray([0.0, 0.0, 1.0], x.dtype),
                                          (pad,) + x.shape[1:])
                    x = jnp.concatenate([x, ez], axis=0)
                else:
                    x = jnp.pad(x, [(0, pad)] + [(0, 0)] * (x.ndim - 1))
            cat.append(x)
        ops_cat.append(rebuild(cat))
    if pad:
        ws_cat = [None if w is None else
                  jnp.pad(w, [(0, pad)] + [(0, 0)] * (w.ndim - 1),
                          constant_values=1.0)
                  for w in ws_cat]
    out = run(tuple(ops_cat), tuple(ws_cat))
    out_leaf = out.data if isinstance(out, Rep) else out
    res, off = [], 0
    for t in range(len(item_ops)):
        o = jnp.reshape(out_leaf[off:off + rows[t]],
                        prefixes[t] + out_leaf.shape[1:])
        if isinstance(out, Rep):
            o = Rep(o, out.L, out.basis, out.form)
        res.append(o)
        off += rows[t]
    return tuple(res)


def _row_constraint(mesh, dp: tuple) -> Callable:
    """The one home of the rank-aware row rule: dim0 of a leaf shards over
    the dp axes, everything else replicates (used by `_shard_rows`'
    constraint mode and the chain plans' grid/exit constraints)."""
    from repro.distributed.sharding import row_sharding

    def con(a):
        return jax.lax.with_sharding_constraint(
            a, row_sharding(mesh, jnp.ndim(a), dp))

    return con


def _shard_rows(run: Callable, mesh, dp: tuple, mode: str) -> Callable:
    """Wrap a row-layout callable in sharded dispatch over the mesh's data
    axes.  Every array leaf entering/leaving ``run`` is [rows, ...] with dim0
    the concatenated row axis, but ranks differ per leaf (SH rows [rows, k],
    half/dense grids [rows, n, nv], Wigner blocks [rows, d, d]) — so specs
    are built rank-aware per leaf at trace time: dim0 shards over ``dp``,
    everything else replicates.
    """
    if mesh is None or not dp:
        return run
    from repro.distributed.sharding import row_pspec

    if mode == "constraint":
        con = _row_constraint(mesh, dp)

        def sharded(*args):
            args = jax.tree.map(con, args)
            return jax.tree.map(con, run(*args))

        return sharded
    if mode == "shard_map":
        from jax.experimental.shard_map import shard_map

        def sharded(*args):
            in_specs = jax.tree.map(lambda a: row_pspec(jnp.ndim(a), dp), args)
            out_sds = jax.eval_shape(run, *args)
            out_specs = jax.tree.map(
                lambda s: row_pspec(len(s.shape), dp), out_sds)
            return shard_map(run, mesh=mesh, in_specs=tuple(in_specs),
                             out_specs=out_specs)(*args)

        return sharded
    raise ValueError(f"unknown shard mode {mode!r} "
                     "(expected 'constraint' or 'shard_map')")


def _make_bucket_fn(plan: GauntPlan, kind: str, item: BatchItem, donate: bool,
                    mesh, dp: tuple, mode: str, granularity: int) -> Callable:
    """Jit the whole bucket step: flatten/concat/pad -> core -> slice out.

    The pre/post layout work traces into the SAME jitted call as the backend
    math, so one bucket invocation is one dispatch — otherwise the eager
    reshapes/concats would cost more dispatches than the loop being replaced.
    The concatenated layout entering the core is a uniform row layout, so
    sharding is the rank-aware row spec per leaf (`_shard_rows`).
    """
    run = _shard_rows(_bucket_runner(plan, kind), mesh, dp, mode)
    rd = _RDTYPE[plan.key.dtype]
    # the storage form resident (Rep) operands are coerced to before their
    # grids enter the row layout — must match what the backend consumes
    form = "half" if plan.backend == "rfft" else "dense"

    def full(item_ops, item_ws):
        return _bucket_batch_body(run, kind, item, granularity, rd, form,
                                  item_ops, item_ws)

    # donation hands the per-item operand buffers to XLA (callers must not
    # reuse them after a donated apply); only meaningful on accelerators
    donate_args = (0,) if donate and jax.default_backend() != "cpu" else ()
    return jax.jit(full, donate_argnums=donate_args)


@dataclasses.dataclass(frozen=True)
class _Bucket:
    """Items sharing one degree signature, resolved to one inner plan."""

    item_ids: tuple
    plan: GauntPlan
    fn: Callable = dataclasses.field(repr=False, compare=False)


@dataclasses.dataclass(frozen=True)
class BatchedGauntPlan:
    """A bucketed multi-degree workload; ``apply`` runs one fused invocation
    per bucket (see GauntEngine.plan_batch)."""

    kind: str
    dtype: str
    items: tuple
    buckets: tuple
    granularity: int = 1
    donate: bool = False
    shard: ShardSpec | None = None

    def plans(self) -> list[GauntPlan]:
        return [b.plan for b in self.buckets]

    def describe(self) -> str:
        lines = [f"plan_batch(kind={self.kind}, dtype={self.dtype}, "
                 f"items={len(self.items)}, buckets={len(self.buckets)}, "
                 f"granularity={self.granularity}, donate={self.donate})"]
        for b in self.buckets:
            lines.append(f"  items {list(b.item_ids)} -> {b.plan.describe()}")
        return "\n".join(lines)

    # -- execution ---------------------------------------------------------

    def apply(self, inputs, weights=None):
        """Run every item; returns outputs aligned with ``items``.

        inputs  : sequence (len == len(items)); element i is the operand
                  tuple of item i — (x1, x2) for pairwise, (x, rhat) for
                  conv_filter, the xs sequence for manybody.  Operands of one
                  item share their leading (batch) dims.
        weights : optional sequence aligned with items; element i is the
                  weight tuple of item i ((w1, w2, w3), or per-operand list
                  for manybody; None entries allowed) or None.
        """
        inputs = list(inputs)
        if len(inputs) != len(self.items):
            raise ValueError(f"apply got {len(inputs)} inputs for "
                             f"{len(self.items)} items")
        if weights is None:
            weights = [None] * len(self.items)
        weights = list(weights)
        if len(weights) != len(self.items):
            raise ValueError(f"apply got {len(weights)} weight entries for "
                             f"{len(self.items)} items")
        if self.donate and jax.default_backend() != "cpu":
            inputs, weights = self._copy_donation_aliases(inputs, weights)
        outs = [None] * len(self.items)
        for bucket in self.buckets:
            self._run_bucket(bucket, inputs, weights, outs)
        return outs

    def _copy_donation_aliases(self, inputs, weights):
        """Donating one buffer twice is invalid, and a buffer donated by an
        earlier bucket is DEAD for later ones — so before any bucket runs,
        copy every repeat reference (operand or weight) to a buffer that
        will have been donated by then (e.g. selfmix's [x, x, x], or one
        rhat shared across degree items).  Dedup runs per LEAF buffer, not
        per operand object: structured operands (Rep grids, WignerBlocks)
        are freshly-wrapped pytrees whose ``id()`` differs even when their
        underlying grid buffers are shared — comparing wrapper ids would
        donate one grid twice."""
        donated: set[int] = set()
        for bucket in self.buckets:
            for i in bucket.item_ids:
                ops_i = list(inputs[i])
                for j, x in enumerate(ops_i):
                    leaves, _, rebuild = _op_parts(x)
                    fresh, copied = [], False
                    for leaf in leaves:
                        if id(leaf) in donated:
                            leaf = jnp.copy(leaf)
                            copied = True
                        else:
                            donated.add(id(leaf))
                        fresh.append(leaf)
                    if copied:
                        ops_i[j] = rebuild(fresh)
                inputs[i] = tuple(ops_i)
                w_i = weights[i]
                if w_i is not None:
                    w_i = list(w_i)
                    for j, w in enumerate(w_i):
                        if w is not None and id(w) in donated:
                            w_i[j] = jnp.copy(w)
                    weights[i] = tuple(w_i)
        return inputs, weights

    def _run_bucket(self, bucket: _Bucket, inputs, weights, outs) -> None:
        item0 = self.items[bucket.item_ids[0]]
        n_ops = _n_operands(self.kind, item0)
        wdeg = _weight_degrees(self.kind, item0)
        item_ops, item_ws = [], []
        for i in bucket.item_ids:
            ops_i = tuple(inputs[i])
            if len(ops_i) != n_ops:
                raise ValueError(f"item {i}: expected {n_ops} operands, "
                                 f"got {len(ops_i)}")
            item_ops.append(ops_i)
            w_i = weights[i]
            w_i = tuple(w_i) if w_i is not None else (None,) * len(wdeg)
            if len(w_i) != len(wdeg):
                raise ValueError(f"item {i}: expected {len(wdeg)} weight "
                                 f"slots, got {len(w_i)}")
            item_ws.append(w_i)
        res = bucket.fn(tuple(item_ops), tuple(item_ws))
        for t, i in enumerate(bucket.item_ids):
            outs[i] = res[t]


# --------------------------------------------------------------------------
# chain plans: whole chained products, Fourier-resident between steps
# (DESIGN.md §6) — each operand converts at most once, one projection at exit;
# or collapsed entirely into the n-way collocation kernel (§6.4)
# --------------------------------------------------------------------------

# chain-level backend dispatch (DESIGN.md §6.4):
#   tree         — resident spectral pass, divide-and-conquer grid combine
#   looped       — per-product pairwise fold, full round trip each step (the
#                  pre-residency strategy, kept as an autotune candidate)
#   fused_xla    — n-way collocation (sample*multiply*project) in plain jnp
#   fused_pallas — the same collocation as ONE MXU-resident pallas_call
CHAIN_BACKENDS = ("tree", "looped", "fused_xla", "fused_pallas")


@dataclasses.dataclass(frozen=True)
class ChainPlan:
    """A chained Gaunt product  x_1 (x) x_2 (x) ... (x) x_n  planned as one
    Fourier-resident pass.

    ``apply(xs, weights=None, w_out=None, out_basis='sh')``:
      xs      : per-operand SH arrays, SH Reps, or Fourier-resident Reps
                (residents skip conversion entirely).
      weights : per-operand per-degree weights [..., L_i+1] (None entries ok).
                Identical operand arrays convert ONCE even under different
                weights (degree-resolved conversion, `sh_to_fourier_bydeg`).
      w_out   : per-degree output weights, applied after the exit projection.
      out_basis: 'sh' projects to degrees <= Lout; 'fourier' returns the
                resident product Rep (requires Lout == sum(Ls), no w_out).

    Versus the looped per-product left fold (2(n-1) sh->F + (n-1) F->sh),
    a chain runs at most n sh->F and exactly one F->sh — eliminating
    ``interior_pairs_eliminated`` = n-2 interior conversion pairs, plus one
    more sh->F per duplicate operand.  Numerically identical to the looped
    path up to dtype roundoff (2D convolution is associative).

    Execution knobs (plan_chain): ``donate`` hands the unique operand
    buffers to XLA through ``apply_jit`` (callers must not reuse them);
    ``shard`` = (mesh, dp_axes, mode) runs the chain row-sharded — converted
    grids and the exit projection carry rank-aware row constraints, and with
    mode='shard_map' the grid-combination stage runs per-shard.
    """

    Ls: tuple
    Lout: int
    conversion: str          # 'dense' | 'half'
    conv: str                # 'fft' | 'direct' | 'rfft'
    dtype: str
    tree: bool
    donate: bool = False
    shard: tuple = (None, (), "constraint")   # (mesh, dp_axes, mode)
    backend: str = "tree"    # one of CHAIN_BACKENDS (DESIGN.md §6.4)
    gate: bool = False       # fused pointwise gate stage (DESIGN.md §6.5)
    apply: Callable = dataclasses.field(repr=False, compare=False, default=None)
    _jit_cache: dict = dataclasses.field(default_factory=dict, repr=False,
                                         compare=False)

    def apply_jit(self, xs, weights=None, w_out=None, out_basis: str = "sh",
                  gate_params=None):
        """``apply`` behind a cached ``jax.jit`` — the default consumer route.

        Duplicate operands are detected BEFORE the jit boundary: jit hands
        two identical arrays to two distinct tracers, which would defeat the
        shared-operand single conversion, so the compiled chain closes over
        the duplication pattern and sees each unique operand exactly once.
        With ``donate`` the unique operand list is donated to XLA (dedup
        also means a shared operand's buffer is never donated twice).

        Gated plans (``plan_chain(..., gate=True)``) REQUIRE ``gate_params``
        (the models' gate MLP dict {"w1", "w2"}); ungated plans reject it —
        the gate changes the plan's math, so it must be part of the plan
        identity, not a per-call surprise.
        """
        from .rep import Rep

        if self.gate and gate_params is None:
            raise ValueError("this chain plan was built with gate=True; "
                             "apply needs gate_params={'w1', 'w2'}")
        if gate_params is not None and not self.gate:
            raise ValueError("gate_params passed to an ungated chain plan — "
                             "build it with plan_chain(..., gate=True)")
        xs = list(xs)
        uniq, idx_map, seen = [], [], {}
        for x in xs:
            # dedup by the underlying BUFFER (plus Rep meta), not the
            # wrapper: two Rep wrappers around one grid are the same
            # operand — and under donation the same donation target
            dk = (("rep", id(x.data), x.L, x.basis, x.form)
                  if isinstance(x, Rep) else id(x))
            k = seen.get(dk)
            if k is None:
                k = seen[dk] = len(uniq)
                uniq.append(x)
            idx_map.append(k)
        ws = list(weights) if weights is not None else None
        key = (tuple(idx_map),
               None if ws is None else tuple(w is not None for w in ws),
               w_out is not None, out_basis)
        fn = self._jit_cache.get(key)
        if fn is None:
            imap = tuple(idx_map)
            gated = self.gate

            def run(uniq, ws, w_out, gp):
                kw = {"gate_params": gp} if gated else {}
                return self.apply([uniq[i] for i in imap], weights=ws,
                                  w_out=w_out, out_basis=out_basis, **kw)

            donate_args = (0,) if self.donate and \
                jax.default_backend() != "cpu" else ()
            fn = self._jit_cache[key] = jax.jit(run, donate_argnums=donate_args)
        return fn(uniq, ws, w_out, gate_params)

    @property
    def interior_pairs_eliminated(self) -> int:
        """fourier_to_sh . sh_to_fourier pairs the looped path pays and this
        plan does not (excludes extra savings from duplicate operands)."""
        return max(0, len(self.Ls) - 2)

    def conversion_counts(self, n_unique: int | None = None) -> dict:
        """{'chain': (s2f, f2s), 'looped': (s2f, f2s)} conversion tallies."""
        n = len(self.Ls)
        return {"chain": (n if n_unique is None else n_unique, 1),
                "looped": (2 * (n - 1), n - 1)}

    def describe(self) -> str:
        g = " +gate" if self.gate else ""
        if self.backend.startswith("fused"):
            return (f"chain(Ls={list(self.Ls)}, Lout={self.Lout}, "
                    f"dtype={self.dtype}) -> {self.backend}{g} "
                    f"[collocation: 1 dispatch, 0 conversions"
                    f"{', fused pointwise gate' if self.gate else ''}]")
        return (f"chain(Ls={list(self.Ls)}, Lout={self.Lout}, "
                f"conversion={self.conversion}, conv={self.conv}, "
                f"dtype={self.dtype}, tree={self.tree}) -> {self.backend}{g} "
                f"[-{self.interior_pairs_eliminated} interior pairs]")


def _build_chain(Ls: tuple, Lout: int, conversion: str, conv: str,
                 dtype: str, tree: bool, mesh=None, dp: tuple = (),
                 mode: str = "constraint") -> Callable:
    cd = _CDTYPE[dtype]
    rd = _RDTYPE[dtype]
    form = "half" if conversion == "half" else "dense"
    Ltot = sum(Ls)
    _warm_spectral_constants(conversion, Ls, Ltot, Lout, cd)

    def _row_con(a, er: int):
        """Rank-aware row constraint: shard dim0 over dp, replicate the rest
        (a no-op for unbatched leaves — a bare [n, nv] grid has no row axis)."""
        if mesh is None or not dp or jnp.ndim(a) <= er:
            return a
        return _row_constraint(mesh, dp)(a)

    def apply(xs, weights=None, w_out=None, out_basis: str = "sh"):
        from .gaunt import fourier_to_sh, sh_to_fourier, sh_to_fourier_bydeg
        from .manybody import _tree_convolve
        from .rep import Rep

        xs = list(xs)
        if len(xs) != len(Ls):
            raise ValueError(f"chain got {len(xs)} operands for degrees {Ls}")
        ws = list(weights) if weights is not None else [None] * len(xs)
        if len(ws) != len(xs):
            raise ValueError(f"chain got {len(ws)} weight entries for "
                             f"{len(xs)} operands")
        grids: list = [None] * len(xs)
        groups: dict[int, list[int]] = {}
        for i, x in enumerate(xs):
            if isinstance(x, Rep):
                if x.is_fourier:
                    if x.L != Ls[i]:
                        raise ValueError(f"operand {i}: resident bandlimit "
                                         f"{x.L} != planned degree {Ls[i]}")
                    if ws[i] is not None:
                        raise ValueError("resident operands cannot take "
                                         "per-degree weights (apply in SH)")
                    grids[i] = x.with_form(form).data
                    continue
                xs[i] = x.data
            groups.setdefault(id(xs[i]), []).append(i)
        for idxs in groups.values():
            # entry cast AFTER id-grouping so shared-operand dedup still sees
            # the caller's buffers (see _chain_entry_cast)
            x, L = _chain_entry_cast(xs[idxs[0]], rd), Ls[idxs[0]]
            w_ids = {id(ws[i]) for i in idxs}
            if len(idxs) == 1 or len(w_ids) == 1:
                # one conversion; duplicates (same weights too) share the grid
                F = sh_to_fourier(_wmul(x, ws[idxs[0]], L), L, conversion,
                                  jnp.dtype(cd))
                for i in idxs:
                    grids[i] = F
            else:
                # shared operand, different weights: ONE degree-resolved
                # conversion + a cheap per-variant degree combination
                Fl = sh_to_fourier_bydeg(x, L, conversion, jnp.dtype(cd))
                for i in idxs:
                    if ws[i] is None:
                        grids[i] = jnp.sum(Fl, axis=-3)
                    else:
                        grids[i] = jnp.einsum("...l,...luv->...uv",
                                              ws[i].astype(Fl.dtype), Fl)
        def combine(gs):
            if tree:
                return _tree_convolve(list(gs), conv, herm=(form == "half"))
            from .gaunt import conv2d_full, conv2d_herm

            fn = conv2d_herm if form == "half" else conv2d_full
            F = gs[0]
            for G in gs[1:]:
                F = fn(F, G, conv)
            return F

        grids = [_row_con(g, 2) for g in grids]
        # per-shard grid combination needs every grid batched over ONE shared
        # row axis (broadcast/unbatched operands cannot row-shard).  Ragged
        # row counts are handled by a pad/slice step folded in here: rows
        # zero-pad to the dp device count (zero grids convolve to zero — the
        # pad rows are inert) and the combined grid slices back, so chains no
        # longer require dim0 to divide the device count (the batched buckets
        # already padded to the lcm; now chains do too).
        use_map = (mesh is not None and dp and mode == "shard_map"
                   and all(jnp.ndim(g) > 2 for g in grids)
                   and len({jnp.shape(g)[0] for g in grids}) == 1)
        if use_map:
            from repro.distributed import sharding as _sh

            rows = jnp.shape(grids[0])[0]
            pad = -rows % _sh.dp_size(mesh, dp)
            if pad:
                grids = [jnp.pad(g, [(0, pad)] + [(0, 0)] * (jnp.ndim(g) - 1))
                         for g in grids]
            F = _shard_rows(combine, mesh, dp, "shard_map")(tuple(grids))
            if pad:
                F = F[:rows]
        else:
            F = combine(tuple(grids))
        if out_basis == "fourier":
            if w_out is not None:
                raise ValueError("w_out applies in SH; project first")
            if Lout != Ltot:
                raise ValueError(f"out_basis='fourier' keeps the full grid "
                                 f"(L={Ltot}); plan with Lout={Ltot} or "
                                 "project to SH")
            return Rep(_row_con(F, 2), Ltot, "fourier", form)
        out = fourier_to_sh(F, Ltot, Lout, conversion, rd)
        return _row_con(_wmul(out, w_out, Lout), 1)

    return apply


def _build_chain_looped(Ls: tuple, Lout: int, dtype: str,
                        engine: "GauntEngine") -> Callable:
    """The pre-residency strategy as a chain backend: a sequential left fold
    of pairwise spectral plans, paying the full SH round trip per step —
    kept so the measured chain autotuner prices what residency buys."""
    rd = _RDTYPE[dtype]

    def apply(xs, weights=None, w_out=None, out_basis: str = "sh"):
        from .rep import Rep

        if out_basis != "sh":
            raise ValueError("the looped chain backend has no resident exit; "
                             "plan with backend='tree' for out_basis='fourier'")
        xs = list(xs)
        ws = list(weights) if weights is not None else [None] * len(xs)
        if len(xs) != len(Ls) or len(ws) != len(xs):
            raise ValueError(f"chain got {len(xs)} operands / {len(ws)} "
                             f"weight entries for degrees {Ls}")
        for i, x in enumerate(xs):
            if isinstance(x, Rep):
                # a resident operand must leave the basis here (lossless at
                # its own bandlimit) — the looped fold works in SH
                xs[i] = x.to_sh(rdtype=rd).data if x.is_fourier else x.data
            xs[i] = _chain_entry_cast(xs[i], rd)
        acc = _wmul(xs[0], ws[0], Ls[0])
        La = Ls[0]
        for i, (x, L) in enumerate(zip(xs[1:], Ls[1:]), start=1):
            Lt = Lout if i == len(Ls) - 1 else La + L
            p = engine.plan(La, L, Lt, kind="pairwise", dtype=dtype,
                            backend=spectral_default(La, L))
            acc = p.apply(acc, x, None, ws[i])
            La += L
        return _wmul(acc.astype(rd), w_out, Lout)

    return apply


def _build_chain_fused(Ls: tuple, Lout: int, dtype: str,
                       pallas: bool, gate: bool = False) -> Callable:
    """The n-way collocation chain (DESIGN.md §6.4): sample every operand
    onto the shared alias-free product grid, multiply pointwise n-way,
    project once — ONE dispatch (`fused_pallas`: one MXU-resident
    pallas_call; `fused_xla`: the same matrices in plain jnp).  Zero basis
    conversions: Fourier-resident operands enter as grids through the
    grid-evaluation sampling matrix, and a 'fourier' exit leaves the half
    product grid resident.

    ``gate=True`` fuses the models' equivariant gate into the kernel's
    pointwise stage (DESIGN.md §6.5): the product's l=0 scalars are a cheap
    multilinear form of the operands (`constants.chain_l0` — they cannot
    come from the kernel's own output without a second dispatch), the gate
    MLP turns them into per-row (g, beta) outside the kernel, and the
    kernel applies ``v <- v*g + beta*Y00`` on the VMEM-resident product
    values before projection — still ONE `pallas_call`, exact (the gate is
    affine given s), and valid for both the SH and the resident exit."""
    from repro.core import constants as _c

    rd = _RDTYPE[dtype]
    acc = jnp.dtype(_acc_dtype_str(dtype))
    Ltot = sum(Ls)
    # warm the all-SH matrices at build time with the EXACT argument tuples
    # the runners use (lru_cache keys on raw args, so entries=None would
    # warm a duplicate); resident-entry variants build lazily on first use.
    # Mixed precision requests TWO sets: T at storage dtype, P at acc dtype.
    _c.chain_matrices(tuple(Ls), Lout, ("sh",) * len(Ls), "sh", dtype=dtype)
    if dtype != _acc_dtype_str(dtype):
        _c.chain_matrices(tuple(Ls), Lout, ("sh",) * len(Ls), "sh",
                          dtype=_acc_dtype_str(dtype))
    if gate:
        _c.chain_l0(tuple(Ls), ("sh",) * len(Ls))

    def apply(xs, weights=None, w_out=None, out_basis: str = "sh",
              gate_params=None):
        from repro.kernels.gaunt_fused import (gaunt_chain_fused_pallas,
                                               gaunt_chain_fused_xla)
        from .rep import Rep

        if gate and gate_params is None:
            raise ValueError("gated chain plan requires gate_params")
        if gate_params is not None and not gate:
            raise ValueError("gate_params on an ungated chain plan — build "
                             "it with plan_chain(..., gate=True)")
        xs = list(xs)
        if len(xs) != len(Ls):
            raise ValueError(f"chain got {len(xs)} operands for degrees {Ls}")
        ws = list(weights) if weights is not None else [None] * len(xs)
        if len(ws) != len(xs):
            raise ValueError(f"chain got {len(ws)} weight entries for "
                             f"{len(xs)} operands")
        entries, arrs = [], []
        for i, x in enumerate(xs):
            if isinstance(x, Rep) and x.is_fourier:
                if x.L != Ls[i]:
                    raise ValueError(f"operand {i}: resident bandlimit {x.L} "
                                     f"!= planned degree {Ls[i]}")
                if ws[i] is not None:
                    raise ValueError("resident operands cannot take per-degree "
                                     "weights (apply in SH)")
                entries.append("grid")
                arrs.append(x.with_form("half").data)
            else:
                if isinstance(x, Rep):
                    x = x.data
                entries.append("sh")
                arrs.append(_wmul(_chain_entry_cast(x, rd), ws[i], Ls[i]))
        if out_basis == "fourier":
            if w_out is not None:
                raise ValueError("w_out applies in SH; project first")
            if Lout != Ltot:
                raise ValueError(f"out_basis='fourier' keeps the full grid "
                                 f"(L={Ltot}); plan with Lout={Ltot} or "
                                 "project to SH")
        gate_arg = None
        if gate:
            # the product's l=0 scalars as a multilinear form of the
            # (already weighted) operands; grid entries contract through
            # their real-stacked form, mirroring the kernel's preparation
            flat = []
            for a, e in zip(arrs, entries):
                if e == "grid":
                    Fl = a.reshape(a.shape[:-2] + (-1,))
                    a = jnp.concatenate([Fl.real, Fl.imag], axis=-1)
                flat.append(a.astype(acc))
            M = jnp.asarray(_c.chain_l0(tuple(Ls), tuple(entries)), acc)
            letters = "abcdefghij"[: len(Ls)]
            expr = (",".join("..." + c for c in letters)
                    + "," + letters + "->...")
            s = jnp.einsum(expr, *flat, M)
            g, beta = _gate_coeffs(gate_params, s)
            gate_arg = (g, beta * _GATE_C0)
        fn = gaunt_chain_fused_pallas if pallas else gaunt_chain_fused_xla
        out = fn(arrs, Ls, Lout, entries=tuple(entries),
                 out_entry="grid" if out_basis == "fourier" else "sh",
                 dtype=dtype, gate=gate_arg)
        if out_basis == "fourier":
            from .rep import Rep as _Rep

            return _Rep(out, Ltot, "fourier", "half")
        return _wmul(out.astype(rd), w_out, Lout)

    return apply


def _wrap_chain_gate(base: Callable, Lout: int) -> Callable:
    """Gate a spectral chain backend (tree/looped) at its exit: SH exits
    gate on the packed coefficients (before ``w_out`` — the gate acts on
    the raw chain product, matching the fused stage's placement), resident
    exits gate on the grid itself via `_gate_rep` — no conversions added
    either way.  The collocation backends never use this wrapper: they fuse
    the stage into the kernel (`_build_chain_fused(gate=True)`)."""

    def apply(xs, weights=None, w_out=None, out_basis: str = "sh",
              gate_params=None):
        if gate_params is None:
            raise ValueError("gated chain plan requires gate_params")
        out = base(xs, weights=weights, w_out=None, out_basis=out_basis)
        if out_basis == "fourier":
            return _gate_rep(gate_params, out)
        # the f32 gate MLP must not promote a bf16 chain exit: gate in f32
        # (the accumulation dtype), round once back to the storage dtype
        return _wmul(_gate_sh(gate_params, out).astype(out.dtype), w_out, Lout)

    return apply


def _constrained_chain_apply(apply: Callable, mesh, dp: tuple) -> Callable:
    """Row-shard a collocation chain: rank-aware row constraints on batched
    operands and the output (the kernel wrapper flattens leading dims to
    rows, so dim0 sharding propagates straight through the matmuls)."""
    con = _row_constraint(mesh, dp)

    def _c(x, er: int):
        from .rep import Rep

        if isinstance(x, Rep):
            return Rep(_c(x.data, 2), x.L, x.basis, x.form)
        return con(x) if jnp.ndim(x) > er else x

    def wrapped(xs, weights=None, w_out=None, out_basis: str = "sh", **kw):
        xs = [_c(x, 1) for x in xs]
        out = apply(xs, weights=weights, w_out=w_out, out_basis=out_basis,
                    **kw)
        return _c(out, 1)

    return wrapped


# --------------------------------------------------------------------------
# cost model (relative real-MAC counts; calibrated coarsely, see DESIGN.md §4;
# the fused skinny-matmul factor is *measured* — `GauntEngine.calibrate_fused`)
# --------------------------------------------------------------------------

_C_CPLX = 4.0        # complex MAC = 4 real MACs
_C_FFT = 10.0        # per point per log2 level: tiny-grid FFTs vectorize poorly
_OVERHEAD = 3e4      # per dispatched op: favors fewer, denser ops at small sizes
_INTERPRET_PENALTY = 1e4   # Pallas interpret mode off-TPU is not a real option

# Measured calibration constants feeding the heuristic cost model.
# 'fused_skinny' scales the collocation backends' per-element cost: their
# matmuls are skinny (G >> d, memory-bound) while dense_einsum is one
# well-blocked contraction, so wall time sits a constant factor off the raw
# MAC ratio.  The default 4.0 is the historical CPU-era magic number;
# `GauntEngine.calibrate_fused()` replaces it with a value measured on THIS
# host/backend (benchmarks run it and record the result in BENCH_gaunt.json),
# so heuristic-mode plans stop inheriting another machine's constant.
#
# Calibration is keyed BY STORAGE DTYPE: bf16 skinny matmuls have a different
# matmul/bandwidth ratio than f32 (half the bytes, same MXU issue), so one
# dtype-agnostic factor would skew the other precisions' rankings.  The bare
# 'fused_skinny' key is the float32 entry (back-compat); other dtypes live at
# 'fused_skinny:<dtype>' and inherit the float32 value until measured
# (``None`` = inherit).
_CALIB = {
    "fused_skinny": 4.0, "fused_skinny_measured": False,
    "fused_skinny:bfloat16": None, "fused_skinny:bfloat16_measured": False,
    "fused_skinny:float64": None, "fused_skinny:float64_measured": False,
}
# pristine copy for reset_calibration(): _CALIB is module-global mutable
# state, so without a reset a calibrate_fused() run in one engine/test
# silently skews heuristic rankings in every other
_CALIB_DEFAULTS = dict(_CALIB)


def _calib_key(dtype: str) -> str:
    return "fused_skinny" if dtype == "float32" else f"fused_skinny:{dtype}"


def _calib_factor(dtype: str) -> float:
    v = _CALIB.get(_calib_key(dtype))
    return _CALIB["fused_skinny"] if v is None else v


def get_calibration() -> dict:
    """The cost model's calibration constants (see `_CALIB`)."""
    return dict(_CALIB)


def set_calibration(**kw) -> None:
    """Override calibration constants (tests / cross-host replay).

    Per-dtype entries use the key 'fused_skinny:<dtype>' — pass them via
    dict-splat (the ':' is not a valid identifier character).
    """
    unknown = set(kw) - set(_CALIB)
    if unknown:
        raise ValueError(f"unknown calibration constants {sorted(unknown)}")
    _CALIB.update(kw)


def reset_calibration() -> None:
    """Restore the default calibration constants and drop all ``*_measured``
    flags — wired into ``GauntEngine.clear()`` so two fresh engines always
    rank backends identically regardless of what a previous engine measured."""
    _CALIB.clear()
    _CALIB.update(_CALIB_DEFAULTS)


def _dims(key: PlanKey):
    B = key.batch_hint or 1
    n1, n2 = 2 * key.L1 + 1, 2 * key.L2 + 1
    N = n1 + n2 - 1
    return B, num_coeffs(key.L1), num_coeffs(key.L2), num_coeffs(key.Lout), n1, n2, N


def _cost_dense_einsum(key: PlanKey) -> float:
    B, d1, d2, do, *_ = _dims(key)
    if key.kind == "channel_mix":
        return 16.0 * B * d1 * d2 * do + _OVERHEAD  # x C1*C2 (unknown): scaled proxy
    if key.kind == "manybody":
        Ls = key.opt("Ls", (key.L1, key.L2))
        total, La = 0.0, Ls[0]
        for L in Ls[1:]:
            total += B * num_coeffs(La) * num_coeffs(L) * num_coeffs(La + L)
            La += L
        return total + _OVERHEAD * len(Ls)
    return B * d1 * d2 * do + _OVERHEAD


def _spectral_common(key: PlanKey, conv: str, packed: bool) -> float:
    B, d1, d2, do, n1, n2, N = _dims(key)
    if packed:  # O(L^3) stacked matmuls
        conv_in = 4.0 * B * (key.L1 + 1) ** 3 + 4.0 * B * (key.L2 + 1) ** 3
        proj = 8.0 * B * (key.Lout + 1) ** 2 * N
    else:  # O(L^4) dense einsum conversions
        conv_in = 2.0 * B * (d1 * n1 * n1 + d2 * n2 * n2)
        proj = _C_CPLX * B * N * N * do
    if conv == "fft":
        c = 3.0 * _C_FFT * B * N * N * max(1.0, math.log2(N * N)) + _C_CPLX * B * N * N
    else:
        c = _C_CPLX * B * N * N * n2 * n2
    n_ops = 8 if not packed else 14
    return conv_in + c + proj + _OVERHEAD * n_ops


def _cost_fft(key):
    if key.kind == "manybody":
        return _cost_manybody_spectral(key, "fft", packed=False)
    return _spectral_common(key, "fft", packed=False)


def _cost_direct(key):
    if key.kind == "manybody":
        return _cost_manybody_spectral(key, "direct", packed=False)
    return _spectral_common(key, "direct", packed=False)


def _cost_packed(key):
    conv = key.opt("conv", "fft")
    if key.kind == "manybody":
        return _cost_manybody_spectral(key, conv, packed=True)
    return _spectral_common(key, conv, packed=True)


def _cost_rfft(key):
    """Half (Hermitian) conversions + real spatial rfft convolution."""
    B, d1, d2, do, n1, n2, N = _dims(key)
    if key.kind == "manybody":
        Ls = key.opt("Ls", (key.L1, key.L2))
        Lt = sum(Ls)
        Nr = 2 * Lt + 2
        conv_in = sum(2.0 * B * num_coeffs(L) * (2 * L + 1) * (L + 1) for L in Ls)
        convs = 1.5 * _C_FFT * len(Ls) * B * Nr * Nr * max(1.0, math.log2(Nr * Nr))
        proj = _C_CPLX * B * Nr * (Lt + 1) * num_coeffs(key.Lout) / 2
        return conv_in + convs + proj + _OVERHEAD * (6 + 2 * len(Ls))
    Nr = N + 1  # the even alias-free spatial grid 2(L1+L2)+2
    conv_in = 2.0 * B * (d1 * n1 * (key.L1 + 1) + d2 * n2 * (key.L2 + 1))
    c = 1.5 * _C_FFT * B * Nr * Nr * max(1.0, math.log2(Nr * Nr)) + B * Nr * Nr
    proj = _C_CPLX * B * N * (key.L1 + key.L2 + 1) * do / 2
    return conv_in + c + proj + _OVERHEAD * 9


def _cost_manybody_spectral(key: PlanKey, conv: str, packed: bool) -> float:
    Ls = key.opt("Ls", (key.L1, key.L2))
    B = key.batch_hint or 1
    Lt = sum(Ls)
    N = 2 * Lt + 1
    convs = _C_FFT * len(Ls) * B * N * N * max(1.0, math.log2(N * N)) if conv == "fft" \
        else _C_CPLX * len(Ls) * B * N * N * (2 * max(Ls) + 1) ** 2
    conv_in = sum(2.0 * B * num_coeffs(L) * (2 * L + 1) ** 2 for L in Ls)
    proj = _C_CPLX * B * N * N * num_coeffs(key.Lout)
    return conv_in + convs + proj + _OVERHEAD * (6 + 2 * len(Ls))


def _cost_fused(key: PlanKey, pallas: bool) -> float:
    B, d1, d2, do, n1, n2, N = _dims(key)
    Nf = 2 * (key.L1 + key.L2) + 2
    G = ((Nf * Nf + 127) // 128) * 128
    # the skinny-matmul factor is a *measured*, per-dtype calibration
    # constant (GauntEngine.calibrate_fused, recorded in BENCH_gaunt.json);
    # 4.0 is only the never-calibrated default
    f = _calib_factor(key.dtype)
    c = f * B * G * (d1 + d2 + do) + _OVERHEAD * 4
    if key.kind == "channel_mix":
        c = 4.0 * f * B * G * (d1 + d2 + do) + _OVERHEAD * 4
    if pallas:
        c *= 0.5 if jax.default_backend() == "tpu" else _INTERPRET_PENALTY
    return c


def _cost_escn(key: PlanKey) -> float:
    B, d1, d2, do, n1, n2, N = _dims(key)
    Lw = max(key.L1, key.Lout)
    wigner = B * sum((2 * l + 1) ** 4 for l in range(2, Lw + 1)) + \
        2.0 * B * sum((2 * l + 1) ** 2 for l in range(Lw + 1))
    s2f = 2.0 * B * d1 * n1 * n1
    banded = _C_CPLX * B * N * n1 * n1
    proj = _C_CPLX * B * N * N * do
    return wigner + s2f + banded + proj + _OVERHEAD * 10


# --------------------------------------------------------------------------
# backend builders
# --------------------------------------------------------------------------


def _build_dense_einsum(key: PlanKey) -> Callable:
    # the Gaunt tensor G and operand copies live at the STORAGE dtype (bf16
    # keys move half the bytes); the einsum contractions accumulate at the
    # derived >= f32 accumulation dtype via ``preferred_element_type``
    gd = key.dtype if key.dtype == "bfloat16" else key.acc_dtype
    acc = jnp.dtype(key.acc_dtype)
    rd = _RDTYPE[key.dtype]
    if key.kind == "channel_mix":
        G = constants.gaunt_dense(key.L1, key.L2, key.Lout, gd)

        def apply_mix(x1, x2, w_mix):
            Gj = jnp.asarray(G)
            out = jnp.einsum("...ci,...dj,ijk,cde->...ek",
                             x1.astype(Gj.dtype), x2.astype(Gj.dtype), Gj,
                             w_mix.astype(Gj.dtype),
                             preferred_element_type=acc)
            return out.astype(rd)

        return apply_mix
    if key.kind == "manybody":
        Ls = key.opt("Ls")

        def apply_mb(xs, weights=None):
            xs = list(xs)
            if weights is not None:
                xs = [_wmul(x, w, L) for x, w, L in zip(xs, weights, Ls)]
            acc_x, La = xs[0], Ls[0]
            for i, (x, L) in enumerate(zip(xs[1:], Ls[1:])):
                last = i == len(Ls) - 2
                Lt = key.Lout if last else La + L
                G = jnp.asarray(constants.gaunt_dense(La, L, Lt, gd))
                acc_x = jnp.einsum("...i,...j,ijk->...k",
                                   acc_x.astype(G.dtype), x.astype(G.dtype), G,
                                   preferred_element_type=acc)
                La += L
            return acc_x.astype(rd)

        return apply_mb
    G = constants.gaunt_dense(key.L1, key.L2, key.Lout, gd)

    def apply_pair(x1, x2, w1=None, w2=None, w3=None):
        Gj = jnp.asarray(G)
        x1 = _wmul(x1, w1, key.L1).astype(Gj.dtype)
        x2 = _wmul(x2, w2, key.L2).astype(Gj.dtype)
        out = jnp.einsum("...i,...j,ijk->...k", x1, x2, Gj,
                         preferred_element_type=acc)
        return _wmul(out.astype(rd), w3, key.Lout)

    return apply_pair


def _warm_spectral_constants(conversion: str, Ls, Lf: int, Lout: int, cd) -> None:
    """Build the conversion constants at plan time so jit tracing never
    re-runs numpy precompute."""
    warm_y = {"dense": constants.y_dense, "packed": constants.y_packed,
              "half": constants.y_half}[conversion]
    warm_z = {"dense": constants.z_dense, "packed": constants.z_packed,
              "half": constants.z_half}[conversion]
    for L in Ls:
        warm_y(L, cd)
    warm_z(Lf, Lout, cd)


def _resident_grid(op, L: int, form: str):
    """A 'fourier' boundary operand: a Rep (validated) or a raw grid."""
    from .rep import Rep

    if isinstance(op, Rep):
        if op.basis != "fourier":
            raise ValueError("boundary='fourier' operand must be Fourier-resident "
                             f"(got basis={op.basis!r}; convert with .to_fourier())")
        if op.L != L:
            raise ValueError(f"resident operand bandlimit {op.L} != planned degree {L}")
        return op.with_form(form).data
    return op


def _build_spectral(key: PlanKey, conversion: str, conv: str) -> Callable:
    from .gaunt import conv2d_full, conv2d_herm, fourier_to_sh, sh_to_fourier  # lazy: gaunt imports engine

    cd = _CDTYPE[key.dtype]
    rd = _RDTYPE[key.dtype]
    form = "half" if conversion == "half" else "dense"
    conv_fn = conv2d_herm if conversion == "half" else conv2d_full

    if key.kind == "manybody":
        from .manybody import _tree_convolve

        Ls = key.opt("Ls")
        Ltot = sum(Ls)
        _warm_spectral_constants(conversion, Ls, Ltot, key.Lout, cd)

        def apply_mb(xs, weights=None):
            grids = []
            for i, (x, L) in enumerate(zip(xs, Ls)):
                if weights is not None and weights[i] is not None:
                    x = _wmul(x, weights[i], L)
                grids.append(sh_to_fourier(x, L, conversion, jnp.dtype(cd)))
            F = _tree_convolve(grids, conv, herm=(conversion == "half"))
            return fourier_to_sh(F, Ltot, key.Lout, conversion, rd)

        return apply_mb

    _warm_spectral_constants(conversion, (key.L1, key.L2), key.L1 + key.L2,
                             key.Lout, cd)
    b1, b2, bo = key.opt("boundary") or ("sh", "sh", "sh")

    def convert_in(x, w, L, b):
        if b == "fourier":
            if w is not None:
                raise ValueError("per-degree weights need an SH operand; apply "
                                 "them before converting to the Fourier basis")
            return _resident_grid(x, L, form)
        return sh_to_fourier(_wmul(x, w, L), L, conversion, jnp.dtype(cd))

    def apply_pair(x1, x2, w1=None, w2=None, w3=None):
        F1 = convert_in(x1, w1, key.L1, b1)
        F2 = convert_in(x2, w2, key.L2, b2)
        F3 = conv_fn(F1, F2, conv)
        if bo == "fourier":
            from .rep import Rep

            if w3 is not None:
                raise ValueError("w3 applies in SH; a Fourier-boundary output "
                                 "cannot carry per-degree output weights")
            return Rep(F3, key.L1 + key.L2, "fourier", form)
        out = fourier_to_sh(F3, key.L1 + key.L2, key.Lout, conversion, rd)
        return _wmul(out, w3, key.Lout)

    return apply_pair


def _build_fused(key: PlanKey, pallas: bool) -> Callable:
    # storage discipline (DESIGN.md §3.6): operands and the sampling matrices
    # T1/T2 at key.dtype, f32 MXU accumulation, f32 projection matrix P
    rd = _RDTYPE[key.dtype]
    sd = jnp.dtype(key.dtype)
    acc = jnp.float32  # fused backends are f32/bf16-storage only
    (T1, T2), _ = constants.chain_matrices(
        (key.L1, key.L2), key.Lout, ("sh", "sh"), "sh", dtype=key.dtype)
    _, P = constants.chain_matrices(
        (key.L1, key.L2), key.Lout, ("sh", "sh"), "sh", dtype="float32")

    if key.kind == "channel_mix":

        def apply_mix(x1, x2, w_mix):
            T1j, T2j, Pj = jnp.asarray(T1), jnp.asarray(T2), jnp.asarray(P)
            V1 = jnp.dot(x1.astype(sd), T1j, preferred_element_type=acc)  # [..., C1, G]
            V2 = jnp.dot(x2.astype(sd), T2j, preferred_element_type=acc)  # [..., C2, G]
            V = jnp.einsum("...cg,...dg,cde->...eg", V1, V2, w_mix.astype(V1.dtype))
            return (V @ Pj).astype(rd)

        return apply_mix

    if pallas:
        block_b = key.opt("block_b")  # None -> the kernel's per-dtype default

        def apply_pair(x1, x2, w1=None, w2=None, w3=None):
            from repro.kernels.gaunt_fused import gaunt_fused_pallas  # lazy: kernels import core

            x1 = _wmul(x1, w1, key.L1)
            x2 = _wmul(x2, w2, key.L2)
            out = gaunt_fused_pallas(x1, x2, key.L1, key.L2, key.Lout,
                                     block_b=block_b, dtype=key.dtype)
            return _wmul(out.astype(rd), w3, key.Lout)

        return apply_pair

    def apply_pair(x1, x2, w1=None, w2=None, w3=None):
        T1j, T2j, Pj = jnp.asarray(T1), jnp.asarray(T2), jnp.asarray(P)
        x1 = _wmul(x1, w1, key.L1)
        x2 = _wmul(x2, w2, key.L2)
        v1 = jnp.dot(x1.astype(sd), T1j, preferred_element_type=acc)
        v2 = jnp.dot(x2.astype(sd), T2j, preferred_element_type=acc)
        out = ((v1 * v2) @ Pj).astype(rd)
        return _wmul(out, w3, key.Lout)

    return apply_pair


def _build_escn(key: PlanKey) -> Callable:
    cd = _CDTYPE[key.dtype]
    rd = _RDTYPE[key.dtype]
    L1, L2, Lout = key.L1, key.L2, key.Lout
    constants.y_dense(L1, cd)
    constants.z_dense(L1 + L2, Lout, cd)
    constants.filter_fourier_col(L2, cd)
    constants.conv_u_index(L1, L2)
    constants.cg_11_blocks(max(L1, Lout))
    fl0 = np.array([math.sqrt((2 * l + 1) / (4 * math.pi)) for l in range(L2 + 1)],
                   dtype=np.float32)
    geometry = key.opt("geometry")

    def apply_conv(x, rhat, w1=None, w2=None, w3=None):
        # lazy: conv.py routes through the engine, so import its helpers at call
        from .conv import (WignerBlocks, align_rotation, apply_wigner_blocks,
                           wigner_blocks_from_rotmat)
        from .gaunt import fourier_to_sh, sh_to_fourier

        x = _wmul(x, w1, L1)
        if geometry == "wigner":
            # rotation residency: the caller precomputed the alignment
            # rotation + Wigner recursion once per geometry (conv.geometry_rep)
            if not isinstance(rhat, WignerBlocks):
                raise ValueError("plans with options={'geometry': 'wigner'} "
                                 "take precomputed WignerBlocks (see "
                                 "EquivariantConv.geometry_rep), got "
                                 f"{type(rhat).__name__}")
            if rhat.L < max(L1, Lout):
                raise ValueError(f"WignerBlocks cover degrees <= {rhat.L}, "
                                 f"need max(L1, Lout) = {max(L1, Lout)}")
            Ds = list(rhat.blocks)
        else:
            R = align_rotation(rhat.astype(jnp.float32))
            Ds = wigner_blocks_from_rotmat(max(L1, Lout), R)
        x_rot = apply_wigner_blocks(Ds[: L1 + 1], x)
        F1 = sh_to_fourier(x_rot, L1, "dense", jnp.dtype(cd))  # [..., n1, n1]
        # filter coefficients: only m=0 -> single v=0 column, O(L^2)
        fl = jnp.asarray(fl0, dtype=rd)
        if w2 is not None:
            fl = fl * w2.astype(rd)
        cols = jnp.asarray(constants.filter_fourier_col(L2, cd))
        k = jnp.einsum("...l,lu->...u", fl.astype(cols.dtype), cols)  # [..., 2L2+1]
        # banded 1D conv along u for every v column (v support unchanged)
        gidx, mask = constants.conv_u_index(L1, L2)
        kmat = k[..., jnp.asarray(gidx)] * jnp.asarray(mask, dtype=rd)  # [..., N, n1]
        F3 = jnp.einsum("...ti,...iv->...tv", kmat, F1)  # [..., N, n1(v)]
        # pad v axis to the full output grid (v support still |v| <= L1)
        pv = (2 * (L1 + L2) + 1 - (2 * L1 + 1)) // 2
        F3 = jnp.pad(F3, [(0, 0)] * (F3.ndim - 1) + [(pv, pv)])
        out_rot = fourier_to_sh(F3, L1 + L2, Lout, "dense", rd)
        out = apply_wigner_blocks(Ds[: Lout + 1], out_rot, transpose=True)
        return _wmul(out, w3, Lout)

    return apply_conv


def _wrap_conv_filter(key: PlanKey, pair_apply: Callable) -> Callable:
    """Serve kind='conv_filter' on a pairwise backend: materialize Y(rhat)."""

    def apply_conv(x, rhat, w1=None, w2=None, w3=None):
        from .so3 import real_sph_harm_jax

        filt = real_sph_harm_jax(key.L2, rhat).astype(x.dtype)
        return pair_apply(x, filt, w1, w2, w3)

    return apply_conv


register_backend(Backend(
    name="dense_einsum",
    kinds=frozenset({"pairwise", "conv_filter", "manybody", "channel_mix"}),
    build=_build_dense_einsum,
    cost=_cost_dense_einsum,
))
register_backend(Backend(
    name="fft",
    kinds=frozenset({"pairwise", "conv_filter", "manybody"}),
    build=lambda key: _build_spectral(key, "dense", "fft"),
    cost=_cost_fft,
    fourier_boundary=True,
))
register_backend(Backend(
    name="direct",
    kinds=frozenset({"pairwise", "conv_filter", "manybody"}),
    build=lambda key: _build_spectral(key, "dense", "direct"),
    cost=_cost_direct,
    fourier_boundary=True,
))
register_backend(Backend(
    name="packed",
    kinds=frozenset({"pairwise", "conv_filter", "manybody"}),
    build=lambda key: _build_spectral(key, "packed", key.opt("conv", "fft")),
    cost=_cost_packed,
    fourier_boundary=True,
))
register_backend(Backend(
    name="rfft",
    kinds=frozenset({"pairwise", "conv_filter", "manybody"}),
    build=lambda key: _build_spectral(key, "half", key.opt("conv", "rfft")),
    cost=_cost_rfft,
    fourier_boundary=True,
))
register_backend(Backend(
    name="fused_xla",
    kinds=frozenset({"pairwise", "conv_filter", "channel_mix"}),
    build=lambda key: _build_fused(key, pallas=False),
    cost=lambda key: _cost_fused(key, pallas=False),
    dtypes=frozenset({"float32", "bfloat16"}),
))
register_backend(Backend(
    name="fused_pallas",
    kinds=frozenset({"pairwise", "conv_filter"}),
    build=lambda key: _build_fused(key, pallas=True),
    cost=lambda key: _cost_fused(key, pallas=True),
    supports_grad=False,  # pallas_call has no registered VJP
    dtypes=frozenset({"float32", "bfloat16"}),
    needs_interpret=True,
))
register_backend(Backend(
    name="escn_aligned",
    kinds=frozenset({"conv_filter"}),
    build=_build_escn,
    cost=_cost_escn,
    wigner_geometry=True,
))


# --------------------------------------------------------------------------
# the engine
# --------------------------------------------------------------------------


class GauntEngine:
    """Plans, caches, and autotunes Gaunt ops over the backend registry."""

    def __init__(self, cache_path: str | None = None):
        self._plans: dict[tuple, GauntPlan] = {}
        self._batched: dict[tuple, BatchedGauntPlan] = {}
        self._chains: dict[tuple, ChainPlan] = {}
        self._measured: dict[PlanKey, str] = {}
        # best measured wall time per key — lets dtype='auto' compare a key's
        # f32/bf16 siblings (one key family) without re-timing either
        self._measured_t: dict[PlanKey, float] = {}
        # persistent autotune cache (core/autotune_cache.py).  Disabled
        # unless a path is configured here, via set_autotune_cache(), or via
        # $REPRO_AUTOTUNE_CACHE — tests and one-shot scripts keep the
        # historical purely-in-process behavior.
        self._cache_path = cache_path
        self._cache_loaded = False
        # counts timed measurement passes (plan backends, chain candidates,
        # fused calibration).  A process booted against a warm cache must
        # keep this at 0 — the warm-start acceptance proof and the CLI's
        # --verify-warm both read it.
        self.timing_runs = 0

    # -- persistent autotune cache -----------------------------------------

    def set_autotune_cache(self, path: str | None) -> None:
        """Point this engine at a persistent cache file (None -> fall back
        to $REPRO_AUTOTUNE_CACHE, or disabled).  The next measure-mode miss
        loads it lazily; every new measurement flushes to it."""
        self._cache_path = path
        self._cache_loaded = False

    def _resolved_cache_path(self) -> str | None:
        from . import autotune_cache as _ac

        return _ac.resolve_path(self._cache_path)

    def load_autotune_cache(self) -> int:
        """Load persisted selections/timings/calibration now (idempotent;
        in-process entries win over the file's).  -> #selections adopted."""
        self._cache_loaded = True
        path = self._resolved_cache_path()
        if path is None:
            return 0
        from . import autotune_cache as _ac

        data = _ac.load(path)
        if data is None:
            return 0
        selections, timings, calib = data
        n = 0
        for k, b in selections.items():
            if k not in self._measured:
                self._measured[k] = b
                n += 1
        for k, t in timings.items():
            self._measured_t.setdefault(k, t)
        _ac.merge_calibration(calib)
        return n

    def _maybe_load_cache(self) -> None:
        if not self._cache_loaded:
            self.load_autotune_cache()

    def flush_autotune_cache(self) -> str | None:
        """Persist the measurement stores (atomic, merging).  No-op without
        a configured cache path.  -> the path written, or None."""
        path = self._resolved_cache_path()
        if path is None:
            return None
        from . import autotune_cache as _ac

        _ac.save(path, self._measured, self._measured_t,
                 calibration=get_calibration())
        return path

    def _autoflush(self) -> None:
        """Flush after a new measurement — an unwritable cache file must
        degrade to in-process-only autotune, never break planning."""
        try:
            self.flush_autotune_cache()
        except OSError:
            pass

    # -- public API --------------------------------------------------------

    def plan(self, L1: int | None = None, L2: int | None = None,
             Lout: int | None = None, *, kind: str = "pairwise",
             Ls: tuple | None = None, batch_hint: int | None = None,
             dtype="float32", backend: str | None = None,
             options: dict | None = None, tune: str = "heuristic",
             requires_grad: bool = True) -> GauntPlan:
        """Resolve (and cache) a plan.  ``backend=None`` -> engine selection.

        kind='manybody' takes ``Ls`` (per-operand degrees) instead of L1/L2.
        ``tune`` is 'heuristic' (cost model) or 'measure' (timed autotune).
        ``dtype`` is the storage dtype; 'auto' (with tune='measure') times
        the f32 and bf16 siblings and keeps bf16 only where it wins.
        """
        if kind not in KINDS:
            raise ValueError(f"unknown kind {kind!r} (expected one of {KINDS})")
        options = dict(options or {})
        bound = options.get("boundary")
        if bound is not None:
            bound = tuple(bound)
            if kind != "pairwise":
                raise ValueError("boundary options are only defined for "
                                 "pairwise plans (chains cover the rest)")
            if len(bound) != 3 or any(b not in ("sh", "fourier") for b in bound):
                raise ValueError(f"boundary must be 3 entries of 'sh'|'fourier', "
                                 f"got {bound!r}")
            if bound == ("sh", "sh", "sh"):
                options.pop("boundary")  # the default; don't fragment the cache
            else:
                options["boundary"] = bound
        geom = options.get("geometry")
        if geom is not None:
            if kind != "conv_filter":
                raise ValueError("geometry options only apply to conv_filter "
                                 "plans (precomputed Wigner alignment)")
            if geom != "wigner":
                raise ValueError(f"unknown geometry {geom!r} (expected 'wigner')")
        extra = tuple(sorted(options.items()))
        if kind == "manybody":
            if Ls is None or len(Ls) < 2:
                raise ValueError("manybody plans need Ls with >= 2 degrees")
            Ls = tuple(int(L) for L in Ls)
            L1, L2 = max(Ls), min(Ls)
            Lout = sum(Ls) if Lout is None else Lout
            extra = extra + (("Ls", Ls),)
        else:
            if L1 is None or L2 is None:
                raise ValueError(f"kind={kind!r} plans need L1 and L2")
            Lout = L1 + L2 if Lout is None else Lout
        if Lout > (sum(Ls) if kind == "manybody" else L1 + L2):
            raise ValueError("Lout cannot exceed the total degree (Gaunt selection rule)")
        if bound is not None and bound[2] == "fourier" and Lout != L1 + L2:
            raise ValueError("a Fourier-boundary output keeps the full product "
                             f"grid (L={L1 + L2}); plan with Lout={L1 + L2} and "
                             "project at the chain exit")
        if isinstance(dtype, str) and dtype == "auto":
            dts = self._select_dtype(
                lambda d: PlanKey(L1, L2, Lout, kind, batch_hint, d, extra),
                tune=tune, requires_grad=requires_grad)
        else:
            dts = _dtype_str(dtype)
        key = PlanKey(L1, L2, Lout, kind, batch_hint, dts, extra)
        cache_key = (key, backend, tune, requires_grad)
        hit = self._plans.get(cache_key)
        if hit is not None:
            return hit
        name = backend or self.select(key, tune=tune, requires_grad=requires_grad)
        spec = _REGISTRY.get(name)
        if spec is None:
            raise ValueError(f"unknown backend {name!r}; registered: {sorted(_REGISTRY)}")
        if not spec.eligible(key, requires_grad):
            raise ValueError(f"backend {name!r} cannot serve {key} "
                             f"(requires_grad={requires_grad})")
        apply = spec.build(key)
        if key.kind == "conv_filter" and spec.name != "escn_aligned":
            # generic backends build the pairwise form; materialize Y(rhat)
            apply = _wrap_conv_filter(key, apply)
        p = GauntPlan(key=key, backend=name, apply=apply)
        self._plans[cache_key] = p
        return p

    def plan_batch(self, items, *, kind: str = "pairwise", dtype="float32",
                   backend: str | None = None, tune: str = "heuristic",
                   requires_grad: bool = True, donate: bool = False,
                   shard_spec: ShardSpec | None = None,
                   pad_to: int | None = None) -> BatchedGauntPlan:
        """Plan a ragged multi-degree workload as bucketed fused invocations.

        items: sequence of (L1, L2, Lout[, size]) tuples / dicts / BatchItems
        (manybody items carry ``Ls``).  Items sharing a degree signature form
        one *bucket*: their operands are flattened to rows, concatenated,
        tail-padded to the plan granularity, and executed by a single jitted
        call on the bucket's inner plan — per-item results are sliced back
        out, numerically identical to per-plan loops (all backends are
        row-parallel).  ``donate=True`` donates the concatenated operand
        buffers on accelerators; ``shard_spec`` shards the row axis over the
        mesh's data axes (see :class:`ShardSpec`).  ``pad_to`` forces a row
        granularity (e.g. 128 for lane alignment); the data-parallel device
        count is always folded in so shards stay equal.
        """
        if kind not in KINDS:
            raise ValueError(f"unknown kind {kind!r} (expected one of {KINDS})")
        if kind == "channel_mix":
            raise ValueError("plan_batch does not support kind='channel_mix': "
                             "w_mix is not a row-batched operand (use plan())")
        norm = []
        for it in items:
            it = _as_batch_item(it)
            if kind == "manybody":
                if it.Ls is None or len(it.Ls) < 2:
                    raise ValueError("manybody batch items need Ls with >= 2 degrees")
                if it.Lout is None:
                    it = dataclasses.replace(it, Lout=sum(it.Ls))
            else:
                if it.L1 is None or it.L2 is None:
                    raise ValueError(f"kind={kind!r} batch items need L1 and L2")
                if it.Lout is None:
                    it = dataclasses.replace(it, Lout=it.L1 + it.L2)
            norm.append(it)
        norm = tuple(norm)
        if not norm:
            raise ValueError("plan_batch needs at least one item")
        # buckets key on the STORAGE dtype; 'auto' flows through to each
        # bucket's inner plan(), which resolves it per degree-signature
        dts = "auto" if (isinstance(dtype, str) and dtype == "auto") \
            else _dtype_str(dtype)
        mesh, dp = (None, ()) if shard_spec is None else shard_spec.resolve()
        g = max(1, int(pad_to or 1))
        if mesh is not None and dp:
            from repro.distributed import sharding as _sh

            g = math.lcm(g, _sh.dp_size(mesh, dp))
        mode = shard_spec.mode if shard_spec is not None else "constraint"
        # cache the batched plan: the jitted bucket callables must be stable
        # across calls or every eager invocation would recompile
        cache_key = (norm, kind, dts, backend, tune, requires_grad, donate,
                     g, mesh, dp, mode)
        hit = self._batched.get(cache_key)
        if hit is not None:
            return hit
        groups: dict[tuple, list[int]] = {}
        for i, it in enumerate(norm):
            groups.setdefault(it.signature(), []).append(i)
        buckets = []
        for idxs in groups.values():
            it0 = norm[idxs[0]]
            known = [norm[i].size for i in idxs if norm[i].size]
            hint = sum(known) if known else None
            p = self.plan(
                it0.L1, it0.L2, it0.Lout, kind=kind, Ls=it0.Ls,
                batch_hint=hint, dtype=dts, backend=backend,
                options=dict(it0.options) or None, tune=tune,
                requires_grad=requires_grad,
            )
            fn = _make_bucket_fn(p, kind, it0, donate, mesh, dp, mode, g)
            buckets.append(_Bucket(item_ids=tuple(idxs), plan=p, fn=fn))
        bp = BatchedGauntPlan(kind=kind, dtype=dts, items=norm,
                              buckets=tuple(buckets), granularity=g,
                              donate=donate, shard=shard_spec)
        self._batched[cache_key] = bp
        return bp

    def plan_chain(self, Ls, Lout: int | None = None, *,
                   conversion: str | None = None, conv: str | None = None,
                   dtype="float32", tree: bool = True, donate: bool = False,
                   shard_spec: ShardSpec | None = None,
                   backend: str | None = None, tune: str = "heuristic",
                   batch_hint: int | None = None,
                   entry_hint: tuple | None = None,
                   out_hint: str = "sh",
                   share_hint: tuple | None = None,
                   gate: bool = False) -> ChainPlan:
        """Plan a chained product  x_1 (x) ... (x) x_n  as ONE pass.

        Ls: per-operand max degrees (n >= 2).  Lout defaults to sum(Ls).

        ``gate=True`` makes the equivariant gate (models.gate_apply) a
        chain-INTERIOR stage (DESIGN.md §6.5): applies take a required
        ``gate_params`` and return gate(product) — on the collocation
        backends the gate fuses into the kernel's pointwise stage (still
        ONE dispatch; l=0 scalars via `constants.chain_l0`), on tree/looped
        it runs at the exit (a resident 'fourier' exit gates the grid
        in-basis, so a whole TP -> gate -> selfmix layer keeps a single
        entry/exit conversion pair).  ``w_out`` applies after the gate.
        Gated plans key separately everywhere (plan cache and measured
        autotune: the measure key gains ("gate", 1), so ungated persisted
        entries stay valid).

        Backend dispatch (DESIGN.md §6.4): ``backend`` picks a chain
        realization from :data:`CHAIN_BACKENDS` — 'tree' (the resident
        spectral pass: convert each operand <= once, divide-and-conquer grid
        combine, one exit projection), 'looped' (per-product pairwise fold),
        'fused_xla' / 'fused_pallas' (the n-way collocation kernel: sample
        every operand onto the shared alias-free product grid, multiply
        pointwise n-way in VMEM, project once — the Pallas flavor is ONE
        MXU-resident `pallas_call`).  ``backend=None`` selects:

        * ``tune='measure'`` — chains fold into the engine's measured
          autotuner, keyed like plans (PlanKey kind='chain' with the Ls,
          ``batch_hint``, and ``entry_hint``): each candidate is jitted and
          timed on synthetic inputs, the winner cached in-process.
          ``entry_hint`` ('sh'|'fourier' per operand) makes the measurement
          honest for resident call sites: 'fourier' slots are timed as
          resident Reps, so a backend that must convert them back (looped)
          or sample them through the larger grid-entry matrix (fused) pays
          that cost in the timing it is judged by.  ``out_hint='fourier'``
          declares that applies will request a resident exit: 'looped'
          (which has none) is excluded, and every candidate is TIMED with
          that exit (tree skips its projection, fused projects through the
          wider grid-exit matrix — both must pay their real cost).
          ``share_hint`` gives the per-operand duplicate-group indices
          (selfmix ``[A]*nu`` -> (0,)*nu): synthetic operands repeat per
          group, so tree's single shared conversion engages in the timing
          exactly as at the real call.  Measurement needs a clean trace: planned inside a jit trace with
          no previously-seeded cache entry, selection silently stays 'tree'
          — seed the key eagerly first (serving warmup does).  This *replaces* the old
          shape-rule policy as the decision mechanism wherever measurement
          is engaged; `fused_pallas` is timed only on TPU (interpret mode is
          never a real option), and a live sharded mesh restricts candidates
          to 'tree' (the only backend with per-shard grid combination).
        * ``tune='heuristic'`` (default) — 'tree', the conservative resident
          pick whose <= 1-conversion-per-operand contract the counter tests
          certify.  An explicit ``conversion``/``conv`` also pins 'tree'
          (those knobs parameterize the spectral pipeline).

        conversion: 'half' (Hermitian real-input grids) or 'dense'; default
        (None) is 'half' — it halves conversion FLOPs for free.
        conv: grid-combination method — 'rfft' (half only), 'fft', 'direct';
        default (None) follows the measured crossover: 'direct' for a single
        small product (len == 2, max L <= 4, tiny grids where shift-and-add
        wins), 'rfft' otherwise (longer chains grow interior grids past the
        spatial-FFT crossover); dense conversions keep the historical
        direct/fft small-L rule.
        tree=True combines grids divide-and-conquer (the paper's many-body
        parallelization); False is the sequential left fold.

        dtype: the STORAGE dtype ('float32' | 'bfloat16' | 'float64';
        accumulation is always >= f32).  'auto' (with tune='measure') times
        the f32 and bf16 siblings of the measured key family and keeps bf16
        only where it actually wins; anywhere measurement cannot run it
        resolves to float32.

        donate=True donates the unique operand buffers through ``apply_jit``
        (callers must not reuse them); ``shard_spec`` runs the chain
        row-sharded over the mesh's data axes (see :class:`ShardSpec`) —
        both compose with residency, and sharded chains pad/slice their row
        axis so ragged row counts no longer need to divide the device count.

        On the spectral route every operand converts at most once
        (duplicates share a single degree-resolved conversion even with
        different per-degree weights), interior products stay in the Fourier
        basis, and a single projection runs at the exit; the collocation
        route converts *zero* times — resident operands enter as grids
        through the grid-evaluation sampling matrix — see :class:`ChainPlan`.
        """
        Ls = tuple(int(L) for L in Ls)
        if len(Ls) < 2:
            raise ValueError("chain plans need at least 2 operands")
        Lout = sum(Ls) if Lout is None else int(Lout)
        if Lout > sum(Ls):
            raise ValueError("Lout cannot exceed the total degree (Gaunt selection rule)")
        pinned_spectral = conversion is not None or conv is not None
        if conversion is None:
            conversion = "half"
        if conversion not in ("dense", "half"):
            raise ValueError(f"chain conversion must be 'dense'|'half', got {conversion!r}")
        if conv is None:
            if conversion == "half":
                conv = "direct" if (len(Ls) == 2 and max(Ls) <= 4) else "rfft"
            else:
                conv = spectral_default(*Ls)
        if conv == "rfft" and conversion != "half":
            raise ValueError("conv='rfft' operates on half grids (conversion='half')")
        mesh, dp = (None, ()) if shard_spec is None else shard_spec.resolve()
        mode = shard_spec.mode if shard_spec is not None else "constraint"
        if backend is not None and backend not in CHAIN_BACKENDS:
            raise ValueError(f"unknown chain backend {backend!r} "
                             f"(expected one of {CHAIN_BACKENDS})")
        if entry_hint is not None:
            entry_hint = tuple(entry_hint)
            if len(entry_hint) != len(Ls) or \
                    any(e not in ("sh", "fourier") for e in entry_hint):
                raise ValueError(f"entry_hint must be {len(Ls)} entries of "
                                 f"'sh'|'fourier', got {entry_hint!r}")
        if out_hint not in ("sh", "fourier"):
            raise ValueError(f"out_hint must be 'sh'|'fourier', got {out_hint!r}")
        if share_hint is not None:
            share_hint = tuple(int(g) for g in share_hint)
            if len(share_hint) != len(Ls):
                raise ValueError(f"share_hint must have {len(Ls)} group "
                                 f"indices, got {share_hint!r}")
        if isinstance(dtype, str) and dtype == "auto":
            dts = self._select_chain_dtype(
                Ls, Lout, batch_hint, sharded=bool(mesh is not None and dp),
                entry_hint=entry_hint, out_hint=out_hint,
                share_hint=share_hint, tune=tune, gate=gate)
        else:
            dts = _dtype_str(dtype)
        if backend is None:
            if pinned_spectral or tune != "measure":
                backend = "tree"
            else:
                backend = self._select_chain(Ls, Lout, dts, batch_hint,
                                             sharded=bool(mesh is not None and dp),
                                             entry_hint=entry_hint,
                                             out_hint=out_hint,
                                             share_hint=share_hint,
                                             gate=gate)
        key = (Ls, Lout, conversion, conv, dts, tree, donate, mesh, dp, mode,
               backend, gate)
        hit = self._chains.get(key)
        if hit is not None:
            return hit
        if backend == "tree":
            apply = _build_chain(Ls, Lout, conversion, conv, dts, tree,
                                 mesh, dp, mode)
            if gate:
                apply = _wrap_chain_gate(apply, Lout)
        elif backend == "looped":
            apply = _build_chain_looped(Ls, Lout, dts, self)
            if gate:
                apply = _wrap_chain_gate(apply, Lout)
        else:
            apply = _build_chain_fused(Ls, Lout, dts,
                                       pallas=(backend == "fused_pallas"),
                                       gate=gate)
            if mesh is not None and dp:
                # collocation is row-parallel: rank-aware row constraints on
                # the flattened operands/outputs let the partitioner shard it
                apply = _constrained_chain_apply(apply, mesh, dp)
        cp = ChainPlan(Ls=Ls, Lout=Lout, conversion=conversion, conv=conv,
                       dtype=dts, tree=tree, donate=donate,
                       shard=(mesh, dp, mode), backend=backend, gate=gate,
                       apply=apply)
        self._chains[key] = cp
        return cp

    def _select_chain(self, Ls: tuple, Lout: int, dts: str,
                      batch_hint: int | None, sharded: bool,
                      entry_hint: tuple | None = None,
                      out_hint: str = "sh",
                      share_hint: tuple | None = None,
                      gate: bool = False) -> str:
        """Measured chain-backend selection, cached like plan autotune.

        The measurement mirrors the real call as closely as the hints allow:
        ``entry_hint`` slots marked 'fourier' are synthesized as resident
        Reps (looped pays its per-call to_sh, fused pays the grid-entry
        sampling matrix), ``out_hint`` sets the out_basis the candidates are
        TIMED with (a resident exit skips tree's projection and widens
        fused's), and ``share_hint`` repeats one synthetic buffer per
        duplicate group so tree's shared-operand single conversion engages
        — a mismatched measurement would install a backend whose real-world
        cost was never measured.  Deliberately NOT mirrored: per-degree
        weights (their _wmul/bydeg cost is one ordinary conversion's FLOPs
        regardless of backend — a second-order effect on the ranking), and
        exact row counts — ``batch_hint`` quantizes to a power-of-two ladder
        capped at 16384, so ragged eager workloads share a handful of
        measurements instead of re-benchmarking (and re-allocating
        synthetic operands for) every distinct size.
        """
        if sharded:
            return "tree"  # the only backend with per-shard grid combination
        key = self._chain_measure_key(Ls, Lout, dts, batch_hint, entry_hint,
                                      out_hint, share_hint, gate=gate)
        batch_hint = key.batch_hint
        entries, share = key.opt("entries"), key.opt("share")
        # consult the persisted table before the trace-clean bail: loading
        # JSON is host-side Python, safe inside a trace, and a traced miss
        # should still reuse a measurement another process already ran
        self._maybe_load_cache()
        hit = self._measured.get(key)
        if hit is not None:
            return hit
        if not _trace_clean():
            return "tree"  # timing inside a trace is meaningless
        self.timing_runs += 1
        candidates = ["tree", "fused_xla"]
        if out_hint == "sh":
            candidates.insert(1, "looped")  # no resident exit on the fold
        if jax.default_backend() == "tpu":
            candidates.append("fused_pallas")
        B = batch_hint or 256
        rng = np.random.default_rng(0)
        rd = _RDTYPE[dts]
        from .rep import Rep

        xs, made = [], {}
        for L, e, g in zip(Ls, entries, share):
            x = made.get((g, L, e))
            if x is None:
                x = jnp.asarray(rng.normal(size=(B, num_coeffs(L))), dtype=rd)
                if e == "fourier":
                    x = Rep.from_sh(x, L).to_fourier("half")
                made[(g, L, e)] = x
            xs.append(x)
        # synthetic gate MLP sized so the per-row scalar path costs what the
        # real [rows, channels] call costs (the synthetic lead is bare [B],
        # so the MLP contracts B with a hidden width of 16 — same FLOPs
        # shape as the models' [n, C] @ [C, 16] gate head)
        gp = ({"w1": jnp.asarray(rng.normal(size=(B, 16)), jnp.float32),
               "w2": jnp.asarray(rng.normal(size=(16, B)), jnp.float32)}
              if gate else None)
        best_name, best_t = "tree", float("inf")
        for name in candidates:
            try:
                cp = self.plan_chain(Ls, Lout, dtype=dts, backend=name,
                                     gate=gate)
                # eager apply, not a fresh jit: apply_jit is the consumer
                # route and its pre-jit dedup is exactly what makes shared
                # operands convert once in tree's real cost
                fn = (lambda _c=cp: jax.block_until_ready(
                    _c.apply_jit(xs, out_basis=out_hint, gate_params=gp)))
                fn()  # compile + warm
                ts = []
                for _ in range(3):
                    t0 = time.perf_counter()
                    fn()
                    ts.append(time.perf_counter() - t0)
                t = sorted(ts)[1]
            except Exception:  # noqa: BLE001 — a broken candidate just loses
                continue
            if t < best_t:
                best_name, best_t = name, t
        if best_t == float("inf"):
            # every candidate (including tree) raised: nothing was ever
            # successfully run, so there is no measurement to cache — return
            # the safe default WITHOUT pinning it, mirroring _measure's
            # cost-model fallback, and let a later (healthier) call re-time
            return "tree"
        self._measured[key] = best_name
        self._measured_t[key] = best_t
        self._autoflush()
        return best_name

    @staticmethod
    def _chain_measure_key(Ls: tuple, Lout: int, dts: str,
                           batch_hint: int | None, entry_hint: tuple | None,
                           out_hint: str, share_hint: tuple | None,
                           gate: bool = False) -> PlanKey:
        """The measured-autotune cache key for one chain shape.  Keys that
        differ only in ``dtype`` form one family (``PlanKey.with_dtype``);
        'auto' is a valid member naming the family's resolved winner.
        Gated chains append ("gate", 1) — ONLY when gated, so ungated keys
        (and every persisted pre-gate cache entry) stay byte-identical."""
        if batch_hint is not None:
            q = 8
            while q < min(batch_hint, 16384):
                q *= 2
            batch_hint = q
        entries = entry_hint or ("sh",) * len(Ls)
        share = share_hint or tuple(range(len(Ls)))
        extra = (("Ls", Ls), ("entries", entries),
                 ("out", out_hint), ("share", share))
        if gate:
            extra = extra + (("gate", 1),)
        return PlanKey(max(Ls), min(Ls), Lout, kind="chain",
                       batch_hint=batch_hint, dtype=dts, extra=extra)

    def _select_chain_dtype(self, Ls: tuple, Lout: int,
                            batch_hint: int | None, sharded: bool,
                            entry_hint: tuple | None, out_hint: str,
                            share_hint: tuple | None, tune: str,
                            gate: bool = False) -> str:
        """Resolve a chain ``dtype='auto'`` request: measure the f32 and bf16
        siblings of the key family and keep bf16 only where it actually wins.
        Falls back to float32 whenever measurement cannot run (heuristic
        mode, dirty trace, sharded mesh)."""
        auto_key = self._chain_measure_key(Ls, Lout, "auto", batch_hint,
                                           entry_hint, out_hint, share_hint,
                                           gate=gate)
        self._maybe_load_cache()
        hit = self._measured.get(auto_key)
        if hit is not None:
            return hit
        if sharded or tune != "measure" or not _trace_clean():
            return "float32"
        times = {}
        for dts in ("float32", "bfloat16"):
            self._select_chain(Ls, Lout, dts, batch_hint, sharded=False,
                               entry_hint=entry_hint, out_hint=out_hint,
                               share_hint=share_hint, gate=gate)
            t = self._measured_t.get(self._chain_measure_key(
                Ls, Lout, dts, batch_hint, entry_hint, out_hint, share_hint,
                gate=gate))
            if t is not None:
                times[dts] = t
        winner = "bfloat16" if times.get("bfloat16", float("inf")) < \
            times.get("float32", float("inf")) else "float32"
        if times:
            # cache the winner only when at least one sibling actually
            # produced a timing — an all-candidate failure must not become
            # a process-lifetime (or persisted) precision decision
            self._measured[auto_key] = winner
            self._autoflush()
        return winner

    def select_gate(self, Ls, Lout: int | None = None, *, dtype="float32",
                    batch_hint: int | None = None,
                    entry_hint: tuple | None = None, out_hint: str = "sh",
                    share_hint: tuple | None = None) -> str:
        """Measured grid-vs-SH gate policy for one chain workload — the
        decision behind ``cfg.grid_gate='auto'``.

        Times the gate-fused chain plan (`plan_chain(..., gate=True)`)
        against the ungated plan followed by the SH gate epilogue; for a
        resident ``out_hint='fourier'`` the epilogue pays the full
        exit -> gate -> re-entry round trip, which is exactly what fusion
        elides.  Returns 'grid' | 'sh'.  Keyed like chain plans (the chain
        measure key + ("gate", "policy")), cached in-process, persisted
        with the autotune table; inside a jit trace an unseeded key
        resolves to 'sh' (the safe no-reorder default) without caching.
        """
        Ls = tuple(int(L) for L in Ls)
        Lout = sum(Ls) if Lout is None else int(Lout)
        if isinstance(dtype, str) and dtype == "auto":
            dts = self._select_chain_dtype(
                Ls, Lout, batch_hint, sharded=False, entry_hint=entry_hint,
                out_hint=out_hint, share_hint=share_hint, tune="measure",
                gate=True)
        else:
            dts = _dtype_str(dtype)
        base = self._chain_measure_key(Ls, Lout, dts, batch_hint, entry_hint,
                                       out_hint, share_hint)
        key = dataclasses.replace(base,
                                  extra=base.extra + (("gate", "policy"),))
        self._maybe_load_cache()
        hit = self._measured.get(key)
        if hit is not None:
            return hit
        if not _trace_clean():
            return "sh"
        entries, share = base.opt("entries"), base.opt("share")
        B = base.batch_hint or 256
        rng = np.random.default_rng(0)
        rd = _RDTYPE[dts]
        from .rep import Rep

        xs, made = [], {}
        for L, e, g in zip(Ls, entries, share):
            x = made.get((g, L, e))
            if x is None:
                x = jnp.asarray(rng.normal(size=(B, num_coeffs(L))), dtype=rd)
                if e == "fourier":
                    x = Rep.from_sh(x, L).to_fourier("half")
                made[(g, L, e)] = x
            xs.append(x)
        gp = {"w1": jnp.asarray(rng.normal(size=(B, 16)), jnp.float32),
              "w2": jnp.asarray(rng.normal(size=(16, B)), jnp.float32)}
        self.timing_runs += 1

        def _time(fn):
            fn()  # compile + warm
            ts = []
            for _ in range(3):
                t0 = time.perf_counter()
                fn()
                ts.append(time.perf_counter() - t0)
            return sorted(ts)[1]

        kw = dict(dtype=dts, tune="measure", batch_hint=batch_hint,
                  entry_hint=entry_hint, out_hint=out_hint,
                  share_hint=share_hint)
        try:
            cpg = self.plan_chain(Ls, Lout, gate=True, **kw)
            cps = self.plan_chain(Ls, Lout, **kw)

            def grid_fn():
                jax.block_until_ready(
                    cpg.apply_jit(xs, out_basis=out_hint, gate_params=gp))

            if out_hint == "fourier":

                def sh_fn():
                    rep = cps.apply_jit(xs, out_basis="fourier")
                    sh = rep.to_sh()
                    out = Rep.from_sh(_gate_sh(gp, sh.data),
                                      rep.L).to_fourier("half")
                    jax.block_until_ready(out.data)

            else:

                def sh_fn():
                    jax.block_until_ready(_gate_sh(gp, cps.apply_jit(xs)))

            tg, tsh = _time(grid_fn), _time(sh_fn)
        except Exception:  # noqa: BLE001 — a failed measurement means 'sh'
            return "sh"
        winner = "grid" if tg < tsh else "sh"
        self._measured[key] = winner
        self._measured_t[key] = min(tg, tsh)
        self._autoflush()
        return winner

    def _select_dtype(self, make_key: Callable, tune: str,
                      requires_grad: bool) -> str:
        """Resolve a plan ``dtype='auto'`` request (pairwise/conv/manybody/
        channel_mix): time the best backend of each precision sibling under
        one key family and pick bf16 only where it beats f32.  Heuristic
        mode or a dirty trace resolves to float32 without measuring."""
        auto_key = make_key("auto")
        self._maybe_load_cache()
        hit = self._measured.get(auto_key)
        if hit is not None:
            return hit
        if tune != "measure" or not _trace_clean():
            return "float32"
        times = {}
        for dts in ("float32", "bfloat16"):
            key = make_key(dts)
            eligible = [b for b in _REGISTRY.values()
                        if b.eligible(key, requires_grad)]
            if not eligible:
                continue
            name = self._measured.get(key)
            if name is None:
                name, t = self._measure(key, eligible)
                if t is None:
                    continue  # cost-model fallback: nothing was timed
                self._measured[key] = name
                self._measured_t[key] = t
            t = self._measured_t.get(key)
            if t is not None:
                times[dts] = t
        winner = "bfloat16" if times.get("bfloat16", float("inf")) < \
            times.get("float32", float("inf")) else "float32"
        if times:
            # same rule as the chain variant: no timings, no cached winner
            self._measured[auto_key] = winner
            self._autoflush()
        return winner

    def calibrate_fused(self, L: int = 6, B: int = 64,
                        dtype: str = "float32") -> dict:
        """Measure the fused cost model's skinny-matmul factor on THIS host.

        Times the `fused_xla` collocation and the `dense_einsum` baseline on
        one reference pairwise workload, infers the per-MAC cost ratio the
        heuristic needs to rank them consistently with measurement, installs
        it under the *per-dtype* calibration key ('fused_skinny' for f32,
        'fused_skinny:<dtype>' otherwise — bf16's matmul/bandwidth ratio
        must not skew the f32 ranking and vice versa), and returns the
        record (benchmarks write it to BENCH_gaunt.json).
        """
        dts = _dtype_str(dtype)
        key = PlanKey(L, L, L, kind="pairwise", batch_hint=B, dtype=dts)
        args = _synthetic_inputs(key)
        self.timing_runs += 1
        times = {}
        for name in ("fused_xla", "dense_einsum"):
            apply = _REGISTRY[name].build(key)
            fn = jax.jit(lambda *a: apply(*a))
            jax.block_until_ready(fn(*args))
            ts = []
            for _ in range(5):
                t0 = time.perf_counter()
                jax.block_until_ready(fn(*args))
                ts.append(time.perf_counter() - t0)
            times[name] = sorted(ts)[len(ts) // 2]
        d = num_coeffs(L)
        G = ((2 * (2 * L) + 2) ** 2 + 127) // 128 * 128
        macs_fused = B * G * (3 * d)
        macs_dense = B * d * d * d
        factor = (times["fused_xla"] / macs_fused) / \
            (times["dense_einsum"] / macs_dense)
        factor = float(min(16.0, max(0.25, factor)))
        ck = _calib_key(dts)
        set_calibration(**{ck: factor, ck + "_measured": True})
        self._autoflush()
        return {"factor": round(factor, 3),
                "fused_xla_us": round(times["fused_xla"] * 1e6, 1),
                "dense_einsum_us": round(times["dense_einsum"] * 1e6, 1),
                "L": L, "B": B, "dtype": dts}

    def select(self, key: PlanKey, tune: str = "heuristic",
               requires_grad: bool = True) -> str:
        """Pick the backend for ``key`` by cost model or measurement."""
        eligible = [b for b in _REGISTRY.values() if b.eligible(key, requires_grad)]
        if not eligible:
            raise ValueError(f"no eligible backend for {key}")
        if tune == "measure":
            # load (and consult) the persisted table even inside a trace —
            # the JSON load is host-side Python; only *timing* needs a
            # clean trace
            self._maybe_load_cache()
            hit = self._measured.get(key)
            if hit is not None and any(b.name == hit for b in eligible):
                # the eligibility re-check guards persisted hits: a file
                # written under requires_grad=False may name a gradless
                # backend this call can't use — fall through and re-measure
                return hit
            if _trace_clean():
                name, t = self._measure(key, eligible)
                if t is not None:
                    self._measured[key] = name
                    self._measured_t[key] = t
                    self._autoflush()
                return name
        return min(eligible, key=lambda b: b.cost(key)).name

    def plans(self) -> list[GauntPlan]:
        return list(self._plans.values())

    def clear(self) -> None:
        self._plans.clear()
        self._batched.clear()
        self._chains.clear()
        self._measured.clear()
        self._measured_t.clear()
        # a cleared engine must behave like a fresh one: calibration is
        # module-global (shared by every engine's cost model), so restore
        # the defaults too, and re-arm the lazy persistent-cache load
        reset_calibration()
        self._cache_loaded = False
        self.timing_runs = 0

    # -- measured autotune -------------------------------------------------

    def _measure(self, key: PlanKey,
                 eligible: list[Backend]) -> tuple[str, float | None]:
        """Time the eligible backends on synthetic inputs.  -> (name, t);
        ``t`` is None when every backend failed and ``name`` is only the
        cost-model fallback — callers must NOT cache that as a measurement."""
        args = _synthetic_inputs(key)
        self.timing_runs += 1
        best_name, best_t = None, float("inf")
        for spec in eligible:
            if spec.needs_interpret and jax.default_backend() != "tpu":
                continue  # interpret-mode timing is meaningless
            try:
                apply = spec.build(key)
                if key.kind == "conv_filter" and spec.name != "escn_aligned":
                    apply = _wrap_conv_filter(key, apply)
                fn = jax.jit(lambda *a: apply(*a))
                jax.block_until_ready(fn(*args))  # compile + warm
                ts = []
                for _ in range(3):
                    t0 = time.perf_counter()
                    jax.block_until_ready(fn(*args))
                    ts.append(time.perf_counter() - t0)
                t = sorted(ts)[1]
            except Exception:  # noqa: BLE001 — a broken backend just loses
                continue
            if t < best_t:
                best_name, best_t = spec.name, t
        if best_name is None:  # everything failed: fall back to the cost model
            return min(eligible, key=lambda b: b.cost(key)).name, None
        return best_name, best_t


def _trace_clean() -> bool:
    try:
        return jax.core.trace_state_clean()
    except Exception:  # noqa: BLE001 — jax internals moved; assume clean
        return True


def _synthetic_inputs(key: PlanKey):
    B = key.batch_hint or 256
    rd = _RDTYPE[key.dtype]
    rng = np.random.default_rng(0)

    def r(*shape):
        return jnp.asarray(rng.normal(size=shape), dtype=rd)

    if key.kind == "pairwise":
        return r(B, num_coeffs(key.L1)), r(B, num_coeffs(key.L2))
    if key.kind == "conv_filter":
        v = rng.normal(size=(B, 3))
        v /= np.linalg.norm(v, axis=-1, keepdims=True)
        return r(B, num_coeffs(key.L1)), jnp.asarray(v, dtype=jnp.float32)
    if key.kind == "manybody":
        Ls = key.opt("Ls")
        return ([r(B, num_coeffs(L)) for L in Ls],)
    # channel_mix: small representative channel counts
    C1 = C2 = E = 4
    return (r(B, C1, num_coeffs(key.L1)), r(B, C2, num_coeffs(key.L2)),
            r(C1, C2, E))


_ENGINE = GauntEngine()


def get_engine() -> GauntEngine:
    """The process-wide engine (plan + autotune caches are shared)."""
    return _ENGINE


def plan(*args, **kw) -> GauntPlan:
    """Module-level shorthand for ``get_engine().plan(...)``."""
    return _ENGINE.plan(*args, **kw)


def plan_batch(*args, **kw) -> BatchedGauntPlan:
    """Module-level shorthand for ``get_engine().plan_batch(...)``."""
    return _ENGINE.plan_batch(*args, **kw)


def plan_chain(*args, **kw) -> ChainPlan:
    """Module-level shorthand for ``get_engine().plan_chain(...)``."""
    return _ENGINE.plan_chain(*args, **kw)
