"""Unified Gaunt execution engine — one plan/dispatch layer for every Gaunt op.

This repo grew several concrete realizations of the paper's O(L^3) Gaunt
tensor product (dense/packed spectral conversions x fft/direct convolution,
the fused collocation kernel, the eSCN rotation-aligned convolution).  The
engine makes them *backends* behind a single planning API (DESIGN.md §4):

    plan = engine.plan(L1, L2, Lout, kind="pairwise", batch_hint=4096)
    out  = plan.apply(x1, x2, w1=w1)          # paper's w_{l1} w_{l2} w_l hooks

A plan is keyed by ``(L1, L2, Lout, kind, batch_hint, dtype)`` (+ kind
specific extras) and resolved to a registered backend:

    kind         backends
    pairwise     dense_einsum | fft | direct | packed | fused_xla | fused_pallas
    conv_filter  escn_aligned + every pairwise backend (filter materialized)
    manybody     dense_einsum | fft | direct | packed
    channel_mix  dense_einsum | fused_xla

Backends carry capability flags (grad support, dtype support, whether Pallas
must run in interpret mode off-TPU); selection is either a closed-form cost
model (``tune="heuristic"``) or measured wall-time on synthetic inputs with
an in-process autotune cache (``tune="measure"``).  Plans and their constants
are cached: planning twice is free, and all numpy precompute lives in the
central :mod:`repro.core.constants` cache.

Thin public wrappers (`GauntTensorProduct`, `EquivariantConv`,
`manybody_gaunt_product`, `gaunt_tp_channel_mix`, the model `_tp` hook) keep
their historical signatures and route here.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from . import constants
from .irreps import l_array, num_coeffs

__all__ = [
    "PlanKey",
    "Backend",
    "GauntPlan",
    "GauntEngine",
    "register_backend",
    "available_backends",
    "expand_degree_weights",
    "get_engine",
    "plan",
]

KINDS = ("pairwise", "conv_filter", "manybody", "channel_mix")

_RDTYPE = {"float32": jnp.float32, "bfloat16": jnp.bfloat16, "float64": jnp.float64}
_CDTYPE = {"float32": "complex64", "bfloat16": "complex64", "float64": "complex128"}


def _dtype_str(dtype) -> str:
    """Normalize any dtype spec (incl. the wrappers' cdtype) to a plan key."""
    s = jnp.dtype(dtype).name
    if s.startswith("complex"):
        return "float64" if s == "complex128" else "float32"
    return s


def expand_degree_weights(w, L: int):
    """w [..., L+1] per-degree -> [..., (L+1)^2] packed broadcast.

    The canonical implementation (gaunt.py re-exports it for back-compat).
    """
    return w[..., jnp.asarray(l_array(L).astype(np.int32))]


def _wmul(x, w, L: int):
    return x if w is None else x * expand_degree_weights(w, L).astype(x.dtype)


# --------------------------------------------------------------------------
# plan keys and backend registry
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PlanKey:
    """Identity of a planned Gaunt op (hashable; the plan-cache key)."""

    L1: int
    L2: int
    Lout: int
    kind: str = "pairwise"
    batch_hint: int | None = None
    dtype: str = "float32"
    # kind/backend-specific knobs, as a sorted tuple of (name, value) pairs:
    # manybody carries ("Ls", (...)); packed carries ("conv", "fft"|"direct").
    extra: tuple = ()

    def opt(self, name: str, default=None):
        return dict(self.extra).get(name, default)


@dataclasses.dataclass(frozen=True)
class Backend:
    """A registered Gaunt realization with capability flags."""

    name: str
    kinds: frozenset
    build: Callable[[PlanKey], Callable] = dataclasses.field(repr=False, compare=False, default=None)
    cost: Callable[[PlanKey], float] = dataclasses.field(repr=False, compare=False, default=None)
    supports_grad: bool = True
    dtypes: frozenset = frozenset({"float32", "bfloat16", "float64"})
    needs_interpret: bool = False  # Pallas: off-TPU only via (slow) interpret mode

    def eligible(self, key: PlanKey, requires_grad: bool) -> bool:
        if key.dtype not in self.dtypes:
            return False
        if requires_grad and not self.supports_grad:
            return False
        if key.kind in self.kinds:
            return True
        # any pairwise backend can serve conv_filter by materializing Y(rhat)
        return key.kind == "conv_filter" and "pairwise" in self.kinds


_REGISTRY: dict[str, Backend] = {}


def register_backend(backend: Backend) -> Backend:
    _REGISTRY[backend.name] = backend
    return backend


def available_backends(kind: str = "pairwise", dtype: str = "float32",
                       requires_grad: bool = True) -> list[str]:
    key = PlanKey(1, 1, 2, kind=kind, dtype=dtype)
    return [b.name for b in _REGISTRY.values() if b.eligible(key, requires_grad)]


@dataclasses.dataclass(frozen=True)
class GauntPlan:
    """A resolved (key, backend) pair; ``apply`` runs the op."""

    key: PlanKey
    backend: str
    apply: Callable = dataclasses.field(repr=False, compare=False)

    def describe(self) -> str:
        k = self.key
        return (f"{k.kind}(L1={k.L1}, L2={k.L2}, Lout={k.Lout}, "
                f"dtype={k.dtype}, batch_hint={k.batch_hint}) -> {self.backend}")


# --------------------------------------------------------------------------
# cost model (relative real-MAC counts; calibrated coarsely, see DESIGN.md §4)
# --------------------------------------------------------------------------

_C_CPLX = 4.0        # complex MAC = 4 real MACs
_C_FFT = 10.0        # per point per log2 level: tiny-grid FFTs vectorize poorly
_OVERHEAD = 3e4      # per dispatched op: favors fewer, denser ops at small sizes
_INTERPRET_PENALTY = 1e4   # Pallas interpret mode off-TPU is not a real option


def _dims(key: PlanKey):
    B = key.batch_hint or 1
    n1, n2 = 2 * key.L1 + 1, 2 * key.L2 + 1
    N = n1 + n2 - 1
    return B, num_coeffs(key.L1), num_coeffs(key.L2), num_coeffs(key.Lout), n1, n2, N


def _cost_dense_einsum(key: PlanKey) -> float:
    B, d1, d2, do, *_ = _dims(key)
    if key.kind == "channel_mix":
        return 16.0 * B * d1 * d2 * do + _OVERHEAD  # x C1*C2 (unknown): scaled proxy
    if key.kind == "manybody":
        Ls = key.opt("Ls", (key.L1, key.L2))
        total, La = 0.0, Ls[0]
        for L in Ls[1:]:
            total += B * num_coeffs(La) * num_coeffs(L) * num_coeffs(La + L)
            La += L
        return total + _OVERHEAD * len(Ls)
    return B * d1 * d2 * do + _OVERHEAD


def _spectral_common(key: PlanKey, conv: str, packed: bool) -> float:
    B, d1, d2, do, n1, n2, N = _dims(key)
    if packed:  # O(L^3) stacked matmuls
        conv_in = 4.0 * B * (key.L1 + 1) ** 3 + 4.0 * B * (key.L2 + 1) ** 3
        proj = 8.0 * B * (key.Lout + 1) ** 2 * N
    else:  # O(L^4) dense einsum conversions
        conv_in = 2.0 * B * (d1 * n1 * n1 + d2 * n2 * n2)
        proj = _C_CPLX * B * N * N * do
    if conv == "fft":
        c = 3.0 * _C_FFT * B * N * N * max(1.0, math.log2(N * N)) + _C_CPLX * B * N * N
    else:
        c = _C_CPLX * B * N * N * n2 * n2
    n_ops = 8 if not packed else 14
    return conv_in + c + proj + _OVERHEAD * n_ops


def _cost_fft(key):
    if key.kind == "manybody":
        return _cost_manybody_spectral(key, "fft", packed=False)
    return _spectral_common(key, "fft", packed=False)


def _cost_direct(key):
    if key.kind == "manybody":
        return _cost_manybody_spectral(key, "direct", packed=False)
    return _spectral_common(key, "direct", packed=False)


def _cost_packed(key):
    conv = key.opt("conv", "fft")
    if key.kind == "manybody":
        return _cost_manybody_spectral(key, conv, packed=True)
    return _spectral_common(key, conv, packed=True)


def _cost_manybody_spectral(key: PlanKey, conv: str, packed: bool) -> float:
    Ls = key.opt("Ls", (key.L1, key.L2))
    B = key.batch_hint or 1
    Lt = sum(Ls)
    N = 2 * Lt + 1
    convs = _C_FFT * len(Ls) * B * N * N * max(1.0, math.log2(N * N)) if conv == "fft" \
        else _C_CPLX * len(Ls) * B * N * N * (2 * max(Ls) + 1) ** 2
    conv_in = sum(2.0 * B * num_coeffs(L) * (2 * L + 1) ** 2 for L in Ls)
    proj = _C_CPLX * B * N * N * num_coeffs(key.Lout)
    return conv_in + convs + proj + _OVERHEAD * (6 + 2 * len(Ls))


def _cost_fused(key: PlanKey, pallas: bool) -> float:
    B, d1, d2, do, n1, n2, N = _dims(key)
    Nf = 2 * (key.L1 + key.L2) + 2
    G = ((Nf * Nf + 127) // 128) * 128
    c = B * G * (d1 + d2 + do) + _OVERHEAD * 4
    if key.kind == "channel_mix":
        c = 16.0 * B * G * (d1 + d2 + do) + _OVERHEAD * 4
    if pallas:
        c *= 0.5 if jax.default_backend() == "tpu" else _INTERPRET_PENALTY
    return c


def _cost_escn(key: PlanKey) -> float:
    B, d1, d2, do, n1, n2, N = _dims(key)
    Lw = max(key.L1, key.Lout)
    wigner = B * sum((2 * l + 1) ** 4 for l in range(2, Lw + 1)) + \
        2.0 * B * sum((2 * l + 1) ** 2 for l in range(Lw + 1))
    s2f = 2.0 * B * d1 * n1 * n1
    banded = _C_CPLX * B * N * n1 * n1
    proj = _C_CPLX * B * N * N * do
    return wigner + s2f + banded + proj + _OVERHEAD * 10


# --------------------------------------------------------------------------
# backend builders
# --------------------------------------------------------------------------


def _build_dense_einsum(key: PlanKey) -> Callable:
    gd = "float64" if key.dtype == "float64" else "float32"
    rd = _RDTYPE[key.dtype]
    if key.kind == "channel_mix":
        G = constants.gaunt_dense(key.L1, key.L2, key.Lout, gd)

        def apply_mix(x1, x2, w_mix):
            Gj = jnp.asarray(G)
            out = jnp.einsum("...ci,...dj,ijk,cde->...ek",
                             x1.astype(Gj.dtype), x2.astype(Gj.dtype), Gj,
                             w_mix.astype(Gj.dtype))
            return out.astype(rd)

        return apply_mix
    if key.kind == "manybody":
        Ls = key.opt("Ls")

        def apply_mb(xs, weights=None):
            xs = list(xs)
            if weights is not None:
                xs = [_wmul(x, w, L) for x, w, L in zip(xs, weights, Ls)]
            acc, La = xs[0], Ls[0]
            for i, (x, L) in enumerate(zip(xs[1:], Ls[1:])):
                last = i == len(Ls) - 2
                Lt = key.Lout if last else La + L
                G = jnp.asarray(constants.gaunt_dense(La, L, Lt, gd))
                acc = jnp.einsum("...i,...j,ijk->...k",
                                 acc.astype(G.dtype), x.astype(G.dtype), G)
                La += L
            return acc.astype(rd)

        return apply_mb
    G = constants.gaunt_dense(key.L1, key.L2, key.Lout, gd)

    def apply_pair(x1, x2, w1=None, w2=None, w3=None):
        Gj = jnp.asarray(G)
        x1 = _wmul(x1, w1, key.L1).astype(Gj.dtype)
        x2 = _wmul(x2, w2, key.L2).astype(Gj.dtype)
        out = jnp.einsum("...i,...j,ijk->...k", x1, x2, Gj)
        return _wmul(out.astype(rd), w3, key.Lout)

    return apply_pair


def _build_spectral(key: PlanKey, conversion: str, conv: str) -> Callable:
    from .gaunt import conv2d_full, fourier_to_sh, sh_to_fourier  # lazy: gaunt imports engine

    cd = _CDTYPE[key.dtype]
    rd = _RDTYPE[key.dtype]
    # warm constants at plan time so jit tracing never re-runs numpy precompute
    if key.kind != "manybody":
        if conversion == "dense":
            constants.y_dense(key.L1, cd), constants.y_dense(key.L2, cd)
            constants.z_dense(key.L1 + key.L2, key.Lout, cd)
        else:
            constants.y_packed(key.L1, cd), constants.y_packed(key.L2, cd)
            constants.z_packed(key.L1 + key.L2, key.Lout, cd)

    if key.kind == "manybody":
        from .manybody import _tree_convolve

        Ls = key.opt("Ls")
        Ltot = sum(Ls)
        if conversion == "dense":
            for L in Ls:
                constants.y_dense(L, cd)
            constants.z_dense(Ltot, key.Lout, cd)
        else:
            for L in Ls:
                constants.y_packed(L, cd)
            constants.z_packed(Ltot, key.Lout, cd)

        def apply_mb(xs, weights=None):
            grids = []
            for i, (x, L) in enumerate(zip(xs, Ls)):
                if weights is not None and weights[i] is not None:
                    x = _wmul(x, weights[i], L)
                grids.append(sh_to_fourier(x, L, conversion, jnp.dtype(cd)))
            F = _tree_convolve(grids, conv)
            return fourier_to_sh(F, Ltot, key.Lout, conversion, rd)

        return apply_mb

    def apply_pair(x1, x2, w1=None, w2=None, w3=None):
        x1 = _wmul(x1, w1, key.L1)
        x2 = _wmul(x2, w2, key.L2)
        F1 = sh_to_fourier(x1, key.L1, conversion, jnp.dtype(cd))
        F2 = sh_to_fourier(x2, key.L2, conversion, jnp.dtype(cd))
        F3 = conv2d_full(F1, F2, conv)
        out = fourier_to_sh(F3, key.L1 + key.L2, key.Lout, conversion, rd)
        return _wmul(out, w3, key.Lout)

    return apply_pair


def _build_fused(key: PlanKey, pallas: bool) -> Callable:
    rd = _RDTYPE[key.dtype]
    T1, T2, P = constants.fused_matrices(key.L1, key.L2, key.Lout)

    if key.kind == "channel_mix":

        def apply_mix(x1, x2, w_mix):
            T1j, T2j, Pj = jnp.asarray(T1), jnp.asarray(T2), jnp.asarray(P)
            V1 = x1.astype(jnp.float32) @ T1j  # [..., C1, G]
            V2 = x2.astype(jnp.float32) @ T2j  # [..., C2, G]
            V = jnp.einsum("...cg,...dg,cde->...eg", V1, V2, w_mix.astype(V1.dtype))
            return (V @ Pj).astype(rd)

        return apply_mix

    if pallas:
        block_b = key.opt("block_b", 256)

        def apply_pair(x1, x2, w1=None, w2=None, w3=None):
            from repro.kernels.gaunt_fused import gaunt_fused_pallas  # lazy: kernels import core

            x1 = _wmul(x1, w1, key.L1)
            x2 = _wmul(x2, w2, key.L2)
            out = gaunt_fused_pallas(x1, x2, key.L1, key.L2, key.Lout, block_b=block_b)
            return _wmul(out.astype(rd), w3, key.Lout)

        return apply_pair

    def apply_pair(x1, x2, w1=None, w2=None, w3=None):
        T1j, T2j, Pj = jnp.asarray(T1), jnp.asarray(T2), jnp.asarray(P)
        x1 = _wmul(x1, w1, key.L1)
        x2 = _wmul(x2, w2, key.L2)
        v1 = x1.astype(jnp.float32) @ T1j
        v2 = x2.astype(jnp.float32) @ T2j
        out = ((v1 * v2) @ Pj).astype(rd)
        return _wmul(out, w3, key.Lout)

    return apply_pair


def _build_escn(key: PlanKey) -> Callable:
    cd = _CDTYPE[key.dtype]
    rd = _RDTYPE[key.dtype]
    L1, L2, Lout = key.L1, key.L2, key.Lout
    constants.y_dense(L1, cd)
    constants.z_dense(L1 + L2, Lout, cd)
    constants.filter_fourier_col(L2, cd)
    constants.conv_u_index(L1, L2)
    constants.cg_11_blocks(max(L1, Lout))
    fl0 = np.array([math.sqrt((2 * l + 1) / (4 * math.pi)) for l in range(L2 + 1)],
                   dtype=np.float32)

    def apply_conv(x, rhat, w1=None, w2=None, w3=None):
        # lazy: conv.py routes through the engine, so import its helpers at call
        from .conv import align_rotation, apply_wigner_blocks, wigner_blocks_from_rotmat
        from .gaunt import fourier_to_sh, sh_to_fourier

        x = _wmul(x, w1, L1)
        R = align_rotation(rhat.astype(jnp.float32))
        Ds = wigner_blocks_from_rotmat(max(L1, Lout), R)
        x_rot = apply_wigner_blocks(Ds[: L1 + 1], x)
        F1 = sh_to_fourier(x_rot, L1, "dense", jnp.dtype(cd))  # [..., n1, n1]
        # filter coefficients: only m=0 -> single v=0 column, O(L^2)
        fl = jnp.asarray(fl0, dtype=rd)
        if w2 is not None:
            fl = fl * w2.astype(rd)
        cols = jnp.asarray(constants.filter_fourier_col(L2, cd))
        k = jnp.einsum("...l,lu->...u", fl.astype(cols.dtype), cols)  # [..., 2L2+1]
        # banded 1D conv along u for every v column (v support unchanged)
        gidx, mask = constants.conv_u_index(L1, L2)
        kmat = k[..., jnp.asarray(gidx)] * jnp.asarray(mask, dtype=rd)  # [..., N, n1]
        F3 = jnp.einsum("...ti,...iv->...tv", kmat, F1)  # [..., N, n1(v)]
        # pad v axis to the full output grid (v support still |v| <= L1)
        pv = (2 * (L1 + L2) + 1 - (2 * L1 + 1)) // 2
        F3 = jnp.pad(F3, [(0, 0)] * (F3.ndim - 1) + [(pv, pv)])
        out_rot = fourier_to_sh(F3, L1 + L2, Lout, "dense", rd)
        out = apply_wigner_blocks(Ds[: Lout + 1], out_rot, transpose=True)
        return _wmul(out, w3, Lout)

    return apply_conv


def _wrap_conv_filter(key: PlanKey, pair_apply: Callable) -> Callable:
    """Serve kind='conv_filter' on a pairwise backend: materialize Y(rhat)."""

    def apply_conv(x, rhat, w1=None, w2=None, w3=None):
        from .so3 import real_sph_harm_jax

        filt = real_sph_harm_jax(key.L2, rhat).astype(x.dtype)
        return pair_apply(x, filt, w1, w2, w3)

    return apply_conv


register_backend(Backend(
    name="dense_einsum",
    kinds=frozenset({"pairwise", "conv_filter", "manybody", "channel_mix"}),
    build=_build_dense_einsum,
    cost=_cost_dense_einsum,
))
register_backend(Backend(
    name="fft",
    kinds=frozenset({"pairwise", "conv_filter", "manybody"}),
    build=lambda key: _build_spectral(key, "dense", "fft"),
    cost=_cost_fft,
))
register_backend(Backend(
    name="direct",
    kinds=frozenset({"pairwise", "conv_filter", "manybody"}),
    build=lambda key: _build_spectral(key, "dense", "direct"),
    cost=_cost_direct,
))
register_backend(Backend(
    name="packed",
    kinds=frozenset({"pairwise", "conv_filter", "manybody"}),
    build=lambda key: _build_spectral(key, "packed", key.opt("conv", "fft")),
    cost=_cost_packed,
))
register_backend(Backend(
    name="fused_xla",
    kinds=frozenset({"pairwise", "conv_filter", "channel_mix"}),
    build=lambda key: _build_fused(key, pallas=False),
    cost=lambda key: _cost_fused(key, pallas=False),
    dtypes=frozenset({"float32", "bfloat16"}),
))
register_backend(Backend(
    name="fused_pallas",
    kinds=frozenset({"pairwise", "conv_filter"}),
    build=lambda key: _build_fused(key, pallas=True),
    cost=lambda key: _cost_fused(key, pallas=True),
    supports_grad=False,  # pallas_call has no registered VJP
    dtypes=frozenset({"float32", "bfloat16"}),
    needs_interpret=True,
))
register_backend(Backend(
    name="escn_aligned",
    kinds=frozenset({"conv_filter"}),
    build=_build_escn,
    cost=_cost_escn,
))


# --------------------------------------------------------------------------
# the engine
# --------------------------------------------------------------------------


class GauntEngine:
    """Plans, caches, and autotunes Gaunt ops over the backend registry."""

    def __init__(self):
        self._plans: dict[tuple, GauntPlan] = {}
        self._measured: dict[PlanKey, str] = {}

    # -- public API --------------------------------------------------------

    def plan(self, L1: int | None = None, L2: int | None = None,
             Lout: int | None = None, *, kind: str = "pairwise",
             Ls: tuple | None = None, batch_hint: int | None = None,
             dtype="float32", backend: str | None = None,
             options: dict | None = None, tune: str = "heuristic",
             requires_grad: bool = True) -> GauntPlan:
        """Resolve (and cache) a plan.  ``backend=None`` -> engine selection.

        kind='manybody' takes ``Ls`` (per-operand degrees) instead of L1/L2.
        ``tune`` is 'heuristic' (cost model) or 'measure' (timed autotune).
        """
        if kind not in KINDS:
            raise ValueError(f"unknown kind {kind!r} (expected one of {KINDS})")
        extra = tuple(sorted((options or {}).items()))
        if kind == "manybody":
            if Ls is None or len(Ls) < 2:
                raise ValueError("manybody plans need Ls with >= 2 degrees")
            Ls = tuple(int(L) for L in Ls)
            L1, L2 = max(Ls), min(Ls)
            Lout = sum(Ls) if Lout is None else Lout
            extra = extra + (("Ls", Ls),)
        else:
            if L1 is None or L2 is None:
                raise ValueError(f"kind={kind!r} plans need L1 and L2")
            Lout = L1 + L2 if Lout is None else Lout
        if Lout > (sum(Ls) if kind == "manybody" else L1 + L2):
            raise ValueError("Lout cannot exceed the total degree (Gaunt selection rule)")
        key = PlanKey(L1, L2, Lout, kind, batch_hint, _dtype_str(dtype), extra)
        cache_key = (key, backend, tune, requires_grad)
        hit = self._plans.get(cache_key)
        if hit is not None:
            return hit
        name = backend or self.select(key, tune=tune, requires_grad=requires_grad)
        spec = _REGISTRY.get(name)
        if spec is None:
            raise ValueError(f"unknown backend {name!r}; registered: {sorted(_REGISTRY)}")
        if not spec.eligible(key, requires_grad):
            raise ValueError(f"backend {name!r} cannot serve {key} "
                             f"(requires_grad={requires_grad})")
        apply = spec.build(key)
        if key.kind == "conv_filter" and spec.name != "escn_aligned":
            # generic backends build the pairwise form; materialize Y(rhat)
            apply = _wrap_conv_filter(key, apply)
        p = GauntPlan(key=key, backend=name, apply=apply)
        self._plans[cache_key] = p
        return p

    def select(self, key: PlanKey, tune: str = "heuristic",
               requires_grad: bool = True) -> str:
        """Pick the backend for ``key`` by cost model or measurement."""
        eligible = [b for b in _REGISTRY.values() if b.eligible(key, requires_grad)]
        if not eligible:
            raise ValueError(f"no eligible backend for {key}")
        if tune == "measure" and _trace_clean():
            hit = self._measured.get(key)
            if hit is not None:
                return hit
            name = self._measure(key, eligible)
            self._measured[key] = name
            return name
        return min(eligible, key=lambda b: b.cost(key)).name

    def plans(self) -> list[GauntPlan]:
        return list(self._plans.values())

    def clear(self) -> None:
        self._plans.clear()
        self._measured.clear()

    # -- measured autotune -------------------------------------------------

    def _measure(self, key: PlanKey, eligible: list[Backend]) -> str:
        args = _synthetic_inputs(key)
        best_name, best_t = None, float("inf")
        for spec in eligible:
            if spec.needs_interpret and jax.default_backend() != "tpu":
                continue  # interpret-mode timing is meaningless
            try:
                apply = spec.build(key)
                if key.kind == "conv_filter" and spec.name != "escn_aligned":
                    apply = _wrap_conv_filter(key, apply)
                fn = jax.jit(lambda *a: apply(*a))
                jax.block_until_ready(fn(*args))  # compile + warm
                ts = []
                for _ in range(3):
                    t0 = time.perf_counter()
                    jax.block_until_ready(fn(*args))
                    ts.append(time.perf_counter() - t0)
                t = sorted(ts)[1]
            except Exception:  # noqa: BLE001 — a broken backend just loses
                continue
            if t < best_t:
                best_name, best_t = spec.name, t
        if best_name is None:  # everything failed: fall back to the cost model
            return min(eligible, key=lambda b: b.cost(key)).name
        return best_name


def _trace_clean() -> bool:
    try:
        return jax.core.trace_state_clean()
    except Exception:  # noqa: BLE001 — jax internals moved; assume clean
        return True


def _synthetic_inputs(key: PlanKey):
    B = key.batch_hint or 256
    rd = _RDTYPE[key.dtype]
    rng = np.random.default_rng(0)

    def r(*shape):
        return jnp.asarray(rng.normal(size=shape), dtype=rd)

    if key.kind == "pairwise":
        return r(B, num_coeffs(key.L1)), r(B, num_coeffs(key.L2))
    if key.kind == "conv_filter":
        v = rng.normal(size=(B, 3))
        v /= np.linalg.norm(v, axis=-1, keepdims=True)
        return r(B, num_coeffs(key.L1)), jnp.asarray(v, dtype=jnp.float32)
    if key.kind == "manybody":
        Ls = key.opt("Ls")
        return ([r(B, num_coeffs(L)) for L in Ls],)
    # channel_mix: small representative channel counts
    C1 = C2 = E = 4
    return (r(B, C1, num_coeffs(key.L1)), r(B, C2, num_coeffs(key.L2)),
            r(C1, C2, E))


_ENGINE = GauntEngine()


def get_engine() -> GauntEngine:
    """The process-wide engine (plan + autotune caches are shared)."""
    return _ENGINE


def plan(*args, **kw) -> GauntPlan:
    """Module-level shorthand for ``get_engine().plan(...)``."""
    return _ENGINE.plan(*args, **kw)
