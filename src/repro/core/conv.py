"""Equivariant Convolution (paper §3.3, class 2): x_i (x)_Gaunt Y(r_ij).

Two paths, tested equal:

general : evaluate the SH filter Y(r_hat) directly and run the Gaunt TP.

escn    : Passaro & Zitnick insight adapted to our z-up convention —
          rotate the frame so the edge lands on the zenith; the filter then
          has only m = 0 components,  S_{l,m}(e_z) = delta_{m0} sqrt((2l+1)/4pi),
          so its torus-Fourier coefficients occupy the single v = 0 column
          (O(L^2) conversion, Eqn. 58 of the paper) and the 2D convolution
          degenerates to a per-v 1D convolution along u (a small banded
          matmul — MXU-friendly).  out = D^T [ (D x) (x)_Gaunt Y(e_z) ].

Wigner rotations are built *differentiably* from the rotation matrix by the
CG intertwiner recursion  D^l = C^T (D^{l-1} (x) D^1) C  — no Euler angles on
the hot path (TPU adaptation; eSCN's CUDA code uses host-precomputed Wigner
matrices instead).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from . import constants as _const

__all__ = [
    "align_rotation",
    "wigner_blocks_from_rotmat",
    "apply_wigner_blocks",
    "WignerBlocks",
    "EquivariantConv",
]


def align_rotation(rhat):
    """[..., 3] unit vectors -> rotation matrices R with R @ rhat = e_z.

    Differentiable away from the (measure-zero) frame-switch boundary.
    """
    r = rhat / jnp.linalg.norm(rhat, axis=-1, keepdims=True)
    ex = jnp.broadcast_to(jnp.array([1.0, 0.0, 0.0], dtype=r.dtype), r.shape)
    ez = jnp.broadcast_to(jnp.array([0.0, 0.0, 1.0], dtype=r.dtype), r.shape)
    use_z = (jnp.abs(r[..., 0:1]) > 0.9).astype(r.dtype)
    u = use_z * ez + (1 - use_z) * ex
    b1 = jnp.cross(u, r)
    b1 = b1 / jnp.linalg.norm(b1, axis=-1, keepdims=True)
    b2 = jnp.cross(r, b1)
    return jnp.stack([b1, b2, r], axis=-2)  # rows


def wigner_blocks_from_rotmat(L: int, R):
    """Real Wigner-D blocks [D^0, ..., D^L] for rotation matrices R [..., 3, 3].

    D^1 = P R P^T with P the (x,y,z) -> (m=-1,0,1)=(y,z,x) reordering;
    D^l = C^T (D^{l-1} (x) D^1) C  (orthogonality of the real CG block).
    """
    shp = R.shape[:-2]
    Ds = [jnp.ones(shp + (1, 1), dtype=R.dtype)]
    if L == 0:
        return Ds
    P = jnp.asarray(
        np.array([[0, 1, 0], [0, 0, 1], [1, 0, 0]], dtype=np.float32), dtype=R.dtype
    )  # row m=-1 <- y, m=0 <- z, m=1 <- x
    D1 = jnp.einsum("ai,...ij,bj->...ab", P, R, P)
    Ds.append(D1)
    for l in range(2, L + 1):
        C = jnp.asarray(_const.cg_11_blocks(L)[l - 2], dtype=R.dtype)
        Dl = jnp.einsum(
            "ijk,...ia,...jb,abm->...km", C, Ds[l - 1], D1, C
        )
        Ds.append(Dl)
    return Ds


def apply_wigner_blocks(Ds, x, transpose: bool = False):
    """Apply block-diagonal Wigner rotation to packed features x [..., (L+1)^2]."""
    outs = []
    for l, D in enumerate(Ds):
        blk = x[..., l * l : (l + 1) ** 2]
        eq = "...ji,...j->...i" if transpose else "...ij,...j->...i"
        outs.append(jnp.einsum(eq, D, blk))
    return jnp.concatenate(outs, axis=-1)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class WignerBlocks:
    """Precomputed rotation-aligned geometry for the eSCN conv path.

    Holds the Wigner-D blocks [D^0, ..., D^L] built from `align_rotation` of
    a fixed edge geometry — the analogue of `EquivariantConv.filter_rep` for
    the rotation-aligned backend: edge geometry is layer-constant in a model
    stack, so the alignment rotation and the CG Wigner recursion run ONCE per
    geometry instead of once per layer.  A pytree (the blocks are the leaves),
    so it flows through jit/vmap/grad and the engine's batched bucket layout
    (each block is a [..., 2l+1, 2l+1] row-parallel leaf).
    """

    blocks: tuple

    @property
    def L(self) -> int:
        return len(self.blocks) - 1

    def tree_flatten(self):
        return tuple(self.blocks), len(self.blocks)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(tuple(children))

    @classmethod
    def from_rhat(cls, rhat, L: int) -> "WignerBlocks":
        R = align_rotation(rhat.astype(jnp.float32))
        return cls(tuple(wigner_blocks_from_rotmat(L, R)))


class EquivariantConv:
    """Gaunt-accelerated equivariant convolution  (x (x) Y(rhat)) with the
    paper's w_{l1} w_{l2} w_l weight reparameterization.

    Thin wrapper over the unified engine (kind='conv_filter'), routed through
    a batched plan: the edge leading dims ([n, n, C] in the models) are
    flattened to one row axis and executed as a single fused invocation, with
    optional operand-buffer donation (`donate`) and sharded dispatch over the
    mesh's data axes (`shard_spec`, see engine.ShardSpec / DESIGN.md §5).

    method='escn' -> the 'escn_aligned' backend (rotation-alignment sparsity,
    default); method='general' -> a generic pairwise backend with the SH
    filter materialized; method='auto' -> engine selection.  `backend` pins
    any registered backend directly.

    Fourier-resident filters (DESIGN.md §6): when the edge geometry is fixed
    across several products (a layer stack over one graph), materialize the
    filter ONCE with :meth:`filter_rep` and pass the resulting Rep instead of
    ``rhat`` — the call routes through a Fourier-boundary pairwise plan that
    skips the filter's SH->Fourier conversion on every reuse.
    """

    def __init__(self, L1: int, L2: int, Lout: int | None = None, method: str = "escn",
                 cdtype=jnp.complex64, rdtype=jnp.float32,
                 backend: str | None = None, batch_hint: int | None = None,
                 tune: str = "heuristic", donate: bool = False,
                 shard_spec=None):
        from . import engine as _engine

        self.L1, self.L2 = L1, L2
        self.Lout = L1 + L2 if Lout is None else Lout
        self.method = method
        self.cdtype, self.rdtype = cdtype, rdtype
        dtype = _engine._dtype_str(cdtype)
        if backend is None:
            if method == "escn":
                backend = "escn_aligned"
            elif method == "general":
                backend = "direct" if max(L1, L2) <= 4 else "fft"
            elif method == "auto":
                backend = None
            else:
                raise ValueError(f"unknown method {method!r}")
        self._bplan = _engine.plan_batch(
            [_engine.BatchItem(L1=L1, L2=L2, Lout=self.Lout, size=batch_hint)],
            kind="conv_filter", dtype=dtype, backend=backend, tune=tune,
            donate=donate, shard_spec=shard_spec,
        )
        self._plan = self._bplan.buckets[0].plan
        self.backend = self._plan.backend
        self._donate, self._shard_spec = donate, shard_spec
        self._tune = tune
        self._resident_plan = None
        self._resident_bplan = None
        self._geom_bplan = None

    @property
    def plan(self):
        return self._plan

    @property
    def batched_plan(self):
        return self._bplan

    # -- Fourier-resident filters -----------------------------------------

    def _spectral_backend(self) -> str:
        """A Fourier-boundary-capable backend matching this conv's choice."""
        from .engine import spectral_default

        if self.backend in ("fft", "direct", "packed", "rfft"):
            return self.backend
        return spectral_default(self.L1, self.L2)

    def filter_rep(self, rhat, w2=None):
        """Materialize Y(rhat) and convert it to a Fourier-resident Rep once.

        ``w2`` (per-degree filter weights [..., L2+1]) must be folded in here
        — a resident operand cannot take per-degree weights downstream."""
        from .gaunt import expand_degree_weights
        from .rep import Rep
        from .so3 import real_sph_harm_jax

        filt = real_sph_harm_jax(self.L2, rhat)
        if w2 is not None:
            filt = filt * expand_degree_weights(w2, self.L2).astype(filt.dtype)
        conversion = "half" if self._spectral_backend() == "rfft" else "dense"
        return Rep.from_sh(filt, self.L2).to_fourier(conversion, self.cdtype)

    def geometry_rep(self, rhat) -> "WignerBlocks":
        """Precompute the rotation-aligned geometry (eSCN path) ONCE.

        `align_rotation` + the CG Wigner recursion are the dominant per-call
        setup of the 'escn_aligned' backend; edge geometry is layer-constant
        in a model stack, so hoist them per geometry and pass the resulting
        :class:`WignerBlocks` in place of ``rhat`` — the analogue of
        :meth:`filter_rep` for the aligned path."""
        if self.backend != "escn_aligned":
            raise ValueError("geometry_rep is the eSCN (rotation-aligned) "
                             f"residency hook; this conv uses {self.backend!r} "
                             "— use filter_rep for the general path")
        return WignerBlocks.from_rhat(rhat, max(self.L1, self.Lout))

    def _resident_batched(self):
        """The Fourier-boundary batched plan (built lazily): same execution
        knobs (donate/shard_spec/tune) as the raw-rhat route, so residency
        and batched/donated/sharded dispatch compose instead of excluding
        each other."""
        from . import engine as _engine

        if self._resident_bplan is None:
            self._resident_bplan = _engine.plan_batch(
                [_engine.BatchItem(
                    L1=self.L1, L2=self.L2, Lout=self.Lout,
                    options=(("boundary", ("sh", "fourier", "sh")),))],
                kind="pairwise", dtype=_engine._dtype_str(self.cdtype),
                backend=self._spectral_backend(), tune=self._tune,
                donate=self._donate, shard_spec=self._shard_spec,
            )
        return self._resident_bplan

    def _geometry_batched(self):
        """The precomputed-Wigner batched plan for WignerBlocks operands."""
        from . import engine as _engine

        if self._geom_bplan is None:
            self._geom_bplan = _engine.plan_batch(
                [_engine.BatchItem(L1=self.L1, L2=self.L2, Lout=self.Lout,
                                   options=(("geometry", "wigner"),))],
                kind="conv_filter", dtype=_engine._dtype_str(self.cdtype),
                backend="escn_aligned", tune=self._tune,
                donate=self._donate, shard_spec=self._shard_spec,
            )
        return self._geom_bplan

    def __call__(self, x, rhat, w1=None, w2=None, w3=None):
        """x [..., (L1+1)^2], rhat [..., 3] (or a resident Rep from
        :meth:`filter_rep`, or WignerBlocks from :meth:`geometry_rep`)
        -> [..., (Lout+1)^2]."""
        from .rep import Rep

        if isinstance(rhat, WignerBlocks):
            out = self._geometry_batched().apply([(x, rhat)],
                                                 weights=[(w1, w2, w3)])[0]
            return out.astype(self.rdtype)
        if isinstance(rhat, Rep):
            from . import engine as _engine

            if w2 is not None:
                raise ValueError("fold w2 into filter_rep(rhat, w2=...) — a "
                                 "resident filter cannot be reweighted")
            if self._donate or self._shard_spec is not None:
                # resident x batched: the boundary-aware bucket flattens the
                # filter's half/dense grid rows like SH rows (DESIGN.md §5/§6)
                out = self._resident_batched().apply(
                    [(x, rhat)], weights=[(w1, None, w3)])[0]
                return out.astype(self.rdtype)
            if self._resident_plan is None:
                self._resident_plan = _engine.plan(
                    self.L1, self.L2, self.Lout, kind="pairwise",
                    backend=self._spectral_backend(),
                    dtype=_engine._dtype_str(self.cdtype),
                    options={"boundary": ("sh", "fourier", "sh")})
            out = self._resident_plan.apply(x, rhat, w1, None, w3)
            return out.astype(self.rdtype)
        out = self._bplan.apply([(x, rhat)], weights=[(w1, w2, w3)])[0]
        return out.astype(self.rdtype)
