"""Equivariant Convolution (paper §3.3, class 2): x_i (x)_Gaunt Y(r_ij).

Two paths, tested equal:

general : evaluate the SH filter Y(r_hat) directly and run the Gaunt TP.

escn    : Passaro & Zitnick insight adapted to our z-up convention —
          rotate the frame so the edge lands on the zenith; the filter then
          has only m = 0 components,  S_{l,m}(e_z) = delta_{m0} sqrt((2l+1)/4pi),
          so its torus-Fourier coefficients occupy the single v = 0 column
          (O(L^2) conversion, Eqn. 58 of the paper) and the 2D convolution
          degenerates to a per-v 1D convolution along u (a small banded
          matmul — MXU-friendly).  out = D^T [ (D x) (x)_Gaunt Y(e_z) ].

Wigner rotations are built *differentiably* from the rotation matrix by the
CG intertwiner recursion  D^l = C^T (D^{l-1} (x) D^1) C  — no Euler angles on
the hot path (TPU adaptation; eSCN's CUDA code uses host-precomputed Wigner
matrices instead).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from . import constants as _const

__all__ = [
    "align_rotation",
    "wigner_blocks_from_rotmat",
    "apply_wigner_blocks",
    "EquivariantConv",
]


def align_rotation(rhat):
    """[..., 3] unit vectors -> rotation matrices R with R @ rhat = e_z.

    Differentiable away from the (measure-zero) frame-switch boundary.
    """
    r = rhat / jnp.linalg.norm(rhat, axis=-1, keepdims=True)
    ex = jnp.broadcast_to(jnp.array([1.0, 0.0, 0.0], dtype=r.dtype), r.shape)
    ez = jnp.broadcast_to(jnp.array([0.0, 0.0, 1.0], dtype=r.dtype), r.shape)
    use_z = (jnp.abs(r[..., 0:1]) > 0.9).astype(r.dtype)
    u = use_z * ez + (1 - use_z) * ex
    b1 = jnp.cross(u, r)
    b1 = b1 / jnp.linalg.norm(b1, axis=-1, keepdims=True)
    b2 = jnp.cross(r, b1)
    return jnp.stack([b1, b2, r], axis=-2)  # rows


def wigner_blocks_from_rotmat(L: int, R):
    """Real Wigner-D blocks [D^0, ..., D^L] for rotation matrices R [..., 3, 3].

    D^1 = P R P^T with P the (x,y,z) -> (m=-1,0,1)=(y,z,x) reordering;
    D^l = C^T (D^{l-1} (x) D^1) C  (orthogonality of the real CG block).
    """
    shp = R.shape[:-2]
    Ds = [jnp.ones(shp + (1, 1), dtype=R.dtype)]
    if L == 0:
        return Ds
    P = jnp.asarray(
        np.array([[0, 1, 0], [0, 0, 1], [1, 0, 0]], dtype=np.float32), dtype=R.dtype
    )  # row m=-1 <- y, m=0 <- z, m=1 <- x
    D1 = jnp.einsum("ai,...ij,bj->...ab", P, R, P)
    Ds.append(D1)
    for l in range(2, L + 1):
        C = jnp.asarray(_const.cg_11_blocks(L)[l - 2], dtype=R.dtype)
        Dl = jnp.einsum(
            "ijk,...ia,...jb,abm->...km", C, Ds[l - 1], D1, C
        )
        Ds.append(Dl)
    return Ds


def apply_wigner_blocks(Ds, x, transpose: bool = False):
    """Apply block-diagonal Wigner rotation to packed features x [..., (L+1)^2]."""
    outs = []
    for l, D in enumerate(Ds):
        blk = x[..., l * l : (l + 1) ** 2]
        eq = "...ji,...j->...i" if transpose else "...ij,...j->...i"
        outs.append(jnp.einsum(eq, D, blk))
    return jnp.concatenate(outs, axis=-1)


class EquivariantConv:
    """Gaunt-accelerated equivariant convolution  (x (x) Y(rhat)) with the
    paper's w_{l1} w_{l2} w_l weight reparameterization.

    Thin wrapper over the unified engine (kind='conv_filter'), routed through
    a batched plan: the edge leading dims ([n, n, C] in the models) are
    flattened to one row axis and executed as a single fused invocation, with
    optional operand-buffer donation (`donate`) and sharded dispatch over the
    mesh's data axes (`shard_spec`, see engine.ShardSpec / DESIGN.md §5).

    method='escn' -> the 'escn_aligned' backend (rotation-alignment sparsity,
    default); method='general' -> a generic pairwise backend with the SH
    filter materialized; method='auto' -> engine selection.  `backend` pins
    any registered backend directly.

    Fourier-resident filters (DESIGN.md §6): when the edge geometry is fixed
    across several products (a layer stack over one graph), materialize the
    filter ONCE with :meth:`filter_rep` and pass the resulting Rep instead of
    ``rhat`` — the call routes through a Fourier-boundary pairwise plan that
    skips the filter's SH->Fourier conversion on every reuse.
    """

    def __init__(self, L1: int, L2: int, Lout: int | None = None, method: str = "escn",
                 cdtype=jnp.complex64, rdtype=jnp.float32,
                 backend: str | None = None, batch_hint: int | None = None,
                 tune: str = "heuristic", donate: bool = False,
                 shard_spec=None):
        from . import engine as _engine

        self.L1, self.L2 = L1, L2
        self.Lout = L1 + L2 if Lout is None else Lout
        self.method = method
        self.cdtype, self.rdtype = cdtype, rdtype
        dtype = _engine._dtype_str(cdtype)
        if backend is None:
            if method == "escn":
                backend = "escn_aligned"
            elif method == "general":
                backend = "direct" if max(L1, L2) <= 4 else "fft"
            elif method == "auto":
                backend = None
            else:
                raise ValueError(f"unknown method {method!r}")
        self._bplan = _engine.plan_batch(
            [_engine.BatchItem(L1=L1, L2=L2, Lout=self.Lout, size=batch_hint)],
            kind="conv_filter", dtype=dtype, backend=backend, tune=tune,
            donate=donate, shard_spec=shard_spec,
        )
        self._plan = self._bplan.buckets[0].plan
        self.backend = self._plan.backend
        self._donate, self._shard_spec = donate, shard_spec
        self._resident_plan = None

    @property
    def plan(self):
        return self._plan

    @property
    def batched_plan(self):
        return self._bplan

    # -- Fourier-resident filters -----------------------------------------

    def _spectral_backend(self) -> str:
        """A Fourier-boundary-capable backend matching this conv's choice."""
        from .engine import spectral_default

        if self.backend in ("fft", "direct", "packed", "rfft"):
            return self.backend
        return spectral_default(self.L1, self.L2)

    def filter_rep(self, rhat, w2=None):
        """Materialize Y(rhat) and convert it to a Fourier-resident Rep once.

        ``w2`` (per-degree filter weights [..., L2+1]) must be folded in here
        — a resident operand cannot take per-degree weights downstream."""
        from .gaunt import expand_degree_weights
        from .rep import Rep
        from .so3 import real_sph_harm_jax

        filt = real_sph_harm_jax(self.L2, rhat)
        if w2 is not None:
            filt = filt * expand_degree_weights(w2, self.L2).astype(filt.dtype)
        conversion = "half" if self._spectral_backend() == "rfft" else "dense"
        return Rep.from_sh(filt, self.L2).to_fourier(conversion, self.cdtype)

    def __call__(self, x, rhat, w1=None, w2=None, w3=None):
        """x [..., (L1+1)^2], rhat [..., 3] (or a resident Rep from
        :meth:`filter_rep`) -> [..., (Lout+1)^2]."""
        from .rep import Rep

        if isinstance(rhat, Rep):
            from . import engine as _engine

            if self._donate or self._shard_spec is not None:
                # the resident route is a plain (unsharded, non-donating)
                # pairwise plan; silently dropping the configured execution
                # knobs would run replicated/undonated without warning
                raise ValueError(
                    "resident filters are not supported with donate/shard_spec "
                    "(ROADMAP: resident batched plans); pass rhat to use the "
                    "batched sharded path")
            if w2 is not None:
                raise ValueError("fold w2 into filter_rep(rhat, w2=...) — a "
                                 "resident filter cannot be reweighted")
            if self._resident_plan is None:
                self._resident_plan = _engine.plan(
                    self.L1, self.L2, self.Lout, kind="pairwise",
                    backend=self._spectral_backend(),
                    dtype=_engine._dtype_str(self.cdtype),
                    options={"boundary": ("sh", "fourier", "sh")})
            out = self._resident_plan.apply(x, rhat, w1, None, w3)
            return out.astype(self.rdtype)
        out = self._bplan.apply([(x, rhat)], weights=[(w1, w2, w3)])[0]
        return out.astype(self.rdtype)
