"""Equivariant Convolution (paper §3.3, class 2): x_i (x)_Gaunt Y(r_ij).

Two paths, tested equal:

general : evaluate the SH filter Y(r_hat) directly and run the Gaunt TP.

escn    : Passaro & Zitnick insight adapted to our z-up convention —
          rotate the frame so the edge lands on the zenith; the filter then
          has only m = 0 components,  S_{l,m}(e_z) = delta_{m0} sqrt((2l+1)/4pi),
          so its torus-Fourier coefficients occupy the single v = 0 column
          (O(L^2) conversion, Eqn. 58 of the paper) and the 2D convolution
          degenerates to a per-v 1D convolution along u (a small banded
          matmul — MXU-friendly).  out = D^T [ (D x) (x)_Gaunt Y(e_z) ].

Wigner rotations are built *differentiably* from the rotation matrix by the
CG intertwiner recursion  D^l = C^T (D^{l-1} (x) D^1) C  — no Euler angles on
the hot path (TPU adaptation; eSCN's CUDA code uses host-precomputed Wigner
matrices instead).
"""
from __future__ import annotations

import math
from functools import lru_cache

import jax
import jax.numpy as jnp
import numpy as np

from . import fourier as _fx
from .gaunt import (
    GauntTensorProduct,
    _y_dense,
    _z_dense,
    expand_degree_weights,
    fourier_to_sh,
    sh_to_fourier,
)
from .irreps import idx, num_coeffs
from .so3 import real_clebsch_gordan_block, real_sph_harm_jax

__all__ = [
    "align_rotation",
    "wigner_blocks_from_rotmat",
    "apply_wigner_blocks",
    "EquivariantConv",
]


def align_rotation(rhat):
    """[..., 3] unit vectors -> rotation matrices R with R @ rhat = e_z.

    Differentiable away from the (measure-zero) frame-switch boundary.
    """
    r = rhat / jnp.linalg.norm(rhat, axis=-1, keepdims=True)
    ex = jnp.broadcast_to(jnp.array([1.0, 0.0, 0.0], dtype=r.dtype), r.shape)
    ez = jnp.broadcast_to(jnp.array([0.0, 0.0, 1.0], dtype=r.dtype), r.shape)
    use_z = (jnp.abs(r[..., 0:1]) > 0.9).astype(r.dtype)
    u = use_z * ez + (1 - use_z) * ex
    b1 = jnp.cross(u, r)
    b1 = b1 / jnp.linalg.norm(b1, axis=-1, keepdims=True)
    b2 = jnp.cross(r, b1)
    return jnp.stack([b1, b2, r], axis=-2)  # rows


@lru_cache(maxsize=None)
def _cg_11_blocks(L: int):
    """CG blocks C_{(l-1,1)->l} for the Wigner recursion (numpy: lru-cached
    constants must NOT be jnp arrays — a jnp constant created inside one jit
    trace leaks into later traces)."""
    return [real_clebsch_gordan_block(l - 1, 1, l).astype(np.float32)
            for l in range(2, L + 1)]


def wigner_blocks_from_rotmat(L: int, R):
    """Real Wigner-D blocks [D^0, ..., D^L] for rotation matrices R [..., 3, 3].

    D^1 = P R P^T with P the (x,y,z) -> (m=-1,0,1)=(y,z,x) reordering;
    D^l = C^T (D^{l-1} (x) D^1) C  (orthogonality of the real CG block).
    """
    shp = R.shape[:-2]
    Ds = [jnp.ones(shp + (1, 1), dtype=R.dtype)]
    if L == 0:
        return Ds
    P = jnp.asarray(
        np.array([[0, 1, 0], [0, 0, 1], [1, 0, 0]], dtype=np.float32), dtype=R.dtype
    )  # row m=-1 <- y, m=0 <- z, m=1 <- x
    D1 = jnp.einsum("ai,...ij,bj->...ab", P, R, P)
    Ds.append(D1)
    for l in range(2, L + 1):
        C = jnp.asarray(_cg_11_blocks(L)[l - 2], dtype=R.dtype)
        Dl = jnp.einsum(
            "ijk,...ia,...jb,abm->...km", C, Ds[l - 1], D1, C
        )
        Ds.append(Dl)
    return Ds


def apply_wigner_blocks(Ds, x, transpose: bool = False):
    """Apply block-diagonal Wigner rotation to packed features x [..., (L+1)^2]."""
    outs = []
    for l, D in enumerate(Ds):
        blk = x[..., l * l : (l + 1) ** 2]
        eq = "...ji,...j->...i" if transpose else "...ij,...j->...i"
        outs.append(jnp.einsum(eq, D, blk))
    return jnp.concatenate(outs, axis=-1)


@lru_cache(maxsize=None)
def _filter_fourier_col(L2: int, cdtype: str):
    """u-column (v=0) Fourier coefficients of S_{l,0}, stacked [L2+1, 2L2+1].
    numpy (see _cg_11_blocks note)."""
    y = _fx.sh_to_fourier_dense(L2)
    cols = np.stack([y[idx(l, 0), :, L2] for l in range(L2 + 1)], axis=0)
    return cols.astype(cdtype)


@lru_cache(maxsize=None)
def _conv_u_index(L1: int, L2: int):
    """Index/mask for the banded 1D convolution along u.

    out[u3] = sum_{u1} F1[u1] * k[u3 - u1]  with centered indices;
    idx[i3, i1] = i3 - i1 (into the kernel array of length 2L2+1, offset L2-L1
    ... computed here once).
    """
    n1, n2 = 2 * L1 + 1, 2 * L2 + 1
    N = n1 + n2 - 1
    i3 = np.arange(N)[:, None]
    i1 = np.arange(n1)[None, :]
    k = i3 - i1  # in [ -(n1-1), N-1 ]
    valid = (k >= 0) & (k < n2)
    return np.where(valid, k, 0).astype(np.int32), valid.astype(np.float32)


class EquivariantConv:
    """Gaunt-accelerated equivariant convolution  (x (x) Y(rhat)) with the
    paper's w_{l1} w_{l2} w_l weight reparameterization.

    method='general' evaluates Y(rhat) and calls the Gaunt TP;
    method='escn' uses the rotation-alignment sparsity (default).
    """

    def __init__(self, L1: int, L2: int, Lout: int | None = None, method: str = "escn",
                 cdtype=jnp.complex64, rdtype=jnp.float32):
        self.L1, self.L2 = L1, L2
        self.Lout = L1 + L2 if Lout is None else Lout
        self.method = method
        self.cdtype, self.rdtype = cdtype, rdtype
        cd = jnp.dtype(cdtype).name
        if method == "general":
            self._tp = GauntTensorProduct(L1, L2, self.Lout, cdtype=cdtype, rdtype=rdtype)
        else:
            _y_dense(L1, cd)
            _z_dense(L1 + L2, self.Lout, cd)
            _filter_fourier_col(L2, cd)

    def __call__(self, x, rhat, w1=None, w2=None, w3=None):
        """x [..., (L1+1)^2], rhat [..., 3] -> [..., (Lout+1)^2]."""
        if self.method == "general":
            filt = real_sph_harm_jax(self.L2, rhat).astype(x.dtype)
            return self._tp(x, filt, w1, w2, w3)
        # --- eSCN-sparsity path ---
        if w1 is not None:
            x = x * expand_degree_weights(w1, self.L1).astype(x.dtype)
        R = align_rotation(rhat.astype(jnp.float32))
        Ds = wigner_blocks_from_rotmat(max(self.L1, self.Lout), R)
        x_rot = apply_wigner_blocks(Ds[: self.L1 + 1], x)
        F1 = sh_to_fourier(x_rot, self.L1, "dense", self.cdtype)  # [..., n1, n1]
        # filter coefficients: only m=0 -> single v=0 column, O(L^2)
        fl = jnp.full((self.L2 + 1,), 1.0, dtype=self.rdtype)
        fl = fl * jnp.asarray(
            [math.sqrt((2 * l + 1) / (4 * math.pi)) for l in range(self.L2 + 1)],
            dtype=self.rdtype,
        )
        if w2 is not None:
            fl = fl * w2.astype(self.rdtype)
        cols = jnp.asarray(_filter_fourier_col(self.L2, jnp.dtype(self.cdtype).name))
        k = jnp.einsum("...l,lu->...u", fl.astype(cols.dtype), cols)  # [..., 2L2+1]
        # banded 1D conv along u for every v column (v support unchanged)
        gidx, mask = _conv_u_index(self.L1, self.L2)
        kmat = k[..., jnp.asarray(gidx)] * jnp.asarray(mask, dtype=self.rdtype)  # [..., N, n1]
        F3 = jnp.einsum("...ti,...iv->...tv", kmat, F1)  # [..., N, n1(v)]
        # pad v axis to the full output grid (v support still |v| <= L1)
        pv = (2 * (self.L1 + self.L2) + 1 - (2 * self.L1 + 1)) // 2
        F3 = jnp.pad(F3, [(0, 0)] * (F3.ndim - 1) + [(pv, pv)])
        out_rot = fourier_to_sh(F3, self.L1 + self.L2, self.Lout, "dense", self.rdtype)
        out = apply_wigner_blocks(Ds[: self.Lout + 1], out_rot, transpose=True)
        if w3 is not None:
            out = out * expand_degree_weights(w3, self.Lout).astype(out.dtype)
        return out
