"""Persistent per-host autotune cache (DESIGN.md §4.5).

The measured autotuner (engine.py, ``tune='measure'``) is the arbiter of
every hot-path choice — backend per plan key, chain flavor per chain key,
storage dtype per 'auto' key family — but its selection table lives
in-process, so every serve process re-times every key at startup.  This
module persists the three measurement stores to ONE versioned JSON file per
host so selections are measured once and reused:

    selections   engine._measured    {PlanKey -> backend | chain backend |
                                       dtype winner ('auto' keys)}
    timings      engine._measured_t  {PlanKey -> best wall seconds}
    calibration  engine._CALIB       the fused-cost skinny-matmul factors

File format (schema-versioned, human-inspectable):

    {"fingerprint": {schema, backend, device_kind, device_count,
                     jax_version, x64},
     "selections": [{"key": {...PlanKey fields...}, "backend": "...",
                     "t": seconds | null}, ...],
     "calibration": {... engine.get_calibration() ...}}

Trust rules — a persisted entry is only as good as the measurement that
produced it:

* The whole file is keyed by a hardware/software **fingerprint** (device
  kind, device count, jax version, x64 mode, cache schema version).  Any
  mismatch invalidates the file wholesale: timings from another device kind
  (or another jax) are not this host's timings.  A corrupted or unreadable
  file behaves identically — ``load`` returns None and the engine falls
  back to in-process measurement, never an error.
* Per-entry **stale invalidation** on load: entries naming a backend that is
  no longer registered (or a chain flavor no longer in CHAIN_BACKENDS, or a
  dtype winner that is not a storage dtype), or keyed by an unknown
  kind/dtype, are silently dropped — a cache written by a newer/older code
  revision degrades to partial warmth instead of poisoning selection.
* Only selections that were actually *run* are persisted (the engine never
  caches a failed measurement — see ``GauntEngine._select_chain`` /
  ``_measure``), so a loaded entry always has a real timing behind it
  ('auto' dtype winners carry ``t: null`` but are only ever cached when at
  least one sibling produced a timing).
* Writes are **atomic** (tempfile in the target directory + ``os.replace``)
  and **merging**: flushing re-reads the file and folds in entries a
  concurrent process persisted meanwhile (same fingerprint only) — last
  writer wins per key, no torn files.

The engine engages persistence only when a path is configured: explicitly
(``GauntEngine(cache_path=...)`` / ``set_autotune_cache``), per serve config
(``EquivariantConfig.autotune_cache``), or via the ``REPRO_AUTOTUNE_CACHE``
environment variable.  With no path configured every load/flush is a no-op
and behavior is exactly the historical in-process autotune.

Offline pre-population::

    python -m repro.core.autotune_cache --cache /var/cache/gaunt.json
    python -m repro.core.autotune_cache --cache ... --verify-warm  # 0 runs?

sweeps the known workload grid (the benchmark pairwise/conv/chain keys at
both storage precisions plus the 'auto' families, the serve selfmix chain
keys — ungated, gate-fused, and the ``grid_gate='auto'`` policy family —
and ``calibrate_fused`` per dtype) so production processes boot with a
fully warm selection table.  ``scripts/calibrate.py`` is a thin wrapper.
"""
from __future__ import annotations

import dataclasses
import json
import os
import tempfile

__all__ = [
    "SCHEMA_VERSION",
    "ENV_VAR",
    "fingerprint",
    "default_path",
    "resolve_path",
    "load",
    "save",
    "main",
]

SCHEMA_VERSION = 1
ENV_VAR = "REPRO_AUTOTUNE_CACHE"


def fingerprint() -> dict:
    """The hardware/software identity persisted measurements are valid for.

    device_kind + device_count pin the hardware (a timing on 1 CPU device
    says nothing about 8 TPU cores), jax_version + x64 pin the software that
    produced the compiled executables being timed, and the schema version
    invalidates files written by an incompatible cache layout.
    """
    import jax

    devs = jax.devices()
    return {
        "schema": SCHEMA_VERSION,
        "backend": jax.default_backend(),
        "device_kind": devs[0].device_kind if devs else "none",
        "device_count": jax.device_count(),
        "jax_version": jax.__version__,
        "x64": bool(jax.config.jax_enable_x64),
    }


def default_path() -> str:
    """The conventional per-user cache location (the CLI's default target)."""
    base = os.environ.get("XDG_CACHE_HOME") or os.path.join(
        os.path.expanduser("~"), ".cache")
    return os.path.join(base, "repro", "gaunt_autotune.json")


def resolve_path(path: str | None = None) -> str | None:
    """The effective cache path: explicit arg, else the env var, else None
    (None = persistence disabled; the engine stays purely in-process)."""
    if path:
        return path
    return os.environ.get(ENV_VAR) or None


# --------------------------------------------------------------------------
# (de)serialization
# --------------------------------------------------------------------------


def _tuplify(v):
    """JSON round-trips tuples as lists; PlanKey hashing needs tuples back."""
    if isinstance(v, list):
        return tuple(_tuplify(x) for x in v)
    return v


def _encode_key(key) -> dict:
    return dataclasses.asdict(key)


def _decode_key(d: dict):
    from .engine import PlanKey

    return PlanKey(
        L1=d["L1"], L2=d["L2"], Lout=d["Lout"], kind=d["kind"],
        batch_hint=d["batch_hint"], dtype=d["dtype"],
        extra=_tuplify(d["extra"]),
    )


def _entry_valid(key, backend: str) -> bool:
    """Per-entry stale invalidation (see module docstring)."""
    from .engine import _RDTYPE, _REGISTRY, CHAIN_BACKENDS, KINDS

    if not isinstance(backend, str):
        return False
    if key.kind != "chain" and key.kind not in KINDS:
        return False
    if key.dtype == "auto":
        # 'auto' family keys store the winning STORAGE dtype, not a backend
        return backend in ("float32", "bfloat16")
    if key.dtype not in _RDTYPE:
        return False
    if key.kind == "chain":
        if ("gate", "policy") in key.extra:
            # grid_gate='auto' policy keys (engine.select_gate) store the
            # gate placement winner, not a chain backend
            return backend in ("grid", "sh")
        return backend in CHAIN_BACKENDS
    return backend in _REGISTRY


def load(path: str | None):
    """-> (selections, timings, calibration) or None.

    None means "no usable cache": missing file, unreadable/corrupt JSON,
    wrong schema, or a fingerprint mismatch — all fall back to in-process
    measurement without error.  Stale entries are dropped individually.
    """
    if not path:
        return None
    try:
        with open(path) as f:
            raw = json.load(f)
    except (OSError, ValueError):
        return None
    if not isinstance(raw, dict) or raw.get("fingerprint") != fingerprint():
        return None
    selections, timings = {}, {}
    for ent in raw.get("selections", ()):
        try:
            key = _decode_key(ent["key"])
            backend = ent["backend"]
        except (KeyError, TypeError):
            continue
        if not _entry_valid(key, backend):
            continue
        selections[key] = backend
        t = ent.get("t")
        if isinstance(t, (int, float)):
            timings[key] = float(t)
    calib = raw.get("calibration")
    return selections, timings, dict(calib) if isinstance(calib, dict) else {}


def save(path: str, selections: dict, timings: dict,
         calibration: dict | None = None, merge: bool = True) -> None:
    """Atomically persist the measurement stores to ``path``.

    With ``merge`` (the default) a valid same-fingerprint file already at
    ``path`` contributes entries we don't have locally — concurrent
    processes flushing different keys converge instead of clobbering.
    The write itself is tempfile + ``os.replace``: readers never see a
    torn file, and the last concurrent writer wins wholesale.
    """
    selections = dict(selections)
    timings = dict(timings)
    if merge:
        prev = load(path)
        if prev is not None:
            for k, b in prev[0].items():
                selections.setdefault(k, b)
            for k, t in prev[1].items():
                timings.setdefault(k, t)
    payload = {
        "fingerprint": fingerprint(),
        "selections": [
            {"key": _encode_key(k), "backend": b, "t": timings.get(k)}
            for k, b in selections.items()
        ],
    }
    if calibration is not None:
        payload["calibration"] = dict(calibration)
    d = os.path.dirname(os.path.abspath(path))
    os.makedirs(d, exist_ok=True)
    fd, tmp = tempfile.mkstemp(prefix=".gaunt_autotune.", suffix=".json", dir=d)
    try:
        with os.fdopen(fd, "w") as f:
            json.dump(payload, f, indent=1)
        os.replace(tmp, path)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise


def merge_calibration(saved: dict) -> int:
    """Fold persisted calibration into the process, without clobbering
    constants this process measured itself (in-process is fresher).  Only
    entries the file marks ``*_measured`` are applied — an inherited default
    in the file must not masquerade as a measurement here.  Returns the
    number of factors applied."""
    from .engine import get_calibration, set_calibration

    cur = get_calibration()
    apply = {}
    for base in [k for k in cur if not k.endswith("_measured")]:
        mk = base + "_measured"
        if saved.get(mk) and not cur.get(mk) \
                and isinstance(saved.get(base), (int, float)):
            apply[base] = float(saved[base])
            apply[mk] = True
    if apply:
        set_calibration(**apply)
    return len(apply) // 2


# --------------------------------------------------------------------------
# offline calibrate CLI
# --------------------------------------------------------------------------


def _sweep(eng, fast: bool, serve_rows: tuple = (1024,)) -> int:
    """Measure the known workload grid into ``eng``'s selection table.

    Mirrors the benchmark sweep (bench_engine.run / run_chain_kernel /
    run_mixed_precision) plus the serve warmup's selfmix chain keys, at both
    storage precisions and the 'auto' family, so a production process that
    loads the resulting file boots with zero timing runs.
    """
    from .engine import _calib_key, get_calibration

    n0 = len(eng._measured)
    dtypes = ("float32", "bfloat16", "auto")
    # fused-cost calibration per storage dtype (feeds heuristic rankings);
    # a persisted cache that already carries a measured factor for this
    # dtype covers it — calibrate_fused always times, so re-running it on a
    # warm host would break the zero-timing-runs contract for no new signal
    for d in ("float32", "bfloat16"):
        if not get_calibration().get(_calib_key(d) + "_measured"):
            eng.calibrate_fused(dtype=d)
    # pairwise + conv_filter plan keys (the bench grid)
    L_list = (1, 2, 3, 6) if fast else (1, 2, 3, 4, 6)
    B_list = (64, 1024)
    for L in L_list:
        for B in B_list:
            for d in dtypes:
                eng.plan(L, L, L, batch_hint=B, dtype=d, tune="measure",
                         requires_grad=False)
        eng.plan(L, L, L, kind="conv_filter", batch_hint=B_list[-1],
                 tune="measure", requires_grad=False)
    # chained workloads (the bench chain-kernel grid)
    chains = [
        ((1, 1, 1), 1, 512),
        ((2, 2), 2, 64),
        ((2, 2, 2), 2, 128),
        ((3, 3, 3), 3, 64),
        ((2, 2, 2, 2), 8, 256),
    ]
    if fast:
        chains = chains[:3]
    for Ls, Lout, B in chains:
        for d in dtypes:
            eng.plan_chain(Ls, Lout, tune="measure", batch_hint=B, dtype=d)
    # serve warmup's selfmix chain keys (shared-operand [A]*nu pattern) for
    # the shipped force-field configs, at the requested row hints
    from repro.configs.gaunt_ff import gaunt_mace_ff as _cfg

    for rows in serve_rows:
        for d in dtypes:
            eng.plan_chain((_cfg.L,) * _cfg.nu, _cfg.L, tune="measure",
                           batch_hint=int(rows), share_hint=(0,) * _cfg.nu,
                           dtype=d)
            # gate-fused siblings + the grid_gate='auto' policy family
            # (DESIGN.md §6.5): a serve config with grid_gate != 'off'
            # seeds exactly these keys in warmup()
            eng.plan_chain((_cfg.L,) * _cfg.nu, _cfg.L, tune="measure",
                           batch_hint=int(rows), share_hint=(0,) * _cfg.nu,
                           dtype=d, gate=True)
            eng.select_gate((_cfg.L,) * _cfg.nu, _cfg.L, dtype=d,
                            batch_hint=int(rows),
                            share_hint=(0,) * _cfg.nu)
    return len(eng._measured) - n0


def main(argv=None) -> int:
    import argparse

    ap = argparse.ArgumentParser(
        prog="python -m repro.core.autotune_cache",
        description="Offline autotune calibration: sweep the known workload "
                    "grid and persist the measured selection table so "
                    "production processes boot warm.")
    ap.add_argument("--cache", default=None,
                    help=f"cache file (default: ${ENV_VAR} or "
                         f"{default_path()})")
    ap.add_argument("--fast", action="store_true", help="smaller sweep")
    ap.add_argument("--serve-rows", default="1024",
                    help="comma-separated serve chain row hints "
                         "(max_atoms*channels per deployment)")
    ap.add_argument("--verify-warm", action="store_true",
                    help="re-run the sweep and FAIL (exit 2) if any timing "
                         "run happened — proves the cache file fully covers "
                         "the grid")
    args = ap.parse_args(argv)

    from .engine import get_engine

    path = resolve_path(args.cache) or default_path()
    eng = get_engine()
    eng.set_autotune_cache(path)
    loaded = eng.load_autotune_cache()
    rows = tuple(int(r) for r in args.serve_rows.split(",") if r)
    new = _sweep(eng, fast=args.fast, serve_rows=rows)
    eng.flush_autotune_cache()
    print(f"cache: {path}")
    print(f"loaded {loaded} persisted selections; measured {new} new; "
          f"{eng.timing_runs} timing runs this process")
    if args.verify_warm and eng.timing_runs > 0:
        print(f"VERIFY-WARM FAILED: {eng.timing_runs} timing runs — the "
              "cache did not cover the sweep (stale fingerprint? partial "
              "file?)")
        return 2
    if args.verify_warm:
        print("verify-warm OK: zero timing runs")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
