"""Exact SO(3)/O(3) representation machinery.

All *precompute* here is numpy / exact rational arithmetic (runs once, cached);
runtime evaluation of spherical harmonics for model code has a JAX twin
(`real_sph_harm_jax`) that is differentiable and TPU-friendly (pure polynomial
recurrences, no trig on the hot path).

Conventions
-----------
Complex SH with Condon-Shortley phase:
    Y_{l,m} = (-1)^m N_{l,m} P_l^m(cos t) e^{i m p},  m >= 0,
    Y_{l,-m} = (-1)^m conj(Y_{l,m}),
    N_{l,m} = sqrt((2l+1)/(4 pi) (l-m)!/(l+m)!)
and P_l^m *without* the CS phase.

Real (orthonormal) SH:
    S_{l,0}  = Y_{l,0}
    S_{l,m}  = sqrt(2) N_{l,m} P_l^m(cos t) cos(m p)    (m > 0)
    S_{l,-m} = sqrt(2) N_{l,m} P_l^m(cos t) sin(m p)    (m > 0)

which corresponds to the unitary change of basis  S^l = U^l Y^l  with
    U[ m,  m] = (-1)^m/sqrt2,  U[ m, -m] = 1/sqrt2          (m>0)
    U[-m,  m] = -i(-1)^m/sqrt2, U[-m, -m] = i/sqrt2          (m>0)
    U[0, 0] = 1.

Wigner-3j is computed exactly (python ints / Fractions) via the Racah
formula; Gaunt coefficients for *real* SH are assembled from an analytic
azimuthal integral and a Gauss-Legendre polar integral that is **exact**
because the integrand is polynomial in cos(t) (see DESIGN.md §9).
"""
from __future__ import annotations

import math
from fractions import Fraction
from functools import lru_cache

import numpy as np

from .irreps import idx, num_coeffs

__all__ = [
    "wigner_3j",
    "clebsch_gordan",
    "gaunt_complex",
    "real_sph_harm",
    "real_sph_harm_jax",
    "real_gaunt_tensor",
    "real_clebsch_gordan_block",
    "u_matrix",
    "wigner_d_complex",
    "wigner_D_real",
    "rotation_matrix_zyz",
    "euler_from_matrix_zyz",
    "align_to_y_angles",
    "sphere_quadrature",
]

# --------------------------------------------------------------------------
# exact Wigner 3j / Clebsch-Gordan
# --------------------------------------------------------------------------


@lru_cache(maxsize=None)
def _fact(n: int) -> int:
    return math.factorial(n)


@lru_cache(maxsize=None)
def wigner_3j(l1: int, l2: int, l3: int, m1: int, m2: int, m3: int) -> float:
    """Exact Wigner 3j symbol (float result of an exact rational*sqrt form)."""
    if m1 + m2 + m3 != 0:
        return 0.0
    if not (abs(l1 - l2) <= l3 <= l1 + l2):
        return 0.0
    if abs(m1) > l1 or abs(m2) > l2 or abs(m3) > l3:
        return 0.0
    # triangle coefficient (exact rational)
    tri = Fraction(
        _fact(l1 + l2 - l3) * _fact(l1 - l2 + l3) * _fact(-l1 + l2 + l3),
        _fact(l1 + l2 + l3 + 1),
    )
    pref = tri * Fraction(
        _fact(l1 - m1) * _fact(l1 + m1) * _fact(l2 - m2) * _fact(l2 + m2)
        * _fact(l3 - m3) * _fact(l3 + m3)
    )
    kmin = max(0, l2 - l3 - m1, l1 - l3 + m2)
    kmax = min(l1 + l2 - l3, l1 - m1, l2 + m2)
    s = Fraction(0)
    for k in range(kmin, kmax + 1):
        den = (
            _fact(k)
            * _fact(l1 + l2 - l3 - k)
            * _fact(l1 - m1 - k)
            * _fact(l2 + m2 - k)
            * _fact(l3 - l2 + m1 + k)
            * _fact(l3 - l1 - m2 + k)
        )
        s += Fraction((-1) ** k, den)
    if s == 0:
        return 0.0
    sign = (-1) ** (l1 - l2 - m3)
    # value = sign * sqrt(pref) * s ;  compute sqrt exactly-ish in float
    val = sign * math.copysign(math.sqrt(float(pref * s * s)), float(s))
    return val


@lru_cache(maxsize=None)
def clebsch_gordan(l1: int, m1: int, l2: int, m2: int, l3: int, m3: int) -> float:
    """<l1 m1 l2 m2 | l3 m3> from the 3j symbol."""
    if m3 != m1 + m2:
        return 0.0
    w = wigner_3j(l1, l2, l3, m1, m2, -m3)
    if w == 0.0:
        return 0.0
    return (-1) ** (l1 - l2 + m3) * math.sqrt(2 * l3 + 1) * w


@lru_cache(maxsize=None)
def gaunt_complex(l1: int, m1: int, l2: int, m2: int, l3: int, m3: int) -> float:
    """Gaunt coefficient for *complex* SH: int Y_{l1m1} Y_{l2m2} Y_{l3m3} dOmega."""
    if (l1 + l2 + l3) % 2 != 0:
        return 0.0
    if m1 + m2 + m3 != 0:
        return 0.0
    w0 = wigner_3j(l1, l2, l3, 0, 0, 0)
    if w0 == 0.0:
        return 0.0
    w = wigner_3j(l1, l2, l3, m1, m2, m3)
    return math.sqrt((2 * l1 + 1) * (2 * l2 + 1) * (2 * l3 + 1) / (4 * math.pi)) * w0 * w


# --------------------------------------------------------------------------
# real spherical harmonics (numpy + jax)
# --------------------------------------------------------------------------


@lru_cache(maxsize=None)
def _sh_norms(L: int) -> np.ndarray:
    """norm[l, m] = sqrt((2l+1)/(4pi) (l-m)!/(l+m)!), m<=l (0 elsewhere)."""
    out = np.zeros((L + 1, L + 1))
    for l in range(L + 1):
        for m in range(l + 1):
            out[l, m] = math.sqrt(
                (2 * l + 1) / (4 * math.pi) * float(Fraction(_fact(l - m), _fact(l + m)))
            )
    return out


def _legendre_sinm_poly(L: int, z: np.ndarray) -> np.ndarray:
    """P~_l^m(z) = P_l^m(z)/sin^m(t)  (a polynomial in z), numpy.

    Returns array [L+1, L+1, *z.shape] with entry [l, m] valid for m <= l.
    No Condon-Shortley phase.
    """
    z = np.asarray(z, dtype=np.float64)
    out = np.zeros((L + 1, L + 1) + z.shape, dtype=np.float64)
    out[0, 0] = 1.0
    for m in range(1, L + 1):
        out[m, m] = out[m - 1, m - 1] * (2 * m - 1)
    for m in range(0, L):
        out[m + 1, m] = (2 * m + 1) * z * out[m, m]
    for m in range(0, L + 1):
        for l in range(m + 2, L + 1):
            out[l, m] = ((2 * l - 1) * z * out[l - 1, m] - (l + m - 1) * out[l - 2, m]) / (l - m)
    return out


def real_sph_harm(L: int, xyz: np.ndarray) -> np.ndarray:
    """All real SH S_{l,m}, l<=L at unit vectors xyz[..., 3] -> [..., (L+1)^2]."""
    xyz = np.asarray(xyz, dtype=np.float64)
    x, y, z = xyz[..., 0], xyz[..., 1], xyz[..., 2]
    P = _legendre_sinm_poly(L, z)  # [L+1, L+1, ...]
    norms = _sh_norms(L)
    # sin^m(t) cos(m p) and sin^m(t) sin(m p) via Cartesian recurrence
    A = [np.ones_like(z)]
    B = [np.zeros_like(z)]
    for m in range(1, L + 1):
        A.append(x * A[m - 1] - y * B[m - 1])
        B.append(y * A[m - 1] + x * B[m - 1])
    out = np.zeros(z.shape + (num_coeffs(L),), dtype=np.float64)
    sq2 = math.sqrt(2.0)
    for l in range(L + 1):
        out[..., idx(l, 0)] = norms[l, 0] * P[l, 0]
        for m in range(1, l + 1):
            c = sq2 * norms[l, m]
            out[..., idx(l, m)] = c * P[l, m] * A[m]
            out[..., idx(l, -m)] = c * P[l, m] * B[m]
    return out


def real_sph_harm_jax(L: int, xyz):
    """JAX twin of :func:`real_sph_harm` (differentiable, unrolled in l,m).

    Polynomial in (x,y,z) -> no trig, well-defined at the poles. Cheap for the
    L<=8 regime used by the equivariant models.
    """
    import jax.numpy as jnp

    x, y, z = xyz[..., 0], xyz[..., 1], xyz[..., 2]
    norms = _sh_norms(L)
    # P~_l^m(z) recurrences, unrolled (L is static)
    P: dict[tuple[int, int], object] = {(0, 0): jnp.ones_like(z)}
    for m in range(1, L + 1):
        P[(m, m)] = P[(m - 1, m - 1)] * (2 * m - 1)
    for m in range(0, L):
        P[(m + 1, m)] = (2 * m + 1) * z * P[(m, m)]
    for m in range(0, L + 1):
        for l in range(m + 2, L + 1):
            P[(l, m)] = ((2 * l - 1) * z * P[(l - 1, m)] - (l + m - 1) * P[(l - 2, m)]) / (l - m)
    A = [jnp.ones_like(z)]
    B = [jnp.zeros_like(z)]
    for m in range(1, L + 1):
        A.append(x * A[m - 1] - y * B[m - 1])
        B.append(y * A[m - 1] + x * B[m - 1])
    cols = []
    sq2 = math.sqrt(2.0)
    for l in range(L + 1):
        for m in range(-l, l + 1):
            if m == 0:
                cols.append(norms[l, 0] * P[(l, 0)])
            elif m > 0:
                cols.append(sq2 * norms[l, m] * P[(l, m)] * A[m])
            else:
                cols.append(sq2 * norms[l, -m] * P[(l, -m)] * B[-m])
    return jnp.stack(cols, axis=-1)


# --------------------------------------------------------------------------
# quadrature (exact for bandlimited integrands)
# --------------------------------------------------------------------------


def sphere_quadrature(bandlimit: int) -> tuple[np.ndarray, np.ndarray]:
    """Nodes xyz [N,3] and weights w [N] exact for spherical polynomials of
    degree <= bandlimit.

    Gauss-Legendre in cos(t) x uniform trapezoid in p.
    """
    n_t = bandlimit // 2 + 2
    n_p = bandlimit + 2
    xg, wg = np.polynomial.legendre.leggauss(n_t)  # x = cos t
    p = 2 * math.pi * np.arange(n_p) / n_p
    wp = 2 * math.pi / n_p
    ct = xg[:, None] + 0 * p[None, :]
    st = np.sqrt(np.maximum(0.0, 1 - ct**2))
    xyz = np.stack(
        [st * np.cos(p)[None, :], st * np.sin(p)[None, :], ct], axis=-1
    ).reshape(-1, 3)
    w = (wg[:, None] * wp * np.ones_like(p)[None, :]).reshape(-1)
    return xyz, w


# --------------------------------------------------------------------------
# real Gaunt tensor (exact, separated polar x azimuthal integrals)
# --------------------------------------------------------------------------


def _azimuthal_triple(m1: int, m2: int, m3: int) -> float:
    """int_0^{2pi} F_{m1} F_{m2} F_{m3} dp with F_m = cos(mp) (m>0), 1 (m=0),
    sin(|m|p) (m<0).  Closed form."""
    neg = sum(1 for m in (m1, m2, m3) if m < 0)
    a, b, c = abs(m1), abs(m2), abs(m3)
    if neg == 1 or neg == 3:
        return 0.0  # odd number of sines integrates to zero

    def d(x: int) -> float:  # delta(x == 0)
        return 1.0 if x == 0 else 0.0

    pi = math.pi
    if neg == 0:  # cos cos cos (m=0 => cos(0)=1 consistent)
        val = 0.5 * pi * (d(a + b - c) + d(a - b + c) + d(-a + b + c) + d(a + b + c))
        if a == 0 and b == 0 and c == 0:
            val = 2 * pi
        return val
    # neg == 2: one cos (or const), two sin. Put sines as (s1, s2), cos as co.
    sins = [abs(m) for m in (m1, m2, m3) if m < 0]
    cosv = [abs(m) for m in (m1, m2, m3) if m >= 0][0]
    s1, s2 = sins
    # int sin(s1 p) sin(s2 p) cos(co p) dp
    val = 0.5 * pi * (d(s1 - s2 + cosv) + d(s1 - s2 - cosv) - d(s1 + s2 + cosv) - d(s1 + s2 - cosv))
    if s1 == 0 or s2 == 0:
        return 0.0  # sin(0)=0
    return val


@lru_cache(maxsize=None)
def _theta_table(L: int, n_nodes: int) -> tuple[np.ndarray, np.ndarray]:
    """Theta_{l,m}(t_k) table [ (l,m) -> node ] on GL nodes, and weights."""
    xg, wg = np.polynomial.legendre.leggauss(n_nodes)
    P = _legendre_sinm_poly(L, xg)  # P~ = P/sin^m
    norms = _sh_norms(L)
    st = np.sqrt(np.maximum(0.0, 1 - xg**2))
    tab = np.zeros((L + 1, L + 1, n_nodes))
    for l in range(L + 1):
        for m in range(l + 1):
            tab[l, m] = norms[l, m] * P[l, m] * st**m
    return tab, wg


@lru_cache(maxsize=None)
def real_gaunt_tensor(L1: int, L2: int, L3: int) -> np.ndarray:
    """Dense real-Gaunt tensor G[(L1+1)^2, (L2+1)^2, (L3+1)^2] (float64).

    G[i1, i2, i3] = int S_{i1} S_{i2} S_{i3} dOmega.  Exact (polynomial
    integrand; see module docstring).
    """
    Lm = max(L1, L2, L3)
    # polar integrand has degree <= L1+L2+L3 (+even sin powers) in cos t
    n_nodes = (L1 + L2 + L3) // 2 + 2
    tab, wg = _theta_table(Lm, n_nodes)
    G = np.zeros((num_coeffs(L1), num_coeffs(L2), num_coeffs(L3)))
    sq2 = math.sqrt(2.0)

    def phi_coeff(m: int) -> float:
        return 1.0 if m == 0 else sq2  # S includes sqrt2 for m != 0

    for l1 in range(L1 + 1):
        for l2 in range(L2 + 1):
            l3lo = abs(l1 - l2)
            for l3 in range(l3lo, min(L3, l1 + l2) + 1):
                if (l1 + l2 + l3) % 2 != 0:
                    continue
                for m1 in range(-l1, l1 + 1):
                    for m2 in range(-l2, l2 + 1):
                        # azimuthal selection: |m3| in {| |m1|+-|m2| |}
                        cands = {abs(abs(m1) + abs(m2)), abs(abs(m1) - abs(m2))}
                        for am3 in cands:
                            if am3 > l3:
                                continue
                            for m3 in ({0} if am3 == 0 else {am3, -am3}):
                                az = _azimuthal_triple(m1, m2, m3)
                                if az == 0.0:
                                    continue
                                pol = float(
                                    np.dot(wg, tab[l1, abs(m1)] * tab[l2, abs(m2)] * tab[l3, abs(m3)])
                                )
                                val = az * pol * phi_coeff(m1) * phi_coeff(m2) * phi_coeff(m3)
                                G[idx(l1, m1), idx(l2, m2), idx(l3, m3)] = val
    return G


# --------------------------------------------------------------------------
# real-basis Clebsch-Gordan blocks (the e3nn-style baseline)
# --------------------------------------------------------------------------


@lru_cache(maxsize=None)
def u_matrix(l: int) -> np.ndarray:
    """Unitary change of basis S^l = U Y^l (rows: real m, cols: complex m)."""
    n = 2 * l + 1
    U = np.zeros((n, n), dtype=np.complex128)
    U[l, l] = 1.0
    for m in range(1, l + 1):
        s = 1 / math.sqrt(2)
        U[l + m, l + m] = (-1) ** m * s
        U[l + m, l - m] = s
        U[l - m, l + m] = -1j * (-1) ** m * s
        U[l - m, l - m] = 1j * s
    return U


@lru_cache(maxsize=None)
def real_clebsch_gordan_block(l1: int, l2: int, l3: int) -> np.ndarray:
    """Real-basis CG block C[2l1+1, 2l2+1, 2l3+1] (real, orthogonality-normalized).

    Transported from the complex-basis CG with the U matrices; the block is
    real up to a global phase which we strip (standard e3nn choice).
    """
    if not (abs(l1 - l2) <= l3 <= l1 + l2):
        return np.zeros((2 * l1 + 1, 2 * l2 + 1, 2 * l3 + 1))
    Cc = np.zeros((2 * l1 + 1, 2 * l2 + 1, 2 * l3 + 1), dtype=np.complex128)
    for m1 in range(-l1, l1 + 1):
        for m2 in range(-l2, l2 + 1):
            m3 = m1 + m2
            if abs(m3) <= l3:
                Cc[l1 + m1, l2 + m2, l3 + m3] = clebsch_gordan(l1, m1, l2, m2, l3, m3)
    U1, U2, U3 = u_matrix(l1), u_matrix(l2), u_matrix(l3)
    T = np.einsum("ai,bj,ck,ijk->abc", U1, U2, U3.conj(), Cc)
    re, im = np.abs(T.real).max(), np.abs(T.imag).max()
    out = T.real if re >= im else T.imag
    return np.ascontiguousarray(out)


# --------------------------------------------------------------------------
# Wigner matrices & rotations
# --------------------------------------------------------------------------


@lru_cache(maxsize=None)
def _d_coeff_table(l: int) -> list:
    """Precomputed sqrt-factorial prefactors for the small-d formula."""
    rows = []
    for mp in range(-l, l + 1):
        for m in range(-l, l + 1):
            pref = math.sqrt(
                _fact(l + mp) * _fact(l - mp) * _fact(l + m) * _fact(l - m)
            )
            kmin = max(0, m - mp)
            kmax = min(l + m, l - mp)
            terms = []
            for k in range(kmin, kmax + 1):
                den = (
                    _fact(l + m - k) * _fact(k) * _fact(mp - m + k) * _fact(l - mp - k)
                )
                terms.append((k, (-1) ** (mp - m + k) * pref / den))
            rows.append(((mp, m), terms))
    return rows


def wigner_d_small(l: int, beta: float) -> np.ndarray:
    """Wigner small-d matrix d^l_{m'm}(beta) [2l+1, 2l+1]."""
    c, s = math.cos(beta / 2), math.sin(beta / 2)
    d = np.zeros((2 * l + 1, 2 * l + 1))
    for (mp, m), terms in _d_coeff_table(l):
        v = 0.0
        for k, coef in terms:
            v += coef * c ** (2 * l - mp + m - 2 * k) * s ** (mp - m + 2 * k)
        d[l + mp, l + m] = v
    return d


def wigner_d_complex(l: int, alpha: float, beta: float, gamma: float) -> np.ndarray:
    """Complex Wigner D^l_{m'm}(alpha,beta,gamma) = e^{-i m' a} d(b) e^{-i m g}.

    Convention fixed so that  Y^l(R r) = D_real^l(R) Y^l(r)  with
    R = Rz(alpha) Ry(beta) Rz(gamma)  (verified in tests/test_so3.py).
    """
    d = wigner_d_small(l, beta)
    ms = np.arange(-l, l + 1)
    # sign convention chosen (and locked by tests) so that the *real* basis
    # transport U D U^H satisfies S^l(R r) = D_real S^l(r) with
    # R = Rz(a) Ry(b) Rz(g): this is conj() of the usual QM state-rotation D.
    return np.exp(1j * alpha * ms)[:, None] * d * np.exp(1j * gamma * ms)[None, :]


@lru_cache(maxsize=None)
def _u_pair(l: int) -> tuple[np.ndarray, np.ndarray]:
    U = u_matrix(l)
    return U, U.conj().T


def wigner_D_real(l: int, alpha: float, beta: float, gamma: float) -> np.ndarray:
    """Real-basis Wigner D for rotation R = Rz(alpha) Ry(beta) Rz(gamma):
    S^l(R r) = D S^l(r)."""
    U, Uh = _u_pair(l)
    D = U @ wigner_d_complex(l, alpha, beta, gamma) @ Uh
    assert np.abs(D.imag).max() < 1e-9
    return D.real


def wigner_D_real_packed(L: int, alpha: float, beta: float, gamma: float) -> np.ndarray:
    """Block-diagonal real Wigner D over the packed (L+1)^2 layout."""
    n = num_coeffs(L)
    out = np.zeros((n, n))
    for l in range(L + 1):
        sl = slice(l * l, (l + 1) * (l + 1))
        out[sl, sl] = wigner_D_real(l, alpha, beta, gamma)
    return out


def rotation_matrix_zyz(alpha: float, beta: float, gamma: float) -> np.ndarray:
    """R = Rz(alpha) Ry(beta) Rz(gamma) acting on column vectors."""

    def rz(a):
        return np.array(
            [[math.cos(a), -math.sin(a), 0], [math.sin(a), math.cos(a), 0], [0, 0, 1]]
        )

    def ry(a):
        return np.array(
            [[math.cos(a), 0, math.sin(a)], [0, 1, 0], [-math.sin(a), 0, math.cos(a)]]
        )

    return rz(alpha) @ ry(beta) @ rz(gamma)


def euler_from_matrix_zyz(R: np.ndarray) -> tuple[float, float, float]:
    """Inverse of rotation_matrix_zyz (beta in [0, pi])."""
    beta = math.acos(max(-1.0, min(1.0, R[2, 2])))
    if abs(R[2, 2]) < 1 - 1e-12:
        alpha = math.atan2(R[1, 2], R[0, 2])
        gamma = math.atan2(R[2, 1], -R[2, 0])
    else:  # gimbal: fold into alpha
        alpha = math.atan2(R[1, 0], R[0, 0]) if R[2, 2] > 0 else math.atan2(-R[1, 0], -R[0, 0])
        gamma = 0.0
    return alpha, beta, gamma


def align_to_z_angles(r: np.ndarray) -> tuple[float, float, float]:
    """Euler angles (zyz) of a rotation g with R(g) @ r_hat = (0, 0, 1).

    eSCN / the paper rotate edges onto the +y axis because e3nn uses a y-up SH
    convention; our SH are standard z-up, so the zenith alignment (which makes
    the SH filter non-zero only at m = 0: S_{l,m}(e_z) = delta_{m0}
    sqrt((2l+1)/4pi)) targets +z instead.  Same insight, adapted convention.
    """
    r = np.asarray(r, dtype=np.float64)
    r = r / np.linalg.norm(r)
    theta = math.acos(max(-1.0, min(1.0, r[2])))
    psi = math.atan2(r[1], r[0])
    # Ry(-theta) Rz(-psi) sends r to +z
    R = rotation_matrix_zyz(0.0, -theta, -psi)
    return euler_from_matrix_zyz(R)
