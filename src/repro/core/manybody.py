"""Equivariant Many-body Interactions (paper §3.3, class 3).

nu-fold Gaunt products  x_1 (x) x_2 (x) ... (x) x_n  computed as one long
chain of spherical-function multiplications: convert every operand to its
torus-Fourier grid once, then combine grids with a **divide-and-conquer**
tree of 2D convolutions (depth ceil(log2 n)); same-shaped siblings are
stacked and convolved in a single batched call — this is the paper's
parallelization, O(n^2 L^2 log L) vs O(n^3 L^2 log L) for the sequential
left-fold.  No intermediate degree truncation (faithful to the paper);
the final grid is projected to SH degrees <= Lout.

`manybody_gaunt_product` is a thin consumer of the engine's **chain plans**
(`engine.plan_chain`, DESIGN.md §6): the whole tree is one Fourier-resident
pass — each operand converts at most once (a shared operand converts once
*total*, even under different per-degree weights, via the degree-resolved
conversion), interior products never round-trip through SH, and a single
projection runs at the exit.  Operands may already be Fourier-resident
``Rep``s (their conversion is skipped), and ``out_basis='fourier'`` keeps
the product resident for a downstream chain.  Residency composes with the
execution knobs: ``donate`` hands the unique operand buffers to XLA and
``shard_spec`` row-shards the whole chain (grids, combination, projection)
over the mesh's data axes — both keep the <= 1-conversion-per-operand
guarantee.  Only an explicit ``backend`` (or conversion='packed') pins the
per-plan batched dispatch (`engine.plan_batch`, kind='manybody') instead.
"""
from __future__ import annotations

import jax.numpy as jnp

from .gaunt import conv2d_full, conv2d_herm

__all__ = ["manybody_gaunt_product", "manybody_selfmix"]


def _tree_convolve(grids: list, method: str, herm: bool = False):
    """grids: list of centered coefficient grids — full [..., n_i, n_i] or,
    with ``herm``, Hermitian half forms [..., n_i, L_i+1]."""
    conv = conv2d_herm if herm else conv2d_full
    while len(grids) > 1:
        nxt = []
        i = 0
        while i + 1 < len(grids):
            a, b = grids[i], grids[i + 1]
            if a.shape == b.shape and len(grids) >= 4:
                # batch same-shaped sibling pairs in one call when several
                j = i
                As, Bs = [], []
                while j + 1 < len(grids) and grids[j].shape == a.shape and grids[j + 1].shape == b.shape:
                    As.append(grids[j])
                    Bs.append(grids[j + 1])
                    j += 2
                A = jnp.stack(As, axis=0)
                B = jnp.stack(Bs, axis=0)
                C = conv(A, B, method)
                nxt.extend([C[t] for t in range(C.shape[0])])
                i = j
            else:
                nxt.append(conv(a, b, method))
                i += 2
        if i < len(grids):
            nxt.append(grids[i])
        grids = nxt
    return grids[0]


def manybody_gaunt_product(xs, Ls, Lout: int | None = None, weights=None,
                           conv: str | None = None, conversion: str | None = None,
                           cdtype=jnp.complex64, rdtype=None,
                           backend: str | None = None, tune: str = "heuristic",
                           donate: bool = False, shard_spec=None,
                           out_basis: str = "sh", dtype=None,
                           gate_params=None):
    """xs: list of [..., (L_i+1)^2] features (or Fourier-resident ``Rep``s);
    Ls: their max degrees.

    weights: optional list of per-degree weights w_i [..., L_i+1] (the paper's
    reparameterized (lm)->l couplings).  Returns [..., (Lout+1)^2], or a
    resident ``Rep`` when ``out_basis='fourier'``.

    dtype: SH *storage* dtype for the plan ('float32' | 'bfloat16' |
    'float64', or 'auto' to let tune='measure' time both precisions —
    DESIGN.md §3.6).  Defaults to the dtype implied by ``cdtype`` (float32
    for complex64).  Accumulation and the resident grids stay >= f32 either
    way; rdtype=None returns the plan's storage dtype, an explicit rdtype
    casts the SH output.

    gate_params: optional {'w1', 'w2'} MLP params — plans the chain with a
    fused grid-resident equivariant gate (DESIGN.md §6.5): the affine gate
    g*f + beta*Y00 runs pointwise on the resident product grid (inside the
    collocation kernel on the fused backends), so gated SH output equals
    ``models.equivariant.gate_apply(gate_params, out, Lout)`` without an
    extra exit/re-entry conversion pair.  Chain route only.

    Default route: one Fourier-resident chain plan (`engine.plan_chain`) —
    conversion/conv default to the plan's measured auto policy ('half' grids,
    direct-vs-rfft by chain shape); 'dense' keeps full grids (conv
    'fft'|'direct').  ``donate`` and ``shard_spec`` stay ON the chain route:
    the plan donates the unique operand buffers and/or row-shards the whole
    resident pass, still converting each distinct operand at most once.
    Only an explicit ``backend`` (or conversion='packed') pins the per-plan
    batched engine dispatch (kind='manybody', DESIGN.md §5) instead, which
    converts through the plan's own boundary.
    """
    from . import engine as _engine

    assert len(xs) == len(Ls) and len(xs) >= 2
    if dtype is None:
        dts = _engine._dtype_str(cdtype)
    else:
        dts = "auto" if dtype == "auto" else _engine._dtype_str(dtype)
    if backend is None and conversion in (None, "dense", "half"):
        # jit-cached chain dispatch (apply_jit) so eager callers keep one
        # compiled invocation per call, as the batched route gave them.
        # ``tune='measure'`` folds the chain into the engine's measured
        # autotuner (DESIGN.md §6.4): backend dispatch across the resident
        # tree, the per-product loop, and the n-way collocation kernel,
        # keyed by (Ls, Lout, dtype, rows); the default keeps the resident
        # tree with the conversion/conv shape rule.
        hint, entry_hint = None, None
        if tune == "measure":
            import numpy as _np

            def _lead(x):
                if getattr(x, "is_fourier", False):
                    return x.data.shape[:-2]
                return (x.data if hasattr(x, "data") else x).shape[:-1]

            lead = jnp.broadcast_shapes(*[_lead(x) for x in xs])
            hint = int(_np.prod(lead)) if lead else 1
            # measure on the operand kinds actually passed: resident Reps
            # stay resident in the timing, and duplicate operands (selfmix's
            # [A]*nu) repeat one synthetic buffer so tree's shared single
            # conversion engages (see engine._select_chain)
            entry_hint = tuple("fourier" if getattr(x, "is_fourier", False)
                               else "sh" for x in xs)
            seen: dict = {}
            share_hint = tuple(
                seen.setdefault(id(x.data if hasattr(x, "data") else x),
                                len(seen)) for x in xs)
        else:
            share_hint = None
        cp = _engine.plan_chain(
            Ls, Lout, conversion=conversion, conv=conv, dtype=dts,
            donate=donate, shard_spec=shard_spec, tune=tune, batch_hint=hint,
            entry_hint=entry_hint, out_hint=out_basis, share_hint=share_hint,
            gate=gate_params is not None)
        out = cp.apply_jit(list(xs), weights=weights, out_basis=out_basis,
                           gate_params=gate_params)
        if out_basis == "fourier":
            return out
        return out if rdtype is None else out.astype(rdtype)
    if gate_params is not None:
        raise ValueError("gate_params requires the chain route "
                         "(no explicit backend/conversion override)")
    if out_basis != "sh":
        raise ValueError("out_basis='fourier' requires the chain route "
                         "(no explicit backend/conversion override)")
    options = None
    if backend == "auto":
        backend = None
    elif backend is None:
        if conversion in (None, "dense"):
            backend = conv or "fft"
        elif conversion == "packed":
            backend, options = "packed", {"conv": conv or "fft"}
        elif conversion == "half":
            backend, options = "rfft", {"conv": conv or "rfft"}
        else:
            raise ValueError(f"unknown conversion {conversion!r}")
    item = _engine.BatchItem(Ls=tuple(int(L) for L in Ls), Lout=Lout,
                             options=tuple(sorted((options or {}).items())))
    bp = _engine.plan_batch([item], kind="manybody",
                            dtype=dts, backend=backend,
                            tune=tune, donate=donate, shard_spec=shard_spec)
    out = bp.apply([list(xs)], weights=[weights])[0]
    return out if rdtype is None else out.astype(rdtype)


def manybody_selfmix(x, L: int, nu: int, Lout: int | None = None, weights=None, **kw):
    """MACE-style B_nu = A (x) ... (x) A (nu operands).

    The nu operands are the SAME tensor, so the chain route converts A to
    the Fourier basis exactly once (degree-resolved when ``weights`` differ
    per operand) instead of nu times."""
    return manybody_gaunt_product([x] * nu, [L] * nu, Lout=Lout, weights=weights, **kw)
