"""Equivariant Many-body Interactions (paper §3.3, class 3).

nu-fold Gaunt products  x_1 (x) x_2 (x) ... (x) x_n  computed as one long
chain of spherical-function multiplications: convert every operand to its
torus-Fourier grid once, then combine grids with a **divide-and-conquer**
tree of 2D convolutions (depth ceil(log2 n)); same-shaped siblings are
stacked and convolved in a single batched call — this is the paper's
parallelization, O(n^2 L^2 log L) vs O(n^3 L^2 log L) for the sequential
left-fold.  No intermediate degree truncation (faithful to the paper);
the final grid is projected to SH degrees <= Lout.
"""
from __future__ import annotations

import jax.numpy as jnp

from .gaunt import conv2d_full

__all__ = ["manybody_gaunt_product", "manybody_selfmix"]


def _tree_convolve(grids: list, method: str):
    """grids: list of [..., n_i, n_i] centered coefficient grids."""
    while len(grids) > 1:
        nxt = []
        i = 0
        while i + 1 < len(grids):
            a, b = grids[i], grids[i + 1]
            if a.shape == b.shape and len(grids) >= 4:
                # batch same-shaped sibling pairs in one call when several
                j = i
                As, Bs = [], []
                while j + 1 < len(grids) and grids[j].shape == a.shape and grids[j + 1].shape == b.shape:
                    As.append(grids[j])
                    Bs.append(grids[j + 1])
                    j += 2
                A = jnp.stack(As, axis=0)
                B = jnp.stack(Bs, axis=0)
                C = conv2d_full(A, B, method)
                nxt.extend([C[t] for t in range(C.shape[0])])
                i = j
            else:
                nxt.append(conv2d_full(a, b, method))
                i += 2
        if i < len(grids):
            nxt.append(grids[i])
        grids = nxt
    return grids[0]


def manybody_gaunt_product(xs, Ls, Lout: int | None = None, weights=None,
                           conv: str = "fft", conversion: str = "dense",
                           cdtype=jnp.complex64, rdtype=jnp.float32,
                           backend: str | None = None, tune: str = "heuristic",
                           donate: bool = False, shard_spec=None):
    """xs: list of [..., (L_i+1)^2] features; Ls: their max degrees.

    weights: optional list of per-degree weights w_i [..., L_i+1] (the paper's
    reparameterized (lm)->l couplings).  Returns [..., (Lout+1)^2].

    Thin wrapper over the unified engine, routed through a batched plan
    (kind='manybody'): leading dims flatten to one row axis executed as a
    single fused invocation, with optional buffer donation and sharded
    dispatch (`shard_spec`, see engine.ShardSpec).  (conversion, conv) map
    onto the 'fft'/'direct'/'packed' backends; `backend` pins any registered
    many-body backend ('auto' -> engine selection).
    """
    from . import engine as _engine

    assert len(xs) == len(Ls) and len(xs) >= 2
    options = None
    if backend is None:
        if conversion == "dense":
            backend = conv  # 'fft' | 'direct'
        elif conversion == "packed":
            backend, options = "packed", {"conv": conv}
        else:
            raise ValueError(f"unknown conversion {conversion!r}")
    elif backend == "auto":
        backend = None
    item = _engine.BatchItem(Ls=tuple(int(L) for L in Ls), Lout=Lout,
                             options=tuple(sorted((options or {}).items())))
    bp = _engine.plan_batch([item], kind="manybody",
                            dtype=_engine._dtype_str(cdtype), backend=backend,
                            tune=tune, donate=donate, shard_spec=shard_spec)
    return bp.apply([list(xs)], weights=[weights])[0].astype(rdtype)


def manybody_selfmix(x, L: int, nu: int, Lout: int | None = None, weights=None, **kw):
    """MACE-style B_nu = A (x) ... (x) A (nu operands)."""
    return manybody_gaunt_product([x] * nu, [L] * nu, Lout=Lout, weights=weights, **kw)
