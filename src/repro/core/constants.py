"""The Gaunt engine's central constant cache (see DESIGN.md §2.4).

Every precomputed tensor used by any Gaunt backend lives behind exactly one
lru-cached builder in this module: SH<->Fourier conversion tensors (dense and
packed), packed-layout gather maps, the eSCN filter column and banded-conv
index, the Wigner-recursion CG blocks, and the fused collocation matrices
T1/T2/P.  This replaces the per-module ``lru_cache`` constellations that used
to live in ``core/gaunt.py``, ``core/conv.py`` and ``kernels/gaunt_fused.py``.

All values are **numpy** arrays: a jnp constant created inside one jit trace
would leak that trace's tracer into every later trace served from the cache.
Consumers wrap with ``jnp.asarray`` at use time (free — XLA hoists constants).

``cache_stats()`` exposes hit/miss counters so tests can assert that plans
reuse constants instead of rebuilding them.
"""
from __future__ import annotations

import math
from functools import lru_cache

import numpy as np

from . import fourier as _fx
from .irreps import idx, num_coeffs
from .so3 import real_clebsch_gordan_block, real_gaunt_tensor, real_sph_harm

__all__ = [
    "y_dense",
    "z_dense",
    "y_packed",
    "z_packed",
    "y_half",
    "z_half",
    "pack_index",
    "filter_fourier_col",
    "conv_u_index",
    "cg_11_blocks",
    "fused_matrices",
    "gaunt_dense",
    "cache_stats",
    "clear_all",
]


# --------------------------------------------------------------------------
# SH <-> 2D Fourier conversion tensors
# --------------------------------------------------------------------------


@lru_cache(maxsize=None)
def _y_raw(L: int) -> np.ndarray:
    return _fx.sh_to_fourier_dense(L)


@lru_cache(maxsize=None)
def _z_raw(Lf: int, Lout: int) -> np.ndarray:
    return _fx.fourier_to_sh_dense(Lf, Lout)


@lru_cache(maxsize=None)
def y_dense(L: int, cdtype: str = "complex64") -> np.ndarray:
    """sh->Fourier tensor [(L+1)^2, 2L+1 (u), 2L+1 (v)], centered."""
    return _y_raw(L).astype(cdtype)


@lru_cache(maxsize=None)
def z_dense(Lf: int, Lout: int, cdtype: str = "complex64") -> np.ndarray:
    """Fourier->sh tensor [2Lf+1, 2Lf+1, (Lout+1)^2], centered."""
    return _z_raw(Lf, Lout).astype(cdtype)


@lru_cache(maxsize=None)
def y_packed(L: int, cdtype: str = "complex64") -> tuple[np.ndarray, np.ndarray]:
    """Packed (per-|m| block-sparse) sh->Fourier matrices (yp, yn)."""
    yp, yn = _fx.sh_to_fourier_packed(L, y=_y_raw(L))
    return yp.astype(cdtype), yn.astype(cdtype)


@lru_cache(maxsize=None)
def z_packed(Lf: int, Lout: int, cdtype: str = "complex64") -> tuple[np.ndarray, np.ndarray]:
    """Packed Fourier->sh matrices (zp, zn)."""
    zp, zn = _fx.fourier_to_sh_packed(Lf, Lout, z=_z_raw(Lf, Lout))
    return zp.astype(cdtype), zn.astype(cdtype)


@lru_cache(maxsize=None)
def y_half(L: int, cdtype: str = "complex64") -> np.ndarray:
    """Half (Hermitian / real-input) sh->Fourier tensor: v >= 0 columns only."""
    return _fx.sh_to_fourier_half(L, y=_y_raw(L)).astype(cdtype)


@lru_cache(maxsize=None)
def z_half(Lf: int, Lout: int, cdtype: str = "complex64") -> np.ndarray:
    """Half Fourier->sh tensor with the v < 0 columns conjugate-folded in."""
    return _fx.fourier_to_sh_half(Lf, Lout, z=_z_raw(Lf, Lout)).astype(cdtype)


@lru_cache(maxsize=None)
def pack_index(L: int) -> tuple[np.ndarray, np.ndarray]:
    """Gather map packed[plane, mm, l] <- flat idx(l, +-mm); mask for valid."""
    gidx = np.zeros((2, L + 1, L + 1), dtype=np.int32)
    mask = np.zeros((2, L + 1, L + 1), dtype=np.float32)
    for mm in range(L + 1):
        for l in range(mm, L + 1):
            gidx[0, mm, l] = l * l + l + mm
            mask[0, mm, l] = 1.0
            if mm > 0:
                gidx[1, mm, l] = l * l + l - mm
                mask[1, mm, l] = 1.0
    return gidx, mask


# --------------------------------------------------------------------------
# eSCN rotation-aligned path constants
# --------------------------------------------------------------------------


@lru_cache(maxsize=None)
def filter_fourier_col(L2: int, cdtype: str = "complex64") -> np.ndarray:
    """u-column (v=0) Fourier coefficients of S_{l,0}, stacked [L2+1, 2L2+1]."""
    y = _y_raw(L2)
    cols = np.stack([y[idx(l, 0), :, L2] for l in range(L2 + 1)], axis=0)
    return cols.astype(cdtype)


@lru_cache(maxsize=None)
def conv_u_index(L1: int, L2: int) -> tuple[np.ndarray, np.ndarray]:
    """Index/mask for the banded 1D convolution along u.

    out[u3] = sum_{u1} F1[u1] * k[u3 - u1] with centered indices;
    idx[i3, i1] = i3 - i1 into the kernel array of length 2L2+1.
    """
    n1, n2 = 2 * L1 + 1, 2 * L2 + 1
    N = n1 + n2 - 1
    i3 = np.arange(N)[:, None]
    i1 = np.arange(n1)[None, :]
    k = i3 - i1  # in [ -(n1-1), N-1 ]
    valid = (k >= 0) & (k < n2)
    return np.where(valid, k, 0).astype(np.int32), valid.astype(np.float32)


@lru_cache(maxsize=None)
def cg_11_blocks(L: int) -> tuple[np.ndarray, ...]:
    """CG blocks C_{(l-1,1)->l} for the Wigner-from-rotmat recursion."""
    return tuple(
        real_clebsch_gordan_block(l - 1, 1, l).astype(np.float32)
        for l in range(2, L + 1)
    )


# --------------------------------------------------------------------------
# fused collocation (sample-multiply-project) matrices
# --------------------------------------------------------------------------


@lru_cache(maxsize=None)
def fused_matrices(L1: int, L2: int, Lout: int, pad_lanes: bool = True):
    """Collocation matrices (T1 [d1,G], T2 [d2,G], P [G,dout]) — exact.

    T_i samples real SH on the alias-free torus grid; P projects pointwise
    products back to SH degrees <= Lout (see DESIGN.md §3.4).  When
    ``pad_lanes``, G is rounded up to a multiple of 128 (extra sample points
    get zero projection weight — harmless and keeps the TPU MXU aligned).
    """
    Lt = L1 + L2
    N = 2 * Lt + 2  # > 2*Lt+1: alias-free for the product
    t = 2 * math.pi * np.arange(N) / N
    p = 2 * math.pi * np.arange(N) / N
    tt, pp = np.meshgrid(t, p, indexing="ij")
    xyz = np.stack([np.sin(tt) * np.cos(pp), np.sin(tt) * np.sin(pp), np.cos(tt)], -1)
    S = real_sph_harm(max(L1, L2), xyz.reshape(-1, 3))  # [G, dmax]
    T1 = S[:, : num_coeffs(L1)].T.copy()  # [d1, G]
    T2 = S[:, : num_coeffs(L2)].T.copy()
    # projection: F3[u,v] = (1/N^2) sum_g V[g] e^{-i(u t_g + v p_g)}; out = sum F3 z
    z = _z_raw(Lt, Lout)  # [2Lt+1, 2Lt+1, dout] complex
    us = np.arange(-Lt, Lt + 1)
    Et = np.exp(-1j * np.outer(t, us))  # [N, 2Lt+1]
    Ep = np.exp(-1j * np.outer(p, us))
    P = np.einsum("au,bv,uvk->abk", Et, Ep, z).real / (N * N)
    P = P.reshape(N * N, -1)
    if pad_lanes:
        G = T1.shape[1]
        Gp = ((G + 127) // 128) * 128
        T1 = np.pad(T1, [(0, 0), (0, Gp - G)])
        T2 = np.pad(T2, [(0, 0), (0, Gp - G)])
        P = np.pad(P, [(0, Gp - G), (0, 0)])
    return T1.astype(np.float32), T2.astype(np.float32), P.astype(np.float32)


@lru_cache(maxsize=None)
def gaunt_dense(L1: int, L2: int, Lout: int, dtype: str = "float32") -> np.ndarray:
    """The exact dense real-Gaunt tensor [(L1+1)^2, (L2+1)^2, (Lout+1)^2]."""
    return real_gaunt_tensor(L1, L2, Lout).astype(dtype)


# --------------------------------------------------------------------------
# introspection
# --------------------------------------------------------------------------

_CACHED = (
    _y_raw, _z_raw, y_dense, z_dense, y_packed, z_packed, y_half, z_half,
    pack_index, filter_fourier_col, conv_u_index, cg_11_blocks, fused_matrices,
    gaunt_dense,
)


def cache_stats() -> dict[str, tuple[int, int, int]]:
    """{builder name: (hits, misses, currsize)} over every cached builder."""
    return {f.__name__: (ci.hits, ci.misses, ci.currsize)
            for f in _CACHED for ci in (f.cache_info(),)}


def clear_all() -> None:
    """Drop every cached constant (tests / memory pressure)."""
    for f in _CACHED:
        f.cache_clear()
