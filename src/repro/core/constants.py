"""The Gaunt engine's central constant cache (see DESIGN.md §2.4).

Every precomputed tensor used by any Gaunt backend lives behind exactly one
lru-cached builder in this module: SH<->Fourier conversion tensors (dense and
packed), packed-layout gather maps, the eSCN filter column and banded-conv
index, the Wigner-recursion CG blocks, and the fused collocation matrices
T1/T2/P.  This replaces the per-module ``lru_cache`` constellations that used
to live in ``core/gaunt.py``, ``core/conv.py`` and ``kernels/gaunt_fused.py``.

All values are **numpy** arrays: a jnp constant created inside one jit trace
would leak that trace's tracer into every later trace served from the cache.
Consumers wrap with ``jnp.asarray`` at use time (free — XLA hoists constants).

``cache_stats()`` exposes hit/miss counters so tests can assert that plans
reuse constants instead of rebuilding them.
"""
from __future__ import annotations

import math
from functools import lru_cache

import numpy as np

from . import fourier as _fx
from .irreps import idx, num_coeffs
from .so3 import real_clebsch_gordan_block, real_gaunt_tensor, real_sph_harm

__all__ = [
    "y_dense",
    "z_dense",
    "y_packed",
    "z_packed",
    "y_half",
    "z_half",
    "pack_index",
    "filter_fourier_col",
    "conv_u_index",
    "cg_11_blocks",
    "fused_matrices",
    "chain_matrices",
    "chain_sample_sh",
    "chain_sample_grid",
    "chain_project_sh",
    "chain_project_grid",
    "chain_l0",
    "quad_sample_sh",
    "quad_project_sh",
    "quad_sample_fourier",
    "quad_project_fourier",
    "gaunt_dense",
    "cache_stats",
    "clear_all",
]


# --------------------------------------------------------------------------
# SH <-> 2D Fourier conversion tensors
# --------------------------------------------------------------------------


@lru_cache(maxsize=None)
def _y_raw(L: int) -> np.ndarray:
    return _fx.sh_to_fourier_dense(L)


@lru_cache(maxsize=None)
def _z_raw(Lf: int, Lout: int) -> np.ndarray:
    return _fx.fourier_to_sh_dense(Lf, Lout)


@lru_cache(maxsize=None)
def y_dense(L: int, cdtype: str = "complex64") -> np.ndarray:
    """sh->Fourier tensor [(L+1)^2, 2L+1 (u), 2L+1 (v)], centered."""
    return _y_raw(L).astype(cdtype)


@lru_cache(maxsize=None)
def z_dense(Lf: int, Lout: int, cdtype: str = "complex64") -> np.ndarray:
    """Fourier->sh tensor [2Lf+1, 2Lf+1, (Lout+1)^2], centered."""
    return _z_raw(Lf, Lout).astype(cdtype)


@lru_cache(maxsize=None)
def y_packed(L: int, cdtype: str = "complex64") -> tuple[np.ndarray, np.ndarray]:
    """Packed (per-|m| block-sparse) sh->Fourier matrices (yp, yn)."""
    yp, yn = _fx.sh_to_fourier_packed(L, y=_y_raw(L))
    return yp.astype(cdtype), yn.astype(cdtype)


@lru_cache(maxsize=None)
def z_packed(Lf: int, Lout: int, cdtype: str = "complex64") -> tuple[np.ndarray, np.ndarray]:
    """Packed Fourier->sh matrices (zp, zn)."""
    zp, zn = _fx.fourier_to_sh_packed(Lf, Lout, z=_z_raw(Lf, Lout))
    return zp.astype(cdtype), zn.astype(cdtype)


@lru_cache(maxsize=None)
def y_half(L: int, cdtype: str = "complex64") -> np.ndarray:
    """Half (Hermitian / real-input) sh->Fourier tensor: v >= 0 columns only."""
    return _fx.sh_to_fourier_half(L, y=_y_raw(L)).astype(cdtype)


@lru_cache(maxsize=None)
def z_half(Lf: int, Lout: int, cdtype: str = "complex64") -> np.ndarray:
    """Half Fourier->sh tensor with the v < 0 columns conjugate-folded in."""
    return _fx.fourier_to_sh_half(Lf, Lout, z=_z_raw(Lf, Lout)).astype(cdtype)


@lru_cache(maxsize=None)
def pack_index(L: int) -> tuple[np.ndarray, np.ndarray]:
    """Gather map packed[plane, mm, l] <- flat idx(l, +-mm); mask for valid."""
    gidx = np.zeros((2, L + 1, L + 1), dtype=np.int32)
    mask = np.zeros((2, L + 1, L + 1), dtype=np.float32)
    for mm in range(L + 1):
        for l in range(mm, L + 1):
            gidx[0, mm, l] = l * l + l + mm
            mask[0, mm, l] = 1.0
            if mm > 0:
                gidx[1, mm, l] = l * l + l - mm
                mask[1, mm, l] = 1.0
    return gidx, mask


# --------------------------------------------------------------------------
# eSCN rotation-aligned path constants
# --------------------------------------------------------------------------


@lru_cache(maxsize=None)
def filter_fourier_col(L2: int, cdtype: str = "complex64") -> np.ndarray:
    """u-column (v=0) Fourier coefficients of S_{l,0}, stacked [L2+1, 2L2+1]."""
    y = _y_raw(L2)
    cols = np.stack([y[idx(l, 0), :, L2] for l in range(L2 + 1)], axis=0)
    return cols.astype(cdtype)


@lru_cache(maxsize=None)
def conv_u_index(L1: int, L2: int) -> tuple[np.ndarray, np.ndarray]:
    """Index/mask for the banded 1D convolution along u.

    out[u3] = sum_{u1} F1[u1] * k[u3 - u1] with centered indices;
    idx[i3, i1] = i3 - i1 into the kernel array of length 2L2+1.
    """
    n1, n2 = 2 * L1 + 1, 2 * L2 + 1
    N = n1 + n2 - 1
    i3 = np.arange(N)[:, None]
    i1 = np.arange(n1)[None, :]
    k = i3 - i1  # in [ -(n1-1), N-1 ]
    valid = (k >= 0) & (k < n2)
    return np.where(valid, k, 0).astype(np.int32), valid.astype(np.float32)


@lru_cache(maxsize=None)
def cg_11_blocks(L: int) -> tuple[np.ndarray, ...]:
    """CG blocks C_{(l-1,1)->l} for the Wigner-from-rotmat recursion."""
    return tuple(
        real_clebsch_gordan_block(l - 1, 1, l).astype(np.float32)
        for l in range(2, L + 1)
    )


# --------------------------------------------------------------------------
# fused collocation (sample-multiply-project) matrices — pairwise and n-way
# chain forms share one set of builders (DESIGN.md §3.4 / §6.4)
# --------------------------------------------------------------------------


def _chain_grid_angles(Ltot: int) -> tuple[int, np.ndarray]:
    """(N, angles) of the alias-free product grid for total degree Ltot.

    A product of bandlimited spherical functions with degrees summing to
    Ltot is bandlimited at Ltot on the torus double cover; N = 2*Ltot + 2
    (> 2*Ltot + 1 and even) samples it alias-free.
    """
    N = 2 * Ltot + 2
    return N, 2 * math.pi * np.arange(N) / N


@lru_cache(maxsize=None)
def chain_sample_sh(L: int, Ltot: int) -> np.ndarray:
    """T [(L+1)^2, G]: real SH of degree <= L sampled on the degree-Ltot
    product grid (float64, unpadded) — the per-operand sampling matrix of
    the chain collocation kernel."""
    N, t = _chain_grid_angles(Ltot)
    tt, pp = np.meshgrid(t, t, indexing="ij")
    xyz = np.stack([np.sin(tt) * np.cos(pp), np.sin(tt) * np.sin(pp), np.cos(tt)], -1)
    S = real_sph_harm(L, xyz.reshape(-1, 3))  # [G, (L+1)^2]
    return S.T.copy()


@lru_cache(maxsize=None)
def chain_sample_grid(L: int, Ltot: int) -> np.ndarray:
    """T' [2*(2L+1)*(L+1), G]: Fourier-resident entry sampling matrix.

    A resident operand arrives as its Hermitian *half* coefficient grid
    F [2L+1 (u), L+1 (v >= 0)]; its real spatial samples on the product grid
    are  V[g] = Re( sum_{u, v>=0} c_v F[u,v] e^{i(u t_g + v p_g)} )  with
    c_0 = 1, c_v = 2 (the v < 0 half is the conjugate mirror).  Stacking the
    grid as the real vector [Re F; Im F] makes this one REAL matmul, so
    resident operands enter the chain kernel as grids — no SH data, no
    sh_to_fourier, the sampling matmul just uses this matrix instead of
    `chain_sample_sh`.
    """
    N, t = _chain_grid_angles(Ltot)
    us = np.arange(-L, L + 1)
    vs = np.arange(0, L + 1)
    Et = np.exp(1j * np.outer(us, t))          # [2L+1, N]
    Ep = np.exp(1j * np.outer(vs, t))          # [L+1, N]
    c = np.where(vs == 0, 1.0, 2.0)
    E = np.einsum("ua,vb,v->uvab", Et, Ep, c).reshape((2 * L + 1) * (L + 1), N * N)
    return np.concatenate([E.real, -E.imag], axis=0)


@lru_cache(maxsize=None)
def chain_project_sh(Ltot: int, Lout: int) -> np.ndarray:
    """P [G, (Lout+1)^2]: product-grid samples -> SH degrees <= Lout.

    P[g, k] = Re((1/G) sum_{u,v} e^{-i(u t_g + v p_g)} z^k_{u,v}) — the
    discrete projection equals the convolution-theorem result to machine
    precision because the sampled product is alias-free (float64, unpadded).
    """
    N, t = _chain_grid_angles(Ltot)
    z = _z_raw(Ltot, Lout)  # [2Lt+1, 2Lt+1, dout] complex
    us = np.arange(-Ltot, Ltot + 1)
    Et = np.exp(-1j * np.outer(t, us))  # [N, 2Lt+1]
    P = np.einsum("au,bv,uvk->abk", Et, Et, z).real / (N * N)
    return P.reshape(N * N, -1)


@lru_cache(maxsize=None)
def chain_project_grid(Ltot: int) -> np.ndarray:
    """P' [G, 2*(2Lt+1)*(Lt+1)]: samples -> real-stacked half product grid.

    F[u,v] = (1/G) sum_g V[g] e^{-i(u t_g + v p_g)} for v >= 0; the output
    stacks [Re F; Im F] so a 'fourier' chain exit is one real matmul whose
    result reassembles into the resident half grid outside the kernel.
    """
    N, t = _chain_grid_angles(Ltot)
    us = np.arange(-Ltot, Ltot + 1)
    vs = np.arange(0, Ltot + 1)
    Et = np.exp(-1j * np.outer(t, us))          # [N, 2Lt+1]
    Ep = np.exp(-1j * np.outer(t, vs))          # [N, Lt+1]
    E = np.einsum("au,bv->abuv", Et, Ep).reshape(N * N, -1) / (N * N)
    return np.concatenate([E.real, E.imag], axis=1)


@lru_cache(maxsize=None)
def chain_matrices(Ls: tuple, Lout: int, entries: tuple = None,
                   out_entry: str = "sh", pad_lanes: bool = True,
                   dtype: str = "float32"):
    """Chain collocation matrices ((T_1..T_n), P) for  x1 (x) ... (x) xn.

    entries: per-operand 'sh' (packed SH vector, T from `chain_sample_sh`)
    or 'grid' (Fourier-resident real-stacked half grid, `chain_sample_grid`);
    out_entry: 'sh' projects to degrees <= Lout, 'grid' returns the
    real-stacked half product grid (requires Lout == sum(Ls)).  When
    ``pad_lanes``, G rounds up to a multiple of 128 (zero sample columns /
    zero projection rows — inert, keeps the TPU MXU lane-aligned).

    ``dtype`` is the *storage* dtype of the returned matrices; 'bfloat16'
    works through numpy via the ml_dtypes registration that jax ships (the
    float64 intermediates round once, at the very end).  Mixed-precision
    callers request T at the storage dtype and P at the accumulation dtype
    (two cache entries — see kernels/gaunt_fused.py).
    """
    Ls = tuple(int(L) for L in Ls)
    Ltot = sum(Ls)
    entries = ("sh",) * len(Ls) if entries is None else tuple(entries)
    if len(entries) != len(Ls) or any(e not in ("sh", "grid") for e in entries):
        raise ValueError(f"entries must be {len(Ls)} of 'sh'|'grid', got {entries!r}")
    Ts = [chain_sample_sh(L, Ltot) if e == "sh" else chain_sample_grid(L, Ltot)
          for L, e in zip(Ls, entries)]
    if out_entry == "sh":
        P = chain_project_sh(Ltot, Lout)
    elif out_entry == "grid":
        if Lout != Ltot:
            raise ValueError(f"out_entry='grid' keeps the full product grid "
                             f"(L={Ltot}); got Lout={Lout}")
        P = chain_project_grid(Ltot)
    else:
        raise ValueError(f"unknown out_entry {out_entry!r} (expected 'sh'|'grid')")
    if pad_lanes:
        G = Ts[0].shape[1]
        Gp = ((G + 127) // 128) * 128
        Ts = [np.pad(T, [(0, 0), (0, Gp - G)]) for T in Ts]
        P = np.pad(P, [(0, Gp - G), (0, 0)])
    return tuple(T.astype(dtype) for T in Ts), P.astype(dtype)


@lru_cache(maxsize=None)
def fused_matrices(L1: int, L2: int, Lout: int, pad_lanes: bool = True,
                   dtype: str = "float32"):
    """Pairwise collocation matrices (T1 [d1,G], T2 [d2,G], P [G,dout]) —
    the n=2 special case of `chain_matrices` (see DESIGN.md §3.4), at the
    requested storage dtype (both T and P; mixed-precision callers that
    want f32 P call `chain_matrices` twice instead)."""
    (T1, T2), P = chain_matrices((L1, L2), Lout, ("sh", "sh"), "sh",
                                 pad_lanes=pad_lanes, dtype=dtype)
    return T1, T2, P


@lru_cache(maxsize=None)
def chain_l0(Ls: tuple, entries: tuple = None) -> np.ndarray:
    """C [d_1, ..., d_n] float64: the l = 0 coefficient of an n-way product
    as a multilinear form over the operands,

        s = einsum('...a,...b,...,ab...->...', x_1, ..., x_n, C),

    built by contracting the chain sampling matrices against the l = 0
    projection column of the alias-free product grid — exact.  This is how
    a gate-fused chain obtains its per-row gate scalars *before* dispatch:
    the fused kernels cannot compute the (channel-mixing) gate MLP on the
    blocked product grid, but the scalars only need the product's l = 0
    component, which is this cheap d^n-sized contraction away.  'grid'
    entries index the real-stacked half-grid layout of `chain_sample_grid`.
    """
    Ls = tuple(int(L) for L in Ls)
    Ltot = sum(Ls)
    entries = ("sh",) * len(Ls) if entries is None else tuple(entries)
    Ts = [chain_sample_sh(L, Ltot) if e == "sh" else chain_sample_grid(L, Ltot)
          for L, e in zip(Ls, entries)]
    p0 = chain_project_sh(Ltot, 0)[:, 0]
    letters = "abcdefghij"[: len(Ls)]
    expr = ",".join(c + "z" for c in letters) + ",z->" + letters
    return np.einsum(expr, *Ts, p0, optimize=True)


# --------------------------------------------------------------------------
# S^2 quadrature matrices (Gauss-Legendre x equispaced phi, DESIGN.md §6.5)
# --------------------------------------------------------------------------


@lru_cache(maxsize=None)
def quad_sample_sh(L: int, n_theta: int, n_phi: int) -> np.ndarray:
    """A [(L+1)^2, G]: SH coefficients -> quadrature-grid samples (float64)."""
    return _fx.s2quad_sample_sh(L, n_theta, n_phi)


@lru_cache(maxsize=None)
def quad_project_sh(Lout: int, n_theta: int, n_phi: int) -> np.ndarray:
    """P [G, (Lout+1)^2]: weighted quadrature projection back onto SH."""
    return _fx.s2quad_project_sh(Lout, n_theta, n_phi)


@lru_cache(maxsize=None)
def quad_sample_fourier(L: int, n_theta: int, n_phi: int) -> np.ndarray:
    """M [2*(2L+1)*(L+1), G]: real-stacked half grid -> quadrature samples."""
    return _fx.s2quad_sample_fourier(L, n_theta, n_phi)


@lru_cache(maxsize=None)
def quad_project_fourier(L: int, n_theta: int, n_phi: int) -> np.ndarray:
    """Z [G, 2L+1, L+1] complex128: quadrature samples -> half product grid."""
    return _fx.s2quad_project_fourier(L, n_theta, n_phi)


@lru_cache(maxsize=None)
def gaunt_dense(L1: int, L2: int, Lout: int, dtype: str = "float32") -> np.ndarray:
    """The exact dense real-Gaunt tensor [(L1+1)^2, (L2+1)^2, (Lout+1)^2]."""
    return real_gaunt_tensor(L1, L2, Lout).astype(dtype)


# --------------------------------------------------------------------------
# introspection
# --------------------------------------------------------------------------

_CACHED = (
    _y_raw, _z_raw, y_dense, z_dense, y_packed, z_packed, y_half, z_half,
    pack_index, filter_fourier_col, conv_u_index, cg_11_blocks, fused_matrices,
    chain_matrices, chain_sample_sh, chain_sample_grid, chain_project_sh,
    chain_project_grid, chain_l0, quad_sample_sh, quad_project_sh,
    quad_sample_fourier, quad_project_fourier, gaunt_dense,
)


def cache_stats() -> dict[str, tuple[int, int, int]]:
    """{builder name: (hits, misses, currsize)} over every cached builder."""
    return {f.__name__: (ci.hits, ci.misses, ci.currsize)
            for f in _CACHED for ci in (f.cache_info(),)}


def clear_all() -> None:
    """Drop every cached constant (tests / memory pressure)."""
    for f in _CACHED:
        f.cache_clear()
