"""SH <-> 2D Fourier basis conversion tensors (the paper's Section 3.2).

Forward (`y` coefficients): every real SH S_{l,m}, extended to the torus
double cover of the sphere (theta in [0, 2pi)), is an exactly bandlimited
2D trigonometric polynomial:
    S_{l,m}(t, p) = sum_{|u|<=l, v = +-m} y^{l,m}_{u,v} e^{i(u t + v p)}.
We obtain y *exactly* by sampling the analytic continuation
(sin^m t  poly(cos t)  trig(m p) — our Cartesian SH formula continues
automatically) on an (N x N) grid with N > 2L and taking a 2D FFT.

Backward (`z` coefficients): SH coefficients of a function known by its torus
Fourier series are given by sphere-domain *projection*
    z^{l,m}_{u,v} = int_0^{2pi} int_0^pi e^{i(u t + v p)} S_{l,m} sin t dt dp,
which separates:  psi-integral is a closed-form delta on v = +-m; the
theta-integral  int_0^pi e^{iut} Theta_{l,m}(t) sin t dt  is computed exactly
by expanding Theta sin t in its (finite) theta-Fourier series and using
    int_0^pi e^{int} dt = pi delta_{n,0} + (1-(-1)^n) i/n.

Both tensors are numpy float64/complex128 precompute; `packed` variants expose
the v = +-m block sparsity as stacked per-|m| matmuls (the O(L^3) path; the
dense einsum is the O(L^4)-but-MXU-friendly path); `half` variants exploit the
Hermitian symmetry F[-u,-v] = conj(F[u,v]) of any *real* spherical function's
coefficient grid, storing only the v >= 0 columns (the real-input packed form
— it halves conversion FLOPs and enables the rfft-based spatial convolution,
see `core.gaunt.conv2d_herm`).  The builders here are *pure* — caching lives
in `core.constants`, the engine's single constant-cache module (DESIGN.md
§2.4); only the internal theta-integral memo stays local.

This module also hosts the jax-side *grid ops* used by Fourier-resident
activations (`core.rep.Rep`): centered bandlimit resize and Hermitian
pack/unpack, so a resident tensor can change grid size or storage form
without ever leaving the Fourier basis.
"""
from __future__ import annotations

import math
from functools import lru_cache

import numpy as np

from .irreps import idx, num_coeffs
from .so3 import _legendre_sinm_poly, _sh_norms, real_sph_harm

__all__ = [
    "sh_to_fourier_dense",
    "fourier_to_sh_dense",
    "sh_to_fourier_packed",
    "fourier_to_sh_packed",
    "sh_to_fourier_half",
    "fourier_to_sh_half",
    "grid_resize",
    "grid_resize_half",
    "pack_hermitian",
    "unpack_hermitian",
    "s2quad_size",
    "s2quad_angles",
    "s2quad_exact_degree",
    "s2quad_sample_sh",
    "s2quad_project_sh",
    "s2quad_sample_fourier",
    "s2quad_project_fourier",
]


def _torus_samples(L: int) -> tuple[np.ndarray, int]:
    """Sample all real SH (analytically continued) on an N x N torus grid."""
    N = 2 * L + 2  # > bandlimit 2L+1
    t = 2 * math.pi * np.arange(N) / N
    p = 2 * math.pi * np.arange(N) / N
    tt, pp = np.meshgrid(t, p, indexing="ij")
    # Cartesian continuation: sin t may be negative for t > pi — exactly the
    # torus extension (see module docstring).
    xyz = np.stack(
        [np.sin(tt) * np.cos(pp), np.sin(tt) * np.sin(pp), np.cos(tt)], axis=-1
    )
    S = real_sph_harm(L, xyz.reshape(-1, 3)).reshape(N, N, num_coeffs(L))
    return S, N


def sh_to_fourier_dense(L: int) -> np.ndarray:
    """y[(L+1)^2, 2L+1 (u), 2L+1 (v)] complex128, centered (index L <-> freq 0)."""
    S, N = _torus_samples(L)
    F = np.fft.fft2(S, axes=(0, 1)) / (N * N)
    # h[n] = sum_k c_k e^{+2pi i k n/N}  =>  c_k = fft(h)[k mod N] / N.
    out = np.zeros((num_coeffs(L), 2 * L + 1, 2 * L + 1), dtype=np.complex128)
    for u in range(-L, L + 1):
        for v in range(-L, L + 1):
            out[:, L + u, L + v] = F[u % N, v % N, :]
    out[np.abs(out) < 1e-14] = 0.0
    return out


@lru_cache(maxsize=None)
def _theta_fourier_integrals(L: int, u_max: int) -> np.ndarray:
    """I[l, m, L+u... wait shape] = int_0^pi e^{iut} Theta_{l,m}(t) sin t dt.

    Returns array [L+1, L+1, 2*u_max+1] complex (index u + u_max), valid for
    m <= l.  Exact (finite trig expansion + closed-form integrals).
    """
    # sample h_{l,m}(t) = Theta_{l,m}(t) sin(t), analytically continued, on a
    # circle grid; it is a trig polynomial of degree <= L+1.
    N = 2 * (L + 2) + 1
    t = 2 * math.pi * np.arange(N) / N
    ct, st = np.cos(t), np.sin(t)
    P = _legendre_sinm_poly(L, ct)  # [L+1, L+1, N]
    norms = _sh_norms(L)
    # Theta_{l,m} = norm * P~ * sin^m t ; h = Theta * sin t
    h = np.zeros((L + 1, L + 1, N))
    for l in range(L + 1):
        for m in range(l + 1):
            h[l, m] = norms[l, m] * P[l, m] * st ** m * st
    hk = np.fft.fft(h, axis=-1) / N  # coeff of e^{+ikt} at index k % N
    # E(n) = int_0^pi e^{int} dt
    def E(n: int) -> complex:
        if n == 0:
            return math.pi
        if n % 2 == 0:
            return 0.0
        return 2j / n
    ks = np.arange(-(L + 1), L + 2)
    hk_c = np.zeros((L + 1, L + 1, len(ks)), dtype=np.complex128)
    for i, k in enumerate(ks):
        hk_c[:, :, i] = hk[:, :, k % N]
    out = np.zeros((L + 1, L + 1, 2 * u_max + 1), dtype=np.complex128)
    for ui, u in enumerate(range(-u_max, u_max + 1)):
        Evec = np.array([E(u + k) for k in ks])
        out[:, :, ui] = hk_c @ Evec
    return out


def fourier_to_sh_dense(Lf: int, Lout: int) -> np.ndarray:
    """z[2Lf+1 (u), 2Lf+1 (v), (Lout+1)^2] complex128 (centered u,v).

    x^{(l)}_m = Re( sum_{u,v} F[u, v] z[u, v, idx(l,m)] )  for F the centered
    torus-Fourier coefficient grid of a real spherical function.
    """
    I = _theta_fourier_integrals(Lout, Lf)  # [Lout+1, Lout+1, 2Lf+1]
    z = np.zeros((2 * Lf + 1, 2 * Lf + 1, num_coeffs(Lout)), dtype=np.complex128)
    sq2 = math.sqrt(2.0)
    for l in range(Lout + 1):
        for m in range(0, l + 1):
            if m > Lf:
                continue
            th = I[l, m]  # [2Lf+1] over u
            if m == 0:
                # psi integral of e^{ivp} * 1: 2pi delta_{v,0}
                z[:, Lf + 0, idx(l, 0)] += 2 * math.pi * th
            else:
                # S_{l,m} has sqrt(2) cos(mp): int e^{ivp} sqrt2 cos(mp) dp
                #   = sqrt2 pi (delta_{v,m} + delta_{v,-m})
                z[:, Lf + m, idx(l, m)] += sq2 * math.pi * th
                z[:, Lf - m, idx(l, m)] += sq2 * math.pi * th
                # S_{l,-m} has sqrt(2) sin(mp): int e^{ivp} sqrt2 sin(mp) dp
                #   = sqrt2 i pi (delta_{v,m} - delta_{v,-m})
                z[:, Lf + m, idx(l, -m)] += sq2 * 1j * math.pi * th
                z[:, Lf - m, idx(l, -m)] += -sq2 * 1j * math.pi * th
    z[np.abs(z) < 1e-14] = 0.0
    return z


# --------------------------------------------------------------------------
# packed (block-sparse, O(L^3)) forms
# --------------------------------------------------------------------------


def sh_to_fourier_packed(L: int, y: np.ndarray | None = None) -> tuple[np.ndarray, np.ndarray]:
    """Exploit v = +-m sparsity as per-|m| stacked matmuls.

    Returns (yp, yn):
      yp[mm, l, u] complex: coefficient of e^{i(ut + (+mm) p)} contributed by
        packed input plane; input planes are x packed as
        xp[mm, l] = x[idx(l, +mm)] and xn[mm, l] = x[idx(l, -mm)] (zero-padded
        for l < mm).  Because y^{l,m}_{u,+m} and y^{l,-m}_{u,+m} are related,
        we fold the +-m input planes into complex combination first:
        for mm > 0,  c[mm, l] = xp[mm, l] + i * xn[mm, l]  and the v = +mm
        column of F is  sum_l c[mm, l] * yp[mm, l, u]  with yp the coefficient
        of the *cos* part minus-i times the sin part... (derived numerically
        from the dense tensor — see build below; validated in tests).
      The v = -mm column follows from Hermitian symmetry of real functions:
        F[-u, -v] = conj(F[u, v]).
    """
    y = sh_to_fourier_dense(L) if y is None else y
    n = 2 * L + 1
    # For v = +mm: F[:, L+mm] = sum over inputs i with |m_i| = mm of
    #   x_i * y[i, :, L+mm]. Pack per (mm, sign-plane, l).
    yp = np.zeros((L + 1, 2, L + 1, n), dtype=np.complex128)  # [mm, plane, l, u]
    for mm in range(L + 1):
        for l in range(mm, L + 1):
            yp[mm, 0, l] = y[idx(l, mm), :, L + mm]
            if mm > 0:
                yp[mm, 1, l] = y[idx(l, -mm), :, L + mm]
    # v = -mm columns (only needed to rebuild the full grid; for real inputs
    # they are conj-mirror, but we keep them explicit for generality)
    yn = np.zeros((L + 1, 2, L + 1, n), dtype=np.complex128)
    for mm in range(L + 1):
        for l in range(mm, L + 1):
            yn[mm, 0, l] = y[idx(l, mm), :, L - mm]
            if mm > 0:
                yn[mm, 1, l] = y[idx(l, -mm), :, L - mm]
    return yp, yn


def fourier_to_sh_packed(Lf: int, Lout: int, z: np.ndarray | None = None) -> tuple[np.ndarray, np.ndarray]:
    """Packed z: per-|m| matrices over u for the v=+m and v=-m columns.

    zp[mm, plane, l, u]: x[idx(l, +-mm)] += Re( F[:, Lf+mm] . zp[mm, plane, l] )
    zn likewise for the v = -mm column.
    """
    z = fourier_to_sh_dense(Lf, Lout) if z is None else z
    n = 2 * Lf + 1
    zp = np.zeros((Lout + 1, 2, Lout + 1, n), dtype=np.complex128)
    zn = np.zeros((Lout + 1, 2, Lout + 1, n), dtype=np.complex128)
    for mm in range(min(Lf, Lout) + 1):
        for l in range(mm, Lout + 1):
            zp[mm, 0, l] = z[:, Lf + mm, idx(l, mm)]
            if mm > 0:
                # mm = 0 would duplicate the v=0 column already in zp
                zn[mm, 0, l] = z[:, Lf - mm, idx(l, mm)]
                zp[mm, 1, l] = z[:, Lf + mm, idx(l, -mm)]
                zn[mm, 1, l] = z[:, Lf - mm, idx(l, -mm)]
    return zp, zn


# --------------------------------------------------------------------------
# half (Hermitian, real-input) forms
# --------------------------------------------------------------------------
#
# The torus coefficient grid of a REAL spherical function satisfies
#     F[-u, -v] = conj(F[u, v]),
# so the v >= 0 columns determine the whole grid.  The half form stores
# exactly those columns: Fh[..., u, v] with u centered (2L+1) and v = 0..L.


def sh_to_fourier_half(L: int, y: np.ndarray | None = None) -> np.ndarray:
    """yh[(L+1)^2, 2L+1 (u), L+1 (v >= 0)] — the v >= 0 columns of `y_dense`."""
    y = sh_to_fourier_dense(L) if y is None else y
    return np.ascontiguousarray(y[:, :, L:])


def fourier_to_sh_half(Lf: int, Lout: int, z: np.ndarray | None = None) -> np.ndarray:
    """zh[2Lf+1 (u), Lf+1 (v >= 0), (Lout+1)^2] with the v < 0 columns folded in.

    For Hermitian F,  Re(sum_{u,v} F[u,v] z[u,v,k])
      = Re( sum_u F[u,0] z[u,0,k]
            + sum_{u,v>0} F[u,v] (z[u,v,k] + conj(z[-u,-v,k])) ),
    so  x = Re(einsum('...uv,uvk->...k', Fh, zh))  is exact.
    """
    z = fourier_to_sh_dense(Lf, Lout) if z is None else z
    zh = z[:, Lf:, :].copy()  # columns v = 0..Lf
    # fold conj(z[-u, -v, k]) into the v = 1..Lf columns (u flipped)
    zh[:, 1:, :] += np.conj(z[::-1, Lf - 1 :: -1, :])
    return zh


# --------------------------------------------------------------------------
# jax grid ops for Fourier-resident tensors (basis-preserving reshapes)
# --------------------------------------------------------------------------


def pack_hermitian(F, L: int):
    """Full centered grid [..., 2L+1, 2L+1] -> half form [..., 2L+1, L+1].

    Keeps the v >= 0 columns; valid (lossless) only for grids of *real*
    spherical functions, which is every grid produced by `sh_to_fourier` of
    real SH coefficients and every convolution of such grids.
    """
    return F[..., L:]


def unpack_hermitian(Fh, L: int):
    """Half form [..., 2L+1, L+1] -> full grid via F[-u,-v] = conj(F[u,v])."""
    import jax.numpy as jnp  # local: keep the numpy builders importable sans jax

    neg = jnp.conj(jnp.flip(Fh[..., 1:], axis=(-2, -1)))  # v = -L .. -1
    return jnp.concatenate([neg, Fh], axis=-1)


def grid_resize(F, L_from: int, L_to: int):
    """Centered bandlimit change of a full grid: zero-pad up or truncate down.

    Padding (L_to > L_from) is exact.  Truncation is exact only when the
    resident function is actually bandlimited at L_to — chain exits that need
    a *projection* to lower degrees must go through `fourier_to_sh` instead.
    """
    import jax.numpy as jnp

    d = L_to - L_from
    if d == 0:
        return F
    if d > 0:
        pad = [(0, 0)] * (F.ndim - 2) + [(d, d), (d, d)]
        return jnp.pad(F, pad)
    c = -d
    return F[..., c:-c, c:-c]


def grid_resize_half(Fh, L_from: int, L_to: int):
    """`grid_resize` for half grids: u pads both sides, v pads the far end."""
    import jax.numpy as jnp

    d = L_to - L_from
    if d == 0:
        return Fh
    if d > 0:
        pad = [(0, 0)] * (Fh.ndim - 2) + [(d, d), (0, d)]
        return jnp.pad(Fh, pad)
    c = -d
    return Fh[..., c:-c, : L_to + 1]


# --------------------------------------------------------------------------
# S^2 quadrature: Gauss-Legendre theta nodes x equispaced phi (DESIGN.md §6.5)
# --------------------------------------------------------------------------
#
# Unlike the torus product grid above (which is exact by bandlimit counting
# for *products of bandlimited signals*), general pointwise nonlinearities
# need a true sphere-domain quadrature.  Gauss-Legendre nodes in cos(theta)
# with n_t points integrate polynomials in cos(theta) up to degree 2*n_t - 1
# exactly; the equispaced phi sum with n_p points kills e^{im phi} exactly
# for 0 < |m| < n_p.  A product of real SH with total degree D therefore
# integrates exactly iff  D <= s2quad_exact_degree(n_t, n_p)
#                            = min(2*n_t - 1, n_p - 1).
# Projecting a degree-d integrand onto degrees <= Lout needs d + Lout within
# that bound; `s2quad_size(L, os)` picks (n_t, n_p) = (os*(L+1), 2*os*(L+1))
# so the default oversampling os=2 resolves degree 4L+3 — enough for any
# quadratic gate content at the signal's own bandlimit.


def s2quad_size(L: int, os: int = 2) -> tuple[int, int]:
    """Default (n_theta, n_phi) for a degree-L signal at oversampling ``os``."""
    if os < 1:
        raise ValueError(f"oversampling factor must be >= 1, got {os}")
    nt = os * (L + 1)
    return nt, 2 * nt


def s2quad_angles(n_theta: int, n_phi: int):
    """(theta [n_t], w_theta [n_t], phi [n_p]) — GL nodes/weights x uniform phi.

    w_theta are the Gauss-Legendre weights in x = cos(theta):
    int_0^pi f(theta) sin(theta) dtheta = sum_i w_i f(theta_i) exactly for f
    polynomial of degree <= 2*n_t - 1 in cos(theta).
    """
    x, w = np.polynomial.legendre.leggauss(n_theta)
    return np.arccos(x), w, 2 * math.pi * np.arange(n_phi) / n_phi


def s2quad_exact_degree(n_theta: int, n_phi: int) -> int:
    """Max total SH degree whose sphere integral this quadrature is exact for."""
    return min(2 * n_theta - 1, n_phi - 1)


def _s2quad_xyz(n_theta: int, n_phi: int) -> np.ndarray:
    theta, _, phi = s2quad_angles(n_theta, n_phi)
    tt, pp = np.meshgrid(theta, phi, indexing="ij")
    return np.stack(
        [np.sin(tt) * np.cos(pp), np.sin(tt) * np.sin(pp), np.cos(tt)], axis=-1
    ).reshape(-1, 3)


def s2quad_sample_sh(L: int, n_theta: int, n_phi: int) -> np.ndarray:
    """A [(L+1)^2, G]: real SH evaluated on the quadrature grid (float64).

    ``x @ A`` turns packed SH coefficients into sample values; reshape the
    last axis to [n_theta, n_phi] for the grid layout.
    """
    return real_sph_harm(L, _s2quad_xyz(n_theta, n_phi)).T.copy()


def s2quad_project_sh(Lout: int, n_theta: int, n_phi: int) -> np.ndarray:
    """P [G, (Lout+1)^2]: quadrature projection of sample values onto SH.

    P[g, k] = w_g * Y_k(omega_g) with w_g = w_GL(theta_g) * (2 pi / n_phi);
    by real-SH orthonormality ``V @ P`` recovers the coefficients exactly
    whenever the sampled content's degree + Lout stays within
    `s2quad_exact_degree`.
    """
    _, w, _ = s2quad_angles(n_theta, n_phi)
    S = real_sph_harm(Lout, _s2quad_xyz(n_theta, n_phi))  # [G, dout]
    wg = np.repeat(w, n_phi) * (2 * math.pi / n_phi)
    return S * wg[:, None]


def s2quad_sample_fourier(L: int, n_theta: int, n_phi: int) -> np.ndarray:
    """M [2*(2L+1)*(L+1), G]: Fourier-resident entry onto the quadrature grid.

    A resident Hermitian half grid F [2L+1 (u), L+1 (v >= 0)], stacked as the
    real vector [Re F; Im F], evaluates to its real sphere samples in one
    real matmul — same construction as `constants.chain_sample_grid`, but at
    the quadrature angles (theta in (0, pi) is inside the torus domain, so
    the torus Fourier series evaluates pointwise without extension issues).
    """
    theta, _, phi = s2quad_angles(n_theta, n_phi)
    us = np.arange(-L, L + 1)
    vs = np.arange(0, L + 1)
    Et = np.exp(1j * np.outer(us, theta))      # [2L+1, n_t]
    Ep = np.exp(1j * np.outer(vs, phi))        # [L+1, n_p]
    c = np.where(vs == 0, 1.0, 2.0)
    E = np.einsum("ua,vb,v->uvab", Et, Ep, c).reshape(
        (2 * L + 1) * (L + 1), n_theta * n_phi)
    return np.concatenate([E.real, -E.imag], axis=0)


def s2quad_project_fourier(L: int, n_theta: int, n_phi: int) -> np.ndarray:
    """Z [G, 2L+1, L+1] complex: quadrature samples -> Hermitian half grid.

    The composition quadrature-project-to-SH then SH->Fourier as ONE matrix,
    so a quadrature-resident Rep re-enters the Fourier basis in a single
    transform (and ticks a single conversion counter).  Exact under the same
    degree bound as `s2quad_project_sh` at Lout = L.
    """
    P = s2quad_project_sh(L, n_theta, n_phi)           # [G, (L+1)^2]
    y = sh_to_fourier_half(L)                          # [(L+1)^2, 2L+1, L+1]
    return np.einsum("gk,kuv->guv", P, y)
