"""Basis-tagged representations — Fourier-resident activations (DESIGN.md §6).

The Gaunt pipeline's cost at practical L is dominated by the SH <-> Fourier
conversions, not the 2D convolution.  `Rep` makes the basis a first-class,
persistent property of an activation so consumers (the engine's chain plans,
the models, the serving engine) can keep tensors *resident* in the Fourier
basis across consecutive products and only project back to SH where the
math demands it (per-degree weights, gates, degree-wise channel mixing).

A Rep carries:
  basis : 'sh'      — ``data`` is the packed real irrep vector [..., (L+1)^2]
          'fourier' — ``data`` is the centered torus-coefficient grid
  form  : fourier storage: 'dense' full grid [..., 2L+1, 2L+1] complex, or
          'half' Hermitian (real-input) form [..., 2L+1, L+1] keeping only
          the v >= 0 columns (lossless for real spherical functions)
  L     : the bandlimit (max SH degree / grid bandlimit)

Rep is a jax pytree (``data`` is the single leaf; ``L``/``basis``/``form``
are static), so Reps flow through ``jit``/``grad``/``vmap`` unchanged.

This module also hosts the conversion counters: every ``sh_to_fourier`` /
``fourier_to_sh`` call (see `core.gaunt`) increments them, which is how
tests and benchmarks *prove* that chain plans elide interior round trips
instead of merely claiming to.  ``with conversion_stats(fresh=True) as c:``
scopes a measurement (snapshot/restore semantics, warm chain-jit caches
dropped) so counter-diffing is order-independent.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from . import fourier as _fx
from .irreps import num_coeffs

__all__ = [
    "Rep",
    "ConversionStats",
    "count_conversion",
    "conversion_stats",
    "reset_conversion_stats",
]


# --------------------------------------------------------------------------
# conversion counters (incremented by core.gaunt at call/trace time)
# --------------------------------------------------------------------------

_COUNTS = {"sh_to_fourier": 0, "fourier_to_sh": 0,
           # S^2 quadrature-grid entry/exit transforms (DESIGN.md §6.5) —
           # counted here for the same reason as the Fourier pair: resident-
           # gate elision proofs must see the quadrature round trips a naive
           # grid-gate implementation pays, or they could pass vacuously.
           "sh_to_quad": 0, "quad_to_sh": 0,
           "fourier_to_quad": 0, "quad_to_fourier": 0}


def count_conversion(name: str) -> None:
    """Record one basis conversion (called by `core.gaunt`'s converters)."""
    _COUNTS[name] += 1


class ConversionStats(dict):
    """A snapshot of the conversion counters, and a scoped counting context.

    Read: ``conversion_stats()["sh_to_fourier"]`` (a plain dict snapshot).

    Count: ``with conversion_stats(fresh=True) as c: run()`` — on entry the
    module counters are snapshotted and zeroed; on exit ``c`` holds the
    conversions that ran inside the block, and the module counters are
    restored to snapshot + delta.  Sequential measurements are isolated
    from each other and from earlier leftovers (the bare-global counters
    made counter-diffing tests order-dependent); an OUTER block is
    *inclusive* of any nested block's delta — nesting scopes the inner
    reading, it does not subtract it from the enclosing one.

    ``fresh=True`` additionally drops the engine's cached ``ChainPlan``
    jit dispatches on entry: conversions tick once per eager call or per jit
    *trace*, so a warm ``apply_jit`` cache would report zero for work that
    certainly ran — fresh forces those chains to retrace inside the block.
    (Batched bucket jits cannot be un-traced; count those on fresh operand
    shapes instead.)
    """

    def __init__(self, data, fresh: bool = False):
        super().__init__(data)
        self._fresh = fresh
        self._snap = None

    def __enter__(self) -> "ConversionStats":
        if self._fresh:
            from . import engine as _engine  # lazy: engine imports this module

            for cp in _engine.get_engine()._chains.values():
                cp._jit_cache.clear()
        self._snap = dict(_COUNTS)
        for k in _COUNTS:
            _COUNTS[k] = 0
        self.clear()
        self.update({k: 0 for k in self._snap})
        return self

    def __exit__(self, *exc) -> bool:
        delta = dict(_COUNTS)
        self.clear()
        self.update(delta)
        for k in _COUNTS:
            _COUNTS[k] = self._snap[k] + delta[k]
        return False


def conversion_stats(fresh: bool = False) -> ConversionStats:
    """{'sh_to_fourier': n, 'fourier_to_sh': m} since the last reset —
    and a context manager for scoped, order-independent counting (see
    :class:`ConversionStats`).

    Counts are incremented when the conversion *code path runs* — once per
    eager call, once per jit trace.  To compare two execution strategies,
    count each inside its own ``with conversion_stats(fresh=True)`` block
    (or reset + run on fresh uncached callables, the historical protocol).
    """
    return ConversionStats(_COUNTS, fresh=fresh)


def reset_conversion_stats() -> None:
    for k in _COUNTS:
        _COUNTS[k] = 0


# --------------------------------------------------------------------------
# the Rep type
# --------------------------------------------------------------------------


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class Rep:
    """A degree-L equivariant activation tagged with its current basis.

    basis 'sh' and 'fourier' are as documented in the module docstring;
    basis 'quad' holds real sample values on the S^2 quadrature grid
    (Gauss-Legendre theta x equispaced phi, data [..., n_theta, n_phi],
    form 'grid') — the home of pointwise nonlinearities between ops
    (DESIGN.md §6.5).  Enter with ``to_quad(os)`` from either basis, apply
    value-space functions with ``apply_pointwise``, and leave with
    ``to_sh``/``to_fourier`` (each leg ticks its own conversion counter).

    ``sdtype`` is the SH-side *storage* dtype tag ('float32' | 'bfloat16' |
    'float64', or None = untagged -> float32).  Resident grids are complex
    (complex has no bf16), so the tag is how a bf16 activation remembers its
    storage precision across a Fourier round trip: ``to_sh()`` with no
    explicit ``rdtype`` exits at the tagged dtype (DESIGN.md §3.6).
    """

    data: object
    L: int
    basis: str = "sh"
    form: str = "dense"
    sdtype: str | None = None

    def __post_init__(self):
        if self.basis not in ("sh", "fourier", "quad"):
            raise ValueError(f"unknown basis {self.basis!r}")
        if self.basis == "fourier" and self.form not in ("dense", "half"):
            raise ValueError(f"unknown fourier form {self.form!r}")
        if self.basis == "quad" and self.form != "grid":
            raise ValueError(f"quad basis stores real samples (form='grid'), "
                             f"got form={self.form!r}")

    # -- pytree protocol ---------------------------------------------------

    def tree_flatten(self):
        return (self.data,), (self.L, self.basis, self.form, self.sdtype)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], *aux)

    # -- constructors ------------------------------------------------------

    @staticmethod
    def _tag(x) -> str | None:
        name = jnp.result_type(x).name
        return name if name in ("float32", "bfloat16", "float64") else None

    @classmethod
    def from_sh(cls, x, L: int) -> "Rep":
        if jnp.shape(x)[-1] != num_coeffs(L):
            raise ValueError(
                f"sh data last dim {jnp.shape(x)[-1]} != (L+1)^2 = {num_coeffs(L)}")
        return cls(x, L, "sh", sdtype=cls._tag(x))

    @classmethod
    def from_fourier(cls, F, L: int, form: str = "dense") -> "Rep":
        n = 2 * L + 1
        want = (n, n) if form == "dense" else (n, L + 1)
        if jnp.shape(F)[-2:] != want:
            raise ValueError(
                f"fourier data trailing dims {jnp.shape(F)[-2:]} != {want} "
                f"for L={L}, form={form!r}")
        return cls(F, L, "fourier", form)

    # -- basis / form changes ---------------------------------------------

    def to_fourier(self, conversion: str = "dense", cdtype=None,
                   form: str | None = None) -> "Rep":
        """-> Fourier-resident Rep (a no-op modulo form when already there).

        ``conversion`` is the SH->Fourier realization ('dense' | 'packed' |
        'half'); ``form`` fixes the resident storage (defaults to 'half'
        when conversion='half', else 'dense').  ``cdtype=None`` derives the
        grid dtype from the storage tag: float64 -> complex128 (under x64),
        float32/bfloat16 -> complex64 (complex has no bf16; the tag rides
        along so a later ``to_sh()`` exits back at bf16).
        """
        from . import gaunt as _g  # lazy: gaunt imports this module

        if form is None:
            form = "half" if conversion == "half" else "dense"
        if self.basis == "fourier":
            return self.with_form(form)
        if self.basis == "quad":
            from . import constants as _c

            tag = self.sdtype or self._tag(self.data)
            if cdtype is None:
                cdtype = (jnp.complex128
                          if tag == "float64" and jax.config.jax_enable_x64
                          else jnp.complex64)
            cdtype = jnp.dtype(cdtype)
            rdt = jnp.dtype("float64" if cdtype == jnp.complex128
                            else "float32")
            nt, nph = self.data.shape[-2:]
            Pf = jnp.asarray(_c.quad_project_fourier(self.L, nt, nph), cdtype)
            count_conversion("quad_to_fourier")
            V = self.data.reshape(self.data.shape[:-2] + (-1,)).astype(rdt)
            F = jnp.einsum("...g,guv->...uv", V, Pf)
            return Rep(F, self.L, "fourier", "half", sdtype=tag).with_form(form)
        tag = self.sdtype or self._tag(self.data)
        if cdtype is None:
            cdtype = (jnp.complex128
                      if tag == "float64" and jax.config.jax_enable_x64
                      else jnp.complex64)
        F = _g.sh_to_fourier(self.data, self.L, conversion, jnp.dtype(cdtype))
        got = "half" if conversion == "half" else "dense"
        return Rep(F, self.L, "fourier", got, sdtype=tag).with_form(form)

    def to_sh(self, Lout: int | None = None, rdtype=None) -> "Rep":
        """Project to SH degrees <= Lout (default: this Rep's bandlimit).

        ``rdtype=None`` exits at the carried storage tag (float32 when
        untagged), so bf16 activations round-trip residency at bf16 without
        every call site spelling the dtype.
        """
        from . import gaunt as _g

        rdt = jnp.dtype((self.sdtype or "float32") if rdtype is None else rdtype)
        Lout = self.L if Lout is None else Lout
        if self.basis == "sh":
            if Lout > self.L:
                raise ValueError(f"cannot raise SH degree {self.L} -> {Lout}")
            x = self.data if Lout == self.L else self.data[..., : num_coeffs(Lout)]
            return Rep(x, Lout, "sh", sdtype=self.sdtype)
        if self.basis == "quad":
            from . import constants as _c

            if Lout > self.L:
                raise ValueError(f"cannot raise SH degree {self.L} -> {Lout}")
            nt, nph = self.data.shape[-2:]
            cdt = jnp.dtype("float64" if self.data.dtype == jnp.float64
                            else "float32")
            P = jnp.asarray(_c.quad_project_sh(Lout, nt, nph), cdt)
            count_conversion("quad_to_sh")
            V = self.data.reshape(self.data.shape[:-2] + (-1,))
            x = (V.astype(cdt) @ P).astype(rdt)
            return Rep(x, Lout, "sh", sdtype=self._tag(x))
        conv = "half" if self.form == "half" else "dense"
        x = _g.fourier_to_sh(self.data, self.L, Lout, conv, rdt)
        return Rep(x, Lout, "sh", sdtype=self._tag(x))

    def to_quad(self, os: int = 2, n_theta: int | None = None,
                n_phi: int | None = None) -> "Rep":
        """-> real samples on the S^2 quadrature grid (DESIGN.md §6.5).

        Gauss-Legendre theta nodes x equispaced phi.  The default
        oversampling ``os=2`` sizes the grid exact through degree 4L+3 —
        enough to project a squared degree-2L signal or an affine gate of
        it without aliasing; transcendental nonlinearities alias with an
        error that shrinks as ``os`` grows (measured, not asserted —
        tests/test_quadrature.py).  Explicit ``n_theta``/``n_phi``
        override the sized grid (for aliasing sweeps).
        """
        from . import constants as _c

        nt, nph = _fx.s2quad_size(self.L, os)
        if n_theta is not None:
            nt = int(n_theta)
        if n_phi is not None:
            nph = int(n_phi)
        if self.basis == "quad":
            if self.data.shape[-2:] != (nt, nph):
                raise ValueError(
                    f"quad Rep already on a {tuple(self.data.shape[-2:])} "
                    f"grid; resampling to ({nt}, {nph}) is not supported — "
                    f"exit via to_sh()/to_fourier() first")
            return self
        tag = self.sdtype or self._tag(self.data)
        rdt = jnp.dtype("float64"
                        if tag == "float64" and jax.config.jax_enable_x64
                        else "float32")
        if self.basis == "sh":
            A = jnp.asarray(_c.quad_sample_sh(self.L, nt, nph), rdt)
            count_conversion("sh_to_quad")
            V = self.data.astype(rdt) @ A
        else:
            E = jnp.asarray(_c.quad_sample_fourier(self.L, nt, nph), rdt)
            count_conversion("fourier_to_quad")
            F = self.with_form("half").data
            FR = jnp.concatenate(
                [jnp.real(F).reshape(F.shape[:-2] + (-1,)),
                 jnp.imag(F).reshape(F.shape[:-2] + (-1,))], axis=-1)
            V = FR.astype(rdt) @ E
        V = V.reshape(V.shape[:-1] + (nt, nph))
        return Rep(V, self.L, "quad", "grid", sdtype=tag)

    def apply_pointwise(self, fn) -> "Rep":
        """Apply a value-space function sample-wise (quad Reps only) — the
        point of the quadrature grid: nonlinearities are plain sample maps
        there, with aliasing controlled by the oversampling chosen at entry.
        """
        if self.basis != "quad":
            raise ValueError("apply_pointwise requires a quadrature-grid "
                             "Rep; enter with to_quad() first")
        return dataclasses.replace(self, data=fn(self.data))

    def with_form(self, form: str) -> "Rep":
        """Change fourier storage form (Hermitian pack/unpack — no FLOPs)."""
        if self.basis != "fourier" or form == self.form:
            return self
        if form == "half":
            return Rep(_fx.pack_hermitian(self.data, self.L), self.L,
                       "fourier", "half", sdtype=self.sdtype)
        if form == "dense":
            return Rep(_fx.unpack_hermitian(self.data, self.L), self.L,
                       "fourier", "dense", sdtype=self.sdtype)
        raise ValueError(f"unknown fourier form {form!r}")

    def resize(self, L_new: int) -> "Rep":
        """Change grid bandlimit without leaving the basis (pad is exact;
        truncate assumes the content is bandlimited at ``L_new``)."""
        if self.basis != "fourier":
            raise ValueError("resize is a Fourier-grid op; project SH Reps "
                             "with to_sh(Lout) instead")
        fn = _fx.grid_resize_half if self.form == "half" else _fx.grid_resize
        return Rep(fn(self.data, self.L, L_new), L_new, "fourier", self.form,
                   sdtype=self.sdtype)

    def grid(self, form: str = "dense"):
        """The raw coefficient grid in the requested form (fourier Reps)."""
        if self.basis != "fourier":
            raise ValueError("grid() requires a Fourier-resident Rep")
        return self.with_form(form).data

    # -- conveniences ------------------------------------------------------

    @property
    def is_fourier(self) -> bool:
        return self.basis == "fourier"

    def astype(self, dtype) -> "Rep":
        data = self.data.astype(dtype)
        tag = self._tag(data) if self.basis in ("sh", "quad") else self.sdtype
        return dataclasses.replace(self, data=data, sdtype=tag)

    def __add__(self, other: "Rep") -> "Rep":
        """Linear combination inside one basis (residuals on residents)."""
        if not isinstance(other, Rep):
            return NotImplemented
        if (self.basis, self.L) != (other.basis, other.L):
            raise ValueError(
                f"cannot add Rep(basis={self.basis}, L={self.L}) and "
                f"Rep(basis={other.basis}, L={other.L})")
        o = other.with_form(self.form) if self.basis == "fourier" else other
        return dataclasses.replace(self, data=self.data + o.data)
