"""The Gaunt Tensor Product (paper Section 3.2/3.3) — O(L^3) full products.

Pipeline:  x1, x2  --s2f-->  torus Fourier grids  --2D conv-->  product grid
           --f2s-->  output irreps.

Three interchangeable realizations of each stage (all tested equal):
  conversion: 'dense'  — one einsum with the [(L+1)^2, n, n] tensor
                         (O(L^4) FLOPs but a single MXU-friendly contraction;
                         wins on TPU for L <~ 16, see DESIGN.md §3)
              'packed' — per-|m| stacked matmuls exploiting v = +-m sparsity
                         (the paper's O(L^3) path)
  conv:       'fft'    — zero-padded FFT2 (convolution theorem), O(L^2 log L)
              'direct' — lax.conv_general_dilated banded conv, O(L^4) with a
                         tiny constant; faster for small grids
Also `gaunt_product_numpy` — a complex128 numpy mirror used by exactness
tests, and weight hooks implementing the paper's w_{l1} w_{l2} w_l
reparameterization of Equivariant Feature Interaction.

`GauntTensorProduct` is a thin wrapper over the unified engine
(`core.engine`): its historical (conversion, conv) arguments map onto
registered backends, and all constants come from the `core.constants` cache.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from . import constants as _const
from . import engine as _engine
from . import rep as _rep
from .engine import expand_degree_weights  # noqa: F401 — canonical impl lives there
from .irreps import degree_slices, num_coeffs

__all__ = [
    "GauntTensorProduct",
    "sh_to_fourier",
    "fourier_to_sh",
    "sh_to_fourier_bydeg",
    "conv2d_full",
    "conv2d_herm",
    "gaunt_product_numpy",
    "expand_degree_weights",
]


# --------------------------------------------------------------------------
# stages
# --------------------------------------------------------------------------


def sh_to_fourier(x, L: int, conversion: str = "dense", cdtype=jnp.complex64):
    """x [..., (L+1)^2] real -> centered Fourier grid, complex.

    conversion 'dense'/'packed' -> the full grid [..., 2L+1, 2L+1];
    'half' -> the Hermitian (real-input) half form [..., 2L+1, L+1]
    holding only the v >= 0 columns (see `core.fourier`).
    """
    _rep.count_conversion("sh_to_fourier")
    cd = jnp.dtype(cdtype).name
    if conversion == "dense":
        y = jnp.asarray(_const.y_dense(L, cd))
        return jnp.einsum("...i,iuv->...uv", x.astype(y.dtype), y)
    if conversion == "half":
        yh = jnp.asarray(_const.y_half(L, cd))
        return jnp.einsum("...i,iuv->...uv", x.astype(yh.dtype), yh)
    if conversion == "packed":
        yp, yn = (jnp.asarray(a) for a in _const.y_packed(L, cd))
        gidx, mask = _const.pack_index(L)
        xb = x[..., gidx] * jnp.asarray(mask, dtype=x.dtype)  # [..., 2, L+1, L+1]
        xb = xb.astype(yp.dtype)
        # F columns for v = +mm and v = -mm
        fp = jnp.einsum("...pml,mplu->...mu", xb, yp)  # [..., L+1(mm), 2L+1(u)]
        fn = jnp.einsum("...pml,mplu->...mu", xb, yn)
        # assemble grid over v: [-L..-1] from fn (mm = -v), [0..L] from fp
        neg = jnp.flip(fn[..., 1:, :], axis=-2)  # v = -L .. -1
        grid_v_u = jnp.concatenate([neg, fp], axis=-2)  # [..., 2L+1(v), 2L+1(u)]
        return jnp.swapaxes(grid_v_u, -1, -2)
    raise ValueError(f"unknown conversion {conversion!r}")


def fourier_to_sh(F, Lf: int, Lout: int, conversion: str = "dense", rdtype=jnp.float32):
    """Centered grid -> real irreps [..., (Lout+1)^2].

    conversion 'dense'/'packed' expect the full grid [..., 2Lf+1, 2Lf+1];
    'half' expects the Hermitian half form [..., 2Lf+1, Lf+1].
    """
    _rep.count_conversion("fourier_to_sh")
    cd = F.dtype.name
    if conversion == "dense":
        z = jnp.asarray(_const.z_dense(Lf, Lout, cd))
        return jnp.einsum("...uv,uvk->...k", F, z).real.astype(rdtype)
    if conversion == "half":
        zh = jnp.asarray(_const.z_half(Lf, Lout, cd))
        return jnp.einsum("...uv,uvk->...k", F, zh).real.astype(rdtype)
    if conversion == "packed":
        zp, zn = (jnp.asarray(a) for a in _const.z_packed(Lf, Lout, cd))
        mmax = min(Lf, Lout)
        # columns v = +mm / v = -mm of the grid, mm = 0..Lout (pad if Lf<Lout)
        Fp = jnp.swapaxes(F, -1, -2)[..., Lf : Lf + mmax + 1, :]   # [..., mm, u]
        Fn = jnp.swapaxes(F, -1, -2)[..., Lf - mmax : Lf + 1, :][..., ::-1, :]
        if mmax < Lout:
            pad = [(0, 0)] * (Fp.ndim - 2) + [(0, Lout - mmax), (0, 0)]
            Fp = jnp.pad(Fp, pad)
            Fn = jnp.pad(Fn, pad)
        vals = (
            jnp.einsum("...mu,mplu->...pml", Fp, zp)
            + jnp.einsum("...mu,mplu->...pml", Fn, zn)
        ).real.astype(rdtype)  # [..., 2, Lout+1, Lout+1]
        gidx, mask = _const.pack_index(Lout)
        out = jnp.zeros(F.shape[:-2] + (num_coeffs(Lout),), dtype=rdtype)
        out = out.at[..., gidx.reshape(-1)].add(
            (vals * jnp.asarray(mask, dtype=rdtype)).reshape(vals.shape[:-3] + (-1,))
        )
        return out
    raise ValueError(f"unknown conversion {conversion!r}")


def conv2d_full(F1, F2, method: str = "fft"):
    """Full (linear) 2D convolution of centered coefficient grids.

    F1 [..., n1, n1], F2 [..., n2, n2] -> [..., n1+n2-1, n1+n2-1], centered.
    """
    n1, n2 = F1.shape[-1], F2.shape[-1]
    N = n1 + n2 - 1
    if method == "fft":
        # pad to N (linear conv via circular conv theorem)
        G1 = jnp.fft.fft2(F1, s=(N, N))
        G2 = jnp.fft.fft2(F2, s=(N, N))
        out = jnp.fft.ifft2(G1 * G2)
        return out  # index i <-> u = i - (c1 + c2) with c = (n-1)/2: centered
    if method == "direct":
        # shift-and-add: out[.., i+di, j+dj] += F1[.., i, j] * F2[.., di, dj].
        # n2^2 shifted copies of the (tiny) F1 grid — vectorized adds, no
        # grouped convolution (per-batch-kernel lax.conv is pathological on
        # CPU and maps poorly to the MXU; this form is pure VPU adds).
        terms = []
        for di in range(n2):
            for dj in range(n2):
                shifted = jnp.pad(
                    F1, [(0, 0)] * (F1.ndim - 2) + [(di, n2 - 1 - di), (dj, n2 - 1 - dj)]
                )
                terms.append(shifted * F2[..., di : di + 1, dj : dj + 1])
        return sum(terms)
    raise ValueError(f"unknown conv method {method!r}")


def sh_to_fourier_bydeg(x, L: int, conversion: str = "dense", cdtype=jnp.complex64):
    """Degree-resolved conversion: x [..., (L+1)^2] -> [..., L+1, n, nv].

    Slice l of the result is the grid contribution of degree l alone, so the
    full grid of any per-degree reweighting  w . x  is the cheap combination
    ``einsum('...l,...luv->...uv', w, Fl)`` — ONE conversion serves every
    reweighted variant of the same tensor (chain plans use this to convert a
    shared operand once; see DESIGN.md §6).  Total FLOPs equal one ordinary
    `sh_to_fourier` (the conversion tensor is block-diagonal over l).
    """
    _rep.count_conversion("sh_to_fourier")
    cd = jnp.dtype(cdtype).name
    if conversion == "dense":
        y = _const.y_dense(L, cd)
    elif conversion == "half":
        y = _const.y_half(L, cd)
    else:
        raise ValueError(f"bydeg conversion supports 'dense'|'half', got {conversion!r}")
    yj = jnp.asarray(y)
    parts = [jnp.einsum("...i,iuv->...uv", x[..., sl].astype(yj.dtype), yj[sl])
             for sl in degree_slices(L)]
    return jnp.stack(parts, axis=-3)


def _herm_spatial(Fh, L: int, N: int):
    """Half grid [..., 2L+1, L+1] -> real spatial samples [..., N, N].

    After the (full) inverse transform over u, each row's v-spectrum of a
    real spherical function is Hermitian in v alone, so `irfft2` applies
    directly to the standard-order half spectrum.
    """
    pos = Fh[..., L:, :]   # u = 0..L
    neg = Fh[..., :L, :]   # u = -L..-1  -> rows N-L..N-1
    lead = Fh.shape[:-2]
    mid = jnp.zeros(lead + (N - 2 * L - 1, L + 1), dtype=Fh.dtype)
    G = jnp.concatenate([pos, mid, neg], axis=-2)          # [..., N, L+1]
    G = jnp.pad(G, [(0, 0)] * len(lead) + [(0, 0), (0, N // 2 + 1 - (L + 1))])
    return jnp.fft.irfft2(G, s=(N, N)) * (N * N)


def conv2d_herm(F1h, F2h, method: str = "rfft"):
    """Full 2D convolution of Hermitian *half* grids -> product half grid.

    F1h [..., 2L1+1, L1+1], F2h [..., 2L2+1, L2+1] -> [..., 2Lt+1, Lt+1]
    with Lt = L1+L2.  method='rfft' multiplies the (real) spatial samples on
    an alias-free N x N grid and transforms back with `rfft2` — all-real
    FLOPs and half-size spectra, the real-input analogue of the fft path.
    Any other method unpacks to full grids, runs `conv2d_full`, and repacks.
    """
    L1 = (F1h.shape[-2] - 1) // 2
    L2 = (F2h.shape[-2] - 1) // 2
    Lt = L1 + L2
    if method != "rfft":
        from .fourier import pack_hermitian, unpack_hermitian

        full = conv2d_full(unpack_hermitian(F1h, L1), unpack_hermitian(F2h, L2),
                           method)
        return pack_hermitian(full, Lt)
    N = 2 * Lt + 2  # even and > 2Lt+1: alias-free for the product
    s = _herm_spatial(F1h, L1, N) * _herm_spatial(F2h, L2, N)
    H = jnp.fft.rfft2(s) / (N * N)                       # [..., N, N//2+1]
    return jnp.concatenate([H[..., N - Lt :, : Lt + 1],  # u = -Lt..-1
                            H[..., : Lt + 1, : Lt + 1]], axis=-2)


# --------------------------------------------------------------------------
# the module
# --------------------------------------------------------------------------


class GauntTensorProduct:
    """Full Gaunt tensor product of irreps up to (L1, L2) -> degrees <= Lout.

    Equivariant Feature Interaction (paper §3.3): optional per-degree weights
    w1 [..., L1+1], w2 [..., L2+1], w3 [..., Lout+1] realize the
    w_{l1} w_{l2} w_l reparameterization.

    Thin wrapper over the unified engine.  The historical knobs map onto
    registered backends: (`conversion`='dense', `conv`='fft'|'direct') ->
    the 'fft'/'direct' backends, `conversion`='packed' -> the 'packed'
    backend.  `backend` overrides them directly ('auto' lets the engine's
    cost model / autotuner choose; any registered backend name pins it).
    """

    def __init__(
        self,
        L1: int,
        L2: int,
        Lout: int | None = None,
        conversion: str = "dense",
        conv: str = "auto",
        cdtype=jnp.complex64,
        rdtype=jnp.float32,
        backend: str | None = None,
        batch_hint: int | None = None,
        tune: str = "heuristic",
    ):
        self.L1, self.L2 = L1, L2
        self.Lout = L1 + L2 if Lout is None else Lout
        self.conversion = conversion
        if conv == "auto":
            conv = "rfft" if conversion == "half" else _engine.spectral_default(L1, L2)
        self.conv = conv
        self.cdtype = cdtype
        self.rdtype = rdtype
        dtype = _engine._dtype_str(cdtype)
        options = None
        if backend is None:
            if conversion == "dense":
                backend = self.conv  # 'fft' | 'direct'
            elif conversion == "packed":
                backend, options = "packed", {"conv": self.conv}
            elif conversion == "half":
                backend, options = "rfft", {"conv": self.conv}
            else:
                raise ValueError(f"unknown conversion {conversion!r}")
        elif backend == "auto":
            backend = None  # engine selection
        # plan now: warms the constant caches so jit tracing never runs numpy
        self._plan = _engine.plan(
            L1, L2, self.Lout, kind="pairwise", batch_hint=batch_hint,
            dtype=dtype, backend=backend, options=options, tune=tune,
        )
        self.backend = self._plan.backend

    @property
    def plan(self):
        return self._plan

    def __call__(self, x1, x2, w1=None, w2=None, w3=None):
        out = self._plan.apply(x1, x2, w1, w2, w3)
        return out.astype(self.rdtype)


# --------------------------------------------------------------------------
# numpy mirror (complex128) — exactness oracle for tests
# --------------------------------------------------------------------------


def gaunt_product_numpy(x1: np.ndarray, x2: np.ndarray, L1: int, L2: int, Lout: int | None = None):
    Lout = L1 + L2 if Lout is None else Lout
    y1 = _const._y_raw(L1)
    y2 = _const._y_raw(L2)
    z = _const._z_raw(L1 + L2, Lout)
    F1 = np.einsum("...i,iuv->...uv", x1.astype(np.float64), y1)
    F2 = np.einsum("...i,iuv->...uv", x2.astype(np.float64), y2)
    N = 2 * (L1 + L2) + 1
    G1 = np.fft.fft2(F1, s=(N, N))
    G2 = np.fft.fft2(F2, s=(N, N))
    F3 = np.fft.ifft2(G1 * G2)
    return np.einsum("...uv,uvk->...k", F3, z).real
