"""The Gaunt Tensor Product (paper Section 3.2/3.3) — O(L^3) full products.

Pipeline:  x1, x2  --s2f-->  torus Fourier grids  --2D conv-->  product grid
           --f2s-->  output irreps.

Three interchangeable realizations of each stage (all tested equal):
  conversion: 'dense'  — one einsum with the [(L+1)^2, n, n] tensor
                         (O(L^4) FLOPs but a single MXU-friendly contraction;
                         wins on TPU for L <~ 16, see DESIGN.md §3)
              'packed' — per-|m| stacked matmuls exploiting v = +-m sparsity
                         (the paper's O(L^3) path)
  conv:       'fft'    — zero-padded FFT2 (convolution theorem), O(L^2 log L)
              'direct' — lax.conv_general_dilated banded conv, O(L^4) with a
                         tiny constant; faster for small grids
Also `gaunt_product_numpy` — a complex128 numpy mirror used by exactness
tests, and weight hooks implementing the paper's w_{l1} w_{l2} w_l
reparameterization of Equivariant Feature Interaction.

`GauntTensorProduct` is a thin wrapper over the unified engine
(`core.engine`): its historical (conversion, conv) arguments map onto
registered backends, and all constants come from the `core.constants` cache.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from . import constants as _const
from . import engine as _engine
from .engine import expand_degree_weights  # noqa: F401 — canonical impl lives there
from .irreps import num_coeffs

__all__ = [
    "GauntTensorProduct",
    "sh_to_fourier",
    "fourier_to_sh",
    "conv2d_full",
    "gaunt_product_numpy",
    "expand_degree_weights",
]


# --------------------------------------------------------------------------
# stages
# --------------------------------------------------------------------------


def sh_to_fourier(x, L: int, conversion: str = "dense", cdtype=jnp.complex64):
    """x [..., (L+1)^2] real -> centered Fourier grid [..., 2L+1, 2L+1] complex."""
    cd = jnp.dtype(cdtype).name
    if conversion == "dense":
        y = jnp.asarray(_const.y_dense(L, cd))
        return jnp.einsum("...i,iuv->...uv", x.astype(y.dtype), y)
    if conversion == "packed":
        yp, yn = (jnp.asarray(a) for a in _const.y_packed(L, cd))
        gidx, mask = _const.pack_index(L)
        xb = x[..., gidx] * jnp.asarray(mask, dtype=x.dtype)  # [..., 2, L+1, L+1]
        xb = xb.astype(yp.dtype)
        # F columns for v = +mm and v = -mm
        fp = jnp.einsum("...pml,mplu->...mu", xb, yp)  # [..., L+1(mm), 2L+1(u)]
        fn = jnp.einsum("...pml,mplu->...mu", xb, yn)
        # assemble grid over v: [-L..-1] from fn (mm = -v), [0..L] from fp
        neg = jnp.flip(fn[..., 1:, :], axis=-2)  # v = -L .. -1
        grid_v_u = jnp.concatenate([neg, fp], axis=-2)  # [..., 2L+1(v), 2L+1(u)]
        return jnp.swapaxes(grid_v_u, -1, -2)
    raise ValueError(f"unknown conversion {conversion!r}")


def fourier_to_sh(F, Lf: int, Lout: int, conversion: str = "dense", rdtype=jnp.float32):
    """Centered grid [..., 2Lf+1, 2Lf+1] -> real irreps [..., (Lout+1)^2]."""
    cd = F.dtype.name
    if conversion == "dense":
        z = jnp.asarray(_const.z_dense(Lf, Lout, cd))
        return jnp.einsum("...uv,uvk->...k", F, z).real.astype(rdtype)
    if conversion == "packed":
        zp, zn = (jnp.asarray(a) for a in _const.z_packed(Lf, Lout, cd))
        mmax = min(Lf, Lout)
        # columns v = +mm / v = -mm of the grid, mm = 0..Lout (pad if Lf<Lout)
        Fp = jnp.swapaxes(F, -1, -2)[..., Lf : Lf + mmax + 1, :]   # [..., mm, u]
        Fn = jnp.swapaxes(F, -1, -2)[..., Lf - mmax : Lf + 1, :][..., ::-1, :]
        if mmax < Lout:
            pad = [(0, 0)] * (Fp.ndim - 2) + [(0, Lout - mmax), (0, 0)]
            Fp = jnp.pad(Fp, pad)
            Fn = jnp.pad(Fn, pad)
        vals = (
            jnp.einsum("...mu,mplu->...pml", Fp, zp)
            + jnp.einsum("...mu,mplu->...pml", Fn, zn)
        ).real.astype(rdtype)  # [..., 2, Lout+1, Lout+1]
        gidx, mask = _const.pack_index(Lout)
        out = jnp.zeros(F.shape[:-2] + (num_coeffs(Lout),), dtype=rdtype)
        out = out.at[..., gidx.reshape(-1)].add(
            (vals * jnp.asarray(mask, dtype=rdtype)).reshape(vals.shape[:-3] + (-1,))
        )
        return out
    raise ValueError(f"unknown conversion {conversion!r}")


def conv2d_full(F1, F2, method: str = "fft"):
    """Full (linear) 2D convolution of centered coefficient grids.

    F1 [..., n1, n1], F2 [..., n2, n2] -> [..., n1+n2-1, n1+n2-1], centered.
    """
    n1, n2 = F1.shape[-1], F2.shape[-1]
    N = n1 + n2 - 1
    if method == "fft":
        # pad to N (linear conv via circular conv theorem)
        G1 = jnp.fft.fft2(F1, s=(N, N))
        G2 = jnp.fft.fft2(F2, s=(N, N))
        out = jnp.fft.ifft2(G1 * G2)
        return out  # index i <-> u = i - (c1 + c2) with c = (n-1)/2: centered
    if method == "direct":
        # shift-and-add: out[.., i+di, j+dj] += F1[.., i, j] * F2[.., di, dj].
        # n2^2 shifted copies of the (tiny) F1 grid — vectorized adds, no
        # grouped convolution (per-batch-kernel lax.conv is pathological on
        # CPU and maps poorly to the MXU; this form is pure VPU adds).
        terms = []
        for di in range(n2):
            for dj in range(n2):
                shifted = jnp.pad(
                    F1, [(0, 0)] * (F1.ndim - 2) + [(di, n2 - 1 - di), (dj, n2 - 1 - dj)]
                )
                terms.append(shifted * F2[..., di : di + 1, dj : dj + 1])
        return sum(terms)
    raise ValueError(f"unknown conv method {method!r}")


# --------------------------------------------------------------------------
# the module
# --------------------------------------------------------------------------


class GauntTensorProduct:
    """Full Gaunt tensor product of irreps up to (L1, L2) -> degrees <= Lout.

    Equivariant Feature Interaction (paper §3.3): optional per-degree weights
    w1 [..., L1+1], w2 [..., L2+1], w3 [..., Lout+1] realize the
    w_{l1} w_{l2} w_l reparameterization.

    Thin wrapper over the unified engine.  The historical knobs map onto
    registered backends: (`conversion`='dense', `conv`='fft'|'direct') ->
    the 'fft'/'direct' backends, `conversion`='packed' -> the 'packed'
    backend.  `backend` overrides them directly ('auto' lets the engine's
    cost model / autotuner choose; any registered backend name pins it).
    """

    def __init__(
        self,
        L1: int,
        L2: int,
        Lout: int | None = None,
        conversion: str = "dense",
        conv: str = "auto",
        cdtype=jnp.complex64,
        rdtype=jnp.float32,
        backend: str | None = None,
        batch_hint: int | None = None,
        tune: str = "heuristic",
    ):
        self.L1, self.L2 = L1, L2
        self.Lout = L1 + L2 if Lout is None else Lout
        self.conversion = conversion
        self.conv = ("direct" if max(L1, L2) <= 4 else "fft") if conv == "auto" else conv
        self.cdtype = cdtype
        self.rdtype = rdtype
        dtype = _engine._dtype_str(cdtype)
        options = None
        if backend is None:
            if conversion == "dense":
                backend = self.conv  # 'fft' | 'direct'
            elif conversion == "packed":
                backend, options = "packed", {"conv": self.conv}
            else:
                raise ValueError(f"unknown conversion {conversion!r}")
        elif backend == "auto":
            backend = None  # engine selection
        # plan now: warms the constant caches so jit tracing never runs numpy
        self._plan = _engine.plan(
            L1, L2, self.Lout, kind="pairwise", batch_hint=batch_hint,
            dtype=dtype, backend=backend, options=options, tune=tune,
        )
        self.backend = self._plan.backend

    @property
    def plan(self):
        return self._plan

    def __call__(self, x1, x2, w1=None, w2=None, w3=None):
        out = self._plan.apply(x1, x2, w1, w2, w3)
        return out.astype(self.rdtype)


# --------------------------------------------------------------------------
# numpy mirror (complex128) — exactness oracle for tests
# --------------------------------------------------------------------------


def gaunt_product_numpy(x1: np.ndarray, x2: np.ndarray, L1: int, L2: int, Lout: int | None = None):
    Lout = L1 + L2 if Lout is None else Lout
    y1 = _const._y_raw(L1)
    y2 = _const._y_raw(L2)
    z = _const._z_raw(L1 + L2, Lout)
    F1 = np.einsum("...i,iuv->...uv", x1.astype(np.float64), y1)
    F2 = np.einsum("...i,iuv->...uv", x2.astype(np.float64), y2)
    N = 2 * (L1 + L2) + 1
    G1 = np.fft.fft2(F1, s=(N, N))
    G2 = np.fft.fft2(F2, s=(N, N))
    F3 = np.fft.ifft2(G1 * G2)
    return np.einsum("...uv,uvk->...k", F3, z).real
