"""Clebsch-Gordan tensor products — the paper's O(L^6) baseline (e3nn-style),
plus the dense real-Gaunt einsum that serves as the *oracle* for every fast
Gaunt path in this repo.
"""
from __future__ import annotations

from functools import lru_cache

import jax
import jax.numpy as jnp
import numpy as np

from .irreps import num_coeffs
from .so3 import real_clebsch_gordan_block, real_gaunt_tensor

__all__ = [
    "cg_full_tensor_product",
    "gaunt_einsum_reference",
    "gaunt_dense_tensor_jnp",
]


@lru_cache(maxsize=None)
def _cg_paths(L1: int, L2: int, Lout: int):
    """All (l1, l2, l3) paths with their real CG blocks (numpy)."""
    paths = []
    for l1 in range(L1 + 1):
        for l2 in range(L2 + 1):
            for l3 in range(abs(l1 - l2), min(Lout, l1 + l2) + 1):
                paths.append((l1, l2, l3, real_clebsch_gordan_block(l1, l2, l3)))
    return paths


def cg_full_tensor_product(x1, x2, L1: int, L2: int, Lout: int | None = None, weights=None):
    """e3nn-style full CG tensor product over all (l1,l2)->l3 paths.

    x1: [..., (L1+1)^2], x2: [..., (L2+1)^2] -> [..., (Lout+1)^2].
    weights: optional dict (l1,l2,l3) -> scalar (or [...]-broadcastable).
    This is the baseline the paper benchmarks against (Fig. 1): per-path 3D
    contractions, O(L^6) total.
    """
    Lout = L1 + L2 if Lout is None else Lout
    out = jnp.zeros(x1.shape[:-1] + (num_coeffs(Lout),), dtype=x1.dtype)
    for l1, l2, l3, C in _cg_paths(L1, L2, Lout):
        xa = x1[..., l1 * l1 : (l1 + 1) ** 2]
        xb = x2[..., l2 * l2 : (l2 + 1) ** 2]
        blk = jnp.einsum("...i,...j,ijk->...k", xa, xb, jnp.asarray(C, dtype=x1.dtype))
        if weights is not None:
            blk = blk * weights[(l1, l2, l3)]
        out = out.at[..., l3 * l3 : (l3 + 1) ** 2].add(blk)
    return out


@lru_cache(maxsize=None)
def gaunt_dense_tensor_jnp(L1: int, L2: int, Lout: int, dtype_str: str = "float32"):
    # numpy in the cache (jnp constants must not be created inside traces)
    return real_gaunt_tensor(L1, L2, Lout).astype(dtype_str)


def gaunt_einsum_reference(x1, x2, L1: int, L2: int, Lout: int | None = None):
    """Dense einsum with the exact real Gaunt tensor — the correctness oracle
    (O(L^6) like the CG baseline, different coefficients)."""
    Lout = L1 + L2 if Lout is None else Lout
    G = jnp.asarray(gaunt_dense_tensor_jnp(L1, L2, Lout, str(np.dtype(x1.dtype))))
    return jnp.einsum("...i,...j,ijk->...k", x1, x2, G)
