"""The paper's primary contribution: Gaunt Tensor Products in JAX.

Public API:
    GauntEngine / plan      unified plan/dispatch layer over all backends
    plan_chain / ChainPlan  whole chained products, Fourier-resident interior
    autotune_cache          persistent per-host measured-selection cache
                            (fingerprinted JSON + offline calibrate CLI)
    Rep                     basis-tagged activations (sh | fourier residency)
    GauntTensorProduct      full O(L^3) tensor product (FFT / direct / packed)
    EquivariantConv         x (x) Y(rhat) with the eSCN-sparsity fast path
    manybody_gaunt_product  nu-fold products (divide-and-conquer chain)
    cg_full_tensor_product  the e3nn-style O(L^6) baseline
    gaunt_einsum_reference  dense real-Gaunt oracle
"""
from .cg import cg_full_tensor_product, gaunt_einsum_reference  # noqa: F401
from .conv import EquivariantConv  # noqa: F401
from .engine import (  # noqa: F401
    ChainPlan,
    GauntEngine,
    GauntPlan,
    available_backends,
    get_engine,
    plan,
    plan_chain,
)
from .gaunt import GauntTensorProduct, expand_degree_weights  # noqa: F401
from .irreps import Irreps, num_coeffs  # noqa: F401
from .manybody import manybody_gaunt_product, manybody_selfmix  # noqa: F401
from .rep import Rep, conversion_stats, reset_conversion_stats  # noqa: F401
