"""Irrep metadata and packed-layout utilities.

Features holding all irreps of degree 0..L (one copy each) are packed into a
single vector of dimension (L+1)^2 using the index map  idx(l, m) = l^2 + l + m
with -l <= m <= l.  All core ops operate on arrays whose *last* axis is this
packed irrep axis (leading axes are arbitrary batch/channel dims).
"""
from __future__ import annotations

import dataclasses
from functools import lru_cache

import numpy as np

__all__ = [
    "num_coeffs",
    "idx",
    "lm_of_index",
    "degree_slices",
    "l_array",
    "m_array",
    "Irreps",
]


def num_coeffs(L: int) -> int:
    """Dimension of a packed feature with degrees 0..L."""
    return (L + 1) ** 2


def idx(l: int, m: int) -> int:
    """Flat index of (l, m) in the packed layout."""
    if not (-l <= m <= l):
        raise ValueError(f"invalid order m={m} for degree l={l}")
    return l * l + l + m


@lru_cache(maxsize=None)
def lm_of_index(L: int) -> tuple[np.ndarray, np.ndarray]:
    """Arrays (l_of_idx, m_of_idx), each of shape [(L+1)^2]."""
    ls = np.concatenate([np.full(2 * l + 1, l, dtype=np.int32) for l in range(L + 1)])
    ms = np.concatenate([np.arange(-l, l + 1, dtype=np.int32) for l in range(L + 1)])
    return ls, ms


def l_array(L: int) -> np.ndarray:
    return lm_of_index(L)[0]


def m_array(L: int) -> np.ndarray:
    return lm_of_index(L)[1]


def degree_slices(L: int) -> list[slice]:
    """slice of the packed axis occupied by each degree l = 0..L."""
    return [slice(l * l, (l + 1) * (l + 1)) for l in range(L + 1)]


@dataclasses.dataclass(frozen=True)
class Irreps:
    """A contiguous stack of irreps 0..L with C channels.

    This is deliberately simpler than e3nn's Irreps: the Gaunt tensor product
    operates on 'full' features (every degree present once per channel), which
    is also what SEGNN / MACE / EquiformerV2 style models use in practice.
    Parity is implicit: degree-l components carry spherical-harmonic parity
    (-1)^l (see DESIGN.md — the Gaunt product lives in this subspace).
    """

    L: int
    channels: int = 1

    @property
    def dim(self) -> int:
        return num_coeffs(self.L)

    def empty(self, *lead: int, dtype=np.float32) -> np.ndarray:
        return np.zeros((*lead, self.channels, self.dim), dtype=dtype)

    def slice_of(self, l: int) -> slice:
        if l > self.L:
            raise ValueError(f"degree {l} > max degree {self.L}")
        return slice(l * l, (l + 1) * (l + 1))

    def __str__(self) -> str:  # e3nn-ish display
        return "+".join(f"{self.channels}x{l}" for l in range(self.L + 1))
