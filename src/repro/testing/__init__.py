"""Test-support package: shared random generators, rotation helpers, numpy
reference products (:mod:`repro.testing.oracles`), and the per-precision
tolerance tiers (:mod:`repro.testing.precision`)."""
from .oracles import (  # noqa: F401
    cg_product_oracle,
    gaunt_product_oracle,
    random_angles,
    random_array,
    random_irreps,
    random_unit_vectors,
    rotate_irreps,
    rotation_matrix,
    wigner_D,
)
from .precision import assert_close, tol_for  # noqa: F401

__all__ = [
    "random_array",
    "random_irreps",
    "random_unit_vectors",
    "random_angles",
    "rotation_matrix",
    "wigner_D",
    "rotate_irreps",
    "gaunt_product_oracle",
    "cg_product_oracle",
    "tol_for",
    "assert_close",
]
