"""Test-support package: shared random generators, rotation helpers, and
numpy reference products (see :mod:`repro.testing.oracles`)."""
from .oracles import (  # noqa: F401
    cg_product_oracle,
    gaunt_product_oracle,
    random_angles,
    random_array,
    random_irreps,
    random_unit_vectors,
    rotate_irreps,
    rotation_matrix,
    wigner_D,
)

__all__ = [
    "random_array",
    "random_irreps",
    "random_unit_vectors",
    "random_angles",
    "rotation_matrix",
    "wigner_D",
    "rotate_irreps",
    "gaunt_product_oracle",
    "cg_product_oracle",
]
