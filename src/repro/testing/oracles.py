"""Shared numeric oracles for the test suite (see tests/README.md).

Everything here is numpy/float64 and built on the *exact* SO(3) machinery in
:mod:`repro.core.so3` — no fast path under test is used to verify itself.

* random irreps / direction / rotation generators with explicit seeds
* Wigner-D helpers: packed block-diagonal rotation of irrep features
* reference products: the dense real-Gaunt einsum and the per-path CG fold

Test files import from :mod:`repro.testing` instead of keeping per-file
ad-hoc ``_rand`` helpers.
"""
from __future__ import annotations

import numpy as np

from repro.core.irreps import num_coeffs
from repro.core.so3 import (
    real_clebsch_gordan_block,
    real_gaunt_tensor,
    rotation_matrix_zyz,
    wigner_D_real_packed,
)

__all__ = [
    "random_array",
    "random_irreps",
    "random_unit_vectors",
    "random_angles",
    "rotation_matrix",
    "wigner_D",
    "rotate_irreps",
    "gaunt_product_oracle",
    "cg_product_oracle",
]


# --------------------------------------------------------------------------
# generators
# --------------------------------------------------------------------------


def random_array(shape, seed: int = 0, dtype=np.float32) -> np.ndarray:
    """Standard-normal array with an explicit seed (the generic generator
    behind every test's inputs — weights, grids, features)."""
    rng = np.random.default_rng(seed)
    return rng.normal(size=tuple(shape)).astype(dtype)


def random_irreps(L: int, lead=(), seed: int = 0, dtype=np.float32) -> np.ndarray:
    """Random packed irrep features [..., (L+1)^2] (standard normal)."""
    rng = np.random.default_rng(seed)
    return rng.normal(size=tuple(lead) + (num_coeffs(L),)).astype(dtype)


def random_unit_vectors(lead=(), seed: int = 0, dtype=np.float32) -> np.ndarray:
    """Uniformly distributed unit vectors [..., 3]."""
    rng = np.random.default_rng(seed)
    v = rng.normal(size=tuple(lead) + (3,))
    return (v / np.linalg.norm(v, axis=-1, keepdims=True)).astype(dtype)


def random_angles(seed: int = 0) -> tuple[float, float, float]:
    """Random zyz Euler angles (alpha, gamma in [0, 2pi); beta in (0, pi))."""
    rng = np.random.default_rng(seed)
    return (float(rng.uniform(0, 2 * np.pi)),
            float(rng.uniform(0.05, np.pi - 0.05)),
            float(rng.uniform(0, 2 * np.pi)))


# --------------------------------------------------------------------------
# rotations
# --------------------------------------------------------------------------


def rotation_matrix(angles) -> np.ndarray:
    """R = Rz(alpha) Ry(beta) Rz(gamma) [3, 3]."""
    return rotation_matrix_zyz(*angles)


def wigner_D(L: int, angles, dtype=np.float32) -> np.ndarray:
    """Block-diagonal real Wigner-D over the packed (L+1)^2 layout, chosen so
    that S^l(R r) = D S^l(r) with R = rotation_matrix(angles)."""
    return wigner_D_real_packed(L, *angles).astype(dtype)


def rotate_irreps(x, L: int, angles) -> np.ndarray:
    """Apply the packed Wigner-D of `angles` to the last axis of x."""
    D = wigner_D(L, angles, dtype=np.float64)
    return (np.asarray(x, np.float64) @ D.T).astype(np.asarray(x).dtype)


# --------------------------------------------------------------------------
# reference products
# --------------------------------------------------------------------------


def gaunt_product_oracle(x1, x2, L1: int, L2: int, Lout: int | None = None) -> np.ndarray:
    """Dense float64 einsum with the exact real Gaunt tensor."""
    Lout = L1 + L2 if Lout is None else Lout
    G = real_gaunt_tensor(L1, L2, Lout)
    return np.einsum("...i,...j,ijk->...k",
                     np.asarray(x1, np.float64), np.asarray(x2, np.float64), G)


def cg_product_oracle(x1, x2, L1: int, L2: int, Lout: int | None = None) -> np.ndarray:
    """Per-path Clebsch-Gordan fold (e3nn-style full TP), numpy float64."""
    Lout = L1 + L2 if Lout is None else Lout
    x1 = np.asarray(x1, np.float64)
    x2 = np.asarray(x2, np.float64)
    out = np.zeros(np.broadcast_shapes(x1.shape[:-1], x2.shape[:-1])
                   + (num_coeffs(Lout),))
    for l1 in range(L1 + 1):
        for l2 in range(L2 + 1):
            for l3 in range(abs(l1 - l2), min(Lout, l1 + l2) + 1):
                C = real_clebsch_gordan_block(l1, l2, l3)
                blk = np.einsum("...i,...j,ijk->...k",
                                x1[..., l1 * l1:(l1 + 1) ** 2],
                                x2[..., l2 * l2:(l2 + 1) ** 2], C)
                out[..., l3 * l3:(l3 + 1) ** 2] += blk
    return out
