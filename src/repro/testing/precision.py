"""Shared per-precision tolerance tiers (DESIGN.md §3.6).

One place owns the error budget the test-suite holds the mixed-precision
paths to, so bf16 cases across test_equivariance / test_engine /
test_kernels / test_chain_kernel agree on what "close enough" means
instead of each file inventing an ad-hoc atol.

The tiers come from the storage quantization, not the accumulation:
accumulation is always >= f32 (``preferred_element_type``), so the error a
stage can add is bounded by rounding its *inputs and outputs* to storage —
bf16 has an 8-bit mantissa (eps = 2^-8 ~ 3.9e-3), and the Gaunt pipeline
rounds at ~3 storage boundaries (operand entry, per-stage store, SH exit),
amplified by the conversion/projection conditioning (small for the
lane-padded collocation matrices).  f32 tiers match the historical
suite-wide bounds.
"""
from __future__ import annotations

import numpy as np

__all__ = ["tol_for", "assert_close"]

# relative tolerance per storage dtype x strictness tier:
#   'identity'  — same math, two execution routes (backend-vs-oracle checks)
#   'transform' — a full equivariance transport (rotate -> product -> compare)
#   'loose'     — long chains / grad checks (more storage round trips)
_TOLS = {
    "float32": {"identity": 3e-4, "transform": 5e-4, "loose": 2e-3},
    "bfloat16": {"identity": 5e-2, "transform": 7e-2, "loose": 1.2e-1},
    "float64": {"identity": 1e-10, "transform": 1e-9, "loose": 1e-8},
}


def tol_for(dtype, tier: str = "identity") -> float:
    """The suite-wide relative tolerance for ``dtype`` ('float32' |
    'bfloat16' | 'float64' or a dtype-like) at the given strictness tier."""
    name = dtype if isinstance(dtype, str) else np.dtype(dtype).name
    try:
        return _TOLS[name][tier]
    except KeyError:
        raise ValueError(f"no tolerance tier {tier!r} for dtype {name!r}") from None


def assert_close(got, ref, dtype=None, tier: str = "identity", tol=None):
    """Scale-relative closeness: max|got-ref| <= tol * max(1, max|ref|).

    ``dtype=None`` infers the tier's dtype from ``got``'s own dtype, so
    parameterized tests pass their arrays straight through.
    """
    got = np.asarray(got)
    if tol is None:
        tol = tol_for(got.dtype if dtype is None else dtype, tier)
    got = got.astype(np.float64)
    ref = np.asarray(ref).astype(np.float64)
    scale = max(1.0, float(np.max(np.abs(ref))) if ref.size else 1.0)
    err = float(np.max(np.abs(got - ref))) if ref.size else 0.0
    assert err <= tol * scale, (
        f"max abs err {err:.3e} > {tol:.1e} * scale {scale:.3e}")
