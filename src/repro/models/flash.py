"""Memory-efficient attention with a FlashAttention-style custom VJP.

Why custom_vjp: differentiating a lax.scan online-softmax saves every tile's
residuals (p, exp corrections) — O(Tq x Tk) memory, silently defeating the
chunking (observed: 116 GB temp on a 0.5B train cell).  The flash backward
recomputes tiles from (q, k, v, o, lse): forward saves only O(Tq) statistics.

Grouped-query layout throughout: q [B,Tq,KV,G,hd], k/v [B,Tk,KV,hd].
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30

__all__ = ["flash_attention_grouped"]


def _tile_mask(qi, ki, qc, kc, q_offset):
    qpos = qi * qc + jnp.arange(qc)[:, None] + q_offset
    kpos = ki * kc + jnp.arange(kc)[None, :]
    return kpos <= qpos  # [qc, kc]


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def flash_attention_grouped(q, k, v, causal: bool, q_chunk: int, kv_chunk: int,
                            q_offset: int):
    o, _ = _fwd_impl(q, k, v, causal, q_chunk, kv_chunk, q_offset)
    return o


def _fwd_impl(q, k, v, causal, q_chunk, kv_chunk, q_offset):
    B, Tq, KV, G, hd = q.shape
    Tk = k.shape[1]
    qc, kc = min(q_chunk, Tq), min(kv_chunk, Tk)
    nq, nk = Tq // qc, Tk // kc
    scale = 1.0 / math.sqrt(hd)
    qb = q.reshape(B, nq, qc, KV, G, hd).transpose(1, 0, 3, 4, 2, 5)  # [nq,B,KV,G,qc,hd]
    kb = k.reshape(B, nk, kc, KV, hd).transpose(1, 0, 3, 2, 4)  # [nk,B,KV,kc,hd]
    vb = v.reshape(B, nk, kc, KV, hd).transpose(1, 0, 3, 2, 4)

    def q_step(_, qi_blk):
        qi, qblk = qi_blk

        def kv_step(carry, ki_blk):
            m, l, acc = carry
            ki, kblk, vblk = ki_blk
            s = jnp.einsum("bkgqh,bksh->bkgqs", qblk, kblk).astype(jnp.float32) * scale
            if causal:
                s = jnp.where(_tile_mask(qi, ki, qc, kc, q_offset)[None, None, None],
                              s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            corr = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[..., None])
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bkgqs,bksh->bkgqh", p.astype(qblk.dtype), vblk).astype(jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, KV, G, qc), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KV, G, qc), jnp.float32)
        a0 = jnp.zeros((B, KV, G, qc, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), (jnp.arange(nk), kb, vb))
        l_safe = jnp.maximum(l, 1e-30)
        out = (acc / l_safe[..., None]).astype(q.dtype)
        lse = m + jnp.log(l_safe)
        return None, (out, lse)

    _, (ob, lseb) = jax.lax.scan(q_step, None, (jnp.arange(nq), qb))
    o = ob.transpose(1, 0, 4, 2, 3, 5).reshape(B, Tq, KV, G, hd)
    lse = lseb.transpose(1, 2, 3, 0, 4).reshape(B, KV, G, Tq)
    return o, lse


def _fwd(q, k, v, causal, q_chunk, kv_chunk, q_offset):
    o, lse = _fwd_impl(q, k, v, causal, q_chunk, kv_chunk, q_offset)
    return o, (q, k, v, o, lse)


def _bwd(causal, q_chunk, kv_chunk, q_offset, res, do):
    q, k, v, o, lse = res
    B, Tq, KV, G, hd = q.shape
    Tk = k.shape[1]
    qc, kc = min(q_chunk, Tq), min(kv_chunk, Tk)
    nq, nk = Tq // qc, Tk // kc
    scale = 1.0 / math.sqrt(hd)

    D = jnp.sum(do.astype(jnp.float32) * o.astype(jnp.float32), axis=-1)  # [B,Tq,KV,G]
    qb = q.reshape(B, nq, qc, KV, G, hd).transpose(1, 0, 3, 4, 2, 5)
    dob = do.reshape(B, nq, qc, KV, G, hd).transpose(1, 0, 3, 4, 2, 5)
    Db = D.reshape(B, nq, qc, KV, G).transpose(1, 0, 3, 4, 2)  # [nq,B,KV,G,qc]
    lseb = lse.reshape(B, KV, G, nq, qc).transpose(3, 0, 1, 2, 4)  # [nq,B,KV,G,qc]
    kb = k.reshape(B, nk, kc, KV, hd).transpose(1, 0, 3, 2, 4)
    vb = v.reshape(B, nk, kc, KV, hd).transpose(1, 0, 3, 2, 4)

    def kv_outer(dq_full, ki_blk):
        ki, kblk, vblk = ki_blk

        def q_inner(carry, qi_blk):
            dkj, dvj, dq_full = carry
            qi, qblk, doblk, Dblk, lseblk = qi_blk
            s = jnp.einsum("bkgqh,bksh->bkgqs", qblk, kblk).astype(jnp.float32) * scale
            if causal:
                s = jnp.where(_tile_mask(qi, ki, qc, kc, q_offset)[None, None, None],
                              s, NEG_INF)
            p = jnp.exp(s - lseblk[..., None])  # [B,KV,G,qc,kc]
            dp = jnp.einsum("bkgqh,bksh->bkgqs", doblk.astype(jnp.float32),
                            vblk.astype(jnp.float32))
            ds = p * (dp - Dblk[..., None]) * scale
            dq_blk = jnp.einsum("bkgqs,bksh->bkgqh", ds, kblk.astype(jnp.float32))
            dkj = dkj + jnp.einsum("bkgqs,bkgqh->bksh", ds, qblk.astype(jnp.float32))
            dvj = dvj + jnp.einsum("bkgqs,bkgqh->bksh", p, doblk.astype(jnp.float32))
            dq_full = jax.lax.dynamic_update_slice(
                dq_full,
                (jax.lax.dynamic_slice(
                    dq_full, (0, qi * qc, 0, 0, 0), (B, qc, KV, G, hd))
                 + dq_blk.transpose(0, 3, 1, 2, 4)),
                (0, qi * qc, 0, 0, 0),
            )
            return (dkj, dvj, dq_full), None

        z = jnp.zeros((B, KV, kc, hd), jnp.float32)
        (dkj, dvj, dq_full), _ = jax.lax.scan(
            q_inner, (z, z, dq_full),
            (jnp.arange(nq), qb, dob, Db, lseb))
        return dq_full, (dkj, dvj)

    dq0 = jnp.zeros((B, Tq, KV, G, hd), jnp.float32)
    dq, (dks, dvs) = jax.lax.scan(kv_outer, dq0, (jnp.arange(nk), kb, vb))
    # dks [nk, B, KV, kc, hd] -> [B, Tk, KV, hd]
    dk = dks.transpose(1, 0, 3, 2, 4).reshape(B, Tk, KV, hd)
    dv = dvs.transpose(1, 0, 3, 2, 4).reshape(B, Tk, KV, hd)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


flash_attention_grouped.defvjp(_fwd, _bwd)
