from .api import Model, build_model, count_params, input_specs  # noqa: F401
