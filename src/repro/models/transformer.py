"""Model assembly for all assigned LM families.

Every family is built scan-over-layers (stacked per-layer params, O(1) HLO in
depth — the production pattern that keeps 80-layer/132B compiles tractable)
with optional per-block remat.  Three entry points per model:

    forward(params, batch)                 train/eval logits (+ MoE aux loss)
    prefill(params, batch)                 populate KV/recurrent caches
    decode_step(params, cache, tok, pos)   one token against the cache

Families: dense | moe | vlm (M-RoPE) | ssm (RWKV6) | hybrid (Zamba2) |
encdec (Whisper, stub frontend).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np

from . import ssm as ssm_mod
from .attention import (
    attn_init,
    attn_out,
    attn_project_qkv,
    blockwise_attention,
    decode_attention,
    full_attention,
)
from .layers import (
    dense,
    dense_init,
    embed_init,
    mlp_apply,
    mlp_init,
    norm_apply,
    norm_init,
    rope,
    rope_mrope,
)
from .moe import moe_apply, moe_init
from repro.distributed.sharding import constrain_batch

__all__ = ["init_params", "forward", "prefill", "decode_step", "init_cache"]


def _adt(cfg):
    return jnp.dtype(cfg.dtype)


def _stack_init(key, n, fn):
    return jax.vmap(fn)(jax.random.split(key, n))


# ---------------------------------------------------------------- blocks


def _block_init(key, cfg, cross: bool = False):
    pd = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 6)
    p = {
        "ln1": norm_init(cfg.d_model, cfg.norm, pd),
        "attn": attn_init(ks[0], cfg.d_model, cfg.n_heads, cfg.kv_heads, cfg.hd, cfg.qkv_bias, pd),
        "ln2": norm_init(cfg.d_model, cfg.norm, pd),
    }
    if cross:
        p["ln_x"] = norm_init(cfg.d_model, cfg.norm, pd)
        p["xattn"] = attn_init(ks[1], cfg.d_model, cfg.n_heads, cfg.kv_heads, cfg.hd, False, pd)
    if cfg.family == "moe":
        p["moe"] = moe_init(ks[2], cfg.d_model, cfg.n_experts, cfg.d_ff_expert or cfg.d_ff,
                            cfg.n_shared_experts, cfg.act, pd)
    else:
        p["mlp"] = mlp_init(ks[3], cfg.d_model, cfg.d_ff, cfg.act, pd)
    return p


def _apply_rope(cfg, q, k, positions):
    if cfg.mrope_sections is not None:
        if positions.ndim == 2:  # text-only: t = h = w
            positions = jnp.stack([positions] * 3, axis=-1)
        return (rope_mrope(q, positions, cfg.rope_theta, cfg.mrope_sections),
                rope_mrope(k, positions, cfg.rope_theta, cfg.mrope_sections))
    if cfg.partial_rotary <= 0:
        return q, k
    return (rope(q, positions, cfg.rope_theta, cfg.partial_rotary),
            rope(k, positions, cfg.rope_theta, cfg.partial_rotary))


def _attention_seq(cfg, q, k, v, causal=True):
    T = q.shape[1]
    if T > cfg.attn_chunk:
        return blockwise_attention(q, k, v, causal=causal,
                                   q_chunk=cfg.attn_chunk, kv_chunk=cfg.attn_chunk)
    return full_attention(q, k, v, causal=causal)


def _block_apply(p, x, positions, cfg, causal=True, enc=None):
    """Full-sequence block.  Returns (x, aux)."""
    dt = _adt(cfg)
    h = norm_apply(p["ln1"], x, cfg.norm, one_offset=cfg.rms_one_offset)
    q, k, v = attn_project_qkv(p["attn"], h, cfg.n_heads, cfg.kv_heads, cfg.hd, dt)
    q, k = _apply_rope(cfg, q, k, positions)
    o = _attention_seq(cfg, q, k, v, causal=causal)
    x = x + attn_out(p["attn"], o, dt)
    if enc is not None:  # cross attention (enc-dec)
        h = norm_apply(p["ln_x"], x, cfg.norm)
        qx = dense(p["xattn"]["wq"], h, dt).reshape(*h.shape[:2], cfg.n_heads, cfg.hd)
        kx = dense(p["xattn"]["wk"], enc, dt).reshape(*enc.shape[:2], cfg.kv_heads, cfg.hd)
        vx = dense(p["xattn"]["wv"], enc, dt).reshape(*enc.shape[:2], cfg.kv_heads, cfg.hd)
        ox = _attention_seq(cfg, qx, kx, vx, causal=False)
        x = x + attn_out(p["xattn"], ox, dt)
    h = norm_apply(p["ln2"], x, cfg.norm, one_offset=cfg.rms_one_offset)
    aux = jnp.zeros((), jnp.float32)
    if cfg.family == "moe":
        y, aux = moe_apply(p["moe"], h, cfg.n_experts, cfg.top_k, cfg.capacity_factor,
                           cfg.act, dt)
    else:
        y = mlp_apply(p["mlp"], h, cfg.act, dt)
    return x + y, aux


def _quant_kv(x):
    """[B,KV,hd] -> int8 values + f16 per-head absmax scale."""
    scale = jnp.maximum(jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1), 1e-6) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]), -127, 127)
    return q.astype(jnp.int8), scale.astype(jnp.float16)


def _block_decode(p, cache, x, pos, cfg, enc_kv=None):
    """One-token block against KV cache. cache: {"k","v"[, "*_scale"]}."""
    dt = _adt(cfg)
    B = x.shape[0]
    h = norm_apply(p["ln1"], x, cfg.norm, one_offset=cfg.rms_one_offset)
    q, k, v = attn_project_qkv(p["attn"], h, cfg.n_heads, cfg.kv_heads, cfg.hd, dt)
    q, k = _apply_rope(cfg, q, k, pos[:, None])
    bidx = jnp.arange(B)
    if "k_scale" in cache:
        kq, ks = _quant_kv(k[:, 0])
        vq, vs = _quant_kv(v[:, 0])
        kc8 = cache["k"].at[bidx, pos].set(kq)
        vc8 = cache["v"].at[bidx, pos].set(vq)
        ksc = cache["k_scale"].at[bidx, pos].set(ks)
        vsc = cache["v_scale"].at[bidx, pos].set(vs)
        kc = (kc8.astype(dt) * ksc.astype(dt)[..., None])
        vc = (vc8.astype(dt) * vsc.astype(dt)[..., None])
        new_cache = {"k": kc8, "v": vc8, "k_scale": ksc, "v_scale": vsc}
    else:
        kc = cache["k"].at[bidx, pos].set(k[:, 0])
        vc = cache["v"].at[bidx, pos].set(v[:, 0])
        new_cache = {"k": kc, "v": vc}
    o = decode_attention(q, kc, vc, pos)
    x = x + attn_out(p["attn"], o, dt)
    if enc_kv is not None:
        h = norm_apply(p["ln_x"], x, cfg.norm)
        qx = dense(p["xattn"]["wq"], h, dt).reshape(B, 1, cfg.n_heads, cfg.hd)
        ke, ve = enc_kv
        ox = decode_attention(qx, ke, ve, jnp.full((B,), ke.shape[1] - 1, jnp.int32))
        x = x + attn_out(p["xattn"], ox, dt)
    h = norm_apply(p["ln2"], x, cfg.norm, one_offset=cfg.rms_one_offset)
    if cfg.family == "moe":
        y, _ = moe_apply(p["moe"], h, cfg.n_experts, cfg.top_k, cfg.capacity_factor,
                         cfg.act, dt)
    else:
        y = mlp_apply(p["mlp"], h, cfg.act, dt)
    return x + y, new_cache


# ---------------------------------------------------------------- params


def init_params(key, cfg):
    pd = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 8)
    p = {"embed": embed_init(ks[0], cfg.vocab, cfg.d_model, pd),
         "ln_f": norm_init(cfg.d_model, cfg.norm, pd)}
    if not cfg.tie_embeddings:
        p["unembed"] = dense_init(ks[1], cfg.d_model, cfg.vocab, dtype=pd)
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        p["layers"] = _stack_init(ks[2], cfg.n_layers, lambda k: _block_init(k, cfg))
    elif fam == "ssm":  # rwkv6
        p["layers"] = _stack_init(ks[2], cfg.n_layers,
                                  lambda k: ssm_mod.rwkv6_block_init(k, cfg, pd))
    elif fam == "hybrid":  # zamba2
        n_stages = cfg.n_layers // cfg.attn_every
        p["mamba"] = _stack_init(ks[2], cfg.n_layers,
                                 lambda k: {"ln": norm_init(cfg.d_model, cfg.norm, pd),
                                            "m": ssm_mod.mamba2_init(k, cfg, pd)})
        p["shared"] = _block_init(ks[3], cfg)
        p["cat_proj"] = dense_init(ks[4], 2 * cfg.d_model, cfg.d_model, dtype=pd)
        del n_stages
    elif fam == "encdec":
        p["enc_layers"] = _stack_init(ks[2], cfg.n_enc_layers, lambda k: _block_init(k, cfg))
        p["layers"] = _stack_init(ks[3], cfg.n_layers, lambda k: _block_init(k, cfg, cross=True))
        p["enc_ln_f"] = norm_init(cfg.d_model, cfg.norm, pd)
        p["dec_pos"] = jax.random.normal(ks[5], (cfg.max_seq, cfg.d_model), pd) * 0.01
    else:
        raise ValueError(fam)
    return p


# ---------------------------------------------------------------- forward


def _embed_tokens(p, cfg, tokens):
    h = p["embed"]["embedding"][tokens].astype(_adt(cfg))
    if cfg.embed_scale:
        h = h * math.sqrt(cfg.d_model)
    return h


def _logits(p, cfg, h):
    h = norm_apply(p["ln_f"], h, cfg.norm, one_offset=cfg.rms_one_offset)
    if cfg.tie_embeddings:
        logits = h @ p["embed"]["embedding"].astype(_adt(cfg)).T
    else:
        logits = dense(p["unembed"], h, _adt(cfg))
    if cfg.logit_softcap > 0:
        logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
    return logits.astype(jnp.float32)


def _scan_blocks(layers, x, body, cfg, extra=None):
    """scan over stacked layer params; body(params_l, x) -> (x, aux).

    The block-boundary constrain_batch pins the carried hidden state (and
    therefore the checkpoint-saved residual stack) to the data-parallel axes
    — SPMD otherwise loses batch sharding through flash/MoE internals and
    saves *unsharded* [L, B, S, d] stacks (observed, §Perf H1)."""

    def f(carry, pl_):
        x, aux = carry
        x, a = body(pl_, x)
        return (constrain_batch(x), aux + a), None

    if cfg.remat:
        f = jax.checkpoint(f, prevent_cse=False)
    (x, aux), _ = jax.lax.scan(f, (x, jnp.zeros((), jnp.float32)), layers)
    return x, aux


def _sinusoid_pos(T, d, dtype):
    pos = np.arange(T)[:, None]
    i = np.arange(d // 2)[None, :]
    ang = pos / (10000 ** (2 * i / d))
    emb = np.concatenate([np.sin(ang), np.cos(ang)], axis=-1)
    return jnp.asarray(emb, dtype=dtype)


def _encode(p, cfg, source_embeds):
    """Whisper encoder over precomputed frame embeddings (conv stub)."""
    h = source_embeds.astype(_adt(cfg))
    h = h + _sinusoid_pos(h.shape[1], cfg.d_model, h.dtype)[None]
    pos = jnp.broadcast_to(jnp.arange(h.shape[1])[None], h.shape[:2])
    h, _ = _scan_blocks(
        p["enc_layers"], h,
        lambda pl_, x: _block_apply(pl_, x, pos, cfg, causal=False), cfg)
    return norm_apply(p["enc_ln_f"], h, cfg.norm)


def forward(p, cfg, batch, return_hidden: bool = False):
    """batch: tokens [B,S] (+ positions3 for vlm, source_embeds for encdec,
    embeds override for stub frontends).  Returns (logits, aux) — or
    (hidden, aux) with return_hidden (the chunked-CE path never materializes
    the full [B,S,V] logits)."""
    fam = cfg.family
    tokens = batch["tokens"]
    B, S = tokens.shape
    if "embeds" in batch:
        h = batch["embeds"].astype(_adt(cfg))
    else:
        h = _embed_tokens(p, cfg, tokens)
    positions = batch.get("positions", jnp.broadcast_to(jnp.arange(S)[None], (B, S)))
    if fam == "vlm" and "positions3" in batch:
        positions = batch["positions3"]

    if fam in ("dense", "moe", "vlm"):
        h, aux = _scan_blocks(
            p["layers"], h, lambda pl_, x: _block_apply(pl_, x, positions, cfg), cfg)
    elif fam == "ssm":
        def body(pl_, x):
            return ssm_mod.rwkv6_apply(pl_, x, cfg), jnp.zeros((), jnp.float32)
        h, aux = _scan_blocks(p["layers"], h, body, cfg)
    elif fam == "hybrid":
        e0 = h
        n_stages = cfg.n_layers // cfg.attn_every
        mam = jax.tree.map(
            lambda a: a.reshape(n_stages, cfg.attn_every, *a.shape[1:]), p["mamba"])

        def stage(carry, mam_s):
            x, aux = carry

            def inner(xc, pl_):
                return xc + ssm_mod.mamba2_apply(
                    pl_["m"], norm_apply(pl_["ln"], xc, cfg.norm), cfg), None

            inner_f = jax.checkpoint(inner, prevent_cse=False) if cfg.remat else inner
            x, _ = jax.lax.scan(inner_f, x, mam_s)
            inp = dense(p["cat_proj"], jnp.concatenate([x, e0], axis=-1), _adt(cfg))
            y, a = _block_apply(p["shared"], inp, positions, cfg)
            return (constrain_batch(x + y - inp), aux + a), None  # residual block delta

        (h, aux), _ = jax.lax.scan(stage, (h, jnp.zeros((), jnp.float32)), mam)
    elif fam == "encdec":
        enc = _encode(p, cfg, batch["source_embeds"])
        h = h + p["dec_pos"][:S].astype(h.dtype)[None]

        def body(pl_, x):
            return _block_apply(pl_, x, positions, cfg, causal=True, enc=enc)

        h, aux = _scan_blocks(p["layers"], h, body, cfg)
    else:
        raise ValueError(fam)
    if return_hidden:
        return h, aux
    return _logits(p, cfg, h), aux


def chunked_cross_entropy(p, cfg, h, labels, chunk: int = 256,
                          ignore_id: int = -1):
    """Next-token CE without materializing [B,S,V] logits.

    Scans the sequence in `chunk`-token slices; each slice's logits are
    (re)computed inside a checkpointed body, so both forward and backward
    peak at B x chunk x V — the production LM-head memory fix (§Perf H1).
    """
    hs = h[:, :-1]
    ys = labels[:, 1:]
    B, S, d = hs.shape
    C = min(chunk, S)
    pad = (-S) % C
    if pad:
        hs = jnp.pad(hs, ((0, 0), (0, pad), (0, 0)))
        ys = jnp.pad(ys, ((0, 0), (0, pad)), constant_values=ignore_id)
    n = (S + pad) // C
    hs = hs.reshape(B, n, C, d).transpose(1, 0, 2, 3)
    ys = ys.reshape(B, n, C).transpose(1, 0, 2)

    def body(acc, xs):
        hc, yc = xs
        logits = _logits(p, cfg, hc)  # [B, C, V] fp32
        mask = (yc != ignore_id).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, jnp.maximum(yc, 0)[..., None], axis=-1)[..., 0]
        nll, cnt = acc
        return (nll + jnp.sum((lse - ll) * mask), cnt + jnp.sum(mask)), None

    body = jax.checkpoint(body, prevent_cse=False)
    (nll, cnt), _ = jax.lax.scan(body, (jnp.zeros((), jnp.float32),) * 2, (hs, ys))
    return nll / jnp.maximum(cnt, 1.0)


# ---------------------------------------------------------------- caches


def init_cache(cfg, batch: int, max_len: int):
    dt = _adt(cfg)
    fam = cfg.family
    if fam in ("dense", "moe", "vlm", "encdec"):
        if cfg.kv_cache_dtype == "int8":
            # quantized cache (§Perf H10): int8 values + per-(pos, head) f16
            # absmax scales — halves the decode memory term
            c = {
                "k": jnp.zeros((cfg.n_layers, batch, max_len, cfg.kv_heads, cfg.hd), jnp.int8),
                "v": jnp.zeros((cfg.n_layers, batch, max_len, cfg.kv_heads, cfg.hd), jnp.int8),
                "k_scale": jnp.zeros((cfg.n_layers, batch, max_len, cfg.kv_heads), jnp.float16),
                "v_scale": jnp.zeros((cfg.n_layers, batch, max_len, cfg.kv_heads), jnp.float16),
            }
        else:
            c = {
                "k": jnp.zeros((cfg.n_layers, batch, max_len, cfg.kv_heads, cfg.hd), dt),
                "v": jnp.zeros((cfg.n_layers, batch, max_len, cfg.kv_heads, cfg.hd), dt),
            }
        if fam == "encdec":
            c["xk"] = jnp.zeros((cfg.n_layers, batch, cfg.max_source_len, cfg.kv_heads, cfg.hd), dt)
            c["xv"] = jnp.zeros((cfg.n_layers, batch, cfg.max_source_len, cfg.kv_heads, cfg.hd), dt)
        return c
    if fam == "ssm":
        proto = ssm_mod.rwkv6_state_init(cfg, batch, dt)
        return jax.tree.map(
            lambda a: jnp.zeros((cfg.n_layers, *a.shape), a.dtype), proto)
    if fam == "hybrid":
        n_stages = cfg.n_layers // cfg.attn_every
        proto = ssm_mod.mamba2_state_init(cfg, batch, dt)
        return {
            "mamba": jax.tree.map(
                lambda a: jnp.zeros((cfg.n_layers, *a.shape), a.dtype), proto),
            "k": jnp.zeros((n_stages, batch, max_len, cfg.kv_heads, cfg.hd), dt),
            "v": jnp.zeros((n_stages, batch, max_len, cfg.kv_heads, cfg.hd), dt),
        }
    raise ValueError(fam)


# ---------------------------------------------------------------- decode


def decode_step(p, cfg, cache, tokens, pos):
    """tokens [B,1], pos [B] -> (logits [B,1,V], cache')."""
    fam = cfg.family
    B = tokens.shape[0]
    h = _embed_tokens(p, cfg, tokens)

    if fam in ("dense", "moe", "vlm"):
        def body(x, xs):
            pl_, c = xs
            x, new = _block_decode(pl_, c, x, pos, cfg)
            return x, new

        h, cache = jax.lax.scan(body, h, (p["layers"], cache))
    elif fam == "encdec":
        self_keys = [k for k in cache if not k.startswith("x")]

        def body(x, xs):
            pl_, c, xk, xv = xs
            x, new = _block_decode(pl_, c, x, pos, cfg, enc_kv=(xk, xv))
            return x, new

        h = h + p["dec_pos"][pos][:, None].astype(h.dtype)
        h, new_self = jax.lax.scan(
            body, h, (p["layers"], {k: cache[k] for k in self_keys},
                      cache["xk"], cache["xv"]))
        cache = dict(cache, **new_self)
    elif fam == "ssm":
        def body(x, xs):
            pl_, st = xs
            x, st = ssm_mod.rwkv6_decode_step(pl_, x, st, cfg)
            return x, st

        h, st = jax.lax.scan(body, h, (p["layers"], cache))
        cache = st
    elif fam == "hybrid":
        e0 = h
        n_stages = cfg.n_layers // cfg.attn_every
        mam = jax.tree.map(
            lambda a: a.reshape(n_stages, cfg.attn_every, *a.shape[1:]), p["mamba"])
        mst = jax.tree.map(
            lambda a: a.reshape(n_stages, cfg.attn_every, *a.shape[1:]), cache["mamba"])

        def stage(x, xs):
            mam_s, mst_s, kc, vc = xs

            def inner(xc, xs2):
                pl_, st = xs2
                d, st = ssm_mod.mamba2_decode_step(
                    pl_["m"], norm_apply(pl_["ln"], xc, cfg.norm), st, cfg)
                return xc + d, st

            x, mst_s = jax.lax.scan(inner, x, (mam_s, mst_s))
            inp = dense(p["cat_proj"], jnp.concatenate([x, e0], axis=-1), _adt(cfg))
            y, new = _block_decode(p["shared"], {"k": kc, "v": vc}, inp, pos, cfg)
            return x + y - inp, (mst_s, new["k"], new["v"])

        h, (mst, ks, vs) = jax.lax.scan(stage, h, (mam, mst, cache["k"], cache["v"]))
        cache = {"mamba": jax.tree.map(
            lambda a: a.reshape(cfg.n_layers, *a.shape[2:]), mst), "k": ks, "v": vs}
    else:
        raise ValueError(fam)
    return _logits(p, cfg, h), cache


# ---------------------------------------------------------------- prefill


def prefill(p, cfg, batch, max_len: int):
    """Run the sequence path, returning (last-token logits, populated cache)."""
    fam = cfg.family
    tokens = batch["tokens"]
    B, S = tokens.shape
    cache = init_cache(cfg, B, max_len)
    h = _embed_tokens(p, cfg, tokens)
    positions = batch.get("positions", jnp.broadcast_to(jnp.arange(S)[None], (B, S)))

    if fam in ("dense", "moe", "vlm", "encdec"):
        enc = None
        if fam == "encdec":
            enc = _encode(p, cfg, batch["source_embeds"])
            h = h + p["dec_pos"][:S].astype(h.dtype)[None]
        dt = _adt(cfg)

        def body(x, xs):
            pl_ = xs
            hn = norm_apply(pl_["ln1"], x, cfg.norm, one_offset=cfg.rms_one_offset)
            q, k, v = attn_project_qkv(pl_["attn"], hn, cfg.n_heads, cfg.kv_heads, cfg.hd, dt)
            q, k = _apply_rope(cfg, q, k, positions)
            o = _attention_seq(cfg, q, k, v, causal=True)
            x = x + attn_out(pl_["attn"], o, dt)
            ys = {"k": k, "v": v}
            if fam == "encdec":
                hx = norm_apply(pl_["ln_x"], x, cfg.norm)
                qx = dense(pl_["xattn"]["wq"], hx, dt).reshape(B, S, cfg.n_heads, cfg.hd)
                kx = dense(pl_["xattn"]["wk"], enc, dt).reshape(B, -1, cfg.kv_heads, cfg.hd)
                vx = dense(pl_["xattn"]["wv"], enc, dt).reshape(B, -1, cfg.kv_heads, cfg.hd)
                ox = _attention_seq(cfg, qx, kx, vx, causal=False)
                x = x + attn_out(pl_["xattn"], ox, dt)
                ys["xk"], ys["xv"] = kx, vx
            hn = norm_apply(pl_["ln2"], x, cfg.norm, one_offset=cfg.rms_one_offset)
            if cfg.family == "moe":
                y, _ = moe_apply(pl_["moe"], hn, cfg.n_experts, cfg.top_k,
                                 cfg.capacity_factor, cfg.act, dt)
            else:
                y = mlp_apply(pl_["mlp"], hn, cfg.act, dt)
            return constrain_batch(x + y), ys

        body_f = jax.checkpoint(body, prevent_cse=False) if cfg.remat else body
        h, kvs = jax.lax.scan(body_f, h, p["layers"])
        if "k_scale" in cache:  # int8 cache (§Perf H10)
            kq, ks2 = _quant_kv(kvs["k"])
            vq, vs2 = _quant_kv(kvs["v"])
            cache["k"] = cache["k"].at[:, :, :S].set(kq)
            cache["v"] = cache["v"].at[:, :, :S].set(vq)
            cache["k_scale"] = cache["k_scale"].at[:, :, :S].set(ks2)
            cache["v_scale"] = cache["v_scale"].at[:, :, :S].set(vs2)
        else:
            cache["k"] = cache["k"].at[:, :, :S].set(kvs["k"])
            cache["v"] = cache["v"].at[:, :, :S].set(kvs["v"])
        if fam == "encdec":
            cache["xk"] = kvs["xk"]
            cache["xv"] = kvs["xv"]
    elif fam == "ssm":
        def body(x, pl_):
            hn = norm_apply(pl_["ln1"], x, "layernorm")
            o, tm_state = ssm_mod.rwkv6_time_mix(pl_["tm"], hn, cfg)
            x = x + o
            h2 = norm_apply(pl_["ln2"], x, "layernorm")
            o2, _ = ssm_mod.rwkv6_channel_mix(pl_["cm"], h2)
            st = dict(tm_state, cm_last_x=h2[:, -1])
            return x + o2, st

        body_f = jax.checkpoint(body, prevent_cse=False) if cfg.remat else body
        h, cache = jax.lax.scan(body_f, h, p["layers"])
    elif fam == "hybrid":
        e0 = h
        n_stages = cfg.n_layers // cfg.attn_every
        mam = jax.tree.map(
            lambda a: a.reshape(n_stages, cfg.attn_every, *a.shape[1:]), p["mamba"])
        dt_ = _adt(cfg)

        def stage(x, mam_s):
            def inner(xc, pl_):
                d_in, H, N, G = ssm_mod._m2_dims(cfg)
                hn = norm_apply(pl_["ln"], xc, cfg.norm)
                y = dense(pl_["m"]["in_proj"], hn, dt_)
                z, xcv, Bm, Cm, dtv = ssm_mod._split_in_proj(y, cfg)
                conv_in = jnp.concatenate([xcv, Bm, Cm], axis=-1)
                conv_out = jax.nn.silu(ssm_mod._causal_conv(
                    conv_in, pl_["m"]["conv_w"].astype(dt_), pl_["m"]["conv_b"].astype(dt_)))
                xcv, Bm, Cm = jnp.split(conv_out, [d_in, d_in + G * N], axis=-1)
                dtp = jax.nn.softplus(dtv.astype(jnp.float32) + pl_["m"]["dt_bias"])
                A = -jnp.exp(pl_["m"]["A_log"])
                from repro.kernels.mamba2 import mamba2_ssd_chunked

                ych, hfin = mamba2_ssd_chunked(
                    xcv.reshape(B, S, H, cfg.ssm_headdim), dtp, A,
                    Bm.reshape(B, S, G, N), Cm.reshape(B, S, G, N),
                    pl_["m"]["D"], chunk=min(64, S), return_state=True)
                yc = ych.reshape(B, S, d_in).astype(xc.dtype)
                yc = norm_apply(pl_["m"]["out_norm"], yc * jax.nn.silu(z), "rmsnorm")
                out = dense(pl_["m"]["out_proj"], yc, dt_)
                st = {"conv": conv_in[:, S - (cfg.ssm_conv - 1):], "ssm": hfin}
                return xc + out, st

            x, mstates = jax.lax.scan(inner, x, mam_s)
            inp = dense(p["cat_proj"], jnp.concatenate([x, e0], axis=-1), dt_)
            hn = norm_apply(p["shared"]["ln1"], inp, cfg.norm)
            q, k, v = attn_project_qkv(p["shared"]["attn"], hn, cfg.n_heads,
                                       cfg.kv_heads, cfg.hd, dt_)
            q, k = _apply_rope(cfg, q, k, positions)
            o = _attention_seq(cfg, q, k, v, causal=True)
            y = inp + attn_out(p["shared"]["attn"], o, dt_)
            hn = norm_apply(p["shared"]["ln2"], y, cfg.norm)
            y = y + mlp_apply(p["shared"]["mlp"], hn, cfg.act, dt_)
            return x + y - inp, (mstates, k, v)

        h, (mst, ks, vs) = jax.lax.scan(stage, h, mam)
        cache["mamba"] = jax.tree.map(
            lambda a: a.reshape(cfg.n_layers, *a.shape[2:]), mst)
        cache["k"] = cache["k"].at[:, :, :S].set(ks)
        cache["v"] = cache["v"].at[:, :, :S].set(vs)
    else:
        raise ValueError(fam)
    return _logits(p, cfg, h[:, -1:]), cache
