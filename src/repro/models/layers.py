"""Shared neural layers: norms, rotary embeddings (incl. M-RoPE), MLPs,
embeddings.  Functional style: params are plain dicts (pytrees); every
initializer is deterministic in its PRNG key.  Naming is load-bearing —
`distributed/sharding.py` pattern-matches leaf paths to PartitionSpecs.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "dense_init", "dense",
    "norm_init", "norm_apply",
    "rope", "rope_mrope", "embed_init",
    "mlp_init", "mlp_apply",
]


def dense_init(key, d_in: int, d_out: int, bias: bool = False, scale: float | None = None,
               dtype=jnp.float32):
    std = (1.0 / math.sqrt(d_in)) if scale is None else scale
    p = {"w": jax.random.normal(key, (d_in, d_out), dtype) * std}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense(p, x, dtype=None):
    w = p["w"] if dtype is None else p["w"].astype(dtype)
    y = x @ w
    if "b" in p:
        y = y + (p["b"] if dtype is None else p["b"].astype(dtype))
    return y


def norm_init(d: int, kind: str = "rmsnorm", dtype=jnp.float32):
    if kind == "rmsnorm":
        return {"scale": jnp.ones((d,), dtype)}
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def norm_apply(p, x, kind: str = "rmsnorm", eps: float = 1e-6, one_offset: bool = False):
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + eps)
        s = p["scale"].astype(jnp.float32)
        y = y * (1.0 + s) if one_offset else y * s
    else:
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


def _rope_angles(positions, dim: int, theta: float):
    """positions [...]; returns cos/sin [..., dim/2]."""
    half = dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def rope(x, positions, theta: float = 10000.0, rotary_frac: float = 1.0):
    """x [B, T, H, hd]; positions [B, T].  Half-split (GPT-NeoX style) rotary
    on the first rotary_frac * hd dims."""
    hd = x.shape[-1]
    rot = int(hd * rotary_frac)
    if rot == 0:
        return x
    rot -= rot % 2
    xr, xp = x[..., :rot], x[..., rot:]
    cos, sin = _rope_angles(positions, rot, theta)  # [B,T,rot/2]
    cos, sin = cos[:, :, None, :], sin[:, :, None, :]
    x1, x2 = xr[..., : rot // 2], xr[..., rot // 2 :]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    ).astype(x.dtype)
    return jnp.concatenate([out, xp], axis=-1) if rot < hd else out


def rope_mrope(x, positions3, theta: float, sections: tuple[int, ...]):
    """Qwen2-VL M-RoPE.  x [B,T,H,hd]; positions3 [B,T,3] (t,h,w ids);
    sections: per-axis frequency-section sizes summing to hd/2."""
    hd = x.shape[-1]
    half = hd // 2
    assert sum(sections) == half, (sections, half)
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    # assign each frequency index to a section -> pick that axis' position id
    sec_id = np.repeat(np.arange(len(sections)), sections)  # [half]
    pos = jnp.take_along_axis(
        positions3.astype(jnp.float32),
        jnp.asarray(sec_id)[None, None, :].repeat(positions3.shape[0], 0).repeat(positions3.shape[1], 1),
        axis=-1,
    )  # [B,T,half]
    ang = pos * freqs[None, None, :]
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1).astype(x.dtype)


def embed_init(key, vocab: int, d: int, dtype=jnp.float32):
    return {"embedding": jax.random.normal(key, (vocab, d), dtype) * 0.02}


def mlp_init(key, d: int, ff: int, act: str, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"w_up": dense_init(k1, d, ff, dtype=dtype), "w_down": dense_init(k2, ff, d, dtype=dtype)}
    if act in ("swiglu", "geglu"):
        p["w_gate"] = dense_init(k3, d, ff, dtype=dtype)
    return p


def mlp_apply(p, x, act: str, dtype=None):
    up = dense(p["w_up"], x, dtype)
    if act == "swiglu":
        h = jax.nn.silu(dense(p["w_gate"], x, dtype)) * up
    elif act == "geglu":
        h = jax.nn.gelu(dense(p["w_gate"], x, dtype), approximate=True) * up
    else:  # gelu_mlp
        h = jax.nn.gelu(up, approximate=True)
    return dense(p["w_down"], h, dtype)
