"""Public model API: build_model(cfg) -> Model with init/forward/loss/prefill/
decode_step, plus input_specs() producing ShapeDtypeStruct stand-ins for every
(shape x step) cell — the dry-run contract (no allocation).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig, ShapeConfig

from . import transformer as T

__all__ = ["Model", "build_model", "input_specs", "count_params"]


def softmax_cross_entropy(logits, labels, ignore_id: int = -1):
    """logits [B,S,V] fp32, labels [B,S] int32; mean over non-ignored."""
    mask = (labels != ignore_id).astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    nll = (lse - ll) * mask
    denom = jnp.maximum(mask.sum(), 1.0)
    return nll.sum() / denom


@dataclasses.dataclass
class Model:
    cfg: ModelConfig

    def init(self, key):
        return T.init_params(key, self.cfg)

    def forward(self, params, batch):
        return T.forward(params, self.cfg, batch)

    def loss(self, params, batch):
        # chunked LM-head CE: never materializes [B,S,V] logits (§Perf H1)
        h, aux = T.forward(params, self.cfg, batch, return_hidden=True)
        ce = T.chunked_cross_entropy(params, self.cfg, h, batch["labels"])
        total = ce + self.cfg.router_aux_loss * aux
        return total, {"ce": ce, "aux": aux}

    def prefill(self, params, batch, max_len: int):
        return T.prefill(params, self.cfg, batch, max_len)

    def decode_step(self, params, cache, tokens, pos):
        return T.decode_step(params, self.cfg, cache, tokens, pos)

    def init_cache(self, batch: int, max_len: int):
        return T.init_cache(self.cfg, batch, max_len)


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)


def count_params(cfg: ModelConfig) -> int:
    """Exact parameter count without allocating (eval_shape over init)."""
    m = build_model(cfg)
    tree = jax.eval_shape(lambda: m.init(jax.random.PRNGKey(0)))
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(tree))


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict[str, Any]:
    """ShapeDtypeStruct stand-ins for the step function of `shape.kind`.

    train   -> {"tokens", "labels", (+family extras)}
    prefill -> {"tokens", (+family extras)}
    decode  -> {"cache", "tokens" [B,1], "pos" [B]}
    """
    B, S = shape.global_batch, shape.seq_len
    fam = cfg.family
    if shape.kind in ("train", "prefill"):
        d = {"tokens": _sds((B, S), jnp.int32)}
        if shape.kind == "train":
            d["labels"] = _sds((B, S), jnp.int32)
        if fam == "vlm":
            d["positions3"] = _sds((B, S, 3), jnp.int32)
        if fam == "encdec":
            d["source_embeds"] = _sds((B, cfg.max_source_len, cfg.d_model), jnp.float32)
        return d
    # decode: one new token against a cache of length S
    cache = jax.eval_shape(lambda: T.init_cache(cfg, B, S))
    d = {
        "cache": cache,
        "tokens": _sds((B, 1), jnp.int32),
        "pos": _sds((B,), jnp.int32),
    }
    return d
