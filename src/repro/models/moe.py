"""Mixture-of-Experts: top-k router + sort-based capacity dispatch.

Scalable formulation (no [T, E, C] one-hot): routing, sorting and capacity are
**per batch row** (GShard-style groups) so every dispatch tensor keeps the
batch dim leading and shards over ('pod','data') like the activations — no
global argsort / all-gather at scale.  Per row: flatten (token, choice)
pairs, stable-sort by expert, rank within expert from segment starts, drop
beyond static capacity C = ceil(S k / E * cf), scatter to [E, C, d] expert
batches, one batched expert einsum (expert dim EP-shardable over 'model'),
weighted scatter-add back.  Matches the dense reference exactly for undropped
tokens (tested).  Shared experts (Qwen-MoE) are a gated dense branch.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain_batch, constrain_ep_weights

from .layers import dense, dense_init

__all__ = ["moe_init", "moe_apply", "moe_capacity"]


def moe_capacity(T: int, E: int, k: int, cf: float) -> int:
    c = int(math.ceil(T * k / E * cf))
    return max(8, ((c + 7) // 8) * 8)


def moe_init(key, d: int, E: int, ff: int, n_shared: int, act: str, dtype=jnp.float32):
    ks = jax.random.split(key, 6)
    std = 1.0 / math.sqrt(d)
    p = {
        "router": dense_init(ks[0], d, E, dtype=jnp.float32),
        "we_gate": jax.random.normal(ks[1], (E, d, ff), dtype) * std,
        "we_up": jax.random.normal(ks[2], (E, d, ff), dtype) * std,
        "we_down": jax.random.normal(ks[3], (E, ff, d), dtype) / math.sqrt(ff),
    }
    if n_shared:
        p["shared"] = {
            "w_gate": dense_init(ks[4], d, ff * n_shared, dtype=dtype),
            "w_up": dense_init(ks[4], d, ff * n_shared, dtype=dtype),
            "w_down": dense_init(ks[5], ff * n_shared, d, dtype=dtype),
            "w_shared_gate": dense_init(ks[5], d, 1, dtype=dtype),
        }
    return p


def moe_apply(p, x, E: int, k: int, cf: float, act: str = "swiglu", dtype=None):
    """x [B, T, d] -> (y [B, T, d], aux_loss scalar).  Per-row dispatch."""
    B, T, d = x.shape
    C = moe_capacity(T, E, k, cf)
    N = T * k

    logits = (x.astype(jnp.float32) @ p["router"]["w"])  # [B,T,E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)  # [B,T,k]
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # load-balancing aux (Switch): E * sum_e f_e P_e, averaged over rows
    ce = jnp.mean(jnp.sum(jax.nn.one_hot(gate_idx, E, dtype=jnp.float32), axis=2), axis=1)
    pe = jnp.mean(probs, axis=1)
    aux = jnp.mean(E * jnp.sum(ce / k * pe, axis=-1))

    flat_e = gate_idx.reshape(B, N)
    flat_g = gate_vals.reshape(B, N)
    flat_tok = jnp.broadcast_to(jnp.repeat(jnp.arange(T), k)[None], (B, N))
    order = jnp.argsort(flat_e, axis=-1, stable=True)
    inv_order = jnp.argsort(order, axis=-1, stable=True)  # entry -> sorted pos
    se = jnp.take_along_axis(flat_e, order, axis=-1)
    sg = jnp.take_along_axis(flat_g, order, axis=-1)
    stok = jnp.take_along_axis(flat_tok, order, axis=-1)
    # segment starts per expert via sorted-order comparison (no bincount)
    starts = jnp.sum(se[:, :, None] < jnp.arange(E)[None, None, :], axis=1)  # [B,E]
    rank = jnp.arange(N)[None, :] - jnp.take_along_axis(starts, se, axis=-1)
    keep = rank < C
    slot = jnp.where(keep, se * C + rank, E * C)  # E*C = drop bin

    # gather/scatter-free dispatch: every float tensor moves through batched
    # take_along_axis (gather with batch dims — GSPMD partitions these along
    # batch; 2D-index scatters do NOT partition and replicate the full batch,
    # observed as 580 GB/device on dbrx).  The only scatter left is an int32
    # slot->entry inverse map.
    entry_of_slot = jnp.full((B, E * C + 1), N, jnp.int32).at[
        jnp.arange(B)[:, None], slot].set(
        jnp.where(keep, jnp.arange(N)[None, :], N).astype(jnp.int32),
        mode="drop")
    xg = constrain_batch(
        jnp.take_along_axis(x, stok[..., None], axis=1))  # [B,N,d] sorted entries
    xg_pad = jnp.concatenate([xg, jnp.zeros((B, 1, d), x.dtype)], axis=1)
    xe = jnp.take_along_axis(xg_pad, entry_of_slot[:, : E * C, None], axis=1)
    xe = constrain_batch(xe.reshape(B, E, C, d), "model")
    # batched experts (EP-shardable einsums over the E dim)
    wg = p["we_gate"] if dtype is None else p["we_gate"].astype(dtype)
    wu = p["we_up"] if dtype is None else p["we_up"].astype(dtype)
    wd = p["we_down"] if dtype is None else p["we_down"].astype(dtype)
    # compute-form pin: gather FSDP weight shards (weight-sized collective)
    # instead of letting SPMD reshard the dispatch activations (H6)
    wg, wu, wd = (constrain_ep_weights(w) for w in (wg, wu, wd))
    g = constrain_batch(jnp.einsum("becd,edf->becf", xe, wg), "model")
    u = constrain_batch(jnp.einsum("becd,edf->becf", xe, wu), "model")
    h = (jax.nn.silu(g) if act == "swiglu" else jax.nn.gelu(g, approximate=True)) * u
    out = constrain_batch(
        jnp.einsum("becf,efd->becd", h, wd), "model").reshape(B, E * C, d)
    out = jnp.concatenate([out, jnp.zeros((B, 1, d), out.dtype)], axis=1)

    out_ent = jnp.take_along_axis(out, slot[..., None], axis=1)  # [B,N,d] sorted
    contrib = out_ent * jnp.where(keep, sg, 0.0)[..., None].astype(out.dtype)
    # un-sort back to (token, choice) order and reduce over choices — no scatter
    contrib = constrain_batch(
        jnp.take_along_axis(contrib, inv_order[..., None], axis=1))
    y = constrain_batch(contrib.reshape(B, T, k, d).sum(axis=2))

    if "shared" in p:
        sp = p["shared"]
        hs = jax.nn.silu(dense(sp["w_gate"], x, dtype)) * dense(sp["w_up"], x, dtype)
        ys = dense(sp["w_down"], hs, dtype)
        ys = ys * jax.nn.sigmoid(dense(sp["w_shared_gate"], x, dtype))
        y = y + ys
    return y.astype(x.dtype), aux


def moe_dense_reference(p, x, E: int, k: int, act: str = "swiglu"):
    """O(E) dense reference (no dropping): oracle for tests."""
    B, T, d = x.shape
    xt = x.reshape(B * T, d)
    logits = xt.astype(jnp.float32) @ p["router"]["w"]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)
    gates = jnp.zeros((xt.shape[0], E), probs.dtype)
    gates = gates.at[jnp.arange(xt.shape[0])[:, None], gate_idx].set(gate_vals)
    g = jnp.einsum("td,edf->tef", xt, p["we_gate"])
    u = jnp.einsum("td,edf->tef", xt, p["we_up"])
    h = (jax.nn.silu(g) if act == "swiglu" else jax.nn.gelu(g, approximate=True)) * u
    out = jnp.einsum("tef,efd->ted", h, p["we_down"])
    y = jnp.einsum("te,ted->td", gates.astype(out.dtype), out)
    if "shared" in p:
        sp = p["shared"]
        hs = jax.nn.silu(dense(sp["w_gate"], xt)) * dense(sp["w_up"], xt)
        ys = dense(sp["w_down"], hs) * jax.nn.sigmoid(dense(sp["w_shared_gate"], xt))
        y = y + ys
    return y.reshape(B, T, d)
