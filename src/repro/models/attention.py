"""Attention: GQA/MQA with flash-style blockwise computation (pure JAX online
softmax — memory O(block^2) instead of O(T^2), the TPU-production pattern for
long context), plus single-token decode against a KV cache.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .layers import dense, dense_init

__all__ = ["attn_init", "attn_project_qkv", "full_attention", "blockwise_attention",
           "decode_attention", "attn_out"]

NEG_INF = -1e30


def attn_init(key, d: int, n_heads: int, kv_heads: int, hd: int, bias: bool, dtype=jnp.float32):
    kq, kk, kv, ko = jax.random.split(key, 4)
    return {
        "wq": dense_init(kq, d, n_heads * hd, bias, dtype=dtype),
        "wk": dense_init(kk, d, kv_heads * hd, bias, dtype=dtype),
        "wv": dense_init(kv, d, kv_heads * hd, bias, dtype=dtype),
        "wo": dense_init(ko, n_heads * hd, d, dtype=dtype),
    }


def attn_project_qkv(p, x, n_heads: int, kv_heads: int, hd: int, dtype=None):
    B, T = x.shape[:2]
    q = dense(p["wq"], x, dtype).reshape(B, T, n_heads, hd)
    k = dense(p["wk"], x, dtype).reshape(B, T, kv_heads, hd)
    v = dense(p["wv"], x, dtype).reshape(B, T, kv_heads, hd)
    return q, k, v


def attn_out(p, o, dtype=None):
    B, T = o.shape[:2]
    return dense(p["wo"], o.reshape(B, T, -1), dtype)


def _group(q, kv_heads):
    """[B,T,H,hd] -> [B,T,KV,G,hd] for GQA einsums."""
    B, T, H, hd = q.shape
    return q.reshape(B, T, kv_heads, H // kv_heads, hd)


def full_attention(q, k, v, causal: bool = True, q_offset: int = 0):
    """Materialized-scores attention (small T).  q [B,Tq,H,hd], k/v [B,Tk,KV,hd]."""
    B, Tq, H, hd = q.shape
    Tk, KV = k.shape[1], k.shape[2]
    qg = _group(q, KV)
    s = jnp.einsum("btkgh,bskh->bkgts", qg, k).astype(jnp.float32) / math.sqrt(hd)
    if causal:
        qi = jnp.arange(Tq)[:, None] + q_offset
        ki = jnp.arange(Tk)[None, :]
        s = jnp.where((ki <= qi)[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    o = jnp.einsum("bkgts,bskh->btkgh", p, v)
    return o.reshape(B, Tq, H, hd)


def blockwise_attention(q, k, v, causal: bool = True, q_chunk: int = 1024, kv_chunk: int = 1024,
                        q_offset: int = 0):
    """Flash-style attention with a custom flash backward (models/flash.py):
    O(tile) memory in forward AND backward (a naive scan-AD saves every tile's
    residuals — observed 116 GB temp on a 0.5B train cell); numerically
    identical to full_attention (tested)."""
    from .flash import flash_attention_grouped

    B, Tq, H, hd = q.shape
    Tk, KV = k.shape[1], k.shape[2]
    qc = min(q_chunk, Tq)
    kc = min(kv_chunk, Tk)
    if Tq % qc or Tk % kc:
        return full_attention(q, k, v, causal, q_offset)
    G = H // KV
    qg = q.reshape(B, Tq, KV, G, hd)
    o = flash_attention_grouped(qg, k, v, causal, qc, kc, q_offset)
    return o.reshape(B, Tq, H, hd)


def decode_attention(q, k_cache, v_cache, pos):
    """Single-token decode.  q [B,1,H,hd]; caches [B,S,KV,hd]; pos [B] = index
    of the new token (cache already updated at pos)."""
    B, _, H, hd = q.shape
    S, KV = k_cache.shape[1], k_cache.shape[2]
    qg = _group(q, KV)[:, 0]  # [B,KV,G,hd]
    s = jnp.einsum("bkgh,bskh->bkgs", qg, k_cache).astype(jnp.float32) / math.sqrt(hd)
    valid = jnp.arange(S)[None, :] <= pos[:, None]  # [B,S]
    s = jnp.where(valid[:, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    o = jnp.einsum("bkgs,bskh->bkgh", p, v_cache)
    return o.reshape(B, 1, H, hd)
