"""The paper's model family — equivariant networks built on the Gaunt ops.

Three models mirroring the paper's experiments:
  * MACE-like force field (Table 2 / 3BPA): equivariant convolution message
    passing + many-body Gaunt self-products, energy readout, forces = -dE/dr.
  * SEGNN-like N-body net (Fig. 1 sanity check): steerable message passing;
    `tp_impl` switches Gaunt vs Clebsch-Gordan parameterization.
  * EquiformerV2-like Selfmix layer (Table 1): the Equivariant Feature
    Interaction the paper adds to EquiformerV2.

Feature layout: x [n_nodes, C, (L+1)^2] (channel-wise products, paper §3.3).
All graph ops are dense masked pairwise (the synthetic molecular/N-body
systems are small); radial weights follow h = MLP(radial basis of |r|).
"""
from __future__ import annotations

import dataclasses
import math
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.gaunt_ff import EquivariantConfig
from repro.core.cg import cg_full_tensor_product
from repro.core.conv import EquivariantConv
from repro.core.gaunt import expand_degree_weights
from repro.core.irreps import l_array, num_coeffs
from repro.core.manybody import manybody_selfmix
from repro.core.so3 import real_sph_harm_jax

__all__ = ["EquivariantConfig", "MaceGaunt", "SegnnNBody", "SelfmixLayer"]


def equi_linear_init(key, L, c_in, c_out):
    return jax.random.normal(key, (L + 1, c_in, c_out)) / math.sqrt(c_in)


def equi_linear(w, x, L):
    """Degree-wise channel mixing: x [..., C, (L+1)^2] @ w [L+1, C, C']."""
    wl = w[jnp.asarray(l_array(L).astype(np.int32))]  # [(L+1)^2, C, C']
    return jnp.einsum("...ck,kcd->...dk", x, wl)


def gate_init(key, c, hidden=32):
    k1, k2 = jax.random.split(key)
    return {"w1": jax.random.normal(k1, (c, hidden)) / math.sqrt(c),
            "w2": jax.random.normal(k2, (hidden, c)) / math.sqrt(hidden)}


def gate_apply(p, x, L):
    """Scalars gate higher degrees (equivariant nonlinearity)."""
    s = x[..., :, 0]  # l=0 channel scalars [n, C]
    g = jax.nn.sigmoid(jax.nn.silu(s @ p["w1"]) @ p["w2"])  # [n, C]
    scal = jax.nn.silu(s)
    rest = x[..., 1:] * g[..., None]
    return jnp.concatenate([scal[..., None], rest], axis=-1)


def _gate_quad(p, x, L, os: int = 2):
    """`gate_apply` evaluated on the S^2 quadrature grid (DESIGN.md §6.5).

    The gate is affine in the signal once its scalars are known — f ->
    g*f + beta*Y00 with (g, beta) functions of the l=0 channel scalars
    only — so the grid evaluation is exact at any quadrature order (an
    affine map does not raise the bandlimit); oversampling matters for
    nonlinearities applied to the *samples* themselves.  Ticks the
    sh_to_quad / quad_to_sh conversion counters: this is the Rep-level
    grid-resident gate, used where no chain is adjacent to absorb the
    gate as a fused pointwise stage (SEGNN's post-mix gate).
    """
    from repro.core.engine import _GATE_C0, _gate_coeffs
    from repro.core.rep import Rep

    s = x[..., :, 0]
    g, beta = _gate_coeffs(p, s)
    rep = Rep.from_sh(x, L).to_quad(os=os)
    gated = rep.apply_pointwise(
        lambda v: v * g[..., None, None].astype(v.dtype)
        + (beta * _GATE_C0)[..., None, None].astype(v.dtype))
    return gated.to_sh(L).data.astype(x.dtype)


def _resolve_grid_gate(cfg, Ls, Lout, batch_hint=None, share_hint=None) -> bool:
    """Resolve ``cfg.grid_gate`` to a concrete on/off for one gated chain
    workload.  'auto' consults the engine's measured gate policy
    (`engine.select_gate`, keyed like chain plans) and requires
    chain_tune='measure' — an unmeasured 'auto' stays off.  NOTE for MACE
    grid_gate is a *parameterization* choice (gate-before-mb_mix): fix it
    per checkpoint; the measured 'auto' policy is per-host but persists
    via the autotune cache, and serve warmup() seeds it."""
    mode = getattr(cfg, "grid_gate", "off")
    if mode in ("off", None, False):
        return False
    if mode in ("on", "grid", True):
        return True
    if mode != "auto":
        raise ValueError(f"unknown grid_gate {mode!r}")
    if getattr(cfg, "chain_tune", "heuristic") != "measure":
        return False
    from repro.core import engine as _engine

    return _engine.get_engine().select_gate(
        Ls, Lout, dtype=_model_dtype(cfg), batch_hint=batch_hint,
        entry_hint=("sh",) * len(Ls), share_hint=share_hint) == "grid"


def radial_basis(r, n: int, cutoff: float):
    """Bessel-like radial basis with smooth cutoff envelope. r [...]."""
    rs = jnp.clip(r, 1e-4, None)
    k = jnp.arange(1, n + 1) * math.pi / cutoff
    rb = jnp.sin(k * rs[..., None]) / rs[..., None]
    env = jnp.where(r < cutoff, 0.5 * (jnp.cos(math.pi * r / cutoff) + 1.0), 0.0)
    return rb * env[..., None]


def _pair_geometry(pos, cutoff):
    """Dense pairwise edges with cutoff mask.  pos [n,3].

    Masked pairs (self-pairs / beyond cutoff) get a *unit* placeholder
    direction: align_rotation of a zero vector is NaN, and NaN * mask = NaN
    — the masking must happen before the rotation math, not after.
    """
    n = pos.shape[0]
    diff = pos[None, :, :] - pos[:, None, :]  # r_ij = r_j - r_i
    dist = jnp.linalg.norm(diff + jnp.eye(n)[..., None], axis=-1) * (1 - jnp.eye(n))
    mask = (dist > 1e-6) & (dist < cutoff)
    rhat = diff / jnp.maximum(dist[..., None], 1e-6)
    ez = jnp.broadcast_to(jnp.asarray([0.0, 0.0, 1.0], rhat.dtype), rhat.shape)
    rhat = jnp.where(mask[..., None], rhat, ez)
    return rhat, dist, mask


# tp_impl -> engine backend (None = historical spectral default mapping,
# 'auto' = engine selection); anything not listed falls back to CG.
_TP_BACKEND = {"gaunt": None, "gaunt_fused": "fused_xla", "gaunt_auto": "auto"}


def _resolve_tp_backend(impl: str, L1: int, L2: int):
    """Map a tp_impl name to a concrete engine backend name (or None=auto)."""
    from repro.core.engine import spectral_default

    backend = _TP_BACKEND[impl]
    if impl == "gaunt":
        # historical spectral default (GauntTensorProduct's conv='auto' rule)
        backend = spectral_default(L1, L2)
    elif backend == "auto":
        backend = None
    return backend


def _model_dtype(cfg) -> str:
    """The config's Gaunt storage dtype ('float32' when absent)."""
    return getattr(cfg, "compute_dtype", "float32")


def _cast_sd(x, dts: str):
    """Cast an SH operand to the configured storage dtype at the product
    boundary (the model-side mirror of the engine's chain-entry cast rule).
    'auto' leaves operands alone — the plan resolves its own dtype."""
    if dts in ("float32", "bfloat16", "float64") and x.dtype != jnp.dtype(dts):
        return x.astype(dts)
    return x


def _tp(cfg: EquivariantConfig, L1, L2, Lout):
    """Resolve the configured tensor-product impl to a batched engine plan.

    tp_impl: 'gaunt' (historical spectral default), 'gaunt_fused'
    (collocation backend), 'gaunt_auto' (engine cost-model pick among
    grad-supporting backends), or anything else -> the CG baseline.  The
    Gaunt impls route through one batched plan (engine.plan_batch) so the
    edge x channel leading dims execute as a single fused — and optionally
    donated/sharded — invocation.
    """
    from repro.core import engine as _engine

    if cfg.tp_impl in _TP_BACKEND:
        dts = _model_dtype(cfg)
        # no donation here: model loops reuse operand buffers (edge_sh is
        # shared across layers) — donation is for callers that own the
        # buffer lifetime (e.g. the serving engine)
        bp = _engine.plan_batch(
            [(L1, L2, Lout)], kind="pairwise",
            backend=_resolve_tp_backend(cfg.tp_impl, L1, L2), dtype=dts,
            shard_spec=_engine.ShardSpec() if getattr(cfg, "shard_data", False) else None,
        )
        return lambda a, b: bp.apply([(_cast_sd(a, dts), _cast_sd(b, dts))])[0]
    return lambda a, b: cg_full_tensor_product(a, b, L1, L2, Lout)


def _tp_resident(cfg: EquivariantConfig, L1, L2, Lout):
    """A Fourier-resident tensor product for a *layer-constant* second
    operand (DESIGN.md §6), or None when the config cannot use one.

    Returns (to_rep, tp): ``to_rep(filt)`` converts the SH filter to a
    Fourier-resident Rep ONCE; ``tp(x, rep)`` runs the product with the
    filter conversion elided — a stack of n layers over one graph pays 1
    filter conversion instead of n.  The unsharded route is a 2-operand
    chain plan, so it inherits the engine's chain-backend dispatch
    (DESIGN.md §6.4): with ``cfg.chain_tune='measure'`` the measured
    autotuner may collapse the whole product into the collocation kernel
    (the resident filter then enters as a grid).  Residency composes with
    ``shard_data``: the sharded config routes the same boundary contract
    through a row-sharded batched bucket (Rep grids shard like SH rows)
    instead of falling back to per-layer filter conversions.
    """
    from repro.core import engine as _engine
    from repro.core.rep import Rep

    if (cfg.tp_impl not in ("gaunt", "gaunt_auto")
            or not getattr(cfg, "fourier_resident", True)):
        return None
    backend = _resolve_tp_backend("gaunt", L1, L2)  # spectral: fft | direct
    dts = _model_dtype(cfg)
    to_rep = lambda filt: Rep.from_sh(filt, L2).to_fourier("dense")  # noqa: E731
    if getattr(cfg, "shard_data", False):
        bp = _engine.plan_batch(
            [_engine.BatchItem(L1=L1, L2=L2, Lout=Lout,
                               options=(("boundary", ("sh", "fourier", "sh")),))],
            kind="pairwise", backend=backend, dtype=dts,
            shard_spec=_engine.ShardSpec(),
        )
        return to_rep, (lambda a, rep: bp.apply([(_cast_sd(a, dts), rep)])[0])
    tune = getattr(cfg, "chain_tune", "heuristic")

    def tp(a, rep):
        # plan per call so chain_tune='measure' measures on the REAL row
        # count (n*n*channels, known from the operand here) — plans and
        # measured selections are engine-cached, so this is lookup-cost
        # after the first call.  Measurement needs a clean trace: under a
        # whole-model jit the first trace stays on 'tree' unless the key
        # was seeded eagerly beforehand (see plan_chain's docstring).
        hint = int(np.prod(a.shape[:-1])) if tune == "measure" else None
        cp = _engine.plan_chain((L1, L2), Lout, tune=tune, batch_hint=hint,
                                entry_hint=("sh", "fourier"), dtype=dts)
        # eager apply (one dispatch per layer, like the historical boundary
        # plan): the layer loop re-binds a fresh activation every call, and
        # the trace-time conversion counters stay per-layer-visible
        return cp.apply([a, rep])

    return to_rep, tp


# --------------------------------------------------------------------------
# MACE-like force field
# --------------------------------------------------------------------------


@dataclasses.dataclass
class MaceGaunt:
    cfg: EquivariantConfig

    def init(self, key):
        c = self.cfg
        dim = num_coeffs(c.L)
        # one key per random leaf group, each consumed exactly once (reusing
        # a key across leaves makes them bitwise-correlated — see the
        # test_no_duplicate_init_leaves regression test)
        ks = jax.random.split(key, 3 + 5 * c.n_layers)
        params = {
            "species": jax.random.normal(ks[0], (c.n_species, c.channels)) * 0.5,
            "layers": [],
            "readout": {
                "w1": jax.random.normal(ks[1], (c.channels, c.hidden)) / math.sqrt(c.channels),
                "w2": jax.random.normal(ks[2], (c.hidden, 1)) / math.sqrt(c.hidden),
            },
        }
        for i in range(c.n_layers):
            k1, k2, k3, k4, k5 = ks[3 + 5 * i : 8 + 5 * i]
            params["layers"].append({
                "radial": {
                    "w1": jax.random.normal(k1, (c.n_radial, 32)) / math.sqrt(c.n_radial),
                    "w2": jax.random.normal(k2, (32, c.channels * (c.L + 1))) / 32.0,
                },
                "mix": equi_linear_init(k3, c.L, c.channels, c.channels),
                "mb_mix": equi_linear_init(k4, c.L, c.channels, c.channels),
                "mb_w": jnp.ones((c.nu, c.L + 1)) / c.nu,
                "gate": gate_init(k5, c.channels),
            })
        return params

    def features(self, params, species, pos):
        """-> per-atom invariant energy features.

        Basis residency (DESIGN.md §6): the many-body self-product runs as
        ONE chain plan per layer — A converts to the Fourier basis once
        (degree-resolved, serving all nu reweighted operands) and projects
        back once, instead of nu conversions and nu-1 round trips.  The
        layer-constant edge geometry converts once for the whole stack:
        conv_impl='general' keeps the filter Y(rhat) Fourier-resident
        (`EquivariantConv.filter_rep`); conv_impl='escn' hoists the
        alignment rotation + Wigner recursion (`geometry_rep`) out of the
        layer loop.  Both compose with ``shard_data`` — resident grids and
        Wigner blocks row-shard like SH rows.  SH checkpoints stay where
        the math demands them: equi_linear mixes and the gate act
        degree-wise on SH coefficients.
        """
        c = self.cfg
        n = pos.shape[0]
        from repro.core.engine import ShardSpec

        shard = ShardSpec() if getattr(c, "shard_data", False) else None
        # no donation: rhat is reused by every layer's conv call
        conv = EquivariantConv(c.L, c.L_edge, c.L, method=c.conv_impl,
                               shard_spec=shard)
        rhat, dist, mask = _pair_geometry(pos, c.cutoff)
        geom = None
        if getattr(c, "fourier_resident", True):
            if c.conv_impl == "general":
                geom = conv.filter_rep(rhat[:, :, None, :])
            elif c.conv_impl == "escn":
                geom = conv.geometry_rep(rhat[:, :, None, :])
        x = jnp.zeros((n, c.channels, num_coeffs(c.L)))
        x = x.at[..., 0].set(params["species"][species])
        # grid-resident gate policy (DESIGN.md §6.5), resolved once for the
        # stack: every layer's selfmix chain shares one workload shape
        grid_gate = _resolve_grid_gate(c, (c.L,) * c.nu, c.L,
                                       batch_hint=n * c.channels,
                                       share_hint=(0,) * c.nu)
        for lp in params["layers"]:
            rb = radial_basis(dist, c.n_radial, c.cutoff)  # [n,n,R]
            h = jax.nn.silu(rb @ lp["radial"]["w1"]) @ lp["radial"]["w2"]
            h = h.reshape(n, n, c.channels, c.L + 1)  # per-edge per-degree weights
            # messages: conv(x_j, r_ij) summed over j (channel-wise, eSCN path)
            xj = jnp.broadcast_to(x[None, :, :, :], (n, n, c.channels, x.shape[-1]))
            m = conv(xj, geom if geom is not None else rhat[:, :, None, :], w1=h)
            m = jnp.sum(m * mask[:, :, None, None], axis=1)  # [n, C, dim]
            A = equi_linear(lp["mix"], m, c.L) + x
            # many-body: nu-fold Gaunt self-product, per-degree weights
            mb_kw = dict(
                weights=[jnp.broadcast_to(w, (n, c.channels, c.L + 1))
                         for w in lp["mb_w"]],
                shard_spec=shard,  # the chain route honors sharding directly
                tune=getattr(c, "chain_tune", "heuristic"),
                dtype=_model_dtype(c),  # storage precision (chain-entry cast)
            )
            if grid_gate:
                # grid-resident gate (DESIGN.md §6.5): the affine gate runs
                # as a pointwise stage on the selfmix chain's resident
                # product grid — the whole many-body stage is one region
                # with one entry + one exit conversion.  The gate cannot
                # cross the mb_mix channel mix, so this variant gates B
                # *before* the mix (an equally expressive
                # reparameterization — fix grid_gate per checkpoint).
                B = manybody_selfmix(A, c.L, c.nu, Lout=c.L,
                                     gate_params=lp["gate"], **mb_kw)
                x = x + equi_linear(lp["mb_mix"], B, c.L)
            else:
                B = manybody_selfmix(A, c.L, c.nu, Lout=c.L, **mb_kw)
                x = x + gate_apply(lp["gate"],
                                   equi_linear(lp["mb_mix"], B, c.L), c.L)
        return x[..., 0]  # invariant channels [n, C]

    def energy(self, params, species, pos):
        feat = self.features(params, species, pos)
        e_atom = jax.nn.silu(feat @ params["readout"]["w1"]) @ params["readout"]["w2"]
        return jnp.sum(e_atom)

    def energy_masked(self, params, species, pos, mask):
        """Energy of the atoms selected by ``mask`` [n] (serving: padded
        slots place ghost atoms beyond the cutoff and mask them out here)."""
        feat = self.features(params, species, pos)
        e_atom = jax.nn.silu(feat @ params["readout"]["w1"]) @ params["readout"]["w2"]
        return jnp.sum(e_atom[:, 0] * mask)

    def energy_forces(self, params, species, pos):
        e, g = jax.value_and_grad(self.energy, argnums=2)(params, species, pos)
        return e, -g

    def loss(self, params, batch, w_e=1.0, w_f=10.0):
        def one(species, pos, e_ref, f_ref):
            e, f = self.energy_forces(params, species, pos)
            return w_e * (e - e_ref) ** 2 + w_f * jnp.mean((f - f_ref) ** 2)

        return jnp.mean(jax.vmap(one)(batch["species"], batch["pos"],
                                      batch["energy"], batch["forces"]))


# --------------------------------------------------------------------------
# SEGNN-like N-body
# --------------------------------------------------------------------------


@dataclasses.dataclass
class SegnnNBody:
    cfg: EquivariantConfig

    def init(self, key):
        c = self.cfg
        # 5 keys per layer, each consumed once: sharing k3 between mix and
        # self_mix (and k1 between radial and gate) made those leaves
        # bitwise-correlated at init
        ks = jax.random.split(key, 2 + 5 * c.n_layers)
        params = {
            "embed": equi_linear_init(ks[0], c.L, 2, c.channels),  # charge,|v| + v irreps
            "out": equi_linear_init(ks[1], c.L, c.channels, 1),
            "layers": [],
        }
        for i in range(c.n_layers):
            k1, k2, k3, k4, k5 = ks[2 + 5 * i : 7 + 5 * i]
            params["layers"].append({
                "radial": {
                    "w1": jax.random.normal(k1, (c.n_radial, 32)) / math.sqrt(c.n_radial),
                    "w2": jax.random.normal(k2, (32, c.channels * (c.L + 1))) / 32.0,
                },
                "mix": equi_linear_init(k3, c.L, c.channels, c.channels),
                "self_mix": equi_linear_init(k4, c.L, c.channels, c.channels),
                "gate": gate_init(k5, c.channels),
            })
        return params

    def _node_feats(self, charge, vel):
        """2-channel input irreps: ch0 = (charge; velocity as l=1),
        ch1 = (|v|; velocity)."""
        n = charge.shape[0]
        L = self.cfg.L
        x = jnp.zeros((n, 2, num_coeffs(L)))
        x = x.at[:, 0, 0].set(charge)
        x = x.at[:, 1, 0].set(jnp.linalg.norm(vel, axis=-1))
        # l=1 slot order (m=-1,0,1) ~ (y,z,x)
        v_sh = jnp.stack([vel[:, 1], vel[:, 2], vel[:, 0]], axis=-1)
        x = x.at[:, 0, 1:4].set(v_sh)
        x = x.at[:, 1, 1:4].set(v_sh)
        return x

    def forward(self, params, charge, pos, vel):
        c = self.cfg
        n = pos.shape[0]
        rhat, dist, mask = _pair_geometry(pos, cutoff=1e9)  # fully connected
        x = equi_linear(params["embed"], self._node_feats(charge, vel), c.L)
        edge_sh = real_sph_harm_jax(c.L_edge, rhat)  # [n,n,(Le+1)^2]
        # the edge filter is layer-constant: with the resident path it
        # converts to the Fourier basis ONCE for the whole layer stack
        # (n_layers - 1 conversions elided) instead of once per layer
        res = _tp_resident(c, c.L, c.L_edge, c.L)
        if res is not None:
            to_rep, tp_res = res
            edge_rep = to_rep(edge_sh[:, :, None, :])  # [n,n,1,...] broadcasts over C
            tp = lambda a: tp_res(a, edge_rep)  # noqa: E731
        else:
            tp0 = _tp(c, c.L, c.L_edge, c.L)
            tp = lambda a: tp0(a, jnp.broadcast_to(  # noqa: E731
                edge_sh[:, :, None, :], (n, n, c.channels, edge_sh.shape[-1])))
        # SEGNN's gate sits after the channel mix, so no adjacent chain can
        # absorb it; grid_gate='on' evaluates it on the S^2 quadrature grid
        # (`_gate_quad` — exact, same function as 'off') to keep the
        # Rep-level residency path exercised.  It adds a quadrature
        # conversion pair rather than eliding one, so the measured 'auto'
        # policy never selects it here — 'auto' resolves to off.
        gg = getattr(c, "grid_gate", "off")
        use_quad_gate = gg in ("on", "grid", True)
        if gg not in ("off", "on", "grid", "auto", True, False, None):
            raise ValueError(f"unknown grid_gate {gg!r}")
        for lp in params["layers"]:
            rb = radial_basis(dist, c.n_radial, cutoff=10.0)
            h = jax.nn.silu(rb @ lp["radial"]["w1"]) @ lp["radial"]["w2"]
            h = h.reshape(n, n, c.channels, c.L + 1)
            xj = jnp.broadcast_to(x[None], (n, n, c.channels, x.shape[-1]))
            hw = expand_degree_weights(h, c.L)
            m = tp(xj * hw)
            m = jnp.sum(m * mask[:, :, None, None], axis=1)[..., : num_coeffs(c.L)]
            y = equi_linear(lp["mix"], m, c.L)
            if use_quad_gate:
                x = x + _gate_quad(lp["gate"], y, c.L)
            else:
                x = x + gate_apply(lp["gate"], y, c.L)
            x = x + equi_linear(lp["self_mix"], x, c.L)
        out = equi_linear(params["out"], x, c.L)[:, 0]  # [n, dim]
        dsh = out[:, 1:4]  # l=1 block (y,z,x)
        dpos = jnp.stack([dsh[:, 2], dsh[:, 0], dsh[:, 1]], axis=-1)
        return pos + dpos

    def loss(self, params, batch):
        def one(charge, pos, vel, target):
            pred = self.forward(params, charge, pos, vel)
            return jnp.mean((pred - target) ** 2)

        return jnp.mean(jax.vmap(one)(batch["charge"], batch["pos"],
                                      batch["vel"], batch["target"]))


# --------------------------------------------------------------------------
# EquiformerV2-like Selfmix (Equivariant Feature Interaction)
# --------------------------------------------------------------------------


@dataclasses.dataclass
class SelfmixLayer:
    """x -> x + mix(GauntTP(w1 . x, w2 . x)) — the paper's added layer.

    With ``resident`` (default) the spectral 'gaunt' impl runs as a chain
    plan: the two operands are the SAME tensor under different per-degree
    weights, so ONE degree-resolved conversion serves both (DESIGN.md §6) —
    one sh->Fourier elided per call versus the looped per-operand path.
    The residual and channel mix are degree-diagonal SH ops, so the layer
    output checkpoints back to SH (as every gate/mix boundary must).

    ``shard_spec`` row-shards the layer's product over the mesh's data axes
    on BOTH routes (the resident chain and the batched fallback) — residency
    no longer forces single-device execution.
    """

    L: int
    channels: int
    tp_impl: str = "gaunt"
    resident: bool = True
    shard_spec: object = None
    # chain-backend policy (DESIGN.md §6.4): 'measure' lets the engine's
    # measured autotuner collapse the shared-operand chain into the
    # collocation kernel when that wins on this host
    tune: str = "heuristic"
    # Gaunt storage precision ('float32' | 'bfloat16' | 'auto', §3.6)
    compute_dtype: str = "float32"

    def init(self, key):
        k1, k2, k3 = jax.random.split(key, 3)
        return {
            "w1": jnp.ones((self.L + 1,)),
            "w2": jnp.ones((self.L + 1,)),
            "w3": jnp.ones((2 * self.L + 1,)),
            "mix": equi_linear_init(k3, self.L, self.channels, self.channels),
        }

    def __call__(self, params, x):
        L = self.L
        if self.tp_impl == "gaunt" and self.resident:
            from repro.core import engine as _engine

            # under 'measure', mirror the real call in the measurement: the
            # layer's actual row count and the shared-operand [x, x] pattern
            hint = (int(np.prod(x.shape[:-1]))
                    if self.tune == "measure" else None)
            cp = _engine.plan_chain([L, L], Lout=L, shard_spec=self.shard_spec,
                                    tune=self.tune, batch_hint=hint,
                                    share_hint=(0, 0) if hint else None,
                                    dtype=self.compute_dtype)
            y = cp.apply_jit([x, x], weights=[params["w1"], params["w2"]],
                             w_out=params["w3"][: L + 1])
        elif self.tp_impl in _TP_BACKEND:
            from repro.core import engine as _engine

            xd = _cast_sd(x, self.compute_dtype)
            bp = _engine.plan_batch([(L, L, L)], kind="pairwise",
                                    backend=_resolve_tp_backend(self.tp_impl, L, L),
                                    shard_spec=self.shard_spec,
                                    dtype=self.compute_dtype)
            y = bp.apply([(xd, xd)],
                         weights=[(params["w1"], params["w2"],
                                   params["w3"][: L + 1])])[0]
        else:  # cg baseline
            xw = x * expand_degree_weights(params["w1"], L)
            yw = x * expand_degree_weights(params["w2"], L)
            y = cg_full_tensor_product(xw, yw, L, L, L) * expand_degree_weights(
                params["w3"][: L + 1], L)
        return x + equi_linear(params["mix"], y, L)
