"""State-space blocks: Mamba-2 (SSD) and RWKV6 (Finch) time/channel mix.

Both provide a sequence path (chunked scan — used for train/prefill) and a
single-step decode path carrying an explicit recurrent state (O(1) per token:
these are the sub-quadratic archs that serve the long_500k shape).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.kernels.mamba2 import mamba2_ssd_chunked
from repro.kernels.wkv6 import wkv6_chunked

from .layers import dense, dense_init, norm_apply, norm_init

__all__ = [
    "mamba2_init", "mamba2_apply", "mamba2_decode_step", "mamba2_state_init",
    "rwkv6_init", "rwkv6_apply", "rwkv6_decode_step", "rwkv6_state_init",
]


# ---------------------------------------------------------------- Mamba-2


def _m2_dims(cfg):
    d_in = cfg.ssm_expand * cfg.d_model
    H = d_in // cfg.ssm_headdim
    return d_in, H, cfg.ssm_state, cfg.ssm_groups


def mamba2_init(key, cfg, dtype=jnp.float32):
    d = cfg.d_model
    d_in, H, N, G = _m2_dims(cfg)
    conv_ch = d_in + 2 * G * N
    ks = jax.random.split(key, 5)
    return {
        "in_proj": dense_init(ks[0], d, 2 * d_in + 2 * G * N + H, dtype=dtype),
        "conv_w": jax.random.normal(ks[1], (cfg.ssm_conv, conv_ch), dtype) * 0.2,
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 8.0, H, dtype=jnp.float32)),
        "D": jnp.ones((H,), jnp.float32),
        "out_norm": norm_init(d_in, "rmsnorm", dtype),
        "out_proj": dense_init(ks[2], d_in, d, dtype=dtype),
    }


def _split_in_proj(y, cfg):
    d_in, H, N, G = _m2_dims(cfg)
    z, xc, B, C, dt = jnp.split(
        y, [d_in, 2 * d_in, 2 * d_in + G * N, 2 * d_in + 2 * G * N], axis=-1
    )
    return z, xc, B, C, dt


def _causal_conv(x, w, b):
    """Depthwise causal conv.  x [B,T,Ch], w [K,Ch] -> [B,T,Ch]."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(xp[:, i : i + x.shape[1]] * w[i] for i in range(K))
    return out + b


def mamba2_apply(p, x, cfg):
    """x [B,T,d] -> [B,T,d] (sequence path)."""
    d_in, H, N, G = _m2_dims(cfg)
    dt_c = jnp.dtype(cfg.dtype)
    Bt, T, _ = x.shape
    y = dense(p["in_proj"], x, dt_c)
    z, xc, Bm, Cm, dt = _split_in_proj(y, cfg)
    conv_in = jnp.concatenate([xc, Bm, Cm], axis=-1)
    conv_out = jax.nn.silu(_causal_conv(conv_in, p["conv_w"].astype(dt_c), p["conv_b"].astype(dt_c)))
    xc, Bm, Cm = jnp.split(conv_out, [d_in, d_in + G * N], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # [B,T,H]
    A = -jnp.exp(p["A_log"])  # [H] < 0
    xh = xc.reshape(Bt, T, H, cfg.ssm_headdim)
    Bg = Bm.reshape(Bt, T, G, N)
    Cg = Cm.reshape(Bt, T, G, N)
    ych = mamba2_ssd_chunked(xh, dt, A, Bg, Cg, p["D"], chunk=min(64, T))
    yc = ych.reshape(Bt, T, d_in).astype(x.dtype)
    yc = norm_apply(p["out_norm"], yc * jax.nn.silu(z), "rmsnorm")
    return dense(p["out_proj"], yc, dt_c)


def mamba2_state_init(cfg, batch: int, dtype=jnp.float32):
    d_in, H, N, G = _m2_dims(cfg)
    conv_ch = d_in + 2 * G * N
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_ch), dtype),
        "ssm": jnp.zeros((batch, H, cfg.ssm_headdim, N), jnp.float32),
    }


def mamba2_decode_step(p, x, state, cfg):
    """x [B,1,d] -> ([B,1,d], new state).  O(1) per token."""
    d_in, H, N, G = _m2_dims(cfg)
    dt_c = jnp.dtype(cfg.dtype)
    Bt = x.shape[0]
    y = dense(p["in_proj"], x[:, 0], dt_c)  # [B, ...]
    z, xc, Bm, Cm, dt = _split_in_proj(y, cfg)
    conv_in = jnp.concatenate([xc, Bm, Cm], axis=-1)  # [B,Ch]
    buf = jnp.concatenate([state["conv"], conv_in[:, None]], axis=1)  # [B,K,Ch]
    conv_out = jax.nn.silu(
        jnp.einsum("bkc,kc->bc", buf, p["conv_w"].astype(dt_c)) + p["conv_b"].astype(dt_c))
    new_conv = buf[:, 1:]
    xc, Bm, Cm = jnp.split(conv_out, [d_in, d_in + G * N], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # [B,H]
    A = -jnp.exp(p["A_log"])
    xh = xc.reshape(Bt, H, cfg.ssm_headdim)
    Bg = jnp.repeat(Bm.reshape(Bt, G, N), H // G, axis=1)
    Cg = jnp.repeat(Cm.reshape(Bt, G, N), H // G, axis=1)
    h = state["ssm"]
    decay = jnp.exp(A[None, :, None, None] * dt[..., None, None])
    h = decay * h + dt[..., None, None] * xh[..., None] * Bg[:, :, None, :]
    yh = jnp.einsum("bhpn,bhn->bhp", h, Cg) + p["D"][None, :, None] * xh
    yc = yh.reshape(Bt, d_in).astype(x.dtype)
    yc = norm_apply(p["out_norm"], yc * jax.nn.silu(z), "rmsnorm")
    out = dense(p["out_proj"], yc, dt_c)[:, None]
    return out, {"conv": new_conv, "ssm": h}


# ---------------------------------------------------------------- RWKV6


def _r6_dims(cfg):
    K = cfg.rwkv_head_k
    H = cfg.d_model // K
    return H, K


def rwkv6_init(key, cfg, dtype=jnp.float32):
    d = cfg.d_model
    H, K = _r6_dims(cfg)
    lora = 32
    ks = jax.random.split(key, 12)
    std = 1.0 / math.sqrt(d)
    p = {
        # time-mix
        "mu": jnp.full((5, d), 0.5, jnp.float32),  # r,k,v,w,g static mix
        "maa_w1": jax.random.normal(ks[0], (d, 5 * lora), jnp.float32) * 0.01,
        "maa_w2": jax.random.normal(ks[1], (5, lora, d), jnp.float32) * 0.01,
        "wr": dense_init(ks[2], d, d, dtype=dtype),
        "wk": dense_init(ks[3], d, d, dtype=dtype),
        "wv": dense_init(ks[4], d, d, dtype=dtype),
        "wg": dense_init(ks[5], d, d, dtype=dtype),
        "wo": dense_init(ks[6], d, d, dtype=dtype),
        "decay_base": jnp.full((d,), -2.0, jnp.float32),
        "decay_w1": jax.random.normal(ks[7], (d, lora * 2), jnp.float32) * 0.01,
        "decay_w2": jax.random.normal(ks[8], (lora * 2, d), jnp.float32) * 0.01,
        "u": jax.random.normal(ks[9], (H, K), jnp.float32) * 0.3,
        "ln_x": norm_init(d, "layernorm", jnp.float32),  # per-head groupnorm
    }
    return p


def _rwkv_mix(p, x, sx):
    """Data-dependent token-shift mixing (maa).  x, sx [B,T,d]."""
    xxx = x + sx * p["mu"][0]  # use mu_r slot for the lora input mix
    lat = jnp.tanh(xxx.astype(jnp.float32) @ p["maa_w1"])  # [B,T,5*lora]
    B, T = x.shape[:2]
    lat = lat.reshape(B, T, 5, -1).transpose(2, 0, 1, 3)  # [5,B,T,lora]
    deltas = jnp.einsum("sbtl,sld->sbtd", lat, p["maa_w2"])  # [5,B,T,d]
    mixed = [(x + sx * (p["mu"][i] + deltas[i]).astype(x.dtype)).astype(x.dtype) for i in range(5)]
    return mixed  # xw, xk, xv, xr, xg order


def _rwkv_groupnorm(p, x, H):
    """Per-head groupnorm over K within each head. x [B,T,d]."""
    B, T, d = x.shape
    xh = x.reshape(B, T, H, d // H).astype(jnp.float32)
    mu = xh.mean(-1, keepdims=True)
    var = xh.var(-1, keepdims=True)
    xh = (xh - mu) * jax.lax.rsqrt(var + 1e-5)
    xf = xh.reshape(B, T, d)
    return (xf * p["ln_x"]["scale"] + p["ln_x"]["bias"]).astype(x.dtype)


def rwkv6_time_mix(p, x, cfg, sx=None, state=None):
    """Sequence path if state is None, else single-step (T==1).

    Returns (out, (last_x, new_wkv_state))."""
    H, K = _r6_dims(cfg)
    B, T, d = x.shape
    if state is None:
        xprev = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    else:
        xprev = state["last_x"][:, None]
    dt_c = jnp.dtype(cfg.dtype)
    sxd = xprev - x
    xw, xk, xv, xr, xg = _rwkv_mix(p, x, sxd)
    r = dense(p["wr"], xr, dt_c).reshape(B, T, H, K)
    k = dense(p["wk"], xk, dt_c).reshape(B, T, H, K)
    v = dense(p["wv"], xv, dt_c).reshape(B, T, H, K)
    g = jax.nn.silu(dense(p["wg"], xg, dt_c))
    dw = jnp.tanh(xw.astype(jnp.float32) @ p["decay_w1"]) @ p["decay_w2"]
    w = jnp.exp(-jnp.exp(p["decay_base"] + dw)).reshape(B, T, H, K)  # (0,1)
    if state is None:
        o, S_fin = wkv6_chunked(r, k, v, w, p["u"], chunk=min(64, T), return_state=True)
        new_state = {"last_x": x[:, -1], "wkv": S_fin}
    else:
        S = state["wkv"]  # [B,H,K,V]
        kt, vt, rt, wt = k[:, 0], v[:, 0], r[:, 0], w[:, 0]
        kv = kt[..., :, None] * vt[..., None, :]
        o = jnp.einsum("bhk,bhkv->bhv", rt, S + p["u"][None, :, :, None] * kv)[:, None]
        S = wt[..., :, None] * S + kv
        new_state = {"last_x": x[:, -1], "wkv": S}
    o = o.reshape(B, T, d).astype(x.dtype)
    out = dense(p["wo"], _rwkv_groupnorm(p, o, H) * g, dt_c)
    return out, new_state


def rwkv6_channel_mix(p, x, state=None):
    B, T, d = x.shape
    dt_c = x.dtype
    if state is None:
        xprev = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    else:
        xprev = state[:, None]
    sx = xprev - x
    xk = (x + sx * p["cm_mu"][0]).astype(dt_c)
    xr = (x + sx * p["cm_mu"][1]).astype(dt_c)
    kk = jnp.square(jax.nn.relu(dense(p["cm_k"], xk, dt_c)))
    kv = dense(p["cm_v"], kk, dt_c)
    out = jax.nn.sigmoid(dense(p["cm_r"], xr, dt_c)) * kv
    return out, (x[:, -1] if state is not None else None)


def rwkv6_state_init(cfg, batch: int, dtype=jnp.float32):
    H, K = _r6_dims(cfg)
    return {
        "last_x": jnp.zeros((batch, cfg.d_model), dtype),
        "wkv": jnp.zeros((batch, H, K, K), jnp.float32),
        "cm_last_x": jnp.zeros((batch, cfg.d_model), dtype),
    }


def rwkv6_apply(p, x, cfg):
    o, _ = rwkv6_time_mix(p["tm"], norm_apply(p["ln1"], x, "layernorm"), cfg)
    x = x + o
    o, _ = rwkv6_channel_mix(p["cm"], norm_apply(p["ln2"], x, "layernorm"))
    return x + o


def rwkv6_decode_step(p, x, state, cfg):
    h = norm_apply(p["ln1"], x, "layernorm")
    o, tm_state = rwkv6_time_mix(
        p["tm"], h, cfg, state={"last_x": state["last_x"], "wkv": state["wkv"]}
    )
    # token-shift state must hold the *normed* input? RWKV shifts raw block
    # input; we store the pre-norm input consistently with the sequence path.
    x = x + o
    h2 = norm_apply(p["ln2"], x, "layernorm")
    o2, cm_last = rwkv6_channel_mix(p["cm"], h2, state=state["cm_last_x"])
    x = x + o2
    new_state = {
        "last_x": tm_state["last_x"],
        "wkv": tm_state["wkv"],
        "cm_last_x": cm_last,
    }
    return x, new_state


def rwkv6_block_init(key, cfg, dtype=jnp.float32):
    k1, k2 = jax.random.split(key)
    return {
        "ln1": norm_init(cfg.d_model, "layernorm", jnp.float32),
        "ln2": norm_init(cfg.d_model, "layernorm", jnp.float32),
        "tm": rwkv6_init(k1, cfg, dtype),
        "cm": _rwkv_cm_init(k2, cfg, dtype),
    }


def _rwkv_cm_init(key, cfg, dtype):
    ks = jax.random.split(key, 3)
    return {
        "cm_mu": jnp.full((2, cfg.d_model), 0.5, jnp.float32),
        "cm_k": dense_init(ks[0], cfg.d_model, cfg.d_ff, dtype=dtype),
        "cm_v": dense_init(ks[1], cfg.d_ff, cfg.d_model, dtype=dtype),
        "cm_r": dense_init(ks[2], cfg.d_model, cfg.d_model, dtype=dtype),
    }
