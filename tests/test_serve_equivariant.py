"""The equivariant serving engine: slot padding, continuous batching, and
padded-vs-direct numerical equality (ghost atoms must be inert)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.gaunt_ff import gaunt_mace_ff
from repro.models.equivariant import MaceGaunt
from repro.serve.engine import EquivariantRequest, EquivariantServeEngine
from repro.testing import random_array, random_irreps  # noqa: F401 (random_array)


@pytest.fixture(scope="module")
def small_model():
    cfg = dataclasses.replace(gaunt_mace_ff, channels=8, n_layers=1, L=1,
                              L_edge=1, n_species=4)
    model = MaceGaunt(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return model, params


def _mol(n, seed):
    rng = np.random.default_rng(seed)
    return (rng.integers(0, 4, n), (rng.normal(size=(n, 3)) * 1.5).astype(np.float32))


def test_padded_energy_matches_direct(small_model):
    model, params = small_model
    eng = EquivariantServeEngine(model, params, n_slots=2, max_atoms=6)
    sp, pos = _mol(3, 0)
    req = EquivariantRequest(species=sp, pos=pos)
    out = eng.run([req])[0]
    assert out.done and out.forces.shape == (3, 3)
    e_direct = float(model.energy(params, jnp.asarray(sp), jnp.asarray(pos)))
    assert abs(out.energy - e_direct) < 1e-4 * max(1.0, abs(e_direct))
    _, f_direct = model.energy_forces(params, jnp.asarray(sp), jnp.asarray(pos))
    np.testing.assert_allclose(out.forces, np.asarray(f_direct),
                               rtol=1e-4, atol=1e-6)


def test_continuous_batching_drains_overflow(small_model):
    """More requests than slots: everything completes, every slot is freed,
    and results are independent of batch composition."""
    model, params = small_model
    eng = EquivariantServeEngine(model, params, n_slots=2, max_atoms=6)
    reqs = [EquivariantRequest(*_mol(2 + i % 4, seed=i), rid=i) for i in range(5)]
    out = eng.run(reqs)
    assert all(r.done for r in out)
    assert eng.slot_req == [None, None]
    for r in out:
        e_direct = float(model.energy(params, jnp.asarray(r.species),
                                      jnp.asarray(np.asarray(r.pos, np.float32))))
        assert abs(r.energy - e_direct) < 1e-4 * max(1.0, abs(e_direct))


def test_relaxation_advances_and_returns_geometry(small_model):
    """steps=2 must evaluate, advance, re-evaluate — and hand back the
    geometry that produced the final energy/forces."""
    model, params = small_model
    eng = EquivariantServeEngine(model, params, n_slots=1, max_atoms=6)
    sp, pos0 = _mol(4, 7)
    s = 1e5  # forces are tiny for a random-init model; make the move visible
    req = EquivariantRequest(species=sp, pos=pos0.copy(), steps=2, step_size=s)
    out = eng.run([req])[0]
    assert out.done and out.steps == 0
    # manual two-step reference
    e0, f0 = model.energy_forces(params, jnp.asarray(sp), jnp.asarray(pos0))
    pos1 = pos0 + s * np.asarray(f0)
    e1, f1 = model.energy_forces(params, jnp.asarray(sp), jnp.asarray(pos1))
    np.testing.assert_allclose(out.pos, pos1, rtol=1e-5, atol=1e-6)
    assert abs(out.energy - float(e1)) < 1e-4 * max(1.0, abs(float(e1)))
    np.testing.assert_allclose(out.forces, np.asarray(f1), rtol=1e-3, atol=1e-6)


def test_oversized_request_rejected(small_model):
    """Oversize is a structured rejection (reason 'too_large'), not an
    exception: the request is consumed without ever touching a slot."""
    model, params = small_model
    eng = EquivariantServeEngine(model, params, n_slots=1, max_atoms=3)
    sp, pos = _mol(5, 8)
    req = EquivariantRequest(species=sp, pos=pos)
    assert eng.add_request(req)  # consumed, not admitted
    assert req.rejected and req.done and req.energy is None
    assert req.reject_reason.startswith("too_large")
    assert eng.slot_req == [None]


def test_invalid_geometry_rejected_not_evaluated(small_model):
    """Admission-time validation: NaN positions, zero step budgets, empty
    species, and shape mismatches are rejected with structured reasons and
    never poison the shared batched step — a good request served in the
    same run still gets the exact direct-evaluation energy."""
    model, params = small_model
    eng = EquivariantServeEngine(model, params, n_slots=2, max_atoms=6)
    sp, pos = _mol(3, 21)
    nan_pos = pos.copy()
    nan_pos[1, 1] = np.nan
    bad_nan = EquivariantRequest(species=sp, pos=nan_pos, rid=1)
    bad_steps = EquivariantRequest(*_mol(3, 22), steps=0, rid=2)
    bad_empty = EquivariantRequest(species=np.zeros(0, np.int64),
                                   pos=np.zeros((0, 3)), rid=3)
    bad_shape = EquivariantRequest(species=sp, pos=pos[:2], rid=4)
    good = EquivariantRequest(*_mol(3, 23), rid=5)
    out = eng.run([bad_nan, bad_steps, bad_empty, bad_shape, good])
    assert all(r.done for r in out)
    for bad in (bad_nan, bad_steps, bad_empty, bad_shape):
        assert bad.rejected and bad.energy is None
        assert bad.reject_reason.startswith("invalid"), bad.reject_reason
    assert not good.rejected
    e_direct = float(model.energy(params, jnp.asarray(good.species),
                                  jnp.asarray(np.asarray(good.pos,
                                                         np.float32))))
    assert abs(good.energy - e_direct) < 1e-4 * max(1.0, abs(e_direct))
    assert np.all(np.isfinite(good.forces))
    assert eng.metrics.counters["rejected:invalid"] == 4


def test_out_of_range_species_rejected(small_model):
    """Species values are validated at admission: negative, >= n_species,
    or non-integral species would flow into the jitted step where gather
    clamping silently produces a WRONG energy — they must reject with a
    structured 'invalid' reason instead, and a good request in the same
    run still serves exactly."""
    model, params = small_model          # cfg.n_species == 4
    eng = EquivariantServeEngine(model, params, n_slots=2, max_atoms=6)
    sp, pos = _mol(3, 31)
    neg = np.array(sp, np.int64)
    neg[0] = -1
    high = np.array(sp, np.int64)
    high[1] = model.cfg.n_species        # first out-of-range value
    bad_neg = EquivariantRequest(species=neg, pos=pos.copy(), rid=1)
    bad_high = EquivariantRequest(species=high, pos=pos.copy(), rid=2)
    bad_float = EquivariantRequest(species=np.asarray(sp, np.float32),
                                   pos=pos.copy(), rid=3)
    good = EquivariantRequest(*_mol(3, 32), rid=4)
    out = eng.run([bad_neg, bad_high, bad_float, good])
    assert all(r.done for r in out)
    for bad in (bad_neg, bad_high, bad_float):
        assert bad.rejected and bad.energy is None, bad.rid
        assert bad.reject_reason.startswith("invalid"), bad.reject_reason
    assert not good.rejected
    e_direct = float(model.energy(params, jnp.asarray(good.species),
                                  jnp.asarray(np.asarray(good.pos,
                                                         np.float32))))
    assert abs(good.energy - e_direct) < 1e-4 * max(1.0, abs(e_direct))


def test_serve_step_runs_resident_and_sharded():
    """The continuous-batching step keeps basis residency under a sharded
    config (PR 4: no more resident/sharded fork): a shard_data=True,
    fourier_resident=True model serves, warms up, and matches the plain
    config's energies."""
    cfg = dataclasses.replace(gaunt_mace_ff, channels=8, n_layers=1, L=1,
                              L_edge=1, n_species=4, shard_data=True,
                              fourier_resident=True)
    model = MaceGaunt(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = EquivariantServeEngine(model, params, n_slots=2, max_atoms=6,
                                 warmup=True)
    sp, pos = _mol(3, 11)
    out = eng.run([EquivariantRequest(species=sp, pos=pos)])[0]
    assert out.done
    ref_model = MaceGaunt(dataclasses.replace(cfg, shard_data=False,
                                              fourier_resident=False))
    e_ref = float(ref_model.energy(params, jnp.asarray(sp), jnp.asarray(pos)))
    assert abs(out.energy - e_ref) < 1e-3 * max(1.0, abs(e_ref))
