"""Equivariant convolution (general + eSCN-sparsity) and many-body products."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import so3
from repro.core.cg import gaunt_einsum_reference
from repro.core.conv import (
    EquivariantConv,
    align_rotation,
    apply_wigner_blocks,
    wigner_blocks_from_rotmat,
)
from repro.core.irreps import num_coeffs
from repro.core.manybody import manybody_gaunt_product, manybody_selfmix
from repro.core.so3 import real_sph_harm, real_sph_harm_jax
from repro.testing import random_array, random_unit_vectors


def _rand(shape, seed=0):
    return jnp.asarray(random_array(shape, seed))


def _rand_dirs(n, seed=0):
    return jnp.asarray(random_unit_vectors((n,), seed))


def test_align_rotation():
    r = _rand_dirs(32, 1)
    R = align_rotation(r)
    z = jnp.einsum("...ij,...j->...i", R, r)
    np.testing.assert_allclose(np.asarray(z), np.tile([0, 0, 1.0], (32, 1)), atol=1e-5)
    det = np.linalg.det(np.asarray(R))
    np.testing.assert_allclose(det, 1.0, atol=1e-5)


def test_wigner_blocks_from_rotmat_vs_exact():
    rng = np.random.default_rng(2)
    a, b, g = 0.4, 1.0, -0.8
    R = so3.rotation_matrix_zyz(a, b, g).astype(np.float32)
    Ds = wigner_blocks_from_rotmat(4, jnp.asarray(R))
    for l in range(5):
        ref = so3.wigner_D_real(l, a, b, g)
        np.testing.assert_allclose(np.asarray(Ds[l]), ref, atol=1e-4)


def test_apply_wigner_matches_sh_rotation():
    r = _rand_dirs(8, 3)
    R = align_rotation(r)
    Ds = wigner_blocks_from_rotmat(3, R)
    S = real_sph_harm_jax(3, r)
    S_rot = apply_wigner_blocks(Ds, S)
    ref = real_sph_harm_jax(3, jnp.einsum("...ij,...j->...i", R, r))
    np.testing.assert_allclose(np.asarray(S_rot), np.asarray(ref), atol=1e-4)


@pytest.mark.parametrize("L1,L2,Lout", [(2, 2, 4), (3, 2, 3), (2, 3, 5), (1, 4, 5)])
def test_escn_conv_matches_general_and_oracle(L1, L2, Lout):
    x = _rand((16, num_coeffs(L1)), 4)
    r = _rand_dirs(16, 5)
    general = EquivariantConv(L1, L2, Lout, method="general")
    escn = EquivariantConv(L1, L2, Lout, method="escn")
    filt = real_sph_harm_jax(L2, r).astype(jnp.float32)
    ref = gaunt_einsum_reference(x, filt, L1, L2, Lout)
    np.testing.assert_allclose(np.asarray(general(x, r)), np.asarray(ref), atol=3e-4)
    np.testing.assert_allclose(np.asarray(escn(x, r)), np.asarray(ref), atol=3e-4)


def test_escn_conv_weights():
    L1, L2, Lout = 2, 2, 3
    x = _rand((6, num_coeffs(L1)), 6)
    r = _rand_dirs(6, 7)
    w1 = _rand((6, L1 + 1), 8)
    w2 = _rand((6, L2 + 1), 9)
    w3 = _rand((6, Lout + 1), 10)
    escn = EquivariantConv(L1, L2, Lout, method="escn")
    general = EquivariantConv(L1, L2, Lout, method="general")
    np.testing.assert_allclose(
        np.asarray(escn(x, r, w1, w2, w3)),
        np.asarray(general(x, r, w1, w2, w3)),
        atol=3e-4,
    )


def test_conv_equivariance():
    """Rotating inputs (feature + geometry) rotates the output."""
    L1, L2 = 2, 2
    Lout = 3
    from repro.testing import random_angles, rotation_matrix, wigner_D

    conv = EquivariantConv(L1, L2, Lout, method="escn")
    x = random_array((num_coeffs(L1),), seed=11)
    r = np.asarray(random_unit_vectors((), seed=11), np.float64)
    angles = random_angles(seed=11)
    Rg = rotation_matrix(angles)
    D1 = wigner_D(L1, angles)
    D3 = wigner_D(Lout, angles)
    out = np.asarray(conv(jnp.asarray(x)[None], jnp.asarray(r, dtype=jnp.float32)[None])[0])
    out_rot = np.asarray(
        conv(jnp.asarray(D1 @ x)[None], jnp.asarray(Rg @ r, dtype=jnp.float32)[None])[0]
    )
    np.testing.assert_allclose(out_rot, D3 @ out, atol=5e-4)


def test_manybody_matches_fold():
    L = 2
    nu = 3
    xs = [_rand((4, num_coeffs(L)), 20 + i) for i in range(nu)]
    got = manybody_gaunt_product(xs, [L] * nu)
    acc = gaunt_einsum_reference(xs[0], xs[1], L, L)
    acc = gaunt_einsum_reference(acc, xs[2], 2 * L, L)
    np.testing.assert_allclose(np.asarray(got), np.asarray(acc), atol=1e-3)


def test_manybody_four_operands_batched_tree():
    L = 1
    xs = [_rand((3, num_coeffs(L)), 30 + i) for i in range(4)]
    got = manybody_gaunt_product(xs, [L] * 4)
    acc = gaunt_einsum_reference(xs[0], xs[1], L, L)
    acc = gaunt_einsum_reference(acc, xs[2], 2 * L, L)
    acc = gaunt_einsum_reference(acc, xs[3], 3 * L, L)
    np.testing.assert_allclose(np.asarray(got), np.asarray(acc), atol=1e-3)


def test_manybody_truncated_output():
    L, nu, Lout = 2, 3, 2
    x = _rand((5, num_coeffs(L)), 40)
    got = manybody_selfmix(x, L, nu, Lout=Lout)
    acc = gaunt_einsum_reference(x, x, L, L)
    acc = gaunt_einsum_reference(acc, x, 2 * L, L, Lout)
    np.testing.assert_allclose(np.asarray(got), np.asarray(acc), atol=1e-3)
    assert got.shape == (5, num_coeffs(Lout))


def test_manybody_weights():
    L, nu = 2, 2
    x = _rand((3, num_coeffs(L)), 41)
    w = [_rand((3, L + 1), 42 + i) for i in range(nu)]
    got = manybody_gaunt_product([x, x], [L, L], weights=w)
    from repro.core.gaunt import expand_degree_weights

    ref = gaunt_einsum_reference(
        x * expand_degree_weights(w[0], L), x * expand_degree_weights(w[1], L), L, L
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=1e-3)
