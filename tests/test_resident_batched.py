"""Resident ∩ batched: the resident/sharded exclusivity is gone.

PR 4 (ROADMAP "Resident batched plans") makes basis-tagged ``Rep`` a
first-class operand/result of the batched engine: resident grids flatten
through the bucket layout (concat/pad/slice/shard/donate like SH rows),
chains plan with ``donate``/``shard_spec``, and every consumer fallback was
deleted.  These tests pin the acceptance criteria:

* counter proofs: ``manybody_gaunt_product(..., donate=True)`` and
  ``EquivariantConv(..., shard_spec=ShardSpec())`` still run the resident
  route — <= 1 ``sh_to_fourier`` per distinct operand, no silent fallback;
* numerical identity of resident batched execution vs the per-plan path,
  including Rep outputs, broadcast inner dims, Wigner-geometry buckets, and
  leaf-level donation alias copies for grid buffers;
* the resident x sharded x donated matrix on 2 virtual devices (both
  ShardSpec modes, rotation equivariance, grad through a donated resident
  chain, and the MaceGaunt ``shard_data=True, fourier_resident=True``
  equivalence) — in subprocesses so the XLA host-device-count flag cannot
  leak into this process.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine, rep
from repro.core.conv import EquivariantConv, WignerBlocks
from repro.core.irreps import num_coeffs
from repro.core.manybody import manybody_gaunt_product, manybody_selfmix
from repro.core.rep import Rep
from repro.testing import random_irreps, random_unit_vectors


def _j(a):
    return jnp.asarray(a)


# ---------------------------------------------------------------------------
# counter proofs: the execution knobs no longer kick workloads off the
# resident route
# ---------------------------------------------------------------------------


def test_manybody_donate_keeps_resident_route():
    """donate=True used to fall back to the legacy batched dispatch (2(n-1)
    conversions); now it stays on the chain plan: one sh->F per distinct
    operand + one exit projection."""
    L, nu = 2, 3
    xs = [_j(random_irreps(L, (13,), seed=i)) for i in range(nu)]
    # reference FIRST: the donated call consumes the operand buffers on
    # accelerators (donation is a no-op only on CPU)
    ref = manybody_gaunt_product(xs, [L] * nu, Lout=L)
    with rep.conversion_stats(fresh=True) as c:
        out = manybody_gaunt_product(xs, [L] * nu, Lout=L, donate=True)
    assert c["sh_to_fourier"] == nu  # <= 1 per distinct operand
    assert c["fourier_to_sh"] == 1
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_selfmix_donate_and_shard_single_conversion():
    """The shared-operand elision survives donation + (inert) sharding: ONE
    degree-resolved conversion serves all nu reweighted operands."""
    L, nu = 2, 3
    x = _j(random_irreps(L, (7,), seed=5))
    ws = [_j(np.random.default_rng(40 + i).normal(size=(7, L + 1)).astype(np.float32))
          for i in range(nu)]
    # reference first — the donated call consumes x on accelerators
    ref = manybody_selfmix(x, L, nu, Lout=L, weights=ws)
    with rep.conversion_stats(fresh=True) as c:
        out = manybody_selfmix(x, L, nu, Lout=L, weights=ws, donate=True,
                               shard_spec=engine.ShardSpec())
    assert (c["sh_to_fourier"], c["fourier_to_sh"]) == (1, 1)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_conv_shard_spec_keeps_resident_route():
    """EquivariantConv with a configured shard_spec used to RAISE on resident
    filters; now the boundary-aware bucket serves them: across a 3-layer
    stack the counters show 1 filter + 1 x conversion (<= 1 per distinct
    operand), not the per-layer fallback's 2 per call."""
    L, n_layers = 2, 3
    conv = EquivariantConv(L, L, L, method="general",
                           shard_spec=engine.ShardSpec())
    x = _j(random_irreps(L, (11,), seed=1))
    r = _j(random_unit_vectors((11,), seed=2))
    with rep.conversion_stats(fresh=True) as c:
        filt = conv.filter_rep(r)
        for _ in range(n_layers):
            out = conv(x, filt)
    # 1 eager filter conversion + 1 x-side conversion at bucket trace time
    assert c["sh_to_fourier"] == 2
    assert c["fourier_to_sh"] == 1
    ref = conv(x, r)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# resident operands/results through the batched layout
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend,form", [("fft", "dense"), ("rfft", "half")])
def test_resident_bucket_matches_per_plan(backend, form):
    L = 2
    x = _j(random_irreps(L, (10,), seed=10))
    f = _j(random_irreps(L, (10,), seed=11))
    rf = Rep.from_sh(f, L).to_fourier(form)
    bp = engine.plan_batch(
        [engine.BatchItem(L1=L, L2=L, Lout=L,
                          options=(("boundary", ("sh", "fourier", "sh")),))],
        kind="pairwise", backend=backend, requires_grad=False, pad_to=16)
    got = bp.apply([(x, rf)])[0]
    ref = engine.plan(L, L, L, backend=backend, requires_grad=False).apply(x, f)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_resident_bucket_broadcast_inner_dims():
    """The SEGNN layout: one resident edge filter against C channel features
    — the filter's grid keeps its un-materialized channel dim through the
    bucket's (row prefix, inner broadcast) split."""
    n, C, L = 3, 4, 1
    x = _j(random_irreps(L, (n, n, C), seed=20))
    f = _j(random_irreps(L, (n, n, 1), seed=21))
    rf = Rep.from_sh(f, L).to_fourier("dense")
    bp = engine.plan_batch(
        [engine.BatchItem(L1=L, L2=L, Lout=L,
                          options=(("boundary", ("sh", "fourier", "sh")),))],
        kind="pairwise", backend="fft", requires_grad=False)
    got = bp.apply([(x, rf)])[0]
    assert got.shape == (n, n, C, num_coeffs(L))
    ref = engine.plan(L, L, L, backend="fft", requires_grad=False).apply(x, f)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_resident_output_bucket_returns_reps():
    """A 'fourier' output boundary keeps bucket outputs resident: per-item
    Reps whose projection matches the SH-boundary result."""
    L = 1
    items = [engine.BatchItem(L1=L, L2=L, Lout=2 * L,
                              options=(("boundary", ("sh", "sh", "fourier")),))] * 2
    bp = engine.plan_batch(items, kind="pairwise", backend="fft",
                           requires_grad=False)
    ins = [(_j(random_irreps(L, (4,), seed=30 + i)),
            _j(random_irreps(L, (4,), seed=35 + i))) for i in range(2)]
    outs = bp.apply(ins)
    p = engine.plan(L, L, 2 * L, backend="fft", requires_grad=False)
    for (x1, x2), got in zip(ins, outs):
        assert isinstance(got, Rep) and got.is_fourier
        np.testing.assert_allclose(np.asarray(got.to_sh().data),
                                   np.asarray(p.apply(x1, x2)),
                                   rtol=1e-4, atol=1e-4)


def test_wigner_geometry_bucket_matches_raw_rhat():
    """Precomputed WignerBlocks through the escn bucket == the per-call
    align+recurse path, weights included."""
    L = 2
    conv = EquivariantConv(L, L, L, method="escn")
    x = _j(random_irreps(L, (9,), seed=50))
    r = _j(random_unit_vectors((9,), seed=51))
    w1 = _j(np.random.default_rng(52).normal(size=(9, L + 1)).astype(np.float32))
    geom = conv.geometry_rep(r)
    assert isinstance(geom, WignerBlocks) and geom.L == L
    got = conv(x, geom, w1=w1)
    ref = conv(x, r, w1=w1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_chain_apply_jit_dedups_rep_wrappers():
    """Donation-safe dedup keys on the underlying buffer + Rep meta, not the
    wrapper id: two Reps around one grid are ONE unique operand (donated
    once, converted once)."""
    L = 1
    x = _j(random_irreps(L, (4,), seed=70))
    r1 = Rep.from_sh(x, L).to_fourier("half")
    alias = Rep(r1.data, r1.L, r1.basis, r1.form)   # new wrapper, same buffer
    cp = engine.plan_chain((L, L), 2 * L, donate=True)
    out = cp.apply_jit([r1, alias], out_basis="fourier")
    (key,) = cp._jit_cache
    assert key[0] == (0, 0), "alias wrapper was not deduped to one operand"
    ref = engine.plan_chain((L, L), 2 * L).apply_jit([x, x])
    np.testing.assert_allclose(np.asarray(out.to_sh().data), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_bucket_rejects_mixed_rep_and_array_items():
    """Two items of one bucket must agree on operand structure: a Fourier
    Rep and a raw SH array in the same slot fail with a real message, not a
    downstream concat shape error."""
    L = 1
    item = engine.BatchItem(L1=L, L2=L, Lout=L,
                            options=(("boundary", ("sh", "fourier", "sh")),))
    bp = engine.plan_batch([item, item], kind="pairwise", backend="fft",
                           requires_grad=False)
    x = _j(random_irreps(L, (3,), seed=80))
    f = _j(random_irreps(L, (3,), seed=81))
    rf = Rep.from_sh(f, L).to_fourier("dense")
    with pytest.raises(ValueError, match="operand structure"):
        bp.apply([(x, rf), (x, f)])


def test_donation_alias_copy_dedups_grid_buffers():
    """Donation dedup must compare LEAF buffers, not wrapper ids: two Rep
    wrappers around one grid buffer alias the same donation target."""
    L = 2
    item = engine.BatchItem(L1=L, L2=L, Lout=L,
                            options=(("boundary", ("sh", "fourier", "sh")),))
    bp = engine.plan_batch([item, item], kind="pairwise", backend="fft",
                           requires_grad=False, donate=True)
    x1 = _j(random_irreps(L, (4,), seed=60))
    x2 = _j(random_irreps(L, (4,), seed=61))
    grid = Rep.from_sh(_j(random_irreps(L, (4,), seed=62)), L).to_fourier("dense")
    alias = Rep(grid.data, grid.L, grid.basis, grid.form)  # new wrapper, same buffer
    inputs, weights = bp._copy_donation_aliases(
        [(x1, grid), (x2, alias)], [None, None])
    assert inputs[0][1].data is grid.data          # first reference donated
    assert inputs[1][1].data is not grid.data      # repeat reference copied
    np.testing.assert_array_equal(np.asarray(inputs[1][1].data),
                                  np.asarray(grid.data))


# ---------------------------------------------------------------------------
# the resident x sharded x donated matrix on 2 virtual devices (subprocess:
# the XLA host-device flag must be set before jax initializes)
# ---------------------------------------------------------------------------

def _subprocess_env() -> dict:
    """Child env: inherit the parent's, force CPU, and make the src path
    absolute so the tests run from any cwd."""
    src = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                       "src")
    env = dict(os.environ)
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    env["JAX_PLATFORMS"] = "cpu"
    return env


def _run_subprocess(code: str, marker: str):
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=_subprocess_env(), timeout=900)
    assert marker in out.stdout, (out.stdout[-2000:], out.stderr[-2000:])


def test_resident_sharded_donated_matrix_two_devices():
    """Batched-vs-looped identity + rotation equivariance for Rep operands
    under both ShardSpec modes, and grad through a donated resident chain —
    all on a real 2-device data mesh."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import jax, jax.numpy as jnp, numpy as np
from repro.core import engine
from repro.core.rep import Rep
from repro.testing import random_angles, random_irreps, rotate_irreps

mesh = jax.make_mesh((2,), ("data",))
L, n = 2, 8
x = jnp.asarray(random_irreps(L, (n,), seed=1))
f = jnp.asarray(random_irreps(L, (n,), seed=2))
rf = Rep.from_sh(f, L).to_fourier("half")
ref = engine.plan(L, L, L, backend="rfft", requires_grad=False).apply(x, f)
item = engine.BatchItem(L1=L, L2=L, Lout=L,
                        options=(("boundary", ("sh", "fourier", "sh")),))
ang = random_angles(seed=7)
for mode in ("constraint", "shard_map"):
    sp = engine.ShardSpec(mesh=mesh, axes=("data",), mode=mode)
    bp = engine.plan_batch([item], kind="pairwise", backend="rfft",
                           requires_grad=False, shard_spec=sp, donate=True)
    got = bp.apply([(x, rf)])[0]
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)
    # rotation equivariance: rotate inputs -> output rotates
    xr = jnp.asarray(rotate_irreps(np.asarray(x), L, ang))
    fr = jnp.asarray(rotate_irreps(np.asarray(f), L, ang))
    got_r = bp.apply([(xr, Rep.from_sh(fr, L).to_fourier("half"))])[0]
    want = rotate_irreps(np.asarray(ref), L, ang)
    np.testing.assert_allclose(np.asarray(got_r), want, rtol=1e-3, atol=1e-3)

# grad through a donated + sharded resident chain, both modes
xs = [jnp.asarray(random_irreps(L, (n,), seed=20 + i)) for i in range(3)]
cp0 = engine.plan_chain((L,) * 3, L)
ref_c = cp0.apply_jit(list(xs))
g0 = jax.grad(lambda a: jnp.sum(cp0.apply([a, xs[1], xs[2]]) ** 2))(xs[0])
for mode in ("constraint", "shard_map"):
    sp = engine.ShardSpec(mesh=mesh, axes=("data",), mode=mode)
    cp = engine.plan_chain((L,) * 3, L, donate=True, shard_spec=sp)
    np.testing.assert_allclose(np.asarray(cp.apply_jit(list(xs))),
                               np.asarray(ref_c), rtol=1e-4, atol=1e-4)
    g = jax.grad(lambda a: jnp.sum(cp.apply([a, xs[1], xs[2]]) ** 2))(xs[0])
    np.testing.assert_allclose(np.asarray(g), np.asarray(g0),
                               rtol=1e-3, atol=1e-3)

# ragged rows (7 % 2 != 0): the shard_map chain pads rows to the device
# count, combines per-shard, and slices back (see also the jaxpr-proven
# ragged test in test_chain_kernel.py)
sp = engine.ShardSpec(mesh=mesh, axes=("data",), mode="shard_map")
cp7 = engine.plan_chain((L, L), 2 * L, shard_spec=sp)
x7 = jnp.asarray(random_irreps(L, (7,), seed=40))
y7 = jnp.asarray(random_irreps(L, (7,), seed=41))
ref7 = engine.plan_chain((L, L), 2 * L).apply_jit([x7, y7])
np.testing.assert_allclose(np.asarray(cp7.apply_jit([x7, y7])),
                           np.asarray(ref7), rtol=1e-4, atol=1e-4)
# mixed leading ranks ([8,k] against [4,8,k]) broadcast fine unsharded and
# must keep working under a shard_map spec (fallback, not a dim0 mis-shard)
xa = jnp.asarray(random_irreps(L, (n,), seed=42))
xb = jnp.asarray(random_irreps(L, (4, n), seed=43))
ref_b = engine.plan_chain((L, L), 2 * L).apply_jit([xa, xb])
np.testing.assert_allclose(np.asarray(cp7.apply_jit([xa, xb])),
                           np.asarray(ref_b), rtol=1e-4, atol=1e-4)
print("MATRIX_OK")
"""
    _run_subprocess(code, "MATRIX_OK")


def test_mace_sharded_resident_matches_legacy_two_devices():
    """The acceptance gate: MaceGaunt with shard_data=True AND
    fourier_resident=True (both conv impls) matches the unsharded legacy
    path numerically on a 2-device mesh."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from repro.configs.gaunt_ff import EquivariantConfig
from repro.distributed.sharding import set_activation_mesh
from repro.models.equivariant import MaceGaunt

mesh = jax.make_mesh((2,), ("data",))
rng = np.random.default_rng(3)
species = jnp.asarray(rng.integers(0, 4, size=(6,)))
pos = jnp.asarray(rng.normal(size=(6, 3)) * 1.5, jnp.float32)
for conv_impl in ("escn", "general"):
    cfg = EquivariantConfig(name="t", kind="mace", L=1, L_edge=1, channels=4,
                            n_layers=2, nu=3, n_species=4, conv_impl=conv_impl,
                            shard_data=False, fourier_resident=False)
    model = MaceGaunt(cfg)
    params = model.init(jax.random.PRNGKey(0))
    e_legacy = float(model.energy(params, species, pos))
    set_activation_mesh(mesh)
    cfg_on = dataclasses.replace(cfg, shard_data=True, fourier_resident=True)
    e_on = float(MaceGaunt(cfg_on).energy(params, species, pos))
    set_activation_mesh(None)
    assert abs(e_on - e_legacy) < 1e-3 * max(1.0, abs(e_legacy)), (
        conv_impl, e_on, e_legacy)
print("MACE_SHARDED_RESIDENT_OK")
"""
    _run_subprocess(code, "MACE_SHARDED_RESIDENT_OK")
