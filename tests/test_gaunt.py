"""Correctness of the Gaunt Tensor Product — every path vs the dense real-Gaunt
einsum oracle, plus O(3) equivariance and the paper's parameterization hooks."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import so3
from repro.core.cg import cg_full_tensor_product, gaunt_einsum_reference
from repro.core.gaunt import (
    GauntTensorProduct,
    conv2d_full,
    expand_degree_weights,
    fourier_to_sh,
    gaunt_product_numpy,
    sh_to_fourier,
)
from repro.core.irreps import num_coeffs
from repro.testing import random_angles, random_array, wigner_D


def _rand(shape, seed=0):
    return jnp.asarray(random_array(shape, seed))


def test_numpy_pipeline_exact():
    rng = np.random.default_rng(1)
    for L1, L2 in [(1, 1), (2, 3), (4, 2), (5, 5)]:
        x1 = rng.normal(size=(3, num_coeffs(L1)))
        x2 = rng.normal(size=(3, num_coeffs(L2)))
        ref = np.einsum("bi,bj,ijk->bk", x1, x2, so3.real_gaunt_tensor(L1, L2, L1 + L2))
        got = gaunt_product_numpy(x1, x2, L1, L2)
        np.testing.assert_allclose(got, ref, atol=1e-12)


@pytest.mark.parametrize("conversion,conv", [
    ("dense", "fft"), ("dense", "direct"),
    ("packed", "fft"), ("packed", "direct"),
    ("half", "rfft"), ("half", "direct"), ("half", "auto"),
])
def test_jax_paths_match_oracle(conversion, conv):
    L1, L2 = 3, 2
    x1 = _rand((4, num_coeffs(L1)), 2)
    x2 = _rand((4, num_coeffs(L2)), 3)
    tp = GauntTensorProduct(L1, L2, conversion=conversion, conv=conv)
    got = tp(x1, x2)
    ref = gaunt_einsum_reference(x1, x2, L1, L2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=2e-5)


def test_s2f_packed_matches_dense():
    L = 4
    x = _rand((5, num_coeffs(L)), 4)
    Fd = sh_to_fourier(x, L, "dense")
    Fp = sh_to_fourier(x, L, "packed")
    np.testing.assert_allclose(np.asarray(Fd), np.asarray(Fp), atol=1e-5)


def test_f2s_packed_matches_dense():
    L1, L2, Lout = 3, 3, 4
    x1 = _rand((2, num_coeffs(L1)), 5)
    x2 = _rand((2, num_coeffs(L2)), 6)
    F = conv2d_full(sh_to_fourier(x1, L1), sh_to_fourier(x2, L2))
    a = fourier_to_sh(F, L1 + L2, Lout, "dense")
    b = fourier_to_sh(F, L1 + L2, Lout, "packed")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


def test_truncated_output_degree():
    L1, L2, Lout = 3, 3, 2
    x1 = _rand((2, num_coeffs(L1)), 7)
    x2 = _rand((2, num_coeffs(L2)), 8)
    tp = GauntTensorProduct(L1, L2, Lout=Lout)
    ref = gaunt_einsum_reference(x1, x2, L1, L2, Lout)
    np.testing.assert_allclose(np.asarray(tp(x1, x2)), np.asarray(ref), atol=2e-5)


def test_equivariance_rotation():
    """D(g) (x1 @G@ x2) == (D(g)x1) @G@ (D(g)x2) for random rotations."""
    L1, L2 = 2, 2
    Lout = L1 + L2
    x1 = random_array((num_coeffs(L1),), seed=9)
    x2 = random_array((num_coeffs(L2),), seed=19)
    tp = GauntTensorProduct(L1, L2)
    angles = random_angles(seed=9)
    D1 = wigner_D(L1, angles)
    D2 = wigner_D(L2, angles)
    D3 = wigner_D(Lout, angles)
    lhs = D3 @ np.asarray(tp(jnp.asarray(x1), jnp.asarray(x2)))
    rhs = np.asarray(tp(jnp.asarray(D1 @ x1), jnp.asarray(D2 @ x2)))
    np.testing.assert_allclose(lhs, rhs, atol=3e-5)


def test_equivariance_parity():
    """Inversion: degree-l inputs scale by (-1)^l; outputs must too."""
    L1, L2 = 2, 3
    rng = np.random.default_rng(10)
    x1 = rng.normal(size=num_coeffs(L1)).astype(np.float32)
    x2 = rng.normal(size=num_coeffs(L2)).astype(np.float32)
    from repro.core.irreps import l_array

    p1 = (-1.0) ** l_array(L1)
    p2 = (-1.0) ** l_array(L2)
    p3 = (-1.0) ** l_array(L1 + L2)
    tp = GauntTensorProduct(L1, L2)
    lhs = p3 * np.asarray(tp(jnp.asarray(x1), jnp.asarray(x2)))
    rhs = np.asarray(tp(jnp.asarray(p1 * x1), jnp.asarray(p2 * x2)))
    np.testing.assert_allclose(lhs, rhs, atol=3e-5)


def test_degree_weights_match_manual():
    L1, L2 = 2, 2
    x1 = _rand((num_coeffs(L1),), 11)
    x2 = _rand((num_coeffs(L2),), 12)
    w1 = _rand((L1 + 1,), 13)
    w2 = _rand((L2 + 1,), 14)
    w3 = _rand((L1 + L2 + 1,), 15)
    tp = GauntTensorProduct(L1, L2)
    got = tp(x1, x2, w1, w2, w3)
    ref = gaunt_einsum_reference(
        x1 * expand_degree_weights(w1, L1), x2 * expand_degree_weights(w2, L2), L1, L2
    ) * expand_degree_weights(w3, L1 + L2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=2e-5)


def test_cg_baseline_orthonormal_norm():
    """CG full TP preserves norm structure: for single paths the CG blocks are
    orthogonal maps — sanity that the baseline implementation is e3nn-faithful."""
    x1 = _rand((num_coeffs(1),), 16).at[0].set(0.0)  # isolate the (1,1,1) path
    x2 = _rand((num_coeffs(1),), 17).at[0].set(0.0)
    out = cg_full_tensor_product(x1, x2, 1, 1)
    # l3=0 component: dot product / sqrt(3)-ish; just check shape & finiteness
    assert out.shape == (num_coeffs(2),)
    assert bool(jnp.all(jnp.isfinite(out)))
    # path (1,1,1) is the cross product up to scale
    v1, v2 = np.asarray(x1)[1:4], np.asarray(x2)[1:4]
    # our packed order is m=-1,0,1 ~ (y, z, x)
    a = np.array([v1[2], v1[0], v1[1]])  # x, y, z
    b = np.array([v2[2], v2[0], v2[1]])
    cr = np.cross(a, b)
    got = np.asarray(out)[1:4]
    got_xyz = np.array([got[2], got[0], got[1]])
    ratio = got_xyz / cr
    assert np.abs(ratio - ratio[0]).max() < 1e-4


def test_gaunt_vs_cg_proportional_per_path():
    """Paper Eqn (3): per (l1,l2,l3) path the Gaunt product equals the CG
    product scaled by a path constant."""
    L1 = L2 = 2
    rng = np.random.default_rng(18)
    for l1 in range(L1 + 1):
        for l2 in range(L2 + 1):
            x1 = np.zeros(num_coeffs(L1), dtype=np.float32)
            x2 = np.zeros(num_coeffs(L2), dtype=np.float32)
            x1[l1 * l1 : (l1 + 1) ** 2] = rng.normal(size=2 * l1 + 1)
            x2[l2 * l2 : (l2 + 1) ** 2] = rng.normal(size=2 * l2 + 1)
            g = np.asarray(gaunt_einsum_reference(jnp.asarray(x1), jnp.asarray(x2), L1, L2))
            c = np.asarray(cg_full_tensor_product(jnp.asarray(x1), jnp.asarray(x2), L1, L2))
            for l3 in range(abs(l1 - l2), l1 + l2 + 1):
                sl = slice(l3 * l3, (l3 + 1) ** 2)
                if (l1 + l2 + l3) % 2 == 1:
                    assert np.abs(g[sl]).max() < 1e-5  # Gaunt kills odd paths
                    continue
                if np.abs(c[sl]).max() < 1e-6:
                    continue
                mask = np.abs(c[sl]) > 1e-4
                ratios = g[sl][mask] / c[sl][mask]
                assert np.abs(ratios - ratios[0]).max() < 1e-3


def test_channel_batched_shapes():
    L1 = L2 = 2
    tp = GauntTensorProduct(L1, L2)
    x1 = _rand((2, 8, num_coeffs(L1)), 19)
    x2 = _rand((2, 8, num_coeffs(L2)), 20)
    out = tp(x1, x2)
    assert out.shape == (2, 8, num_coeffs(4))


def test_jit_and_grad():
    L1 = L2 = 2
    tp = GauntTensorProduct(L1, L2)

    @jax.jit
    def f(x1, x2):
        return jnp.sum(tp(x1, x2) ** 2)

    x1 = _rand((num_coeffs(L1),), 21)
    x2 = _rand((num_coeffs(L2),), 22)
    g = jax.grad(f)(x1, x2)
    assert g.shape == x1.shape
    assert bool(jnp.all(jnp.isfinite(g)))
    # grad correctness vs oracle
    def f_ref(x1, x2):
        return jnp.sum(gaunt_einsum_reference(x1, x2, L1, L2) ** 2)

    g_ref = jax.grad(f_ref)(x1, x2)
    np.testing.assert_allclose(np.asarray(g), np.asarray(g_ref), atol=1e-3, rtol=1e-3)
