"""The unified Gaunt engine: cross-backend equivalence against the complex128
numpy oracle, plan/constant caching, capability filtering, and autotune."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import constants, engine
from repro.core.cg import gaunt_einsum_reference
from repro.core.gaunt import gaunt_product_numpy
from repro.core.irreps import num_coeffs
from repro.core.so3 import real_sph_harm_jax
from repro.testing import random_array

PAIRWISE = engine.available_backends("pairwise", requires_grad=False)
CONV = engine.available_backends("conv_filter", requires_grad=False)
MANYBODY = engine.available_backends("manybody", requires_grad=False)
CHANNEL_MIX = engine.available_backends("channel_mix", requires_grad=False)

# the full grid the acceptance criteria name: degrees up to L=6
GRID = [(1, 1, 2), (2, 3, 5), (4, 2, 3), (3, 3, 2), (6, 6, 12), (6, 4, 6)]


def _rand(shape, seed=0, dtype=jnp.float32):
    return jnp.asarray(random_array(shape, seed), dtype=dtype)


def test_registry_is_complete():
    assert set(PAIRWISE) == {"dense_einsum", "fft", "direct", "packed", "rfft",
                             "fused_xla", "fused_pallas"}
    assert set(CONV) == set(PAIRWISE) | {"escn_aligned"}
    assert set(MANYBODY) == {"dense_einsum", "fft", "direct", "packed", "rfft"}
    assert set(CHANNEL_MIX) == {"dense_einsum", "fused_xla"}


@pytest.mark.parametrize("backend", PAIRWISE)
@pytest.mark.parametrize("L1,L2,Lout", GRID)
def test_pairwise_backends_vs_numpy_oracle(backend, L1, L2, Lout):
    x1 = np.random.default_rng(1).normal(size=(4, num_coeffs(L1))).astype(np.float32)
    x2 = np.random.default_rng(2).normal(size=(4, num_coeffs(L2))).astype(np.float32)
    ref = gaunt_product_numpy(x1, x2, L1, L2, Lout)
    p = engine.plan(L1, L2, Lout, backend=backend, requires_grad=False)
    got = np.asarray(p.apply(jnp.asarray(x1), jnp.asarray(x2)))
    scale = max(1.0, np.abs(ref).max())
    np.testing.assert_allclose(got, ref, atol=1e-4 * scale)


@pytest.mark.parametrize("batch", [(), (5,), (2, 3)])
@pytest.mark.parametrize("backend", PAIRWISE)
def test_pairwise_backends_batch_shapes(backend, batch):
    L1, L2, Lout = 2, 2, 3
    x1 = np.random.default_rng(3).normal(size=batch + (num_coeffs(L1),)).astype(np.float32)
    x2 = np.random.default_rng(4).normal(size=batch + (num_coeffs(L2),)).astype(np.float32)
    ref = gaunt_product_numpy(x1, x2, L1, L2, Lout)
    p = engine.plan(L1, L2, Lout, backend=backend, requires_grad=False)
    got = np.asarray(p.apply(jnp.asarray(x1), jnp.asarray(x2)))
    assert got.shape == batch + (num_coeffs(Lout),)
    np.testing.assert_allclose(got, ref, atol=1e-4)


@pytest.mark.parametrize("backend",
                         engine.available_backends("pairwise", dtype="bfloat16",
                                                   requires_grad=False))
def test_pairwise_backends_bfloat16(backend):
    """bf16 storage vs the f32 oracle on quantized inputs — bounds from the
    shared per-precision tiers (repro.testing.tol_for)."""
    from repro.testing import assert_close

    L1, L2, Lout = 2, 2, 4
    x1 = _rand((8, num_coeffs(L1)), 5, jnp.bfloat16)
    x2 = _rand((8, num_coeffs(L2)), 6, jnp.bfloat16)
    ref = gaunt_product_numpy(np.asarray(x1, np.float32), np.asarray(x2, np.float32),
                              L1, L2, Lout)
    p = engine.plan(L1, L2, Lout, dtype="bfloat16", backend=backend,
                    requires_grad=False)
    got = np.asarray(p.apply(x1, x2), dtype=np.float32)
    assert_close(got, ref, dtype="bfloat16", tier="identity")


@pytest.mark.parametrize("backend", PAIRWISE)
def test_pairwise_backends_weight_hooks(backend):
    L1, L2, Lout = 2, 3, 4
    x1 = _rand((3, num_coeffs(L1)), 7)
    x2 = _rand((3, num_coeffs(L2)), 8)
    w1 = _rand((3, L1 + 1), 9)
    w2 = _rand((3, L2 + 1), 10)
    w3 = _rand((3, Lout + 1), 11)
    from repro.core.gaunt import expand_degree_weights

    ref = gaunt_einsum_reference(
        x1 * expand_degree_weights(w1, L1), x2 * expand_degree_weights(w2, L2),
        L1, L2, Lout) * expand_degree_weights(w3, Lout)
    p = engine.plan(L1, L2, Lout, backend=backend, requires_grad=False)
    got = p.apply(x1, x2, w1, w2, w3)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=2e-4)


@pytest.mark.parametrize("backend", CONV)
def test_conv_filter_backends_vs_oracle(backend):
    L1, L2, Lout = 2, 2, 3
    x = _rand((10, num_coeffs(L1)), 12)
    v = np.random.default_rng(13).normal(size=(10, 3))
    r = jnp.asarray(v / np.linalg.norm(v, axis=-1, keepdims=True), jnp.float32)
    filt = real_sph_harm_jax(L2, r).astype(jnp.float32)
    ref = gaunt_einsum_reference(x, filt, L1, L2, Lout)
    p = engine.plan(L1, L2, Lout, kind="conv_filter", backend=backend,
                    requires_grad=False)
    got = p.apply(x, r)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=5e-4)


@pytest.mark.parametrize("backend", MANYBODY)
def test_manybody_backends_vs_fold(backend):
    L, nu = 2, 3
    xs = [_rand((4, num_coeffs(L)), 20 + i) for i in range(nu)]
    acc = gaunt_einsum_reference(xs[0], xs[1], L, L)
    acc = gaunt_einsum_reference(acc, xs[2], 2 * L, L)
    p = engine.plan(kind="manybody", Ls=(L,) * nu, backend=backend)
    got = p.apply(xs)
    np.testing.assert_allclose(np.asarray(got), np.asarray(acc), atol=1e-3)


@pytest.mark.parametrize("backend", CHANNEL_MIX)
def test_channel_mix_backends_vs_loop(backend):
    L1, L2, Lout = 2, 1, 2
    C1, C2, E = 3, 2, 4
    x1 = _rand((2, C1, num_coeffs(L1)), 30)
    x2 = _rand((2, C2, num_coeffs(L2)), 31)
    w = _rand((C1, C2, E), 32)
    ref = jnp.einsum(
        "cde,...cdk->...ek", w,
        jnp.stack([jnp.stack([gaunt_einsum_reference(x1[:, c], x2[:, d], L1, L2, Lout)
                              for d in range(C2)], axis=1)
                   for c in range(C1)], axis=1))
    p = engine.plan(L1, L2, Lout, kind="channel_mix", backend=backend)
    got = p.apply(x1, x2, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=2e-4)


def test_plan_cache_hit_and_constants_built_once():
    """Planning the same op twice returns the same object and rebuilds no
    constants; applying it twice rebuilds no constants either."""
    eng = engine.get_engine()
    # unusual degrees so earlier tests have not warmed these cache entries
    p1 = eng.plan(5, 1, 4, backend="fft")
    stats_after_first = constants.cache_stats()
    p2 = eng.plan(5, 1, 4, backend="fft")
    assert p1 is p2
    x1 = _rand((2, num_coeffs(5)), 40)
    x2 = _rand((2, num_coeffs(1)), 41)
    jax.block_until_ready(p2.apply(x1, x2))
    jax.block_until_ready(p2.apply(x1, x2))
    stats_after_use = constants.cache_stats()
    misses_first = {k: v[1] for k, v in stats_after_first.items()}
    misses_use = {k: v[1] for k, v in stats_after_use.items()}
    assert misses_use == misses_first, "apply() rebuilt constants the plan owns"


def test_heuristic_selection_scales_with_batch():
    """Auto selection runs and returns an eligible backend at every size."""
    for B in (1, 64, 4096):
        p = engine.plan(4, 4, 4, batch_hint=B)
        assert p.backend in engine.available_backends("pairwise", requires_grad=True)


def test_grad_capability_filtering():
    # fused_pallas has no VJP: requires_grad must exclude it...
    with pytest.raises(ValueError):
        engine.plan(2, 2, 4, backend="fused_pallas", requires_grad=True)
    # ...and auto selection under grad must still differentiate fine
    p = engine.plan(2, 2, 4, batch_hint=16)
    x1 = _rand((16, num_coeffs(2)), 50)
    x2 = _rand((16, num_coeffs(2)), 51)
    g = jax.grad(lambda a, b: jnp.sum(p.apply(a, b) ** 2))(x1, x2)
    assert bool(jnp.all(jnp.isfinite(g)))


def test_measured_autotune_caches_choice():
    eng = engine.GauntEngine()
    key_kwargs = dict(batch_hint=32, tune="measure", requires_grad=False)
    p1 = eng.plan(1, 1, 2, **key_kwargs)
    assert p1.backend in PAIRWISE
    assert len(eng._measured) == 1
    p2 = eng.plan(1, 1, 2, **key_kwargs)
    assert p2 is p1
    assert len(eng._measured) == 1  # second plan reused the measurement


def test_selection_rule_rejected():
    with pytest.raises(ValueError):
        engine.plan(2, 2, 5)  # Lout > L1+L2


def test_float64_requests_normalized_consistently():
    """Regression (dtype-mismatch path): with x64 disabled, float64 requests
    must collapse onto the float32 plans — same PlanKey hash, same capability
    set, same cached plan — instead of building complex128 constants that
    every apply silently downcasts."""
    if jax.config.jax_enable_x64:
        pytest.skip("x64 enabled: float64 is a real dtype here")
    assert engine._dtype_str("float64") == "float32"
    assert engine._dtype_str(jnp.complex128) == "float32"
    # available_backends must agree with plan() on the effective dtype:
    # fused backends only support f32/bf16, so a phantom-f64 query would
    # wrongly exclude them
    assert (engine.available_backends("pairwise", dtype="float64")
            == engine.available_backends("pairwise", dtype="float32"))
    p64 = engine.plan(2, 2, 4, dtype="float64", backend="fft")
    p32 = engine.plan(2, 2, 4, dtype="float32", backend="fft")
    assert p64 is p32  # one cache entry, consistent PlanKey hashing
    assert p64.key.dtype == "float32"
    # the fused backend is reachable under a float64 request
    engine.plan(2, 2, 4, dtype="float64", backend="fused_xla")
    x1 = _rand((3, num_coeffs(2)), 70)
    out = p64.apply(x1, x1)
    assert out.dtype == jnp.float32
    with pytest.raises(ValueError):
        engine._dtype_str(jnp.int32)  # non-float requests are rejected


def test_jit_containing_plan_and_apply():
    """Plans can be created and applied inside a jit trace (wrappers do)."""

    @jax.jit
    def f(a, b):
        p = engine.plan(2, 2, 4, backend="fused_xla")
        return p.apply(a, b)

    x1 = _rand((4, num_coeffs(2)), 60)
    x2 = _rand((4, num_coeffs(2)), 61)
    ref = gaunt_einsum_reference(x1, x2, 2, 2)
    np.testing.assert_allclose(np.asarray(f(x1, x2)), np.asarray(ref), atol=2e-4)


# ---------------------------------------------------------------------------
# mixed precision: storage/accumulation split, dtype='auto', per-dtype calib
# ---------------------------------------------------------------------------


def test_plankey_storage_accumulation_split():
    """PlanKey.dtype is the STORAGE dtype; accumulation derives from it and
    never drops below f32 (DESIGN.md §3.6)."""
    k = engine.PlanKey(2, 2, 4, dtype="bfloat16")
    assert k.acc_dtype == "float32"
    assert engine.PlanKey(2, 2, 4, dtype="float32").acc_dtype == "float32"
    assert engine.PlanKey(2, 2, 4, dtype="float64").acc_dtype == "float64"
    assert k.with_dtype("float32") == engine.PlanKey(2, 2, 4, dtype="float32")


def test_dtype_auto_measures_both_precisions_and_caches():
    """dtype='auto' + tune='measure' times the f32 and bf16 siblings under
    one key family, picks bf16 only when it measured faster, and caches the
    family winner (second request returns the same plan object)."""
    eng = engine.GauntEngine()
    p = eng.plan(2, 2, 4, dtype="auto", tune="measure", batch_hint=64,
                 requires_grad=False)
    assert p.key.dtype in ("float32", "bfloat16")
    # winner cached under the 'auto' family key
    fam = engine.PlanKey(2, 2, 4, kind="pairwise", batch_hint=64, dtype="auto")
    assert eng._measured[fam] == p.key.dtype
    assert eng.plan(2, 2, 4, dtype="auto", tune="measure", batch_hint=64,
                    requires_grad=False) is p
    # the pick is justified: if bf16 won, its measured time beat f32's
    kb = fam.with_dtype("bfloat16")
    kf = fam.with_dtype("float32")
    if p.key.dtype == "bfloat16":
        assert eng._measured_t[kb] < eng._measured_t[kf]
    # heuristic mode never gambles: 'auto' resolves to float32
    assert eng.plan(2, 2, 4, dtype="auto", requires_grad=False).key.dtype == "float32"


def test_chain_dtype_auto_measures_and_caches():
    eng = engine.GauntEngine()
    cp = eng.plan_chain((2, 2), 2, dtype="auto", tune="measure", batch_hint=32)
    assert cp.dtype in ("float32", "bfloat16")
    assert eng.plan_chain((2, 2), 2, dtype="auto", tune="measure",
                          batch_hint=32) is cp
    # heuristic 'auto' resolves to float32
    assert eng.plan_chain((2, 2), 2, dtype="auto").dtype == "float32"
    x = _rand((32, num_coeffs(2)), 300)
    ref = eng.plan_chain((2, 2), 2, backend="tree").apply([x, x])
    from repro.testing import assert_close

    assert_close(np.asarray(cp.apply([x, x])).astype(np.float64),
                 np.asarray(ref), dtype=cp.dtype, tier="identity")


def test_calibration_is_keyed_by_dtype():
    """Satellite: calibrate_fused(dtype=...) installs a per-dtype factor and
    leaves the other precisions' entries untouched."""
    from repro.core.engine import get_calibration, set_calibration

    base = get_calibration()
    eng = engine.GauntEngine()
    try:
        rec = eng.calibrate_fused(L=2, B=32, dtype="bfloat16")
        assert rec["dtype"] == "bfloat16"
        cal = get_calibration()
        assert cal["fused_skinny:bfloat16_measured"]
        assert cal["fused_skinny:bfloat16"] == pytest.approx(rec["factor"],
                                                             rel=1e-2)
        # the f32 entry did not move
        assert cal["fused_skinny"] == base["fused_skinny"]
        assert cal["fused_skinny_measured"] == base["fused_skinny_measured"]
        # cost model reads the per-dtype factor
        kf = engine.PlanKey(4, 4, 4, kind="pairwise", batch_hint=256)
        kb = kf.with_dtype("bfloat16")
        set_calibration(**{"fused_skinny": 2.0, "fused_skinny:bfloat16": 8.0})
        assert engine._cost_fused(kb, pallas=False) > engine._cost_fused(kf, pallas=False)
    finally:
        set_calibration(**{k: v for k, v in base.items()})


def test_plan_batch_buckets_key_on_storage_dtype():
    """plan_batch keys its buckets on storage dtype: the same workload at
    f32 and bf16 builds distinct bucket plans with the right output dtypes."""
    items = [(2, 2, 4, 4)]
    bp32 = engine.plan_batch([(2, 2, 4)], kind="pairwise", dtype="float32")
    bpb = engine.plan_batch([(2, 2, 4)], kind="pairwise", dtype="bfloat16")
    a = _rand((4, num_coeffs(2)), 310)
    b = _rand((4, num_coeffs(2)), 311)
    out32 = bp32.apply([(a, b)])[0]
    outb = bpb.apply([(a.astype(jnp.bfloat16), b.astype(jnp.bfloat16))])[0]
    assert out32.dtype == jnp.float32 and outb.dtype == jnp.bfloat16
    from repro.testing import assert_close

    assert_close(np.asarray(outb).astype(np.float64), np.asarray(out32),
                 dtype="bfloat16", tier="identity")


# ---------------------------------------------------------------------------
# measured-autotune key corners the persistent cache keys on (DESIGN.md §4.5)
# ---------------------------------------------------------------------------


def test_chain_measure_key_batch_hint_quantization_edges():
    """batch_hint quantizes to the power-of-two ladder [8, 16384]: hints <= 8
    share the bottom rung, hints above the cap collapse to ONE key — the
    invariant that keeps the measured (and persisted) table bounded."""
    def q(b):
        return engine.GauntEngine._chain_measure_key(
            (2, 2), 2, "float32", b, None, "sh", None).batch_hint

    assert q(None) is None  # no hint: one unquantized key
    for b in (1, 2, 7, 8):
        assert q(b) == 8  # the ladder starts at 8
    assert q(9) == 16 and q(12) == 16
    assert q(16384) == 16384
    for b in (16385, 100_000, 10**9):
        assert q(b) == 16384  # everything above the cap is one key
    # quantized hints literally share a measurement key
    mk = engine.GauntEngine._chain_measure_key
    assert mk((2, 2), 2, "float32", 3, None, "sh", None) == \
        mk((2, 2), 2, "float32", 8, None, "sh", None)
    assert mk((2, 2), 2, "float32", 20_000, None, "sh", None) == \
        mk((2, 2), 2, "float32", 10**8, None, "sh", None)
    # ...but a distinct out/share hint still splits the family
    assert mk((2, 2), 2, "float32", 3, None, "fourier", None) != \
        mk((2, 2), 2, "float32", 3, None, "sh", None)


def test_auto_key_family_across_clear():
    """The dtype='auto' family key and its siblings live and die together:
    clear() empties every measurement store (and the timing counter), and a
    fresh measurement afterwards repopulates the family from scratch."""
    eng = engine.GauntEngine()
    p = eng.plan(1, 1, 2, dtype="auto", tune="measure", batch_hint=16,
                 requires_grad=False)
    fam = engine.PlanKey(1, 1, 2, kind="pairwise", batch_hint=16, dtype="auto")
    winner = eng._measured[fam]
    assert winner == p.key.dtype and winner in ("float32", "bfloat16")
    assert fam.with_dtype(winner) in eng._measured_t
    assert eng.timing_runs > 0
    eng.clear()
    assert eng._measured == {} and eng._measured_t == {}
    assert eng.timing_runs == 0
    p2 = eng.plan(1, 1, 2, dtype="auto", tune="measure", batch_hint=16,
                  requires_grad=False)
    assert eng._measured[fam] == p2.key.dtype


def test_clear_resets_calibration_so_fresh_engines_rank_identically():
    """Satellite: _CALIB is module-global — clear() must restore defaults so
    a calibrate_fused() run in one engine cannot skew another's rankings."""
    from repro.core.engine import (get_calibration, reset_calibration,
                                   set_calibration)

    base = get_calibration()
    try:
        reset_calibration()
        defaults = get_calibration()
        k = engine.PlanKey(6, 6, 6, kind="pairwise", batch_hint=64)
        pick_fresh = engine.GauntEngine().select(k)
        # a "measured" calibration from some other engine skews the model...
        set_calibration(fused_skinny=16.0, fused_skinny_measured=True)
        assert get_calibration() != defaults
        # ...until any engine's clear() restores the defaults
        engine.GauntEngine().clear()
        assert get_calibration() == defaults
        assert engine.GauntEngine().select(k) == pick_fresh
    finally:
        set_calibration(**base)
