"""Optional-hypothesis shim: property tests degrade to clean skips.

``from _hyp import given, settings, st`` instead of importing hypothesis
directly.  When hypothesis is installed the real decorators come through
untouched; when it is missing, @given marks the test skipped (with a clear
reason) and the strategy stubs accept any construction without error, so
module collection never fails.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    import pytest

    HAVE_HYPOTHESIS = False

    def given(*_args, **_kw):
        return pytest.mark.skip(reason="hypothesis not installed")

    def settings(*_args, **_kw):
        def deco(f):
            return f

        return deco

    class _StrategyStub:
        """Accepts any strategy construction (st.integers(...).filter(...))."""

        def __call__(self, *a, **kw):
            return self

        def __getattr__(self, name):
            return self

    st = _StrategyStub()
