"""Persistent per-host autotune cache (core/autotune_cache.py, DESIGN.md
§4.5): roundtrip + zero-timing warm start, fingerprint/corruption fallback,
stale-entry invalidation, merge-on-save, and the measured-selection failure
paths the persistence layer depends on (never cache a selection that was
never successfully run)."""
import json
import os
import subprocess
import sys

import pytest

from repro.core import autotune_cache as ac
from repro.core import engine


def _measure_some(eng):
    """One plan key + one chain key + one auto family, all measured."""
    p = eng.plan(1, 1, 2, batch_hint=32, tune="measure", requires_grad=False)
    cp = eng.plan_chain((1, 1), 1, tune="measure", batch_hint=32)
    pa = eng.plan(1, 1, 2, dtype="auto", batch_hint=32, tune="measure",
                  requires_grad=False)
    return p, cp, pa


# ---------------------------------------------------------------------------
# roundtrip + warm start
# ---------------------------------------------------------------------------


def test_roundtrip_warm_engine_zero_timing_runs(tmp_path):
    """A second engine pointed at the flushed cache answers every selection
    from the file: zero timing runs, identical picks."""
    path = str(tmp_path / "cache.json")
    cold = engine.GauntEngine(cache_path=path)
    p, cp, pa = _measure_some(cold)
    assert cold.timing_runs > 0
    # every measurement autoflushed; the file is already complete
    assert os.path.exists(path)

    warm = engine.GauntEngine(cache_path=path)
    p2, cp2, pa2 = _measure_some(warm)
    assert warm.timing_runs == 0
    assert (p2.backend, cp2.backend, pa2.key.dtype) == \
        (p.backend, cp.backend, pa.key.dtype)
    assert warm._measured == cold._measured


def test_load_is_lazy_and_in_process_wins(tmp_path):
    """The cache loads on the first measure-mode miss (not at construction),
    and an in-process measurement is never overwritten by the file's."""
    path = str(tmp_path / "cache.json")
    cold = engine.GauntEngine(cache_path=path)
    _measure_some(cold)

    warm = engine.GauntEngine(cache_path=path)
    assert not warm._cache_loaded and warm._measured == {}
    # pre-seed one in-process entry with a DIFFERENT (but real, eligible)
    # backend than the file's, then trigger the lazy load via a miss
    key = engine.PlanKey(1, 1, 2, kind="pairwise", batch_hint=32)
    assert key in cold._measured
    local_pick = "fft" if cold._measured[key] != "fft" else "direct"
    warm._measured[key] = local_pick
    p = warm.plan(1, 1, 2, batch_hint=32, tune="measure", requires_grad=False)
    assert warm._cache_loaded
    assert warm._measured[key] == local_pick  # file did not overwrite it
    assert p.backend == local_pick


def test_calibration_roundtrips_without_masquerading(tmp_path):
    """Persisted fused-cost factors apply on load — but only entries the
    file marks *_measured, and never over a locally measured value."""
    path = str(tmp_path / "cache.json")
    base = engine.get_calibration()
    try:
        cold = engine.GauntEngine(cache_path=path)
        rec = cold.calibrate_fused(L=2, B=32)
        cold.flush_autotune_cache()

        engine.reset_calibration()
        warm = engine.GauntEngine(cache_path=path)
        warm.load_autotune_cache()
        cal = engine.get_calibration()
        assert cal["fused_skinny_measured"]
        assert cal["fused_skinny"] == pytest.approx(rec["factor"], rel=1e-2)
        # the file's unmeasured per-dtype defaults were NOT applied as real
        assert not cal["fused_skinny:float64_measured"]

        # a locally measured value survives a load of a stale file
        engine.reset_calibration()
        engine.set_calibration(fused_skinny=9.5, fused_skinny_measured=True)
        warm2 = engine.GauntEngine(cache_path=path)
        warm2.load_autotune_cache()
        assert engine.get_calibration()["fused_skinny"] == 9.5
    finally:
        engine.set_calibration(**base)


# ---------------------------------------------------------------------------
# fallback paths: the cache must never break planning
# ---------------------------------------------------------------------------


def test_fingerprint_mismatch_falls_back_to_measurement(tmp_path):
    path = str(tmp_path / "cache.json")
    cold = engine.GauntEngine(cache_path=path)
    _measure_some(cold)
    raw = json.load(open(path))
    raw["fingerprint"]["jax_version"] = "0.0.0-other-host"
    json.dump(raw, open(path, "w"))

    assert ac.load(path) is None
    warm = engine.GauntEngine(cache_path=path)
    assert warm.load_autotune_cache() == 0
    p = warm.plan(1, 1, 2, batch_hint=32, tune="measure", requires_grad=False)
    assert warm.timing_runs > 0  # fell back to real measurement
    assert p.backend in engine.available_backends("pairwise",
                                                  requires_grad=False)


@pytest.mark.parametrize("content", ["{truncated", "", "[1, 2, 3]", "null"])
def test_corrupt_cache_falls_back_without_error(tmp_path, content):
    path = str(tmp_path / "cache.json")
    with open(path, "w") as f:
        f.write(content)
    assert ac.load(path) is None
    eng = engine.GauntEngine(cache_path=path)
    eng.plan(1, 1, 2, batch_hint=32, tune="measure", requires_grad=False)
    assert eng.timing_runs > 0
    # and the broken file is repaired by the autoflush
    assert ac.load(path) is not None


def test_missing_and_disabled_paths_are_noops(tmp_path):
    assert ac.load(str(tmp_path / "nope.json")) is None
    assert ac.load(None) is None
    eng = engine.GauntEngine()  # no path, no env: persistence off
    assert eng.load_autotune_cache() == 0
    assert eng.flush_autotune_cache() is None


def test_stale_entries_dropped_individually(tmp_path):
    """Entries naming unregistered backends / unknown kinds / non-storage
    dtype winners are dropped on load; valid neighbors survive."""
    path = str(tmp_path / "cache.json")
    cold = engine.GauntEngine(cache_path=path)
    _measure_some(cold)
    n_valid = len(cold._measured)
    raw = json.load(open(path))

    def fake(kind="pairwise", dtype="float32", backend="dense_einsum"):
        return {"key": {"L1": 1, "L2": 1, "Lout": 2, "kind": kind,
                        "batch_hint": 8, "dtype": dtype, "extra": []},
                "backend": backend, "t": 1.0}

    raw["selections"] += [
        fake(backend="warp_drive"),              # unregistered backend
        fake(kind="chain", backend="packed"),    # not a chain flavor
        fake(kind="sixbody"),                    # unknown kind
        fake(dtype="float16"),                   # unknown storage dtype
        fake(dtype="auto", backend="float16"),   # auto winner not a storage dtype
        {"backend": "fft", "t": 1.0},            # missing key entirely
    ]
    json.dump(raw, open(path, "w"))
    loaded = ac.load(path)
    assert loaded is not None
    assert len(loaded[0]) == n_valid  # every injected stale entry dropped


def test_save_merges_concurrent_same_fingerprint_entries(tmp_path):
    """Two processes flushing different keys to one file converge: save()
    folds in what the other wrote (local wins on collision)."""
    path = str(tmp_path / "cache.json")
    ka = engine.PlanKey(1, 1, 2, kind="pairwise", batch_hint=8)
    kb = engine.PlanKey(2, 2, 4, kind="pairwise", batch_hint=8)
    ac.save(path, {ka: "fft"}, {ka: 1.0})
    ac.save(path, {kb: "direct"}, {kb: 2.0})  # a "concurrent" process
    sel, tim, _ = ac.load(path)
    assert sel == {ka: "fft", kb: "direct"}
    assert tim == {ka: 1.0, kb: 2.0}
    # collision: the flushing process's own entry wins
    ac.save(path, {ka: "dense_einsum"}, {ka: 0.5})
    sel, tim, _ = ac.load(path)
    assert sel[ka] == "dense_einsum" and tim[ka] == 0.5


def test_unwritable_cache_degrades_to_in_process(tmp_path, monkeypatch):
    eng = engine.GauntEngine(cache_path=str(tmp_path / "cache.json"))

    def boom(*a, **kw):
        raise OSError("disk full")

    monkeypatch.setattr(ac, "save", boom)
    p = eng.plan(1, 1, 2, batch_hint=32, tune="measure", requires_grad=False)
    assert p.backend and len(eng._measured) >= 1  # planned + cached in-process
    with pytest.raises(OSError):
        eng.flush_autotune_cache()  # only the explicit flush surfaces it


def test_env_var_activates_persistence(tmp_path, monkeypatch):
    path = str(tmp_path / "env_cache.json")
    monkeypatch.setenv(ac.ENV_VAR, path)
    eng = engine.GauntEngine()  # no explicit path
    eng.plan(1, 1, 2, batch_hint=32, tune="measure", requires_grad=False)
    assert os.path.exists(path)
    assert ac.load(path) is not None


# ---------------------------------------------------------------------------
# measured-selection failure paths (the bugs that would poison a persisted
# cache): never cache — in-process or on disk — a selection that never ran
# ---------------------------------------------------------------------------


def test_select_chain_all_candidates_failed_is_not_cached(monkeypatch):
    """Satellite: when every chain candidate raises during timing, the safe
    'tree' default is returned but NOT pinned — a later healthy call
    re-measures and caches a real winner."""
    eng = engine.GauntEngine()

    def boom(self, xs, weights=None, w_out=None, out_basis="sh"):
        raise RuntimeError("synthetic all-candidate failure")

    monkeypatch.setattr(engine.ChainPlan, "apply_jit", boom)
    assert eng._select_chain((1, 1), 1, "float32", 32, sharded=False) == "tree"
    assert eng._measured == {} and eng._measured_t == {}

    monkeypatch.undo()
    eng._chains.clear()  # drop plans built during the failed pass
    name = eng._select_chain((1, 1), 1, "float32", 32, sharded=False)
    assert name in engine.CHAIN_BACKENDS
    key = engine.GauntEngine._chain_measure_key((1, 1), 1, "float32", 32,
                                                None, "sh", None)
    assert eng._measured[key] == name
    assert eng._measured_t[key] < float("inf")


def test_measure_fallback_is_not_cached(monkeypatch):
    """Satellite: when _measure falls back to the cost model (every backend
    failed), select() must not pin the never-run pick."""
    eng = engine.GauntEngine()
    key = engine.PlanKey(1, 1, 2, kind="pairwise", batch_hint=16)
    eligible = [b for b in engine._REGISTRY.values()
                if b.eligible(key, False)]

    monkeypatch.setattr(engine.GauntEngine, "_measure",
                        lambda self, k, e: ("dense_einsum", None))
    name = eng.select(key, tune="measure", requires_grad=False)
    assert name == "dense_einsum"
    assert eng._measured == {} and eng._measured_t == {}

    monkeypatch.undo()
    name2 = eng.select(key, tune="measure", requires_grad=False)
    assert name2 in [b.name for b in eligible]
    assert key in eng._measured and key in eng._measured_t


def test_auto_dtype_not_cached_without_timings(monkeypatch):
    """Satellite: a measurement pass that produced no timings must not pin
    'float32' under the auto key for the process lifetime (or the file)."""
    eng = engine.GauntEngine()
    monkeypatch.setattr(engine.GauntEngine, "_measure",
                        lambda self, k, e: ("dense_einsum", None))
    p = eng.plan(1, 1, 2, dtype="auto", batch_hint=16, tune="measure",
                 requires_grad=False)
    assert p.key.dtype == "float32"  # safe resolution...
    auto_key = engine.PlanKey(1, 1, 2, kind="pairwise", batch_hint=16,
                              dtype="auto")
    assert auto_key not in eng._measured  # ...but never a cached decision

    # chain flavor of the same rule
    def boom(self, xs, weights=None, w_out=None, out_basis="sh"):
        raise RuntimeError("synthetic failure")

    monkeypatch.setattr(engine.ChainPlan, "apply_jit", boom)
    assert eng._select_chain_dtype((1, 1), 1, 16, sharded=False,
                                   entry_hint=None, out_hint="sh",
                                   share_hint=None, tune="measure") == "float32"
    chain_auto = engine.GauntEngine._chain_measure_key(
        (1, 1), 1, "auto", 16, None, "sh", None)
    assert chain_auto not in eng._measured

    # the healthy path still caches the winner
    monkeypatch.undo()
    eng.clear()
    eng._select_chain_dtype((1, 1), 1, 16, sharded=False, entry_hint=None,
                            out_hint="sh", share_hint=None, tune="measure")
    assert eng._measured[chain_auto] in ("float32", "bfloat16")


# ---------------------------------------------------------------------------
# the acceptance proof: counter-proven warm serve start across processes
# ---------------------------------------------------------------------------

_SERVE_CHILD = r"""
import dataclasses, json, os
import numpy as np
import jax
from repro.configs.gaunt_ff import gaunt_mace_ff
from repro.models.equivariant import MaceGaunt
from repro.serve.engine import EquivariantRequest, EquivariantServeEngine
from repro.core import engine as ce

cfg = dataclasses.replace(gaunt_mace_ff, channels=4, n_layers=1, L=1,
                          L_edge=1, n_species=4, chain_tune="measure",
                          autotune_cache=os.environ["CACHE_PATH"])
model = MaceGaunt(cfg)
params = model.init(jax.random.PRNGKey(0))
eng = EquivariantServeEngine(model, params, n_slots=1, max_atoms=4,
                             warmup=True)
rng = np.random.default_rng(0)
req = EquivariantRequest(species=rng.integers(0, 4, 3),
                         pos=(rng.normal(size=(3, 3)) * 1.5).astype(np.float32))
out = eng.run([req])[0]
assert out.done
g = ce.get_engine()
g.flush_autotune_cache()
print("RUNS=" + str(g.timing_runs))
print("PICKS=" + json.dumps(sorted((repr(k), v)
                                   for k, v in g._measured.items())))
print("SERVE_OK")
"""


def _subprocess_env() -> dict:
    src = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(
        __file__))), "src")
    env = dict(os.environ)
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    env["JAX_PLATFORMS"] = "cpu"
    return env


def test_warm_serve_process_performs_zero_timing_runs(tmp_path):
    """ISSUE acceptance: a second process pointed at the populated cache
    file performs ZERO timing runs through serve warmup() + the first step,
    while selecting identically to the cold process."""
    env = _subprocess_env()
    env["CACHE_PATH"] = str(tmp_path / "serve_cache.json")
    out = []
    for _ in range(2):
        r = subprocess.run([sys.executable, "-c", _SERVE_CHILD],
                           capture_output=True, text=True, env=env,
                           timeout=900)
        assert "SERVE_OK" in r.stdout, (r.stdout[-2000:], r.stderr[-2000:])
        vals = dict(ln.split("=", 1) for ln in r.stdout.splitlines()
                    if "=" in ln)
        out.append((int(vals["RUNS"]), vals["PICKS"]))
    (cold_runs, cold_picks), (warm_runs, warm_picks) = out
    assert cold_runs > 0, "cold process should have measured something"
    assert warm_runs == 0, \
        f"warm process ran {warm_runs} timing passes (cache not consulted)"
    assert warm_picks == cold_picks, "warm selections diverged from cold"
