"""Property tests (hypothesis) for the distribution layer invariants."""
import jax
import numpy as np
import pytest
from _hyp import given, settings, st  # hypothesis, or clean skips when absent
from jax.sharding import Mesh, PartitionSpec as P

from repro.distributed.sharding import (
    batch_shardings,
    cache_shardings,
    choose_pspec,
    param_pspec,
)


def _mesh(shape=(2, 2), axes=("data", "model")):
    devs = np.array(jax.devices() * int(np.prod(shape)))[: int(np.prod(shape))]
    return Mesh(devs.reshape(shape), axes)


MESH = _mesh((1, 1))  # 1 CPU device; rules must still produce VALID specs


@given(
    st.lists(st.sampled_from([1, 2, 3, 8, 16, 60, 64, 128, 896, 6144]),
             min_size=1, max_size=4),
    st.lists(st.lists(st.sampled_from(["data", "model", "bogus"]), max_size=2),
             max_size=4),
)
@settings(max_examples=50, deadline=None)
def test_choose_pspec_always_valid(shape, prefs):
    """Any shape x any preference list -> a spec whose sharded dims divide."""
    mesh = MESH
    spec = choose_pspec(tuple(shape), mesh, prefs)
    assert len(spec) == len(shape)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    used = [a for a in spec if a is not None]
    assert len(used) == len(set(used))  # no axis reuse
    for dim, ax in zip(shape, spec):
        if ax is not None:
            assert dim % sizes[ax] == 0


@given(
    st.sampled_from([
        "layers/attn/wq/w", "layers/mlp/w_down/w", "layers/moe/we_gate",
        "embed/embedding", "unembed/w", "mamba/m/in_proj/w", "layers/tm/wo/w",
        "cat_proj/w", "layers/ln1/scale", "shared/attn/wk/b",
    ]),
    st.lists(st.sampled_from([1, 2, 16, 64, 128, 896, 2048, 50304]),
             min_size=1, max_size=4),
    st.sampled_from(["default", "dp_heavy", "moe_expert_tp"]),
)
@settings(max_examples=80, deadline=None)
def test_param_pspec_valid_for_any_leaf(key, shape, layout):
    spec = param_pspec(key, tuple(shape), MESH, layout)
    assert len(spec) == len(shape)
    sizes = dict(zip(MESH.axis_names, MESH.devices.shape))
    for dim, ax in zip(shape, spec):
        if ax is None:
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        n = int(np.prod([sizes[a] for a in axes]))
        assert dim % n == 0, (key, shape, spec)


@given(st.integers(1, 8), st.integers(1, 1024))
@settings(max_examples=30, deadline=None)
def test_batch_shardings_never_invalid(b, s):
    tree = {"tokens": jax.ShapeDtypeStruct((b, s), np.int32)}
    sh = batch_shardings(tree, MESH)
    # on a 1-device mesh everything is trivially valid; the contract we check
    # is structural: same tree, NamedSharding leaves
    assert set(sh) == {"tokens"}


@given(
    st.integers(1, 4),    # layers
    st.sampled_from([1, 2, 8, 128]),   # batch
    st.sampled_from([64, 4096, 32768]),  # seq
    st.sampled_from([1, 2, 8, 40]),   # kv heads
)
@settings(max_examples=30, deadline=None)
def test_cache_shardings_structural(L, B, S, KV):
    tree = {"k": jax.ShapeDtypeStruct((L, B, S, KV, 64), np.float16)}
    sh = cache_shardings(tree, MESH)
    spec = sh["k"].spec
    assert len(spec) == 5
    # never shards the layer or head-dim axes
    assert spec[0] is None and spec[4] is None


def test_int8_ef_compression_roundtrip_unbiased():
    """Error-feedback compression: mean over steps converges to true mean."""
    import jax.numpy as jnp

    from repro.distributed.collectives import _quant

    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(64,)) * 3.0, jnp.float32)
    e = jnp.zeros_like(x)
    acc = jnp.zeros_like(x)
    steps = 50
    for _ in range(steps):
        q, scale = _quant(x + e)
        deq = q.astype(jnp.float32) * scale
        e = (x + e) - deq
        acc = acc + deq
    np.testing.assert_allclose(np.asarray(acc / steps), np.asarray(x),
                               atol=0.05, rtol=0.02)
