"""jit/vmap/grad conformance for engine backends + batched-plan parity.

Three contracts:
* every differentiable backend survives jit, vmap, and grad with values
  matching the eager path (vmap vs Python loop, finite-difference gradients);
* the batched execution layer (`engine.plan_batch`) is numerically identical
  to per-plan loops for every backend, ragged sizes, weights, padding,
  broadcasting, and sharded dispatch included;
* the float-dtype plumbing around PlanKey stays consistent.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine
from repro.core.irreps import num_coeffs
from repro.testing import random_array, random_irreps, random_unit_vectors

PAIRWISE = engine.available_backends("pairwise", requires_grad=False)
PAIRWISE_GRAD = engine.available_backends("pairwise", requires_grad=True)
MANYBODY = engine.available_backends("manybody", requires_grad=False)
CONV = engine.available_backends("conv_filter", requires_grad=False)


def _j(a):
    return jnp.asarray(a)


# ---------------------------------------------------------------------------
# jit / vmap / grad conformance per backend
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", PAIRWISE)
def test_jit_matches_eager(backend):
    L1, L2, Lout = 2, 2, 3
    p = engine.plan(L1, L2, Lout, backend=backend, requires_grad=False)
    x1 = _j(random_irreps(L1, (6,), seed=1))
    x2 = _j(random_irreps(L2, (6,), seed=2))
    eager = p.apply(x1, x2)
    jitted = jax.jit(lambda a, b: p.apply(a, b))(x1, x2)
    np.testing.assert_allclose(np.asarray(jitted), np.asarray(eager),
                               rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("backend", PAIRWISE_GRAD)
def test_vmap_matches_loop(backend):
    """vmap over a stacked leading axis == Python loop over slices."""
    L1, L2, Lout = 2, 1, 3
    k, n = 4, 5
    p = engine.plan(L1, L2, Lout, backend=backend)
    x1 = _j(random_irreps(L1, (k, n), seed=3))
    x2 = _j(random_irreps(L2, (k, n), seed=4))
    vm = jax.vmap(lambda a, b: p.apply(a, b))(x1, x2)
    loop = jnp.stack([p.apply(x1[i], x2[i]) for i in range(k)])
    np.testing.assert_allclose(np.asarray(vm), np.asarray(loop),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("backend", PAIRWISE_GRAD)
def test_grad_finite_difference(backend):
    """<grad f, v> matches the central finite difference along v."""
    L1, L2, Lout = 2, 2, 2
    p = engine.plan(L1, L2, Lout, backend=backend)
    x1 = _j(random_irreps(L1, (3,), seed=5))
    x2 = _j(random_irreps(L2, (3,), seed=6))
    v = _j(random_irreps(L1, (3,), seed=7))

    def f(a):
        return jnp.sum(jnp.tanh(p.apply(a, x2)))

    g = jax.grad(f)(x1)
    assert bool(jnp.all(jnp.isfinite(g)))
    eps = 1e-2
    fd = (f(x1 + eps * v) - f(x1 - eps * v)) / (2 * eps)
    directional = jnp.sum(g * v)
    np.testing.assert_allclose(float(directional), float(fd),
                               rtol=2e-2, atol=2e-3)


@pytest.mark.parametrize("backend", MANYBODY)
def test_manybody_grad_and_vmap(backend):
    L, nu = 2, 3
    p = engine.plan(kind="manybody", Ls=(L,) * nu, Lout=L, backend=backend)
    xs = [_j(random_irreps(L, (4,), seed=10 + i)) for i in range(nu)]
    g = jax.grad(lambda a: jnp.sum(p.apply([a] + xs[1:]) ** 2))(xs[0])
    assert bool(jnp.all(jnp.isfinite(g)))
    stacked = [jnp.stack([x, 2 * x]) for x in xs]
    vm = jax.vmap(lambda *a: p.apply(list(a)))(*stacked)
    np.testing.assert_allclose(np.asarray(vm[0]), np.asarray(p.apply(xs)),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# batched-plan parity: plan_batch == per-plan loops, exactly
# ---------------------------------------------------------------------------

RAGGED = [(2, 2, 4, 7), (1, 1, 2, 4), (2, 2, 4, 3), (3, 2, 3, 5)]


@pytest.mark.parametrize("backend", PAIRWISE)
def test_plan_batch_matches_per_plan_loop(backend):
    bp = engine.plan_batch(RAGGED, backend=backend, requires_grad=False)
    ins = [(_j(random_irreps(L1, (n,), seed=i)),
            _j(random_irreps(L2, (n,), seed=50 + i)))
           for i, (L1, L2, Lout, n) in enumerate(RAGGED)]
    outs = bp.apply(ins)
    for (L1, L2, Lout, n), (x1, x2), got in zip(RAGGED, ins, outs):
        p = engine.plan(L1, L2, Lout, backend=backend, requires_grad=False)
        ref = p.apply(x1, x2)
        assert got.shape == (n, num_coeffs(Lout))
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("backend", PAIRWISE)
def test_plan_batch_weights_match_per_plan(backend):
    items = [(2, 3, 4, 5), (2, 3, 4, 2)]
    bp = engine.plan_batch(items, backend=backend, requires_grad=False)
    ins, ws = [], []
    for i, (L1, L2, Lout, n) in enumerate(items):
        ins.append((_j(random_irreps(L1, (n,), seed=i)),
                    _j(random_irreps(L2, (n,), seed=20 + i))))
        ws.append((_j(random_array((n, L1 + 1), seed=30 + i)), None,
                   _j(random_array((n, Lout + 1), seed=40 + i))))
    ws[1] = None  # second item unweighted — exercises the ones-fill path
    outs = bp.apply(ins, weights=ws)
    p = engine.plan(2, 3, 4, backend=backend, requires_grad=False)
    ref0 = p.apply(*ins[0], ws[0][0], None, ws[0][2])
    ref1 = p.apply(*ins[1])
    np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(ref0),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(outs[1]), np.asarray(ref1),
                               rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("backend", MANYBODY)
def test_plan_batch_manybody_matches_per_plan(backend):
    item = engine.BatchItem(Ls=(2, 2, 2), Lout=2)
    bp = engine.plan_batch([item], kind="manybody", backend=backend,
                           requires_grad=False)
    xs = [_j(random_irreps(2, (5,), seed=60 + i)) for i in range(3)]
    got = bp.apply([xs])[0]
    p = engine.plan(kind="manybody", Ls=(2, 2, 2), Lout=2, backend=backend,
                    requires_grad=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(p.apply(xs)),
                               rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("backend", CONV)
def test_plan_batch_conv_filter_matches_per_plan(backend):
    bp = engine.plan_batch([(2, 2, 3, 6)], kind="conv_filter", backend=backend,
                           requires_grad=False, pad_to=8)  # 6 rows -> 2 pad rows
    x = _j(random_irreps(2, (6,), seed=70))
    r = _j(random_unit_vectors((6,), seed=71))
    got = bp.apply([(x, r)])[0]
    p = engine.plan(2, 2, 3, kind="conv_filter", backend=backend,
                    requires_grad=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(p.apply(x, r)),
                               rtol=1e-5, atol=1e-5)
    assert bool(jnp.all(jnp.isfinite(got)))  # e_z padding keeps escn NaN-free


def test_plan_batch_broadcast_inner_dims():
    """One direction per edge against C channel features (the MACE layout)."""
    n, C = 4, 5
    x = _j(random_irreps(2, (n, n, C), seed=80))
    r = _j(random_unit_vectors((n, n, 1), seed=81))
    bp = engine.plan_batch([(2, 2, 2)], kind="conv_filter",
                           backend="escn_aligned")
    got = bp.apply([(x, r)])[0]
    p = engine.plan(2, 2, 2, kind="conv_filter", backend="escn_aligned")
    assert got.shape == (n, n, C, num_coeffs(2))
    np.testing.assert_allclose(np.asarray(got), np.asarray(p.apply(x, r)),
                               rtol=1e-5, atol=1e-5)


def test_plan_batch_weight_broadened_output():
    """Weights with leading dims beyond the operands' broadcast shape widen
    the output (the plan.apply 'w [..., L+1]' contract) — the batched layout
    must degrade to backend broadcasting, not raise."""
    x = _j(random_irreps(2, (), seed=120))       # unbatched operands
    r = _j(random_unit_vectors((), seed=121))
    w1 = _j(random_array((5, 3), seed=122))      # 5 weight sets -> out [5, ...]
    bp = engine.plan_batch([(2, 2, 2)], kind="conv_filter",
                           backend="escn_aligned")
    got = bp.apply([(x, r)], weights=[(w1, None, None)])[0]
    p = engine.plan(2, 2, 2, kind="conv_filter", backend="escn_aligned")
    ref = p.apply(x, r, w1)
    assert got.shape == ref.shape == (5, num_coeffs(2))
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_plan_batch_grad_matches_per_plan():
    bp = engine.plan_batch([(2, 2, 4, 6)])
    p = engine.plan(2, 2, 4)
    x1 = _j(random_irreps(2, (6,), seed=90))
    x2 = _j(random_irreps(2, (6,), seed=91))
    g_b = jax.grad(lambda a: jnp.sum(bp.apply([(a, x2)])[0] ** 2))(x1)
    g_p = jax.grad(lambda a: jnp.sum(p.apply(a, x2) ** 2))(x1)
    np.testing.assert_allclose(np.asarray(g_b), np.asarray(g_p),
                               rtol=1e-5, atol=1e-5)


def test_plan_batch_inside_jit():
    bp = engine.plan_batch([(1, 1, 2, 4), (2, 2, 4, 4)], requires_grad=False)
    ins = [(_j(random_irreps(1, (4,), seed=95)), _j(random_irreps(1, (4,), seed=96))),
           (_j(random_irreps(2, (4,), seed=97)), _j(random_irreps(2, (4,), seed=98)))]
    f = jax.jit(lambda a, b, c, d: bp.apply([(a, b), (c, d)])[1])
    ref = bp.apply(ins)[1]
    np.testing.assert_allclose(np.asarray(f(*ins[0], *ins[1])), np.asarray(ref),
                               rtol=1e-6, atol=1e-6)


def test_plan_batch_sharded_matches_unsharded():
    mesh = jax.make_mesh((1,), ("data",))
    x1 = _j(random_irreps(2, (8,), seed=100))
    x2 = _j(random_irreps(2, (8,), seed=101))
    ref = engine.plan_batch([(2, 2, 4, 8)], requires_grad=False).apply(
        [(x1, x2)])[0]
    for mode in ("constraint", "shard_map"):
        sp = engine.ShardSpec(mesh=mesh, axes=("data",), mode=mode)
        bp = engine.plan_batch([(2, 2, 4, 8)], shard_spec=sp,
                               requires_grad=False)
        got = bp.apply([(x1, x2)])[0]
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=1e-6, atol=1e-6)


def test_plan_batch_bucketing_and_cache():
    items = [(2, 2, 4, 4), (1, 1, 2, 4), (2, 2, 4, 9)]
    bp1 = engine.plan_batch(items, requires_grad=False)
    assert len(bp1.buckets) == 2  # two distinct signatures
    sizes = {tuple(sorted(b.item_ids)) for b in bp1.buckets}
    assert sizes == {(0, 2), (1,)}
    bp2 = engine.plan_batch(items, requires_grad=False)
    assert bp1 is bp2  # cached: jitted bucket callables stay stable
    assert "plan_batch" in bp1.describe()


def test_plan_batch_donate_flag_plumbing():
    bp = engine.plan_batch([(2, 2, 4, 4)], donate=True, requires_grad=False)
    assert bp.donate
    x1 = _j(random_irreps(2, (4,), seed=110))
    x2 = _j(random_irreps(2, (4,), seed=111))
    out = bp.apply([(x1, x2)])[0]  # on CPU donation is a no-op, not an error
    assert out.shape == (4, num_coeffs(4))


def test_plan_batch_rejects_channel_mix_and_bad_items():
    with pytest.raises(ValueError):
        engine.plan_batch([(1, 1, 2)], kind="channel_mix")
    with pytest.raises(ValueError):
        engine.plan_batch([])
    with pytest.raises(ValueError):
        engine.plan_batch([(1, 1)])
    with pytest.raises(ValueError):
        engine.plan_batch([engine.BatchItem(Ls=(2,))], kind="manybody")
