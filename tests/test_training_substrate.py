"""Optimizer / pipeline / checkpoint / train-loop / serving tests."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st  # hypothesis, or clean skips when absent

from repro.config import TrainConfig, get_config
from repro.checkpoint import CheckpointManager
from repro.data import LMTokenPipeline
from repro.models import build_model
from repro.optim import adamw, apply_updates, clip_by_global_norm, cosine_schedule
from repro.serve import Request, ServeEngine
from repro.train import make_train_step, train_loop


# ---------------------------------------------------------------- optimizer


def test_adamw_matches_reference_numpy():
    """One AdamW step vs a hand-written numpy reference."""
    lr = 1e-2
    opt = adamw(lambda s: jnp.asarray(lr), b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.1)
    params = {"w": jnp.asarray([[1.0, -2.0], [0.5, 3.0]]), "b": jnp.asarray([0.1, -0.1])}
    grads = {"w": jnp.asarray([[0.1, 0.2], [-0.3, 0.4]]), "b": jnp.asarray([0.01, -0.02])}
    st_ = opt.init(params)
    upd, st_ = opt.update(grads, st_, params)
    new = apply_updates(params, upd)
    # reference
    for k, decay in (("w", 0.1), ("b", 0.0)):
        g = np.asarray(grads[k])
        m = 0.1 * g
        v = 0.001 * g * g
        mh = m / (1 - 0.9)
        vh = v / (1 - 0.999)
        ref = np.asarray(params[k]) - lr * (mh / (np.sqrt(vh) + 1e-8) + decay * np.asarray(params[k]))
        np.testing.assert_allclose(np.asarray(new[k]), ref, atol=1e-6)


def test_clip_by_global_norm():
    g = {"a": jnp.full((3,), 10.0)}
    clipped, n = clip_by_global_norm(g, 1.0)
    np.testing.assert_allclose(float(n), np.sqrt(300.0), rtol=1e-5)
    np.testing.assert_allclose(
        float(jnp.linalg.norm(clipped["a"])), 1.0, rtol=1e-5)


def test_cosine_schedule_shape():
    lr = cosine_schedule(1.0, warmup=10, total=110)
    assert float(lr(jnp.asarray(0))) == 0.0
    np.testing.assert_allclose(float(lr(jnp.asarray(10))), 1.0, atol=1e-6)
    assert float(lr(jnp.asarray(110))) < 0.2


# ---------------------------------------------------------------- pipeline


@given(st.integers(0, 50), st.integers(1, 4))
@settings(max_examples=10, deadline=None)
def test_pipeline_deterministic_resume(step, n_hosts):
    gb = 8
    p1 = LMTokenPipeline(vocab=64, seq_len=16, global_batch=gb, seed=3)
    for _ in range(step):
        p1.next_batch()
    want = p1.next_batch()
    p2 = LMTokenPipeline(vocab=64, seq_len=16, global_batch=gb, seed=3)
    p2.restore({"step": step, "seed": 3})
    got = p2.next_batch()
    np.testing.assert_array_equal(want["tokens"], got["tokens"])


def test_pipeline_host_sharding_partitions_batch():
    gb = 8
    full = LMTokenPipeline(vocab=64, seq_len=8, global_batch=gb, seed=5).next_batch()
    parts = []
    for h in range(4):
        p = LMTokenPipeline(vocab=64, seq_len=8, global_batch=gb, seed=5,
                            host_id=h, n_hosts=4)
        parts.append(p.next_batch()["tokens"])
    np.testing.assert_array_equal(np.concatenate(parts, 0), full["tokens"])


# ---------------------------------------------------------------- checkpoint


def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"a": jnp.arange(10.0), "nested": {"b": jnp.ones((3, 4), jnp.bfloat16)}}
    mgr.save(5, tree, extra={"pipeline": {"step": 7, "seed": 1}}, blocking=True)
    assert mgr.latest_step() == 5
    restored, extra = mgr.restore(5, jax.eval_shape(lambda: tree))
    np.testing.assert_array_equal(np.asarray(restored["a"]), np.arange(10.0))
    assert extra["pipeline"]["step"] == 7
    assert restored["nested"]["b"].dtype == jnp.bfloat16


def test_checkpoint_retention_and_commit_protocol(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"a": jnp.zeros(4)}
    for s in (1, 2, 3):
        mgr.save(s, tree, blocking=True)
    assert mgr.all_steps() == [2, 3]
    # uncommitted dirs are ignored
    os.makedirs(tmp_path / "step_99")
    assert mgr.latest_step() == 3


def test_checkpoint_corruption_detected(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"a": jnp.arange(100.0)}
    mgr.save(1, tree, blocking=True)
    # corrupt the shard
    import numpy as np_

    path = tmp_path / "step_1" / "shard_0.npz"
    data = dict(np_.load(path))
    data["a"][0] = 999.0
    np_.savez(path, **data)
    with pytest.raises(IOError):
        mgr.restore(1, jax.eval_shape(lambda: tree))


def test_checkpoint_elastic_reshard(tmp_path):
    """Save unsharded, restore with explicit shardings on a host mesh."""
    mgr = CheckpointManager(str(tmp_path))
    tree = {"w": jnp.arange(16.0).reshape(4, 4)}
    mgr.save(1, tree, blocking=True)
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1), ("data",))
    sh = {"w": NamedSharding(mesh, P("data", None))}
    restored, _ = mgr.restore(1, jax.eval_shape(lambda: tree), shardings=sh)
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.arange(16.0).reshape(4, 4))
    assert restored["w"].sharding == sh["w"]


# ---------------------------------------------------------------- train loop


def _tiny_setup():
    cfg = get_config("qwen2-0.5b").reduced(n_layers=1, d_model=64, d_ff=128,
                                           vocab=64, n_heads=2, n_kv_heads=2,
                                           head_dim=32)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    pipe = LMTokenPipeline(vocab=cfg.vocab, seq_len=16, global_batch=4, seed=0)
    return cfg, m, params, pipe


def test_train_loop_loss_decreases(tmp_path):
    cfg, m, params, pipe = _tiny_setup()
    tcfg = TrainConfig(lr=5e-3, warmup_steps=2, total_steps=12, checkpoint_every=6,
                       log_every=1)
    state, hist = train_loop(m.loss, params, pipe, tcfg, ckpt_dir=str(tmp_path))
    assert hist[-1]["loss"] < hist[0]["loss"]
    assert state.step == 12


def test_train_loop_resume_from_checkpoint(tmp_path):
    cfg, m, params, pipe = _tiny_setup()
    tcfg = TrainConfig(lr=5e-3, warmup_steps=2, total_steps=6, checkpoint_every=3,
                       log_every=1)
    train_loop(m.loss, params, pipe, tcfg, ckpt_dir=str(tmp_path))
    # "crash" and resume with more steps
    tcfg2 = TrainConfig(lr=5e-3, warmup_steps=2, total_steps=9, checkpoint_every=3,
                        log_every=1)
    pipe2 = LMTokenPipeline(vocab=cfg.vocab, seq_len=16, global_batch=4, seed=0)
    state, hist = train_loop(m.loss, params, pipe2, tcfg2, ckpt_dir=str(tmp_path))
    assert state.step == 9
    assert pipe2.step == 9  # pipeline state resumed too


def test_grad_accumulation_equivalence():
    cfg, m, params, pipe = _tiny_setup()
    batch = {k: jnp.asarray(v) for k, v in pipe.next_batch().items()}
    t1 = TrainConfig(lr=1e-3, warmup_steps=1, total_steps=10, microbatch=0)
    t2 = TrainConfig(lr=1e-3, warmup_steps=1, total_steps=10, microbatch=2)
    s1, opt1 = make_train_step(m.loss, t1)
    s2, opt2 = make_train_step(m.loss, t2)
    p1, o1, m1 = jax.jit(s1)(params, opt1.init(params), batch)
    p2, o2, m2 = jax.jit(s2)(params, opt2.init(params), batch)
    # same data, same total gradient -> same update (loss is mean-reduced)
    d = max(float(jnp.max(jnp.abs(a - b)))
            for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
    assert d < 5e-3, d


# ---------------------------------------------------------------- serving


@pytest.mark.parametrize("arch", ["qwen2-0.5b", "rwkv6-3b"])
def test_serve_engine_continuous_batching(arch):
    cfg = get_config(arch).reduced()
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(1))
    eng = ServeEngine(m, params, n_slots=2, max_len=64)
    reqs = [Request(prompt=[1, 2, 3], max_new_tokens=4, rid=i) for i in range(4)]
    out = eng.run(reqs)
    assert all(r.done for r in out)
    assert all(len(r.output) == 4 for r in out)


def test_serve_engine_matches_forward_greedy():
    """Greedy engine tokens == argmax over teacher-forced forward logits."""
    cfg = get_config("qwen2-0.5b").reduced(capacity_factor=8.0)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(2))
    prompt = [5, 9, 2, 7]
    eng = ServeEngine(m, params, n_slots=2, max_len=32)
    req = Request(prompt=prompt, max_new_tokens=3)
    eng.run([req])
    # reference: step-by-step argmax with full forward
    toks = list(prompt)
    for _ in range(3):
        batch = {"tokens": jnp.asarray([toks], jnp.int32)}
        logits, _ = m.forward(params, batch)
        toks.append(int(jnp.argmax(logits[0, -1])))
    assert req.output == toks[len(prompt):], (req.output, toks[len(prompt):])


def test_serve_engine_budget_one_stops_at_one_token():
    """Stop-condition off-by-one regression: max_new_tokens=1 must yield
    EXACTLY the prefill-sampled token (the budget is checked at admission),
    not that token plus a decode step's extra one — and the slot must be
    free immediately for the next request."""
    cfg = get_config("qwen2-0.5b").reduced()
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(1))
    eng = ServeEngine(m, params, n_slots=1, max_len=32)
    reqs = [Request(prompt=[1, 2, 3], max_new_tokens=1, rid=i)
            for i in range(3)]
    out = eng.run(reqs)
    assert all(r.done for r in out)
    assert [len(r.output) for r in out] == [1, 1, 1]
    assert eng.slot_req == [None]
    # the single token must equal the greedy argmax over the prompt logits
    logits, _ = m.forward(params, {"tokens": jnp.asarray([[1, 2, 3]],
                                                         jnp.int32)})
    assert out[0].output == [int(jnp.argmax(logits[0, -1]))]


def test_serve_engine_budget_one_leaves_cache_clean():
    """Fast-retire regression: a max_new_tokens=1 request retires at
    admission WITHOUT occupying a slot, so its prefill must not leave that
    slot's cache rows dirty — the cache after the fast-retire is exactly
    the cache before it (a later tenant of the slot starts from the same
    state it would have without the fast-retire)."""
    cfg = get_config("qwen2-0.5b").reduced()
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(1))
    eng = ServeEngine(m, params, n_slots=1, max_len=32)
    before = jax.tree.map(np.asarray, eng.cache)
    eng.run([Request(prompt=[1, 2, 3], max_new_tokens=1, rid=0)])
    after = jax.tree.map(np.asarray, eng.cache)
    for a, b in zip(jax.tree.leaves(before), jax.tree.leaves(after)):
        np.testing.assert_array_equal(a, b)
    # and a normal request through the same slot afterwards decodes exactly
    # as it would on a fresh engine
    req = Request(prompt=[4, 5], max_new_tokens=3, rid=1)
    eng.run([req])
    fresh = Request(prompt=[4, 5], max_new_tokens=3, rid=1)
    ServeEngine(m, params, n_slots=1, max_len=32).run([fresh])
    assert req.output == fresh.output


def test_serve_sampling_reproducible_across_admission_order():
    """Sampled outputs derive from (engine seed, rid, token index): the
    same request sampled at temperature>0 produces the SAME tokens no
    matter what other requests share the batch or which order admission
    happened in."""
    cfg = get_config("qwen2-0.5b").reduced()
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(2))

    def serve(order, n_slots):
        reqs = [Request(prompt=[3 + r, 5, 2], max_new_tokens=4,
                        temperature=0.8, rid=r) for r in order]
        ServeEngine(m, params, n_slots=n_slots, max_len=32, seed=7).run(reqs)
        return {r.rid: list(r.output) for r in reqs}

    a = serve([0, 1, 2, 3], n_slots=2)
    b = serve([3, 2, 1, 0], n_slots=1)  # reversed admission, serial slots
    assert a == b
    # a different engine seed must change the stream (keys really fold it in)
    reqs = [Request(prompt=[3, 5, 2], max_new_tokens=4, temperature=0.8)]
    ServeEngine(m, params, n_slots=1, max_len=32, seed=8).run(reqs)
    assert any(list(reqs[0].output) != v for v in a.values())


def test_elastic_reshard_live_tree():
    """distributed/elastic: live pytree moves onto a new mesh (1-dev host)."""
    from repro.distributed.elastic import reshard_tree, restore_on_mesh
    from repro.launch.mesh import make_host_mesh

    mesh = make_host_mesh(data=1, model=1)
    tree = {"layers": {"mlp": {"w_up": {"w": jnp.ones((8, 16))}}},
            "ln_f": {"scale": jnp.ones((8,))}}
    out = reshard_tree(tree, mesh)
    np.testing.assert_array_equal(np.asarray(out["ln_f"]["scale"]), np.ones(8))
    assert out["layers"]["mlp"]["w_up"]["w"].sharding.mesh.shape == dict(mesh.shape)


def test_elastic_restore_on_mesh(tmp_path):
    from repro.checkpoint import CheckpointManager
    from repro.distributed.elastic import restore_on_mesh
    from repro.launch.mesh import make_host_mesh

    mgr = CheckpointManager(str(tmp_path))
    tree = {"embed": {"embedding": jnp.arange(32.0).reshape(4, 8)}}
    mgr.save(3, tree, blocking=True)
    mesh = make_host_mesh(data=1, model=1)
    restored, _ = restore_on_mesh(mgr, 3, jax.eval_shape(lambda: tree), mesh)
    np.testing.assert_array_equal(np.asarray(restored["embed"]["embedding"]),
                                  np.arange(32.0).reshape(4, 8))
