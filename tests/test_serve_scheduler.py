"""The serve scheduler and slot pools (DESIGN.md §10): deadline expiry,
priority ordering, FIFO discipline, bucket-selection boundaries, and the
counter-proof that a small-bucket request never triggers a larger bucket's
compile."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs.gaunt_ff import gaunt_mace_ff
from repro.models.equivariant import MaceGaunt
from repro.serve.engine import EquivariantRequest, EquivariantServeEngine
from repro.serve.metrics import ServeMetrics, percentile
from repro.serve.pools import BucketedPools, BucketSpec, default_buckets
from repro.serve.scheduler import (AdmissionQueue, REASON_DEADLINE,
                                   REASON_INVALID, Scheduler)


class FakeClock:
    def __init__(self, t: float = 0.0):
        self.t = t

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        self.t += dt


@dataclasses.dataclass
class _Req:
    rid: int = 0
    priority: int = 0
    deadline: float | None = None
    invalid: str | None = None   # stub validation verdict
    done: bool = False
    rejected: bool = False
    reject_reason: str | None = None


class _StubEngine:
    """Capacity-limited engine stub: records admission order, completes
    every active request per step."""

    def __init__(self, capacity: int = 1):
        self.capacity = capacity
        self.active: list[_Req] = []
        self.admitted_order: list[int] = []
        self.metrics = None

    def validate(self, req):
        return (REASON_INVALID, req.invalid) if req.invalid else None

    def try_admit(self, req) -> bool:
        if len(self.active) >= self.capacity:
            return False
        self.active.append(req)
        self.admitted_order.append(req.rid)
        return True

    def has_active(self) -> bool:
        return bool(self.active)

    def step(self, overlap=None):
        stepping, self.active = self.active, []
        if overlap is not None:
            overlap()
        for r in stepping:
            r.done = True


# --------------------------------------------------------------- the queue


def test_queue_priority_order_fifo_within_class():
    clock = FakeClock()
    q = AdmissionQueue(clock)
    for rid, prio in [(0, 1), (1, 0), (2, 1), (3, 0), (4, 2)]:
        q.submit(_Req(rid=rid, priority=prio))
    # priority ascending, submission order within each priority class
    assert [q.pop().rid for _ in range(len(q))] == [1, 3, 0, 2, 4]


def test_queue_expire_removes_only_stale():
    clock = FakeClock()
    q = AdmissionQueue(clock)
    q.submit(_Req(rid=0, deadline=1.0))
    q.submit(_Req(rid=1, deadline=5.0))
    q.submit(_Req(rid=2))                  # no deadline: never expires
    clock.advance(2.0)
    assert [r.rid for r in q.expire()] == [0]
    assert len(q) == 2


def test_queue_requeue_preserves_fifo_standing():
    clock = FakeClock()
    q = AdmissionQueue(clock)
    a, b = _Req(rid=0), _Req(rid=1)
    q.submit(a)
    q.submit(b)
    popped = q.pop()
    assert popped is a
    q.requeue(a)                       # blocked, not consumed
    assert q.pop() is a                # still ahead of b
    assert q.pop() is b


# ----------------------------------------------------------- the scheduler


def test_deadline_expired_rejected_with_structured_reason():
    clock = FakeClock()
    eng = _StubEngine(capacity=1)
    sched = Scheduler(eng, clock=clock, metrics=ServeMetrics(clock=clock))
    fresh, stale = _Req(rid=0), _Req(rid=1, deadline=0.5)
    sched.submit(fresh)
    sched.submit(stale)
    clock.advance(1.0)                 # stale's queue wait exceeds deadline
    sched.drain()
    assert fresh.done and not fresh.rejected
    assert stale.rejected and stale.done
    assert stale.reject_reason.startswith(REASON_DEADLINE)
    assert sched.metrics.counters[f"rejected:{REASON_DEADLINE}"] == 1
    assert eng.admitted_order == [0]   # the expired request never admitted


def test_admission_respects_priority_then_fifo():
    eng = _StubEngine(capacity=1)      # serial: admission order observable
    sched = Scheduler(eng, clock=FakeClock())
    reqs = [_Req(rid=0, priority=1), _Req(rid=1, priority=0),
            _Req(rid=2, priority=1), _Req(rid=3, priority=0)]
    sched.run(list(reqs))
    assert all(r.done for r in reqs)
    assert eng.admitted_order == [1, 3, 0, 2]


def test_blocked_request_requeued_without_losing_position():
    eng = _StubEngine(capacity=1)
    sched = Scheduler(eng, clock=FakeClock())
    a, b, c = _Req(rid=0), _Req(rid=1), _Req(rid=2)
    sched.submit(a)
    sched.submit(b)
    assert sched.admit_ready() == 1    # a admitted, b blocked + requeued
    sched.submit(c)
    eng.step()                         # a completes, capacity frees
    sched.drain()
    assert eng.admitted_order == [0, 1, 2]


def test_invalid_requests_rejected_by_engine_validator():
    eng = _StubEngine(capacity=4)
    sched = Scheduler(eng, clock=FakeClock())
    bad = _Req(rid=0, invalid="broken geometry")
    good = _Req(rid=1)
    sched.run([bad, good])
    assert bad.rejected and bad.reject_reason == \
        f"{REASON_INVALID}:broken geometry"
    assert good.done and not good.rejected
    assert eng.admitted_order == [1]


# ------------------------------------------------------------ the metrics


def test_percentile_interpolates():
    assert percentile([], 99) == 0.0
    assert percentile([5.0], 50) == 5.0
    xs = list(range(1, 101))
    assert percentile(xs, 50) == pytest.approx(50.5)
    assert percentile(xs, 99) == pytest.approx(99.01)
    assert percentile(xs, 100) == 100.0


def test_metrics_padding_and_occupancy_gauges():
    m = ServeMetrics(clock=FakeClock())
    m.observe_step("small", active=2, n_slots=4, real_atoms=6,
                   padded_atoms=12, dur_s=0.01)
    m.observe_step("large", active=1, n_slots=4, real_atoms=20,
                   padded_atoms=64, dur_s=0.02)
    assert m.padding_efficiency() == pytest.approx(26 / 76)
    assert m.occupancy_mean() == pytest.approx(3 / 8)
    s = m.summary()
    assert s["steps"] == 2
    assert s["pool:small:padding_efficiency"] == pytest.approx(0.5)
    assert "engine_timing_runs" in s and "conversions" in s


def test_metrics_latency_pipeline():
    clock = FakeClock()
    m = ServeMetrics(clock=clock)
    r = _Req()
    m.observe_submit(r)
    clock.advance(0.5)
    m.observe_admit(r)
    clock.advance(1.5)
    m.observe_complete(r)
    s = m.summary()
    assert s["queue_wait_p50_ms"] == pytest.approx(500.0)
    assert s["latency_p50_ms"] == pytest.approx(2000.0)
    assert s["completed"] == 1


# ----------------------------------------------------------------- buckets


def test_default_buckets_ladder():
    specs = default_buckets(256, n_slots=4)
    assert [s.max_atoms for s in specs] == [64, 128, 256]
    assert [s.name for s in specs] == ["small", "medium", "large"]
    assert all(s.n_slots == 4 for s in specs)
    assert [s.max_atoms for s in default_buckets(4)] == [2, 4]
    assert [s.max_atoms for s in default_buckets(2)] == [2]


def test_duplicate_bucket_sizes_rejected():
    with pytest.raises(ValueError):
        BucketedPools(None, None, [BucketSpec(8, 1), BucketSpec(8, 2)])


@pytest.fixture(scope="module")
def small_model():
    cfg = dataclasses.replace(gaunt_mace_ff, channels=8, n_layers=1, L=1,
                              L_edge=1, n_species=4)
    model = MaceGaunt(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return model, params


def test_bucket_selection_boundaries(small_model):
    """select() routes to the SMALLEST bucket that fits, with exact
    boundary behavior at every bucket edge."""
    model, params = small_model
    pools = BucketedPools(model, params,
                          [BucketSpec(4, 1), BucketSpec(8, 1),
                           BucketSpec(16, 1)])
    assert pools.select(1).spec.max_atoms == 4
    assert pools.select(4).spec.max_atoms == 4    # boundary: exact fit
    assert pools.select(5).spec.max_atoms == 8    # boundary + 1: next bucket
    assert pools.select(8).spec.max_atoms == 8
    assert pools.select(9).spec.max_atoms == 16
    assert pools.select(16).spec.max_atoms == 16
    assert pools.select(17) is None
    assert pools.max_atoms == 16


def _mol(n, seed):
    rng = np.random.default_rng(seed)
    return (rng.integers(0, 4, n),
            (rng.normal(size=(n, 3)) * 1.5).astype(np.float32))


def test_small_requests_never_compile_the_large_bucket(small_model):
    """Counter-proof: a workload that fits the small bucket leaves the
    large bucket's step function UNCOMPILED (its jit cache stays empty) and
    never steps it — bucketing really isolates compilation, it does not
    just relabel slots."""
    model, params = small_model
    eng = EquivariantServeEngine(model, params,
                                 buckets=[(4, 2), (12, 2)])
    small_pool, large_pool = eng.pools.pools
    assert not small_pool.compiled() and not large_pool.compiled()
    reqs = [EquivariantRequest(*_mol(2 + i % 3, seed=i), rid=i)
            for i in range(5)]                      # all <= 4 atoms
    out = eng.run(reqs)
    assert all(r.done and not r.rejected for r in out)
    assert small_pool.compiled() and small_pool.steps_run > 0
    assert not large_pool.compiled(), \
        "a small-bucket workload compiled the large bucket's step"
    assert large_pool.steps_run == 0
    assert "large" not in {k.split(":")[1]
                           for k in eng.metrics.summary() if ":" in k}
    # and the large bucket still works when a large request does arrive
    big = EquivariantRequest(*_mol(10, seed=99), rid=99)
    eng.run([big])
    assert big.done and large_pool.compiled() and large_pool.steps_run == 1
