"""S^2 quadrature (DESIGN.md §6.5): exactness at the predicted order,
aliasing decay under oversampling, Rep-level grid residency counters, and
rotation equivariance of the grid-resident gate.

The quadrature constants are plain numpy float64, so the exactness tests
run at full precision without an x64 subprocess.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import constants
from repro.core.fourier import s2quad_exact_degree, s2quad_size
from repro.core.rep import Rep, conversion_stats
from repro.models.equivariant import _gate_quad, gate_apply
from repro.testing import assert_close, random_angles, random_irreps, rotate_irreps


def _sigmoid(v):
    return 1.0 / (1.0 + np.exp(-v))


# --------------------------------------------------------------------------
# quadrature rule: numpy float64 exactness
# --------------------------------------------------------------------------


@pytest.mark.parametrize("L", [1, 2, 3])
def test_roundtrip_exact_at_os1(L):
    # degree-L coeffs -> samples -> coeffs needs integrands of degree 2L,
    # within the os=1 exact degree 2L+1: sample @ project == identity.
    nt, nph = s2quad_size(L, 1)
    I = constants.quad_sample_sh(L, nt, nph) @ constants.quad_project_sh(L, nt, nph)
    assert np.max(np.abs(I - np.eye((L + 1) ** 2))) < 1e-12


def test_exact_degree_bound_is_sharp():
    # On the os=2 grid for L=1 (n_t=4, n_phi=8) the predicted exact degree
    # is 7: the SH Gram matrix is the identity exactly up to the largest L'
    # with 2L' <= 7 (L'=3) and breaks at L'=4.
    nt, nph = s2quad_size(1, 2)
    assert s2quad_exact_degree(nt, nph) == 7
    ok = constants.quad_sample_sh(3, nt, nph) @ constants.quad_project_sh(3, nt, nph)
    assert np.max(np.abs(ok - np.eye(16))) < 1e-12
    bad = constants.quad_sample_sh(4, nt, nph) @ constants.quad_project_sh(4, nt, nph)
    assert np.max(np.abs(bad - np.eye(25))) > 1e-2


@pytest.mark.parametrize("L", [1, 2])
def test_polynomial_gate_exact_at_predicted_order(L):
    # Squaring a degree-L signal and projecting to 2L integrates degree-4L
    # content: exact at os=2 (degree 4L+3 resolved), aliased at os=1
    # (degree 2L+1 only).  Exactness is shown as os=2 == os=4 at f64.
    rng = np.random.default_rng(0)
    x = rng.normal(size=(5, (L + 1) ** 2))

    def squared(os):
        nt, nph = s2quad_size(L, os)
        v = x @ constants.quad_sample_sh(L, nt, nph)
        return v**2 @ constants.quad_project_sh(2 * L, nt, nph)

    assert np.max(np.abs(squared(2) - squared(4))) < 1e-12
    assert np.max(np.abs(squared(1) - squared(4))) > 1e-4


def test_sigmoid_aliasing_bounded_and_monotone():
    # A transcendental sample map aliases at every finite order, but its
    # smooth spectrum decays fast: the projection error vs a dense (os=16)
    # reference is bounded and shrinks monotonically with oversampling.
    L = 2
    rng = np.random.default_rng(0)
    x = rng.normal(size=(5, (L + 1) ** 2)) * 0.5

    def proj(os):
        nt, nph = s2quad_size(L, os)
        v = _sigmoid(x @ constants.quad_sample_sh(L, nt, nph))
        return v @ constants.quad_project_sh(L, nt, nph)

    ref = proj(16)
    errs = [np.max(np.abs(proj(os) - ref)) for os in (1, 2, 4)]
    assert errs[0] < 1e-2  # bounded even at critical sampling
    assert errs[0] > errs[1] > errs[2]
    assert errs[2] < 1e-9


# --------------------------------------------------------------------------
# Rep-level grid residency
# --------------------------------------------------------------------------


def test_rep_sh_quad_roundtrip_ticks_counters():
    L = 2
    x = random_irreps(L, (4, 3), seed=1)
    with conversion_stats(fresh=True) as stats:
        back = Rep.from_sh(x, L).to_quad().to_sh()
    assert stats["sh_to_quad"] == 1
    assert stats["quad_to_sh"] == 1
    assert back.basis == "sh"
    assert_close(back.data, x, "float32", tier="identity")


def test_rep_fourier_quad_legs():
    # fourier -> quad -> fourier residency uses the single-transform legs
    # (one counter tick each), and the quad detour is value-exact.
    L = 2
    x = random_irreps(L, (4,), seed=2)
    with conversion_stats(fresh=True) as stats:
        r = Rep.from_sh(x, L).to_fourier("half").to_quad()
        back = r.to_fourier().to_sh()
    assert stats["fourier_to_quad"] == 1
    assert stats["quad_to_fourier"] == 1
    assert stats["sh_to_quad"] == 0 and stats["quad_to_sh"] == 0
    assert_close(back.data, x, "float32", tier="transform")


def test_rep_quad_error_paths():
    L = 1
    x = random_irreps(L, (2,), seed=3)
    sh = Rep.from_sh(x, L)
    with pytest.raises(ValueError, match="apply_pointwise requires"):
        sh.apply_pointwise(lambda v: v)
    q = sh.to_quad(os=2)
    with pytest.raises(ValueError, match="resampling"):
        q.to_quad(os=4)
    with pytest.raises(ValueError, match="cannot raise"):
        q.to_sh(L + 1)


def test_quad_gate_matches_gate_apply():
    # The gate is affine in the signal (g*f + beta*Y00 with g, beta from
    # the l=0 scalars), so the quadrature evaluation matches the SH-side
    # gate at any oversampling — including critical sampling.
    L = 2
    x = jnp.asarray(random_irreps(L, (5, 4), seed=4))
    rng = np.random.default_rng(5)
    p = {"w1": jnp.asarray(rng.normal(size=(4, 16)) * 0.3, jnp.float32),
         "w2": jnp.asarray(rng.normal(size=(16, 4)) * 0.3, jnp.float32)}
    ref = gate_apply(p, x, L)
    for os in (1, 2):
        assert_close(_gate_quad(p, x, L, os=os), ref, "float32", tier="transform")


# --------------------------------------------------------------------------
# rotation equivariance of the grid-gate path
# --------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_grid_gate_rotation_equivariance(dtype):
    # The gate scalars live in l=0 (rotation-invariant), so gating commutes
    # with rotation.  bf16 inputs are pre-quantized so both orders see the
    # same representable values.
    L = 2
    x32 = random_irreps(L, (6, 4), seed=6)
    if dtype == "bfloat16":
        x32 = np.asarray(
            jnp.asarray(x32).astype(jnp.bfloat16).astype(jnp.float32))
    x = jnp.asarray(x32, jnp.dtype(dtype))
    rng = np.random.default_rng(7)
    p = {"w1": jnp.asarray(rng.normal(size=(4, 16)) * 0.3, jnp.float32),
         "w2": jnp.asarray(rng.normal(size=(16, 4)) * 0.3, jnp.float32)}
    ang = random_angles(8)
    gate_then_rot = rotate_irreps(
        np.asarray(_gate_quad(p, x, L), dtype=np.float32), L, ang)
    rot_then_gate = _gate_quad(
        p, jnp.asarray(rotate_irreps(x32, L, ang), jnp.dtype(dtype)), L)
    assert_close(np.asarray(rot_then_gate, dtype=np.float32), gate_then_rot,
                 dtype, tier="transform")
