"""int8 KV cache (§Perf H10): accuracy + end-to-end decode consistency."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.config import get_config
from repro.models import build_model


def _batch(cfg, B=2, S=24, seed=0):
    rng = np.random.default_rng(seed)
    return {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)}


def test_int8_cache_decode_close_to_fp():
    base = get_config("qwen2-0.5b").reduced(capacity_factor=8.0)
    q8 = get_config("qwen2-0.5b").reduced(capacity_factor=8.0,
                                          kv_cache_dtype="int8")
    m_fp, m_q8 = build_model(base), build_model(q8)
    params = m_fp.init(jax.random.PRNGKey(0))
    batch = _batch(base)
    B, S = batch["tokens"].shape

    _, c_fp = jax.jit(lambda p, b: m_fp.prefill(p, b, S + 8))(params, batch)
    _, c_q8 = jax.jit(lambda p, b: m_q8.prefill(p, b, S + 8))(params, batch)
    assert c_q8["k"].dtype == jnp.int8
    # cache bytes halve (+small scales)
    fp_bytes = sum(np.prod(a.shape) * a.dtype.itemsize for a in jax.tree.leaves(c_fp))
    q8_bytes = sum(np.prod(a.shape) * a.dtype.itemsize for a in jax.tree.leaves(c_q8))
    assert q8_bytes < 0.55 * fp_bytes * (base.hd + 2) / base.hd

    pos = jnp.full((B,), S, jnp.int32)
    tok = batch["tokens"][:, :1]
    log_fp, _ = jax.jit(m_fp.decode_step)(params, c_fp, tok, pos)
    log_q8, _ = jax.jit(m_q8.decode_step)(params, c_q8, tok, pos)
    # quantization noise bounded; argmax agreement on a reduced model
    assert float(jnp.max(jnp.abs(log_fp - log_q8))) < 0.5
    agree = float(jnp.mean(
        (jnp.argmax(log_fp[:, 0], -1) == jnp.argmax(log_q8[:, 0], -1)).astype(jnp.float32)))
    assert agree == 1.0


def test_int8_cache_greedy_generation_matches():
    """A few greedy steps: int8 cache should reproduce fp16-cache tokens on a
    well-conditioned reduced model."""
    base = get_config("gemma-2b").reduced()
    q8 = get_config("gemma-2b").reduced(kv_cache_dtype="int8")
    m_fp, m_q8 = build_model(base), build_model(q8)
    params = m_fp.init(jax.random.PRNGKey(1))
    batch = _batch(base, seed=2)
    B, S = batch["tokens"].shape
    outs = {}
    for name, m in (("fp", m_fp), ("q8", m_q8)):
        last, cache = jax.jit(lambda p, b: m.prefill(p, b, S + 8))(params, batch)
        tok = jnp.argmax(last[:, 0], -1)[:, None].astype(jnp.int32)
        seq = [tok]
        step = jax.jit(m.decode_step)
        for i in range(4):
            pos = jnp.full((B,), S + i, jnp.int32)
            logits, cache = step(params, cache, tok, pos)
            tok = jnp.argmax(logits[:, 0], -1)[:, None].astype(jnp.int32)
            seq.append(tok)
        outs[name] = np.concatenate([np.asarray(t) for t in seq], axis=1)
    np.testing.assert_array_equal(outs["fp"], outs["q8"])
