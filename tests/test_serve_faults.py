"""Fault-tolerant serving (DESIGN.md §11): deterministic fault injection,
step-level recovery (idempotent retries, structured rejection past the
budget), non-finite quarantine that spares bucket-mates, warmup-time fault
handling, replica failover that preserves (priority, FIFO) order — and the
subprocess acceptance proof: a cordoned replica's requests complete on the
survivor with zero mid-serve autotune timing runs on a warm cache."""
import dataclasses
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.gaunt_ff import gaunt_mace_ff
from repro.distributed.fault_tolerance import StragglerMonitor
from repro.models.equivariant import MaceGaunt
from repro.serve.engine import EquivariantRequest, EquivariantServeEngine
from repro.serve.faults import FaultPlan, InjectedFault, fire, injected
from repro.serve.replicas import ReplicaSet
from repro.serve.scheduler import Scheduler


@pytest.fixture(scope="module")
def small_model():
    cfg = dataclasses.replace(gaunt_mace_ff, channels=8, n_layers=1, L=1,
                              L_edge=1, n_species=4)
    model = MaceGaunt(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return model, params


def _mol(n, seed):
    rng = np.random.default_rng(seed)
    return (rng.integers(0, 4, n),
            (rng.normal(size=(n, 3)) * 1.5).astype(np.float32))


def _reqs(n_req=6, steps=2, step_size=0.01, max_retries=8):
    return [EquivariantRequest(*_mol(3 + (i % 3), seed=i), rid=i,
                               steps=steps, step_size=step_size,
                               max_retries=max_retries)
            for i in range(n_req)]


def _direct_energy(model, params, r):
    return float(model.energy(params, jnp.asarray(r.species),
                              jnp.asarray(np.asarray(r.pos, np.float32))))


# ---------------------------------------------------------------------------
# FaultPlan determinism (no model needed)
# ---------------------------------------------------------------------------


def test_same_seed_same_schedule():
    """Satellite (a): two plans with the same seed realize the SAME fault
    schedule over the same invocation stream — chaos runs replay exactly."""
    def drive(plan):
        with injected(plan):
            for _ in range(200):
                fire("step_raise", n_active=2)
                fire("step_nonfinite", n_active=2)
        return plan.schedule_keys(), [s.payload for s in plan.fired]

    a = drive(FaultPlan(seed=7, rates={"step_raise": 0.1,
                                       "step_nonfinite": 0.1}))
    b = drive(FaultPlan(seed=7, rates={"step_raise": 0.1,
                                       "step_nonfinite": 0.1}))
    assert a == b and a[0], "same seed must fire identically (and fire)"
    c = drive(FaultPlan(seed=8, rates={"step_raise": 0.1,
                                       "step_nonfinite": 0.1}))
    assert a[0] != c[0], "different seeds should realize different schedules"


def test_point_streams_are_independent():
    """A point's schedule is a pure function of (seed, its own invocation
    index): adding traffic on OTHER points does not shift it."""
    p1 = FaultPlan(seed=3, rates={"step_raise": 0.2})
    with injected(p1):
        for _ in range(100):
            fire("step_raise", n_active=1)
    p2 = FaultPlan(seed=3, rates={"step_raise": 0.2, "step_timeout": 0.5})
    with injected(p2):
        for _ in range(100):
            fire("step_timeout", n_active=1)   # interleaved other-point noise
            fire("step_raise", n_active=1)
    assert [k for k in p1.schedule_keys()] == \
        [k for k in p2.schedule_keys() if k[0] == "step_raise"]


def test_scope_gates_without_advancing_counter():
    """Out-of-scope invocations neither fire nor consume invocation indices:
    the scoped stream sees the same schedule as an unscoped run of only the
    in-scope calls."""
    scoped = FaultPlan(seed=5, at={"step_raise": (0, 2)},
                       scope=lambda ctx: ctx.get("tag") == "replica1")
    with injected(scoped):
        for i in range(6):
            fire("step_raise", tag=f"replica{i % 2}", n_active=1)
    # replica1 sees in-scope invocations 0,1,2 -> fires at its 0 and 2
    assert scoped.schedule_keys() == [("step_raise", 0), ("step_raise", 2)]


def test_unknown_point_rejected():
    with pytest.raises(ValueError):
        FaultPlan(rates={"not_a_point": 1.0})
    plan = FaultPlan()
    with pytest.raises(ValueError):
        plan.check("not_a_point")


def test_no_plan_fire_is_noop():
    assert fire("step_raise", n_active=1) is None


# ---------------------------------------------------------------------------
# step-level recovery on the real engine
# ---------------------------------------------------------------------------


def test_faulted_results_match_fault_free(small_model):
    """Satellite (b): under injected raises + NaNs + timeouts, every request
    still completes and every completed result — including multi-step
    relaxations — is IDENTICAL to the fault-free run (retries restart from
    the admission snapshot, so recovery never changes numbers)."""
    model, params = small_model
    base = EquivariantServeEngine(model, params, buckets=[(6, 2)]) \
        .run(_reqs())
    eng = EquivariantServeEngine(model, params, buckets=[(6, 2)])
    plan = FaultPlan(seed=1, rates={"step_raise": 0.15,
                                    "step_nonfinite": 0.15,
                                    "step_timeout": 0.1})
    with injected(plan):
        out = eng.run(_reqs())
    assert plan.fired, "the plan must actually have injected faults"
    assert eng.metrics.counters["step_failures"] > 0
    for b, o in zip(base, out):
        assert o.done and not o.rejected, (o.rid, o.reject_reason)
        assert o.energy == b.energy, o.rid
        np.testing.assert_array_equal(o.forces, b.forces)
        np.testing.assert_array_equal(o.pos, b.pos)


def test_retry_exhaustion_rejects_structurally(small_model):
    """A request whose every attempt fails is rejected with the structured
    ``step_failed:*`` reason, not lost or left hanging."""
    model, params = small_model
    eng = EquivariantServeEngine(model, params, buckets=[(6, 1)])
    req = _reqs(1, max_retries=2)[0]
    with injected(FaultPlan(seed=0, rates={"step_raise": 1.0})):
        out = eng.run([req])[0]
    assert out.done and out.rejected
    assert out.reject_reason == "step_failed:step_raised"
    assert out.energy is None and out.forces is None
    s = eng.metrics.summary()
    assert s["rejected:step_failed"] == 1
    assert s["retries"] == 2        # budget honored exactly
    assert s["step_failures"] == 3  # initial attempt + 2 retries


def test_quarantine_spares_bucket_mates(small_model):
    """Satellite (c): a non-finite slot is quarantined ALONE — its bucket-
    mate retires in the same step with its normal (fault-free) energy."""
    model, params = small_model
    base = EquivariantServeEngine(model, params, buckets=[(6, 2)]) \
        .run(_reqs(2, steps=1, step_size=0.0))
    eng = EquivariantServeEngine(model, params, buckets=[(6, 2)])
    plan = FaultPlan(seed=0, at={"step_nonfinite": (0,)},
                     payload={"step_nonfinite": {"slots": [0]}})
    with injected(plan):
        out = eng.run(_reqs(2, steps=1, step_size=0.0))
    assert all(o.done and not o.rejected for o in out)
    assert eng.metrics.counters["quarantined"] == 1
    # the mate (slot 1) retired on the FIRST step, untouched by recovery
    assert out[1].energy == base[1].energy
    assert out[0].energy == base[0].energy   # retried to the same number
    assert eng.metrics.counters["retries"] == 1


def test_collective_nonfinite_bisects_to_retry(small_model):
    """slots='all' poisons the whole batch: the pool bisects, finds every
    slot individually finite (batch-level corruption), and retries them all
    without quarantine accounting — results still match fault-free."""
    model, params = small_model
    eng = EquivariantServeEngine(model, params, buckets=[(6, 2)])
    plan = FaultPlan(seed=0, at={"step_nonfinite": (0,)},
                     payload={"step_nonfinite": {"slots": "all"}})
    with injected(plan):
        out = eng.run(_reqs(2, steps=1, step_size=0.0))
    assert all(o.done and not o.rejected for o in out)
    s = eng.metrics.summary()
    assert s["nonfinite_bisects"] == 1
    assert s["quarantined"] == 0
    assert s["step_failures:nonfinite_collective"] == 1
    base = EquivariantServeEngine(model, params, buckets=[(6, 2)]) \
        .run(_reqs(2, steps=1, step_size=0.0))
    assert [o.energy for o in out] == [b.energy for b in base]


def test_real_watchdog_timeout(small_model):
    """The non-injected watchdog: with ``step_timeout_s=0.0`` every step
    exceeds its deadline against the real clock, so the request burns its
    retry budget and is rejected as ``step_failed:step_timeout``."""
    model, params = small_model
    eng = EquivariantServeEngine(model, params, buckets=[(6, 1)],
                                 step_timeout_s=0.0)
    out = eng.run(_reqs(1, max_retries=1))[0]
    assert out.rejected and out.reject_reason == "step_failed:step_timeout"
    assert eng.metrics.counters["step_failures:step_timeout"] == 2


def test_recovery_time_recorded(small_model):
    """Time-to-recovery samples land in the metrics (first failure detection
    -> next successful finish) and surface as p50/p99 in summary()."""
    model, params = small_model
    eng = EquivariantServeEngine(model, params, buckets=[(6, 1)])
    with injected(FaultPlan(seed=0, at={"step_raise": (0,)})):
        eng.run(_reqs(1))
    assert len(eng.metrics.recovery_s) == 1
    s = eng.metrics.summary()
    assert s["recovery_p99_ms"] >= s["recovery_p50_ms"] > 0.0


# ---------------------------------------------------------------------------
# warmup-time faults
# ---------------------------------------------------------------------------


def test_compile_fail_warmup_retries(small_model):
    """A transient warmup compile failure is retried (counted), and the
    engine then serves normally."""
    model, params = small_model
    eng = EquivariantServeEngine(model, params, buckets=[(6, 1)])
    with injected(FaultPlan(seed=0, at={"compile_fail": (0,)})):
        eng.warmup()
    assert eng.metrics.counters["warmup_retries"] == 1
    out = eng.run(_reqs(1))[0]
    assert out.done and not out.rejected


def test_compile_fail_persistent_raises(small_model):
    """Three consecutive compile failures exhaust warmup's retry budget and
    surface the error — a host that cannot compile must not claim warm."""
    model, params = small_model
    eng = EquivariantServeEngine(model, params, buckets=[(6, 1)])
    with injected(FaultPlan(seed=0, at={"compile_fail": (0, 1, 2)})):
        with pytest.raises(InjectedFault):
            eng.warmup()
    assert eng.metrics.counters["warmup_retries"] == 3


def test_autotune_cache_unreadable_degrades(small_model):
    """An unreadable persistent autotune cache at warmup is survivable:
    the engine counts the degradation and still serves correctly."""
    model, params = small_model
    eng = EquivariantServeEngine(model, params, buckets=[(6, 1)])
    with injected(FaultPlan(seed=0, at={"autotune_cache_load": (0,)})):
        eng.warmup()
    assert eng.metrics.counters["autotune_cache_load_failed"] == 1
    out = eng.run(_reqs(1))[0]
    assert out.done and not out.rejected


# ---------------------------------------------------------------------------
# straggler monitor (satellite: capped memory + summary fold)
# ---------------------------------------------------------------------------


def test_straggler_flagged_is_capped():
    mon = StragglerMonitor(window=20, factor=2.0, max_flagged=8)
    for i in range(10):
        mon.record(i, 1.0)            # build the baseline
    for i in range(100):
        mon.record(100 + i, 10.0)     # everything after is a straggler
    assert len(mon.flagged) == 8      # bounded on a long-lived host
    assert mon.total_flagged > 8      # but the count is not lost


def test_straggler_count_in_serve_summary(small_model):
    """Step durations feed the metrics' straggler monitor; the summary
    reports the total."""
    model, params = small_model
    eng = EquivariantServeEngine(model, params, buckets=[(6, 1)])
    # prime a fast baseline, then a slow outlier via the metrics layer
    for i in range(12):
        eng.metrics.observe_step("b6", 1, 1, 3, 6, dur_s=1e-3)
    eng.metrics.observe_step("b6", 1, 1, 3, 6, dur_s=1.0)
    s = eng.metrics.summary()
    assert s["straggler_steps"] == 1
    assert eng.metrics.per_pool["b6"]["straggler_steps"] == 1


# ---------------------------------------------------------------------------
# replica failover
# ---------------------------------------------------------------------------


def _factory(model, params, **kw):
    def make(i, metrics):
        return EquivariantServeEngine(model, params, buckets=[(6, 1)],
                                      metrics=metrics, tag=f"replica{i}",
                                      **kw)
    return make


def test_failover_preserves_priority_fifo_order(small_model):
    """Satellite (d): a cordoned replica's in-flight request rejoins the
    queue at its ORIGINAL (priority, _seq) standing — it is re-served ahead
    of lower-priority work that was queued after it, and completes with its
    fault-free numbers."""
    model, params = small_model
    rset = ReplicaSet(_factory(model, params), n_replicas=2,
                      max_fail_streak=2, restart_backoff_s=60.0)
    doomed = EquivariantRequest(*_mol(4, seed=0), rid=0, priority=-1,
                                steps=2, step_size=0.01, max_retries=10)
    rest = [EquivariantRequest(*_mol(3 + i, seed=10 + i), rid=1 + i,
                               steps=2, step_size=0.01, max_retries=10)
            for i in range(3)]
    # replica0 (which top-priority `doomed` is admitted to first) always
    # fails; the survivor must serve everything
    plan = FaultPlan(seed=0, rates={"step_raise": 1.0},
                     scope=lambda ctx: ctx.get("tag") == "replica0")
    with injected(plan):
        out = rset.run([doomed] + rest)
    assert all(r.done and not r.rejected for r in out)
    m = rset.metrics.summary()
    assert m["failovers"] >= 1
    assert m["requeued_on_failover"] >= 1
    assert doomed._seq == 0, "failover must not re-sequence the request"
    order = list(rset.metrics.completed_order)
    # priority -1 work completes before the lowest-standing priority-0 work
    # it was requeued ahead of
    assert order.index(0) < order.index(3)
    # numbers are the single-engine fault-free numbers
    base_eng = EquivariantServeEngine(model, params, buckets=[(6, 2)])
    base = base_eng.run([EquivariantRequest(*_mol(4, seed=0), rid=0,
                                            steps=2, step_size=0.01)])[0]
    assert doomed.energy == base.energy


def test_cordoned_replica_restarts_with_backoff(small_model):
    """After the backoff elapses the cordoned replica rejoins the fleet
    (same engine, fresh health state) and serves new work."""
    model, params = small_model
    rset = ReplicaSet(_factory(model, params), n_replicas=2,
                      max_fail_streak=1, restart_backoff_s=0.0)
    plan = FaultPlan(seed=0, rates={"step_raise": 1.0}, max_fires=1,
                     scope=lambda ctx: ctx.get("tag") == "replica0")
    with injected(plan):
        out = rset.run(_reqs(4))
    assert all(r.done and not r.rejected for r in out)
    m = rset.metrics.summary()
    assert m["failovers:step_failures"] == 1
    assert m["replica_restarts"] == 1
    assert all(r.live for r in rset.replicas)


def test_heartbeat_stale_cordons(small_model, tmp_path):
    """A replica whose heartbeat FILE is stale (the cluster health-checker
    signal, wall-time based) is cordoned even if it never observably failed
    a step in-process."""
    import json as _json
    import time as _time
    model, params = small_model
    rset = ReplicaSet(_factory(model, params), n_replicas=2,
                      stale_after_s=30.0, restart_backoff_s=60.0,
                      heartbeat_dir=str(tmp_path))
    # age replica0's heartbeat far past the staleness horizon
    hb = rset.replicas[0].heartbeat.path
    with open(hb, "w") as f:
        _json.dump({"step": 0, "t": _time.time() - 1e4, "pid": 0}, f)
    out = rset.run(_reqs(3))
    assert all(r.done and not r.rejected for r in out)
    m = rset.metrics.summary()
    assert m["failovers:heartbeat_stale"] == 1
    assert not rset.replicas[0].live


def test_replicaset_through_scheduler_attaches_queue(small_model):
    """Scheduler construction hands its AdmissionQueue to the ReplicaSet
    (the failover requeue path), without the single-engine stack changing."""
    model, params = small_model
    rset = ReplicaSet(_factory(model, params), n_replicas=2)
    sched = Scheduler(rset)
    assert rset._queue is sched.queue
    eng = EquivariantServeEngine(model, params, buckets=[(6, 1)])
    Scheduler(eng)   # engines without attach_queue are untouched


# ---------------------------------------------------------------------------
# acceptance: failover in a subprocess on a warm autotune cache
# ---------------------------------------------------------------------------

_FAILOVER_CHILD = r"""
import dataclasses, os
import numpy as np
import jax
from repro.configs.gaunt_ff import gaunt_mace_ff
from repro.models.equivariant import MaceGaunt
from repro.serve.engine import EquivariantRequest, EquivariantServeEngine
from repro.serve.faults import FaultPlan, injected
from repro.serve.replicas import ReplicaSet
from repro.core import engine as ce

cfg = dataclasses.replace(gaunt_mace_ff, channels=4, n_layers=1, L=1,
                          L_edge=1, n_species=4, chain_tune="measure",
                          autotune_cache=os.environ["CACHE_PATH"])
model = MaceGaunt(cfg)
params = model.init(jax.random.PRNGKey(0))

def factory(i, metrics):
    eng = EquivariantServeEngine(model, params, buckets=[(6, 1)],
                                 metrics=metrics, tag=f"replica{i}")
    eng.warmup()
    return eng

rset = ReplicaSet(factory, n_replicas=2, max_fail_streak=2,
                  restart_backoff_s=60.0)
g = ce.get_engine()
warm_runs = g.timing_runs
rng = np.random.default_rng(0)
reqs = [EquivariantRequest(species=rng.integers(0, 4, 3 + i % 3),
                           pos=(rng.normal(size=(3 + i % 3, 3)) * 1.5)
                           .astype(np.float32), rid=i, steps=2,
                           step_size=0.01, max_retries=10)
        for i in range(4)]
plan = FaultPlan(seed=0, rates={"step_raise": 1.0},
                 scope=lambda ctx: ctx.get("tag") == "replica0")
with injected(plan):
    rset.run(reqs)
assert all(r.done and not r.rejected for r in reqs), reqs
m = rset.metrics.summary()
assert m["failovers"] >= 1, m
assert not rset.replicas[0].live, "the failing replica must be cordoned"
g.flush_autotune_cache()
print("RUNS=" + str(g.timing_runs))
print("MIDSERVE=" + str(g.timing_runs - warm_runs))
print("FAILOVER_OK")
"""


def _subprocess_env() -> dict:
    src = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(
        __file__))), "src")
    env = dict(os.environ)
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    env["JAX_PLATFORMS"] = "cpu"
    return env


def test_failover_completes_on_survivor_with_warm_cache(tmp_path):
    """ISSUE acceptance: in a fresh process, one replica of a ReplicaSet
    fails every step, is cordoned, and its requests complete on the
    survivor; on the second (warm-cache) process the ENTIRE run — warmup
    included — performs zero autotune timing runs, and neither process ever
    time-measures mid-serve (failover re-staging must not re-autotune)."""
    env = _subprocess_env()
    env["CACHE_PATH"] = str(tmp_path / "failover_cache.json")
    out = []
    for _ in range(2):
        r = subprocess.run([sys.executable, "-c", _FAILOVER_CHILD],
                           capture_output=True, text=True, env=env,
                           timeout=900)
        assert "FAILOVER_OK" in r.stdout, (r.stdout[-2000:],
                                           r.stderr[-2000:])
        vals = dict(ln.split("=", 1) for ln in r.stdout.splitlines()
                    if "=" in ln)
        out.append((int(vals["RUNS"]), int(vals["MIDSERVE"])))
    (cold_runs, cold_mid), (warm_runs, warm_mid) = out
    assert cold_runs > 0, "cold process should have measured something"
    assert cold_mid == 0 and warm_mid == 0, \
        "failover recovery must never trigger mid-serve timing runs"
    assert warm_runs == 0, \
        f"warm process ran {warm_runs} timing passes (cache not consulted)"
