"""Rotation-equivariance conformance for EVERY registered engine backend.

For each kind and each eligible backend up to L=4 the suite checks the
defining property  apply(D(R) x1, D(R) x2) == D(R) apply(x1, x2)  under
deterministic random rotations (exact Wigner-D from repro.testing), plus
hypothesis-driven random-angle sweeps when hypothesis is installed
(tests/_hyp.py shim -> clean skips otherwise).

The suite is parameterized over storage precision {float32, bfloat16}
(DESIGN.md §3.6): equivariance is a property of the *operator*, so it must
hold at every storage dtype — only the tolerance tier changes
(repro.testing.tol_for).  Backends that don't register a dtype are skipped
for it, mirroring the engine's own eligibility filter.
"""
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import HAVE_HYPOTHESIS, given, settings, st

from repro.core import engine
from repro.core.irreps import num_coeffs
from repro.testing import (
    assert_close,
    random_angles,
    random_irreps,
    random_unit_vectors,
    rotation_matrix,
    tol_for,
    wigner_D,
)

PAIRWISE = engine.available_backends("pairwise", requires_grad=False)
CONV = engine.available_backends("conv_filter", requires_grad=False)
MANYBODY = engine.available_backends("manybody", requires_grad=False)
CHANNEL_MIX = engine.available_backends("channel_mix", requires_grad=False)
DTYPES = ["float32", "bfloat16"]

LS = [1, 2, 3, 4]  # the acceptance grid: every backend up to L=4
B = 3              # rows per check — equivariance is per-row, keep it cheap


def _close(got, ref, tol=2e-4):
    got, ref = np.asarray(got), np.asarray(ref)
    scale = max(1.0, np.abs(ref).max())
    np.testing.assert_allclose(got, ref, atol=tol * scale)


def _skip_unless_eligible(backend, kind, dtype):
    if backend is not None and backend not in engine.available_backends(
            kind, dtype=dtype, requires_grad=False):
        pytest.skip(f"{backend} does not register {dtype}")


def _f64(a):
    return np.asarray(a).astype(np.float64)


def _check_pairwise(backend, L1, L2, Lout, angles, seed=0, dtype="float32"):
    x1 = random_irreps(L1, (B,), seed=seed)
    x2 = random_irreps(L2, (B,), seed=seed + 100)
    D1, D2, D3 = wigner_D(L1, angles), wigner_D(L2, angles), wigner_D(Lout, angles)
    p = engine.plan(L1, L2, Lout, backend=backend, requires_grad=False,
                    dtype=dtype)
    cast = lambda a: jnp.asarray(a).astype(dtype)  # noqa: E731
    lhs = _f64(p.apply(cast(x1 @ D1.T), cast(x2 @ D2.T)))
    rhs = _f64(p.apply(cast(x1), cast(x2))) @ D3.T
    assert_close(lhs, rhs, dtype=dtype, tier="transform")


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("L", LS)
@pytest.mark.parametrize("backend", PAIRWISE)
def test_pairwise_rotation_equivariance(backend, L, dtype):
    _skip_unless_eligible(backend, "pairwise", dtype)
    _check_pairwise(backend, L, L, L, random_angles(seed=L), seed=L, dtype=dtype)


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("backend", PAIRWISE)
def test_pairwise_equivariance_mixed_degrees(backend, dtype):
    # unequal degrees + full (untruncated) output
    _skip_unless_eligible(backend, "pairwise", dtype)
    _check_pairwise(backend, 2, 3, 5, random_angles(seed=7), seed=7, dtype=dtype)


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("L", LS)
@pytest.mark.parametrize("backend", CONV)
def test_conv_filter_rotation_equivariance(backend, L, dtype):
    """Rotating the features AND the edge direction rotates the output."""
    _skip_unless_eligible(backend, "conv_filter", dtype)
    angles = random_angles(seed=10 + L)
    R = rotation_matrix(angles)
    x = random_irreps(L, (B,), seed=20 + L)
    r = random_unit_vectors((B,), seed=30 + L)
    D1, D3 = wigner_D(L, angles), wigner_D(L, angles)
    p = engine.plan(L, L, L, kind="conv_filter", backend=backend,
                    requires_grad=False, dtype=dtype)
    cast = lambda a: jnp.asarray(a).astype(dtype)  # noqa: E731
    # edge directions stay f32: the filter is *built* from them (Wigner
    # recursion / SH evaluation), it is not a stored operand
    lhs = _f64(p.apply(cast(x @ D1.T), jnp.asarray((r @ R.T).astype(np.float32))))
    rhs = _f64(p.apply(cast(x), jnp.asarray(r))) @ D3.T
    assert_close(lhs, rhs, dtype=dtype, tier="transform")


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("L", LS)
@pytest.mark.parametrize("backend", MANYBODY)
def test_manybody_rotation_equivariance(backend, L, dtype):
    _skip_unless_eligible(backend, "manybody", dtype)
    nu = 3 if L <= 2 else 2
    angles = random_angles(seed=40 + L)
    xs = [random_irreps(L, (B,), seed=50 + L + i) for i in range(nu)]
    D, Do = wigner_D(L, angles), wigner_D(L, angles)
    p = engine.plan(kind="manybody", Ls=(L,) * nu, Lout=L, backend=backend,
                    requires_grad=False, dtype=dtype)
    cast = lambda a: jnp.asarray(a).astype(dtype)  # noqa: E731
    lhs = _f64(p.apply([cast(x @ D.T) for x in xs]))
    rhs = _f64(p.apply([cast(x) for x in xs])) @ Do.T
    # nu-fold chains accumulate more storage round trips than a pairwise op
    assert_close(lhs, rhs, dtype=dtype, tier="loose")


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("L", LS)
@pytest.mark.parametrize("backend", CHANNEL_MIX)
def test_channel_mix_rotation_equivariance(backend, L, dtype):
    """Channel mixing commutes with rotation (w_mix acts on channels only)."""
    _skip_unless_eligible(backend, "channel_mix", dtype)
    C1, C2, E = 3, 2, 4
    angles = random_angles(seed=60 + L)
    x1 = random_irreps(L, (B, C1), seed=70 + L)
    x2 = random_irreps(L, (B, C2), seed=80 + L)
    from repro.testing import random_array

    w = random_array((C1, C2, E), seed=90 + L)
    D, Do = wigner_D(L, angles), wigner_D(L, angles)
    p = engine.plan(L, L, L, kind="channel_mix", backend=backend,
                    requires_grad=False, dtype=dtype)
    cast = lambda a: jnp.asarray(a).astype(dtype)  # noqa: E731
    lhs = _f64(p.apply(cast(x1 @ D.T), cast(x2 @ D.T), jnp.asarray(w)))
    rhs = _f64(p.apply(cast(x1), cast(x2), jnp.asarray(w))) @ Do.T
    assert_close(lhs, rhs, dtype=dtype, tier="transform")


@pytest.mark.parametrize("dtype", DTYPES)
def test_batched_plan_rotation_equivariance(dtype):
    """The batched execution layer preserves equivariance across a ragged
    multi-degree workload (the tentpole path end-to-end)."""
    items = [(2, 2, 2, 4), (1, 1, 2, 6), (2, 2, 2, 3)]
    bp = engine.plan_batch(items, requires_grad=False, dtype=dtype)
    angles = random_angles(seed=3)
    ins, refs = [], []
    for t, (L1, L2, Lout, n) in enumerate(items):
        x1 = random_irreps(L1, (n,), seed=t)
        x2 = random_irreps(L2, (n,), seed=t + 10)
        ins.append((x1, x2))
        refs.append((L1, L2, Lout))
    cast = lambda a: jnp.asarray(a).astype(dtype)  # noqa: E731
    outs = bp.apply([(cast(a), cast(b)) for a, b in ins])
    rot_outs = bp.apply([
        (cast(a @ wigner_D(L1, angles).T), cast(b @ wigner_D(L2, angles).T))
        for (a, b), (L1, L2, _) in zip(ins, refs)])
    for o, ro, (_, _, Lout) in zip(outs, rot_outs, refs):
        assert_close(_f64(ro), _f64(o) @ wigner_D(Lout, angles).T,
                     dtype=dtype, tier="transform")


# ---------------------------------------------------------------------------
# hypothesis sweeps (skipped cleanly when hypothesis is absent)
# ---------------------------------------------------------------------------

_angles_st = st.tuples(
    st.floats(0.0, 2 * np.pi), st.floats(0.05, np.pi - 0.05),
    st.floats(0.0, 2 * np.pi),
) if HAVE_HYPOTHESIS else st


@given(angles=_angles_st, seed=st.integers(0, 2**16))
@settings(max_examples=20, deadline=None)
def test_pairwise_equivariance_property(angles, seed):
    """Random rotations x random inputs on the default-selected backend."""
    _check_pairwise(None, 2, 2, 3, tuple(angles), seed=seed)


@given(angles=_angles_st, seed=st.integers(0, 2**16))
@settings(max_examples=10, deadline=None)
def test_escn_equivariance_property(angles, seed):
    angles = tuple(angles)
    R = rotation_matrix(angles)
    x = random_irreps(2, (B,), seed=seed)
    r = random_unit_vectors((B,), seed=seed + 1)
    p = engine.plan(2, 2, 3, kind="conv_filter", backend="escn_aligned")
    lhs = np.asarray(p.apply(jnp.asarray(x @ wigner_D(2, angles).T),
                             jnp.asarray((r @ R.T).astype(np.float32))))
    rhs = np.asarray(p.apply(jnp.asarray(x), jnp.asarray(r))) @ wigner_D(3, angles).T
    _close(lhs, rhs, tol=5e-4)
