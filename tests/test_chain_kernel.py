"""The n-way fused chain collocation kernel (DESIGN.md §6.4) and the chain
autotune fold.

The tentpole claims, each pinned here:
  * a whole >= 3-operand ChainPlan on the kernel backend is ONE pallas_call
    (proven by the kernel dispatch counter AND by walking the jaxpr);
  * the kernel matches the tree-conv ChainPlan numerically — to f64 machine
    precision under x64 (subprocess), bounded f32 otherwise — across
    2/3/4-operand chains, with per-operand and output weights, under grad
    and vmap, and through `fourier_boundary` entry (resident operands enter
    as grids) and exit (the product stays resident);
  * rotation equivariance holds (testing/ oracle);
  * chains fold into the engine's measured autotuner keyed like plans;
  * sharded chains pad/slice ragged row counts (2-virtual-device
    subprocess).

Everything runs on CPU via interpret=True.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine
from repro.core.irreps import num_coeffs
from repro.core.rep import Rep
from repro.kernels.gaunt_fused import (gaunt_chain_fused_pallas,
                                       gaunt_chain_fused_xla, kernel_stats,
                                       reset_kernel_stats)
from repro.testing import (assert_close, random_angles, random_irreps,
                           rotate_irreps)


def _rand(shape, seed, dtype=jnp.float32):
    return jnp.asarray(np.random.default_rng(seed).normal(size=shape), dtype)


DTYPES = ["float32", "bfloat16"]


CHAINS = [
    ((1, 1), 2),          # pairwise, full degree
    ((2, 2), 2),          # pairwise, truncated exit
    ((2, 1, 2), 3),       # 3-operand, mixed degrees
    ((2, 2, 2), 2),       # 3-operand, truncated
    ((1, 2, 1, 2), 4),    # 4-operand
]


# --------------------------------------------------------------------------
# numerical identity vs the tree-conv ChainPlan
# --------------------------------------------------------------------------


@pytest.mark.parametrize("Ls,Lout", CHAINS)
@pytest.mark.parametrize("backend", ["fused_xla", "fused_pallas"])
@pytest.mark.parametrize("weighted", [False, True])
@pytest.mark.parametrize("dtype", DTYPES)
def test_chain_kernel_matches_tree(Ls, Lout, backend, weighted, dtype):
    """Kernel-vs-tree identity at both storage precisions: inputs quantized
    to ``dtype``, reference = f32 tree on the same values, tolerance from
    the shared per-precision tiers (repro.testing.tol_for)."""
    B = 9
    xs = [_rand((B, num_coeffs(L)), 3 * i, dtype) for i, L in enumerate(Ls)]
    ws = wo = None
    if weighted:
        ws = [_rand((B, L + 1), 50 + i) for i, L in enumerate(Ls)]
        wo = _rand((B, Lout + 1), 99)
    tree = engine.plan_chain(Ls, Lout, backend="tree")  # f32 reference
    cp = engine.plan_chain(Ls, Lout, backend=backend, dtype=dtype)
    assert cp.backend == backend
    want = np.asarray(tree.apply([x.astype(jnp.float32) for x in xs],
                                 weights=ws, w_out=wo))
    got = cp.apply(xs, weights=ws, w_out=wo)
    assert got.dtype == jnp.dtype(dtype)
    assert_close(np.asarray(got).astype(np.float64), want, dtype=dtype,
                 tier="identity", tol=3e-5 if dtype == "float32" else None)


def test_chain_kernel_f64_exact_vs_tree():
    """Under x64 the collocation kernel and the tree-conv chain agree to
    f64 machine precision (both are exact realizations of the same alias-free
    product) — subprocess so the x64 flag cannot leak into this process."""
    code = r"""
import os
os.environ["JAX_ENABLE_X64"] = "1"
import jax, jax.numpy as jnp, numpy as np
from repro.core import engine
from repro.core.irreps import num_coeffs

rng = np.random.default_rng(0)
for Ls, Lout in [((2, 2), 2), ((2, 1, 2), 3), ((1, 2, 1, 2), 4)]:
    xs = [jnp.asarray(rng.normal(size=(5, num_coeffs(L))), jnp.float64)
          for L in Ls]
    ws = [jnp.asarray(rng.normal(size=(5, L + 1)), jnp.float64) for L in Ls]
    tree = engine.plan_chain(Ls, Lout, backend="tree", dtype="float64")
    want = np.asarray(tree.apply(xs, weights=ws))
    for backend in ("fused_xla", "fused_pallas"):
        cp = engine.plan_chain(Ls, Lout, backend=backend, dtype="float64")
        got = np.asarray(cp.apply(xs, weights=ws))
        assert got.dtype == np.float64
        err = np.abs(got - want).max() / (np.abs(want).max() + 1.0)
        assert err < 1e-12, (Ls, Lout, backend, err)
print("F64_OK")
"""
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=900)
    assert "F64_OK" in out.stdout, (out.stdout[-2000:], out.stderr[-2000:])


# --------------------------------------------------------------------------
# grad / vmap conformance
# --------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["fused_xla", "fused_pallas"])
def test_chain_kernel_grad_matches_tree(backend):
    Ls, Lout, B = (2, 1, 2), 3, 6
    xs = [_rand((B, num_coeffs(L)), 10 + i) for i, L in enumerate(Ls)]
    ws = [_rand((B, L + 1), 20 + i) for i, L in enumerate(Ls)]
    cp = engine.plan_chain(Ls, Lout, backend=backend)
    tree = engine.plan_chain(Ls, Lout, backend="tree")

    def loss(plan):
        return lambda a: jnp.sum(plan.apply([a, xs[1], xs[2]], weights=ws) ** 2)

    g = jax.grad(loss(cp))(xs[0])
    g0 = jax.grad(loss(tree))(xs[0])
    np.testing.assert_allclose(np.asarray(g), np.asarray(g0),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("backend", ["fused_xla", "fused_pallas"])
def test_chain_kernel_vmap(backend):
    Ls, Lout = (2, 2, 2), 2
    xs = [_rand((4, 3, num_coeffs(L)), 30 + i) for i, L in enumerate(Ls)]
    cp = engine.plan_chain(Ls, Lout, backend=backend)
    direct = cp.apply(xs)
    mapped = jax.vmap(lambda *a: cp.apply(list(a)))(*xs)
    np.testing.assert_allclose(np.asarray(mapped), np.asarray(direct),
                               rtol=1e-4, atol=1e-4)


# --------------------------------------------------------------------------
# fourier_boundary: resident operands enter as grids; resident exit
# --------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["fused_xla", "fused_pallas"])
def test_chain_kernel_resident_entry(backend):
    """A Fourier-resident operand enters the kernel AS A GRID (via the
    grid-evaluation sampling matrix) — no sh_to_fourier runs, and the result
    matches the all-SH kernel chain."""
    from repro.core import rep as _rep

    Ls, Lout, B = (2, 2, 1), 5, 7
    xs = [_rand((B, num_coeffs(L)), 40 + i) for i, L in enumerate(Ls)]
    cp = engine.plan_chain(Ls, Lout, backend=backend)
    want = np.asarray(cp.apply(xs))
    resident = Rep.from_sh(xs[1], Ls[1]).to_fourier("half")
    with _rep.conversion_stats(fresh=True) as c:
        got = np.asarray(cp.apply([xs[0], resident, xs[2]]))
    assert c["sh_to_fourier"] == 0 and c["fourier_to_sh"] == 0
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
    # dense-form residents coerce losslessly too
    got_d = np.asarray(cp.apply(
        [xs[0], Rep.from_sh(xs[1], Ls[1]).to_fourier("dense"), xs[2]]))
    np.testing.assert_allclose(got_d, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("backend", ["fused_xla", "fused_pallas"])
def test_chain_kernel_resident_exit(backend):
    """out_basis='fourier' returns the resident half product grid — equal to
    the tree chain's resident exit, and projecting it recovers the SH out."""
    Ls, B = (1, 2, 1), 5
    Ltot = sum(Ls)
    xs = [_rand((B, num_coeffs(L)), 60 + i) for i, L in enumerate(Ls)]
    cp = engine.plan_chain(Ls, Ltot, backend=backend)
    tree = engine.plan_chain(Ls, Ltot, backend="tree")
    got = cp.apply(xs, out_basis="fourier")
    want = tree.apply(xs, out_basis="fourier")
    assert got.is_fourier and got.L == Ltot and got.form == "half"
    np.testing.assert_allclose(np.asarray(got.data),
                               np.asarray(want.with_form("half").data),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(got.to_sh().data),
                               np.asarray(tree.apply(xs)), rtol=1e-4, atol=1e-4)


# --------------------------------------------------------------------------
# rotation equivariance (testing/ oracle)
# --------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["fused_xla", "fused_pallas"])
def test_chain_kernel_rotation_equivariance(backend):
    Ls, Lout = (2, 1, 2), 2
    ang = random_angles(seed=5)
    xs = [np.asarray(random_irreps(L, (6,), seed=70 + i))
          for i, L in enumerate(Ls)]
    cp = engine.plan_chain(Ls, Lout, backend=backend)
    out = np.asarray(cp.apply([jnp.asarray(x) for x in xs]))
    out_rot = np.asarray(cp.apply(
        [jnp.asarray(rotate_irreps(x, L, ang)) for x, L in zip(xs, Ls)]))
    np.testing.assert_allclose(out_rot, rotate_irreps(out, Lout, ang),
                               rtol=2e-3, atol=2e-3)


# --------------------------------------------------------------------------
# ONE pallas_call: counter- and trace-proven
# --------------------------------------------------------------------------


def _count_pallas_eqns(jaxpr) -> int:
    n = 0
    for eqn in jaxpr.eqns:
        if "pallas" in eqn.primitive.name:
            n += 1
        for sub in jax.core.jaxprs_in_params(eqn.params):
            n += _count_pallas_eqns(sub.jaxpr if hasattr(sub, "jaxpr") else sub)
    return n


def test_chain_kernel_single_pallas_call():
    """A 3-operand chain on the fused_pallas backend is ONE pallas_call:
    the kernel dispatch counter ticks once per apply, and the traced jaxpr
    contains exactly one pallas_call primitive (n+2 ops collapsed to 1)."""
    Ls, Lout, B = (2, 2, 2), 2, 8
    xs = [_rand((B, num_coeffs(L)), 80 + i) for i, L in enumerate(Ls)]
    cp = engine.plan_chain(Ls, Lout, backend="fused_pallas")
    reset_kernel_stats()
    jax.block_until_ready(cp.apply(xs))
    assert kernel_stats()["chain_pallas_calls"] == 1
    jaxpr = jax.make_jaxpr(lambda *a: cp.apply(list(a)))(*xs)
    assert _count_pallas_eqns(jaxpr.jaxpr) == 1
    # weights/resident entries don't change the dispatch count
    ws = [_rand((B, L + 1), 90 + i) for i, L in enumerate(Ls)]
    rep = Rep.from_sh(xs[1], Ls[1]).to_fourier("half")
    reset_kernel_stats()
    jax.block_until_ready(cp.apply([xs[0], rep, xs[2]], weights=[ws[0], None, ws[2]]))
    assert kernel_stats()["chain_pallas_calls"] == 1


@pytest.mark.parametrize("dtype", DTYPES)
def test_chain_kernel_grid_blocking_accumulates(dtype):
    """Large product grids run blocked over the sample axis (accumulating in
    the output block) and still match the unblocked kernel exactly — at
    both storage precisions (blocking must not change where bf16 rounds:
    accumulation stays f32 within and across grid blocks)."""
    Ls, Lout, B = (3, 3, 2), 4, 5
    xs = [_rand((B, num_coeffs(L)), 100 + i, dtype) for i, L in enumerate(Ls)]
    full = gaunt_chain_fused_pallas(xs, Ls, Lout, block_g=4096, interpret=True)
    blocked = gaunt_chain_fused_pallas(xs, Ls, Lout, block_g=128, interpret=True)
    np.testing.assert_allclose(np.asarray(blocked).astype(np.float64),
                               np.asarray(full).astype(np.float64),
                               rtol=1e-5, atol=1e-5)
    xla = gaunt_chain_fused_xla(xs, Ls, Lout)
    np.testing.assert_allclose(np.asarray(blocked).astype(np.float64),
                               np.asarray(xla).astype(np.float64),
                               rtol=1e-5, atol=1e-5)


# --------------------------------------------------------------------------
# grid-resident gate fused into the chain (DESIGN.md §6.5)
# --------------------------------------------------------------------------


def _gate_params(C, seed):
    rng = np.random.default_rng(seed)
    return {"w1": jnp.asarray(rng.normal(size=(C, 16)) * 0.3, jnp.float32),
            "w2": jnp.asarray(rng.normal(size=(16, C)) * 0.3, jnp.float32)}


@pytest.mark.parametrize("backend", engine.CHAIN_BACKENDS)
@pytest.mark.parametrize("dtype", DTYPES)
def test_gated_chain_matches_sh_gate(backend, dtype, Ls=(2, 1, 2), Lout=3):
    """plan_chain(gate=True) == gate applied to the ungated product on SH
    coefficients — on EVERY chain backend (tree/looped gate at the exit; the
    collocation backends fuse the gate as a kernel pointwise stage)."""
    B, C = 5, 3
    xs = [_rand((B, C, num_coeffs(L)), 300 + i, dtype) for i, L in enumerate(Ls)]
    gp = _gate_params(C, 310)
    tree = engine.plan_chain(Ls, Lout, backend="tree")
    want = np.asarray(engine._gate_sh(
        gp, tree.apply([x.astype(jnp.float32) for x in xs])))
    cp = engine.plan_chain(Ls, Lout, backend=backend, dtype=dtype, gate=True)
    assert cp.gate and "+gate" in cp.describe()
    got = cp.apply(xs, gate_params=gp)
    assert got.dtype == jnp.dtype(dtype)
    assert_close(np.asarray(got).astype(np.float64), want, dtype=dtype,
                 tier="identity", tol=3e-5 if dtype == "float32" else None)


@pytest.mark.parametrize("backend", ["tree", "fused_xla", "fused_pallas"])
def test_gated_chain_resident_exit(backend):
    """A gated plan's out_basis='fourier' exit gates the product grid
    in-basis (no extra conversions) and projects back to the gated SH out."""
    Ls, B, C = (1, 2, 1), 4, 3
    Ltot = sum(Ls)
    xs = [_rand((B, C, num_coeffs(L)), 320 + i) for i, L in enumerate(Ls)]
    gp = _gate_params(C, 330)
    cp = engine.plan_chain(Ls, Ltot, backend=backend, gate=True)
    want = np.asarray(cp.apply(xs, gate_params=gp))
    rep = cp.apply(xs, out_basis="fourier", gate_params=gp)
    assert rep.is_fourier and rep.L == Ltot
    np.testing.assert_allclose(np.asarray(rep.to_sh().data), want,
                               rtol=1e-4, atol=1e-4)


def test_gated_looped_has_no_resident_exit():
    cp = engine.plan_chain((1, 1), 2, backend="looped", gate=True)
    xs = [_rand((4, 2, num_coeffs(1)), 340 + i) for i in range(2)]
    with pytest.raises(ValueError, match="no resident exit"):
        cp.apply(xs, out_basis="fourier", gate_params=_gate_params(2, 341))


def test_gated_chain_single_pallas_call():
    """The acceptance proof: the gate-fused chain is still ONE pallas_call —
    dispatch counter ticks once, and the traced jaxpr holds exactly one
    pallas_call primitive (the gate rides the kernel's pointwise stage, it
    does not add a dispatch)."""
    Ls, Lout, B, C = (2, 2, 2), 2, 8, 3
    xs = [_rand((B, C, num_coeffs(L)), 350 + i) for i, L in enumerate(Ls)]
    gp = _gate_params(C, 360)
    cp = engine.plan_chain(Ls, Lout, backend="fused_pallas", gate=True)
    reset_kernel_stats()
    jax.block_until_ready(cp.apply(xs, gate_params=gp))
    assert kernel_stats()["chain_pallas_calls"] == 1
    jaxpr = jax.make_jaxpr(
        lambda a, b, c, p: cp.apply([a, b, c], gate_params=p))(*xs, gp)
    assert _count_pallas_eqns(jaxpr.jaxpr) == 1


def test_gated_chain_grad_matches_xla():
    """The extended custom VJP: gradients through the fused gate (wrt both
    an operand and the gate MLP weights) match the XLA reference kernel."""
    Ls, Lout, B, C = (2, 1, 2), 3, 4, 3
    xs = [_rand((B, C, num_coeffs(L)), 370 + i) for i, L in enumerate(Ls)]
    gp = _gate_params(C, 380)
    plans = [engine.plan_chain(Ls, Lout, backend=b, gate=True)
             for b in ("fused_pallas", "fused_xla")]

    def loss(plan):
        return lambda a, p: jnp.sum(
            plan.apply([a, xs[1], xs[2]], gate_params=p) ** 2)

    gx_p, gw_p = jax.grad(loss(plans[0]), argnums=(0, 1))(xs[0], gp)
    gx_x, gw_x = jax.grad(loss(plans[1]), argnums=(0, 1))(xs[0], gp)
    np.testing.assert_allclose(np.asarray(gx_p), np.asarray(gx_x),
                               rtol=2e-3, atol=2e-3)
    for k in ("w1", "w2"):
        np.testing.assert_allclose(np.asarray(gw_p[k]), np.asarray(gw_x[k]),
                                   rtol=2e-3, atol=2e-3)


def test_gated_plan_params_validation():
    cp = engine.plan_chain((1, 1), 2, backend="tree", gate=True)
    cpu = engine.plan_chain((1, 1), 2, backend="tree")
    xs = [_rand((4, 2, num_coeffs(1)), 390 + i) for i in range(2)]
    with pytest.raises(ValueError, match="gate_params"):
        cp.apply_jit(xs)
    with pytest.raises(ValueError, match="ungated"):
        cpu.apply_jit(xs, gate_params=_gate_params(2, 391))


def test_gated_chain_rotation_equivariance():
    """The fused gate is equivariant: its scalars are l=0 functions of the
    operands (rotation-invariant), so gating commutes with rotation."""
    Ls, Lout, C = (2, 1, 2), 2, 3
    ang = random_angles(seed=6)
    xs = [np.asarray(random_irreps(L, (5, C), seed=400 + i))
          for i, L in enumerate(Ls)]
    gp = _gate_params(C, 410)
    cp = engine.plan_chain(Ls, Lout, backend="fused_pallas", gate=True)
    out = np.asarray(cp.apply([jnp.asarray(x) for x in xs], gate_params=gp))
    out_rot = np.asarray(cp.apply(
        [jnp.asarray(rotate_irreps(x, L, ang)) for x, L in zip(xs, Ls)],
        gate_params=gp))
    np.testing.assert_allclose(out_rot, rotate_irreps(out, Lout, ang),
                               rtol=2e-3, atol=2e-3)


def test_gate_autotune_keys_and_policy():
    """Gated plans measure under their own key (("gate", 1) appended — the
    ungated persisted keys stay byte-identical), and select_gate caches a
    ("gate", "policy") entry whose value is 'grid' or 'sh'."""
    eng = engine.GauntEngine()
    Ls, B = (1, 1), 64
    cp = eng.plan_chain(Ls, 2, tune="measure", batch_hint=B, gate=True)
    assert cp.backend in engine.CHAIN_BACKENDS and cp.gate
    key = engine.PlanKey(1, 1, 2, kind="chain", batch_hint=B,
                         dtype="float32",
                         extra=(("Ls", Ls), ("entries", ("sh", "sh")),
                                ("out", "sh"), ("share", (0, 1)),
                                ("gate", 1)))
    assert eng._measured[key] == cp.backend
    # ungated key is untouched by the gated measurement
    ukey = engine.PlanKey(1, 1, 2, kind="chain", batch_hint=B,
                          dtype="float32",
                          extra=(("Ls", Ls), ("entries", ("sh", "sh")),
                                 ("out", "sh"), ("share", (0, 1))))
    assert ukey not in eng._measured
    pol = eng.select_gate(Ls, 2, batch_hint=B)
    assert pol in ("grid", "sh")
    pkey = engine.PlanKey(1, 1, 2, kind="chain", batch_hint=B,
                          dtype="float32",
                          extra=(("Ls", Ls), ("entries", ("sh", "sh")),
                                 ("out", "sh"), ("share", (0, 1)),
                                 ("gate", "policy")))
    assert eng._measured[pkey] == pol
    # cached: a second call re-times nothing
    runs = eng.timing_runs
    assert eng.select_gate(Ls, 2, batch_hint=B) == pol
    assert eng.timing_runs == runs


# --------------------------------------------------------------------------
# mixed-precision: the chain-entry dtype rule (DESIGN.md §3.6)
# --------------------------------------------------------------------------


@pytest.mark.parametrize("backend", engine.CHAIN_BACKENDS)
def test_chain_mixed_dtype_operands_cast_at_entry(backend):
    """THE chain-entry rule: SH operands arriving in a different storage
    dtype are cast ONCE at entry to the plan's storage dtype — uniformly
    across every chain backend, never backend-dependent.  An f32 plan fed
    mixed bf16/f32 operands returns f32 within bf16 input-quantization
    error; a bf16 plan fed f32 operands returns bf16."""
    Ls, Lout, B = (2, 1, 2), 2, 8
    xs32 = [_rand((B, num_coeffs(L)), 200 + i) for i, L in enumerate(Ls)]
    mixed = [xs32[0].astype(jnp.bfloat16), xs32[1],
             xs32[2].astype(jnp.bfloat16)]
    cp = engine.plan_chain(Ls, Lout, backend=backend)
    ref = np.asarray(cp.apply(xs32))
    got = cp.apply(mixed)
    assert got.dtype == jnp.float32, backend
    assert_close(np.asarray(got).astype(np.float64), ref,
                 dtype="bfloat16", tier="identity")
    cpb = engine.plan_chain(Ls, Lout, backend=backend, dtype="bfloat16")
    gotb = cpb.apply(xs32)
    assert gotb.dtype == jnp.bfloat16, backend
    assert_close(np.asarray(gotb).astype(np.float64), ref,
                 dtype="bfloat16", tier="identity")


# --------------------------------------------------------------------------
# chain autotune: measured, keyed like plans, cached
# --------------------------------------------------------------------------


def test_chain_autotune_measures_and_caches():
    eng = engine.GauntEngine()
    cp = eng.plan_chain((1, 1, 1), 1, tune="measure", batch_hint=64)
    assert cp.backend in engine.CHAIN_BACKENDS
    # keyed like plans: the measured selection is cached on the engine
    key = engine.PlanKey(1, 1, 1, kind="chain", batch_hint=64,
                         dtype="float32",
                         extra=(("Ls", (1, 1, 1)),
                                ("entries", ("sh", "sh", "sh")),
                                ("out", "sh"), ("share", (0, 1, 2))))
    assert eng._measured[key] == cp.backend
    assert eng.plan_chain((1, 1, 1), 1, tune="measure", batch_hint=64) is cp
    # heuristic default stays the resident tree (the counter-test contract)
    assert eng.plan_chain((1, 1, 1), 1).backend == "tree"
    # an explicit conversion pins the spectral pipeline
    assert eng.plan_chain((1, 1, 1), 1, conversion="dense",
                          tune="measure").backend == "tree"


def test_chain_autotune_entry_hint_keys_and_measures_resident():
    """Resident call sites measure on resident operands: the entry_hint is
    part of the autotune key, and the selected backend reproduces the tree
    result when fed the hinted operand kinds."""
    eng = engine.GauntEngine()
    Ls, Lout, B = (2, 2), 2, 16
    cp = eng.plan_chain(Ls, Lout, tune="measure", batch_hint=B,
                        entry_hint=("sh", "fourier"))
    assert cp.backend in engine.CHAIN_BACKENDS
    key = engine.PlanKey(2, 2, Lout, kind="chain", batch_hint=B,
                         dtype="float32",
                         extra=(("Ls", Ls), ("entries", ("sh", "fourier")),
                                ("out", "sh"), ("share", (0, 1))))
    assert eng._measured[key] == cp.backend
    x = _rand((B, num_coeffs(2)), 150)
    f = _rand((B, num_coeffs(2)), 151)
    rep = Rep.from_sh(f, 2).to_fourier("half")
    want = eng.plan_chain(Ls, Lout, backend="tree").apply([x, rep])
    got = cp.apply([x, rep])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
    with pytest.raises(ValueError):
        eng.plan_chain(Ls, Lout, tune="measure", entry_hint=("sh", "bogus"))


def test_chain_autotune_share_hint_measures_duplicates():
    """Selfmix-style [A]*nu chains measure with ONE repeated synthetic
    buffer (tree's shared single conversion engages in the timing), keyed
    separately from the all-distinct chain."""
    eng = engine.GauntEngine()
    Ls, B = (2, 2, 2), 32
    cp = eng.plan_chain(Ls, 2, tune="measure", batch_hint=B,
                        share_hint=(0, 0, 0))
    assert cp.backend in engine.CHAIN_BACKENDS
    key = engine.PlanKey(2, 2, 2, kind="chain", batch_hint=B,
                         dtype="float32",
                         extra=(("Ls", Ls), ("entries", ("sh",) * 3),
                                ("out", "sh"), ("share", (0, 0, 0))))
    assert eng._measured[key] == cp.backend
    x = _rand((B, num_coeffs(2)), 160)
    ws = [_rand((B, 3), 170 + i) for i in range(3)]
    want = eng.plan_chain(Ls, 2, backend="tree").apply_jit(
        [x, x, x], weights=ws)
    got = cp.apply_jit([x, x, x], weights=ws)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
    with pytest.raises(ValueError):
        eng.plan_chain(Ls, 2, tune="measure", share_hint=(0, 0))


def test_chain_autotune_result_matches_tree():
    eng = engine.GauntEngine()
    Ls, Lout, B = (2, 2), 2, 32
    xs = [_rand((B, num_coeffs(L)), 110 + i) for i, L in enumerate(Ls)]
    cp = eng.plan_chain(Ls, Lout, tune="measure", batch_hint=B)
    tree = eng.plan_chain(Ls, Lout, backend="tree")
    np.testing.assert_allclose(np.asarray(cp.apply_jit(xs)),
                               np.asarray(tree.apply_jit(xs)),
                               rtol=1e-4, atol=1e-4)


def test_fused_cost_calibration():
    """The skinny-matmul factor is a calibration constant, not a literal:
    measured installs override the default and the fused cost moves with it."""
    from repro.core.engine import (PlanKey, _cost_fused, get_calibration,
                                   set_calibration)

    base = get_calibration()
    try:
        key = PlanKey(4, 4, 4, kind="pairwise", batch_hint=256)
        set_calibration(fused_skinny=2.0, fused_skinny_measured=True)
        c2 = _cost_fused(key, pallas=False)
        set_calibration(fused_skinny=8.0)
        c8 = _cost_fused(key, pallas=False)
        assert c8 > c2
        with pytest.raises(ValueError):
            set_calibration(nonsense=1.0)
    finally:
        set_calibration(**base)
    # the measuring entry point installs a sane factor and reports it
    eng = engine.get_engine()
    rec = eng.calibrate_fused(L=2, B=32)
    assert 0.25 <= rec["factor"] <= 16.0
    assert get_calibration()["fused_skinny_measured"]


# --------------------------------------------------------------------------
# sharded chains: ragged rows pad/slice over the device count
# --------------------------------------------------------------------------


def test_sharded_chain_ragged_rows_two_devices():
    """Chain shard_map granularity (ROADMAP): a 2-virtual-device shard_map
    chain with a row count that does NOT divide the device count pads, runs
    per-shard, slices — matching the unsharded chain exactly (the old code
    silently fell back to the constrained combine)."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import jax, jax.numpy as jnp, numpy as np
from repro.core import engine
from repro.core.irreps import num_coeffs

assert jax.device_count() == 2
mesh = jax.make_mesh((2,), ("data",))
L = 2
for rows in (5, 7):  # ragged: neither divides 2
    xs = [jnp.asarray(np.random.default_rng(10 + i).normal(
        size=(rows, num_coeffs(L))), jnp.float32) for i in range(3)]
    ref = engine.plan_chain((L,) * 3, L).apply_jit(list(xs))
    sp = engine.ShardSpec(mesh=mesh, axes=("data",), mode="shard_map")
    cp = engine.plan_chain((L,) * 3, L, shard_spec=sp)
    got = cp.apply_jit(list(xs))
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)
    # the per-shard combine really ran (not the constrained fallback): the
    # jaxpr of the sharded apply contains a shard_map primitive
    jaxpr = jax.make_jaxpr(lambda a, b, c: cp.apply([a, b, c]))(*xs)
    names = set()
    def walk(jx):
        for e in jx.eqns:
            names.add(e.primitive.name)
            for sub in jax.core.jaxprs_in_params(e.params):
                walk(sub.jaxpr if hasattr(sub, "jaxpr") else sub)
    walk(jaxpr.jaxpr)
    assert any("shard_map" in n for n in names), sorted(names)
print("RAGGED_OK")
"""
    src = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
    env = dict(os.environ)
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=900)
    assert "RAGGED_OK" in out.stdout, (out.stdout[-2000:], out.stderr[-2000:])


# --------------------------------------------------------------------------
# consumers inherit the dispatch
# --------------------------------------------------------------------------


def test_manybody_tune_measure_matches_default():
    from repro.core.manybody import manybody_gaunt_product

    Ls, B = (2, 2, 2), 16
    xs = [_rand((B, num_coeffs(L)), 120 + i) for i, L in enumerate(Ls)]
    ref = manybody_gaunt_product(xs, Ls, Lout=2)
    got = manybody_gaunt_product(xs, Ls, Lout=2, tune="measure")
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)
    # a measured plan must still serve a resident exit: out_basis='fourier'
    # excludes the exit-less 'looped' candidate via the out hint
    rep = manybody_gaunt_product(xs, Ls, tune="measure", out_basis="fourier")
    ref_rep = manybody_gaunt_product(xs, Ls, out_basis="fourier")
    assert rep.is_fourier and rep.L == sum(Ls)
    np.testing.assert_allclose(np.asarray(rep.with_form("half").data),
                               np.asarray(ref_rep.with_form("half").data),
                               rtol=1e-4, atol=1e-4)


def test_selfmix_layer_tune_measure_matches_default():
    from repro.models.equivariant import SelfmixLayer

    L, C = 2, 3
    x = _rand((5, C, num_coeffs(L)), 130)
    layer = SelfmixLayer(L=L, channels=C, tp_impl="gaunt")
    params = layer.init(jax.random.PRNGKey(0))
    layer_m = SelfmixLayer(L=L, channels=C, tp_impl="gaunt", tune="measure")
    np.testing.assert_allclose(np.asarray(layer_m(params, x)),
                               np.asarray(layer(params, x)),
                               rtol=1e-4, atol=1e-4)


def test_segnn_chain_tune_measure_matches_default():
    from repro.configs.gaunt_ff import EquivariantConfig
    from repro.models.equivariant import SegnnNBody

    import dataclasses

    cfg = EquivariantConfig(name="t", kind="segnn", L=1, L_edge=1, channels=4,
                            n_layers=2)
    n = 5
    rng = np.random.default_rng(140)
    charge = jnp.asarray(rng.normal(size=(n,)), jnp.float32)
    pos = jnp.asarray(rng.normal(size=(n, 3)), jnp.float32)
    vel = jnp.asarray(rng.normal(size=(n, 3)), jnp.float32)
    m = SegnnNBody(cfg)
    params = m.init(jax.random.PRNGKey(1))
    ref = m.forward(params, charge, pos, vel)
    m_meas = SegnnNBody(dataclasses.replace(cfg, chain_tune="measure"))
    got = m_meas.forward(params, charge, pos, vel)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-3, atol=1e-3)
