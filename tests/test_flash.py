"""Flash attention (custom VJP) vs materialized attention — values & grads."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import blockwise_attention, full_attention


def _qkv(B, Tq, Tk, H, KV, hd, seed=0):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(B, Tq, H, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, Tk, KV, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, Tk, KV, hd)), jnp.float32)
    return q, k, v


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("B,T,H,KV,hd,qc,kc", [
    (2, 64, 4, 2, 16, 16, 16),
    (1, 128, 4, 4, 8, 32, 64),
    (2, 64, 6, 2, 16, 64, 16),
])
def test_flash_forward_matches_full(causal, B, T, H, KV, hd, qc, kc):
    q, k, v = _qkv(B, T, T, H, KV, hd)
    o1 = blockwise_attention(q, k, v, causal=causal, q_chunk=qc, kv_chunk=kc)
    o2 = full_attention(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("causal", [True, False])
def test_flash_grads_match_full(causal):
    B, T, H, KV, hd = 2, 64, 4, 2, 16
    q, k, v = _qkv(B, T, T, H, KV, hd, seed=1)

    def loss_flash(q, k, v):
        o = blockwise_attention(q, k, v, causal=causal, q_chunk=16, kv_chunk=16)
        return jnp.sum(o * jnp.cos(o))

    def loss_full(q, k, v):
        o = full_attention(q, k, v, causal=causal)
        return jnp.sum(o * jnp.cos(o))

    g1 = jax.grad(loss_flash, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(loss_full, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=3e-4, rtol=3e-4)


def test_flash_q_offset_decode_chunk():
    """Query block appended at offset (speculative/chunked decode pattern)."""
    B, Tk, H, KV, hd = 1, 64, 4, 2, 16
    q, k, v = _qkv(B, 16, Tk, H, KV, hd, seed=2)
    off = 48  # the 16 queries sit at positions 48..63
    o1 = blockwise_attention(q, k, v, causal=True, q_chunk=8, kv_chunk=16, q_offset=off)
    o2 = full_attention(q, k, v, causal=True, q_offset=off)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=2e-5, rtol=2e-5)
